//! TABLE 2 — final test accuracy under extreme bit budgets (1 and 2 bits
//! per parameter) + the extra-memory column.
//!
//! Workload: 8 workers, ring, MLP on the synthetic 10-class task (ResNet20/
//! CIFAR10 stand-in, DESIGN.md §Hardware-Adaptation). Baselines use the
//! same stochastic-rounding quantizer as the paper ("for fair comparison we
//! consistently use stochastic rounding"); Moniqua at 1 bit uses nearest
//! rounding + the Theorem-3 slack matrix (its supported biased-quantizer
//! mode — 1-bit *stochastic* has δ = ½, outside Lemma 2).
//!
//! Expected shape: DCD and ECD diverge; ChocoSGD, DeepSqueeze and Moniqua
//! converge near the full-precision reference; extra memory is
//! Θ(md) / Θ(md) / 0 respectively.
//!
//! Run: `cargo bench --offline --bench bench_table2_lowbit`

use std::sync::Arc;

use moniqua::algorithms::{Algorithm, ThetaPolicy};
use moniqua::bench_support::{section, BenchJson};
use moniqua::coordinator::{TrainConfig, Trainer};
use moniqua::data::{partition::Partition, SynthClassification, SynthSpec};
use moniqua::objectives::{Mlp, Objective};
use moniqua::quant::{QuantConfig, Rounding};
use moniqua::topology::Topology;

fn main() {
    let bench_t0 = std::time::Instant::now();
    let mut json = BenchJson::new("table2_lowbit");
    let fast = std::env::var("MONIQUA_FAST").is_ok();
    let workers = 8;
    let steps = if fast { 100 } else { 1200 };
    let data = Arc::new(SynthClassification::generate(SynthSpec::default()));
    let make_objective = || -> Box<dyn Objective> {
        Box::new(Mlp::new(Arc::clone(&data), workers, Partition::Iid, 32, 32, 3))
    };
    let d = make_objective().dim();
    let m = Topology::Ring(workers).edge_count();
    println!("MLP d = {d}, ring m = {m} edges, {steps} steps\n");

    // Full-precision reference ("state of the art" row of Table 2).
    let ref_report = {
        let cfg = TrainConfig {
            workers,
            steps,
            lr: 0.1,
            decay_factor: 0.1,
            decay_at: vec![steps * 3 / 4],
            algorithm: Algorithm::DPsgd,
            eval_every: steps / 8,
            seed: 3,
            network: None,
            ..TrainConfig::default()
        };
        Trainer::new(cfg, Topology::Ring(workers), make_objective()).run()
    };
    println!(
        "full-precision D-PSGD reference accuracy: {:.1}%\n",
        ref_report.final_accuracy().unwrap() * 100.0
    );
    json.scenario(
        "fp32.dpsgd",
        ref_report.final_sim_time(),
        ref_report.total_bytes,
        ref_report.final_loss(),
    );

    println!(
        "{:<8} {:<14} {:>10} {:>9} {:>14}",
        "budget", "algorithm", "verdict", "accuracy", "extra_mem(KB)"
    );
    for bits in [1u32, 2] {
        section(&format!("budget: {bits} bit/param"));
        let qb = QuantConfig::stochastic(bits);
        let mq = QuantConfig { rounding: Rounding::Nearest, ..qb };
        let gamma = if bits == 1 { 0.05 } else { 0.2 };
        let rows: Vec<(&str, Algorithm)> = vec![
            ("dcd", Algorithm::Dcd { quant: qb, range: 4.0 }),
            ("ecd", Algorithm::Ecd { quant: qb, range: 16.0 }),
            ("choco", Algorithm::Choco { quant: qb, range: 4.0, gamma }),
            (
                "deepsqueeze",
                Algorithm::DeepSqueeze { quant: qb, range: 4.0, gamma },
            ),
            (
                "moniqua",
                Algorithm::MoniquaSlack {
                    theta: ThetaPolicy::Constant(2.0),
                    quant: mq,
                    gamma: if bits == 1 { 0.2 } else { 0.5 },
                },
            ),
        ];
        for (name, algorithm) in rows {
            let extra = algorithm.extra_memory_floats(workers, m, d);
            let cfg = TrainConfig {
                workers,
                steps,
                lr: 0.1,
                decay_factor: 0.1,
                decay_at: vec![steps * 3 / 4],
                algorithm,
                eval_every: steps / 8,
                seed: 3,
                network: None,
                ..TrainConfig::default()
            };
            let report = Trainer::new(cfg, Topology::Ring(workers), make_objective()).run();
            let loss = report.final_loss();
            let diverged = !loss.is_finite() || loss > 2.0;
            json.scenario(
                &format!("{bits}bit.{name}"),
                report.final_sim_time(),
                report.total_bytes,
                loss,
            );
            println!(
                "{:<8} {:<14} {:>10} {:>8} {:>14.1}",
                format!("{bits}bit"),
                name,
                if diverged { "diverge" } else { "converged" },
                if diverged {
                    "-".to_string()
                } else {
                    format!("{:.1}%", report.final_accuracy().unwrap() * 100.0)
                },
                extra as f64 * 4.0 / 1e3,
            );
        }
    }
    println!(
        "\n(Moniqua extra memory is exactly 0; DeepSqueeze Θ(nd) < ChocoSGD/DCD/ECD Θ(md) — Table 1/2.)"
    );
    json.metric("wall_s", bench_t0.elapsed().as_secs_f64());
    json.write().expect("write bench json");
}
