//! §4 "Bound on the Bits" — the dimension-free O(log log n) bit budget.
//!
//! For rings and expanders of growing size this bench prints:
//!   * the measured spectral quantity ρ,
//!   * the theoretical bound B ≤ ⌈log₂(4·log₂(16n)/(1−ρ) + 3)⌉,
//!   * the *empirically sufficient* bits: the smallest budget at which
//!     Moniqua (with the Theorem-2 θ/δ settings) still reaches the
//!     full-precision loss on a decentralized quadratic,
//!   * the same check at two very different dimensions d (the bound is
//!     dimension-free — the empirical budget must not grow with d).
//!
//! Run: `cargo bench --offline --bench bench_bits_bound`

use moniqua::algorithms::{Algorithm, StepCtx, SyncAlgorithm, ThetaPolicy};
use moniqua::bench_support::{section, BenchJson};
use moniqua::quant::theta::{bits_bound, theta_theorem2};
use moniqua::quant::QuantConfig;
use moniqua::topology::{CommMatrix, Topology};

/// Final mean loss of a short decentralized quadratic run.
fn run_quadratic(w: &CommMatrix, mut alg: Box<dyn SyncAlgorithm>, d: usize, steps: u64) -> f64 {
    let n = w.n();
    let rho = w.rho();
    let c = 0.3f32;
    let mut xs: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0; d]).collect();
    let ctx = StepCtx { seed: 7, rho, g_inf: 1.0 };
    for k in 0..steps {
        let grads: Vec<Vec<f32>> = xs
            .iter()
            .map(|x| x.iter().map(|&v| v - c).collect())
            .collect();
        alg.step(&mut xs, &grads, 0.1, k, &ctx);
    }
    xs.iter()
        .map(|x| x.iter().map(|&v| ((v - c) as f64).powi(2)).sum::<f64>())
        .sum::<f64>()
        / n as f64
}

fn empirical_bits(w: &CommMatrix, d: usize, steps: u64, target: f64) -> u32 {
    let rho = w.rho();
    let n = w.n();
    for bits in 2..=12u32 {
        let theta = theta_theorem2(0.1, 1.0, n, rho) as f32;
        let alg = Algorithm::Moniqua {
            theta: ThetaPolicy::Constant(theta),
            quant: QuantConfig::stochastic(bits),
        };
        let loss = run_quadratic(w, alg.make_sync(w, d), d, steps);
        if loss <= target {
            return bits;
        }
    }
    13
}

fn main() {
    let bench_t0 = std::time::Instant::now();
    let mut json = BenchJson::new("bits_bound");
    let fast = std::env::var("MONIQUA_FAST").is_ok();
    let steps = if fast { 100 } else { 400 };
    let sizes: &[usize] = if fast { &[4, 8, 16] } else { &[4, 8, 16, 32, 64, 128] };

    section("ring topology: bits bound vs n (dimension-free, O(log log n))");
    println!(
        "{:>6} {:>8} {:>12} {:>16} {:>16}",
        "n", "rho", "bound(bits)", "empirical(d=16)", "empirical(d=256)"
    );
    for &n in sizes {
        let w = Topology::Ring(n).comm_matrix();
        let rho = w.rho();
        // full-precision reference loss → target = 2x that (same ballpark)
        let ref_loss = run_quadratic(&w, Algorithm::DPsgd.make_sync(&w, 16), 16, steps);
        let target = (ref_loss * 4.0).max(1e-4);
        let e16 = empirical_bits(&w, 16, steps, target);
        let e256 = empirical_bits(&w, 256, steps, target * 16.0); // scale w/ d
        println!(
            "{:>6} {:>8.4} {:>12} {:>16} {:>16}",
            n,
            rho,
            bits_bound(n, rho),
            e16,
            e256
        );
        json.metric(&format!("ring{n}.bound_bits"), bits_bound(n, rho) as f64)
            .metric(&format!("ring{n}.empirical_bits_d16"), e16 as f64)
            .metric(&format!("ring{n}.empirical_bits_d256"), e256 as f64);
    }

    section("expander (random 4-regular): better gap → smaller bound");
    println!("{:>6} {:>8} {:>12} {:>16}", "n", "rho", "bound(bits)", "empirical(d=16)");
    for &n in sizes.iter().filter(|&&n| n >= 8) {
        let w = Topology::RandomRegular { n, degree: 4, seed: 5 }.comm_matrix();
        let rho = w.rho();
        let ref_loss = run_quadratic(&w, Algorithm::DPsgd.make_sync(&w, 16), 16, steps);
        let target = (ref_loss * 4.0).max(1e-4);
        let emp = empirical_bits(&w, 16, steps, target);
        println!("{:>6} {:>8.4} {:>12} {:>16}", n, rho, bits_bound(n, rho), emp);
        json.metric(&format!("regular4_{n}.bound_bits"), bits_bound(n, rho) as f64)
            .metric(&format!("regular4_{n}.empirical_bits_d16"), emp as f64);
    }
    println!("\n(paper: bound grows O(log log n) and is independent of d; expanders need fewer bits than rings)");
    json.metric("wall_s", bench_t0.elapsed().as_secs_f64());
    json.write().expect("write bench json");
}
