//! FIGURE 2(b) — Moniqua on AD-PSGD (asynchronous gossip), wall-clock.
//!
//! 6 workers on a ring, 20 Mbps / 0.15 ms network (the paper's tc setting),
//! straggler-prone compute. Three systems:
//!
//!   * synchronous D-PSGD — pays max-over-workers compute each round,
//!   * AD-PSGD (full-precision async) — no barrier,
//!   * Moniqua-AD-PSGD (Algorithm 3) — async + quantized exchange with the
//!     Theorem-5 settings θ = 16·t_mix·α·G∞, δ = 1/(64·t_mix+2).
//!
//! Expected shape: both async variants beat sync D-PSGD in time-to-loss;
//! Moniqua-AD beats AD because each gossip message is ~4x smaller.
//!
//! Run: `cargo bench --offline --bench bench_fig2b_adpsgd`

use std::sync::Arc;

use moniqua::algorithms::{AdPsgd, Algorithm, AsyncVariant};
use moniqua::bench_support::{section, BenchJson};
use moniqua::coordinator::{AsyncTrainer, TrainConfig, Trainer};
use moniqua::data::{partition::Partition, SynthClassification, SynthSpec};
use moniqua::network::NetworkConfig;
use moniqua::objectives::{Mlp, Objective};
use moniqua::quant::theta::{delta_adpsgd, theta_adpsgd};
use moniqua::quant::QuantConfig;
use moniqua::topology::Topology;

fn main() {
    let bench_t0 = std::time::Instant::now();
    let mut json = BenchJson::new("fig2b_adpsgd");
    let fast = std::env::var("MONIQUA_FAST").is_ok();
    let workers = 6;
    let topo = Topology::Ring(workers);
    // ResNet110 stand-in: a wider MLP so messages are network-visible.
    let data = Arc::new(SynthClassification::generate(SynthSpec {
        dim: 128,
        classes: 10,
        train_per_class: 100,
        test_per_class: 20,
        ..SynthSpec::default()
    }));
    let hidden = if fast { 32 } else { 256 };
    let make_objective = || -> Box<dyn Objective> {
        Box::new(Mlp::new(Arc::clone(&data), workers, Partition::Iid, hidden, 16, 9))
    };
    let d = make_objective().dim();
    println!("model d = {d} ({:.0} KB fp32/message)", d as f64 * 4.0 / 1e3);

    let net = NetworkConfig::fig2b();
    let grad_time = 50e-3;
    let straggler = 0.4;
    let events = if fast { 300 } else { 3000 };
    // sync rounds pay E[max over n lognormal compute samples] — straggler tax
    let sync_straggler_factor = 1.0 + straggler * (2.0 * (workers as f64).ln()).sqrt();

    section("sync D-PSGD (straggler-taxed rounds)");
    let sync_steps = (events / workers as u64).max(10);
    let cfg = TrainConfig {
        workers,
        steps: sync_steps,
        lr: 0.1,
        algorithm: Algorithm::DPsgd,
        network: Some(net),
        grad_time_s: Some(grad_time * sync_straggler_factor),
        eval_every: (sync_steps / 10).max(1),
        seed: 9,
        ..TrainConfig::default()
    };
    let sync_report = Trainer::new(cfg, topo.clone(), make_objective()).run();
    for row in &sync_report.trace {
        println!("  step {:>5} t={:>8.2}s loss={:.4}", row.step, row.sim_time_s, row.eval_loss);
    }

    let t_mix = AdPsgd::estimate_t_mix(&topo, 1, 1_000_000) as f64;
    let theta = theta_adpsgd(0.1, 1.0, t_mix) as f32;
    let delta = delta_adpsgd(t_mix);
    let bits = ((1.0 / delta).log2().ceil() as u32).clamp(2, 12);
    println!("\nTheorem-5: t_mix = {t_mix}, theta = {theta:.2}, delta = {delta:.5} → {bits} bits");

    json.scenario(
        "dpsgd-sync",
        sync_report.final_sim_time(),
        sync_report.total_bytes,
        sync_report.final_loss(),
    );
    let mut finals = vec![("dpsgd(sync)", sync_report.final_sim_time(), sync_report.final_loss())];
    for (name, variant) in [
        ("adpsgd", AsyncVariant::FullPrecision),
        (
            "moniqua-adpsgd",
            AsyncVariant::Moniqua { theta, quant: QuantConfig::stochastic(8) },
        ),
    ] {
        section(name);
        let mut trainer = AsyncTrainer {
            topo: topo.clone(),
            objective: make_objective(),
            variant,
            network: net,
            grad_time_s: grad_time,
            straggler,
            lr: 0.1,
            events,
            eval_every: (events / 10).max(1),
            seed: 9,
        };
        let r = trainer.run();
        for row in &r.trace {
            println!("  event {:>6} t={:>8.2}s loss={:.4}", row.step, row.sim_time_s, row.eval_loss);
        }
        json.scenario(name, r.final_sim_time(), r.total_bytes, r.final_loss());
        finals.push((name, r.final_sim_time(), r.final_loss()));
    }

    section("summary: time to finish equal gradient-update budget");
    for (name, t, loss) in &finals {
        println!("  {name:<16} {t:>8.2}s   final loss {loss:.4}");
    }
    println!("(expected: adpsgd < dpsgd in time; moniqua-adpsgd < adpsgd — Figure 2b)");
    json.metric("wall_s", bench_t0.elapsed().as_secs_f64());
    json.write().expect("write bench json");
}
