//! Byzantine fault sweep — attack mode × adversary count on the cluster
//! runtime, with the defense plane live (rust/DESIGN.md
//! §Adversarial-robustness).
//!
//! Three questions, answered with numbers in `BENCH_byzantine.json`:
//!
//! * **What does the defense cost?** Zero-adversary runs with the gate off
//!   vs on (the +8 B machine seal on raw-f32 engines, the §6 digest on
//!   Moniqua) price the overhead in wall time and wire bytes.
//! * **Does the cohort survive the attack?** Every `byz_mode` at 1 and 2
//!   adversaries on a 6-ring, recording final loss, quarantine counts, and
//!   typed reject counters. The acceptance bar: attacked final loss within
//!   2× the fault-free run of the same engine.
//! * **What does the robust mix buy?** Wrap against a raw-f32 engine is
//!   seal-valid (no digest exists to convict it), so the clipped mix is
//!   the only defense — its loss is reported next to the plain mean's.
//!
//! Run: `cargo bench --offline --bench bench_byzantine`
//! (`MONIQUA_BENCH_QUICK=1` / `MONIQUA_FAST=1` shrinks the grid.)

use moniqua::adversary::{ByzMode, ByzantineConfig};
use moniqua::algorithms::{Algorithm, MixPolicy, ThetaPolicy};
use moniqua::bench_support::{quick_mode, section, BenchJson};
use moniqua::coordinator::{ClusterConfig, ClusterTrainer, Report, TrainConfig};
use moniqua::objectives::{Objective, Quadratic};
use moniqua::quant::QuantConfig;
use moniqua::telemetry::Counter;
use moniqua::topology::Topology;

const WORKERS: usize = 6;

fn config(steps: u64, algorithm: Algorithm, verify_wire: bool, mix: MixPolicy) -> TrainConfig {
    TrainConfig {
        workers: WORKERS,
        steps,
        lr: 0.1,
        algorithm,
        network: None,
        grad_time_s: Some(0.0),
        eval_every: steps.max(4) / 4,
        seed: 7,
        verify_wire,
        mix,
        ..TrainConfig::default()
    }
}

fn objective() -> Box<dyn Objective> {
    Box::new(Quadratic::new(24, 1.0, 0.1, WORKERS, 3))
}

struct RunOut {
    report: Report,
    wall_s: f64,
    digest_rejects: u64,
    replay_rejects: u64,
    equivocations: u64,
    quarantined: u64,
}

fn run_cluster(cfg: TrainConfig, byz: Option<ByzantineConfig>) -> RunOut {
    let mut t = ClusterTrainer::new(
        cfg,
        Topology::Ring(WORKERS),
        objective(),
        ClusterConfig { byz, ..ClusterConfig::default() },
    )
    .expect("cluster config accepted");
    let t0 = std::time::Instant::now();
    let report = t.run().expect("cluster run");
    let wall_s = t0.elapsed().as_secs_f64();
    assert!(t.failures.is_empty(), "run recorded failures: {:?}", t.failures);
    let snap = t.metrics().snapshot();
    RunOut {
        report,
        wall_s,
        digest_rejects: snap.counter(Counter::DigestRejects),
        replay_rejects: snap.counter(Counter::ReplayRejects),
        equivocations: snap.counter(Counter::EquivocationRejects),
        quarantined: snap.counter(Counter::QuarantinedPeers),
    }
}

fn final_loss(r: &Report) -> f64 {
    r.trace.last().expect("trace").eval_loss
}

fn main() {
    let bench_t0 = std::time::Instant::now();
    let mut json = BenchJson::new("byzantine");
    let fast = quick_mode();
    let steps: u64 = if fast { 12 } else { 40 };
    json.metric("steps", steps as f64);
    json.label("topology", &format!("ring/{WORKERS}"));

    let q8 = QuantConfig::stochastic(8);
    let moniqua_digest = Algorithm::Moniqua {
        theta: ThetaPolicy::Constant(2.0),
        quant: q8.with_verify_hash(true),
    };

    // ------------------------------------------------------------------
    section("defense overhead (zero adversaries, gate off vs on)");
    println!(
        "{:<20} {:>10} {:>14} {:>12}",
        "engine", "gate", "total_bytes", "wall_s"
    );
    for (name, algorithm, verify_wire) in [
        ("dpsgd", Algorithm::DPsgd, true),
        ("moniqua-q8", moniqua_digest.clone(), false),
    ] {
        let off = run_cluster(
            config(
                steps,
                match &algorithm {
                    Algorithm::Moniqua { theta, .. } => {
                        Algorithm::Moniqua { theta: *theta, quant: q8 }
                    }
                    a => a.clone(),
                },
                false,
                MixPolicy::Mean,
            ),
            None,
        );
        let on = run_cluster(config(steps, algorithm, verify_wire, MixPolicy::Mean), None);
        for (gate, r) in [("off", &off), ("on", &on)] {
            println!(
                "{:<20} {:>10} {:>14} {:>12.3}",
                name, gate, r.report.total_bytes, r.wall_s
            );
            json.scenario(
                &format!("{name}.gate_{gate}"),
                r.wall_s,
                r.report.total_bytes,
                final_loss(&r.report),
            );
        }
        assert_eq!(
            (on.digest_rejects, on.quarantined),
            (0, 0),
            "{name}: honest traffic struck the live gate"
        );
        json.metric(
            &format!("{name}.seal_byte_overhead"),
            on.report.total_bytes as f64 - off.report.total_bytes as f64,
        );
    }

    // ------------------------------------------------------------------
    section("attack sweep (mode × adversary count, defense live)");
    println!(
        "{:<12} {:>6} {:>12} {:>12} {:>8} {:>8} {:>8} {:>8}",
        "mode", "byz", "final_loss", "baseline", "digest", "replay", "equiv", "quar"
    );
    // Adversary masks on ring/6: worker 2, then workers 2 and 5
    // (non-adjacent, so each keeps two honest neighbors to convict it).
    let masks: &[(usize, u64)] = if fast { &[(1, 0b100)] } else { &[(1, 0b100), (2, 0b100100)] };
    // Wrap needs the §6 digest to convict (only a modulo decode sees the θ
    // escape); the other modes are caught by the machine seal on dpsgd.
    let cases: Vec<(&'static str, ByzMode, Algorithm, bool)> = vec![
        ("flip", ByzMode::Flip, Algorithm::DPsgd, true),
        ("replay", ByzMode::Replay, Algorithm::DPsgd, true),
        ("equivocate", ByzMode::Equivocate, Algorithm::DPsgd, true),
        ("wrap", ByzMode::Wrap, moniqua_digest.clone(), false),
    ];
    for (name, mode, algorithm, verify_wire) in &cases {
        let baseline = run_cluster(
            config(steps, algorithm.clone(), *verify_wire, MixPolicy::Mean),
            None,
        );
        let base_loss = final_loss(&baseline.report);
        json.scenario(
            &format!("{name}.byz0"),
            baseline.wall_s,
            baseline.report.total_bytes,
            base_loss,
        );
        for &(count, mask) in masks {
            let r = run_cluster(
                config(steps, algorithm.clone(), *verify_wire, MixPolicy::Mean),
                Some(ByzantineConfig { workers: mask, mode: *mode, strike_limit: 2 }),
            );
            let loss = final_loss(&r.report);
            println!(
                "{:<12} {:>6} {:>12.6} {:>12.6} {:>8} {:>8} {:>8} {:>8}",
                name,
                count,
                loss,
                base_loss,
                r.digest_rejects,
                r.replay_rejects,
                r.equivocations,
                r.quarantined,
            );
            let tag = format!("{name}.byz{count}");
            json.scenario(&tag, r.wall_s, r.report.total_bytes, loss);
            json.metric(&format!("{tag}.digest_rejects"), r.digest_rejects as f64);
            json.metric(&format!("{tag}.replay_rejects"), r.replay_rejects as f64);
            json.metric(&format!("{tag}.equivocations"), r.equivocations as f64);
            json.metric(&format!("{tag}.quarantined_peers"), r.quarantined as f64);
            // Each adversary is convicted once by each of its two honest
            // ring neighbors.
            assert_eq!(
                r.quarantined,
                2 * count as u64,
                "{tag}: adversaries not fully quarantined"
            );
            // The acceptance bar: attacked loss within 2x fault-free (the
            // tiny absolute slack only matters if both sit at the SGD
            // noise floor).
            assert!(
                loss.is_finite() && loss <= 2.0 * base_loss + 1e-9,
                "{tag}: attacked loss {loss} exceeds 2x fault-free {base_loss}"
            );
        }
    }

    // ------------------------------------------------------------------
    section("robust mix vs the seal-valid outlier attack (wrap on dpsgd)");
    // Honestly sealed wrap bytes pass the machine seal on a raw-f32 engine:
    // the gate stays silent and the robust mix is the only line of defense.
    println!("{:<12} {:>12} {:>8}", "mix", "final_loss", "quar");
    let mut wrap_losses: Vec<(&'static str, f64)> = Vec::new();
    for (name, mix) in [
        ("mean", MixPolicy::Mean),
        ("clipped", MixPolicy::Clipped(1.0)),
        ("median", MixPolicy::Median),
    ] {
        let r = run_cluster(
            config(steps, Algorithm::DPsgd, true, mix),
            Some(ByzantineConfig { workers: 0b100, mode: ByzMode::Wrap, strike_limit: 2 }),
        );
        let loss = final_loss(&r.report);
        println!("{:<12} {:>12.6} {:>8}", name, loss, r.quarantined);
        assert_eq!(r.quarantined, 0, "seal-valid wrap must not convict ({name})");
        json.scenario(
            &format!("wrap_undetected.mix_{name}"),
            r.wall_s,
            r.report.total_bytes,
            loss,
        );
        wrap_losses.push((name, loss));
    }
    let mean_loss = wrap_losses[0].1;
    for &(name, loss) in &wrap_losses[1..] {
        assert!(
            loss < mean_loss,
            "robust mix {name} did not improve on mean under wrap: {loss} vs {mean_loss}"
        );
    }

    json.metric("wall_s", bench_t0.elapsed().as_secs_f64());
    json.write().expect("write bench json");
}
