//! HOT-PATH MICROBENCH — the L3 quantize/recover/pack/unpack pipeline.
//!
//! This is the per-round per-neighbor work Moniqua adds on top of D-PSGD,
//! and the §Perf target: the pipeline must run at memory-bandwidth-ish
//! rates so the *network* stays the bottleneck (the whole point of
//! quantized communication).
//!
//! Sections:
//!
//! 1. **pack/unpack GB/s sweep** over bits {1, 2, 3, 4, 5, 8, 16} ×
//!    d {1e4, 1e6}: the word kernels versus the retained byte-accumulator
//!    reference (`pack_into_ref`/`unpack_into_ref`). The
//!    `pack_speedup_vs_ref_*` metrics are the acceptance numbers for the
//!    §Perf word-kernel pass (≥2× at bits ∈ {1, 2, 4}).
//! 2. **fused codec sweep** (`encode_packed_into`/`recover_packed_into`)
//!    over the same grid — the bytes the round engine actually puts on the
//!    wire.
//! 3. Pooled chunked codec scaling, entropy coders, the full per-neighbor
//!    round trip, and full Moniqua rounds on the parallel round engine.
//!
//! Every metric lands in `BENCH_quant_throughput.json`; CI's bench-smoke
//! job runs this in quick mode (`MONIQUA_BENCH_QUICK=1`) and diffs the
//! JSON against the committed baseline in `rust/benches/baselines/`.
//!
//! Run: `cargo bench --offline --bench bench_quant_throughput`

use moniqua::algorithms::engine::CODEC_CHUNK_CODES;
use moniqua::algorithms::{Algorithm, RoundPool, StepCtx, SyncAlgorithm, ThetaPolicy};
use moniqua::bench_support::{
    bench, black_box, print_speedup, print_throughput, section, speedup, speedup_best,
    BenchJson,
};
use moniqua::quant::{packing, Compression, MoniquaCodec, QuantConfig};
use moniqua::rng::Pcg64;
use moniqua::topology::Topology;

/// The §Perf sweep grid. 1-bit is the paper's headline Table-2 budget; 3
/// and 5 exercise the ragged two-word staging kernel; 8/16 the
/// byte-aligned fast paths.
const BITS_SWEEP: [u32; 7] = [1, 2, 3, 4, 5, 8, 16];
const DIMS: [usize; 2] = [10_000, 1_000_000];

fn main() {
    let bench_t0 = std::time::Instant::now();
    let mut json = BenchJson::new("quant_throughput");
    let mut rng = Pcg64::seeded(1);

    // ---- 1. word kernels vs byte-accumulator reference -------------------
    for &d in &DIMS {
        let bytes_f32 = d * 4;
        section(&format!("pack/unpack sweep, d = {d} ({} MB f32)", bytes_f32 / 1_000_000));
        for bits in BITS_SWEEP {
            let codes: Vec<u32> = (0..d)
                .map(|_| (rng.next_u64() & ((1u64 << bits) - 1)) as u32)
                .collect();
            let mut packed = vec![0u8; packing::packed_len(d, bits)];
            let mut out = vec![0u32; d];
            let tag = |k: &str| format!("{k}_{bits}bit_d{d}");

            let word_pack = bench(&format!("pack word {bits}-bit d={d}"), 2, 9, || {
                packing::pack_into(black_box(&codes), bits, &mut packed);
            });
            print_throughput(&word_pack, bytes_f32);
            json.metric(&format!("{}.gbps", tag("pack")), word_pack.throughput(bytes_f32) / 1e9);

            let ref_pack = bench(&format!("pack ref  {bits}-bit d={d}"), 2, 9, || {
                packing::pack_into_ref(black_box(&codes), bits, &mut packed);
            });
            print_throughput(&ref_pack, bytes_f32);

            let word_unpack = bench(&format!("unpack word {bits}-bit d={d}"), 2, 9, || {
                packing::unpack_into(black_box(&packed), bits, &mut out);
            });
            print_throughput(&word_unpack, bytes_f32);
            json.metric(
                &format!("{}.gbps", tag("unpack")),
                word_unpack.throughput(bytes_f32) / 1e9,
            );

            let ref_unpack = bench(&format!("unpack ref  {bits}-bit d={d}"), 2, 9, || {
                packing::unpack_into_ref(black_box(&packed), bits, &mut out);
            });
            print_throughput(&ref_unpack, bytes_f32);

            if d == 1_000_000 {
                // Acceptance metrics: word kernels vs the seed byte kernels.
                print_speedup(
                    &format!("pack word/ref speedup {bits}-bit"),
                    &ref_pack,
                    &word_pack,
                );
                print_speedup(
                    &format!("unpack word/ref speedup {bits}-bit"),
                    &ref_unpack,
                    &word_unpack,
                );
                // Gated metrics use the best-of-N estimator (see
                // bench_support::speedup_best and baselines/compare.py).
                json.metric(
                    &format!("pack_speedup_vs_ref_{bits}bit"),
                    speedup_best(&ref_pack, &word_pack),
                );
                json.metric(
                    &format!("unpack_speedup_vs_ref_{bits}bit"),
                    speedup_best(&ref_unpack, &word_unpack),
                );
            }
        }
    }

    // ---- 2. fused wire path over the same grid ---------------------------
    for &d in &DIMS {
        let bytes_f32 = d * 4;
        let x: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let y: Vec<f32> = x.iter().map(|&v| v + 0.01 * (rng.next_f32() - 0.5)).collect();
        let noise: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
        let mut out = vec![0.0f32; d];
        section(&format!(
            "fused wire path (encode_packed / recover_packed), d = {d}"
        ));
        for bits in BITS_SWEEP {
            let cfg = QuantConfig::nearest(bits);
            let codec = MoniquaCodec::from_theta(2.0, &cfg);
            let mut wire = vec![0u8; packing::packed_len(d, bits)];
            let r = bench(&format!("encode_packed nearest {bits}-bit d={d}"), 2, 9, || {
                codec.encode_packed_into(black_box(&x), &noise, &mut wire);
            });
            print_throughput(&r, bytes_f32);
            json.metric(
                &format!("encode_packed_{bits}bit_d{d}.gbps"),
                r.throughput(bytes_f32) / 1e9,
            );
            let r = bench(&format!("recover_packed {bits}-bit d={d}"), 2, 9, || {
                codec.recover_packed_into(black_box(&wire), &y, &mut out);
            });
            print_throughput(&r, bytes_f32);
            json.metric(
                &format!("recover_packed_{bits}bit_d{d}.gbps"),
                r.throughput(bytes_f32) / 1e9,
            );
        }
    }

    // ---- 3a. pooled chunked codec scaling --------------------------------
    {
        let d = DIMS[1];
        let bytes_f32 = d * 4;
        assert!(d >= 2 * CODEC_CHUNK_CODES);
        let x: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let noise: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
        let cfg8 = QuantConfig::stochastic(8);
        let codec8 = MoniquaCodec::from_theta(2.0, &cfg8);
        let mut wire8 = vec![0u8; packing::packed_len(d, 8)];
        section("pooled chunked encode (word-aligned 32Ki-code chunks), 8-bit, d = 1M");
        let cores = std::thread::available_parallelism().map_or(1, |p| p.get());
        let mut seq: Option<moniqua::bench_support::BenchResult> = None;
        for threads in [1usize, 2, 4, cores] {
            if threads > cores {
                continue;
            }
            let pool = RoundPool::new(threads);
            let r = bench(&format!("encode_packed pooled, {threads} thread(s)"), 2, 9, || {
                pool.encode_packed(&codec8, black_box(&x), &noise, &mut wire8);
            });
            print_throughput(&r, bytes_f32);
            json.metric(
                &format!("encode_packed_pooled_{threads}t.gbps"),
                r.throughput(bytes_f32) / 1e9,
            );
            if threads == 1 {
                seq = Some(r);
            } else if let Some(s) = &seq {
                print_speedup(&format!("pooled encode speedup at {threads} threads"), s, &r);
            }
        }
    }

    // ---- 3b. entropy coders + full round trip + round engine -------------
    let d = DIMS[1];
    let bytes_f32 = d * 4;
    let x: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
    let y: Vec<f32> = x.iter().map(|&v| v + 0.01 * (rng.next_f32() - 0.5)).collect();
    let noise: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
    let mut codes = vec![0u32; d];
    let mut out = vec![0.0f32; d];
    let cfg8 = QuantConfig::stochastic(8);
    let codec8 = MoniquaCodec::from_theta(2.0, &cfg8);
    let mut wire8 = vec![0u8; packing::packed_len(d, 8)];

    section("entropy coders on a near-consensus 8-bit stream (d = 1M)");
    codec8.encode_packed_into(&x, &noise, &mut wire8);
    for comp in Compression::enabled() {
        if comp == Compression::None {
            continue;
        }
        let r = bench(&format!("{comp:?} compress"), 1, 5, || {
            black_box(comp.compress(black_box(&wire8)));
        });
        print_throughput(&r, wire8.len());
        println!("    ratio: {} -> {} bytes", wire8.len(), comp.wire_len(&wire8));
    }

    section("full per-neighbor round trip, 8-bit");
    // What the parallel round engine runs per (sender, receiver) pair:
    // fused encode straight to wire bytes, fused recovery straight from
    // them. No Vec<u32>, no per-round allocation.
    let fused = bench("fused pipeline 8-bit", 2, 9, || {
        codec8.encode_packed_into(black_box(&x), &noise, &mut wire8);
        codec8.recover_packed_into(&wire8, &y, &mut out);
    });
    print_throughput(&fused, bytes_f32);
    // The pre-fusion pipeline for comparison (extra Vec<u32> pass each way).
    let mut packed = vec![0u8; packing::packed_len(d, 8)];
    let unfused = bench("unfused pipeline 8-bit", 2, 9, || {
        codec8.encode_into(black_box(&x), &noise, &mut codes);
        packing::pack_into(&codes, 8, &mut packed);
        packing::unpack_into(&packed, 8, &mut codes);
        codec8.recover_into(&codes, &y, &mut out);
    });
    print_throughput(&unfused, bytes_f32);
    print_speedup("fusion speedup (wire path)", &unfused, &fused);
    json.metric("fused_pipeline_8bit.gbps", fused.throughput(bytes_f32) / 1e9)
        .metric("fusion_speedup_x", speedup_best(&unfused, &fused));

    section("parallel round engine: full Moniqua rounds, ring(8), d = 250k");
    // One full synchronous round (encode + recover/accumulate + apply) per
    // iteration; the engine determinism contract makes every width produce
    // identical models, so this isolates pure scaling.
    let n_workers = 8usize;
    let dm = 250_000usize;
    let w = Topology::Ring(n_workers).comm_matrix();
    let rho = w.rho();
    let algo = Algorithm::Moniqua {
        theta: ThetaPolicy::Constant(2.0),
        quant: QuantConfig::stochastic(8),
    };
    let cores = std::thread::available_parallelism().map_or(1, |p| p.get());
    let mut widths = vec![1usize, 2, 4];
    if !widths.contains(&cores) {
        widths.push(cores);
    }
    let mut seq: Option<moniqua::bench_support::BenchResult> = None;
    for threads in widths {
        if threads > cores.max(4) {
            continue;
        }
        let mut engine = algo.make_sync(&w, dm);
        engine.set_threads(threads);
        let mut xs: Vec<Vec<f32>> = (0..n_workers)
            .map(|i| (0..dm).map(|k| 0.5 + 0.001 * ((i + k) % 17) as f32).collect())
            .collect();
        let grads: Vec<Vec<f32>> = (0..n_workers).map(|_| vec![0.01; dm]).collect();
        let ctx = StepCtx { seed: 7, rho, g_inf: 1.0 };
        let mut round = 0u64;
        let r = bench(&format!("round engine, {threads} thread(s)"), 1, 7, || {
            engine.step(black_box(&mut xs), &grads, 0.01, round, &ctx);
            round += 1;
        });
        print_throughput(&r, n_workers * dm * 4);
        json.metric(
            &format!("round_engine_{threads}t.gbps"),
            r.throughput(n_workers * dm * 4) / 1e9,
        );
        if threads == 1 {
            seq = Some(r);
        } else if let Some(seq) = &seq {
            print_speedup(&format!("engine speedup at {threads} threads"), seq, &r);
        }
    }
    json.metric("wall_s", bench_t0.elapsed().as_secs_f64());
    json.write().expect("write bench json");
    println!(
        "\nFor reference: a 1 GB/s pipeline quantizes a 1M-param model in ~4 ms —\n\
         below the 8.8 ms one fp32 model costs on a 1 Gbps link (Fig 1b regime)."
    );
}
