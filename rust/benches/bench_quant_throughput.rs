//! HOT-PATH MICROBENCH — the L3 quantize/recover/pack/unpack pipeline.
//!
//! This is the per-round per-neighbor work Moniqua adds on top of D-PSGD,
//! and the §Perf target: the pipeline must run at memory-bandwidth-ish
//! rates so the *network* stays the bottleneck (the whole point of
//! quantized communication). Results before/after the perf pass are
//! recorded in EXPERIMENTS.md §Perf.
//!
//! Run: `cargo bench --offline --bench bench_quant_throughput`

use moniqua::bench_support::{bench, black_box, print_throughput, section};
use moniqua::quant::{packing, Compression, MoniquaCodec, QuantConfig};
use moniqua::rng::Pcg64;

fn main() {
    let d = 1_000_000usize;
    let bytes_f32 = d * 4;
    let mut rng = Pcg64::seeded(1);
    let x: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
    let y: Vec<f32> = x.iter().map(|&v| v + 0.01 * (rng.next_f32() - 0.5)).collect();
    let noise: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
    let mut codes = vec![0u32; d];
    let mut out = vec![0.0f32; d];

    section(&format!("Moniqua codec over d = {d} params (f32 input = {} MB)", bytes_f32 / 1_000_000));
    for bits in [1u32, 2, 4, 8] {
        let cfg = QuantConfig::nearest(bits);
        let codec = MoniquaCodec::from_theta(2.0, &cfg);
        let r = bench(&format!("encode nearest {bits}-bit"), 2, 9, || {
            codec.encode_into(black_box(&x), &noise, &mut codes);
        });
        print_throughput(&r, bytes_f32);
    }
    let cfg = QuantConfig::stochastic(8);
    let codec = MoniquaCodec::from_theta(2.0, &cfg);
    let r = bench("encode stochastic 8-bit", 2, 9, || {
        codec.encode_into(black_box(&x), &noise, &mut codes);
    });
    print_throughput(&r, bytes_f32);

    let r = bench("recover 8-bit", 2, 9, || {
        codec.recover_into(black_box(&codes), &y, &mut out);
    });
    print_throughput(&r, bytes_f32);

    let r = bench("local_biased (fused line 4)", 2, 9, || {
        codec.local_biased_into(black_box(&x), &noise, &mut out);
    });
    print_throughput(&r, bytes_f32);

    section("bit packing");
    for bits in [1u32, 4, 8] {
        let mut packed = vec![0u8; packing::packed_len(d, bits)];
        let r = bench(&format!("pack {bits}-bit"), 2, 9, || {
            packing::pack_into(black_box(&codes[..d]), bits, &mut packed);
        });
        print_throughput(&r, bytes_f32);
        let r = bench(&format!("unpack {bits}-bit"), 2, 9, || {
            packing::unpack_into(black_box(&packed), bits, &mut codes);
        });
        print_throughput(&r, bytes_f32);
    }

    section("entropy coders on a near-consensus 8-bit stream (d = 1M)");
    let codec8 = MoniquaCodec::from_theta(2.0, &QuantConfig::stochastic(8));
    codec8.encode_into(&x, &noise, &mut codes);
    let packed = packing::pack(&codes, 8);
    for comp in [Compression::Rle, Compression::Deflate, Compression::Bzip2] {
        let r = bench(&format!("{comp:?} compress"), 1, 5, || {
            black_box(comp.compress(black_box(&packed)));
        });
        print_throughput(&r, packed.len());
        println!(
            "    ratio: {} -> {} bytes",
            packed.len(),
            comp.wire_len(&packed)
        );
    }

    section("full per-neighbor pipeline (encode + pack + unpack + recover), 8-bit");
    let mut packed = vec![0u8; packing::packed_len(d, 8)];
    let r = bench("pipeline 8-bit", 2, 9, || {
        codec8.encode_into(black_box(&x), &noise, &mut codes);
        packing::pack_into(&codes, 8, &mut packed);
        packing::unpack_into(&packed, 8, &mut codes);
        codec8.recover_into(&codes, &y, &mut out);
    });
    print_throughput(&r, bytes_f32);
    println!(
        "\nFor reference: a 1 GB/s pipeline quantizes a 1M-param model in ~4 ms —\n\
         below the 8.8 ms one fp32 model costs on a 1 Gbps link (Fig 1b regime)."
    );
}
