//! HOT-PATH MICROBENCH — the L3 quantize/recover/pack/unpack pipeline.
//!
//! This is the per-round per-neighbor work Moniqua adds on top of D-PSGD,
//! and the §Perf target: the pipeline must run at memory-bandwidth-ish
//! rates so the *network* stays the bottleneck (the whole point of
//! quantized communication). The headline rows are the **fused** wire path
//! the round engine actually runs (`encode_packed_into` /
//! `recover_packed_into` — no `Vec<u32>` intermediate, zero allocations
//! per call); the unfused two-step rows are kept as the comparison
//! baseline. Results before/after the perf pass are recorded in
//! EXPERIMENTS.md §Perf.
//!
//! Run: `cargo bench --offline --bench bench_quant_throughput`

use moniqua::algorithms::{Algorithm, StepCtx, SyncAlgorithm, ThetaPolicy};
use moniqua::bench_support::{
    bench, black_box, print_speedup, print_throughput, section, speedup, BenchJson,
};
use moniqua::quant::{packing, Compression, MoniquaCodec, QuantConfig};
use moniqua::rng::Pcg64;
use moniqua::topology::Topology;

fn main() {
    let bench_t0 = std::time::Instant::now();
    let mut json = BenchJson::new("quant_throughput");
    let d = 1_000_000usize;
    let bytes_f32 = d * 4;
    let mut rng = Pcg64::seeded(1);
    let x: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
    let y: Vec<f32> = x.iter().map(|&v| v + 0.01 * (rng.next_f32() - 0.5)).collect();
    let noise: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
    let mut codes = vec![0u32; d];
    let mut out = vec![0.0f32; d];

    section(&format!(
        "fused wire path (encode_packed / recover_packed) over d = {d} params ({} MB f32)",
        bytes_f32 / 1_000_000
    ));
    for bits in [1u32, 2, 4, 8, 16] {
        let cfg = QuantConfig::nearest(bits);
        let codec = MoniquaCodec::from_theta(2.0, &cfg);
        let mut wire = vec![0u8; packing::packed_len(d, bits)];
        let r = bench(&format!("encode_packed nearest {bits}-bit"), 2, 9, || {
            codec.encode_packed_into(black_box(&x), &noise, &mut wire);
        });
        print_throughput(&r, bytes_f32);
        json.metric(
            &format!("encode_packed_{bits}bit.gbps"),
            r.throughput(bytes_f32) / 1e9,
        );
        let r = bench(&format!("recover_packed {bits}-bit"), 2, 9, || {
            codec.recover_packed_into(black_box(&wire), &y, &mut out);
        });
        print_throughput(&r, bytes_f32);
        json.metric(
            &format!("recover_packed_{bits}bit.gbps"),
            r.throughput(bytes_f32) / 1e9,
        );
    }
    let cfg8 = QuantConfig::stochastic(8);
    let codec8 = MoniquaCodec::from_theta(2.0, &cfg8);
    let mut wire8 = vec![0u8; packing::packed_len(d, 8)];
    let r = bench("encode_packed stochastic 8-bit", 2, 9, || {
        codec8.encode_packed_into(black_box(&x), &noise, &mut wire8);
    });
    print_throughput(&r, bytes_f32);

    section("unfused baseline (encode -> pack, unpack -> recover)");
    for bits in [1u32, 4, 8] {
        let cfg = QuantConfig::nearest(bits);
        let codec = MoniquaCodec::from_theta(2.0, &cfg);
        let mut packed = vec![0u8; packing::packed_len(d, bits)];
        let r = bench(&format!("encode+pack {bits}-bit (unfused)"), 2, 9, || {
            codec.encode_into(black_box(&x), &noise, &mut codes);
            packing::pack_into(&codes, bits, &mut packed);
        });
        print_throughput(&r, bytes_f32);
        let r = bench(&format!("unpack+recover {bits}-bit (unfused)"), 2, 9, || {
            packing::unpack_into(black_box(&packed), bits, &mut codes);
            codec.recover_into(&codes, &y, &mut out);
        });
        print_throughput(&r, bytes_f32);
    }

    let r = bench("local_biased (fused line 4)", 2, 9, || {
        codec8.local_biased_into(black_box(&x), &noise, &mut out);
    });
    print_throughput(&r, bytes_f32);

    section("entropy coders on a near-consensus 8-bit stream (d = 1M)");
    codec8.encode_packed_into(&x, &noise, &mut wire8);
    for comp in Compression::enabled() {
        if comp == Compression::None {
            continue;
        }
        let r = bench(&format!("{comp:?} compress"), 1, 5, || {
            black_box(comp.compress(black_box(&wire8)));
        });
        print_throughput(&r, wire8.len());
        println!(
            "    ratio: {} -> {} bytes",
            wire8.len(),
            comp.wire_len(&wire8)
        );
    }

    section("full per-neighbor round trip, 8-bit");
    // What the parallel round engine runs per (sender, receiver) pair:
    // fused encode straight to wire bytes, fused recovery straight from
    // them. No Vec<u32>, no per-round allocation.
    let fused = bench("fused pipeline 8-bit", 2, 9, || {
        codec8.encode_packed_into(black_box(&x), &noise, &mut wire8);
        codec8.recover_packed_into(&wire8, &y, &mut out);
    });
    print_throughput(&fused, bytes_f32);
    // The pre-fusion pipeline for comparison (extra Vec<u32> pass each way).
    let mut packed = vec![0u8; packing::packed_len(d, 8)];
    let unfused = bench("unfused pipeline 8-bit", 2, 9, || {
        codec8.encode_into(black_box(&x), &noise, &mut codes);
        packing::pack_into(&codes, 8, &mut packed);
        packing::unpack_into(&packed, 8, &mut codes);
        codec8.recover_into(&codes, &y, &mut out);
    });
    print_throughput(&unfused, bytes_f32);
    print_speedup("fusion speedup (wire path)", &unfused, &fused);
    json.metric("fused_pipeline_8bit.gbps", fused.throughput(bytes_f32) / 1e9)
        .metric("fusion_speedup_x", speedup(&unfused, &fused));

    section("parallel round engine: full Moniqua rounds, ring(8), d = 250k");
    // One full synchronous round (encode + recover/accumulate + apply) per
    // iteration; the engine determinism contract makes every width produce
    // identical models, so this isolates pure scaling.
    let n_workers = 8usize;
    let dm = 250_000usize;
    let w = Topology::Ring(n_workers).comm_matrix();
    let rho = w.rho();
    let algo = Algorithm::Moniqua {
        theta: ThetaPolicy::Constant(2.0),
        quant: QuantConfig::stochastic(8),
    };
    let cores = std::thread::available_parallelism().map_or(1, |p| p.get());
    let mut widths = vec![1usize, 2, 4];
    if !widths.contains(&cores) {
        widths.push(cores);
    }
    let mut seq: Option<moniqua::bench_support::BenchResult> = None;
    for threads in widths {
        if threads > cores.max(4) {
            continue;
        }
        let mut engine = algo.make_sync(&w, dm);
        engine.set_threads(threads);
        let mut xs: Vec<Vec<f32>> = (0..n_workers)
            .map(|i| (0..dm).map(|k| 0.5 + 0.001 * ((i + k) % 17) as f32).collect())
            .collect();
        let grads: Vec<Vec<f32>> = (0..n_workers).map(|_| vec![0.01; dm]).collect();
        let ctx = StepCtx { seed: 7, rho, g_inf: 1.0 };
        let mut round = 0u64;
        let r = bench(&format!("round engine, {threads} thread(s)"), 1, 7, || {
            engine.step(black_box(&mut xs), &grads, 0.01, round, &ctx);
            round += 1;
        });
        print_throughput(&r, n_workers * dm * 4);
        json.metric(
            &format!("round_engine_{threads}t.gbps"),
            r.throughput(n_workers * dm * 4) / 1e9,
        );
        if threads == 1 {
            seq = Some(r);
        } else if let Some(seq) = &seq {
            print_speedup(&format!("engine speedup at {threads} threads"), seq, &r);
        }
    }
    json.metric("wall_s", bench_t0.elapsed().as_secs_f64());
    json.write().expect("write bench json");
    println!(
        "\nFor reference: a 1 GB/s pipeline quantizes a 1M-param model in ~4 ms —\n\
         below the 8.8 ms one fp32 model costs on a 1 Gbps link (Fig 1b regime)."
    );
}
