//! DES fault sweep — drop-rate × straggler severity for Moniqua-AD-PSGD vs
//! full-precision AD-PSGD on heterogeneous links.
//!
//! The paper's Figure 2b shows AD-PSGD variants on a *clean* 20 Mbps
//! network. Real decentralized deployments lose messages and host
//! stragglers; this bench measures how both async systems degrade across
//! the fault grid, on a log-normal heterogeneous link matrix:
//!
//! * each cell runs the same gradient-event budget and reports final loss,
//!   simulated wall-clock, and drop/recovery counters;
//! * the expected shape: Moniqua keeps its ~4× time advantage while both
//!   variants degrade gracefully with drops (stale-neighbor fallback) and
//!   stragglers stretch the clock roughly log-normally;
//! * event digests are printed so a run is checkable for reproducibility.
//!
//! Run: `cargo bench --offline --bench bench_des_faults`
//! (`MONIQUA_FAST=1` shrinks the grid and the event budget.)

use std::sync::Arc;

use moniqua::algorithms::AsyncVariant;
use moniqua::bench_support::{section, BenchJson};
use moniqua::coordinator::{DesAsyncTrainer, FaultConfig};
use moniqua::data::{partition::Partition, SynthClassification, SynthSpec};
use moniqua::network::{LinkMatrix, NetworkConfig};
use moniqua::objectives::{Mlp, Objective};
use moniqua::quant::QuantConfig;
use moniqua::topology::Topology;

fn main() {
    let bench_t0 = std::time::Instant::now();
    let mut json = BenchJson::new("des_faults");
    let fast = std::env::var("MONIQUA_FAST").is_ok();
    let workers = 6;
    let topo = Topology::Ring(workers);
    let data = Arc::new(SynthClassification::generate(SynthSpec {
        dim: 64,
        classes: 8,
        train_per_class: 80,
        test_per_class: 20,
        ..SynthSpec::default()
    }));
    let hidden = if fast { 16 } else { 128 };
    let make_objective = || -> Box<dyn Objective> {
        Box::new(Mlp::new(Arc::clone(&data), workers, Partition::Iid, hidden, 16, 9))
    };
    let d = make_objective().dim();
    println!("model d = {d} ({:.0} KB fp32/message)", d as f64 * 4.0 / 1e3);

    // Heterogeneous links around the paper's fig2b setting: the straggler
    // *links*, not just straggler hosts, are what the DES adds.
    let links = LinkMatrix::lognormal(workers, NetworkConfig::fig2b(), 0.4, 13);
    let events = if fast { 400 } else { 4000 };
    let grad_time = 20e-3;

    let drops: &[f64] = if fast { &[0.0, 0.2] } else { &[0.0, 0.05, 0.2] };
    let stragglers: &[f64] = if fast { &[0.0, 0.8] } else { &[0.0, 0.4, 0.8] };

    let variants: [(&str, AsyncVariant); 2] = [
        ("adpsgd", AsyncVariant::FullPrecision),
        (
            "moniqua-adpsgd",
            AsyncVariant::Moniqua { theta: 2.0, quant: QuantConfig::stochastic(8) },
        ),
    ];

    section("drop-rate × straggler sweep (final loss | sim seconds)");
    println!(
        "{:<16} {:>6} {:>10} {:>12} {:>12} {:>10} {:>10}",
        "system", "drop", "straggler", "final_loss", "sim_time_s", "dropped", "recovered"
    );
    for (name, variant) in &variants {
        for &drop_prob in drops {
            for &straggler in stragglers {
                let mut trainer = DesAsyncTrainer {
                    topo: topo.clone(),
                    objective: make_objective(),
                    variant: variant.clone(),
                    links: links.clone(),
                    faults: FaultConfig {
                        drop_prob,
                        delay_prob: 0.0,
                        delay_s: 0.0,
                        straggler,
                        byz: None,
                    },
                    topo_schedule: None,
                    grad_time_s: grad_time,
                    lr: 0.1,
                    events,
                    eval_every: events,
                    seed: 9,
                    out: Default::default(),
                };
                let r = trainer.run();
                println!(
                    "{:<16} {:>6.2} {:>10.2} {:>12.4} {:>12.2} {:>10} {:>10}",
                    name,
                    drop_prob,
                    straggler,
                    r.final_loss(),
                    r.final_sim_time(),
                    trainer.out.messages_dropped,
                    trainer.out.stale_fallbacks,
                );
                json.scenario(
                    &format!("{name}.drop{drop_prob}.straggler{straggler}"),
                    r.final_sim_time(),
                    r.total_bytes,
                    r.final_loss(),
                );
            }
        }
    }

    section("reproducibility: clean-vs-clean event digests");
    let digest = |seed: u64| {
        let mut trainer = DesAsyncTrainer {
            topo: topo.clone(),
            objective: make_objective(),
            variant: AsyncVariant::FullPrecision,
            links: links.clone(),
            faults: FaultConfig { drop_prob: 0.1, straggler: 0.4, ..Default::default() },
            topo_schedule: None,
            grad_time_s: grad_time,
            lr: 0.1,
            events: if fast { 200 } else { 1000 },
            eval_every: u64::MAX,
            seed,
            out: Default::default(),
        };
        trainer.run();
        trainer.out.event_digest
    };
    let (a, b, c) = (digest(9), digest(9), digest(10));
    println!("seed 9: {a:#018x}  seed 9 again: {b:#018x}  seed 10: {c:#018x}");
    assert_eq!(a, b, "same seed must replay the identical event sequence");
    assert_ne!(a, c, "different seeds must not");
    println!("(expected: moniqua-adpsgd ≈4x faster in sim time at every fault level)");
    json.metric("wall_s", bench_t0.elapsed().as_secs_f64());
    json.write().expect("write bench json");
}
