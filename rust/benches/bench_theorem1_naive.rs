//! THEOREM 1 — naive quantization provably stalls; Moniqua does not.
//!
//! Setup straight from §3: quadratic f(x) = ½‖x − x*‖² whose optimum lies
//! exactly between two representable points of an *unbiased* linear
//! quantizer with step δ. Theorem 1: under direct quantization (Eq. 4),
//! E‖∇f(x_{k,i})‖² ≥ φ²δ²/(8(1+φ²)) for ALL k — no step size escapes.
//!
//! The bench prints the gradient-norm trajectory of naive quantization vs
//! the floor, and the same trajectory for full-precision D-PSGD and Moniqua
//! (both of which crash through it).
//!
//! Run: `cargo bench --offline --bench bench_theorem1_naive`

use moniqua::algorithms::{Algorithm, StepCtx, SyncAlgorithm, ThetaPolicy};
use moniqua::bench_support::{section, BenchJson};
use moniqua::objectives::quadratic::theorem1_floor;
use moniqua::quant::QuantConfig;
use moniqua::topology::Topology;

fn main() {
    let bench_t0 = std::time::Instant::now();
    let mut json = BenchJson::new("theorem1_naive");
    let n = 4usize;
    let d = 64usize;
    let topo = Topology::Ring(n);
    let w = topo.comm_matrix();
    let rho = w.rho();
    let phi = w.min_nonzero();
    // Unbiased 2-bit quantizer over range 4 → absolute grid step δ = 1.
    let delta_abs = 1.0f64;
    let floor = theorem1_floor(phi, delta_abs);
    println!("ring({n}): phi = {phi:.4}, delta = {delta_abs}, Theorem-1 floor = {floor:.5}\n");

    // Optimum exactly between grid points (grid at half-integers → opt 0).
    let opt = 0.0f32;
    let steps = 600u64;
    let stride = 50u64;

    let run = |mut alg: Box<dyn SyncAlgorithm>, lr: f32| -> Vec<f64> {
        let mut xs: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0; d]).collect();
        let ctx = StepCtx { seed: 3, rho, g_inf: 1.0 };
        let mut curve = Vec::new();
        for k in 0..steps {
            let grads: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| x.iter().map(|&v| v - opt).collect())
                .collect();
            alg.step(&mut xs, &grads, lr, k, &ctx);
            if k % stride == 0 || k + 1 == steps {
                // E‖∇f(x_i)‖² averaged over workers
                let gsq: f64 = xs
                    .iter()
                    .map(|x| x.iter().map(|&v| ((v - opt) as f64).powi(2)).sum::<f64>())
                    .sum::<f64>()
                    / n as f64;
                curve.push(gsq);
            }
        }
        curve
    };

    let q2 = QuantConfig::stochastic(2).with_shared_randomness(false);
    let systems: Vec<(&str, Algorithm, f32)> = vec![
        ("naive-quant (Eq.4)", Algorithm::NaiveQuant { quant: q2, range: 4.0 }, 0.05),
        ("dpsgd fp32", Algorithm::DPsgd, 0.05),
        (
            "moniqua 8-bit",
            Algorithm::Moniqua {
                theta: ThetaPolicy::Constant(2.0),
                quant: QuantConfig::stochastic(8),
            },
            0.05,
        ),
    ];

    section("E‖∇f‖² trajectories (one row per system, sampled every 50 steps)");
    let mut naive_final = f64::NAN;
    for (name, algorithm, lr) in systems {
        let algo_name = algorithm.name();
        let curve = run(algorithm.make_sync(&w, d), lr);
        println!(
            "{:<20} {}",
            name,
            curve.iter().map(|v| format!("{v:.2e}")).collect::<Vec<_>>().join(" ")
        );
        json.metric(&format!("{algo_name}.final_grad_norm_sq"), *curve.last().unwrap());
        if name.starts_with("naive") {
            naive_final = *curve.last().unwrap();
        }
    }
    println!("\nTheorem-1 floor: {floor:.5}");
    println!(
        "naive-quant final E‖∇f‖² = {naive_final:.5} — {} the floor (paper: must stay ≥ floor)",
        if naive_final >= floor { "ABOVE" } else { "below?!" }
    );
    assert!(naive_final >= floor, "Theorem 1 violated by the implementation");
    json.metric("theorem1_floor", floor)
        .metric("wall_s", bench_t0.elapsed().as_secs_f64());
    json.write().expect("write bench json");
}
