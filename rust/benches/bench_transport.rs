//! Transport bench: mem vs tcp wall-clock for the cluster runtime, and
//! measured-vs-predicted wire bytes per round.
//!
//! Three readouts per (algorithm, transport) cell:
//!
//! * wall-clock seconds for the whole run (real threads, real sockets for
//!   tcp — this is host time, not simulated time);
//! * predicted payload bytes per directed message (the arithmetic
//!   `CommStats::bytes_per_msg` that Lemma 2 / the bit-budget analysis
//!   bounds) vs the measured bytes the transport actually shipped per
//!   frame (payload + the `HEADER_LEN`-byte frame header);
//! * a cross-transport check: mem and tcp runs must report identical
//!   `total_bytes` (the transports may not change the math).
//!
//! Run: `cargo bench --offline --bench bench_transport`
//! (`MONIQUA_FAST=1` shrinks rounds and the model.)

use std::time::Instant;

use moniqua::algorithms::{Algorithm, ThetaPolicy};
use moniqua::bench_support::{section, BenchJson};
use moniqua::coordinator::{ClusterConfig, ClusterTrainer, TrainConfig, TransportKind};
use moniqua::objectives::{Objective, Quadratic};
use moniqua::quant::QuantConfig;
use moniqua::topology::Topology;
use moniqua::transport::HEADER_LEN;

fn main() {
    let bench_t0 = std::time::Instant::now();
    let mut json = BenchJson::new("transport");
    let fast = std::env::var("MONIQUA_FAST").is_ok();
    let workers = 4;
    let d = if fast { 1 << 12 } else { 1 << 16 };
    let steps = if fast { 10 } else { 40 };
    let make_objective = || -> Box<dyn Objective> {
        Box::new(Quadratic::new(d, 1.0, 0.1, workers, 11))
    };

    let algorithms: Vec<(&str, Algorithm)> = vec![
        ("dpsgd", Algorithm::DPsgd),
        (
            "moniqua8",
            Algorithm::Moniqua {
                theta: ThetaPolicy::Constant(2.0),
                quant: QuantConfig::stochastic(8),
            },
        ),
        (
            "moniqua2",
            Algorithm::Moniqua {
                theta: ThetaPolicy::Constant(2.0),
                quant: QuantConfig::stochastic(2),
            },
        ),
    ];
    let transports: [(&str, TransportKind); 2] = [
        ("mem", TransportKind::Mem),
        ("tcp", TransportKind::Tcp { port_base: 0 }),
    ];

    section(&format!(
        "cluster runtime, ring/{workers}, d = {d}, {steps} rounds (wall-clock is host time)"
    ));
    println!(
        "{:<10} {:>6} {:>10} {:>10} {:>14} {:>14} {:>12}",
        "algorithm", "xport", "wall_s", "frames", "pred_B/msg", "meas_B/frame", "overhead"
    );
    for (name, algorithm) in &algorithms {
        let mut totals: Vec<u64> = Vec::new();
        for (tname, kind) in &transports {
            let cfg = TrainConfig {
                workers,
                steps,
                lr: 0.05,
                algorithm: algorithm.clone(),
                network: None,
                grad_time_s: Some(0.0),
                eval_every: steps, // first + last only
                seed: 11,
                ..TrainConfig::default()
            };
            let mut trainer = ClusterTrainer::new(
                cfg,
                Topology::Ring(workers),
                make_objective(),
                ClusterConfig { transport: *kind, ..ClusterConfig::default() },
            )
            .expect("cluster config");
            let t0 = Instant::now();
            let report = trainer.run().expect("cluster run");
            let wall = t0.elapsed().as_secs_f64();
            totals.push(report.total_bytes);
            let predicted_per_msg = report.total_bytes as f64 / trainer.frames_sent as f64;
            let measured_per_frame =
                trainer.wire_bytes_sent as f64 / trainer.frames_sent as f64;
            // Per-frame overhead beyond the payload must be exactly the
            // fixed header.
            assert_eq!(
                trainer.wire_bytes_sent,
                report.total_bytes + trainer.frames_sent * HEADER_LEN as u64,
                "{name}/{tname}: measured bytes must be payload + header*frames"
            );
            println!(
                "{:<10} {:>6} {:>10.3} {:>10} {:>14.1} {:>14.1} {:>11.2}%",
                name,
                tname,
                wall,
                trainer.frames_sent,
                predicted_per_msg,
                measured_per_frame,
                100.0 * (measured_per_frame - predicted_per_msg) / predicted_per_msg,
            );
            json.scenario(
                &format!("{name}.{tname}"),
                wall,
                trainer.wire_bytes_sent,
                report.final_loss(),
            );
            json.telemetry(&format!("{name}.{tname}"), &trainer.metrics().snapshot());
        }
        assert!(
            totals.windows(2).all(|w| w[0] == w[1]),
            "{name}: transports disagree on modeled bytes: {totals:?}"
        );
    }
    println!(
        "\nframe header is {HEADER_LEN} bytes; overhead shrinks as 1/payload — at 8 bits \
         and d = {d} it is already noise, which is why the paper's bit-budget bound \
         survives a real wire format."
    );
    json.metric("wall_s", bench_t0.elapsed().as_secs_f64());
    json.write().expect("write bench json");
}
