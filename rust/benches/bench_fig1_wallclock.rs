//! FIGURE 1 — wall-clock convergence under four network configurations.
//!
//! 8 workers on a ring train a ~137k-parameter MLP (stand-in for ResNet20's
//! 270k params; see DESIGN.md §Hardware-Adaptation) with every algorithm the
//! paper plots: AllReduce, D-PSGD (fp32), DCD/ECD-PSGD, ChocoSGD,
//! DeepSqueeze, and Moniqua — all quantized methods at 8 bits with
//! stochastic rounding, exactly the paper's setup.
//!
//! Networks: (a) 10 Gbps/0.05 ms  (b) 1 Gbps/0.05 ms  (c) 1 Gbps/5 ms
//! (d) 100 Mbps/20 ms. Gradient compute is modeled at 50 ms/step (P100-ish
//! ResNet20 batch) for the simulated-time axis; the algorithms' own local
//! passes are measured for real.
//!
//! Expected shape (paper): curves coincide on (a); as bandwidth drops and
//! latency grows, AllReduce and fp32 D-PSGD fall behind; Moniqua leads the
//! quantized baselines (no extra local pass); on (d) all quantized methods
//! bunch together.
//!
//! Run: `cargo bench --offline --bench bench_fig1_wallclock`
//! (set MONIQUA_FAST=1 for a quick smoke run)

use std::sync::Arc;

use moniqua::algorithms::{Algorithm, ThetaPolicy};
use moniqua::bench_support::{section, BenchJson};
use moniqua::coordinator::{metrics, DesConfig, DesTrainer, TrainConfig, Trainer};
use moniqua::data::{partition::Partition, SynthClassification, SynthSpec};
use moniqua::network::NetworkConfig;
use moniqua::objectives::{Mlp, Objective};
use moniqua::quant::QuantConfig;
use moniqua::topology::Topology;

fn main() {
    let bench_t0 = std::time::Instant::now();
    let mut json = BenchJson::new("fig1_wallclock");
    let fast = std::env::var("MONIQUA_FAST").is_ok();
    let workers = 8;
    let (hidden, steps) = if fast { (64, 20) } else { (512, 80) };
    let data = Arc::new(SynthClassification::generate(SynthSpec {
        dim: 256,
        classes: 10,
        train_per_class: 100,
        test_per_class: 20,
        ..SynthSpec::default()
    }));
    let make_objective = || -> Box<dyn Objective> {
        Box::new(Mlp::new(Arc::clone(&data), workers, Partition::Iid, hidden, 16, 7))
    };
    let d = make_objective().dim();
    println!("model: MLP d = {d} params ({:.0} KB fp32/message)", d as f64 * 4.0 / 1e3);

    let q8 = QuantConfig::stochastic(8);
    let algorithms = || {
        vec![
            Algorithm::AllReduce,
            Algorithm::DPsgd,
            // range 0.0 = per-message dynamic scaling: the charitable
            // production-style baseline (fixed grids die on long horizons;
            // Table 2's fixed-grid mode lives in bench_table2_lowbit).
            Algorithm::Dcd { quant: q8, range: 0.0 },
            Algorithm::Ecd { quant: q8, range: 0.0 },
            Algorithm::Choco { quant: q8, range: 4.0, gamma: 0.6 },
            Algorithm::DeepSqueeze { quant: q8, range: 4.0, gamma: 0.6 },
            Algorithm::Moniqua { theta: ThetaPolicy::Constant(2.0), quant: q8 },
        ]
    };

    let networks = [
        ("fig1a: 10Gbps / 0.05ms", NetworkConfig::fig1a()),
        ("fig1b:  1Gbps / 0.05ms", NetworkConfig::fig1b()),
        ("fig1c:  1Gbps / 5ms", NetworkConfig::fig1c()),
        ("fig1d: 100Mbps / 20ms", NetworkConfig::fig1d()),
    ];

    for (label, net) in networks {
        section(label);
        let fig = &label[..5]; // "fig1a" … "fig1d"
        let mut reports = Vec::new();
        for algorithm in algorithms() {
            let cfg = TrainConfig {
                workers,
                steps,
                lr: 0.1,
                algorithm,
                network: Some(net),
                grad_time_s: Some(50e-3),
                eval_every: (steps / 8).max(1),
                seed: 7,
                ..TrainConfig::default()
            };
            let mut trainer = Trainer::new(cfg, Topology::Ring(workers), make_objective());
            reports.push(trainer.run());
        }
        println!("{}", metrics::comparison_table(&reports.iter().collect::<Vec<_>>()));
        // loss-vs-time series (the actual figure curves)
        println!("loss @ simulated time (s):");
        for r in &reports {
            let series: Vec<String> = r
                .trace
                .iter()
                .map(|row| format!("({:.1}s, {:.3})", row.sim_time_s, row.eval_loss))
                .collect();
            println!("  {:<12} {}", r.algorithm, series.join(" "));
        }
        // per-round communication time ranking
        let t_moniqua = reports.last().unwrap().final_sim_time();
        let t_dpsgd = reports[1].final_sim_time();
        let t_allreduce = reports[0].final_sim_time();
        println!(
            "sim-time ratios at equal steps: allreduce/moniqua = {:.2}x, dpsgd/moniqua = {:.2}x\n",
            t_allreduce / t_moniqua,
            t_dpsgd / t_moniqua
        );
        for r in &reports {
            json.scenario(
                &format!("{fig}.{}", r.algorithm),
                r.final_sim_time(),
                r.total_bytes,
                r.final_loss(),
            );
        }
    }
    // --- overlap vs lockstep per-round wall clock (DES, fig1d) -------------
    // The comm-bound corner (100 Mbps / 20 ms, the paper's worst network):
    // with the pipelined scheduler, gradient-independent frames stream
    // under the 50 ms compute, so a round costs max(compute, comm) instead
    // of compute + comm. DES virtual time makes the ratio machine-portable
    // — it is a pure function of the config, not of the host — which is
    // what lets compare.py hard-gate `overlap_vs_lockstep` ≥ 1.
    section("fig1d: pipelined overlap vs lockstep per-round wall clock (DES)");
    let overlap_algos = [
        ("dpsgd", Algorithm::DPsgd),
        ("moniqua", Algorithm::Moniqua { theta: ThetaPolicy::Constant(2.0), quant: q8 }),
    ];
    for (name, algorithm) in overlap_algos {
        let round_s = |overlap: bool| {
            let cfg = TrainConfig {
                workers,
                steps,
                lr: 0.1,
                algorithm: algorithm.clone(),
                network: Some(NetworkConfig::fig1d()),
                grad_time_s: Some(50e-3),
                eval_every: (steps / 8).max(1),
                seed: 7,
                ..TrainConfig::default()
            };
            let des = DesConfig {
                overlap,
                ..DesConfig::uniform(workers, NetworkConfig::fig1d(), 50e-3)
            };
            let mut t = DesTrainer::new(cfg, Topology::Ring(workers), make_objective(), des);
            let per_round = t.run().final_sim_time() / steps as f64;
            (per_round, t.metrics().snapshot())
        };
        let (lockstep, lockstep_snap) = round_s(false);
        let (overlapped, overlap_snap) = round_s(true);
        let speedup = lockstep / overlapped;
        println!(
            "  {name:<8} per-round: lockstep {:.1} ms, overlap {:.1} ms ({speedup:.2}x)",
            lockstep * 1e3,
            overlapped * 1e3,
        );
        json.metric(&format!("fig1d.{name}.round_s_lockstep"), lockstep);
        json.metric(&format!("fig1d.{name}.round_s_overlap"), overlapped);
        json.metric(&format!("fig1d.{name}.overlap_vs_lockstep_speedup"), speedup);
        // Virtual-time barrier summaries: the overlap win shows up directly
        // as a shorter barrier-wait distribution at identical byte counts.
        json.telemetry(&format!("fig1d.{name}.lockstep"), &lockstep_snap);
        json.telemetry(&format!("fig1d.{name}.overlap"), &overlap_snap);
    }

    json.metric("wall_s", bench_t0.elapsed().as_secs_f64());
    json.write().expect("write bench json");
}
