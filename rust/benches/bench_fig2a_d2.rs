//! FIGURE 2(a) — Moniqua on D² with decentralized data.
//!
//! 10 workers each hold exactly ONE class of the 10-class task (maximal
//! outer variance ς², the paper's VGG16/CIFAR10 by-label setup). D-PSGD's
//! local models chase their local optima; D² removes the ς² term; Moniqua-D²
//! (Algorithm 2) matches D² with 8-bit quantized communication.
//!
//! Two workloads: the classification task (accuracy readout) and a
//! heterogeneous quadratic where the bias floor of D-PSGD is provable and
//! the separation is stark.
//!
//! Run: `cargo bench --offline --bench bench_fig2a_d2`

use std::sync::Arc;

use moniqua::algorithms::{Algorithm, SyncAlgorithm, StepCtx, ThetaPolicy};
use moniqua::bench_support::{section, BenchJson};
use moniqua::coordinator::{metrics, TrainConfig, Trainer};
use moniqua::data::{partition::Partition, SynthClassification, SynthSpec};
use moniqua::objectives::{Logistic, Objective};
use moniqua::quant::QuantConfig;
use moniqua::topology::Topology;

fn main() {
    let bench_t0 = std::time::Instant::now();
    let mut json = BenchJson::new("fig2a_d2");
    let fast = std::env::var("MONIQUA_FAST").is_ok();
    let workers = 10;
    let steps = if fast { 100 } else { 800 };
    let q8 = QuantConfig::stochastic(8);

    section("classification, one exclusive class per worker");
    let data = Arc::new(SynthClassification::generate(SynthSpec {
        classes: 10,
        train_per_class: 150,
        test_per_class: 30,
        ..SynthSpec::default()
    }));
    let shards = Partition::ByLabel.split(&data.train, workers, 1);
    println!(
        "label skew: by_label = {:.3}, iid = {:.3}",
        Partition::label_skew(&data.train, &shards, data.classes),
        Partition::label_skew(
            &data.train,
            &Partition::Iid.split(&data.train, workers, 1),
            data.classes
        )
    );
    let make_objective = || -> Box<dyn Objective> {
        Box::new(Logistic::new(Arc::clone(&data), workers, Partition::ByLabel, 32, 5))
    };
    let mut reports = Vec::new();
    for algorithm in [
        Algorithm::DPsgd,
        Algorithm::D2,
        Algorithm::MoniquaD2 { theta: ThetaPolicy::Constant(2.0), quant: q8 },
    ] {
        let cfg = TrainConfig {
            workers,
            steps,
            lr: 0.05,
            algorithm,
            eval_every: (steps / 10).max(1),
            seed: 5,
            network: None,
            ..TrainConfig::default()
        };
        let mut trainer = Trainer::new(cfg, Topology::Ring(workers), make_objective());
        let r = trainer.run();
        println!(
            "{:<12} loss curve: {}",
            r.algorithm,
            r.trace
                .iter()
                .map(|t| format!("{:.3}", t.eval_loss))
                .collect::<Vec<_>>()
                .join(" → ")
        );
        reports.push(r);
    }
    println!("\n{}", metrics::comparison_table(&reports.iter().collect::<Vec<_>>()));
    for r in &reports {
        json.scenario(
            &format!("bylabel.{}", r.algorithm),
            r.final_sim_time(),
            r.total_bytes,
            r.final_loss(),
        );
    }

    section("heterogeneous quadratic (provable D-PSGD bias floor)");
    // worker i minimizes ½‖x−c_i‖² with spread-out c_i; global optimum at 0.
    let n = 10usize;
    let d = 32usize;
    let w = Topology::Ring(n).comm_matrix();
    let rho = w.rho();
    let cs: Vec<f32> = (0..n).map(|i| (i as f32) - 4.5).collect();
    let run = |mut alg: Box<dyn SyncAlgorithm>| -> Vec<f64> {
        let mut xs: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0; d]).collect();
        let ctx = StepCtx { seed: 5, rho, g_inf: 10.0 };
        let mut curve = Vec::new();
        for k in 0..(steps as u64) {
            let grads: Vec<Vec<f32>> = (0..n)
                .map(|i| xs[i].iter().map(|&v| v - cs[i]).collect())
                .collect();
            alg.step(&mut xs, &grads, 0.08, k, &ctx);
            if k % (steps as u64 / 10).max(1) == 0 {
                // worst local distance from the global optimum (0)
                let worst = xs
                    .iter()
                    .map(|x| moniqua::linalg::norm2_sq(x) / d as f64)
                    .fold(0.0f64, f64::max);
                curve.push(worst);
            }
        }
        curve
    };
    for algorithm in [
        Algorithm::DPsgd,
        Algorithm::D2,
        Algorithm::MoniquaD2 { theta: ThetaPolicy::Constant(8.0), quant: q8 },
    ] {
        let name = algorithm.name();
        let curve = run(algorithm.make_sync(&w, d));
        println!(
            "{:<12} worst local ‖x−x*‖²/d: {}",
            name,
            curve.iter().map(|v| format!("{v:.2e}")).collect::<Vec<_>>().join(" ")
        );
        json.metric(&format!("quadratic.{name}.worst_local_err"), *curve.last().unwrap());
    }
    println!("\n(D-PSGD stalls at its ς²-bias floor; D² and Moniqua-D² go to ~0 — Figure 2a's shape.)");
    json.metric("wall_s", bench_t0.elapsed().as_secs_f64());
    json.write().expect("write bench json");
}
