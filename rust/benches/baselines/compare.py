#!/usr/bin/env python3
"""Gate a fresh BENCH json against a committed baseline.

Usage: compare.py FRESH.json BASELINE.json [--max-regression 0.25] [--gate-gbps]

Rules (stdlib only, no deps):
  * ``overlap_vs_lockstep`` ratios in the FRESH file are gated
    **absolutely**: each must be >= 1.0. Both sides of the ratio come out
    of the DES's virtual clock — a pure function of the config, identical
    on every machine and in both quick and full mode — so this check needs
    no baseline and runs even against an unblessed placeholder;
  * missing baseline file, or baseline with an empty ``metrics`` map
    -> exit 0 with a notice (nothing blessed yet — skip gracefully);
  * **gated** metrics are the self-relative ``speedup`` ratios (word
    kernels vs the in-run reference, fused vs unfused): both sides of a
    ratio are measured in the same run on the same machine, so they are
    portable between CI's quick mode and a full-mode blessing machine. A
    gated metric present in both files that dropped by more than
    ``--max-regression`` (fraction of the baseline) fails the run;
  * absolute ``.gbps`` throughputs are machine- and mode-sized
    (CI's quick mode runs 1-3 iterations on a shared runner; the blessing
    protocol is full mode on a quiet machine), so they are reported for
    the trajectory but NEVER fail — unless ``--gate-gbps`` is passed for
    a same-machine, same-mode comparison;
  * metrics present only on one side are reported but never fail (the
    sweep grid is allowed to grow).
"""

import json
import sys


def load(path):
    with open(path, "r", encoding="utf-8") as fh:
        return json.load(fh)


def main(argv):
    if len(argv) < 3:
        print(__doc__)
        return 2
    fresh_path, base_path = argv[1], argv[2]
    max_reg = 0.25
    if "--max-regression" in argv:
        max_reg = float(argv[argv.index("--max-regression") + 1])
    gate_gbps = "--gate-gbps" in argv

    def gated(key):
        return "speedup" in key or (gate_gbps and key.endswith(".gbps"))

    def informational(key):
        return key.endswith(".gbps") and not gated(key)

    fresh = load(fresh_path)
    fresh_metrics = {k: v for k, v in fresh.get("metrics", {}).items() if v is not None}

    # Absolute, baseline-free gate: DES virtual-time overlap/lockstep
    # ratios are machine-portable, so overlap must never lose to lockstep.
    absolute_failures = []
    for key in sorted(fresh_metrics):
        if "overlap_vs_lockstep" not in key:
            continue
        value = fresh_metrics[key]
        marker = "OK  " if value >= 1.0 else "SLOW"
        if value < 1.0:
            absolute_failures.append((key, value))
        print(f"[bench-compare] {marker} {key}: {value:.3f} (absolute gate: >= 1.0)")
    if absolute_failures:
        print(
            f"[bench-compare] FAIL: {len(absolute_failures)} overlap ratio(s) below 1.0 "
            "(pipelined rounds slower than lockstep)"
        )
        return 1

    try:
        base = load(base_path)
    except FileNotFoundError:
        print(f"[bench-compare] no baseline at {base_path}; skipping (bless one per README)")
        return 0
    base_metrics = {k: v for k, v in base.get("metrics", {}).items() if v is not None}
    if not base_metrics:
        print(f"[bench-compare] baseline {base_path} is an unblessed placeholder; skipping")
        return 0

    failures = []
    for key in sorted(base_metrics):
        if not (gated(key) or informational(key)):
            continue
        if key not in fresh_metrics:
            print(f"[bench-compare] NOTE: baseline metric {key} missing from fresh run")
            continue
        b, f = base_metrics[key], fresh_metrics[key]
        if b <= 0:
            continue
        delta = (f - b) / b
        if gated(key):
            marker = "OK  "
            if delta < -max_reg:
                marker = "REG "
                failures.append((key, b, f, delta))
        else:
            marker = "info"
        print(f"[bench-compare] {marker} {key}: baseline {b:.3f} fresh {f:.3f} ({delta:+.1%})")
    for key in sorted(set(fresh_metrics) - set(base_metrics)):
        if "speedup" in key or key.endswith(".gbps"):
            print(f"[bench-compare] NOTE: new metric {key} (not in baseline)")

    if failures:
        print(f"[bench-compare] FAIL: {len(failures)} gated metric(s) regressed more than {max_reg:.0%}")
        return 1
    print("[bench-compare] PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
