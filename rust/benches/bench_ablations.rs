//! §6 ABLATIONS — the paper's "More efficient Moniqua" and "Choosing θ"
//! techniques, measured:
//!
//!   A. shared randomness: pairwise quantization-error variance with the
//!      same vs independent stochastic-rounding noise (supplementary §C
//!      predicts a strict reduction near consensus);
//!   B. entropy coding: actual wire bytes of packed Moniqua code streams
//!      under bzip2 / deflate / RLE as consensus tightens (the modulo
//!      stream's high bits become redundant);
//!   C. θ sensitivity: final loss across a θ sweep, plus the Theorem-2
//!      formula ("auto") and the hash-verification failure counter when θ
//!      is chosen too small;
//!   D. slack-matrix γ (Theorem 3): 1-bit convergence vs γ.
//!
//! Run: `cargo bench --offline --bench bench_ablations`

use moniqua::algorithms::{Algorithm, StepCtx, SyncAlgorithm, ThetaPolicy};
use moniqua::bench_support::{section, BenchJson};
use moniqua::quant::{packing, Compression, MoniquaCodec, QuantConfig, Rounding};
use moniqua::rng::Pcg64;
use moniqua::topology::Topology;

fn quad_loss(algorithm: Algorithm, w: &moniqua::topology::CommMatrix, steps: u64) -> f64 {
    let n = w.n();
    let d = 64;
    let rho = w.rho();
    let mut alg = algorithm.make_sync(w, d);
    let mut xs: Vec<Vec<f32>> = (0..n).map(|i| vec![1.0 + 0.05 * i as f32; d]).collect();
    let ctx = StepCtx { seed: 11, rho, g_inf: 1.0 };
    for k in 0..steps {
        let grads: Vec<Vec<f32>> = xs
            .iter()
            .map(|x| x.iter().map(|&v| v - 0.3).collect())
            .collect();
        alg.step(&mut xs, &grads, 0.1, k, &ctx);
    }
    xs.iter()
        .map(|x| x.iter().map(|&v| ((v - 0.3) as f64).powi(2)).sum::<f64>())
        .sum::<f64>()
        / n as f64
}

fn main() {
    let bench_t0 = std::time::Instant::now();
    let mut json = BenchJson::new("ablations");
    let fast = std::env::var("MONIQUA_FAST").is_ok();
    let steps = if fast { 100 } else { 600 };
    let w = Topology::Ring(8).comm_matrix();

    // ---------------- A: shared randomness --------------------------------
    section("A. shared randomness (supp. §C): pairwise quantization error");
    let mut rng = Pcg64::seeded(1);
    let n_el = 100_000;
    let codec = MoniquaCodec::from_theta(2.0, &QuantConfig::stochastic(4));
    for spread in [0.01f32, 0.1, 1.0] {
        let y: Vec<f32> = (0..n_el).map(|_| rng.next_gaussian() as f32).collect();
        let x: Vec<f32> = y
            .iter()
            .map(|&v| v + spread * (rng.next_f32() - 0.5))
            .collect();
        let mut err = |ux: &[f32], uy: &[f32]| -> f64 {
            let mut cx = vec![0u32; n_el];
            let mut cy = vec![0u32; n_el];
            codec.encode_into(&x, ux, &mut cx);
            codec.encode_into(&y, uy, &mut cy);
            // pairwise error of the biased terms (what enters the averaging)
            let mut sx = vec![0.0f32; n_el];
            let mut sy = vec![0.0f32; n_el];
            codec.local_biased_into(&x, ux, &mut sx);
            codec.local_biased_into(&y, uy, &mut sy);
            (0..n_el)
                .map(|i| (((sx[i] - x[i]) - (sy[i] - y[i])) as f64).powi(2))
                .sum::<f64>()
                / n_el as f64
        };
        let u: Vec<f32> = (0..n_el).map(|_| rng.next_f32()).collect();
        let u2: Vec<f32> = (0..n_el).map(|_| rng.next_f32()).collect();
        let shared = err(&u, &u);
        let indep = err(&u, &u2);
        println!(
            "  spread {spread:<5} shared = {shared:.3e}   independent = {indep:.3e}   reduction = {:.2}x",
            indep / shared
        );
        json.metric(&format!("shared_noise.spread{spread}.reduction_x"), indep / shared);
    }

    // ---------------- B: entropy coding ------------------------------------
    section("B. entropy coding (§6 'bzip'): wire bytes per message, d = 100k");
    let d = 100_000;
    let mut rng = Pcg64::seeded(2);
    let codecs: Vec<Compression> = Compression::enabled()
        .into_iter()
        .filter(|&c| c != Compression::None)
        .collect();
    let header: Vec<String> = codecs.iter().map(|c| format!("{c:?}")).collect();
    println!(
        "  {:<22} {:>10} {}",
        "consensus spread",
        "packed",
        header.iter().map(|h| format!("{h:>10}")).collect::<String>()
    );
    for spread in [0.005f32, 0.05, 0.5, 2.0] {
        let cfg = QuantConfig::stochastic(8);
        let codec = MoniquaCodec::from_theta(2.0, &cfg);
        let x: Vec<f32> = (0..d)
            .map(|_| 0.3 + spread * (rng.next_f32() - 0.5))
            .collect();
        let noise: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
        // The fused wire path: packed bytes, no intermediate code vector.
        let mut packed = vec![0u8; packing::packed_len(d, cfg.bits)];
        codec.encode_packed_into(&x, &noise, &mut packed);
        let row: String = codecs
            .iter()
            .map(|c| format!("{:>10}", c.wire_len(&packed)))
            .collect();
        println!("  {:<22} {:>10} {}", format!("±{spread}"), packed.len(), row);
        json.metric(&format!("entropy.spread{spread}.packed_bytes"), packed.len() as f64);
        for c in &codecs {
            json.metric(
                &format!("entropy.spread{spread}.{c:?}_bytes"),
                c.wire_len(&packed) as f64,
            );
        }
    }
    println!("  (tight consensus → strongly compressible modulo streams, as §6 predicts; deflate/bzip2 rows appear with `--features compression`)");

    // ---------------- C: θ sensitivity -------------------------------------
    section("C. θ sweep on the decentralized quadratic (8-bit)");
    let q8 = QuantConfig::stochastic(8);
    for theta in [0.05f32, 0.25, 1.0, 2.0, 8.0, 32.0] {
        let loss = quad_loss(
            Algorithm::Moniqua { theta: ThetaPolicy::Constant(theta), quant: q8 },
            &w,
            steps,
        );
        println!("  theta = {theta:<6} final loss = {loss:.3e}");
        json.metric(&format!("theta_sweep.theta{theta}.final_loss"), loss);
    }
    let loss_auto = quad_loss(
        Algorithm::Moniqua {
            theta: ThetaPolicy::Theorem2 { warmup: 10, safety: 2.0 },
            quant: q8,
        },
        &w,
        steps,
    );
    println!("  theta = auto (Theorem-2 formula)  final loss = {loss_auto:.3e}");
    println!("  (too-small θ aliases the modulo and stalls; too-large θ wastes precision: δ·B grows with θ)");

    // hash verification as a θ-violation detector
    {
        use moniqua::algorithms::moniqua::MoniquaSync;
        let d = 32;
        let mut alg = MoniquaSync::new(
            w.clone(),
            d,
            ThetaPolicy::Constant(0.02),
            QuantConfig::nearest(8).with_verify_hash(true),
        );
        let mut xs: Vec<Vec<f32>> = (0..8).map(|i| vec![0.2 * i as f32; d]).collect();
        let grads: Vec<Vec<f32>> = (0..8).map(|_| vec![0.0; d]).collect();
        let ctx = StepCtx { seed: 1, rho: w.rho(), g_inf: 1.0 };
        alg.step(&mut xs, &grads, 0.0, 0, &ctx);
        println!(
            "  §6 verification: θ=0.02 with spread 1.4 → {} hash failures in one round (detected)",
            alg.verify_failures
        );
    }

    // ---------------- D: slack-matrix γ at 1 bit ----------------------------
    section("D. Theorem-3 slack matrix: 1-bit Moniqua vs γ (heterogeneous + noisy grads)");
    // Heterogeneous per-worker optima + gradient noise keep the workers
    // permanently decorrelated, so the 1-bit modulo noise actually couples
    // into the consensus dynamics (a symmetric noiseless quadratic would
    // cancel it exactly and show nothing).
    let quad_hetero = |algorithm: Algorithm, steps: u64| -> f64 {
        let n = w.n();
        let d = 64;
        let rho = w.rho();
        let mut alg = algorithm.make_sync(&w, d);
        let mut grng = Pcg64::seeded(77);
        let cs: Vec<f32> = (0..n).map(|i| 0.3 + 0.4 * (i as f32 - 3.5)).collect(); // mean 0.3
        let mut xs: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0; d]).collect();
        let ctx = StepCtx { seed: 11, rho, g_inf: 2.0 };
        for k in 0..steps {
            let grads: Vec<Vec<f32>> = (0..n)
                .map(|i| {
                    xs[i]
                        .iter()
                        .map(|&v| v - cs[i] + 0.1 * grng.next_gaussian() as f32)
                        .collect()
                })
                .collect();
            alg.step(&mut xs, &grads, 0.05, k, &ctx);
        }
        // distance of the averaged model from the global optimum 0.3
        let mut mean = vec![0.0f32; d];
        for x in &xs {
            moniqua::linalg::axpy(&mut mean, 1.0 / n as f32, x);
        }
        mean.iter().map(|&v| ((v - 0.3) as f64).powi(2)).sum::<f64>()
    };
    let one_bit = QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::stochastic(1) };
    println!("  full-precision dpsgd reference: {:.3e}", quad_hetero(Algorithm::DPsgd, steps * 2));
    for gamma in [1.0f64, 0.5, 0.2, 0.05, 0.01] {
        let loss = quad_hetero(
            Algorithm::MoniquaSlack {
                theta: ThetaPolicy::Constant(4.0),
                quant: one_bit,
                gamma,
            },
            steps * 2,
        );
        println!("  gamma = {gamma:<5} final loss = {loss:.3e}");
        json.metric(&format!("slack.gamma{gamma}.final_loss"), loss);
    }
    println!("  (moderate γ balances 1-bit modulo noise vs consensus speed — Theorem 3's trade-off)");
    json.metric("wall_s", bench_t0.elapsed().as_secs_f64());
    json.write().expect("write bench json");
}
