//! Self-test corpus for `moniqua-lint`.
//!
//! Two halves:
//!
//! 1. `tests/fixtures/bad_tree/` mimics the runtime crate's layout (the
//!    path-scoped rules key off relative paths like `quant/packing.rs`)
//!    with one deliberately-bad file per rule; every fixture must be
//!    flagged at its exact `file:line`, and nothing else may be flagged.
//! 2. The real `rust/src/` tree must produce **zero** diagnostics — the
//!    same invariant the CI `lint` job enforces.

use moniqua_lint::{analyze_sources, analyze_tree, Diagnostic, Rule};
use std::path::{Path, PathBuf};

fn fixture_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/bad_tree")
}

/// (line, rule) pairs for one fixture file, sorted.
fn hits(diags: &[Diagnostic], suffix: &str) -> Vec<(usize, Rule)> {
    let mut v: Vec<(usize, Rule)> = diags
        .iter()
        .filter(|d| d.file.ends_with(suffix))
        .map(|d| (d.line, d.rule))
        .collect();
    v.sort();
    v
}

#[test]
fn every_fixture_is_flagged_at_its_exact_line() {
    let diags = analyze_tree(&fixture_root()).expect("read fixture tree");

    assert_eq!(
        hits(&diags, "algorithms/graph.rs"),
        vec![(3, Rule::Unordered), (5, Rule::Unordered), (6, Rule::Unordered)],
    );
    assert_eq!(hits(&diags, "coordinator/timer.rs"), vec![(4, Rule::WallClock)]);
    // The telemetry clock confinement: `Instant` outside `telemetry/clock.rs`
    // is flagged at its exact line; the clock file itself is the exemption.
    assert_eq!(hits(&diags, "telemetry/sampler.rs"), vec![(6, Rule::WallClock)]);
    assert!(hits(&diags, "telemetry/clock.rs").is_empty());
    assert_eq!(
        hits(&diags, "quant/packing.rs"),
        vec![(4, Rule::CheckedArith), (8, Rule::CheckedArith), (12, Rule::CheckedArith)],
    );
    assert_eq!(
        hits(&diags, "transport/bad_panic.rs"),
        vec![(4, Rule::PanicSurface), (8, Rule::PanicSurface)],
    );
    assert_eq!(
        hits(&diags, "transport/frame.rs"),
        vec![(8, Rule::WireFormat), (10, Rule::WireFormat)],
    );
    assert_eq!(
        hits(&diags, "engine/hot.rs"),
        vec![(9, Rule::HotAlloc), (15, Rule::HotAlloc)],
    );
    // The byzantine-era hot paths: a robust-mix accumulate loop that
    // rebuilds its sort buffer per frame, and a frame-drain quarantine
    // check that copies the strike table per frame.
    assert_eq!(hits(&diags, "engine/robust_mix.rs"), vec![(6, Rule::HotAlloc)]);
    assert_eq!(hits(&diags, "coordinator/drain.rs"), vec![(8, Rule::HotAlloc)]);

    // The unparsable fixture reports the bookkeeping `parse` rule (its
    // exact line is syn's error span, which we do not pin).
    let parse: Vec<_> = diags.iter().filter(|d| d.file.ends_with("parse_error.rs")).collect();
    assert_eq!(parse.len(), 1);
    assert_eq!(parse[0].rule, Rule::Parse);

    // ... and nothing beyond the expectations above was flagged.
    assert_eq!(diags.len(), 17, "unexpected extra diagnostics:\n{}", render(&diags));
}

#[test]
fn real_source_tree_is_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../src");
    let diags = analyze_tree(&src).expect("read rust/src");
    assert!(diags.is_empty(), "rust/src must lint clean:\n{}", render(&diags));
}

#[test]
fn allow_marker_suppresses_the_flagged_line() {
    let src = r#"
pub fn stamp() -> u64 {
    // lint: allow(wall_clock) — timing is display-only here
    let t = std::time::Instant::now();
    let _ = t;
    0
}
"#;
    let diags = analyze_sources(&[("coordinator/timer.rs".into(), src.into())]);
    assert!(diags.is_empty(), "{}", render(&diags));

    // The same allow does NOT silence a different rule's tag.
    let diags = analyze_sources(&[(
        "coordinator/timer.rs".into(),
        src.replace("allow(wall_clock)", "allow(unordered)"),
    )]);
    assert_eq!(hits(&diags, "coordinator/timer.rs"), vec![(4, Rule::WallClock)]);
}

#[test]
fn cold_marker_cuts_the_hot_closure() {
    let hot_then_cold = r#"
// lint: hot-path
pub fn round_step() {
    helper();
}

// lint: cold
fn helper() {
    let _ = Vec::new();
}
"#;
    let diags = analyze_sources(&[("engine.rs".into(), hot_then_cold.into())]);
    assert!(diags.is_empty(), "{}", render(&diags));

    // Without the cold boundary the same allocation is reachable.
    let diags = analyze_sources(&[(
        "engine.rs".into(),
        hot_then_cold.replace("// lint: cold\n", ""),
    )]);
    assert_eq!(hits(&diags, "engine.rs"), vec![(8, Rule::HotAlloc)]);
}

#[test]
fn unattached_marker_is_itself_a_diagnostic() {
    let diags = analyze_sources(&[("orphan.rs".into(), "// lint: hot-path\n".into())]);
    assert_eq!(hits(&diags, "orphan.rs"), vec![(1, Rule::HotAlloc)]);
}

#[test]
fn test_code_is_exempt() {
    let src = r#"
#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn uses_hash_containers_freely() {
        let _ = HashMap::<u32, u32>::new();
        let _ = std::time::Instant::now();
    }
}
"#;
    let diags = analyze_sources(&[("algorithms/x.rs".into(), src.into())]);
    assert!(diags.is_empty(), "{}", render(&diags));
}

fn render(diags: &[Diagnostic]) -> String {
    diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
}
