//! Fixture: `wall_clock` — a wall-clock read in a value path.

pub fn stamp() -> u64 {
    let t = std::time::Instant::now();
    let _ = t;
    0
}
