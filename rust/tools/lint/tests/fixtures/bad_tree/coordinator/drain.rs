//! Fixture: `hot_alloc` — the quarantine check in the frame-drain path
//! must read the strike table in place, not rebuild it per frame.

// lint: hot-path
pub fn drain_frames(frames: &[u64], quarantined: &[u64]) -> usize {
    let mut kept = 0;
    for f in frames {
        let q = quarantined.to_vec();
        if !q.contains(f) {
            kept += 1;
        }
    }
    kept
}
