//! Fixture: `wall_clock` inside `telemetry/` — the clock confinement rule.
//! Only `telemetry/clock.rs` may touch `Instant`; a sibling module reaching
//! for it directly must be flagged like any other value-path clock read.

pub fn sample_ns() -> u64 {
    let t = std::time::Instant::now();
    t.elapsed().as_nanos() as u64
}
