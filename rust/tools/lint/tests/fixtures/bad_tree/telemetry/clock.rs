//! Fixture: `telemetry/clock.rs` is the **sole** telemetry wall-clock
//! exemption — the one file allowed to hold an `Instant`. Nothing in here
//! may be flagged; the sibling `telemetry/sampler.rs` proves the exemption
//! is path-exact, not a blanket `telemetry/` pass.

pub struct Clock {
    origin: std::time::Instant,
}

impl Clock {
    pub fn monotonic() -> Self {
        Self { origin: std::time::Instant::now() }
    }

    pub fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }
}
