//! Fixture: `checked_arith` — raw length arithmetic in a pack kernel.

pub fn packed_bytes(n_len: usize, bits: usize) -> usize {
    n_len * bits
}

pub fn joined_size(a: &[u8], b: &[u8]) -> usize {
    a.len() + b.len()
}

pub fn header_guess(data: &[u8]) -> u32 {
    data.len() as u32
}
