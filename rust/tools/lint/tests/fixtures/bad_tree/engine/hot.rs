//! Fixture: `hot_alloc` — allocations reachable from a hot-path seed.

// lint: hot-path
pub fn round_step(out: &mut Vec<u8>) {
    fill_payload(out);
}

fn fill_payload(out: &mut Vec<u8>) {
    let scratch = Vec::new();
    out.extend_from_slice(&scratch);
    let _ = make_frame();
}

fn make_frame() -> Vec<u8> {
    vec![0u8; 4]
}
