//! Fixture: `hot_alloc` — the robust-mix accumulate loop must reuse its
//! preallocated deviation rows and sort buffer, never allocate per frame.

// lint: hot-path
pub fn median_accumulate(rows: &[Vec<f32>], out: &mut Vec<f32>) {
    let mut sortbuf: Vec<f32> = rows.iter().map(|r| r[0]).collect();
    sortbuf.sort_by(f32::total_cmp);
    out.push(sortbuf[sortbuf.len() / 2]);
}
