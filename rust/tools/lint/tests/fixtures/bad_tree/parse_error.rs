//! Fixture: `parse` — this file is grammatically invalid on purpose.

pub fn broken() -> {}
