//! Fixture: `unordered` — a hash container in non-test code.

use std::collections::HashMap;

pub fn degree_table(edges: &[(usize, usize)]) -> HashMap<usize, usize> {
    let mut m = HashMap::new();
    for &(a, _) in edges {
        *m.entry(a).or_insert(0) += 1;
    }
    m
}
