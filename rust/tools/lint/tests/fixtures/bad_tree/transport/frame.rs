//! Fixture: `wire_format` — a layout gap and a `FrameKind` variant that
//! never appears in `to_wire`.

pub const HEADER_LEN: usize = 10;
pub const OFF_MAGIC: usize = 0;
pub const OFF_ROUND: usize = 6;

pub const FIELD_LAYOUT: [(usize, usize); 2] = [(OFF_MAGIC, 4), (OFF_ROUND, 4)];

pub enum FrameKind {
    Data,
    Bootstrap,
}

impl FrameKind {
    fn from_wire(v: u16) -> Option<FrameKind> {
        match v {
            0 => Some(FrameKind::Data),
            1 => Some(FrameKind::Bootstrap),
            _ => None,
        }
    }

    fn to_wire(self) -> u16 {
        match self {
            FrameKind::Data => 0,
            _ => 1,
        }
    }
}
