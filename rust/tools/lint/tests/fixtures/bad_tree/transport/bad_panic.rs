//! Fixture: `panic_surface` — unwrap/expect on the transport surface.

pub fn parse_port(s: &str) -> u16 {
    s.parse().unwrap()
}

pub fn first_byte(b: &[u8]) -> u8 {
    b.first().copied().expect("empty buffer")
}
