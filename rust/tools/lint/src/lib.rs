//! `moniqua-lint` — repo-invariant static analysis for the runtime crate.
//!
//! The runtime's correctness story (DESIGN.md §Static-analysis) rests on
//! invariants that `rustc` cannot see:
//!
//! * **Bitwise-deterministic replicas** — Trainer, DES, and ClusterTrainer
//!   must compute identical bytes, so unordered-iteration containers and
//!   wall-clock reads in value paths are correctness bugs, not style.
//! * **Zero-allocation steady-state rounds** — the pooled wire path
//!   (`tests/alloc_discipline.rs`) only stays allocation-free if nobody
//!   reintroduces a `Vec::new`/`clone`/`collect` under `node_send`/
//!   `node_recv`/the transports.
//! * **Total, checked decode** — the frame layer promises typed errors and
//!   overflow-free length arithmetic on attacker-controlled input.
//! * **Wire-format layout** — the 38-byte header is spelled out as named
//!   offsets that must tile `HEADER_LEN` exactly, and every `FrameKind`
//!   must round-trip through both encode and decode matches.
//!
//! This crate parses `rust/src/` with `syn` and enforces those invariants
//! as six rules, each reported as `file:line: [rule] message`:
//!
//! | tag                    | rule                                          |
//! |------------------------|-----------------------------------------------|
//! | `unordered`            | no `HashMap`/`HashSet` in non-test code       |
//! | `wall_clock`           | no `Instant`/`SystemTime`/`thread_rng`/`RandomState` outside `rng/`, `bench_support/`, `telemetry/clock.rs` |
//! | `checked_arith`        | no unchecked `+`/`*`/narrowing `as` on length-like values in the pack/frame kernels |
//! | `panic_surface`        | no `unwrap()`/`expect()` in `transport/` non-test code |
//! | `wire_format`          | `FIELD_LAYOUT` offsets tile `HEADER_LEN`; every `FrameKind` variant appears in `from_wire` **and** `to_wire` |
//! | `hot_alloc`            | no `Vec::new`/`vec!`/`clone`/`collect`/`to_vec`/`Box::new` in the call-graph closure of `// lint: hot-path` seeds |
//!
//! ## Marker protocol (the escape hatch)
//!
//! Markers are ordinary line comments, placed either on the line directly
//! above a `fn` signature (one attribute line may sit between) or anywhere
//! inside the function body:
//!
//! * `// lint: hot-path` — seeds the `hot_alloc` call-graph closure.
//! * `// lint: cold` — excludes the function from the hot set and stops
//!   traversal through it (for opt-in paths such as entropy recompression
//!   that are off under the zero-alloc contract).
//! * `// lint: allow(<tag>) — <reason>` — suppresses diagnostics of
//!   `<tag>`: on the next line when placed immediately above it, or from
//!   the marker line to the end of the enclosing function when placed in
//!   a body. Every allow must carry a reason; reviewers treat a new allow
//!   like a new `unsafe` block.
//!
//! The analysis is deliberately syntactic (no type inference): length-like
//! means "mentions `.len()` or an identifier named `len`/`*_len`/`*_LEN`",
//! and the call graph resolves `Type::fn` by impl-type name and method
//! calls by name alone. That makes it conservative in a predictable way —
//! `#[cfg(not(test))]` code is under-linted rather than mis-linted, and a
//! name-only edge can only *widen* the hot set, never drop a function
//! from it.

use proc_macro2::TokenTree;
use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::path::{Path, PathBuf};
use syn::spanned::Spanned;
use syn::visit::{self, Visit};

/// The six enforced rules plus the bookkeeping `parse` rule (a file that
/// does not parse cannot be certified, so it is itself a diagnostic).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    Unordered,
    WallClock,
    CheckedArith,
    PanicSurface,
    WireFormat,
    HotAlloc,
    Parse,
}

impl Rule {
    /// The short tag used in diagnostics and in `// lint: allow(<tag>)`.
    pub fn tag(self) -> &'static str {
        match self {
            Rule::Unordered => "unordered",
            Rule::WallClock => "wall_clock",
            Rule::CheckedArith => "checked_arith",
            Rule::PanicSurface => "panic_surface",
            Rule::WireFormat => "wire_format",
            Rule::HotAlloc => "hot_alloc",
            Rule::Parse => "parse",
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.tag())
    }
}

/// One finding, addressed like a compiler error.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diagnostic {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

const UNORDERED_TYPES: &[&str] = &["HashMap", "HashSet"];
const WALL_CLOCK_NAMES: &[&str] = &["Instant", "SystemTime", "thread_rng", "RandomState"];
const DENIED_ALLOC_METHODS: &[&str] = &["clone", "collect", "to_vec"];
/// Files under the checked-arithmetic rule: the kernels whose length math
/// runs against wire-controlled sizes.
const ARITH_SCOPE: &[&str] = &[
    "quant/packing.rs",
    "quant/moniqua.rs",
    "quant/entropy.rs",
    "transport/frame.rs",
];

#[derive(Clone, Debug, PartialEq, Eq)]
enum MarkerKind {
    HotPath,
    Cold,
    Allow(String),
}

#[derive(Clone, Debug)]
struct Marker {
    kind: MarkerKind,
    line: usize,
}

#[derive(Clone, Debug)]
struct FnRec {
    name: String,
    /// Impl self-type (or trait name) for `Type::fn` call resolution.
    owner: Option<String>,
    sig_line: usize,
    end_line: usize,
}

#[derive(Clone, Debug)]
struct CallRec {
    fn_ix: usize,
    name: String,
    /// `Some(TypeName)` only for `Type::fn(..)` paths with an
    /// uppercase-initial qualifier (`Self::` is resolved to the enclosing
    /// impl type at collection time). Method calls and module-qualified
    /// calls resolve by name alone.
    qual: Option<String>,
}

#[derive(Clone, Debug)]
enum EventKind {
    Unordered(String),
    WallClock(String),
    LenArith(&'static str),
    LenCast(String),
    Panic(String),
    Alloc(String),
}

#[derive(Clone, Debug)]
struct Event {
    kind: EventKind,
    line: usize,
    fn_ix: Option<usize>,
}

/// Reference to an offset in `FIELD_LAYOUT`: a named `OFF_*` const or an
/// integer literal.
#[derive(Clone, Debug)]
enum OffRef {
    Name(String),
    Lit(usize),
}

#[derive(Default)]
struct FileAnalysis {
    rel: String,
    fns: Vec<FnRec>,
    calls: Vec<CallRec>,
    events: Vec<Event>,
    markers: Vec<Marker>,
    /// Integer-literal consts (`HEADER_LEN`, `OFF_*`) for the wire rule.
    int_consts: BTreeMap<String, usize>,
    field_layout: Option<(usize, Vec<(OffRef, usize)>)>,
    field_layout_malformed: Option<usize>,
    /// `FrameKind` enum: declaration line + variant names.
    frame_kind: Option<(usize, Vec<String>)>,
    /// Path identifiers mentioned inside `from_wire` / `to_wire` bodies.
    wire_fn_idents: BTreeMap<String, Vec<String>>,
}

fn parse_markers(text: &str) -> Vec<Marker> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let ln = i + 1;
        let Some(pos) = line.find("// lint:") else { continue };
        let rest = line[pos + "// lint:".len()..].trim_start();
        if rest.starts_with("hot-path") {
            out.push(Marker { kind: MarkerKind::HotPath, line: ln });
        } else if rest.starts_with("cold") {
            out.push(Marker { kind: MarkerKind::Cold, line: ln });
        } else if let Some(r) = rest.strip_prefix("allow(") {
            if let Some(end) = r.find(')') {
                out.push(Marker {
                    kind: MarkerKind::Allow(r[..end].trim().to_string()),
                    line: ln,
                });
            }
        }
    }
    out
}

fn is_cfg_test(attrs: &[syn::Attribute]) -> bool {
    attrs.iter().any(|a| {
        if a.path().is_ident("test") {
            return true;
        }
        if !a.path().is_ident("cfg") {
            return false;
        }
        match &a.meta {
            // NB: matches `cfg(not(test))` too — deliberate under-linting
            // in preference to parsing cfg boolean logic.
            syn::Meta::List(l) => l.tokens.to_string().contains("test"),
            _ => false,
        }
    })
}

/// Syntactic "this expression is about a length": mentions `.len()` or an
/// identifier named `len` / `*_len` / `*_LEN`.
fn is_len_like(e: &syn::Expr) -> bool {
    struct F {
        found: bool,
    }
    impl<'a> Visit<'a> for F {
        fn visit_expr_method_call(&mut self, n: &'a syn::ExprMethodCall) {
            if n.method == "len" && n.args.is_empty() {
                self.found = true;
            }
            visit::visit_expr_method_call(self, n);
        }
        fn visit_path(&mut self, n: &'a syn::Path) {
            if let Some(seg) = n.segments.last() {
                let s = seg.ident.to_string();
                if s == "len" || s.ends_with("_len") || s.ends_with("_LEN") {
                    self.found = true;
                }
            }
            visit::visit_path(self, n);
        }
    }
    let mut f = F { found: false };
    f.visit_expr(e);
    f.found
}

fn lit_usize(e: &syn::Expr) -> Option<usize> {
    if let syn::Expr::Lit(l) = e {
        if let syn::Lit::Int(i) = &l.lit {
            return i.base10_parse::<usize>().ok();
        }
    }
    None
}

fn parse_layout(e: &syn::Expr) -> Option<Vec<(OffRef, usize)>> {
    let syn::Expr::Array(arr) = e else { return None };
    let mut out = Vec::new();
    for elem in &arr.elems {
        let syn::Expr::Tuple(t) = elem else { return None };
        if t.elems.len() != 2 {
            return None;
        }
        let off = match &t.elems[0] {
            syn::Expr::Path(p) => OffRef::Name(p.path.segments.last()?.ident.to_string()),
            other => OffRef::Lit(lit_usize(other)?),
        };
        let width = lit_usize(&t.elems[1])?;
        out.push((off, width));
    }
    Some(out)
}

struct Collector<'a> {
    out: &'a mut FileAnalysis,
    fn_stack: Vec<usize>,
    impl_type: Vec<Option<String>>,
    test_depth: usize,
}

impl<'a> Collector<'a> {
    fn in_fn(&self) -> Option<usize> {
        self.fn_stack.last().copied()
    }

    fn event(&mut self, kind: EventKind, line: usize) {
        let fn_ix = self.in_fn();
        self.out.events.push(Event { kind, line, fn_ix });
    }

    fn begin_fn(&mut self, sig: &syn::Signature, body: &syn::Block) -> bool {
        if self.test_depth > 0 {
            return false;
        }
        self.out.fns.push(FnRec {
            name: sig.ident.to_string(),
            owner: self.impl_type.last().cloned().flatten(),
            sig_line: sig.ident.span().start().line,
            end_line: body.span().end().line,
        });
        self.fn_stack.push(self.out.fns.len() - 1);
        true
    }

    fn scan_tokens(&mut self, ts: proc_macro2::TokenStream) {
        for tt in ts {
            match tt {
                TokenTree::Group(g) => self.scan_tokens(g.stream()),
                TokenTree::Ident(id) => {
                    let s = id.to_string();
                    let line = id.span().start().line;
                    if UNORDERED_TYPES.contains(&s.as_str()) {
                        self.event(EventKind::Unordered(s.clone()), line);
                    }
                    if WALL_CLOCK_NAMES.contains(&s.as_str()) && self.in_fn().is_some() {
                        self.event(EventKind::WallClock(s), line);
                    }
                }
                _ => {}
            }
        }
    }
}

impl<'a, 'ast> Visit<'ast> for Collector<'a> {
    fn visit_item_mod(&mut self, node: &'ast syn::ItemMod) {
        let test = is_cfg_test(&node.attrs);
        if test {
            self.test_depth += 1;
        }
        visit::visit_item_mod(self, node);
        if test {
            self.test_depth -= 1;
        }
    }

    fn visit_item_impl(&mut self, node: &'ast syn::ItemImpl) {
        let test = is_cfg_test(&node.attrs);
        if test {
            self.test_depth += 1;
        }
        let name = match &*node.self_ty {
            syn::Type::Path(tp) => tp.path.segments.last().map(|s| s.ident.to_string()),
            _ => None,
        };
        self.impl_type.push(name);
        visit::visit_item_impl(self, node);
        self.impl_type.pop();
        if test {
            self.test_depth -= 1;
        }
    }

    fn visit_item_trait(&mut self, node: &'ast syn::ItemTrait) {
        let test = is_cfg_test(&node.attrs);
        if test {
            self.test_depth += 1;
        }
        self.impl_type.push(Some(node.ident.to_string()));
        visit::visit_item_trait(self, node);
        self.impl_type.pop();
        if test {
            self.test_depth -= 1;
        }
    }

    fn visit_item_fn(&mut self, node: &'ast syn::ItemFn) {
        let test = is_cfg_test(&node.attrs);
        if test {
            self.test_depth += 1;
        }
        let registered = !test && self.begin_fn(&node.sig, &node.block);
        visit::visit_item_fn(self, node);
        if registered {
            self.fn_stack.pop();
        }
        if test {
            self.test_depth -= 1;
        }
    }

    fn visit_impl_item_fn(&mut self, node: &'ast syn::ImplItemFn) {
        let test = is_cfg_test(&node.attrs);
        if test {
            self.test_depth += 1;
        }
        let registered = !test && self.begin_fn(&node.sig, &node.block);
        visit::visit_impl_item_fn(self, node);
        if registered {
            self.fn_stack.pop();
        }
        if test {
            self.test_depth -= 1;
        }
    }

    fn visit_trait_item_fn(&mut self, node: &'ast syn::TraitItemFn) {
        let test = is_cfg_test(&node.attrs);
        if test {
            self.test_depth += 1;
        }
        // Only default methods have bodies worth walking.
        let registered = match (&node.default, test) {
            (Some(body), false) => self.begin_fn(&node.sig, body),
            _ => false,
        };
        visit::visit_trait_item_fn(self, node);
        if registered {
            self.fn_stack.pop();
        }
        if test {
            self.test_depth -= 1;
        }
    }

    fn visit_item_use(&mut self, node: &'ast syn::ItemUse) {
        if self.test_depth == 0 {
            fn walk(c: &mut Collector<'_>, t: &syn::UseTree) {
                match t {
                    syn::UseTree::Path(p) => walk(c, &p.tree),
                    syn::UseTree::Name(n) => {
                        let s = n.ident.to_string();
                        if UNORDERED_TYPES.contains(&s.as_str()) {
                            let line = n.ident.span().start().line;
                            c.event(EventKind::Unordered(s), line);
                        }
                    }
                    syn::UseTree::Rename(r) => {
                        let s = r.ident.to_string();
                        if UNORDERED_TYPES.contains(&s.as_str()) {
                            let line = r.ident.span().start().line;
                            c.event(EventKind::Unordered(s), line);
                        }
                    }
                    syn::UseTree::Group(g) => {
                        for item in &g.items {
                            walk(c, item);
                        }
                    }
                    syn::UseTree::Glob(_) => {}
                }
            }
            walk(self, &node.tree);
        }
        visit::visit_item_use(self, node);
    }

    fn visit_path(&mut self, node: &'ast syn::Path) {
        if self.test_depth == 0 {
            let wire_fn = self.in_fn().map(|f| self.out.fns[f].name.clone());
            for seg in &node.segments {
                let id = seg.ident.to_string();
                let line = seg.ident.span().start().line;
                if UNORDERED_TYPES.contains(&id.as_str()) {
                    self.event(EventKind::Unordered(id.clone()), line);
                }
                if WALL_CLOCK_NAMES.contains(&id.as_str()) && self.in_fn().is_some() {
                    self.event(EventKind::WallClock(id.clone()), line);
                }
                if let Some(name) = &wire_fn {
                    if name == "from_wire" || name == "to_wire" {
                        self.out
                            .wire_fn_idents
                            .entry(name.clone())
                            .or_default()
                            .push(id);
                    }
                }
            }
        }
        visit::visit_path(self, node);
    }

    fn visit_expr_call(&mut self, node: &'ast syn::ExprCall) {
        if self.test_depth == 0 {
            if let Some(f) = self.in_fn() {
                if let syn::Expr::Path(p) = &*node.func {
                    let segs: Vec<String> =
                        p.path.segments.iter().map(|s| s.ident.to_string()).collect();
                    if let Some(name) = segs.last().cloned() {
                        let mut qual = if segs.len() >= 2 {
                            Some(segs[segs.len() - 2].clone())
                        } else {
                            None
                        };
                        if qual.as_deref() == Some("Self") {
                            qual = self.impl_type.last().cloned().flatten();
                        }
                        let typed = qual
                            .as_deref()
                            .and_then(|q| q.chars().next())
                            .is_some_and(|c| c.is_ascii_uppercase());
                        if typed && name == "new" {
                            if let Some(q) = qual.as_deref() {
                                if q == "Vec" || q == "Box" {
                                    self.event(
                                        EventKind::Alloc(format!("{q}::new()")),
                                        node.span().start().line,
                                    );
                                }
                            }
                        }
                        self.out.calls.push(CallRec {
                            fn_ix: f,
                            name,
                            qual: if typed { qual } else { None },
                        });
                    }
                }
            }
        }
        visit::visit_expr_call(self, node);
    }

    fn visit_expr_method_call(&mut self, node: &'ast syn::ExprMethodCall) {
        if self.test_depth == 0 {
            if let Some(f) = self.in_fn() {
                let m = node.method.to_string();
                let line = node.method.span().start().line;
                if m == "unwrap" || m == "expect" {
                    self.event(EventKind::Panic(format!("{m}()")), line);
                }
                if DENIED_ALLOC_METHODS.contains(&m.as_str()) {
                    self.event(EventKind::Alloc(format!(".{m}()")), line);
                }
                self.out.calls.push(CallRec { fn_ix: f, name: m, qual: None });
            }
        }
        visit::visit_expr_method_call(self, node);
    }

    fn visit_expr_binary(&mut self, node: &'ast syn::ExprBinary) {
        if self.test_depth == 0 {
            use syn::BinOp;
            let op = match node.op {
                BinOp::Add(_) | BinOp::AddAssign(_) => Some("+"),
                BinOp::Mul(_) | BinOp::MulAssign(_) => Some("*"),
                _ => None,
            };
            if let Some(op) = op {
                if is_len_like(&node.left) || is_len_like(&node.right) {
                    self.event(EventKind::LenArith(op), node.span().start().line);
                }
            }
        }
        visit::visit_expr_binary(self, node);
    }

    fn visit_expr_cast(&mut self, node: &'ast syn::ExprCast) {
        if self.test_depth == 0 {
            if let syn::Type::Path(tp) = &*node.ty {
                if let Some(seg) = tp.path.segments.last() {
                    let t = seg.ident.to_string();
                    if matches!(t.as_str(), "u8" | "u16" | "u32") && is_len_like(&node.expr) {
                        self.event(EventKind::LenCast(t), node.span().start().line);
                    }
                }
            }
        }
        visit::visit_expr_cast(self, node);
    }

    fn visit_macro(&mut self, node: &'ast syn::Macro) {
        if self.test_depth == 0 {
            if let Some(seg) = node.path.segments.last() {
                if seg.ident == "vec" && self.in_fn().is_some() {
                    self.event(
                        EventKind::Alloc("vec! macro".to_string()),
                        node.span().start().line,
                    );
                }
            }
            self.scan_tokens(node.tokens.clone());
        }
        visit::visit_macro(self, node);
    }

    fn visit_item_const(&mut self, node: &'ast syn::ItemConst) {
        if self.test_depth == 0 {
            let name = node.ident.to_string();
            let line = node.ident.span().start().line;
            if name == "FIELD_LAYOUT" {
                match parse_layout(&node.expr) {
                    Some(entries) => self.out.field_layout = Some((line, entries)),
                    None => self.out.field_layout_malformed = Some(line),
                }
            } else if let Some(v) = lit_usize(&node.expr) {
                self.out.int_consts.insert(name, v);
            }
        }
        visit::visit_item_const(self, node);
    }

    fn visit_item_enum(&mut self, node: &'ast syn::ItemEnum) {
        if self.test_depth == 0 && node.ident == "FrameKind" {
            let variants = node.variants.iter().map(|v| v.ident.to_string()).collect();
            self.out.frame_kind = Some((node.ident.span().start().line, variants));
        }
        visit::visit_item_enum(self, node);
    }
}

/// The function a marker belongs to: the signature on the next line or the
/// one after (one attribute line may intervene), else the innermost
/// function whose body spans the marker line.
fn attach_fn(fns: &[FnRec], line: usize) -> Option<usize> {
    let mut above: Option<usize> = None;
    for (i, f) in fns.iter().enumerate() {
        if f.sig_line > line && f.sig_line - line <= 2 {
            match above {
                Some(j) if fns[j].sig_line <= f.sig_line => {}
                _ => above = Some(i),
            }
        }
    }
    if above.is_some() {
        return above;
    }
    let mut best: Option<usize> = None;
    for (i, f) in fns.iter().enumerate() {
        if f.sig_line <= line && line <= f.end_line {
            match best {
                Some(j) if fns[j].end_line - fns[j].sig_line <= f.end_line - f.sig_line => {}
                _ => best = Some(i),
            }
        }
    }
    best
}

fn suppressed(fa: &FileAnalysis, tag: &str, line: usize) -> bool {
    fa.markers.iter().any(|m| {
        let MarkerKind::Allow(r) = &m.kind else { return false };
        if r != tag {
            return false;
        }
        if m.line == line || m.line + 1 == line {
            return true;
        }
        if let Some(ix) = attach_fn(&fa.fns, m.line) {
            let f = &fa.fns[ix];
            return m.line <= line && f.sig_line <= line && line <= f.end_line;
        }
        false
    })
}

/// Analyze in-memory sources: `(relative_path, contents)` pairs. Paths use
/// `/` separators relative to the source root (e.g. `transport/frame.rs`).
pub fn analyze_sources(files: &[(String, String)]) -> Vec<Diagnostic> {
    let mut analyses: Vec<FileAnalysis> = Vec::new();
    let mut diags: Vec<Diagnostic> = Vec::new();

    for (rel, text) in files {
        let mut fa = FileAnalysis { rel: rel.clone(), ..FileAnalysis::default() };
        fa.markers = parse_markers(text);
        match syn::parse_file(text) {
            Ok(ast) => {
                let mut c = Collector {
                    out: &mut fa,
                    fn_stack: Vec::new(),
                    impl_type: Vec::new(),
                    test_depth: 0,
                };
                c.visit_file(&ast);
            }
            Err(e) => {
                diags.push(Diagnostic {
                    file: rel.clone(),
                    line: e.span().start().line.max(1),
                    rule: Rule::Parse,
                    message: format!("file does not parse: {e}"),
                });
            }
        }
        analyses.push(fa);
    }

    // Per-file rules 1–5.
    for fa in &analyses {
        let rel = fa.rel.as_str();
        let in_bench = rel.starts_with("bench_support");
        let in_rng = rel.starts_with("rng");
        // The telemetry plane confines monotonic time to exactly one file;
        // everything else under telemetry/ must go through its `Clock`.
        let in_clock = rel == "telemetry/clock.rs";
        let in_transport = rel.starts_with("transport");
        let in_arith = ARITH_SCOPE.contains(&rel);

        for ev in &fa.events {
            match &ev.kind {
                EventKind::Unordered(name) if !in_bench => {
                    if !suppressed(fa, Rule::Unordered.tag(), ev.line) {
                        diags.push(Diagnostic {
                            file: rel.to_string(),
                            line: ev.line,
                            rule: Rule::Unordered,
                            message: format!(
                                "`{name}` has nondeterministic iteration order; replicas must \
                                 be bitwise-identical — use `BTreeMap`/`BTreeSet` or sort \
                                 explicitly"
                            ),
                        });
                    }
                }
                EventKind::WallClock(name) if !in_bench && !in_rng && !in_clock => {
                    if !suppressed(fa, Rule::WallClock.tag(), ev.line) {
                        diags.push(Diagnostic {
                            file: rel.to_string(),
                            line: ev.line,
                            rule: Rule::WallClock,
                            message: format!(
                                "`{name}` reads ambient entropy/time; value paths must be \
                                 deterministic (allowed only in `rng/`, `bench_support/`, and \
                                 `telemetry/clock.rs`)"
                            ),
                        });
                    }
                }
                EventKind::LenArith(op) if in_arith => {
                    if !suppressed(fa, Rule::CheckedArith.tag(), ev.line) {
                        diags.push(Diagnostic {
                            file: rel.to_string(),
                            line: ev.line,
                            rule: Rule::CheckedArith,
                            message: format!(
                                "unchecked `{op}` on a length-like value; use \
                                 `checked_add`/`saturating_mul`/`try_packed_len`-style helpers"
                            ),
                        });
                    }
                }
                EventKind::LenCast(ty) if in_arith => {
                    if !suppressed(fa, Rule::CheckedArith.tag(), ev.line) {
                        diags.push(Diagnostic {
                            file: rel.to_string(),
                            line: ev.line,
                            rule: Rule::CheckedArith,
                            message: format!(
                                "narrowing `as {ty}` cast of a length-like value; use \
                                 `{ty}::try_from` and handle the error"
                            ),
                        });
                    }
                }
                EventKind::Panic(what) if in_transport => {
                    if !suppressed(fa, Rule::PanicSurface.tag(), ev.line) {
                        diags.push(Diagnostic {
                            file: rel.to_string(),
                            line: ev.line,
                            rule: Rule::PanicSurface,
                            message: format!(
                                "`{what}` in transport code; decode/recv paths return typed \
                                 `FrameError`/`TransportError`, never panic"
                            ),
                        });
                    }
                }
                _ => {}
            }
        }

        // Rule 5: wire-format structure, only meaningful for frame.rs.
        if rel.ends_with("transport/frame.rs") || rel == "transport/frame.rs" {
            diags.extend(check_wire_format(fa));
        }
    }

    // Rule 6: hot-path allocation, a crate-global call-graph closure.
    diags.extend(check_hot_alloc(&analyses));

    diags.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    diags
}

fn check_wire_format(fa: &FileAnalysis) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let rel = fa.rel.clone();
    let mk = |line: usize, message: String| Diagnostic {
        file: rel.clone(),
        line,
        rule: Rule::WireFormat,
        message,
    };

    if let Some(line) = fa.field_layout_malformed {
        diags.push(mk(
            line,
            "FIELD_LAYOUT must be a literal array of (OFF_* | integer, width) tuples".into(),
        ));
        return diags;
    }
    match (&fa.field_layout, fa.int_consts.get("HEADER_LEN")) {
        (Some((line, entries)), Some(&header_len)) => {
            let mut expected = 0usize;
            let mut ok = true;
            for (off_ref, width) in entries {
                let off = match off_ref {
                    OffRef::Lit(v) => Some(*v),
                    OffRef::Name(n) => fa.int_consts.get(n).copied(),
                };
                match off {
                    None => {
                        let n = match off_ref {
                            OffRef::Name(n) => n.clone(),
                            OffRef::Lit(v) => v.to_string(),
                        };
                        diags.push(mk(
                            *line,
                            format!("FIELD_LAYOUT references `{n}`, which is not an \
                                     integer-literal const in this file"),
                        ));
                        ok = false;
                        break;
                    }
                    Some(o) if o != expected => {
                        diags.push(mk(
                            *line,
                            format!(
                                "FIELD_LAYOUT gap/overlap: field at offset {o} but the \
                                 previous field ends at {expected}"
                            ),
                        ));
                        ok = false;
                        break;
                    }
                    Some(o) => expected = o + width,
                }
            }
            if ok && expected != header_len {
                diags.push(mk(
                    *line,
                    format!(
                        "FIELD_LAYOUT widths sum to {expected} but HEADER_LEN is {header_len}"
                    ),
                ));
            }
        }
        (None, _) => diags.push(mk(
            1,
            "frame.rs must declare the header as a FIELD_LAYOUT const of named offsets".into(),
        )),
        (_, None) => diags.push(mk(
            1,
            "frame.rs must declare HEADER_LEN as an integer-literal const".into(),
        )),
    }

    if let Some((line, variants)) = &fa.frame_kind {
        for dir in ["from_wire", "to_wire"] {
            match fa.wire_fn_idents.get(dir) {
                None => diags.push(mk(
                    *line,
                    format!("FrameKind must have a `{dir}` conversion covering every variant"),
                )),
                Some(idents) => {
                    for v in variants {
                        if !idents.iter().any(|i| i == v) {
                            diags.push(mk(
                                *line,
                                format!("FrameKind variant `{v}` never appears in `{dir}`"),
                            ));
                        }
                    }
                }
            }
        }
    }
    diags
}

fn check_hot_alloc(analyses: &[FileAnalysis]) -> Vec<Diagnostic> {
    let mut diags = Vec::new();

    // Global function tables.
    let mut by_name: BTreeMap<&str, Vec<(usize, usize)>> = BTreeMap::new();
    let mut by_typed: BTreeMap<(&str, &str), Vec<(usize, usize)>> = BTreeMap::new();
    for (fi, fa) in analyses.iter().enumerate() {
        for (xi, f) in fa.fns.iter().enumerate() {
            by_name.entry(f.name.as_str()).or_default().push((fi, xi));
            if let Some(owner) = &f.owner {
                by_typed
                    .entry((owner.as_str(), f.name.as_str()))
                    .or_default()
                    .push((fi, xi));
            }
        }
    }

    // Seeds and cold boundaries from markers.
    let mut seeds: Vec<(usize, usize)> = Vec::new();
    let mut cold: BTreeSet<(usize, usize)> = BTreeSet::new();
    for (fi, fa) in analyses.iter().enumerate() {
        for m in &fa.markers {
            let target = attach_fn(&fa.fns, m.line);
            match (&m.kind, target) {
                (MarkerKind::HotPath, Some(ix)) => seeds.push((fi, ix)),
                (MarkerKind::Cold, Some(ix)) => {
                    cold.insert((fi, ix));
                }
                (MarkerKind::HotPath, None) | (MarkerKind::Cold, None) => {
                    diags.push(Diagnostic {
                        file: fa.rel.clone(),
                        line: m.line,
                        rule: Rule::HotAlloc,
                        message: "lint marker is not attached to any function (place it \
                                  directly above a `fn` signature or inside a body)"
                            .into(),
                    });
                }
                (MarkerKind::Allow(_), _) => {}
            }
        }
    }

    // Closure over the call graph.
    let mut hot: BTreeSet<(usize, usize)> = BTreeSet::new();
    let mut work: Vec<(usize, usize)> = Vec::new();
    for s in seeds {
        if !cold.contains(&s) && hot.insert(s) {
            work.push(s);
        }
    }
    while let Some((fi, xi)) = work.pop() {
        for call in analyses[fi].calls.iter().filter(|c| c.fn_ix == xi) {
            let candidates: &[(usize, usize)] = match &call.qual {
                // `Type::fn` resolves within impls of that type name only;
                // no fallback — an unmatched typed call targets std.
                Some(q) => by_typed
                    .get(&(q.as_str(), call.name.as_str()))
                    .map(|v| v.as_slice())
                    .unwrap_or(&[]),
                None => by_name
                    .get(call.name.as_str())
                    .map(|v| v.as_slice())
                    .unwrap_or(&[]),
            };
            for &c in candidates {
                if !cold.contains(&c) && hot.insert(c) {
                    work.push(c);
                }
            }
        }
    }

    for &(fi, xi) in &hot {
        let fa = &analyses[fi];
        let fname = &fa.fns[xi].name;
        for ev in &fa.events {
            if ev.fn_ix != Some(xi) {
                continue;
            }
            let EventKind::Alloc(what) = &ev.kind else { continue };
            if suppressed(fa, Rule::HotAlloc.tag(), ev.line) {
                continue;
            }
            diags.push(Diagnostic {
                file: fa.rel.clone(),
                line: ev.line,
                rule: Rule::HotAlloc,
                message: format!(
                    "{what} allocates inside `{fname}`, which is reachable from a \
                     `// lint: hot-path` seed; steady-state rounds must be allocation-free"
                ),
            });
        }
    }
    diags
}

/// Recursively collect `.rs` files under `root`, sorted for deterministic
/// output, as paths relative to `root`.
pub fn collect_rs_files(root: &Path) -> std::io::Result<Vec<PathBuf>> {
    fn walk(dir: &Path, base: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
        let mut entries: Vec<_> =
            std::fs::read_dir(dir)?.collect::<std::io::Result<Vec<_>>>()?;
        entries.sort_by_key(|e| e.file_name());
        for e in entries {
            let p = e.path();
            if p.is_dir() {
                walk(&p, base, out)?;
            } else if p.extension().is_some_and(|x| x == "rs") {
                if let Ok(rel) = p.strip_prefix(base) {
                    out.push(rel.to_path_buf());
                }
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    walk(root, root, &mut out)?;
    Ok(out)
}

/// Analyze every `.rs` file under `root` (the crate's `src/` directory).
/// Diagnostics carry paths prefixed with `root` so they are clickable from
/// the invocation directory.
pub fn analyze_tree(root: &Path) -> std::io::Result<Vec<Diagnostic>> {
    let rels = collect_rs_files(root)?;
    let mut files = Vec::new();
    for rel in &rels {
        let text = std::fs::read_to_string(root.join(rel))?;
        let rel_str = rel
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        files.push((rel_str, text));
    }
    let mut diags = analyze_sources(&files);
    for d in &mut diags {
        d.file = format!("{}/{}", root.display(), d.file);
    }
    Ok(diags)
}
