//! CLI for `moniqua-lint`: analyze a source tree, print `file:line`
//! diagnostics, exit nonzero on any finding.
//!
//! ```text
//! moniqua-lint [SRC_DIR]    # default: src (run from rust/)
//! ```

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let root = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("src"));
    if !root.is_dir() {
        eprintln!("moniqua-lint: `{}` is not a directory", root.display());
        return ExitCode::from(2);
    }
    let files = match moniqua_lint::collect_rs_files(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("moniqua-lint: cannot walk `{}`: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    let diags = match moniqua_lint::analyze_tree(&root) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("moniqua-lint: cannot read `{}`: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    for d in &diags {
        println!("{d}");
    }
    if diags.is_empty() {
        println!(
            "moniqua-lint: {} files clean (unordered, wall_clock, checked_arith, \
             panic_surface, wire_format, hot_alloc)",
            files.len()
        );
        ExitCode::SUCCESS
    } else {
        println!("moniqua-lint: {} diagnostic(s) in {} files", diags.len(), files.len());
        ExitCode::FAILURE
    }
}
