//! # Moniqua — Modulo Quantized Communication in Decentralized SGD
//!
//! Full-system reproduction of Lu & De Sa, *Moniqua: Modulo Quantized
//! Communication in Decentralized SGD* (ICML 2020), as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the decentralized-training coordinator: graph
//!   topologies and doubly-stochastic communication matrices, the full
//!   quantizer stack (linear quantizers, bit-packing, the Moniqua modulo
//!   wrap/unwrap of Lemmas 1–2, entropy coding, θ policies), the paper's
//!   algorithm plus every baseline it compares against (D-PSGD, DCD/ECD,
//!   ChocoSGD, DeepSqueeze, D², AD-PSGD, AllReduce), a parametric network
//!   simulator, and synchronous / asynchronous training runtimes.
//! * **L2/L1 (python/, build-time only)** — a JAX transformer LM whose MLP
//!   runs through Pallas kernels, AOT-lowered to HLO text in `artifacts/`.
//! * **Runtime bridge** — [`runtime`] loads those artifacts through the
//!   `xla` crate's PJRT CPU client; Python never runs on the training path.
//!
//! ## Quick start
//!
//! ```no_run
//! use std::sync::Arc;
//! use moniqua::prelude::*;
//! use moniqua::objectives::Logistic;
//!
//! let topo = Topology::ring(8);
//! let quant = QuantConfig::stochastic(8).with_shared_randomness(true);
//! let cfg = TrainConfig {
//!     workers: 8,
//!     steps: 500,
//!     lr: 0.1,
//!     algorithm: Algorithm::Moniqua { theta: ThetaPolicy::Constant(2.0), quant },
//!     ..TrainConfig::default()
//! };
//! let data = Arc::new(SynthClassification::default());
//! let objective = Box::new(Logistic::new(data, 8, Partition::Iid, 32, 7));
//! let mut runner = Trainer::new(cfg, topo, objective);
//! let report = runner.run();
//! println!("final loss {:.4}", report.final_loss());
//! ```
//!
//! See `examples/` for runnable end-to-end drivers and `rust/benches/` for
//! the harnesses that regenerate every figure and table in the paper.
//!
//! ## Architecture notes
//!
//! `rust/DESIGN.md` documents the system design; in particular **§Engine**
//! describes the parallel round engine every synchronous algorithm runs on
//! ([`algorithms::engine::RoundPool`]): the three per-round phases, how
//! they fan out across cores, the fused quantize→pack wire path
//! ([`quant::MoniquaCodec::encode_packed_into`] /
//! [`quant::MoniquaCodec::recover_packed_into`]), and the determinism
//! contract that makes pool width a pure performance knob (bitwise-equal
//! results at every width, pinned by `tests/engine_equivalence.rs`).
//! **§Event-model** documents the discrete-event runtime
//! ([`coordinator::des`]): heterogeneous per-edge links
//! ([`network::LinkMatrix`]), straggler/drop/delay fault injection with
//! Moniqua-aware recovery, time-varying topologies
//! ([`topology::TopologySchedule`]), and the `(time, seq)` determinism
//! contract pinned by `tests/des_determinism.rs`. **§Elasticity** documents
//! the membership + checkpoint/recovery subsystem ([`elastic`]): versioned
//! snapshots with per-algorithm engine state, frame-log crash replay that
//! is bitwise-transparent to the rest of the cluster (pinned by
//! `tests/elastic_equivalence.rs`), reconfiguration barriers for joins and
//! leaves, and the full-precision bootstrap handshake a joiner needs
//! before the θ proximity bound lets it decode modulo-quantized traffic.

// Style lints the codebase deliberately trades for explicit indexed hot
// loops (the §Perf kernels are written against godbolt output, not clippy
// idiom); CI runs `cargo clippy -- -D warnings` with these exceptions.
#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_div_ceil)]
#![allow(clippy::too_many_arguments)]

pub mod adversary;
pub mod algorithms;
pub mod bench_support;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod elastic;
pub mod linalg;
pub mod mem;
pub mod network;
pub mod objectives;
pub mod quant;
pub mod rng;
pub mod runtime;
pub mod telemetry;
pub mod testing;
pub mod topology;
pub mod transport;

/// Convenience re-exports covering the common public API surface.
pub mod prelude {
    pub use crate::adversary::{ByzMode, ByzantineConfig};
    pub use crate::algorithms::{Algorithm, RoundPool, ThetaPolicy};
    pub use crate::coordinator::{
        AsyncTrainer, ClusterConfig, ClusterTrainer, DesAsyncTrainer, DesConfig,
        DesTrainer, FaultConfig, Report, TraceRow, TrainConfig, Trainer, TransportKind,
    };
    pub use crate::elastic::{ElasticConfig, MembershipPlan, Snapshot};
    pub use crate::transport::{Frame, FrameKind, MemTransport, TcpTransport, Transport};
    pub use crate::data::{partition::Partition, SynthClassification};
    pub use crate::network::{LinkMatrix, NetworkConfig, NetworkModel};
    pub use crate::objectives::{Objective, ObjectiveKind};
    pub use crate::quant::{QuantConfig, Rounding};
    pub use crate::rng::Pcg64;
    pub use crate::telemetry::{Clock, MetricsMode, Registry, Snapshot, Telemetry, VirtualTime};
    pub use crate::topology::{Topology, TopologySchedule};
}
