//! Real message-passing transport: the layer that turns the simulator's
//! shared-memory "communication" into bytes crossing an actual boundary.
//!
//! * [`frame`] — the versioned wire format ([`Frame`]): a 38-byte header
//!   (magic, version, algo id, round, sender, bit budget, frame kind, θ,
//!   payload length, FNV-1a checksum) followed by the packed-quantized
//!   payload the fused codec paths produce. Decoding returns typed
//!   [`FrameError`]s, never panics. [`FrameKind::Bootstrap`] frames carry
//!   the full-precision model a (re)joining elastic node adopts before it
//!   may decode modulo-quantized traffic ([`crate::elastic`]).
//! * [`Transport`] — the pluggable endpoint trait: `send(peer, &Frame)` +
//!   `recv(timeout)`. One endpoint per worker; endpoints are `Send` so a
//!   worker thread can own one.
//! * [`mem`] — [`MemTransport`]: process-local shared queues drawing wire
//!   buffers from a cluster-shared [`FramePool`](crate::mem::FramePool)
//!   (§Perf: zero allocations per steady-state round). Frames are
//!   serialized/deserialized through the real codec (so the mem transport
//!   exercises the same bytes TCP ships) and delivered in deterministic
//!   `(round, sender)` order from the receive buffer.
//! * [`tcp`] — [`TcpTransport`]: length-prefixed frames over
//!   `std::net::TcpStream` on localhost, one listener per worker,
//!   lazily-dialed outbound connections (each behind a `BufWriter`, so a
//!   frame is one syscall), reader threads draining inbound sockets into
//!   pooled buffers. Binding port 0 + discovered addresses makes clusters
//!   port-collision-safe under parallel test runs.
//!
//! Both implementations satisfy one conformance contract
//! (`tests/transport_conformance.rs`): per-sender FIFO, `(round, sender)`
//! ordering of buffered frames, concurrent senders, >64 KiB frames, and
//! timeout on an idle endpoint.
//!
//! The consumer above this layer is
//! [`coordinator::cluster::ClusterTrainer`](crate::coordinator::cluster):
//! one OS thread per worker, each owning only its own model, every model
//! byte it learns about a neighbor arriving through `recv`.

// Decode/recv paths return typed errors, never panic — enforced twice:
// `moniqua-lint`'s `panic_surface` rule and clippy's unwrap/expect lints,
// scoped to the transport modules (tests keep their unwraps).
#[cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]
pub mod frame;
#[cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]
pub mod mem;
#[cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]
pub mod nb_tcp;
#[cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]
pub mod tcp;
#[cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]
pub mod wake;

pub use frame::{
    algo_wire_id, Frame, FrameError, FrameKind, HEADER_LEN, MAGIC, MAX_PAYLOAD, VERSION,
};
pub use mem::MemTransport;
pub use nb_tcp::NbTcpTransport;
pub use tcp::TcpTransport;
pub use wake::WakeHandle;

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::telemetry::{Counter, Telemetry};

/// Record one shipped frame (by kind) and its wire bytes. Shared by every
/// transport so the per-kind taxonomy cannot drift between them.
// lint: hot-path
pub(crate) fn note_sent(t: &Telemetry, kind: FrameKind, wire_len: usize) {
    match kind {
        FrameKind::Data => {
            t.record(Counter::FramesSentData, 1);
            t.record(Counter::BytesSentData, wire_len as u64);
        }
        FrameKind::Bootstrap => {
            t.record(Counter::FramesSentBootstrap, 1);
            t.record(Counter::BytesSentBootstrap, wire_len as u64);
        }
    }
}

/// Record one successfully decoded inbound frame (by kind) and its wire
/// bytes.
// lint: hot-path
pub(crate) fn note_received(t: &Telemetry, kind: FrameKind, wire_len: usize) {
    match kind {
        FrameKind::Data => {
            t.record(Counter::FramesRecvData, 1);
            t.record(Counter::BytesRecvData, wire_len as u64);
        }
        FrameKind::Bootstrap => {
            t.record(Counter::FramesRecvBootstrap, 1);
            t.record(Counter::BytesRecvBootstrap, wire_len as u64);
        }
    }
}

/// Deadline arithmetic that cannot overflow: `Instant::now() + timeout`
/// panics when `timeout` is enormous (`Duration::MAX`, or a config file's
/// `recv_timeout_ms` set to "never"), because `Instant` saturates nowhere.
/// This helper clamps to a far-future instant (~100 years) instead — far
/// enough to mean "wait forever" for any real run, near enough to stay
/// representable on every platform's monotonic clock.
// lint: allow(wall_clock) — deadline arithmetic helper; gates *when* a
// recv gives up waiting, never the bytes of any frame.
pub fn saturating_deadline(now: Instant, timeout: Duration) -> Instant {
    const FAR_FUTURE: Duration = Duration::from_secs(100 * 365 * 24 * 60 * 60);
    now.checked_add(timeout)
        .or_else(|| now.checked_add(FAR_FUTURE))
        .unwrap_or(now)
}

/// Transport-level failures.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TransportError {
    /// No frame arrived within the `recv` timeout.
    Timeout,
    /// The peer endpoint (or the whole cluster) is gone.
    Closed,
    /// Socket-level failure (TCP only), stringified for portability.
    Io(String),
    /// The peer shipped bytes that do not decode as a frame.
    Frame(FrameError),
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Timeout => write!(f, "recv timed out"),
            TransportError::Closed => write!(f, "transport closed"),
            TransportError::Io(e) => write!(f, "transport io error: {e}"),
            TransportError::Frame(e) => write!(f, "frame decode error: {e}"),
        }
    }
}

impl std::error::Error for TransportError {}

impl From<FrameError> for TransportError {
    fn from(e: FrameError) -> Self {
        TransportError::Frame(e)
    }
}

/// One worker's endpoint of a cluster transport.
///
/// `send` is non-blocking from the caller's perspective (buffered channels
/// / OS socket buffers drained by reader threads), so the lockstep
/// send-all-then-receive-all round pattern cannot deadlock. `recv` returns
/// the buffered frame with the smallest `(round, sender)` key — ties (same
/// sender re-sending within a round) break by arrival order, preserving
/// per-sender FIFO.
pub trait Transport: Send {
    /// This endpoint's worker id.
    fn local_id(&self) -> usize;

    /// Number of endpoints in the cluster (peer ids are `0..cluster_size`).
    fn cluster_size(&self) -> usize;

    /// Ship one frame to `peer`.
    fn send(&mut self, peer: usize, frame: &Frame) -> Result<(), TransportError>;

    /// Ship one frame to every peer in `peers` — the cluster's hot send
    /// path. Both implementations override the default to serialize (and
    /// checksum) the frame **once** and reuse the wire bytes per peer;
    /// the default exists so the two stay behaviorally interchangeable.
    fn broadcast(&mut self, peers: &[usize], frame: &Frame) -> Result<(), TransportError> {
        for &p in peers {
            self.send(p, frame)?;
        }
        Ok(())
    }

    /// Receive the next frame in `(round, sender)` order, waiting up to
    /// `timeout` for one to arrive.
    fn recv(&mut self, timeout: Duration) -> Result<Frame, TransportError>;

    /// Return a consumed frame's payload buffer to the transport's wire
    /// pool (§Perf). Both implementations feed it back into the
    /// [`FramePool`](crate::mem::FramePool) their senders draw from, which
    /// is what makes steady-state rounds allocation-free
    /// (`tests/alloc_discipline.rs`); the default drops the buffer, so
    /// recycling is always a pure optimization — never a correctness
    /// requirement.
    fn recycle(&mut self, payload: Vec<u8>) {
        drop(payload);
    }

    /// Register a wake token the transport fires whenever a new frame
    /// becomes receivable, so a reactor driver parked between poll
    /// iterations wakes immediately instead of sleeping out its poll tick.
    /// The default ignores the token: the blocking transports wake their
    /// own `recv` through internal condvars/channels, and polling them a
    /// tick late is merely latency, never lost data.
    fn set_waker(&mut self, _waker: &Arc<WakeHandle>) {}

    /// Attach a telemetry recording handle (registry + this worker's
    /// shard). Mirrors [`Self::set_waker`]: the default ignores it, so
    /// telemetry — like recycling — is a pure observation layer, never a
    /// correctness requirement. All three real transports override it to
    /// count frames/bytes by kind, decode rejects, and pool hit/miss.
    fn set_metrics(&mut self, _t: Telemetry) {}
}

/// Receive-side reorder buffer shared by both transports: frames are pushed
/// in arrival order and popped in `(round, sender, arrival)` order, which
/// is what makes delivery deterministic regardless of thread interleaving
/// among frames that have already arrived.
#[derive(Default)]
pub(crate) struct ReorderBuffer {
    heap: BinaryHeap<Reverse<Keyed>>,
    arrivals: u64,
}

struct Keyed {
    round: u64,
    sender: u16,
    arrival: u64,
    frame: Frame,
}

impl PartialEq for Keyed {
    fn eq(&self, other: &Self) -> bool {
        (self.round, self.sender, self.arrival) == (other.round, other.sender, other.arrival)
    }
}
impl Eq for Keyed {}
impl PartialOrd for Keyed {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Keyed {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.round, self.sender, self.arrival).cmp(&(
            other.round,
            other.sender,
            other.arrival,
        ))
    }
}

impl ReorderBuffer {
    pub fn push(&mut self, frame: Frame) {
        let key = Keyed {
            round: frame.round,
            sender: frame.sender,
            arrival: self.arrivals,
            frame,
        };
        self.arrivals += 1;
        self.heap.push(Reverse(key));
    }

    pub fn pop(&mut self) -> Option<Frame> {
        self.heap.pop().map(|Reverse(k)| k.frame)
    }

    #[cfg(test)]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(round: u64, sender: u16) -> Frame {
        Frame {
            round,
            sender,
            algo: 2,
            bits: 32,
            kind: FrameKind::Data,
            theta: 0.0,
            payload: vec![sender as u8],
        }
    }

    #[test]
    fn reorder_pops_round_then_sender() {
        let mut rb = ReorderBuffer::default();
        rb.push(frame(1, 0));
        rb.push(frame(0, 2));
        rb.push(frame(0, 1));
        let order: Vec<(u64, u16)> = std::iter::from_fn(|| rb.pop())
            .map(|f| (f.round, f.sender))
            .collect();
        assert_eq!(order, vec![(0, 1), (0, 2), (1, 0)]);
        assert!(rb.is_empty());
    }

    #[test]
    fn saturating_deadline_survives_duration_max() {
        // Regression: `Instant::now() + Duration::MAX` panics; the helper
        // must clamp instead and still land in the future.
        let now = Instant::now();
        let d = saturating_deadline(now, Duration::MAX);
        assert!(d > now);
        // Ordinary timeouts are exact.
        let t = Duration::from_millis(250);
        assert_eq!(saturating_deadline(now, t), now + t);
    }

    #[test]
    fn reorder_ties_break_by_arrival() {
        let mut rb = ReorderBuffer::default();
        let mut a = frame(0, 1);
        a.payload = vec![10];
        let mut b = frame(0, 1);
        b.payload = vec![20];
        rb.push(a);
        rb.push(b);
        assert_eq!(rb.pop().unwrap().payload, vec![10]);
        assert_eq!(rb.pop().unwrap().payload, vec![20]);
    }
}
