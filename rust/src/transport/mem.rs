//! In-process transport over shared pooled queues.
//!
//! Every endpoint owns a receive queue (`Mutex<VecDeque> + Condvar`);
//! senders hold `Arc`s of each peer's queue. Frames are serialized to wire
//! bytes on `send` and decoded on `recv` — the mem transport ships the
//! *same bytes* TCP ships, so a codec bug cannot hide behind shared
//! memory. Buffered frames are delivered in `(round, sender)` order (see
//! [`ReorderBuffer`](super::ReorderBuffer)).
//!
//! §Perf: wire buffers come from one cluster-shared
//! [`FramePool`](crate::mem::FramePool) and are returned by the consumer
//! through [`Transport::recycle`], so a steady-state round moves bytes
//! through recycled capacity only — zero heap allocations (the previous
//! `mpsc` channel allocated a node per send). `tests/alloc_discipline.rs`
//! pins this.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::{
    note_received, note_sent, saturating_deadline, Frame, ReorderBuffer, Transport,
    TransportError, WakeHandle,
};
use crate::mem::FramePool;
use crate::telemetry::{Counter, Telemetry};

/// One endpoint's inbound queue: preallocated ring of wire-byte buffers
/// plus a condvar for blocking receives. `closed` flips when the owning
/// endpoint drops, so senders fail fast with
/// [`TransportError::Closed`] instead of silently queueing into the void
/// (the mpsc-backed transport errored the same way when the receiver was
/// gone).
struct ByteQueue {
    q: Mutex<VecDeque<Vec<u8>>>,
    cv: Condvar,
    closed: AtomicBool,
    /// Reactor wake token: when the owning endpoint is driven by a parked
    /// readiness loop instead of a blocking `recv`, every push fires this
    /// so the driver re-polls immediately (see
    /// [`Transport::set_waker`]).
    watcher: Mutex<Option<Arc<WakeHandle>>>,
}

impl ByteQueue {
    fn with_capacity(cap: usize) -> Self {
        ByteQueue {
            q: Mutex::new(VecDeque::with_capacity(cap)),
            cv: Condvar::new(),
            closed: AtomicBool::new(false),
            watcher: Mutex::new(None),
        }
    }

    fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }

    /// Lock the queue, recovering from poisoning: the deque holds plain
    /// byte buffers with no invariant a panicking holder could have half
    /// applied, so the poison flag carries no information worth dying for
    /// (and the transport hot path must stay panic-free).
    fn locked(&self) -> std::sync::MutexGuard<'_, VecDeque<Vec<u8>>> {
        match self.q.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    fn push(&self, bytes: Vec<u8>) {
        self.locked().push_back(bytes);
        self.cv.notify_one();
        self.wake_watcher();
    }

    /// Fire the registered reactor wake token, if any.
    fn wake_watcher(&self) {
        let g = match self.watcher.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        if let Some(w) = g.as_ref() {
            w.wake();
        }
    }

    fn try_pop(&self) -> Option<Vec<u8>> {
        self.locked().pop_front()
    }

    /// Block up to `timeout` for one buffer.
    fn pop_timeout(&self, timeout: Duration) -> Option<Vec<u8>> {
        // lint: allow(wall_clock) — condvar deadline arithmetic; purely
        // about *when* to give up waiting, never about frame contents.
        let deadline = saturating_deadline(Instant::now(), timeout);
        let mut g = self.locked();
        loop {
            if let Some(b) = g.pop_front() {
                return Some(b);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            g = match self.cv.wait_timeout(g, deadline - now) {
                Ok((g, _)) => g,
                Err(poisoned) => poisoned.into_inner().0,
            };
        }
    }
}

/// One worker's endpoint of an in-process cluster.
pub struct MemTransport {
    id: usize,
    queues: Vec<Arc<ByteQueue>>,
    buf: ReorderBuffer,
    pool: FramePool,
    telemetry: Telemetry,
}

impl MemTransport {
    /// Build a fully-connected cluster of `n` endpoints sharing one wire
    /// buffer pool.
    pub fn cluster(n: usize) -> Vec<MemTransport> {
        assert!(n > 0);
        let pool = FramePool::new();
        let queues: Vec<Arc<ByteQueue>> = (0..n)
            // Depth 64 covers a full round of frames per peer with slack;
            // beyond it the deque grows (an allocation, not a loss).
            .map(|_| Arc::new(ByteQueue::with_capacity(64)))
            .collect();
        (0..n)
            .map(|id| MemTransport {
                id,
                queues: queues.clone(),
                buf: ReorderBuffer::default(),
                pool: pool.clone(),
                telemetry: Telemetry::disabled(),
            })
            .collect()
    }

    /// As [`Self::cluster`], with the shared pool prewarmed with `buffers`
    /// wire buffers of `frame_capacity` bytes each. The caller declares
    /// its own working set — the coordinator sizes it topology-aware (two
    /// rounds of frames in flight per directed *edge* of the densest
    /// epoch, the pipelined scheduler's bound: a peer runs at most one
    /// round ahead; see `mem` module docs) — so even the warm-up rounds
    /// allocate nothing, and a prewarm past the pool's default backstop
    /// raises its retention bound to match.
    pub fn cluster_prewarmed(
        n: usize,
        buffers: usize,
        frame_capacity: usize,
    ) -> Vec<MemTransport> {
        let eps = Self::cluster(n);
        eps[0].pool.prewarm(buffers, frame_capacity);
        eps
    }

    /// The cluster-shared wire buffer pool (tests assert recycling works).
    pub fn pool(&self) -> &FramePool {
        &self.pool
    }

    /// Move everything already sitting in the queue into the reorder
    /// buffer (non-blocking).
    fn drain(&mut self) -> Result<(), TransportError> {
        while let Some(bytes) = self.queues[self.id].try_pop() {
            self.push_decoded(bytes)?;
        }
        Ok(())
    }

    /// Decode one wire buffer into the reorder buffer; on decode failure
    /// the buffer is returned to the pool *before* the error propagates,
    /// so corrupt traffic cannot shrink the pool (satellite bugfix —
    /// `decode_owned(bytes)?` dropped the checked-out buffer).
    fn push_decoded(&mut self, bytes: Vec<u8>) -> Result<(), TransportError> {
        let wire_len = bytes.len();
        match Frame::decode_reclaim(bytes) {
            Ok(f) => {
                note_received(&self.telemetry, f.kind, wire_len);
                self.buf.push(f);
                Ok(())
            }
            Err((e, junk)) => {
                self.telemetry.record(Counter::FramesRejected, 1);
                self.pool.give(junk);
                Err(e.into())
            }
        }
    }

    /// Push raw wire bytes straight into `peer`'s inbound queue, bypassing
    /// the frame encoder — the fault-injection hook the corrupt-frame
    /// regression tests use (`tests/alloc_discipline.rs` and the unit
    /// tests below).
    pub fn inject_raw(&mut self, peer: usize, bytes: Vec<u8>) {
        assert!(peer < self.queues.len(), "peer {peer} out of range");
        self.queues[peer].push(bytes);
    }
}

impl Transport for MemTransport {
    fn local_id(&self) -> usize {
        self.id
    }

    fn cluster_size(&self) -> usize {
        self.queues.len()
    }

    // lint: hot-path
    fn send(&mut self, peer: usize, frame: &Frame) -> Result<(), TransportError> {
        assert!(peer < self.queues.len(), "peer {peer} out of range");
        if self.queues[peer].is_closed() {
            return Err(TransportError::Closed);
        }
        let mut bytes = self.pool.take();
        frame.encode_into(&mut bytes);
        note_sent(&self.telemetry, frame.kind, bytes.len());
        self.queues[peer].push(bytes);
        Ok(())
    }

    // lint: hot-path
    fn broadcast(&mut self, peers: &[usize], frame: &Frame) -> Result<(), TransportError> {
        // Encode (and checksum) once into a pooled scratch; intermediate
        // peers get a copy into a recycled buffer, the last peer takes the
        // scratch itself — k peers cost k−1 memcpys, not k.
        let Some((&last, rest)) = peers.split_last() else {
            return Ok(());
        };
        let mut wire = self.pool.take();
        frame.encode_into(&mut wire);
        for &p in rest {
            assert!(p < self.queues.len(), "peer {p} out of range");
            if self.queues[p].is_closed() {
                self.pool.give(wire);
                return Err(TransportError::Closed);
            }
            let mut bytes = self.pool.take();
            bytes.extend_from_slice(&wire);
            note_sent(&self.telemetry, frame.kind, bytes.len());
            self.queues[p].push(bytes);
        }
        assert!(last < self.queues.len(), "peer {last} out of range");
        if self.queues[last].is_closed() {
            self.pool.give(wire);
            return Err(TransportError::Closed);
        }
        note_sent(&self.telemetry, frame.kind, wire.len());
        self.queues[last].push(wire);
        Ok(())
    }

    // lint: hot-path
    fn recv(&mut self, timeout: Duration) -> Result<Frame, TransportError> {
        // lint: allow(wall_clock) — the recv deadline is transport-local
        // timing; it gates *when* a frame is returned, never its bytes.
        let deadline = saturating_deadline(Instant::now(), timeout);
        loop {
            self.drain()?;
            if let Some(f) = self.buf.pop() {
                return Ok(f);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(TransportError::Timeout);
            }
            match self.queues[self.id].pop_timeout(deadline - now) {
                Some(bytes) => self.push_decoded(bytes)?,
                None => return Err(TransportError::Timeout),
            }
        }
    }

    // lint: hot-path
    fn recycle(&mut self, payload: Vec<u8>) {
        self.pool.give(payload);
    }

    fn set_waker(&mut self, waker: &Arc<WakeHandle>) {
        let mut g = match self.queues[self.id].watcher.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        *g = Some(Arc::clone(waker));
    }

    fn set_metrics(&mut self, t: Telemetry) {
        // This endpoint's *clone* of the shared pool gets the handle too,
        // so checkouts are attributed to this worker's shard.
        self.pool.set_metrics(t.clone());
        self.telemetry = t;
    }
}

impl Drop for MemTransport {
    fn drop(&mut self) {
        // Senders to this endpoint fail fast from now on; anyone blocked
        // in a wait sees the flag after the notify, and a parked reactor
        // driver re-polls and observes the closure.
        self.queues[self.id].closed.store(true, Ordering::Release);
        self.queues[self.id].cv.notify_all();
        self.queues[self.id].wake_watcher();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(round: u64, sender: u16, payload: Vec<u8>) -> Frame {
        Frame {
            round,
            sender,
            algo: 4,
            bits: 8,
            kind: crate::transport::FrameKind::Data,
            theta: 2.0,
            payload,
        }
    }

    #[test]
    fn delivers_across_endpoints() {
        let mut eps = MemTransport::cluster(2);
        let (mut a, mut b) = {
            let b = eps.pop().unwrap();
            (eps.pop().unwrap(), b)
        };
        assert_eq!(a.local_id(), 0);
        assert_eq!(a.cluster_size(), 2);
        a.send(1, &frame(0, 0, vec![1, 2, 3])).unwrap();
        let got = b.recv(Duration::from_secs(1)).unwrap();
        assert_eq!(got.payload, vec![1, 2, 3]);
        assert_eq!(got.sender, 0);
    }

    #[test]
    fn buffered_frames_pop_in_round_sender_order() {
        let mut eps = MemTransport::cluster(3);
        let mut rx = eps.remove(0);
        eps[1].send(0, &frame(1, 2, vec![])).unwrap();
        eps[0].send(0, &frame(0, 1, vec![])).unwrap();
        eps[1].send(0, &frame(0, 2, vec![])).unwrap();
        let order: Vec<(u64, u16)> = (0..3)
            .map(|_| {
                let f = rx.recv(Duration::from_secs(1)).unwrap();
                (f.round, f.sender)
            })
            .collect();
        assert_eq!(order, vec![(0, 1), (0, 2), (1, 2)]);
    }

    #[test]
    fn timeout_on_idle_endpoint() {
        let mut eps = MemTransport::cluster(2);
        let mut a = eps.remove(0);
        let err = a.recv(Duration::from_millis(20)).unwrap_err();
        assert_eq!(err, TransportError::Timeout);
    }

    #[test]
    fn recycled_buffers_circulate_through_the_pool() {
        let mut eps = MemTransport::cluster(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        // Warm-up round: allocates the first buffers.
        a.send(1, &frame(0, 0, vec![9; 256])).unwrap();
        let f = b.recv(Duration::from_secs(1)).unwrap();
        b.recycle(f.payload);
        assert!(b.pool().pooled() >= 1, "consumer must return capacity");
        // Steady state: the sender's take() reuses the recycled buffer.
        a.send(1, &frame(1, 0, vec![7; 256])).unwrap();
        let f = b.recv(Duration::from_secs(1)).unwrap();
        assert_eq!(f.payload, vec![7; 256]);
        b.recycle(f.payload);
    }

    #[test]
    fn send_to_dropped_endpoint_is_closed() {
        let mut eps = MemTransport::cluster(3);
        let gone = eps.remove(2);
        drop(gone);
        let err = eps[0].send(2, &frame(0, 0, vec![1])).unwrap_err();
        assert_eq!(err, TransportError::Closed);
        // Broadcast fails fast too (peer 2 is the copy target here)…
        let err = eps[0].broadcast(&[2, 1], &frame(0, 0, vec![1])).unwrap_err();
        assert_eq!(err, TransportError::Closed);
        // …and as the final (buffer-handoff) target.
        let err = eps[0].broadcast(&[1, 2], &frame(0, 0, vec![1])).unwrap_err();
        assert_eq!(err, TransportError::Closed);
        // The surviving pair still works.
        eps[0].send(1, &frame(1, 0, vec![9])).unwrap();
        assert_eq!(eps[1].recv(Duration::from_secs(1)).unwrap().payload, vec![9]);
    }

    #[test]
    fn recv_with_duration_max_does_not_overflow() {
        // Regression: `Instant::now() + Duration::MAX` panicked, so any
        // config with a huge recv_timeout_ms crashed the first barrier.
        let mut eps = MemTransport::cluster(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, &frame(0, 0, vec![5])).unwrap();
        let got = b.recv(Duration::MAX).unwrap();
        assert_eq!(got.payload, vec![5]);
    }

    #[test]
    fn corrupt_frame_recycles_the_wire_buffer() {
        // Regression: a decode failure dropped the checked-out pool
        // buffer; the pool must grow by exactly the reclaimed buffer.
        let mut eps = MemTransport::cluster(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let before = b.pool().pooled();
        let mut junk = b.pool().take();
        junk.extend_from_slice(&[0xAB; 16]);
        a.inject_raw(1, junk);
        let err = b.recv(Duration::from_millis(50)).unwrap_err();
        assert!(matches!(err, TransportError::Frame(_)), "got {err:?}");
        assert_eq!(
            b.pool().pooled(),
            before + 1,
            "corrupt wire buffer must return to the pool, not leak"
        );
        // The endpoint survives the poison frame: good traffic still flows.
        a.send(1, &frame(1, 0, vec![7])).unwrap();
        assert_eq!(b.recv(Duration::from_secs(1)).unwrap().payload, vec![7]);
    }

    #[test]
    fn telemetry_counts_frames_bytes_and_rejects() {
        use crate::telemetry::Registry;
        let reg = Registry::new();
        let mut eps = MemTransport::cluster(3);
        for (i, ep) in eps.iter_mut().enumerate() {
            ep.set_metrics(Telemetry::new(&reg, i));
        }
        let mut c = eps.pop().unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        // One unicast + one 2-peer broadcast, then a corrupt frame.
        a.send(1, &frame(0, 0, vec![1; 32])).unwrap();
        a.broadcast(&[1, 2], &frame(0, 0, vec![2; 32])).unwrap();
        let f = b.recv(Duration::from_secs(1)).unwrap();
        let wire_len = f.encoded_len() as u64;
        let _ = b.recv(Duration::from_secs(1)).unwrap();
        let _ = c.recv(Duration::from_secs(1)).unwrap();
        let mut junk = a.pool().take();
        junk.extend_from_slice(&[0xCD; 8]);
        a.inject_raw(1, junk);
        let _ = b.recv(Duration::from_millis(20)).unwrap_err();

        let snap = reg.snapshot();
        assert_eq!(snap.counter(Counter::FramesSentData), 3);
        assert_eq!(snap.counter(Counter::FramesRecvData), 3);
        assert_eq!(snap.counter(Counter::FramesRejected), 1);
        assert_eq!(snap.counter(Counter::FramesSentBootstrap), 0);
        assert!(snap.counter(Counter::BytesSentData) >= 3 * wire_len - 8);
        assert_eq!(
            snap.counter(Counter::BytesSentData),
            snap.counter(Counter::BytesRecvData)
        );
        assert_eq!(snap.frames_sent(), snap.frames_received() + 1);
    }

    #[test]
    fn waker_fires_on_push() {
        let mut eps = MemTransport::cluster(2);
        let mut rx = eps.remove(0);
        let mut tx = eps.remove(0);
        let w = crate::transport::WakeHandle::new();
        rx.set_waker(&w);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            tx.send(0, &frame(0, 1, vec![1])).unwrap();
        });
        let t0 = std::time::Instant::now();
        w.park_timeout(Duration::from_secs(10));
        assert!(t0.elapsed() < Duration::from_secs(5), "push did not wake the parked driver");
        let f = rx.recv(Duration::from_secs(1)).unwrap();
        assert_eq!(f.sender, 1);
        h.join().unwrap();
    }

    #[test]
    fn blocking_recv_wakes_on_push() {
        let mut eps = MemTransport::cluster(2);
        let mut rx = eps.remove(0);
        let mut tx = eps.remove(0);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            tx.send(0, &frame(5, 1, vec![1])).unwrap();
        });
        let f = rx.recv(Duration::from_secs(5)).unwrap();
        assert_eq!(f.round, 5);
        h.join().unwrap();
    }
}
