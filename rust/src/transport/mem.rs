//! In-process transport over `std::sync::mpsc` channels.
//!
//! Every endpoint owns a receiver; senders hold clones of each peer's
//! `Sender`. Frames are serialized to wire bytes on `send` and decoded on
//! `recv` — the mem transport ships the *same bytes* TCP would, so a codec
//! bug cannot hide behind shared memory. Buffered frames are delivered in
//! `(round, sender)` order (see [`ReorderBuffer`](super::ReorderBuffer)).

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::time::{Duration, Instant};

use super::{Frame, ReorderBuffer, Transport, TransportError};

/// One worker's endpoint of an in-process cluster.
pub struct MemTransport {
    id: usize,
    txs: Vec<Sender<Vec<u8>>>,
    rx: Receiver<Vec<u8>>,
    buf: ReorderBuffer,
}

impl MemTransport {
    /// Build a fully-connected cluster of `n` endpoints.
    pub fn cluster(n: usize) -> Vec<MemTransport> {
        assert!(n > 0);
        let mut txs = Vec::with_capacity(n);
        let mut rxs = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(Some(rx));
        }
        rxs.iter_mut()
            .enumerate()
            .map(|(id, rx)| MemTransport {
                id,
                txs: txs.clone(),
                rx: rx.take().expect("receiver taken once"),
                buf: ReorderBuffer::default(),
            })
            .collect()
    }

    /// Move everything already sitting in the channel into the reorder
    /// buffer (non-blocking).
    fn drain(&mut self) -> Result<(), TransportError> {
        loop {
            match self.rx.try_recv() {
                Ok(bytes) => self.buf.push(Frame::decode_owned(bytes)?),
                Err(TryRecvError::Empty) => return Ok(()),
                // All peer senders dropped; buffered frames stay poppable.
                Err(TryRecvError::Disconnected) => return Ok(()),
            }
        }
    }
}

impl Transport for MemTransport {
    fn local_id(&self) -> usize {
        self.id
    }

    fn cluster_size(&self) -> usize {
        self.txs.len()
    }

    fn send(&mut self, peer: usize, frame: &Frame) -> Result<(), TransportError> {
        assert!(peer < self.txs.len(), "peer {peer} out of range");
        self.txs[peer]
            .send(frame.encode())
            .map_err(|_| TransportError::Closed)
    }

    fn broadcast(&mut self, peers: &[usize], frame: &Frame) -> Result<(), TransportError> {
        // Encode (and checksum) once; each channel send needs its own
        // owned buffer, which is the unavoidable per-peer copy.
        let bytes = frame.encode();
        for &p in peers {
            assert!(p < self.txs.len(), "peer {p} out of range");
            self.txs[p]
                .send(bytes.clone())
                .map_err(|_| TransportError::Closed)?;
        }
        Ok(())
    }

    fn recv(&mut self, timeout: Duration) -> Result<Frame, TransportError> {
        let deadline = Instant::now() + timeout;
        loop {
            self.drain()?;
            if let Some(f) = self.buf.pop() {
                return Ok(f);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(TransportError::Timeout);
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(bytes) => self.buf.push(Frame::decode_owned(bytes)?),
                Err(RecvTimeoutError::Timeout) => return Err(TransportError::Timeout),
                Err(RecvTimeoutError::Disconnected) => return Err(TransportError::Closed),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(round: u64, sender: u16, payload: Vec<u8>) -> Frame {
        Frame {
            round,
            sender,
            algo: 4,
            bits: 8,
            kind: crate::transport::FrameKind::Data,
            theta: 2.0,
            payload,
        }
    }

    #[test]
    fn delivers_across_endpoints() {
        let mut eps = MemTransport::cluster(2);
        let (mut a, mut b) = {
            let b = eps.pop().unwrap();
            (eps.pop().unwrap(), b)
        };
        assert_eq!(a.local_id(), 0);
        assert_eq!(a.cluster_size(), 2);
        a.send(1, &frame(0, 0, vec![1, 2, 3])).unwrap();
        let got = b.recv(Duration::from_secs(1)).unwrap();
        assert_eq!(got.payload, vec![1, 2, 3]);
        assert_eq!(got.sender, 0);
    }

    #[test]
    fn buffered_frames_pop_in_round_sender_order() {
        let mut eps = MemTransport::cluster(3);
        let mut rx = eps.remove(0);
        eps[1].send(0, &frame(1, 2, vec![])).unwrap();
        eps[0].send(0, &frame(0, 1, vec![])).unwrap();
        eps[1].send(0, &frame(0, 2, vec![])).unwrap();
        let order: Vec<(u64, u16)> = (0..3)
            .map(|_| {
                let f = rx.recv(Duration::from_secs(1)).unwrap();
                (f.round, f.sender)
            })
            .collect();
        assert_eq!(order, vec![(0, 1), (0, 2), (1, 2)]);
    }

    #[test]
    fn timeout_on_idle_endpoint() {
        let mut eps = MemTransport::cluster(2);
        let mut a = eps.remove(0);
        let err = a.recv(Duration::from_millis(20)).unwrap_err();
        assert_eq!(err, TransportError::Timeout);
    }
}
