//! The versioned wire frame — the unit every byte of inter-worker traffic
//! travels in.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//!  offset  size  field
//!  ------  ----  -----------------------------------------------------
//!       0     4  magic        b"MQWF"
//!       4     2  version      wire-format version (currently 2)
//!       6     2  algo         algorithm id (see [`algo_wire_id`])
//!       8     8  round        synchronous round index
//!      16     2  sender       worker id of the sender
//!      18     2  bits         quantizer bit budget (32 = raw f32 payload)
//!      20     2  kind         frame kind (see [`FrameKind`])
//!      22     4  theta        sender's θ this round (f32 bits; diagnostics)
//!      26     4  payload_len  payload bytes following the header
//!      30     8  checksum     FNV-1a over bytes 0..30 ++ payload
//!      38     …  payload      packed-quantized codes / raw f32 vector
//! ```
//!
//! Version 2 added the `kind` field for the elastic runtime
//! ([`crate::elastic`]): a [`FrameKind::Bootstrap`] frame carries a raw
//! full-precision model a (re)joining node must adopt before it may decode
//! modulo-quantized traffic (the θ proximity bound of Lemma 1 does not hold
//! for a node arbitrarily far from the cohort).
//!
//! The payload is exactly what the fused codec paths produce
//! ([`MoniquaCodec::encode_packed_into`](crate::quant::MoniquaCodec::encode_packed_into)
//! for the Moniqua family, [`packing::pack`](crate::quant::packing) for the
//! code-based baselines, raw f32 little-endian words for the
//! full-precision ones) — the frame layer never re-encodes it.
//!
//! Decoding is total: every malformed input maps to a typed [`FrameError`]
//! (no panics, no truncation reads), which the property suite
//! (`tests/frame_codec.rs`) fuzzes with the repo's deterministic RNG.

use crate::quant::hash::fnv1a_bytes;

/// Leading magic of every frame.
pub const MAGIC: [u8; 4] = *b"MQWF";
/// Current wire-format version.
pub const VERSION: u16 = 2;
/// Header bytes before the payload.
pub const HEADER_LEN: usize = 38;
/// Upper bound on a frame payload (1 GiB) — rejects absurd length prefixes
/// before any allocation happens on the receive path.
pub const MAX_PAYLOAD: usize = 1 << 30;

/// Named header field offsets. These are the single source of truth for
/// the byte layout: `encode_into`/`validate` address fields through them,
/// [`FIELD_LAYOUT`] proves they tile the header, and `moniqua-lint`'s
/// `wire_format` rule re-checks the tiling on every run (as does the
/// `field_layout_tiles_header` unit test).
pub const OFF_MAGIC: usize = 0;
pub const OFF_VERSION: usize = 4;
pub const OFF_ALGO: usize = 6;
pub const OFF_ROUND: usize = 8;
pub const OFF_SENDER: usize = 16;
pub const OFF_BITS: usize = 18;
pub const OFF_KIND: usize = 20;
pub const OFF_THETA: usize = 22;
pub const OFF_PAYLOAD_LEN: usize = 26;
pub const OFF_CHECKSUM: usize = 30;

/// `(offset, width)` of every header field, in wire order. Must start at
/// 0, be gap-free, and sum to [`HEADER_LEN`] — checked statically by
/// `moniqua-lint` and dynamically by the unit test below.
pub const FIELD_LAYOUT: [(usize, usize); 10] = [
    (OFF_MAGIC, 4),
    (OFF_VERSION, 2),
    (OFF_ALGO, 2),
    (OFF_ROUND, 8),
    (OFF_SENDER, 2),
    (OFF_BITS, 2),
    (OFF_KIND, 2),
    (OFF_THETA, 4),
    (OFF_PAYLOAD_LEN, 4),
    (OFF_CHECKSUM, 8),
];

/// Typed decode failures. Every variant carries enough context to debug a
/// corrupt capture without a hex dump.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// Fewer bytes than the fixed header, or fewer than the header's
    /// declared payload length.
    Truncated { expected: usize, got: usize },
    /// More bytes than header + declared payload — the framing layer
    /// (length prefix) and the header disagree.
    TrailingBytes { expected: usize, got: usize },
    /// First four bytes are not [`MAGIC`].
    BadMagic([u8; 4]),
    /// Unknown wire-format version.
    BadVersion(u16),
    /// Declared payload length exceeds [`MAX_PAYLOAD`].
    Oversize(usize),
    /// FNV-1a over header+payload does not match the checksum field.
    ChecksumMismatch { expected: u64, got: u64 },
    /// Unknown frame kind (checked after the checksum, so it can only fire
    /// on a well-formed frame from a newer/foreign sender).
    BadKind(u16),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Truncated { expected, got } => {
                write!(f, "truncated frame: need {expected} bytes, got {got}")
            }
            FrameError::TrailingBytes { expected, got } => {
                write!(f, "frame length mismatch: header says {expected} bytes, got {got}")
            }
            FrameError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            FrameError::BadVersion(v) => write!(f, "unsupported frame version {v}"),
            FrameError::Oversize(n) => write!(f, "payload length {n} exceeds MAX_PAYLOAD"),
            FrameError::ChecksumMismatch { expected, got } => write!(
                f,
                "frame checksum mismatch: header {expected:#018x}, computed {got:#018x}"
            ),
            FrameError::BadKind(k) => write!(f, "unknown frame kind {k}"),
        }
    }
}

impl std::error::Error for FrameError {}

/// What a frame's payload *is* — added in wire-format version 2 for the
/// elastic runtime. Ids are part of the wire format: never renumber.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u16)]
pub enum FrameKind {
    /// A regular round payload (the only kind version 1 could express).
    Data = 0,
    /// A full-precision model (raw f32 little-endian words, `bits = 32`)
    /// a neighbor ships to a (re)joining node so its model is inside the θ
    /// proximity bound before any modulo-quantized traffic reaches it.
    Bootstrap = 1,
}

impl FrameKind {
    /// Decode a wire id. Total: unknown ids are a typed error, and the
    /// `wire_format` lint checks every variant appears here.
    fn from_wire(v: u16) -> Result<FrameKind, FrameError> {
        match v {
            0 => Ok(FrameKind::Data),
            1 => Ok(FrameKind::Bootstrap),
            other => Err(FrameError::BadKind(other)),
        }
    }

    /// Wire id of this kind — the inverse of [`Self::from_wire`], spelled
    /// as an explicit match (not `as u16`) so the `wire_format` lint can
    /// prove every variant is encodable.
    fn to_wire(self) -> u16 {
        match self {
            FrameKind::Data => 0,
            FrameKind::Bootstrap => 1,
        }
    }
}

/// One wire message: header fields + the packed payload.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub round: u64,
    pub sender: u16,
    /// Algorithm id ([`algo_wire_id`]); receivers reject cross-algorithm
    /// frames instead of mis-decoding the payload.
    pub algo: u16,
    /// Bits per parameter of the payload encoding (32 = raw f32).
    pub bits: u16,
    /// What the payload carries (round data vs. a bootstrap model).
    pub kind: FrameKind,
    /// The sender's θ bound this round (0.0 for unquantized algorithms).
    pub theta: f32,
    pub payload: Vec<u8>,
}

impl Frame {
    /// Total encoded size. Saturating: a `Frame` whose payload somehow
    /// exceeded `usize::MAX - HEADER_LEN` would already have tripped the
    /// `MAX_PAYLOAD` assert in `encode_into`, but length math on frame
    /// fields is checked as a matter of policy (`checked_arith` lint).
    pub fn encoded_len(&self) -> usize {
        HEADER_LEN.saturating_add(self.payload.len())
    }

    /// Serialize into a fresh buffer.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_len());
        self.encode_into(&mut out);
        out
    }

    /// Serialize by appending to `out` (the TCP path reuses one buffer).
    // lint: hot-path
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        assert!(self.payload.len() <= MAX_PAYLOAD, "payload exceeds MAX_PAYLOAD");
        let payload_len = match u32::try_from(self.payload.len()) {
            Ok(v) => v,
            // MAX_PAYLOAD (1 GiB) fits in u32; the assert above already
            // rejected anything larger.
            Err(_) => unreachable!("payload exceeds MAX_PAYLOAD"),
        };
        let base = out.len();
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&self.algo.to_le_bytes());
        out.extend_from_slice(&self.round.to_le_bytes());
        out.extend_from_slice(&self.sender.to_le_bytes());
        out.extend_from_slice(&self.bits.to_le_bytes());
        out.extend_from_slice(&self.kind.to_wire().to_le_bytes());
        out.extend_from_slice(&self.theta.to_bits().to_le_bytes());
        out.extend_from_slice(&payload_len.to_le_bytes());
        // checksum covers header-so-far ++ payload
        let mut h = fnv1a_bytes(&out[base..base + OFF_CHECKSUM]);
        h = fnv1a_continue(h, &self.payload);
        out.extend_from_slice(&h.to_le_bytes());
        out.extend_from_slice(&self.payload);
    }

    /// Decode a complete frame from `bytes` (must contain exactly one
    /// frame — the transports deliver length-prefixed units).
    pub fn decode(bytes: &[u8]) -> Result<Frame, FrameError> {
        let mut f = Self::validate(bytes)?;
        f.payload = bytes[HEADER_LEN..].to_vec();
        Ok(f)
    }

    /// As [`Self::decode`] but consuming the wire buffer: the payload is
    /// the buffer itself with the header drained off — no copy. This is
    /// the transports' receive path (they already own the bytes). On
    /// error the buffer is dropped; callers holding *pooled* buffers
    /// should use [`Self::decode_reclaim`] instead.
    // lint: hot-path
    pub fn decode_owned(bytes: Vec<u8>) -> Result<Frame, FrameError> {
        Self::decode_reclaim(bytes).map_err(|(e, _)| e)
    }

    /// As [`Self::decode_owned`], but on failure the wire buffer rides
    /// back alongside the error so the caller can return it to its
    /// [`FramePool`](crate::mem::FramePool). Without this, every corrupt
    /// frame silently shrank the pool by one buffer (the decode error
    /// dropped the checked-out `Vec`), so sustained frame-fuzz/Byzantine
    /// traffic degraded the zero-allocation steady state into
    /// allocate-per-frame — `tests/alloc_discipline.rs` pins the fixed
    /// behavior with a corrupt-frame round.
    // lint: hot-path
    pub fn decode_reclaim(mut bytes: Vec<u8>) -> Result<Frame, (FrameError, Vec<u8>)> {
        match Self::validate(&bytes) {
            Ok(mut f) => {
                bytes.drain(..HEADER_LEN);
                f.payload = bytes;
                Ok(f)
            }
            Err(e) => Err((e, bytes)),
        }
    }

    /// Full header + checksum validation; returns the frame with an empty
    /// payload (the callers above attach it without re-checking).
    fn validate(bytes: &[u8]) -> Result<Frame, FrameError> {
        if bytes.len() < HEADER_LEN {
            return Err(FrameError::Truncated { expected: HEADER_LEN, got: bytes.len() });
        }
        if bytes[OFF_MAGIC..OFF_VERSION] != MAGIC {
            return Err(FrameError::BadMagic([bytes[0], bytes[1], bytes[2], bytes[3]]));
        }
        let version = read_u16(bytes, OFF_VERSION);
        if version != VERSION {
            return Err(FrameError::BadVersion(version));
        }
        let algo = read_u16(bytes, OFF_ALGO);
        let round = read_u64(bytes, OFF_ROUND);
        let sender = read_u16(bytes, OFF_SENDER);
        let bits = read_u16(bytes, OFF_BITS);
        let kind_raw = read_u16(bytes, OFF_KIND);
        let theta = f32::from_bits(read_u32(bytes, OFF_THETA));
        let payload_len = read_u32(bytes, OFF_PAYLOAD_LEN) as usize;
        if payload_len > MAX_PAYLOAD {
            return Err(FrameError::Oversize(payload_len));
        }
        let expected = HEADER_LEN
            .checked_add(payload_len)
            .ok_or(FrameError::Oversize(payload_len))?;
        if bytes.len() < expected {
            return Err(FrameError::Truncated { expected, got: bytes.len() });
        }
        if bytes.len() > expected {
            return Err(FrameError::TrailingBytes { expected, got: bytes.len() });
        }
        let checksum = read_u64(bytes, OFF_CHECKSUM);
        let mut h = fnv1a_bytes(&bytes[OFF_MAGIC..OFF_CHECKSUM]);
        h = fnv1a_continue(h, &bytes[HEADER_LEN..]);
        if h != checksum {
            return Err(FrameError::ChecksumMismatch { expected: checksum, got: h });
        }
        // Kind is validated *after* the checksum: a BadKind is a well-formed
        // frame from a foreign/newer peer, not corruption.
        let kind = FrameKind::from_wire(kind_raw)?;
        // lint: allow(hot_alloc) — a capacity-0 `Vec::new` never touches
        // the heap; the decode entry points attach the real payload buffer.
        Ok(Frame { round, sender, algo, bits, kind, theta, payload: Vec::new() })
    }
}

/// Little-endian field readers. Bounds are guaranteed by the
/// `bytes.len() >= HEADER_LEN` check in `validate` plus the
/// [`FIELD_LAYOUT`] tiling invariant, so no per-field `try_into` (and no
/// panic path the `panic_surface` lint would have to trust) is needed.
#[inline]
fn read_u16(b: &[u8], off: usize) -> u16 {
    u16::from_le_bytes([b[off], b[off + 1]])
}

#[inline]
fn read_u32(b: &[u8], off: usize) -> u32 {
    let mut a = [0u8; 4];
    a.copy_from_slice(&b[off..off + 4]);
    u32::from_le_bytes(a)
}

#[inline]
fn read_u64(b: &[u8], off: usize) -> u64 {
    let mut a = [0u8; 8];
    a.copy_from_slice(&b[off..off + 8]);
    u64::from_le_bytes(a)
}

/// Continue an FNV-1a hash over more bytes (same constants as
/// [`fnv1a_bytes`], which seeds with the FNV offset basis).
fn fnv1a_continue(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Stable wire id for each algorithm's report name. Ids are part of the
/// wire format: never renumber, only append.
pub fn algo_wire_id(name: &str) -> u16 {
    match name {
        "allreduce" => 1,
        "dpsgd" => 2,
        "naive" => 3,
        "moniqua" => 4,
        "moniqua-slack" => 5,
        "d2" => 6,
        "moniqua-d2" => 7,
        "dcd" => 8,
        "ecd" => 9,
        "choco" => 10,
        "deepsqueeze" => 11,
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(payload: Vec<u8>) -> Frame {
        Frame {
            round: 7,
            sender: 3,
            algo: 4,
            bits: 8,
            kind: FrameKind::Data,
            theta: 2.0,
            payload,
        }
    }

    #[test]
    fn field_layout_tiles_header() {
        let mut expect = 0;
        for (off, width) in FIELD_LAYOUT {
            assert_eq!(off, expect, "field at offset {off} leaves a gap/overlap");
            expect += width;
        }
        assert_eq!(expect, HEADER_LEN);
    }

    #[test]
    fn kind_wire_ids_roundtrip_and_stay_stable() {
        for k in [FrameKind::Data, FrameKind::Bootstrap] {
            assert_eq!(FrameKind::from_wire(k.to_wire()).unwrap(), k);
        }
        // Ids are part of the wire format: never renumber.
        assert_eq!(FrameKind::Data.to_wire(), 0);
        assert_eq!(FrameKind::Bootstrap.to_wire(), 1);
    }

    #[test]
    fn roundtrip_preserves_every_field() {
        let f = sample(vec![1, 2, 3, 250]);
        let bytes = f.encode();
        assert_eq!(bytes.len(), f.encoded_len());
        let g = Frame::decode(&bytes).unwrap();
        assert_eq!(f, g);
    }

    #[test]
    fn empty_payload_roundtrips() {
        let f = sample(Vec::new());
        assert_eq!(Frame::decode(&f.encode()).unwrap(), f);
    }

    #[test]
    fn decode_owned_matches_decode() {
        let f = sample((0..200u32).map(|v| v as u8).collect());
        let bytes = f.encode();
        assert_eq!(Frame::decode_owned(bytes.clone()).unwrap(), f);
        assert_eq!(Frame::decode_owned(bytes).unwrap(), Frame::decode(&f.encode()).unwrap());
        let mut bad = f.encode();
        bad[0] ^= 1;
        assert!(matches!(Frame::decode_owned(bad), Err(FrameError::BadMagic(_))));
    }

    #[test]
    fn truncation_is_typed() {
        let bytes = sample(vec![9; 16]).encode();
        for cut in 0..bytes.len() {
            match Frame::decode(&bytes[..cut]) {
                Err(FrameError::Truncated { .. }) => {}
                other => panic!("cut={cut}: {other:?}"),
            }
        }
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut bytes = sample(vec![9; 8]).encode();
        bytes.push(0);
        assert!(matches!(
            Frame::decode(&bytes),
            Err(FrameError::TrailingBytes { .. })
        ));
    }

    #[test]
    fn corrupt_magic_version_checksum() {
        let f = sample(vec![5; 32]);
        let mut bad = f.encode();
        bad[0] ^= 0xff;
        assert!(matches!(Frame::decode(&bad), Err(FrameError::BadMagic(_))));
        let mut bad = f.encode();
        bad[4] ^= 0x01;
        assert!(matches!(Frame::decode(&bad), Err(FrameError::BadVersion(_))));
        let mut bad = f.encode();
        *bad.last_mut().unwrap() ^= 0x01; // flip a payload bit
        assert!(matches!(
            Frame::decode(&bad),
            Err(FrameError::ChecksumMismatch { .. })
        ));
    }

    #[test]
    fn bootstrap_kind_roundtrips() {
        let mut f = sample(vec![0, 0, 128, 63]); // one f32 1.0
        f.kind = FrameKind::Bootstrap;
        f.bits = 32;
        let g = Frame::decode(&f.encode()).unwrap();
        assert_eq!(g.kind, FrameKind::Bootstrap);
        assert_eq!(f, g);
    }

    #[test]
    fn unknown_kind_is_typed_after_checksum() {
        // Forge a frame with kind = 7 and a *correct* checksum: decode must
        // report BadKind, not ChecksumMismatch.
        let mut bytes = sample(vec![1, 2, 3]).encode();
        bytes[20] = 7;
        let mut h = crate::quant::hash::fnv1a_bytes(&bytes[0..30]);
        h = super::fnv1a_continue(h, &bytes[HEADER_LEN..]);
        bytes[30..38].copy_from_slice(&h.to_le_bytes());
        assert_eq!(Frame::decode(&bytes), Err(FrameError::BadKind(7)));
    }

    #[test]
    fn algo_ids_are_stable_and_distinct() {
        let names = [
            "allreduce", "dpsgd", "naive", "moniqua", "moniqua-slack", "d2",
            "moniqua-d2", "dcd", "ecd", "choco", "deepsqueeze",
        ];
        let ids: Vec<u16> = names.iter().map(|n| algo_wire_id(n)).collect();
        let uniq: std::collections::HashSet<_> = ids.iter().collect();
        assert_eq!(uniq.len(), ids.len());
        assert!(ids.iter().all(|&i| i != 0));
        assert_eq!(algo_wire_id("unknown"), 0);
    }
}
