//! TCP transport: length-prefixed frames over `std::net` on localhost.
//!
//! Topology-of-sockets: one `TcpListener` per worker (bound before any
//! endpoint is handed out, so dials never race the bind), outbound
//! connections dialed lazily on first `send` to a peer, one reader thread
//! per accepted inbound connection pushing decoded-length units into the
//! endpoint's channel. The stream protocol is `u32 le frame_len ++ frame
//! bytes`; the frame itself re-validates magic/version/checksum, so a
//! desynchronized stream surfaces as a typed error, not garbage models.
//!
//! Binding `port_base = 0` asks the OS for ephemeral ports and shares the
//! *discovered* addresses with every endpoint — the port-collision-safe
//! mode the conformance and equivalence suites use. A non-zero `port_base`
//! pins worker `i` to `port_base + i` (useful for externally-observed runs,
//! e.g. packet captures).

use std::io::{BufWriter, ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::{
    note_received, note_sent, saturating_deadline, Frame, ReorderBuffer, Transport,
    TransportError, HEADER_LEN, MAX_PAYLOAD,
};
use crate::mem::FramePool;
use crate::telemetry::{Counter, Telemetry};

/// Write-buffer capacity per outbound connection: large enough that a
/// typical quantized frame (length prefix + header + packed payload) is
/// staged in full and leaves as **one** `write` syscall on flush, instead
/// of whatever partial-write pattern the raw socket produces.
const WRITE_BUF: usize = 1 << 16;

/// One worker's TCP endpoint.
pub struct TcpTransport {
    id: usize,
    addrs: Vec<SocketAddr>,
    /// Outbound connections, each behind a [`BufWriter`] flushed once per
    /// frame (§Perf: one syscall per frame per peer on the broadcast path).
    outs: Vec<Option<BufWriter<TcpStream>>>,
    rx: Receiver<Result<Vec<u8>, String>>,
    buf: ReorderBuffer,
    /// Pooled frame-encode scratch, reused across every send on this
    /// endpoint (length prefix + header + payload serialized once per
    /// broadcast).
    scratch: Vec<u8>,
    /// Wire buffer pool shared with this endpoint's reader threads; the
    /// cluster consumer returns payloads through [`Transport::recycle`].
    pool: FramePool,
    shutdown: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<()>>,
    telemetry: Telemetry,
}

impl TcpTransport {
    /// Build an `n`-endpoint cluster on loopback. `port_base = 0` uses OS
    /// ephemeral ports (collision-safe); otherwise worker `i` listens on
    /// `port_base + i`.
    pub fn cluster(n: usize, port_base: u16) -> std::io::Result<Vec<TcpTransport>> {
        assert!(n > 0);
        // Last worker listens on port_base + n - 1; 65535 itself is valid.
        if port_base != 0 && port_base as usize + n - 1 > u16::MAX as usize {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("port_base {port_base} + {n} workers exceeds the u16 port range"),
            ));
        }
        let listeners: Vec<TcpListener> = (0..n)
            .map(|i| {
                let port = if port_base == 0 { 0 } else { port_base + i as u16 };
                TcpListener::bind(("127.0.0.1", port))
            })
            .collect::<std::io::Result<_>>()?;
        let addrs: Vec<SocketAddr> = listeners
            .iter()
            .map(|l| l.local_addr())
            .collect::<std::io::Result<_>>()?;
        let pool = FramePool::new();
        Ok(listeners
            .into_iter()
            .enumerate()
            .map(|(id, listener)| {
                let (tx, rx) = channel();
                let shutdown = Arc::new(AtomicBool::new(false));
                let accept_handle = Some(spawn_acceptor(
                    listener,
                    tx,
                    Arc::clone(&shutdown),
                    pool.clone(),
                ));
                TcpTransport {
                    id,
                    addrs: addrs.clone(),
                    outs: (0..n).map(|_| None).collect(),
                    rx,
                    buf: ReorderBuffer::default(),
                    scratch: Vec::new(),
                    pool: pool.clone(),
                    shutdown,
                    accept_handle,
                    telemetry: Telemetry::disabled(),
                }
            })
            .collect())
    }

    /// The address each worker listens on (index = worker id).
    pub fn addrs(&self) -> &[SocketAddr] {
        &self.addrs
    }

    /// The cluster-shared wire buffer pool (tests assert recycling works).
    pub fn pool(&self) -> &FramePool {
        &self.pool
    }

    fn connect(&mut self, peer: usize) -> Result<&mut BufWriter<TcpStream>, TransportError> {
        if self.outs[peer].is_none() {
            let stream = TcpStream::connect(self.addrs[peer])
                .map_err(|e| TransportError::Io(e.to_string()))?;
            stream
                .set_nodelay(true)
                .map_err(|e| TransportError::Io(e.to_string()))?;
            self.outs[peer] = Some(BufWriter::with_capacity(WRITE_BUF, stream));
        }
        match self.outs[peer] {
            Some(ref mut s) => Ok(s),
            // Unreachable (populated just above), but a typed error keeps
            // the send path panic-free (`panic_surface` lint).
            None => Err(TransportError::Closed),
        }
    }

    fn drain(&mut self) -> Result<(), TransportError> {
        loop {
            match self.rx.try_recv() {
                Ok(Ok(bytes)) => self.push_decoded(bytes)?,
                Ok(Err(io)) => return Err(TransportError::Io(io)),
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => return Ok(()),
            }
        }
    }

    /// Decode one wire buffer into the reorder buffer, returning the
    /// buffer to the pool on decode failure (satellite bugfix: the
    /// `decode_owned(bytes)?` form dropped the pooled buffer, so corrupt
    /// traffic shrank the pool one buffer per bad frame).
    fn push_decoded(&mut self, bytes: Vec<u8>) -> Result<(), TransportError> {
        let wire_len = bytes.len();
        match Frame::decode_reclaim(bytes) {
            Ok(f) => {
                note_received(&self.telemetry, f.kind, wire_len);
                self.buf.push(f);
                Ok(())
            }
            Err((e, junk)) => {
                self.telemetry.record(Counter::FramesRejected, 1);
                self.pool.give(junk);
                Err(e.into())
            }
        }
    }
}

impl Transport for TcpTransport {
    fn local_id(&self) -> usize {
        self.id
    }

    fn cluster_size(&self) -> usize {
        self.addrs.len()
    }

    // lint: hot-path
    fn send(&mut self, peer: usize, frame: &Frame) -> Result<(), TransportError> {
        self.broadcast(&[peer], frame)
    }

    // lint: hot-path
    fn broadcast(&mut self, peers: &[usize], frame: &Frame) -> Result<(), TransportError> {
        // Serialize (length prefix + header + checksum) once into the
        // pooled per-endpoint scratch; every peer gets the same bytes. The
        // buffered writer stages prefix + frame together and the explicit
        // flush hands the kernel one contiguous write per frame.
        let prefix = match u32::try_from(frame.encoded_len()) {
            Ok(v) => v,
            // Unreachable: encode_into rejects payloads over MAX_PAYLOAD
            // (1 GiB), so the prefix always fits a u32.
            Err(_) => unreachable!("frame exceeds u32 length prefix"),
        };
        let mut scratch = std::mem::take(&mut self.scratch);
        scratch.clear();
        scratch.extend_from_slice(&prefix.to_le_bytes());
        frame.encode_into(&mut scratch);
        let mut result = Ok(());
        for &p in peers {
            assert!(p < self.addrs.len(), "peer {p} out of range");
            result = self.connect(p).and_then(|s| {
                s.write_all(&scratch)
                    .and_then(|()| s.flush())
                    .map_err(|e| TransportError::Io(e.to_string()))
            });
            if result.is_err() {
                // A broken pipe poisons the cached stream; redial on retry.
                self.outs[p] = None;
                break;
            }
            // Wire bytes exclude the 4-byte stream prefix so the sent/
            // received byte counters agree across transports.
            note_sent(&self.telemetry, frame.kind, scratch.len() - 4);
        }
        self.scratch = scratch;
        result
    }

    // lint: hot-path
    fn recv(&mut self, timeout: Duration) -> Result<Frame, TransportError> {
        // lint: allow(wall_clock) — the recv deadline is transport-local
        // timing; it gates *when* a frame is returned, never its bytes.
        let deadline = saturating_deadline(Instant::now(), timeout);
        loop {
            self.drain()?;
            if let Some(f) = self.buf.pop() {
                return Ok(f);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(TransportError::Timeout);
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(Ok(bytes)) => self.push_decoded(bytes)?,
                Ok(Err(io)) => return Err(TransportError::Io(io)),
                Err(RecvTimeoutError::Timeout) => return Err(TransportError::Timeout),
                Err(RecvTimeoutError::Disconnected) => return Err(TransportError::Closed),
            }
        }
    }

    // lint: hot-path
    fn recycle(&mut self, payload: Vec<u8>) {
        self.pool.give(payload);
    }

    fn set_metrics(&mut self, t: Telemetry) {
        self.pool.set_metrics(t.clone());
        self.telemetry = t;
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Closing our outbound streams EOFs the peers' reader threads.
        for out in self.outs.iter_mut() {
            *out = None;
        }
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

/// Accept loop: non-blocking accept polled against the shutdown flag; each
/// inbound connection gets a reader thread that reframes the byte stream
/// into length-delimited units.
fn spawn_acceptor(
    listener: TcpListener,
    tx: Sender<Result<Vec<u8>, String>>,
    shutdown: Arc<AtomicBool>,
    pool: FramePool,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        if listener.set_nonblocking(true).is_err() {
            return;
        }
        while !shutdown.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(false).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let tx = tx.clone();
                    let pool = pool.clone();
                    std::thread::spawn(move || read_frames(stream, tx, pool));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    // All dials land in round 0 (lazy connect on first
                    // send); afterwards this poll only has to notice
                    // shutdown and the rare redial, so a coarse interval
                    // keeps the acceptor near-idle for the whole run.
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(_) => break,
            }
        }
    })
}

/// Reader loop for one inbound connection. Exits on EOF (peer closed) or
/// when the owning endpoint dropped its receiver. Read buffers are checked
/// out of the cluster's [`FramePool`]; the consumer returns them through
/// [`Transport::recycle`], so steady-state reads reuse capacity.
// lint: hot-path
fn read_frames(mut stream: TcpStream, tx: Sender<Result<Vec<u8>, String>>, pool: FramePool) {
    let max_frame = HEADER_LEN + MAX_PAYLOAD;
    loop {
        let mut len_bytes = [0u8; 4];
        match stream.read_exact(&mut len_bytes) {
            Ok(()) => {}
            // Clean EOF between frames: peer closed its end.
            Err(_) => return,
        }
        let len = u32::from_le_bytes(len_bytes) as usize;
        if len > max_frame {
            let _ = tx.send(Err(format!("frame length prefix {len} exceeds maximum")));
            return;
        }
        let mut bytes = pool.take();
        bytes.resize(len, 0);
        if let Err(e) = stream.read_exact(&mut bytes) {
            // Hand the half-filled buffer back before reporting: the
            // reader dies here, and a dropped buffer would shrink the
            // cluster-shared pool for everyone else.
            pool.give(bytes);
            let _ = tx.send(Err(format!("mid-frame read failed: {e}")));
            return;
        }
        if tx.send(Ok(bytes)).is_err() {
            return; // endpoint dropped
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(round: u64, sender: u16, payload: Vec<u8>) -> Frame {
        Frame {
            round,
            sender,
            algo: 4,
            bits: 8,
            kind: crate::transport::FrameKind::Data,
            theta: 2.0,
            payload,
        }
    }

    #[test]
    fn loopback_roundtrip() {
        let mut eps = TcpTransport::cluster(2, 0).unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, &frame(3, 0, vec![7; 100])).unwrap();
        let got = b.recv(Duration::from_secs(5)).unwrap();
        assert_eq!(got.round, 3);
        assert_eq!(got.payload, vec![7; 100]);
    }

    #[test]
    fn ephemeral_ports_are_distinct() {
        let eps = TcpTransport::cluster(3, 0).unwrap();
        let ports: std::collections::HashSet<u16> =
            eps[0].addrs().iter().map(|a| a.port()).collect();
        assert_eq!(ports.len(), 3);
        assert!(eps[0].addrs().iter().all(|a| a.port() != 0));
    }

    #[test]
    fn timeout_on_idle_endpoint() {
        let mut eps = TcpTransport::cluster(1, 0).unwrap();
        let err = eps[0].recv(Duration::from_millis(20)).unwrap_err();
        assert_eq!(err, TransportError::Timeout);
    }

    #[test]
    fn recv_with_duration_max_does_not_overflow() {
        // Regression: `Instant::now() + Duration::MAX` panicked.
        let mut eps = TcpTransport::cluster(2, 0).unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, &frame(0, 0, vec![5])).unwrap();
        let got = b.recv(Duration::MAX).unwrap();
        assert_eq!(got.payload, vec![5]);
    }

    #[test]
    fn corrupt_stream_bytes_recycle_the_wire_buffer() {
        // Regression: a decode failure on the recv path dropped the pooled
        // buffer the reader thread had checked out.
        let mut eps = TcpTransport::cluster(1, 0).unwrap();
        let before = eps[0].pool().pooled();
        let mut raw = std::net::TcpStream::connect(eps[0].addrs()[0]).unwrap();
        // Well-formed length prefix, garbage frame bytes: the reader
        // delivers a 16-byte unit that fails magic validation.
        raw.write_all(&16u32.to_le_bytes()).unwrap();
        raw.write_all(&[0xAB; 16]).unwrap();
        raw.flush().unwrap();
        let err = eps[0].recv(Duration::from_secs(5)).unwrap_err();
        assert!(matches!(err, TransportError::Frame(_)), "got {err:?}");
        assert!(
            eps[0].pool().pooled() > before,
            "corrupt wire buffer must return to the pool, not leak"
        );
    }
}
