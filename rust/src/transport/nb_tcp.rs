//! Nonblocking TCP transport for the reactor runtime: zero internal
//! threads, all socket I/O driven by the caller's poll loop.
//!
//! [`TcpTransport`](super::TcpTransport) spends three OS threads per worker
//! (acceptor + one reader per inbound connection), which is exactly the
//! thread-per-worker cost the reactor exists to remove — at 256 workers
//! that transport would spawn ~768 threads before the first frame moves.
//! [`NbTcpTransport`] keeps the same wire protocol (`u32 le frame_len ++
//! frame bytes`, same listener-per-worker/lazy-dial topology) but services
//! every socket inline from [`Transport::recv`]:
//!
//! * **accept** — the listener is nonblocking; each `poll_io` drains the
//!   accept queue and registers the new connection's reassembly state.
//! * **read** — each inbound connection owns a tiny reassembly machine:
//!   4 length-prefix bytes, then a pooled wire buffer filled across as many
//!   `read` calls as the kernel needs. Partial frames persist across polls;
//!   a complete frame decodes into the `(round, sender)` reorder buffer.
//! * **write** — `broadcast` encodes once and enqueues per-peer copies
//!   (pooled buffers); unfinished writes stay queued and every poll retries
//!   them, so a send never blocks the driver thread.
//!
//! Reassembly invariants (DESIGN.md §Reactor): a pooled buffer is owned by
//! exactly one reassembly machine or write queue at a time; every exit path
//! — complete frame, decode failure, mid-frame EOF, connection teardown —
//! either hands the buffer to the consumer or returns it to the pool.
//! Errors discovered inside `poll_io` park in `pending_err` and surface
//! from the next `recv`, after already-decoded frames drain.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use super::{
    note_received, note_sent, saturating_deadline, Frame, ReorderBuffer, Transport,
    TransportError, HEADER_LEN, MAX_PAYLOAD,
};
use crate::mem::FramePool;
use crate::telemetry::{Counter, Telemetry};

/// Sleep between polls when `recv` is called with a real (non-zero)
/// timeout: long enough to stay off the CPU on an idle socket, short
/// enough that frame latency stays well under a scheduler tick. The
/// reactor driver never sleeps here — it polls with `Duration::ZERO` and
/// parks on its own wake token instead.
const POLL_SLEEP: Duration = Duration::from_micros(200);

/// One inbound connection's frame-reassembly state.
struct InConn {
    stream: TcpStream,
    /// Set when the connection is done (EOF or error); reaped by the next
    /// poll, returning any partial buffer to the pool.
    closed: bool,
    /// Length-prefix accumulator: `len_buf[..len_got]` is valid.
    len_buf: [u8; 4],
    len_got: usize,
    /// True once the prefix is complete and `frame[..filled]` is the
    /// partially-read frame of `need` total bytes.
    have_len: bool,
    need: usize,
    filled: usize,
    /// Pooled wire buffer the frame assembles into.
    frame: Vec<u8>,
    /// Successful body reads feeding the current frame; a frame that needed
    /// more than one is a reassembly split (telemetry).
    body_reads: u32,
}

/// One outbound connection: pending wire buffers flushed opportunistically
/// on every poll (FIFO — a later frame never passes an earlier one).
struct OutConn {
    stream: TcpStream,
    queue: VecDeque<Vec<u8>>,
    /// Bytes of `queue.front()` already written.
    written: usize,
}

/// One worker's nonblocking TCP endpoint (see module docs).
pub struct NbTcpTransport {
    id: usize,
    addrs: Vec<SocketAddr>,
    listener: TcpListener,
    ins: Vec<InConn>,
    outs: Vec<Option<OutConn>>,
    buf: ReorderBuffer,
    /// Pooled frame-encode scratch, reused across sends.
    scratch: Vec<u8>,
    pool: FramePool,
    /// First error discovered inside `poll_io`; surfaced by the next
    /// `recv` after buffered frames drain.
    pending_err: Option<TransportError>,
    telemetry: Telemetry,
}

impl NbTcpTransport {
    /// Build an `n`-endpoint cluster on loopback, mirroring
    /// [`TcpTransport::cluster`](super::TcpTransport::cluster): listeners
    /// all bound before any endpoint is handed out, `port_base = 0` for OS
    /// ephemeral ports, one shared wire-buffer pool.
    pub fn cluster(n: usize, port_base: u16) -> std::io::Result<Vec<NbTcpTransport>> {
        assert!(n > 0);
        if port_base != 0 && port_base as usize + n - 1 > u16::MAX as usize {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("port_base {port_base} + {n} workers exceeds the u16 port range"),
            ));
        }
        let listeners: Vec<TcpListener> = (0..n)
            .map(|i| {
                let port = if port_base == 0 { 0 } else { port_base + i as u16 };
                let l = TcpListener::bind(("127.0.0.1", port))?;
                l.set_nonblocking(true)?;
                Ok(l)
            })
            .collect::<std::io::Result<_>>()?;
        let addrs: Vec<SocketAddr> = listeners
            .iter()
            .map(|l| l.local_addr())
            .collect::<std::io::Result<_>>()?;
        let pool = FramePool::new();
        Ok(listeners
            .into_iter()
            .enumerate()
            .map(|(id, listener)| NbTcpTransport {
                id,
                addrs: addrs.clone(),
                listener,
                ins: Vec::new(),
                outs: (0..n).map(|_| None).collect(),
                buf: ReorderBuffer::default(),
                scratch: Vec::new(),
                pool: pool.clone(),
                pending_err: None,
                telemetry: Telemetry::disabled(),
            })
            .collect())
    }

    /// The address each worker listens on (index = worker id).
    pub fn addrs(&self) -> &[SocketAddr] {
        &self.addrs
    }

    /// The cluster-shared wire buffer pool (tests assert recycling works).
    pub fn pool(&self) -> &FramePool {
        &self.pool
    }

    /// Dial `peer` if no cached connection exists. The dial itself is the
    /// one blocking call in this transport (connect-then-set-nonblocking);
    /// it happens once per peer per run, in round 0 or after a redial.
    fn ensure_connected(&mut self, peer: usize) -> Result<(), TransportError> {
        if self.outs[peer].is_some() {
            return Ok(());
        }
        let stream = TcpStream::connect(self.addrs[peer])
            .map_err(|e| TransportError::Io(e.to_string()))?;
        stream
            .set_nodelay(true)
            .map_err(|e| TransportError::Io(e.to_string()))?;
        stream
            .set_nonblocking(true)
            .map_err(|e| TransportError::Io(e.to_string()))?;
        // Depth 4 covers the strict schedule's one-frame-in-flight and the
        // pipelined schedule's one-round-ahead bound without regrowth.
        self.outs[peer] = Some(OutConn { stream, queue: VecDeque::with_capacity(4), written: 0 });
        Ok(())
    }

    /// Queue `wire` (a complete prefix+frame unit) toward `peer` in a
    /// pooled copy, then flush as much of the queue as the socket accepts.
    // lint: hot-path
    fn enqueue_to(&mut self, peer: usize, wire: &[u8]) -> Result<(), TransportError> {
        self.ensure_connected(peer)?;
        let mut copy = self.pool.take();
        copy.extend_from_slice(wire);
        if let Some(conn) = self.outs[peer].as_mut() {
            conn.queue.push_back(copy);
        }
        if let Err(e) = self.flush_out(peer) {
            self.drop_out(peer);
            return Err(e);
        }
        Ok(())
    }

    /// Write queued buffers to `peer` until the socket would block or the
    /// queue empties; fully-written buffers return to the pool.
    // lint: hot-path
    fn flush_out(&mut self, peer: usize) -> Result<(), TransportError> {
        loop {
            let Some(conn) = self.outs[peer].as_mut() else { return Ok(()) };
            let Some(front) = conn.queue.front() else { return Ok(()) };
            match conn.stream.write(&front[conn.written..]) {
                Ok(0) => return Err(TransportError::Closed),
                Ok(k) => {
                    conn.written += k;
                    if conn.written == front.len() {
                        conn.written = 0;
                        if let Some(done) = conn.queue.pop_front() {
                            self.pool.give(done);
                        }
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    // Backpressure: the rest of the queue retries on the
                    // next poll sweep.
                    self.telemetry.record(Counter::NbWouldBlock, 1);
                    return Ok(());
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(TransportError::Io(e.to_string())),
            }
        }
    }

    /// Tear down the cached connection to `peer`, reclaiming queued wire
    /// buffers. The next send redials (same recovery as `TcpTransport`).
    fn drop_out(&mut self, peer: usize) {
        if let Some(mut conn) = self.outs[peer].take() {
            while let Some(b) = conn.queue.pop_front() {
                self.pool.give(b);
            }
        }
    }

    /// One readiness sweep: accept new connections, advance every inbound
    /// reassembly machine, retry pending writes. Never blocks.
    // lint: hot-path
    fn poll_io(&mut self) {
        self.accept_ready();
        self.read_ready();
        for p in 0..self.outs.len() {
            let needs_flush = self.outs[p].as_ref().is_some_and(|c| !c.queue.is_empty());
            if needs_flush && self.flush_out(p).is_err() {
                // The frames on this queue are lost; the peer's barrier
                // will time out and failure propagation takes over —
                // identical to a reader-thread death in `TcpTransport`.
                self.drop_out(p);
            }
        }
    }

    /// Drain the listener's accept queue (nonblocking).
    // lint: hot-path
    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    self.ins.push(InConn {
                        stream,
                        closed: false,
                        len_buf: [0u8; 4],
                        len_got: 0,
                        have_len: false,
                        need: 0,
                        filled: 0,
                        frame: self.pool.take(),
                        body_reads: 0,
                    });
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return,
            }
        }
    }

    /// Advance every inbound connection's reassembly machine as far as the
    /// kernel's buffers allow, then reap closed connections.
    // lint: hot-path
    fn read_ready(&mut self) {
        let max_frame = HEADER_LEN + MAX_PAYLOAD;
        for ix in 0..self.ins.len() {
            loop {
                let conn = &mut self.ins[ix];
                if conn.closed {
                    break;
                }
                if !conn.have_len {
                    // Accumulate the 4-byte length prefix.
                    match conn.stream.read(&mut conn.len_buf[conn.len_got..]) {
                        Ok(0) => {
                            // EOF on a prefix boundary is a clean close;
                            // mid-prefix it means a truncated stream.
                            if conn.len_got != 0 && self.pending_err.is_none() {
                                self.pending_err = Some(TransportError::Io(
                                    "stream ended mid length prefix".into(),
                                ));
                            }
                            self.ins[ix].closed = true;
                            break;
                        }
                        Ok(k) => {
                            conn.len_got += k;
                            if conn.len_got == 4 {
                                let len = u32::from_le_bytes(conn.len_buf) as usize;
                                if len > max_frame {
                                    if self.pending_err.is_none() {
                                        self.pending_err = Some(TransportError::Io(format!(
                                            "frame length prefix {len} exceeds maximum"
                                        )));
                                    }
                                    self.ins[ix].closed = true;
                                    break;
                                }
                                conn.have_len = true;
                                conn.need = len;
                                conn.filled = 0;
                                conn.body_reads = 0;
                                conn.frame.resize(len, 0);
                            }
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                        Err(e) => {
                            if self.pending_err.is_none() {
                                self.pending_err = Some(TransportError::Io(e.to_string()));
                            }
                            self.ins[ix].closed = true;
                            break;
                        }
                    }
                } else if conn.filled == conn.need {
                    // Frame complete (handles zero-length prefixes too):
                    // swap in a fresh pooled buffer and decode.
                    let full = std::mem::replace(&mut conn.frame, self.pool.take());
                    let split = conn.body_reads > 1;
                    conn.have_len = false;
                    conn.len_got = 0;
                    let wire_len = full.len();
                    match Frame::decode_reclaim(full) {
                        Ok(f) => {
                            note_received(&self.telemetry, f.kind, wire_len);
                            if split {
                                self.telemetry.record(Counter::NbReassemblySplit, 1);
                            }
                            self.buf.push(f);
                        }
                        Err((e, junk)) => {
                            // Reclaim before reporting — a dropped buffer
                            // would shrink the cluster-shared pool.
                            self.telemetry.record(Counter::FramesRejected, 1);
                            self.pool.give(junk);
                            if self.pending_err.is_none() {
                                self.pending_err = Some(e.into());
                            }
                        }
                    }
                } else {
                    match conn.stream.read(&mut conn.frame[conn.filled..]) {
                        Ok(0) => {
                            if self.pending_err.is_none() {
                                self.pending_err = Some(TransportError::Io(format!(
                                    "stream ended mid frame ({} of {} bytes)",
                                    conn.filled, conn.need
                                )));
                            }
                            self.ins[ix].closed = true;
                            break;
                        }
                        Ok(k) => {
                            conn.filled += k;
                            conn.body_reads += 1;
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                        Err(e) => {
                            if self.pending_err.is_none() {
                                self.pending_err = Some(TransportError::Io(e.to_string()));
                            }
                            self.ins[ix].closed = true;
                            break;
                        }
                    }
                }
            }
        }
        // Reap closed connections, returning partial buffers to the pool.
        let mut ix = 0;
        while ix < self.ins.len() {
            if self.ins[ix].closed {
                let conn = self.ins.swap_remove(ix);
                self.pool.give(conn.frame);
            } else {
                ix += 1;
            }
        }
    }
}

impl Transport for NbTcpTransport {
    fn local_id(&self) -> usize {
        self.id
    }

    fn cluster_size(&self) -> usize {
        self.addrs.len()
    }

    // lint: hot-path
    fn send(&mut self, peer: usize, frame: &Frame) -> Result<(), TransportError> {
        self.broadcast(&[peer], frame)
    }

    // lint: hot-path
    fn broadcast(&mut self, peers: &[usize], frame: &Frame) -> Result<(), TransportError> {
        // Serialize (length prefix + header + checksum) once into the
        // pooled scratch; each peer gets a pooled copy on its write queue
        // — k peers cost k memcpys and zero blocking writes.
        let prefix = match u32::try_from(frame.encoded_len()) {
            Ok(v) => v,
            // Unreachable: encode_into rejects payloads over MAX_PAYLOAD
            // (1 GiB), so the prefix always fits a u32.
            Err(_) => unreachable!("frame exceeds u32 length prefix"),
        };
        let mut scratch = std::mem::take(&mut self.scratch);
        scratch.clear();
        scratch.extend_from_slice(&prefix.to_le_bytes());
        frame.encode_into(&mut scratch);
        let mut result = Ok(());
        for &p in peers {
            assert!(p < self.addrs.len(), "peer {p} out of range");
            result = self.enqueue_to(p, &scratch);
            if result.is_err() {
                break;
            }
            // Wire bytes exclude the 4-byte stream prefix so the sent/
            // received byte counters agree across transports.
            note_sent(&self.telemetry, frame.kind, scratch.len() - 4);
        }
        self.scratch = scratch;
        result
    }

    // lint: hot-path
    fn recv(&mut self, timeout: Duration) -> Result<Frame, TransportError> {
        // lint: allow(wall_clock) — the recv deadline is transport-local
        // timing; it gates *when* a frame is returned, never its bytes.
        let deadline = saturating_deadline(Instant::now(), timeout);
        loop {
            self.poll_io();
            if let Some(f) = self.buf.pop() {
                return Ok(f);
            }
            if let Some(e) = self.pending_err.take() {
                return Err(e);
            }
            if Instant::now() >= deadline {
                return Err(TransportError::Timeout);
            }
            // Reactor drivers pass Duration::ZERO and never reach this
            // sleep; it only paces direct blocking callers.
            std::thread::sleep(POLL_SLEEP);
        }
    }

    // lint: hot-path
    fn recycle(&mut self, payload: Vec<u8>) {
        self.pool.give(payload);
    }

    fn set_metrics(&mut self, t: Telemetry) {
        self.pool.set_metrics(t.clone());
        self.telemetry = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::FrameKind;

    fn frame(round: u64, sender: u16, payload: Vec<u8>) -> Frame {
        Frame {
            round,
            sender,
            algo: 4,
            bits: 8,
            kind: FrameKind::Data,
            theta: 2.0,
            payload,
        }
    }

    #[test]
    fn loopback_roundtrip_without_threads() {
        let mut eps = NbTcpTransport::cluster(2, 0).unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, &frame(3, 0, vec![7; 100])).unwrap();
        let got = b.recv(Duration::from_secs(5)).unwrap();
        assert_eq!(got.round, 3);
        assert_eq!(got.payload, vec![7; 100]);
    }

    #[test]
    fn partial_frames_reassemble_across_polls() {
        // Drip one frame through a raw socket in tiny chunks with pauses:
        // every poll sees a partial prefix or partial frame and must carry
        // the reassembly state forward.
        let reg = crate::telemetry::Registry::new();
        let mut eps = NbTcpTransport::cluster(1, 0).unwrap();
        eps[0].set_metrics(Telemetry::new(&reg, 0));
        let addr = eps[0].addrs()[0];
        let f = frame(1, 0, vec![9; 64]);
        let mut wire = Vec::new();
        wire.extend_from_slice(&u32::try_from(f.encoded_len()).unwrap().to_le_bytes());
        f.encode_into(&mut wire);
        let h = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            for chunk in wire.chunks(7) {
                s.write_all(chunk).unwrap();
                s.flush().unwrap();
                std::thread::sleep(Duration::from_millis(2));
            }
            // Hold the socket open until the frame is surely consumed.
            std::thread::sleep(Duration::from_millis(200));
        });
        let got = eps[0].recv(Duration::from_secs(10)).unwrap();
        assert_eq!(got.payload, vec![9; 64]);
        h.join().unwrap();
        // 7-byte chunks force the body across many reads: telemetry must
        // see one received data frame that counted as a reassembly split.
        let snap = reg.snapshot();
        assert_eq!(snap.counter(Counter::FramesRecvData), 1);
        assert_eq!(snap.counter(Counter::NbReassemblySplit), 1);
        assert_eq!(snap.counter(Counter::BytesRecvData), got.encoded_len() as u64);
    }

    #[test]
    fn recv_with_duration_max_does_not_overflow() {
        let mut eps = NbTcpTransport::cluster(2, 0).unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, &frame(0, 0, vec![5])).unwrap();
        let got = b.recv(Duration::MAX).unwrap();
        assert_eq!(got.payload, vec![5]);
    }

    #[test]
    fn corrupt_stream_bytes_recycle_the_wire_buffer() {
        let mut eps = NbTcpTransport::cluster(1, 0).unwrap();
        let mut raw = TcpStream::connect(eps[0].addrs()[0]).unwrap();
        raw.write_all(&16u32.to_le_bytes()).unwrap();
        raw.write_all(&[0xAB; 16]).unwrap();
        raw.flush().unwrap();
        let err = eps[0].recv(Duration::from_secs(5)).unwrap_err();
        assert!(matches!(err, TransportError::Frame(_)), "got {err:?}");
        // The reassembly buffer that held the garbage — and the fresh one
        // swapped in behind it — stay pool-owned; nothing leaked. The
        // endpoint itself survives and still times out cleanly.
        let err = eps[0].recv(Duration::from_millis(20)).unwrap_err();
        assert_eq!(err, TransportError::Timeout);
    }

    #[test]
    fn zero_timeout_recv_never_blocks() {
        let mut eps = NbTcpTransport::cluster(1, 0).unwrap();
        let t0 = std::time::Instant::now();
        for _ in 0..100 {
            let err = eps[0].recv(Duration::ZERO).unwrap_err();
            assert_eq!(err, TransportError::Timeout);
        }
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "zero-timeout polls must not sleep"
        );
    }
}
