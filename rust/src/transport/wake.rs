//! Wake token for the reactor's readiness loop.
//!
//! A [`WakeHandle`] is the event-source side of the abort-latch fix: the
//! reactor driver parks on one handle between poll iterations, and anything
//! that can make a worker runnable again — a frame landing in a mem queue,
//! the cluster abort latch tripping — calls [`WakeHandle::wake`] instead of
//! relying on the 50ms `ABORT_POLL_TICK` to be noticed. The handle is a
//! level-triggered flag under a mutex + condvar: a wake that races the park
//! is never lost (the flag is observed before the wait), and a park after a
//! wake returns immediately, consuming the flag.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Level-triggered wake flag shared between a parked driver thread and any
/// number of wakers (transports, the abort latch).
#[derive(Default)]
pub struct WakeHandle {
    flagged: Mutex<bool>,
    cv: Condvar,
}

impl WakeHandle {
    pub fn new() -> Arc<WakeHandle> {
        Arc::new(WakeHandle::default())
    }

    /// Lock the flag, recovering from poisoning: the flag is a plain bool
    /// with no invariant a panicking holder could have half applied, and
    /// the wake path must stay panic-free.
    fn locked(&self) -> std::sync::MutexGuard<'_, bool> {
        match self.flagged.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Mark the handle runnable and wake any parked thread. Idempotent;
    /// never blocks beyond the flag mutex.
    pub fn wake(&self) {
        *self.locked() = true;
        self.cv.notify_all();
    }

    /// Park the calling thread until [`Self::wake`] is called or `timeout`
    /// elapses, whichever is first. Consumes the wake flag, so a wake that
    /// happened *before* the park returns immediately instead of being
    /// lost.
    pub fn park_timeout(&self, timeout: Duration) {
        let mut g = self.locked();
        if !*g {
            g = match self.cv.wait_timeout(g, timeout) {
                Ok((g, _)) => g,
                Err(poisoned) => poisoned.into_inner().0,
            };
        }
        *g = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn wake_before_park_returns_immediately() {
        let w = WakeHandle::new();
        w.wake();
        let t0 = Instant::now();
        w.park_timeout(Duration::from_secs(5));
        assert!(t0.elapsed() < Duration::from_secs(1), "pre-wake was lost");
        // The flag is consumed: the next park must actually wait.
        let t0 = Instant::now();
        w.park_timeout(Duration::from_millis(20));
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn park_wakes_on_concurrent_wake() {
        let w = WakeHandle::new();
        let w2 = Arc::clone(&w);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            w2.wake();
        });
        let t0 = Instant::now();
        w.park_timeout(Duration::from_secs(10));
        assert!(t0.elapsed() < Duration::from_secs(5), "wake not delivered");
        h.join().unwrap();
    }
}
