//! The Moniqua codec: centered modulo (Lemma 1) + wrap/quantize/recover
//! (Lemma 2, Algorithm 1 lines 3–5).
//!
//! Given a consensus bound θ (‖x_i − x_j‖∞ < θ for all neighbors) and a
//! quantizer with error δ < ½ on the unit interval, define
//!
//! ```text
//!     B_θ = 2θ / (1 − 2δ)
//! ```
//!
//! *Send*     `c = Q_δ( centered_mod(x / B_θ, 1) )`               (line 3)
//! *Self*     `x̂_i = g_c·B_θ − centered_mod(x_i, B_θ) + x_i`      (line 4)
//! *Recover*  `x̂_j = centered_mod(g_c·B_θ − y, B_θ) + y`          (line 5)
//!
//! Lemma 2 guarantees `|x̂ − x| ≤ δ·B_θ = 2δθ/(1−2δ)` — the error shrinks
//! with the consensus bound, which is what lets decentralized SGD keep its
//! full-precision rate.

use super::linear::LinearQuantizer;
use super::{packing, QuantConfig};

/// Centered modulo (paper Eq. 1): the unique value in `[-a/2, a/2)`
/// congruent to `z` modulo `a`.
#[inline]
pub fn centered_mod(z: f32, a: f32) -> f32 {
    z - a * (z / a + 0.5).floor()
}

/// f64 variant for analysis-grade code paths.
#[inline]
pub fn centered_mod64(z: f64, a: f64) -> f64 {
    z - a * (z / a + 0.5).floor()
}

/// A Moniqua encoder/decoder bound to a quantizer config and a modulo base.
#[derive(Clone, Copy, Debug)]
pub struct MoniquaCodec {
    pub quant: LinearQuantizer,
    pub b_theta: f32,
}

/// Precomputed per-element encode math of Algorithm 1 line 3 — the single
/// source of truth shared by [`MoniquaCodec::encode_into`],
/// [`MoniquaCodec::encode_packed_into`], and the §6 sender digest
/// (`hash::sender_digest`), so a change to the rounding/clamp rules cannot
/// silently drift between the wire path and the verification path.
#[derive(Clone, Copy)]
pub(crate) struct EncodeKernel {
    inv_b: f32,
    l: f32,
    max_code: f32,
    stochastic: bool,
}

impl EncodeKernel {
    #[inline(always)]
    pub(crate) fn stochastic(&self) -> bool {
        self.stochastic
    }

    /// Wrapped code of one element (`u` is the stochastic-rounding draw;
    /// ignored — pass anything — for nearest rounding).
    #[inline(always)]
    pub(crate) fn code(&self, xi: f32, u: f32) -> u32 {
        let z = xi * self.inv_b;
        let w = z - (z + 0.5).floor(); // centered_mod(z, 1)
        let t = if self.stochastic {
            (w + 0.5) * self.l - 0.5 + u
        } else {
            (w + 0.5) * self.l
        };
        // §Perf: clamp on the f32 side (maxss/minss), no i64 round-trip.
        t.floor().max(0.0).min(self.max_code) as u32
    }
}

impl MoniquaCodec {
    /// Build from a θ bound and quantizer config: `B_θ = 2θ/(1−2δ)`.
    /// Requires δ < ½ (1-bit *nearest* qualifies with δ=¼; 1-bit stochastic
    /// has δ=½ and is rejected — the paper's 1-bit mode uses the slack
    /// matrix of Theorem 3 with a nearest/biased quantizer).
    pub fn from_theta(theta: f32, cfg: &QuantConfig) -> Self {
        let q = LinearQuantizer::new(cfg.levels(), cfg.rounding);
        let delta = q.delta();
        assert!(
            delta < 0.5,
            "Moniqua requires delta < 1/2 (got {delta}); use nearest rounding at 1 bit"
        );
        let b = 2.0 * theta as f64 / (1.0 - 2.0 * delta);
        MoniquaCodec { quant: q, b_theta: b as f32 }
    }

    /// Worst-case reconstruction error δ·B_θ (Lemma 2).
    pub fn max_error(&self) -> f32 {
        (self.quant.delta() * self.b_theta as f64) as f32
    }

    /// The shared per-element encode kernel (see [`EncodeKernel`]).
    #[inline]
    pub(crate) fn encode_kernel(&self) -> EncodeKernel {
        EncodeKernel {
            inv_b: 1.0 / self.b_theta,
            l: self.quant.levels as f32,
            max_code: (self.quant.levels - 1) as f32,
            stochastic: matches!(self.quant.rounding, super::Rounding::Stochastic),
        }
    }

    /// Line 3: wrap each coordinate and quantize to codes. `noise` is the
    /// stochastic-rounding stream (shared across workers if configured).
    ///
    /// §Perf: the clamp happens on the f32 side (`max`/`min` lower to
    /// maxss/minss and `as u32` saturates), avoiding the f32→i64→clamp→u32
    /// round-trip of the naive formulation — 3.6× on the 1M-param
    /// microbench (EXPERIMENTS.md §Perf). The `stochastic` branch inside
    /// [`EncodeKernel::code`] is loop-invariant and unswitched by LLVM.
    pub fn encode_into(&self, x: &[f32], noise: &[f32], codes: &mut [u32]) {
        debug_assert_eq!(x.len(), codes.len());
        let ker = self.encode_kernel();
        if ker.stochastic() {
            debug_assert_eq!(noise.len(), x.len());
            for ((c, &xi), &u) in codes.iter_mut().zip(x).zip(noise) {
                *c = ker.code(xi, u);
            }
        } else {
            for (c, &xi) in codes.iter_mut().zip(x) {
                *c = ker.code(xi, 0.0);
            }
        }
    }

    /// Bits per parameter of the bound quantizer (levels = 2^bits always,
    /// by [`QuantConfig`] construction).
    #[inline]
    pub fn bits(&self) -> u32 {
        debug_assert!(self.quant.levels.is_power_of_two());
        self.quant.levels.trailing_zeros()
    }

    /// Fused **line 3 + bit-packing**: wrap, quantize, and write packed
    /// bytes directly into `out` (`out.len() == packed_len(x.len(), bits)`).
    ///
    /// This is the wire path: it produces bit-identical bytes to
    /// `encode_into` followed by [`packing::pack_into`], but never
    /// materializes the intermediate `Vec<u32>` code vector — one pass over
    /// `x`, one pass over `out`. The bit layout is owned entirely by
    /// [`packing::pack_with`]'s word kernels (§Perf): this method only
    /// supplies the per-index quantizer closure, so the fused and unfused
    /// paths cannot diverge on layout.
    pub fn encode_packed_into(&self, x: &[f32], noise: &[f32], out: &mut [u8]) {
        let bits = self.bits();
        assert_eq!(out.len(), packing::packed_len(x.len(), bits));
        let ker = self.encode_kernel();
        // The shared [`EncodeKernel`] guarantees the closure below is
        // bitwise the same computation as `encode_into`; the branch is
        // hoisted so the word kernels see a noise-free closure in nearest
        // mode.
        if ker.stochastic() {
            debug_assert_eq!(noise.len(), x.len());
            packing::pack_with(bits, x.len(), out, |i| ker.code(x[i], noise[i]));
        } else {
            packing::pack_with(bits, x.len(), out, |i| ker.code(x[i], 0.0));
        }
    }

    /// Fused **unpack + line 5**: reconstruct the remote vector straight
    /// from the packed wire bytes, never materializing a `Vec<u32>`.
    /// Bitwise identical to [`packing::unpack_into`] + `recover_into`; the
    /// code stream is read by [`packing::unpack_with`]'s word kernels.
    pub fn recover_packed_into(&self, bytes: &[u8], y: &[f32], out: &mut [f32]) {
        let bits = self.bits();
        debug_assert_eq!(y.len(), out.len());
        assert!(bytes.len() >= packing::packed_len(out.len(), bits));
        let b = self.b_theta;
        let inv_b = 1.0 / b;
        let scale = b / self.quant.levels as f32;
        let off = 0.5 * scale - 0.5 * b;
        // Same per-element recovery math as `recover_into`.
        packing::unpack_with(bits, out.len(), bytes, |i, c| {
            let q = c as f32 * scale + off;
            let z = q - y[i];
            out[i] = z - b * (z * inv_b + 0.5).floor() + y[i];
        });
    }

    /// Dequantized grid value (scaled by B_θ) for a code.
    #[inline]
    pub fn grid(&self, code: u32) -> f32 {
        ((code as f32 + 0.5) / self.quant.levels as f32 - 0.5) * self.b_theta
    }

    /// Line 5: reconstruct the remote vector from codes + the local model y.
    ///
    /// §Perf: `1/B` is hoisted so the centered-mod divide becomes a multiply
    /// (divss is ~4× the latency of mulss and not pipelined as well).
    pub fn recover_into(&self, codes: &[u32], y: &[f32], out: &mut [f32]) {
        debug_assert_eq!(codes.len(), y.len());
        debug_assert_eq!(codes.len(), out.len());
        let b = self.b_theta;
        let inv_b = 1.0 / b;
        let scale = self.b_theta / self.quant.levels as f32;
        let off = 0.5 * scale - 0.5 * b;
        for ((o, &c), &yi) in out.iter_mut().zip(codes).zip(y) {
            let q = c as f32 * scale + off; // grid value scaled by B
            let z = q - yi;
            *o = z - b * (z * inv_b + 0.5).floor() + yi;
        }
    }

    /// Line 4: the sender's own biased term
    /// `x̂_i = g_c·B_θ − centered_mod(x_i, B_θ) + x_i`, fused single pass.
    pub fn local_biased_into(&self, x: &[f32], noise: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), out.len());
        let b = self.b_theta;
        let inv_b = 1.0 / b;
        let l = self.quant.levels as f32;
        let max_code = (self.quant.levels - 1) as f32;
        let scale = b / l;
        let off = 0.5 * scale - 0.5 * b;
        match self.quant.rounding {
            super::Rounding::Nearest => {
                for (o, &xi) in out.iter_mut().zip(x) {
                    let z = xi * inv_b;
                    let zf = (z + 0.5).floor();
                    let w = z - zf;
                    let c = ((w + 0.5) * l).floor().max(0.0).min(max_code);
                    let q = c * scale + off;
                    let xm = xi - b * zf; // centered_mod(x, B) reuses zf
                    *o = q - xm + xi;
                }
            }
            super::Rounding::Stochastic => {
                debug_assert_eq!(noise.len(), x.len());
                for ((o, &xi), &u) in out.iter_mut().zip(x).zip(noise) {
                    let z = xi * inv_b;
                    let zf = (z + 0.5).floor();
                    let w = z - zf;
                    let c = ((w + 0.5) * l - 0.5 + u).floor().max(0.0).min(max_code);
                    let q = c * scale + off;
                    let xm = xi - b * zf;
                    *o = q - xm + xi;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantConfig;
    use crate::testing::{forall, gaussian_vec, uniform};

    #[test]
    fn centered_mod_range_and_congruence() {
        forall(500, |rng| {
            let a = uniform(rng, 0.01, 100.0);
            let z = uniform(rng, -1e4, 1e4);
            let m = centered_mod(z, a);
            assert!((-a / 2.0 - 1e-3..a / 2.0 + 1e-3).contains(&m), "m={m} a={a}");
            let k = (z - m) / a;
            assert!((k - k.round()).abs() < 1e-3 * k.abs().max(1.0), "z={z} a={a}");
        });
    }

    #[test]
    fn lemma1_exact_recovery_f64() {
        forall(500, |rng| {
            let theta = rng.next_f64() * 10.0 + 0.01;
            let y = (rng.next_f64() - 0.5) * 200.0;
            let x = y + (rng.next_f64() - 0.5) * 1.999 * theta;
            let a = 2.0 * theta;
            let rec = centered_mod64(centered_mod64(x, a) - centered_mod64(y, a), a) + y;
            assert!((rec - x).abs() < 1e-9 * x.abs().max(1.0));
        });
    }

    #[test]
    fn lemma2_roundtrip_error_bound() {
        forall(200, |rng| {
            let bits = 2 + rng.below(7) as u32;
            let cfg = QuantConfig::stochastic(bits);
            let theta = uniform(rng, 0.05, 4.0);
            let codec = MoniquaCodec::from_theta(theta, &cfg);
            let n = 1 + rng.below(300) as usize;
            let y = gaussian_vec(rng, n, 5.0);
            let x: Vec<f32> = y
                .iter()
                .map(|&yi| yi + uniform(rng, -0.999, 0.999) * theta)
                .collect();
            let noise: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let mut codes = vec![0u32; n];
            codec.encode_into(&x, &noise, &mut codes);
            let mut xhat = vec![0.0f32; n];
            codec.recover_into(&codes, &y, &mut xhat);
            let bound = codec.max_error() + 1e-4 * codec.b_theta.abs().max(1.0);
            for i in 0..n {
                assert!(
                    (xhat[i] - x[i]).abs() <= bound,
                    "bits={bits} theta={theta} err={} bound={bound}",
                    (xhat[i] - x[i]).abs()
                );
            }
        });
    }

    #[test]
    fn local_biased_matches_composition() {
        // line 4 must equal: grid(encode(x)) - centered_mod(x, B) + x
        forall(100, |rng| {
            let cfg = QuantConfig::stochastic(4);
            let codec = MoniquaCodec::from_theta(1.0, &cfg);
            let n = 1 + rng.below(100) as usize;
            let x = gaussian_vec(rng, n, 3.0);
            let noise: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let mut fused = vec![0.0f32; n];
            codec.local_biased_into(&x, &noise, &mut fused);
            let mut codes = vec![0u32; n];
            codec.encode_into(&x, &noise, &mut codes);
            for i in 0..n {
                let manual = codec.grid(codes[i]) - centered_mod(x[i], codec.b_theta) + x[i];
                assert!((fused[i] - manual).abs() < 1e-5, "i={i}");
            }
        });
    }

    #[test]
    fn local_biased_error_bounded() {
        // |x̂_i − x_i| = |Q(w) − w|·B ≤ δ·B.
        let cfg = QuantConfig::stochastic(8);
        let codec = MoniquaCodec::from_theta(2.0, &cfg);
        let mut rng = crate::rng::Pcg64::seeded(3);
        let x = gaussian_vec(&mut rng, 1000, 10.0);
        let noise: Vec<f32> = (0..1000).map(|_| rng.next_f32()).collect();
        let mut out = vec![0.0f32; 1000];
        codec.local_biased_into(&x, &noise, &mut out);
        for i in 0..1000 {
            assert!((out[i] - x[i]).abs() <= codec.max_error() + 1e-5);
        }
    }

    #[test]
    fn nearest_rounding_supported_at_one_bit() {
        let cfg = QuantConfig::nearest(1);
        let codec = MoniquaCodec::from_theta(1.0, &cfg);
        assert!(codec.quant.delta() < 0.5);
        // Round-trip within bound for |x-y| < θ.
        let y = [0.7f32];
        let x = [1.3f32];
        let mut codes = vec![0u32; 1];
        codec.encode_into(&x, &[], &mut codes);
        let mut xhat = vec![0.0f32; 1];
        codec.recover_into(&codes, &y, &mut xhat);
        assert!((xhat[0] - x[0]).abs() <= codec.max_error() + 1e-6);
    }

    #[test]
    #[should_panic]
    fn one_bit_stochastic_rejected() {
        // δ = 1/2 violates Lemma 2's requirement.
        let cfg = QuantConfig::stochastic(1);
        MoniquaCodec::from_theta(1.0, &cfg);
    }

    #[test]
    fn b_theta_formula() {
        let cfg = QuantConfig::stochastic(8); // δ = 1/256
        let codec = MoniquaCodec::from_theta(1.0, &cfg);
        let expect = 2.0 / (1.0 - 2.0 / 256.0);
        assert!((codec.b_theta - expect as f32).abs() < 1e-6);
    }

    #[test]
    fn encode_packed_matches_encode_then_pack() {
        // The fused wire path must be byte-identical to the two-step path
        // for every supported budget — all 16, so the word kernels' pow2,
        // byte-aligned, and ragged paths are each pinned with tails.
        for bits in 1..=16u32 {
            let cfg = if bits == 1 {
                QuantConfig::nearest(bits) // 1-bit stochastic has δ = ½
            } else {
                QuantConfig::stochastic(bits)
            };
            let codec = MoniquaCodec::from_theta(1.7, &cfg);
            forall(30, |rng| {
                let n = rng.below(300) as usize;
                let x = gaussian_vec(rng, n, 4.0);
                let noise: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
                let mut codes = vec![0u32; n];
                codec.encode_into(&x, &noise, &mut codes);
                let unfused = crate::quant::packing::pack(&codes, bits);
                let mut fused = vec![0u8; crate::quant::packing::packed_len(n, bits)];
                codec.encode_packed_into(&x, &noise, &mut fused);
                assert_eq!(fused, unfused, "bits={bits} n={n}");
            });
        }
    }

    #[test]
    fn recover_packed_matches_unpack_then_recover() {
        for bits in 1..=16u32 {
            let cfg = if bits == 1 {
                QuantConfig::nearest(bits)
            } else {
                QuantConfig::stochastic(bits)
            };
            let codec = MoniquaCodec::from_theta(1.0, &cfg);
            forall(30, |rng| {
                let n = 1 + rng.below(200) as usize;
                let y = gaussian_vec(rng, n, 3.0);
                let codes: Vec<u32> = (0..n)
                    .map(|_| rng.below(codec.quant.levels as u64) as u32)
                    .collect();
                let bytes = crate::quant::packing::pack(&codes, bits);
                let mut unfused = vec![0.0f32; n];
                codec.recover_into(&codes, &y, &mut unfused);
                let mut fused = vec![0.0f32; n];
                codec.recover_packed_into(&bytes, &y, &mut fused);
                // bitwise, not approximate: same float ops in the same order
                assert_eq!(
                    fused.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    unfused.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "bits={bits} n={n}"
                );
            });
        }
    }

    #[test]
    fn packed_roundtrip_respects_lemma2() {
        // End-to-end over the *wire* representation only.
        let cfg = QuantConfig::stochastic(6);
        let codec = MoniquaCodec::from_theta(0.8, &cfg);
        let mut rng = crate::rng::Pcg64::seeded(11);
        let n = 500;
        let y = gaussian_vec(&mut rng, n, 5.0);
        let x: Vec<f32> = y
            .iter()
            .map(|&v| v + (rng.next_f32() - 0.5) * 1.6 * 0.8)
            .collect();
        let noise: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let mut wire = vec![0u8; crate::quant::packing::packed_len(n, 6)];
        codec.encode_packed_into(&x, &noise, &mut wire);
        let mut xhat = vec![0.0f32; n];
        codec.recover_packed_into(&wire, &y, &mut xhat);
        let bound = codec.max_error() + 1e-4;
        for i in 0..n {
            assert!((xhat[i] - x[i]).abs() <= bound);
        }
    }

    #[test]
    fn bits_accessor_matches_config() {
        for bits in [1u32, 3, 8, 16] {
            let cfg = QuantConfig::nearest(bits);
            assert_eq!(MoniquaCodec::from_theta(1.0, &cfg).bits(), bits);
        }
    }

    #[test]
    fn violated_theta_breaks_recovery() {
        // Failure injection: if |x−y| ≥ θ the wrap aliases and recovery is
        // wrong by a multiple of B_θ — this is exactly what the §6 hash
        // verification detects.
        let cfg = QuantConfig::nearest(8);
        let codec = MoniquaCodec::from_theta(0.5, &cfg);
        let y = [0.0f32];
        let x = [10.0f32]; // way beyond θ
        let mut codes = vec![0u32; 1];
        codec.encode_into(&x, &[], &mut codes);
        let mut xhat = vec![0.0f32; 1];
        codec.recover_into(&codes, &y, &mut xhat);
        assert!((xhat[0] - x[0]).abs() > 1.0);
    }
}
