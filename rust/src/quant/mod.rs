//! The quantized-communication stack.
//!
//! This is the paper's object of study and the L3 hot path. Layout:
//!
//! * [`linear`] — linear quantizer on the unit interval `[-1/2, 1/2)`
//!   (nearest + stochastic rounding), semantics **identical** to the Pallas
//!   kernels / `python/compile/kernels/ref.py` (cross-checked in tests).
//! * [`moniqua`] — the centered modulo of Lemma 1 and the wrap → quantize →
//!   recover pipeline of Lemma 2 / Algorithm 1, plus θ→B_θ plumbing. The
//!   round engine's hot path is the **fused** wire pair
//!   [`MoniquaCodec::encode_packed_into`] /
//!   [`MoniquaCodec::recover_packed_into`] (quantize⇄bit-pack in one pass,
//!   no intermediate code vector — DESIGN.md §Engine).
//! * [`packing`] — bit-packing integer codes at 1..=16 bits/parameter via
//!   the §Perf word-level kernels; the fused codec paths feed the same
//!   kernels through per-index closures, so the wire layout has exactly
//!   one implementation (plus a retained byte-accumulator reference).
//! * [`entropy`] — optional lossless recompression of packed code streams
//!   (bzip2 / deflate / in-crate RLE), the paper's §6 "bzip" trick.
//! * [`hash`] — FNV-1a digest of the code stream for the paper's §6
//!   θ-verification method (detects a violated consensus bound).
//! * [`theta`] — θ policies: constant, Theorem-2 formula, tracked-G∞.
//!
//! [`QuantConfig`] bundles rounding mode + bit budget; every algorithm in
//! [`crate::algorithms`] that quantizes takes one.

pub mod entropy;
pub mod hash;
pub mod linear;
pub mod moniqua;
pub mod packing;
pub mod theta;

pub use entropy::Compression;
pub use linear::{dequantize_codes, quantize_codes, LinearQuantizer};
pub use moniqua::{centered_mod, MoniquaCodec};
pub use theta::ThetaTracker;

/// Rounding mode of the linear quantizer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rounding {
    /// Deterministic nearest-point rounding: biased, `δ = 1/(2L)`.
    Nearest,
    /// Unbiased stochastic rounding: `δ = 1/L`. When
    /// `QuantConfig::shared_randomness` is set, all workers draw the same
    /// noise per round (paper §6 — provably smaller pairwise error).
    Stochastic,
}

/// Quantizer configuration shared by all algorithms.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantConfig {
    /// Bits per parameter (1..=16). Levels = 2^bits.
    pub bits: u32,
    pub rounding: Rounding,
    /// Paper §6 shared-randomness trick for stochastic rounding.
    pub shared_randomness: bool,
    /// Optional lossless recompression of the packed stream (§6 "bzip").
    pub compression: Compression,
    /// Attach an FNV digest of the code stream (§6 θ-verification).
    pub verify_hash: bool,
}

impl QuantConfig {
    pub fn stochastic(bits: u32) -> Self {
        assert!((1..=16).contains(&bits), "bits must be 1..=16");
        QuantConfig {
            bits,
            rounding: Rounding::Stochastic,
            shared_randomness: true,
            compression: Compression::None,
            verify_hash: false,
        }
    }

    pub fn nearest(bits: u32) -> Self {
        QuantConfig { rounding: Rounding::Nearest, ..Self::stochastic(bits) }
    }

    pub fn with_shared_randomness(mut self, on: bool) -> Self {
        self.shared_randomness = on;
        self
    }

    pub fn with_compression(mut self, c: Compression) -> Self {
        self.compression = c;
        self
    }

    pub fn with_verify_hash(mut self, on: bool) -> Self {
        self.verify_hash = on;
        self
    }

    /// Number of representable points L.
    pub fn levels(&self) -> u32 {
        1u32 << self.bits
    }

    /// Worst-case quantization error δ on `[-1/2, 1/2)` (assumption (2)).
    pub fn delta(&self) -> f64 {
        match self.rounding {
            Rounding::Nearest => 0.5 / self.levels() as f64,
            Rounding::Stochastic => 1.0 / self.levels() as f64,
        }
    }

    /// Raw payload bytes for `d` parameters (before entropy coding).
    pub fn payload_bytes(&self, d: usize) -> usize {
        packing::packed_len(d, self.bits)
    }
}

/// Additional-memory accounting, reproducing Table 1's comparison. Values
/// are f32 counts *per worker*; multiply by 4 for bytes.
///
/// | algorithm   | extra state                               | total (graph) |
/// |-------------|-------------------------------------------|---------------|
/// | DCD-PSGD    | replica of each neighbor's model          | Θ(m·d)        |
/// | ECD-PSGD    | extrapolated estimate per neighbor        | Θ(m·d)        |
/// | ChocoSGD    | x̂ per neighbor + own x̂                  | Θ(m·d)        |
/// | DeepSqueeze | error accumulator per worker              | Θ(n·d)        |
/// | Moniqua     | —                                         | 0             |
pub fn extra_memory_floats(algorithm: &str, n: usize, m: usize, d: usize) -> usize {
    match algorithm {
        "dcd" | "ecd" => 2 * m * d,          // replica per edge endpoint
        "choco" => 2 * m * d + n * d,        // neighbor estimates + own estimate
        "deepsqueeze" => n * d,              // one error accumulator per worker
        "moniqua" | "dpsgd" | "allreduce" | "d2" | "adpsgd" | "moniqua-d2"
        | "moniqua-adpsgd" => 0,
        other => panic!("unknown algorithm {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_delta_and_levels() {
        let q = QuantConfig::stochastic(8);
        assert_eq!(q.levels(), 256);
        assert!((q.delta() - 1.0 / 256.0).abs() < 1e-12);
        let qn = QuantConfig::nearest(8);
        assert!((qn.delta() - 0.5 / 256.0).abs() < 1e-12);
    }

    #[test]
    fn one_bit_is_supported() {
        let q = QuantConfig::stochastic(1);
        assert_eq!(q.levels(), 2);
        assert_eq!(q.payload_bytes(8), 1);
    }

    #[test]
    #[should_panic]
    fn zero_bits_rejected() {
        QuantConfig::stochastic(0);
    }

    #[test]
    fn memory_table_matches_table1() {
        // n=8 ring: m=8 edges, d arbitrary.
        let (n, m, d) = (8, 8, 1000);
        assert_eq!(extra_memory_floats("moniqua", n, m, d), 0);
        assert_eq!(extra_memory_floats("dpsgd", n, m, d), 0);
        assert_eq!(extra_memory_floats("dcd", n, m, d), 2 * m * d);
        assert_eq!(extra_memory_floats("ecd", n, m, d), 2 * m * d);
        assert!(extra_memory_floats("choco", n, m, d) >= 2 * m * d);
        assert_eq!(extra_memory_floats("deepsqueeze", n, m, d), n * d);
        // Ordering of Table 2's "extra memory" column:
        assert!(extra_memory_floats("deepsqueeze", n, m, d)
            < extra_memory_floats("choco", n, m, d));
    }

    #[test]
    fn payload_scales_with_bits() {
        let d = 1000;
        assert_eq!(QuantConfig::stochastic(8).payload_bytes(d), 1000);
        assert_eq!(QuantConfig::stochastic(4).payload_bytes(d), 500);
        assert_eq!(QuantConfig::stochastic(1).payload_bytes(d), 125);
    }
}
