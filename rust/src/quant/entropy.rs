//! Lossless recompression of packed code streams (paper §6, "use a standard
//! entropy compressor like bzip to further compress the communicated
//! tensors").
//!
//! Near consensus, the modulo-wrapped values concentrate around 0, so the
//! high-order bits of each code are heavily redundant; a generic entropy
//! coder removes them. We expose bzip2 (the paper's choice, behind the
//! `bzip2` cargo feature), DEFLATE (cheaper, behind `deflate`), and an
//! in-crate order-0 RLE that is always available; `None` disables
//! recompression. The external codecs are feature-gated so the default
//! build works in fully offline environments; selecting a disabled codec
//! panics with a clear message (the config layer rejects it earlier with a
//! proper error).

#[cfg(any(feature = "deflate", feature = "bzip2"))]
use std::io::{Read, Write};

/// Compression codec applied to the packed byte stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Compression {
    None,
    /// DEFLATE (flate2), level 6.
    Deflate,
    /// bzip2, level 6 — the paper's suggestion.
    Bzip2,
    /// In-crate byte-level run-length coding (escape 0xFF).
    Rle,
}

impl Compression {
    /// The codecs this build supports (always `None` + `Rle`; `Deflate` /
    /// `Bzip2` when their cargo features are enabled). Benches and tests
    /// iterate this instead of hard-coding the full set.
    pub fn enabled() -> Vec<Compression> {
        #[allow(unused_mut)]
        let mut v = vec![Compression::None, Compression::Rle];
        #[cfg(feature = "deflate")]
        v.push(Compression::Deflate);
        #[cfg(feature = "bzip2")]
        v.push(Compression::Bzip2);
        v
    }

    pub fn compress(&self, data: &[u8]) -> Vec<u8> {
        match self {
            Compression::None => data.to_vec(),
            #[cfg(feature = "deflate")]
            Compression::Deflate => {
                let mut enc = flate2::write::DeflateEncoder::new(
                    Vec::new(),
                    flate2::Compression::new(6),
                );
                enc.write_all(data).expect("deflate write");
                enc.finish().expect("deflate finish")
            }
            #[cfg(not(feature = "deflate"))]
            Compression::Deflate => {
                panic!("DEFLATE support not compiled in (enable the `deflate` feature)")
            }
            #[cfg(feature = "bzip2")]
            Compression::Bzip2 => {
                let mut enc = bzip2::write::BzEncoder::new(
                    Vec::new(),
                    bzip2::Compression::new(6),
                );
                enc.write_all(data).expect("bzip2 write");
                enc.finish().expect("bzip2 finish")
            }
            #[cfg(not(feature = "bzip2"))]
            Compression::Bzip2 => {
                panic!("bzip2 support not compiled in (enable the `bzip2` feature)")
            }
            Compression::Rle => rle_encode(data),
        }
    }

    pub fn decompress(&self, data: &[u8]) -> Vec<u8> {
        match self {
            Compression::None => data.to_vec(),
            #[cfg(feature = "deflate")]
            Compression::Deflate => {
                let mut dec = flate2::read::DeflateDecoder::new(data);
                let mut out = Vec::new();
                dec.read_to_end(&mut out).expect("deflate read");
                out
            }
            #[cfg(not(feature = "deflate"))]
            Compression::Deflate => {
                panic!("DEFLATE support not compiled in (enable the `deflate` feature)")
            }
            #[cfg(feature = "bzip2")]
            Compression::Bzip2 => {
                let mut dec = bzip2::read::BzDecoder::new(data);
                let mut out = Vec::new();
                dec.read_to_end(&mut out).expect("bzip2 read");
                out
            }
            #[cfg(not(feature = "bzip2"))]
            Compression::Bzip2 => {
                panic!("bzip2 support not compiled in (enable the `bzip2` feature)")
            }
            Compression::Rle => rle_decode(data),
        }
    }

    /// Wire size for a payload under this codec (compression may *expand*
    /// incompressible data; the network layer charges the real size).
    ///
    /// Cold for the hot-path lint: recompression is opt-in and explicitly
    /// outside the zero-alloc steady-state contract
    /// (`tests/alloc_discipline.rs` runs with `Compression::None`), so the
    /// allocating codec calls behind it are not hot-path violations.
    // lint: cold
    pub fn wire_len(&self, data: &[u8]) -> usize {
        match self {
            Compression::None => data.len(),
            _ => self.compress(data).len(),
        }
    }
}

const RLE_ESCAPE: u8 = 0xFF;

/// Byte RLE: runs of length >= 4 (or any run of the escape byte) are coded
/// as `ESC, byte, len`; other bytes are literal; a literal escape byte is
/// `ESC, ESC, 1`.
fn rle_encode(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity((data.len() / 2).saturating_add(8));
    let mut i = 0;
    while i < data.len() {
        let b = data[i];
        let mut run = 1usize;
        while i + run < data.len() && data[i + run] == b && run < 254 {
            run += 1;
        }
        if run >= 4 || b == RLE_ESCAPE {
            out.push(RLE_ESCAPE);
            out.push(b);
            let run_byte = match u8::try_from(run) {
                Ok(v) => v,
                // Unreachable: the scan loop caps run at 254.
                Err(_) => unreachable!("RLE run exceeds a byte"),
            };
            out.push(run_byte);
        } else {
            for _ in 0..run {
                out.push(b);
            }
        }
        i += run;
    }
    out
}

fn rle_decode(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len().saturating_mul(2));
    let mut i = 0;
    while i < data.len() {
        if data[i] == RLE_ESCAPE {
            assert!(i + 2 < data.len(), "truncated RLE stream");
            let b = data[i + 1];
            let run = data[i + 2] as usize;
            out.extend(std::iter::repeat(b).take(run));
            i += 3;
        } else {
            out.push(data[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;

    #[test]
    fn roundtrip_random_data() {
        forall(40, |rng| {
            let n = rng.below(2000) as usize;
            let data: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
            for c in Compression::enabled() {
                assert_eq!(c.decompress(&c.compress(&data)), data, "{c:?}");
            }
        });
    }

    #[test]
    fn roundtrip_runs_and_escapes() {
        let mut data = vec![7u8; 1000];
        data.extend([0xFF, 0xFF, 0xFF, 1, 2, 3, 0xFF]);
        for c in Compression::enabled() {
            assert_eq!(c.decompress(&c.compress(&data)), data, "{c:?}");
        }
    }

    #[test]
    fn compressors_shrink_redundant_streams() {
        // Near-consensus modulo streams: most codes equal -> long runs.
        let data = vec![128u8; 64 * 1024];
        for c in Compression::enabled() {
            if c == Compression::None {
                continue;
            }
            let z = c.compress(&data);
            assert!(z.len() < data.len() / 8, "{c:?}: {} bytes", z.len());
        }
    }

    #[test]
    fn wire_len_matches_compressed_len() {
        let data = vec![5u8; 4096];
        for c in Compression::enabled() {
            assert_eq!(c.wire_len(&data), c.compress(&data).len());
        }
    }

    #[test]
    fn empty_input_ok() {
        for c in Compression::enabled() {
            assert_eq!(c.decompress(&c.compress(&[])), Vec::<u8>::new());
        }
    }

    #[test]
    fn enabled_always_includes_dependency_free_codecs() {
        let e = Compression::enabled();
        assert!(e.contains(&Compression::None));
        assert!(e.contains(&Compression::Rle));
    }
}
