//! Lossless recompression of packed code streams (paper §6, "use a standard
//! entropy compressor like bzip to further compress the communicated
//! tensors").
//!
//! Near consensus, the modulo-wrapped values concentrate around 0, so the
//! high-order bits of each code are heavily redundant; a generic entropy
//! coder removes them. We expose bzip2 (the paper's choice), DEFLATE
//! (cheaper), and an in-crate order-0 RLE for dependency-free use; `None`
//! disables recompression.

use std::io::{Read, Write};

/// Compression codec applied to the packed byte stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Compression {
    None,
    /// DEFLATE (flate2), level 6.
    Deflate,
    /// bzip2, level 6 — the paper's suggestion.
    Bzip2,
    /// In-crate byte-level run-length coding (escape 0xFF).
    Rle,
}

impl Compression {
    pub fn compress(&self, data: &[u8]) -> Vec<u8> {
        match self {
            Compression::None => data.to_vec(),
            Compression::Deflate => {
                let mut enc = flate2::write::DeflateEncoder::new(
                    Vec::new(),
                    flate2::Compression::new(6),
                );
                enc.write_all(data).expect("deflate write");
                enc.finish().expect("deflate finish")
            }
            Compression::Bzip2 => {
                let mut enc = bzip2::write::BzEncoder::new(
                    Vec::new(),
                    bzip2::Compression::new(6),
                );
                enc.write_all(data).expect("bzip2 write");
                enc.finish().expect("bzip2 finish")
            }
            Compression::Rle => rle_encode(data),
        }
    }

    pub fn decompress(&self, data: &[u8]) -> Vec<u8> {
        match self {
            Compression::None => data.to_vec(),
            Compression::Deflate => {
                let mut dec = flate2::read::DeflateDecoder::new(data);
                let mut out = Vec::new();
                dec.read_to_end(&mut out).expect("deflate read");
                out
            }
            Compression::Bzip2 => {
                let mut dec = bzip2::read::BzDecoder::new(data);
                let mut out = Vec::new();
                dec.read_to_end(&mut out).expect("bzip2 read");
                out
            }
            Compression::Rle => rle_decode(data),
        }
    }

    /// Wire size for a payload under this codec (compression may *expand*
    /// incompressible data; the network layer charges the real size).
    pub fn wire_len(&self, data: &[u8]) -> usize {
        match self {
            Compression::None => data.len(),
            _ => self.compress(data).len(),
        }
    }
}

const RLE_ESCAPE: u8 = 0xFF;

/// Byte RLE: runs of length >= 4 (or any run of the escape byte) are coded
/// as `ESC, byte, len`; other bytes are literal; a literal escape byte is
/// `ESC, ESC, 1`.
fn rle_encode(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 2 + 8);
    let mut i = 0;
    while i < data.len() {
        let b = data[i];
        let mut run = 1usize;
        while i + run < data.len() && data[i + run] == b && run < 254 {
            run += 1;
        }
        if run >= 4 || b == RLE_ESCAPE {
            out.push(RLE_ESCAPE);
            out.push(b);
            out.push(run as u8);
        } else {
            for _ in 0..run {
                out.push(b);
            }
        }
        i += run;
    }
    out
}

fn rle_decode(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 2);
    let mut i = 0;
    while i < data.len() {
        if data[i] == RLE_ESCAPE {
            assert!(i + 2 < data.len(), "truncated RLE stream");
            let b = data[i + 1];
            let run = data[i + 2] as usize;
            out.extend(std::iter::repeat(b).take(run));
            i += 3;
        } else {
            out.push(data[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;

    const ALL: [Compression; 4] = [
        Compression::None,
        Compression::Deflate,
        Compression::Bzip2,
        Compression::Rle,
    ];

    #[test]
    fn roundtrip_random_data() {
        forall(40, |rng| {
            let n = rng.below(2000) as usize;
            let data: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
            for c in ALL {
                assert_eq!(c.decompress(&c.compress(&data)), data, "{c:?}");
            }
        });
    }

    #[test]
    fn roundtrip_runs_and_escapes() {
        let mut data = vec![7u8; 1000];
        data.extend([0xFF, 0xFF, 0xFF, 1, 2, 3, 0xFF]);
        for c in ALL {
            assert_eq!(c.decompress(&c.compress(&data)), data, "{c:?}");
        }
    }

    #[test]
    fn compressors_shrink_redundant_streams() {
        // Near-consensus modulo streams: most codes equal -> long runs.
        let data = vec![128u8; 64 * 1024];
        for c in [Compression::Deflate, Compression::Bzip2, Compression::Rle] {
            let z = c.compress(&data);
            assert!(z.len() < data.len() / 8, "{c:?}: {} bytes", z.len());
        }
    }

    #[test]
    fn wire_len_matches_compressed_len() {
        let data = vec![5u8; 4096];
        for c in ALL {
            assert_eq!(c.wire_len(&data), c.compress(&data).len());
        }
    }

    #[test]
    fn empty_input_ok() {
        for c in ALL {
            assert_eq!(c.decompress(&c.compress(&[])), Vec::<u8>::new());
        }
    }
}
