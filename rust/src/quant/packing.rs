//! Bit-packing of quantizer codes — the §Perf word-level kernels.
//!
//! Messages on the wire carry `bits` bits per parameter, so a d-parameter
//! tensor costs `ceil(d*bits/8)` bytes — this is what the network simulator
//! charges and what the entropy coder recompresses.
//!
//! ## Layout contract
//!
//! The stream is one continuous **little-endian bit stream**: code `i`
//! occupies bits `[i·bits, (i+1)·bits)` counted LSB-first from byte 0, and
//! the sub-byte tail is zero-padded. This layout is the wire-format
//! contract both sides must honor; it is pinned by the retained reference
//! implementation ([`pack_into_ref`] / [`unpack_into_ref`] — the original
//! byte-at-a-time accumulator) and by the fused-vs-unfused equality tests
//! in `quant::moniqua` plus the exhaustive tail suite in
//! `tests/quant_properties.rs`.
//!
//! ## Kernels (§Perf)
//!
//! The hot kernels move whole 64-bit words instead of single bytes:
//!
//! * **bits ∈ {8, 16}** — byte/halfword memcpy loops (no accumulator);
//! * **bits ∈ {1, 2, 4}** — a fixed `64/bits` codes-per-word inner loop
//!   (branchless shift-or into a `u64`, one 8-byte store per word; the
//!   constant trip count lets LLVM fully unroll it). 1-bit is the paper's
//!   headline Table-2 configuration;
//! * **ragged widths (3, 5, 6, 7, 9..15)** — a two-word `u128` staging
//!   accumulator: codes shift-or into the low word, and every time 64 bits
//!   are ready one 8-byte store (or load, on the unpack side) moves a whole
//!   word via `chunks_exact`. At most `⌈64/bits⌉+1` codes are staged, so
//!   the accumulator never overflows 80 bits.
//!
//! Sub-word tails fall back to the byte accumulator, which is also the
//! retained reference the property tests cross-check every width × tail
//! combination against.
//!
//! On the round-engine hot path these kernels are shared with the fused
//! codec (`MoniquaCodec::encode_packed_into` / `recover_packed_into`)
//! through [`pack_with`] / [`unpack_with`]: the codec supplies a
//! per-index code source/sink closure, so the wire layout exists in
//! exactly one place.

/// Packed byte length for `d` codes at `bits` bits each, or `None` when
/// `d * bits` overflows `usize` (a >2-exabit message on 64-bit targets —
/// only reachable through corrupt/hostile configuration, but the old
/// unchecked multiply would silently wrap to a tiny buffer).
#[inline]
pub fn try_packed_len(d: usize, bits: u32) -> Option<usize> {
    d.checked_mul(bits as usize)?.checked_add(7).map(|b| b / 8)
}

/// Packed byte length for `d` codes at `bits` bits each.
///
/// Panics (rather than wrapping) when `d * bits` overflows `usize`; use
/// [`try_packed_len`] to handle untrusted dimensions gracefully.
#[inline]
pub fn packed_len(d: usize, bits: u32) -> usize {
    try_packed_len(d, bits)
        .unwrap_or_else(|| panic!("packed_len overflows usize: d={d} bits={bits}"))
}

/// Pack `codes` (each `< 2^bits`) into bytes.
pub fn pack(codes: &[u32], bits: u32) -> Vec<u8> {
    let mut out = vec![0u8; packed_len(codes.len(), bits)];
    pack_into(codes, bits, &mut out);
    out
}

/// Pack into a preallocated buffer (must be exactly `packed_len` long).
pub fn pack_into(codes: &[u32], bits: u32, out: &mut [u8]) {
    assert!((1..=16).contains(&bits));
    assert_eq!(out.len(), packed_len(codes.len(), bits));
    debug_assert!(codes.iter().all(|&c| (c as u64) < (1u64 << bits)));
    pack_with(bits, codes.len(), out, |i| codes[i]);
}

/// Unpack `d` codes of `bits` bits from `bytes`.
pub fn unpack(bytes: &[u8], bits: u32, d: usize) -> Vec<u32> {
    let mut out = vec![0u32; d];
    unpack_into(bytes, bits, &mut out);
    out
}

/// Unpack into a preallocated buffer.
pub fn unpack_into(bytes: &[u8], bits: u32, out: &mut [u32]) {
    assert!((1..=16).contains(&bits));
    assert!(bytes.len() >= packed_len(out.len(), bits));
    unpack_with(bits, out.len(), bytes, |i, c| out[i] = c);
}

// ---------------------------------------------------------------------------
// Streaming word kernels (shared with the fused codec paths)
// ---------------------------------------------------------------------------

/// Pack `n` codes produced by `code_at(i)` (called once per index, `i`
/// ascending) into `out` (`out.len() == packed_len(n, bits)`). This is the
/// single wire-layout implementation: `pack_into` feeds it from a slice,
/// the fused `MoniquaCodec::encode_packed_into` feeds it straight from the
/// quantizer so no intermediate code vector ever exists.
#[inline]
pub(crate) fn pack_with<F: FnMut(usize) -> u32>(
    bits: u32,
    n: usize,
    out: &mut [u8],
    mut code_at: F,
) {
    debug_assert!((1..=16).contains(&bits));
    debug_assert_eq!(out.len(), packed_len(n, bits));
    match bits {
        8 => {
            for (i, o) in out.iter_mut().enumerate() {
                *o = code_at(i) as u8;
            }
        }
        16 => {
            for (i, o) in out.chunks_exact_mut(2).enumerate() {
                o.copy_from_slice(&(code_at(i) as u16).to_le_bytes());
            }
        }
        1 | 2 | 4 => pack_pow2(bits, n, out, code_at),
        _ => pack_ragged(bits, n, out, code_at),
    }
}

/// Unpack `n` codes from `bytes` into `sink(i, code)` (called once per
/// index, `i` ascending). `bytes` may be longer than the packed length;
/// only the first `packed_len(n, bits)` bytes are consumed.
#[inline]
pub(crate) fn unpack_with<F: FnMut(usize, u32)>(
    bits: u32,
    n: usize,
    bytes: &[u8],
    mut sink: F,
) {
    debug_assert!((1..=16).contains(&bits));
    debug_assert!(bytes.len() >= packed_len(n, bits));
    match bits {
        8 => {
            for (i, &b) in bytes.iter().take(n).enumerate() {
                sink(i, b as u32);
            }
        }
        16 => {
            for (i, c) in bytes.chunks_exact(2).take(n).enumerate() {
                sink(i, u16::from_le_bytes([c[0], c[1]]) as u32);
            }
        }
        1 | 2 | 4 => unpack_pow2(bits, n, bytes, sink),
        _ => unpack_ragged(bits, n, bytes, sink),
    }
}

/// Word kernel for the power-of-two sub-byte widths: exactly `64/bits`
/// codes per `u64`, branchless shift-or, one 8-byte store per word.
fn pack_pow2<F: FnMut(usize) -> u32>(bits: u32, n: usize, out: &mut [u8], mut code_at: F) {
    let cpw = (64 / bits) as usize;
    let full = n / cpw;
    let mut i = 0usize;
    for ob in out[..full * 8].chunks_exact_mut(8) {
        let mut word = 0u64;
        for k in 0..cpw {
            word |= (code_at(i + k) as u64) << (k as u32 * bits);
        }
        ob.copy_from_slice(&word.to_le_bytes());
        i += cpw;
    }
    pack_tail(bits, i, n, &mut out[full * 8..], code_at);
}

fn unpack_pow2<F: FnMut(usize, u32)>(bits: u32, n: usize, bytes: &[u8], mut sink: F) {
    let cpw = (64 / bits) as usize;
    let mask = (1u64 << bits) - 1;
    let full = n / cpw;
    let mut i = 0usize;
    for wb in bytes[..full * 8].chunks_exact(8) {
        let mut word = u64::from_le_bytes(wb.try_into().expect("8-byte chunk"));
        for k in 0..cpw {
            sink(i + k, (word & mask) as u32);
            word >>= bits;
        }
        i += cpw;
    }
    unpack_tail(bits, i, n, &bytes[full * 8..], sink);
}

/// Two-word staging kernel for the ragged widths: codes shift-or into a
/// `u128` and every complete low word leaves as one 8-byte store. The
/// accumulator holds < 64 + bits ≤ 80 bits at any time, so the widest
/// shift is `< 64 + 16 < 128`.
fn pack_ragged<F: FnMut(usize) -> u32>(bits: u32, n: usize, out: &mut [u8], mut code_at: F) {
    let mut acc: u128 = 0;
    let mut nb: u32 = 0;
    let mut o = 0usize;
    let mut i = 0usize;
    while i < n {
        while nb < 64 && i < n {
            acc |= (code_at(i) as u128) << nb;
            nb += bits;
            i += 1;
        }
        while nb >= 64 {
            out[o..o + 8].copy_from_slice(&(acc as u64).to_le_bytes());
            o += 8;
            acc >>= 64;
            nb -= 64;
        }
    }
    // flush the sub-word tail byte by byte (zero-padded high bits)
    while nb > 0 {
        out[o] = acc as u8;
        o += 1;
        acc >>= 8;
        nb = nb.saturating_sub(8);
    }
    debug_assert_eq!(o, out.len());
}

fn unpack_ragged<F: FnMut(usize, u32)>(bits: u32, n: usize, bytes: &[u8], mut sink: F) {
    // Bound whole-word loads by the bytes the n codes actually occupy:
    // `bytes` is allowed to be longer, and the tail refill below must read
    // exactly the reference implementation's bytes.
    let used = packed_len(n, bits);
    let mask: u128 = (1u128 << bits) - 1;
    let mut acc: u128 = 0;
    let mut nb: u32 = 0;
    let mut o = 0usize;
    for i in 0..n {
        if nb < bits {
            if o + 8 <= used {
                let w = u64::from_le_bytes(bytes[o..o + 8].try_into().expect("8-byte chunk"));
                acc |= (w as u128) << nb;
                o += 8;
                nb += 64;
            } else {
                while nb < bits {
                    acc |= (bytes[o] as u128) << nb;
                    o += 1;
                    nb += 8;
                }
            }
        }
        sink(i, (acc & mask) as u32);
        acc >>= bits;
        nb -= bits;
    }
}

/// Byte-accumulator tail for the word kernels: packs codes `start..n` into
/// `out` (the bytes after the last whole word). Same code as the reference
/// implementation, so word path + tail ≡ reference end to end.
fn pack_tail<F: FnMut(usize) -> u32>(
    bits: u32,
    start: usize,
    n: usize,
    out: &mut [u8],
    mut code_at: F,
) {
    let mut acc: u64 = 0;
    let mut nb: u32 = 0;
    let mut o = 0usize;
    for i in start..n {
        acc |= (code_at(i) as u64) << nb;
        nb += bits;
        while nb >= 8 {
            out[o] = acc as u8;
            o += 1;
            acc >>= 8;
            nb -= 8;
        }
    }
    if nb > 0 {
        out[o] = acc as u8;
    }
}

fn unpack_tail<F: FnMut(usize, u32)>(
    bits: u32,
    start: usize,
    n: usize,
    bytes: &[u8],
    mut sink: F,
) {
    let mask: u64 = (1u64 << bits) - 1;
    let mut acc: u64 = 0;
    let mut nb: u32 = 0;
    let mut o = 0usize;
    for i in start..n {
        while nb < bits {
            acc |= (bytes[o] as u64) << nb;
            o += 1;
            nb += 8;
        }
        sink(i, (acc & mask) as u32);
        acc >>= bits;
        nb -= bits;
    }
}

// ---------------------------------------------------------------------------
// Retained reference implementation (the wire-layout source of truth)
// ---------------------------------------------------------------------------

/// The original byte-at-a-time accumulator packer, retained verbatim as the
/// executable definition of the wire layout. The word kernels must produce
/// byte-identical output (pinned exhaustively — every `bits` × tail length
/// — by `tests/quant_properties.rs`); the throughput bench reports the
/// word kernels' speedup over this.
pub fn pack_into_ref(codes: &[u32], bits: u32, out: &mut [u8]) {
    assert!((1..=16).contains(&bits));
    assert_eq!(out.len(), packed_len(codes.len(), bits));
    let mut acc: u64 = 0;
    let mut nbits: u32 = 0;
    let mut o = 0usize;
    for &c in codes {
        acc |= (c as u64) << nbits;
        nbits += bits;
        while nbits >= 8 {
            out[o] = acc as u8;
            o += 1;
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out[o] = acc as u8;
    }
}

/// Reference unpacker paired with [`pack_into_ref`].
pub fn unpack_into_ref(bytes: &[u8], bits: u32, out: &mut [u32]) {
    assert!((1..=16).contains(&bits));
    assert!(bytes.len() >= packed_len(out.len(), bits));
    let mask: u64 = (1u64 << bits) - 1;
    let mut acc: u64 = 0;
    let mut nbits: u32 = 0;
    let mut i = 0usize;
    for o in out.iter_mut() {
        while nbits < bits {
            acc |= (bytes[i] as u64) << nbits;
            i += 1;
            nbits += 8;
        }
        *o = (acc & mask) as u32;
        acc >>= bits;
        nbits -= bits;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;

    #[test]
    fn roundtrip_all_bit_widths() {
        forall(200, |rng| {
            let bits = 1 + rng.below(16) as u32;
            let d = rng.below(400) as usize;
            let codes: Vec<u32> = (0..d)
                .map(|_| (rng.next_u32() as u64 & ((1u64 << bits) - 1)) as u32)
                .collect();
            let bytes = pack(&codes, bits);
            assert_eq!(bytes.len(), packed_len(d, bits));
            let back = unpack(&bytes, bits, d);
            assert_eq!(codes, back);
        });
    }

    #[test]
    fn word_kernels_match_reference_bytes() {
        // The word kernels must be byte-identical to the retained reference
        // accumulator (the exhaustive bits × tail matrix lives in
        // tests/quant_properties.rs; this is the in-module smoke version).
        forall(200, |rng| {
            let bits = 1 + rng.below(16) as u32;
            let d = rng.below(600) as usize;
            let codes: Vec<u32> = (0..d)
                .map(|_| (rng.next_u32() as u64 & ((1u64 << bits) - 1)) as u32)
                .collect();
            let mut word = vec![0u8; packed_len(d, bits)];
            let mut byte = vec![0u8; packed_len(d, bits)];
            pack_into(&codes, bits, &mut word);
            pack_into_ref(&codes, bits, &mut byte);
            assert_eq!(word, byte, "bits={bits} d={d}");
            let mut back_word = vec![0u32; d];
            let mut back_byte = vec![0u32; d];
            unpack_into(&word, bits, &mut back_word);
            unpack_into_ref(&byte, bits, &mut back_byte);
            assert_eq!(back_word, codes, "bits={bits} d={d}");
            assert_eq!(back_byte, codes, "bits={bits} d={d}");
        });
    }

    #[test]
    fn unpack_tolerates_oversized_byte_slices() {
        // recover paths hand the whole payload in; trailing bytes beyond
        // packed_len(n) must be ignored, not folded into codes.
        forall(100, |rng| {
            let bits = 1 + rng.below(16) as u32;
            let d = rng.below(200) as usize;
            let codes: Vec<u32> = (0..d)
                .map(|_| (rng.next_u32() as u64 & ((1u64 << bits) - 1)) as u32)
                .collect();
            let mut bytes = pack(&codes, bits);
            for _ in 0..(rng.below(16) as usize) {
                bytes.push(rng.next_u32() as u8); // garbage tail
            }
            assert_eq!(unpack(&bytes, bits, d), codes, "bits={bits} d={d}");
        });
    }

    #[test]
    fn packed_len_exact() {
        assert_eq!(packed_len(8, 1), 1);
        assert_eq!(packed_len(9, 1), 2);
        assert_eq!(packed_len(3, 8), 3);
        assert_eq!(packed_len(5, 3), 2); // 15 bits -> 2 bytes
        assert_eq!(packed_len(0, 7), 0);
    }

    #[test]
    fn packed_len_overflow_is_checked() {
        // d * bits wraps in the old formulation; now it is a typed None /
        // loud panic instead of a silently tiny buffer.
        assert_eq!(try_packed_len(usize::MAX, 2), None);
        assert_eq!(try_packed_len(usize::MAX / 16, 16), Some(usize::MAX / 16 * 2));
        assert!(std::panic::catch_unwind(|| packed_len(usize::MAX, 3)).is_err());
    }

    #[test]
    fn empty_roundtrip() {
        let bytes = pack(&[], 5);
        assert!(bytes.is_empty());
        assert_eq!(unpack(&bytes, 5, 0), Vec::<u32>::new());
    }

    #[test]
    fn one_bit_bit_layout() {
        // codes 1,0,1,1,0,0,0,1 -> little-endian bit order -> 0b1000_1101
        let bytes = pack(&[1, 0, 1, 1, 0, 0, 0, 1], 1);
        assert_eq!(bytes, vec![0b1000_1101]);
    }

    #[test]
    fn one_bit_word_boundary_layout() {
        // 65 one-bits: a full u64 word of 1s plus a 1-bit tail — the word
        // store and the tail byte must butt-join with no gap or overlap.
        let codes = vec![1u32; 65];
        let bytes = pack(&codes, 1);
        assert_eq!(bytes.len(), 9);
        assert_eq!(&bytes[..8], &[0xFF; 8]);
        assert_eq!(bytes[8], 0x01);
    }

    #[test]
    fn cross_width_no_interference() {
        // Adjacent 3-bit codes must not leak into each other.
        let codes = vec![0b101u32, 0b010, 0b111, 0b001];
        let back = unpack(&pack(&codes, 3), 3, 4);
        assert_eq!(back, codes);
    }
}
