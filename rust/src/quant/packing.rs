//! Bit-packing of quantizer codes.
//!
//! Messages on the wire carry `bits` bits per parameter, so a d-parameter
//! tensor costs `ceil(d*bits/8)` bytes — this is what the network simulator
//! charges and what the entropy coder recompresses. The packer writes codes
//! little-endian into a u64 accumulator; the hot loop is branch-light and is
//! one of the targets of the §Perf pass.
//!
//! On the round-engine hot path these standalone functions are inlined
//! into the fused codec kernels (`MoniquaCodec::encode_packed_into` /
//! `recover_packed_into`); the bit layout here is the wire-format contract
//! both sides must honor (pinned by the fused-vs-unfused equality tests in
//! `quant::moniqua`).

/// Packed byte length for `d` codes at `bits` bits each.
#[inline]
pub fn packed_len(d: usize, bits: u32) -> usize {
    (d * bits as usize + 7) / 8
}

/// Pack `codes` (each `< 2^bits`) into bytes.
pub fn pack(codes: &[u32], bits: u32) -> Vec<u8> {
    let mut out = vec![0u8; packed_len(codes.len(), bits)];
    pack_into(codes, bits, &mut out);
    out
}

/// Pack into a preallocated buffer (must be exactly `packed_len` long).
pub fn pack_into(codes: &[u32], bits: u32, out: &mut [u8]) {
    assert!((1..=16).contains(&bits));
    assert_eq!(out.len(), packed_len(codes.len(), bits));
    debug_assert!(codes.iter().all(|&c| (c as u64) < (1u64 << bits)));
    // §Perf: byte-aligned budgets skip the bit accumulator entirely
    // (the 8-bit case is the paper's main experimental configuration).
    if bits == 8 {
        for (o, &c) in out.iter_mut().zip(codes) {
            *o = c as u8;
        }
        return;
    }
    if bits == 16 {
        for (o, &c) in out.chunks_exact_mut(2).zip(codes) {
            o.copy_from_slice(&(c as u16).to_le_bytes());
        }
        return;
    }
    let mut acc: u64 = 0;
    let mut nbits: u32 = 0;
    let mut o = 0usize;
    for &c in codes {
        acc |= (c as u64) << nbits;
        nbits += bits;
        while nbits >= 8 {
            out[o] = acc as u8;
            o += 1;
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out[o] = acc as u8;
    }
}

/// Unpack `d` codes of `bits` bits from `bytes`.
pub fn unpack(bytes: &[u8], bits: u32, d: usize) -> Vec<u32> {
    let mut out = vec![0u32; d];
    unpack_into(bytes, bits, &mut out);
    out
}

/// Unpack into a preallocated buffer.
pub fn unpack_into(bytes: &[u8], bits: u32, out: &mut [u32]) {
    assert!((1..=16).contains(&bits));
    assert!(bytes.len() >= packed_len(out.len(), bits));
    if bits == 8 {
        for (o, &b) in out.iter_mut().zip(bytes) {
            *o = b as u32;
        }
        return;
    }
    if bits == 16 {
        for (o, b) in out.iter_mut().zip(bytes.chunks_exact(2)) {
            *o = u16::from_le_bytes([b[0], b[1]]) as u32;
        }
        return;
    }
    let mask: u64 = (1u64 << bits) - 1;
    let mut acc: u64 = 0;
    let mut nbits: u32 = 0;
    let mut i = 0usize;
    for o in out.iter_mut() {
        while nbits < bits {
            acc |= (bytes[i] as u64) << nbits;
            i += 1;
            nbits += 8;
        }
        *o = (acc & mask) as u32;
        acc >>= bits;
        nbits -= bits;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;

    #[test]
    fn roundtrip_all_bit_widths() {
        forall(200, |rng| {
            let bits = 1 + rng.below(16) as u32;
            let d = rng.below(400) as usize;
            let codes: Vec<u32> = (0..d)
                .map(|_| (rng.next_u32() as u64 & ((1u64 << bits) - 1)) as u32)
                .collect();
            let bytes = pack(&codes, bits);
            assert_eq!(bytes.len(), packed_len(d, bits));
            let back = unpack(&bytes, bits, d);
            assert_eq!(codes, back);
        });
    }

    #[test]
    fn packed_len_exact() {
        assert_eq!(packed_len(8, 1), 1);
        assert_eq!(packed_len(9, 1), 2);
        assert_eq!(packed_len(3, 8), 3);
        assert_eq!(packed_len(5, 3), 2); // 15 bits -> 2 bytes
        assert_eq!(packed_len(0, 7), 0);
    }

    #[test]
    fn empty_roundtrip() {
        let bytes = pack(&[], 5);
        assert!(bytes.is_empty());
        assert_eq!(unpack(&bytes, 5, 0), Vec::<u32>::new());
    }

    #[test]
    fn one_bit_bit_layout() {
        // codes 1,0,1,1,0,0,0,1 -> little-endian bit order -> 0b1000_1101
        let bytes = pack(&[1, 0, 1, 1, 0, 0, 0, 1], 1);
        assert_eq!(bytes, vec![0b1000_1101]);
    }

    #[test]
    fn cross_width_no_interference() {
        // Adjacent 3-bit codes must not leak into each other.
        let codes = vec![0b101u32, 0b010, 0b111, 0b001];
        let back = unpack(&pack(&codes, 3), 3, 4);
        assert_eq!(back, codes);
    }
}
