//! Linear quantizer on the unit interval `[-1/2, 1/2)`.
//!
//! Semantics are **identical** to `python/compile/kernels/ref.py` (and hence
//! the Pallas kernels): `L = 2^bits` grid points
//!
//! ```text
//!     g_c = -1/2 + (c + 1/2)/L          c ∈ [0, L)
//! ```
//!
//! * nearest:     `c = clip(floor((w + 1/2)·L), 0, L-1)`, `δ = 1/(2L)`
//! * stochastic:  `c = clip(floor((w + 1/2)·L − 1/2 + u), 0, L-1)`, `δ = 1/L`
//!
//! The Python tests export golden vectors these implementations are checked
//! against (see `rust/tests/cross_language.rs`).

use super::Rounding;

/// A concrete (levels, rounding) pair with encode/decode over slices.
#[derive(Clone, Copy, Debug)]
pub struct LinearQuantizer {
    pub levels: u32,
    pub rounding: Rounding,
}

impl LinearQuantizer {
    pub fn new(levels: u32, rounding: Rounding) -> Self {
        assert!(levels >= 2, "need at least 2 levels");
        LinearQuantizer { levels, rounding }
    }

    /// Worst-case error on [-1/2, 1/2).
    pub fn delta(&self) -> f64 {
        match self.rounding {
            Rounding::Nearest => 0.5 / self.levels as f64,
            Rounding::Stochastic => 1.0 / self.levels as f64,
        }
    }

    /// Encode `w[i] ∈ [-1/2, 1/2)` into codes. For stochastic rounding,
    /// `noise[i] ∈ [0,1)` supplies the randomness (pass the shared stream
    /// for the paper's §6 trick); ignored for nearest.
    pub fn encode_into(&self, w: &[f32], noise: &[f32], codes: &mut [u32]) {
        debug_assert_eq!(w.len(), codes.len());
        let l = self.levels as f32;
        let max_code = self.levels - 1;
        match self.rounding {
            Rounding::Nearest => {
                for (c, &wi) in codes.iter_mut().zip(w) {
                    let t = (wi + 0.5) * l;
                    *c = (t.floor() as i64).clamp(0, max_code as i64) as u32;
                }
            }
            Rounding::Stochastic => {
                debug_assert_eq!(noise.len(), w.len());
                for ((c, &wi), &u) in codes.iter_mut().zip(w).zip(noise) {
                    let t = (wi + 0.5) * l - 0.5 + u;
                    *c = (t.floor() as i64).clamp(0, max_code as i64) as u32;
                }
            }
        }
    }

    /// Decode codes back to grid values in [-1/2, 1/2).
    pub fn decode_into(&self, codes: &[u32], out: &mut [f32]) {
        debug_assert_eq!(codes.len(), out.len());
        let inv = 1.0 / self.levels as f32;
        for (o, &c) in out.iter_mut().zip(codes) {
            *o = (c as f32 + 0.5) * inv - 0.5;
        }
    }
}

/// Convenience: allocate-and-encode.
pub fn quantize_codes(w: &[f32], noise: &[f32], levels: u32, rounding: Rounding) -> Vec<u32> {
    let q = LinearQuantizer::new(levels, rounding);
    let mut codes = vec![0u32; w.len()];
    q.encode_into(w, noise, &mut codes);
    codes
}

/// Convenience: allocate-and-decode.
pub fn dequantize_codes(codes: &[u32], levels: u32) -> Vec<f32> {
    let q = LinearQuantizer::new(levels, Rounding::Nearest);
    let mut out = vec![0.0f32; codes.len()];
    q.decode_into(codes, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testing::forall;

    #[test]
    fn nearest_error_bound() {
        forall(100, |rng| {
            let levels = 1u32 << (1 + rng.below(8) as u32);
            let q = LinearQuantizer::new(levels, Rounding::Nearest);
            let n = 1 + rng.below(200) as usize;
            let w: Vec<f32> = (0..n).map(|_| rng.next_f32() * 0.999 - 0.4995).collect();
            let codes = quantize_codes(&w, &[], levels, Rounding::Nearest);
            let back = dequantize_codes(&codes, levels);
            for (a, b) in w.iter().zip(&back) {
                assert!(((a - b).abs() as f64) <= q.delta() + 1e-6);
            }
        });
    }

    #[test]
    fn stochastic_error_bound() {
        forall(100, |rng| {
            let levels = 1u32 << (1 + rng.below(8) as u32);
            let q = LinearQuantizer::new(levels, Rounding::Stochastic);
            let n = 1 + rng.below(200) as usize;
            let w: Vec<f32> = (0..n).map(|_| rng.next_f32() * 0.999 - 0.4995).collect();
            let u: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let codes = quantize_codes(&w, &u, levels, Rounding::Stochastic);
            let back = dequantize_codes(&codes, levels);
            for (a, b) in w.iter().zip(&back) {
                assert!(((a - b).abs() as f64) <= q.delta() + 1e-6);
            }
        });
    }

    #[test]
    fn stochastic_is_unbiased() {
        let levels = 16u32;
        let w = vec![0.123f32; 100_000];
        let mut rng = Pcg64::seeded(9);
        let u: Vec<f32> = (0..w.len()).map(|_| rng.next_f32()).collect();
        let codes = quantize_codes(&w, &u, levels, Rounding::Stochastic);
        let back = dequantize_codes(&codes, levels);
        let mean: f64 = back.iter().map(|&x| x as f64).sum::<f64>() / back.len() as f64;
        assert!((mean - 0.123).abs() < 1e-3, "mean {mean}");
    }

    #[test]
    fn codes_in_range_even_at_boundary() {
        // Inputs slightly outside [-1/2, 1/2) must clamp, not overflow.
        let w = vec![-0.5f32, 0.4999, 0.5, 0.7, -0.7];
        let u = vec![0.999f32; 5];
        for levels in [2u32, 4, 256] {
            for r in [Rounding::Nearest, Rounding::Stochastic] {
                let codes = quantize_codes(&w, &u, levels, r);
                assert!(codes.iter().all(|&c| c < levels), "{codes:?}");
            }
        }
    }

    #[test]
    fn one_bit_two_levels() {
        // L=2: grid points are -0.25 and +0.25.
        let back = dequantize_codes(&[0, 1], 2);
        assert_eq!(back, vec![-0.25, 0.25]);
    }

    #[test]
    fn matches_ref_py_golden_vectors() {
        // Golden values generated by python ref.quantize_codes_stochastic /
        // _nearest with the exact inputs below (levels=8):
        //   w = [-0.49, -0.2, 0.0, 0.13, 0.49], u = [0.1, 0.9, 0.5, 0.3, 0.7]
        let w = [-0.49f32, -0.2, 0.0, 0.13, 0.49];
        let u = [0.1f32, 0.9, 0.5, 0.3, 0.7];
        let stoch = quantize_codes(&w, &u, 8, Rounding::Stochastic);
        assert_eq!(stoch, vec![0, 2, 4, 4, 7]);
        let near = quantize_codes(&w, &[], 8, Rounding::Nearest);
        assert_eq!(near, vec![0, 2, 4, 5, 7]);
        let back = dequantize_codes(&near, 8);
        let expect = [-0.4375f32, -0.1875, 0.0625, 0.1875, 0.4375];
        for (a, b) in back.iter().zip(expect.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
