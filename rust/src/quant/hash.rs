//! θ-verification digests (paper §6, third method for choosing θ).
//!
//! The sender attaches a 64-bit FNV-1a hash of its **un-modded** quantized
//! vector (absolute grid codes, before the modulo wrap — "a hash function
//! that takes the un-modded vector"). The receiver reconstructs the remote
//! model `x̂` and computes the absolute codes of `x̂` (which sits exactly on
//! the absolute grid): if the a-priori bound θ held, the wrap count `k` was
//! recovered correctly and the digests match; if θ was violated, `x̂`
//! aliased by a multiple of `B_θ` and the digests mismatch with probability
//! ≈ 1 − 2⁻⁶⁴. The 8-byte overhead is negligible next to the payload.

use super::MoniquaCodec;

/// FNV-1a over i64 absolute codes (little-endian bytes).
pub fn fnv1a_abs_codes(codes: &[i64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &c in codes {
        for b in c.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
    }
    h
}

/// FNV-1a over raw bytes (for packed payloads / message integrity).
pub fn fnv1a_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Sender side: absolute (un-modded) codes of `x`:
/// `c_abs = c_wrapped + L * floor(x/B + 1/2)` — the wrapped code plus the
/// wrap count, so it identifies the exact absolute grid point quantization
/// chose.
pub fn sender_abs_codes(codec: &MoniquaCodec, x: &[f32], noise: &[f32]) -> Vec<i64> {
    let mut wrapped = vec![0u32; x.len()];
    codec.encode_into(x, noise, &mut wrapped);
    let l = codec.quant.levels as i64;
    let b = codec.b_theta;
    wrapped
        .iter()
        .zip(x)
        .map(|(&c, &xi)| c as i64 + l * ((xi / b + 0.5).floor() as i64))
        .collect()
}

/// Streaming sender digest: FNV-1a of the absolute codes of `x`, computed
/// in one pass with **no intermediate allocations** — equivalent to
/// `fnv1a_abs_codes(&sender_abs_codes(codec, x, noise))`, but cheap enough
/// to run once per sender per round during the encode phase. The engine
/// computes this exactly once per worker and reuses it at every receiving
/// edge (previously it was recomputed per edge: O(n·m·d) hashing per round).
pub fn sender_digest(codec: &MoniquaCodec, x: &[f32], noise: &[f32]) -> u64 {
    // The wrapped code comes from the codec's shared EncodeKernel — the
    // same per-element math `encode_into`/`encode_packed_into` run, so the
    // digest can never drift from the wire path.
    let ker = codec.encode_kernel();
    let stochastic = ker.stochastic();
    let li = codec.quant.levels as i64;
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for (i, &xi) in x.iter().enumerate() {
        let c = ker.code(xi, if stochastic { noise[i] } else { 0.0 });
        // Wrap count via true division, exactly as sender_abs_codes does
        // (x/B and x*(1/B) can round differently at grid boundaries).
        let abs = c as i64 + li * ((xi / codec.b_theta + 0.5).floor() as i64);
        for b in abs.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
    }
    h
}

/// Receiver side: absolute codes of a reconstruction `x̂` (which lies
/// exactly on the absolute grid, so nearest rounding recovers the code).
pub fn receiver_abs_codes(codec: &MoniquaCodec, xhat: &[f32]) -> Vec<i64> {
    let l = codec.quant.levels as f64;
    let b = codec.b_theta as f64;
    xhat.iter()
        .map(|&v| ((v as f64 / b + 0.5) * l - 0.5).round() as i64)
        .collect()
}

/// Full §6 verification: does the receiver's reconstruction hash to the
/// sender's digest? `false` flags a violated θ bound.
///
/// Cold for the hot-path lint: digest *verification* is opt-in
/// (`QuantConfig::with_verify_hash`) and allocates a codes vector; the
/// zero-alloc contract covers the always-on sender digest
/// ([`sender_digest`]), which streams without allocating.
// lint: cold
pub fn verify_reconstruction(
    codec: &MoniquaCodec,
    xhat: &[f32],
    sender_digest: u64,
) -> bool {
    fnv1a_abs_codes(&receiver_abs_codes(codec, xhat)) == sender_digest
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{MoniquaCodec, QuantConfig};
    use crate::testing::{forall, gaussian_vec, uniform};

    #[test]
    fn digest_is_stable_and_sensitive() {
        let codes = vec![1i64, 2, -3, 4];
        assert_eq!(fnv1a_abs_codes(&codes), fnv1a_abs_codes(&codes));
        let mut other = codes.clone();
        other[2] ^= 1;
        assert_ne!(fnv1a_abs_codes(&codes), fnv1a_abs_codes(&other));
    }

    #[test]
    fn verification_passes_when_theta_holds() {
        forall(50, |rng| {
            let theta = uniform(rng, 0.2, 2.0);
            let cfg = QuantConfig::stochastic(6);
            let codec = MoniquaCodec::from_theta(theta, &cfg);
            let n = 64;
            let y = gaussian_vec(rng, n, 3.0);
            let x: Vec<f32> = y
                .iter()
                .map(|&yi| yi + uniform(rng, -0.9, 0.9) * theta)
                .collect();
            let noise: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let digest = fnv1a_abs_codes(&sender_abs_codes(&codec, &x, &noise));
            let mut codes = vec![0u32; n];
            codec.encode_into(&x, &noise, &mut codes);
            let mut xhat = vec![0.0f32; n];
            codec.recover_into(&codes, &y, &mut xhat);
            assert!(verify_reconstruction(&codec, &xhat, digest));
        });
    }

    #[test]
    fn verification_detects_violated_theta() {
        // |x - y| far beyond θ: recovery aliases by multiples of B_θ and the
        // absolute-code digest mismatches.
        let cfg = QuantConfig::nearest(8);
        let codec = MoniquaCodec::from_theta(0.25, &cfg);
        let n = 64;
        let y = vec![0.0f32; n];
        let x: Vec<f32> = (0..n).map(|i| 3.0 + 0.37 * i as f32).collect();
        let noise = vec![0.0f32; n];
        let digest = fnv1a_abs_codes(&sender_abs_codes(&codec, &x, &noise));
        let mut codes = vec![0u32; n];
        codec.encode_into(&x, &noise, &mut codes);
        let mut xhat = vec![0.0f32; n];
        codec.recover_into(&codes, &y, &mut xhat);
        assert!(!verify_reconstruction(&codec, &xhat, digest));
    }

    #[test]
    fn abs_codes_consistent_between_sides() {
        // With θ held, receiver_abs_codes(recover(...)) == sender_abs_codes.
        let cfg = QuantConfig::stochastic(8);
        let codec = MoniquaCodec::from_theta(1.0, &cfg);
        let mut rng = crate::rng::Pcg64::seeded(5);
        let n = 128;
        let y = gaussian_vec(&mut rng, n, 4.0);
        let x: Vec<f32> = y.iter().map(|&v| v + 0.8 * (rng.next_f32() - 0.5)).collect();
        let noise: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let s = sender_abs_codes(&codec, &x, &noise);
        let mut codes = vec![0u32; n];
        codec.encode_into(&x, &noise, &mut codes);
        let mut xhat = vec![0.0f32; n];
        codec.recover_into(&codes, &y, &mut xhat);
        let r = receiver_abs_codes(&codec, &xhat);
        assert_eq!(s, r);
    }

    #[test]
    fn bytes_digest_differs_from_codes_digest_domain() {
        assert_ne!(fnv1a_abs_codes(&[1]), fnv1a_bytes(&[1]));
    }

    #[test]
    fn streaming_digest_matches_allocating_path() {
        forall(100, |rng| {
            let bits = 2 + rng.below(7) as u32;
            let cfg = QuantConfig::stochastic(bits);
            let theta = uniform(rng, 0.1, 3.0);
            let codec = MoniquaCodec::from_theta(theta, &cfg);
            let n = rng.below(200) as usize;
            let x = gaussian_vec(rng, n, 6.0);
            let noise: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            assert_eq!(
                sender_digest(&codec, &x, &noise),
                fnv1a_abs_codes(&sender_abs_codes(&codec, &x, &noise)),
            );
        });
    }

    #[test]
    fn streaming_digest_matches_for_nearest_rounding() {
        let cfg = QuantConfig::nearest(4);
        let codec = MoniquaCodec::from_theta(0.5, &cfg);
        let mut rng = crate::rng::Pcg64::seeded(8);
        let x = gaussian_vec(&mut rng, 333, 2.0);
        assert_eq!(
            sender_digest(&codec, &x, &[]),
            fnv1a_abs_codes(&sender_abs_codes(&codec, &x, &[])),
        );
    }
}
