//! θ policies — the a-priori consensus bound Moniqua needs (paper §6,
//! "Choosing θ empirically", plus the Theorem 2/3 formulas).
//!
//! Three methods, matching the paper:
//! 1. **Formula** — compute θ from its Theorem-2 expression using a G∞
//!    estimate tracked over warmup steps ([`ThetaTracker`]).
//! 2. **Constant** — treat θ as a hyperparameter (what the paper's
//!    experiments use: θ = 2.0).
//! 3. **Verified** — any policy + the §6 hash check
//!    ([`crate::quant::hash`]), wired up in the coordinator as failure
//!    detection.

/// Theorem 2's θ for constant step size (C_α = η = 1):
/// `θ = 2 α G∞ log(16 n) / (1 − ρ)`.
pub fn theta_theorem2(alpha: f64, g_inf: f64, n: usize, rho: f64) -> f64 {
    2.0 * alpha * g_inf * (16.0 * n as f64).ln() / (1.0 - rho).max(1e-9)
}

/// Theorem 2's recommended quantizer error for constant step size:
/// `δ = (1 − ρ) / (8 log(16n) + 2 (1 − ρ))`.
pub fn delta_theorem2(n: usize, rho: f64) -> f64 {
    let gap = (1.0 - rho).max(1e-9);
    gap / (8.0 * (16.0 * n as f64).ln() + 2.0 * gap)
}

/// §4 "Bound on the Bits": with nearest rounding,
/// `B ≤ ⌈log2(4·log2(16n)/(1−ρ) + 3)⌉` bits per parameter suffice —
/// independent of d and growing O(log log n).
pub fn bits_bound(n: usize, rho: f64) -> u32 {
    let gap = (1.0 - rho).max(1e-9);
    let inner = 4.0 * (16.0 * n as f64).log2() / gap + 3.0;
    inner.log2().ceil() as u32
}

/// Theorem 3's θ under a slack matrix `W̄ = γW + (1−γ)I`:
/// `θ = 2 α G∞ log(16 n) / (γ (1 − ρ))`.
pub fn theta_theorem3(alpha: f64, g_inf: f64, n: usize, rho: f64, gamma: f64) -> f64 {
    theta_theorem2(alpha, g_inf, n, rho) / gamma.max(1e-9)
}

/// Theorem 4's settings for Moniqua-on-D² (constants D1, D2 depend only on
/// the eigenvalues of W; see supplementary Lemma 12).
pub fn theta_d2(alpha: f64, g_inf: f64, n: usize, d1: f64) -> f64 {
    (6.0 * d1 * n as f64 + 8.0) * alpha * g_inf
}

pub fn delta_d2(n: usize, d2: f64) -> f64 {
    1.0 / (12.0 * n as f64 * d2 + 2.0)
}

/// Theorem 5's settings for Moniqua-on-AD-PSGD.
pub fn theta_adpsgd(alpha: f64, g_inf: f64, t_mix: f64) -> f64 {
    16.0 * t_mix * alpha * g_inf
}

pub fn delta_adpsgd(t_mix: f64) -> f64 {
    1.0 / (64.0 * t_mix + 2.0)
}

/// Modulus of the dominant root of D²'s per-eigenvalue recursion
/// `z² − 2λz + λ = 0` (supplementary Lemma 12). The roots are
/// `λ ± sqrt(λ² − λ)`; for `λ ∈ (0, 1)` the radicand is negative, so the
/// pair is complex-conjugate and — because the product of the roots is the
/// constant term λ — both have modulus `sqrt(λ)`. Outside that interval the
/// roots are real and the larger magnitude is `|λ| + sqrt(λ² − λ)`.
/// Boundary check: `λ = −1/3` gives modulus exactly 1, matching D²'s
/// `λn > −1/3` convergence requirement.
fn d2_root_modulus(lambda: f64) -> f64 {
    let rad = lambda * lambda - lambda;
    if rad >= 0.0 {
        lambda.abs() + rad.sqrt()
    } else {
        lambda.sqrt()
    }
}

/// Supplementary Lemma 12's D1/D2 constants from W's extreme eigenvalues.
/// `vn` is the dominant-root modulus of the recursion at `λn`, taken from
/// the correct complex/real branch ([`d2_root_modulus`]) — the naive
/// `λ − sqrt(λ² − λ)` form is NaN for `λn ∈ (0, 1)` (lazy / PSD gossip
/// matrices) and used to silently poison θ/δ for Moniqua-on-D².
pub fn d2_constants(lambda2: f64, lambda_n: f64) -> (f64, f64) {
    let vn = d2_root_modulus(lambda_n);
    let d1 = f64::max(
        vn + 2.0 * lambda_n.abs() / (1.0 - vn).max(1e-9),
        (lambda2 / (1.0 - lambda2).max(1e-9)).max(0.0).sqrt()
            + 2.0 * lambda2 / (1.0 - lambda2).max(1e-9),
    );
    let d2 = f64::max(
        2.0 / (1.0 - vn).max(1e-9),
        2.0 / (1.0 - lambda2).max(1e-9).sqrt(),
    );
    (d1, d2)
}

/// Tracks ‖g̃‖∞ during warmup to instantiate the Theorem-2 θ ("first
/// method": run a few epochs, record the gradient infinity norm, then use
/// the formula for the rest of training").
#[derive(Clone, Debug, Default)]
pub struct ThetaTracker {
    g_inf_max: f64,
    samples: usize,
}

impl ThetaTracker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe(&mut self, grad: &[f32]) {
        let g = crate::linalg::norm_inf(grad) as f64;
        self.g_inf_max = self.g_inf_max.max(g);
        self.samples += 1;
    }

    pub fn g_inf(&self) -> f64 {
        self.g_inf_max
    }

    pub fn samples(&self) -> usize {
        self.samples
    }

    /// θ via Theorem 2 with the tracked G∞ (plus a safety factor: the bound
    /// tracks the *max* over the whole run, warmup only lower-bounds it).
    pub fn theta(&self, alpha: f64, n: usize, rho: f64, safety: f64) -> f64 {
        theta_theorem2(alpha, self.g_inf_max * safety, n, rho)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_bound_grows_loglog() {
        // Paper: O(log log n) growth, dimension-free.
        let rho = 0.8;
        let b4 = bits_bound(4, rho);
        let b64 = bits_bound(64, rho);
        let b4096 = bits_bound(4096, rho);
        assert!(b4 <= b64 && b64 <= b4096);
        assert!(b4096 - b4 <= 2, "loglog growth: {b4} -> {b4096}");
        assert!(b4 >= 4 && b4096 <= 10, "{b4}..{b4096}");
    }

    #[test]
    fn bits_bound_worsens_with_rho() {
        assert!(bits_bound(8, 0.99) >= bits_bound(8, 0.5));
    }

    #[test]
    fn theta_scales_linearly_with_alpha_and_ginf() {
        let t1 = theta_theorem2(0.1, 1.0, 8, 0.8);
        let t2 = theta_theorem2(0.2, 1.0, 8, 0.8);
        let t3 = theta_theorem2(0.1, 2.0, 8, 0.8);
        assert!((t2 - 2.0 * t1).abs() < 1e-12);
        assert!((t3 - 2.0 * t1).abs() < 1e-12);
    }

    #[test]
    fn delta_theorem2_below_half() {
        for n in [2usize, 8, 128] {
            for rho in [0.1, 0.8, 0.99] {
                let d = delta_theorem2(n, rho);
                assert!(d > 0.0 && d < 0.5, "n={n} rho={rho} d={d}");
            }
        }
    }

    #[test]
    fn theorem3_theta_inflates_with_small_gamma() {
        let base = theta_theorem2(0.1, 1.0, 8, 0.8);
        let slack = theta_theorem3(0.1, 1.0, 8, 0.8, 0.005);
        assert!((slack - base / 0.005).abs() < 1e-9);
    }

    #[test]
    fn d2_constants_positive() {
        let (d1, d2) = d2_constants(0.8, -0.2);
        assert!(d1 > 0.0 && d2 > 0.0);
        let theta = theta_d2(0.1, 1.0, 8, d1);
        let delta = delta_d2(8, d2);
        assert!(theta > 0.0 && delta > 0.0 && delta < 0.5);
    }

    #[test]
    fn d2_root_modulus_branches() {
        // Complex pair for λ ∈ (0, 1): modulus sqrt(λ) (product of roots).
        assert!((d2_root_modulus(0.25) - 0.5).abs() < 1e-12);
        // Real branch: |λ| + sqrt(λ² − λ).
        assert!((d2_root_modulus(-0.2) - (0.2 + 0.24f64.sqrt())).abs() < 1e-12);
        // λ = −1/3 sits exactly on the unit circle — D²'s λn > −1/3 wall.
        assert!((d2_root_modulus(-1.0 / 3.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn d2_constants_finite_positive_over_eigenvalue_grid() {
        // Regression for the NaN radicand: λn ∈ (0, 1) (lazy / PSD gossip
        // matrices) used to poison d1 → theta_d2/delta_d2 with NaN. Sweep
        // both signs of both eigenvalues (λn ≤ λ2 < 1 for a gossip matrix).
        for &lambda2 in &[-0.2, 0.1, 0.5, 0.9] {
            for &lambda_n in &[-0.3, -0.1, 0.05, 0.3, 0.7, 0.95] {
                if lambda_n > lambda2 {
                    continue;
                }
                let (d1, d2) = d2_constants(lambda2, lambda_n);
                assert!(
                    d1.is_finite() && d1 > 0.0,
                    "d1={d1} at λ2={lambda2} λn={lambda_n}"
                );
                assert!(
                    d2.is_finite() && d2 > 0.0,
                    "d2={d2} at λ2={lambda2} λn={lambda_n}"
                );
                let theta = theta_d2(0.1, 1.0, 8, d1);
                let delta = delta_d2(8, d2);
                assert!(
                    theta.is_finite() && theta > 0.0,
                    "θ={theta} at λ2={lambda2} λn={lambda_n}"
                );
                assert!(
                    delta.is_finite() && delta > 0.0 && delta < 0.5,
                    "δ={delta} at λ2={lambda2} λn={lambda_n}"
                );
            }
        }
    }

    #[test]
    fn adpsgd_settings() {
        let theta = theta_adpsgd(0.1, 1.0, 20.0);
        assert!((theta - 32.0).abs() < 1e-12);
        let delta = delta_adpsgd(20.0);
        assert!((delta - 1.0 / 1282.0).abs() < 1e-15);
    }

    #[test]
    fn tracker_records_max() {
        let mut t = ThetaTracker::new();
        t.observe(&[0.5, -1.5]);
        t.observe(&[0.2, 0.3]);
        assert_eq!(t.g_inf(), 1.5);
        assert_eq!(t.samples(), 2);
        let theta = t.theta(0.1, 8, 0.8, 2.0);
        assert!((theta - theta_theorem2(0.1, 3.0, 8, 0.8)).abs() < 1e-12);
    }
}
