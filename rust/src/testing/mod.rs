//! In-crate property-testing helper.
//!
//! `proptest` is not available in this offline environment, so this module
//! provides the minimal equivalent used throughout the test suite: run a
//! closure over many seeded [`Pcg64`] generators and report the failing seed
//! so cases are reproducible.

use crate::rng::Pcg64;

/// Run `body` for `cases` independent seeded RNGs. On panic, the failing
/// case index/seed is printed before the panic propagates, so any failure
/// can be replayed with `forall_seed`.
pub fn forall<F: FnMut(&mut Pcg64)>(cases: u64, mut body: F) {
    for case in 0..cases {
        let seed = 0x51ed_c0de ^ (case.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let mut rng = Pcg64::new(seed, case);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!("property failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Replay a single case by its index (for debugging failures).
pub fn forall_seed<F: FnMut(&mut Pcg64)>(case: u64, mut body: F) {
    let seed = 0x51ed_c0de ^ (case.wrapping_mul(0x9e37_79b9_7f4a_7c15));
    let mut rng = Pcg64::new(seed, case);
    body(&mut rng);
}

/// Uniform float in [lo, hi).
pub fn uniform(rng: &mut Pcg64, lo: f32, hi: f32) -> f32 {
    lo + rng.next_f32() * (hi - lo)
}

/// Random vector of gaussians with the given std.
pub fn gaussian_vec(rng: &mut Pcg64, n: usize, std: f32) -> Vec<f32> {
    (0..n).map(|_| rng.next_gaussian() as f32 * std).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        forall(25, |_| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    fn forall_cases_use_distinct_streams() {
        let mut first: Vec<u64> = Vec::new();
        forall(10, |rng| first.push(rng.next_u64()));
        let uniq: std::collections::HashSet<_> = first.iter().collect();
        assert_eq!(uniq.len(), first.len());
    }

    #[test]
    fn gaussian_vec_has_right_scale() {
        let mut rng = Pcg64::seeded(0);
        let v = gaussian_vec(&mut rng, 50_000, 2.0);
        let var = v.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / v.len() as f64;
        assert!((var - 4.0).abs() < 0.2, "var {var}");
    }
}
