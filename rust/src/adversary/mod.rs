//! **Adversarial fault plane**: Byzantine sender models, the round-bound
//! payload seal the defense gate verifies, and the quarantine matrix
//! re-derivation that excises convicted peers from the gossip graph.
//!
//! The §6 sender digests detect *accidental* corruption (a bit flip fails
//! the FNV checksum, a θ-ball escape fails the semantic digest). This
//! module models *deliberate* misbehavior — a peer that re-stamps its
//! checksum after corrupting the payload, replays a stale round, or tells
//! each neighbor a different story — and supplies the two deterministic
//! primitives the defense layer in
//! [`RoundStateMachine`](crate::coordinator) builds on:
//!
//! * a **round-bound seal** ([`seal_payload`] / [`seal_ok`]): an 8-byte
//!   FNV-1a tail over `round ‖ body`, appended after the engine writes its
//!   payload and stripped before the engine reads one. Binding the round
//!   into the hash defeats replayed-content-with-a-fresh-round-stamp
//!   without remembering any per-peer history, and two honest senders that
//!   converge to identical payloads never collide with a stale frame of a
//!   different round. The Moniqua family carries its own §6 semantic
//!   digest instead (it additionally proves the θ bound); the seal covers
//!   the raw-f32 engines whose wire bytes previously shipped unverified.
//! * a **quarantine matrix** ([`excised_matrix`]): the gossip row
//!   re-derivation over the surviving cohort, the same
//!   [`Topology::resized`] + metropolis embedding the elastic subsystem
//!   uses for leaves — convicted slots become isolated identity rows, so
//!   the matrix stays symmetric and doubly stochastic and every engine's
//!   math is unchanged. On ring/complete families the excision is locally
//!   computable yet globally consistent: every honest node that convicts
//!   the same peer derives the same matrix with no extra protocol round.

use anyhow::{bail, ensure, Context, Result};

use crate::topology::{CommMatrix, Topology};

/// Length of the seal tail appended to sealed payloads.
pub const SEAL_LEN: usize = 8;

/// The wrap attack's model offset: far outside any θ ball the paper's
/// policies produce (θ is O(αG/(1−ρ)), single digits in every recipe), so
/// a receiver's modulo decode recovers *different* absolute codes than the
/// sender hashed — exactly the Lemma-1 violation the §6 digest exists to
/// catch.
pub const WRAP_KICK: f32 = 257.0;

/// What a designated Byzantine worker does to its outgoing frames.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ByzMode {
    /// Corrupt the payload body and re-stamp the frame checksum valid:
    /// only the seal / semantic digest can catch it.
    Flip,
    /// Send the honest current-round frame *plus* a re-broadcast of the
    /// previous round's frame with its stale round stamp — the classic
    /// replay. (The honest copy keeps the barrier from deadlocking; the
    /// stale copy is what the gate must strike.)
    Replay,
    /// Broadcast honestly, then send each peer a *different* second
    /// payload for the same `(round, sender)` — equivocation. Receivers
    /// catch the divergent duplicate without comparing notes.
    Equivocate,
    /// Perturb the local model by a large constant before encoding, so the
    /// frame is honestly encoded but escapes the θ ball: the receiver's
    /// modulo decode recovers different absolute codes and the §6 digest
    /// convicts it. On raw-f32 engines this degrades to an outlier attack
    /// countered by the robust mix, not the digest gate.
    Wrap,
}

impl ByzMode {
    /// Parse the `byz_mode=` config key.
    pub fn parse(s: &str) -> Result<ByzMode> {
        Ok(match s {
            "flip" => ByzMode::Flip,
            "replay" => ByzMode::Replay,
            "equivocate" => ByzMode::Equivocate,
            "wrap" => ByzMode::Wrap,
            other => bail!("unknown byz_mode '{other}' (flip|replay|equivocate|wrap)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ByzMode::Flip => "flip",
            ByzMode::Replay => "replay",
            ByzMode::Equivocate => "equivocate",
            ByzMode::Wrap => "wrap",
        }
    }
}

/// Which workers misbehave, how, and how many strikes convict them.
///
/// `Copy` so it can ride inside [`FaultConfig`](crate::coordinator::des) —
/// the worker set is a bitmask, which caps adversarial ids at 63. (A
/// majority-honest cohort that large is far beyond the quorum the defense
/// can tolerate anyway.)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ByzantineConfig {
    /// Bitmask of misbehaving worker ids (bit `i` ⇒ worker `i`).
    pub workers: u64,
    pub mode: ByzMode,
    /// Strikes before a peer is quarantined (≥ 1).
    pub strike_limit: u32,
}

impl ByzantineConfig {
    /// Whether worker `i` is designated Byzantine.
    #[inline]
    pub fn is_byz(&self, i: usize) -> bool {
        i < 64 && self.workers & (1u64 << i) != 0
    }

    /// Number of designated adversaries.
    pub fn count(&self) -> usize {
        self.workers.count_ones() as usize
    }

    /// Parse the `byz_workers=` comma list of ids and inclusive `a-b`
    /// ranges (`byz_workers=0,2` or `byz_workers=1-3,5`) into the bitmask.
    /// Range against the worker count is checked by
    /// [`validate`](Self::validate), which knows `n`.
    pub fn parse_workers(spec: &str) -> Result<u64> {
        let mut mask = 0u64;
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (lo, hi) = match part.split_once('-') {
                Some((a, b)) => {
                    let lo: usize = a.trim().parse().with_context(|| {
                        format!("byz_workers range '{part}' start is not a worker id")
                    })?;
                    let hi: usize = b.trim().parse().with_context(|| {
                        format!("byz_workers range '{part}' end is not a worker id")
                    })?;
                    ensure!(lo <= hi, "byz_workers range '{part}' runs backwards");
                    (lo, hi)
                }
                None => {
                    let id: usize = part.parse().with_context(|| {
                        format!("byz_workers entry '{part}' is not a worker id")
                    })?;
                    (id, id)
                }
            };
            ensure!(hi < 64, "byz_workers id {hi} exceeds the bitmask capacity (ids < 64)");
            for id in lo..=hi {
                mask |= 1u64 << id;
            }
        }
        Ok(mask)
    }

    /// Loud typed errors on out-of-range values, mirroring the
    /// `drop_prob` checks in `FaultConfig::validate`.
    pub fn validate(&self, n: usize) -> Result<()> {
        ensure!(self.workers != 0, "byz_workers must name at least one worker");
        ensure!(self.strike_limit >= 1, "quarantine strike limit must be >= 1, got 0");
        let top = 63 - self.workers.leading_zeros() as usize;
        ensure!(
            top < n,
            "byz_workers names worker {top} but the run has only {n} workers"
        );
        ensure!(
            self.count() < n,
            "byz_workers designates every worker; at least one honest worker is required"
        );
        Ok(())
    }
}

/// FNV-1a over `round ‖ body` — the seal value.
#[inline]
fn seal_value(round: u64, body: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in round.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    for &b in body {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Append the 8-byte round-bound seal to `payload`. Called by the round
/// machine after the engine's `node_send`, so engines never see the tail.
// lint: hot-path
#[inline]
pub fn seal_payload(round: u64, payload: &mut Vec<u8>) {
    let h = seal_value(round, payload);
    payload.extend_from_slice(&h.to_le_bytes());
}

/// Verify a sealed payload against the round it claims. The frame-level
/// FNV checksum covers `seal ‖ body` alike, so a tampered body with a
/// re-stamped checksum still decodes — this is the gate that catches it.
// lint: hot-path
#[inline]
pub fn seal_ok(round: u64, payload: &[u8]) -> bool {
    if payload.len() < SEAL_LEN {
        return false;
    }
    let (body, tail) = payload.split_at(payload.len() - SEAL_LEN);
    let want = u64::from_le_bytes(tail.try_into().expect("8-byte seal tail"));
    seal_value(round, body) == want
}

/// The body of a sealed payload (everything before the tail). Callers must
/// have checked [`seal_ok`] first; a short payload panics.
#[inline]
pub fn sealed_body(payload: &[u8]) -> &[u8] {
    &payload[..payload.len() - SEAL_LEN]
}

/// The substitution-equivalent matrix of the pre-conviction window: every
/// edge touching a Byzantine worker is folded into the two diagonals, so
/// an honest row applies the weight it would have given the rejected frame
/// to its *own* model — exactly what the gate's self-substitution does —
/// while the matrix stays symmetric and doubly stochastic (every engine's
/// invariants hold). Used by the DES to model the defended value path; the
/// cluster runtime realizes the same effect per-frame through
/// [`Inbox::from_frames_with_self`](crate::algorithms::Inbox).
// lint: cold
pub fn folded_matrix(w: &CommMatrix, byz: &[bool]) -> CommMatrix {
    let n = w.n();
    assert_eq!(byz.len(), n, "byzantine mask/matrix size mismatch");
    let mut m = crate::linalg::MatF64::zeros(n, n);
    for i in 0..n {
        m[(i, i)] = w.weight(i, i);
    }
    for i in 0..n {
        for j in (i + 1)..n {
            let v = w.weight(i, j);
            if v == 0.0 {
                continue;
            }
            if byz[i] || byz[j] {
                m[(i, i)] += v;
                m[(j, j)] += v;
            } else {
                m[(i, j)] = v;
                m[(j, i)] = v;
            }
        }
    }
    CommMatrix::from_matrix(m)
}

/// Re-derive the gossip matrix over the non-quarantined cohort: the
/// elastic-leave embedding (resize the topology family to the survivor
/// count, embed in ascending id order, metropolis weights) with convicted
/// slots as isolated identity rows. Returns the n×n matrix plus the
/// n-sized embedded adjacency (quarantined slots have no edges).
///
/// Errors when the surviving cohort would disconnect or the topology
/// family has no canonical shape at the smaller size (torus) — the caller
/// surfaces that as a quorum-loss [`WorkerFailure`](crate::coordinator).
// lint: cold
pub fn excised_matrix(
    topo: &Topology,
    quarantined: &[bool],
) -> Result<(CommMatrix, Vec<Vec<usize>>)> {
    let n = topo.n();
    ensure!(quarantined.len() == n, "quarantine table/topology size mismatch");
    let slots: Vec<usize> = (0..n).filter(|&w| !quarantined[w]).collect();
    ensure!(
        slots.len() >= 2,
        "quarantine leaves fewer than 2 workers; quorum lost"
    );
    let shape = topo
        .resized(slots.len())
        .context("quarantine needs a resizable topology")?;
    ensure!(
        shape.is_connected(),
        "quarantining disconnects the surviving cohort ({shape:?})"
    );
    let small = shape.adjacency();
    let mut adj = vec![Vec::new(); n];
    for (si, nbrs) in small.iter().enumerate() {
        adj[slots[si]] = nbrs.iter().map(|&sj| slots[sj]).collect();
    }
    let matrix = CommMatrix::metropolis(&adj);
    Ok((matrix, adj))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seal_roundtrips_and_binds_the_round() {
        let mut p = vec![1u8, 2, 3, 4, 5];
        seal_payload(7, &mut p);
        assert_eq!(p.len(), 5 + SEAL_LEN);
        assert!(seal_ok(7, &p));
        assert_eq!(sealed_body(&p), &[1, 2, 3, 4, 5]);
        // Same body, different round: the seal must not transfer.
        assert!(!seal_ok(8, &p));
        // Tampered body under a valid-looking tail.
        let mut q = p.clone();
        q[0] ^= 0xFF;
        assert!(!seal_ok(7, &q));
        // Too short to even hold a tail.
        assert!(!seal_ok(7, &[1, 2, 3]));
    }

    #[test]
    fn identical_bodies_in_different_rounds_get_different_seals() {
        let mut a = vec![9u8; 16];
        let mut b = vec![9u8; 16];
        seal_payload(3, &mut a);
        seal_payload(4, &mut b);
        assert_ne!(a, b, "converged honest payloads must not alias across rounds");
    }

    #[test]
    fn mode_and_worker_parsing() {
        assert_eq!(ByzMode::parse("flip").unwrap(), ByzMode::Flip);
        assert_eq!(ByzMode::parse("replay").unwrap(), ByzMode::Replay);
        assert_eq!(ByzMode::parse("equivocate").unwrap(), ByzMode::Equivocate);
        assert_eq!(ByzMode::parse("wrap").unwrap(), ByzMode::Wrap);
        assert!(ByzMode::parse("gaslight").is_err());

        assert_eq!(ByzantineConfig::parse_workers("0,2").unwrap(), 0b101);
        assert_eq!(ByzantineConfig::parse_workers(" 3 ").unwrap(), 0b1000);
        assert!(ByzantineConfig::parse_workers("x").is_err());
        assert!(ByzantineConfig::parse_workers("64").is_err());
        // Inclusive a-b ranges, mixable with single ids.
        assert_eq!(ByzantineConfig::parse_workers("0-2").unwrap(), 0b111);
        assert_eq!(ByzantineConfig::parse_workers("1-1").unwrap(), 0b10);
        assert_eq!(ByzantineConfig::parse_workers("0, 2-4 ,6").unwrap(), 0b101_1101);
        assert!(ByzantineConfig::parse_workers("3-1").is_err(), "backwards range");
        assert!(ByzantineConfig::parse_workers("0-64").is_err(), "range off the mask");
        assert!(ByzantineConfig::parse_workers("1-x").is_err());
    }

    #[test]
    fn validate_rejects_out_of_range_configs() {
        let cfg = |workers, strike_limit| ByzantineConfig {
            workers,
            mode: ByzMode::Flip,
            strike_limit,
        };
        assert!(cfg(0b1, 3).validate(4).is_ok());
        // Empty worker set.
        assert!(cfg(0, 3).validate(4).is_err());
        // Zero strike budget.
        assert!(cfg(0b1, 0).validate(4).is_err());
        // Worker id ≥ n.
        let err = cfg(0b1_0000, 3).validate(4).unwrap_err().to_string();
        assert!(err.contains("worker 4"), "{err}");
        // All workers Byzantine.
        assert!(cfg(0b1111, 3).validate(4).is_err());
        assert!(cfg(0b1, 3).is_byz(0));
        assert!(!cfg(0b1, 3).is_byz(1));
    }

    #[test]
    fn folded_matrix_redirects_byzantine_weight_to_the_diagonal() {
        let w = Topology::Ring(4).comm_matrix();
        let folded = folded_matrix(&w, &[false, false, true, false]);
        // Honest neighbors of worker 2 keep its old edge weight on their
        // own diagonal (the self-substitution), everyone else is untouched.
        assert_eq!(folded.weight(1, 2), 0.0);
        assert_eq!(folded.weight(3, 2), 0.0);
        assert_eq!(folded.weight(1, 1), w.weight(1, 1) + w.weight(1, 2));
        assert_eq!(folded.weight(0, 1), w.weight(0, 1));
        for i in 0..4 {
            let row: f64 = (0..4).map(|j| folded.weight(i, j)).sum();
            assert!((row - 1.0).abs() < 1e-12, "row {i} must stay stochastic");
        }
    }

    #[test]
    fn excised_ring_is_still_a_metropolis_ring_over_survivors() {
        // Removing one node from a 5-ring yields a 4-ring over the
        // survivors: every surviving row keeps degree 2 and weight 1/3 per
        // edge; the convicted slot is an identity row.
        let mut q = vec![false; 5];
        q[2] = true;
        let (m, adj) = excised_matrix(&Topology::Ring(5), &q).unwrap();
        assert!(adj[2].is_empty());
        assert_eq!(m.weight(2, 2), 1.0);
        for i in [0usize, 1, 3, 4] {
            assert_eq!(adj[i].len(), 2, "survivor {i} must keep ring degree 2");
            assert_eq!(m.weight(i, 2), 0.0, "no survivor may keep an edge to the convict");
            let row: f64 = (0..5).map(|j| m.weight(i, j)).sum();
            assert!((row - 1.0).abs() < 1e-12, "row {i} must stay stochastic");
        }
        // The bridge: 1 and 3 become neighbors around the excised slot.
        assert!(adj[1].contains(&3) && adj[3].contains(&1));
    }

    #[test]
    fn excision_refuses_quorum_loss_and_unsizable_shapes() {
        let q = vec![false, true, true, true];
        assert!(excised_matrix(&Topology::Ring(4), &q).is_err());
        let mut q6 = vec![false; 6];
        q6[0] = true;
        assert!(excised_matrix(&Topology::Torus(2, 3), &q6).is_err());
    }
}
