//! Elastic membership + checkpoint/recovery: the subsystem that lets the
//! cluster runtime survive crashes bitwise-exactly and grow/shrink its
//! cohort mid-run.
//!
//! * [`snapshot`] — the durable formats: the versioned, checksummed
//!   [`Snapshot`] (model + per-algorithm engine state + node ledger + round
//!   cursors, same magic/version/FNV discipline as the wire frame), the
//!   per-worker [`FrameLog`] write-ahead log, and the byte-level helpers
//!   every [`SyncAlgorithm::snapshot`] implementation encodes with.
//! * [`membership`] — the [`MembershipPlan`] (`churn=join@r:w,...`), epoch
//!   computation with per-epoch gossip matrices over the active cohort, and
//!   the bootstrap designation rule for joiners.
//!
//! The consumer is [`coordinator::cluster::ClusterTrainer`]
//! (`runtime=cluster churn=... ckpt_every=K ckpt_dir=...`); the paper-side
//! argument for why a joiner must receive one full-precision frame before
//! quantized traffic is laid out in `rust/DESIGN.md` §Elasticity.
//!
//! [`Snapshot`]: snapshot::Snapshot
//! [`FrameLog`]: snapshot::FrameLog
//! [`MembershipPlan`]: membership::MembershipPlan
//! [`SyncAlgorithm::snapshot`]: crate::algorithms::SyncAlgorithm::snapshot
//! [`coordinator::cluster::ClusterTrainer`]: crate::coordinator::cluster::ClusterTrainer

pub mod membership;
pub mod snapshot;

pub use membership::{
    epoch_at, epoch_index, ChurnEvent, ChurnKind, ElasticConfig, Epoch, MembershipPlan,
};
pub use snapshot::{FrameLog, NodeTrace, Snapshot, SnapshotError};
