//! **Elastic membership**: a declarative plan of joins, leaves, and crashes
//! that drives the cluster runtime through epochs of stable membership
//! separated by reconfiguration barriers.
//!
//! The paper's machinery (Lemma 1 / Theorem 1) never needs a fixed worker
//! set — it needs (a) a doubly-stochastic gossip matrix over whoever is
//! currently present and (b) every pair of gossiping neighbors within the θ
//! proximity bound. A [`MembershipPlan`] preserves exactly those two
//! invariants:
//!
//! * the provisioned cluster has `n` **slots**; at any round a subset is
//!   *active*. The gossip matrix of an epoch is the configured topology
//!   family re-instantiated over the active cohort
//!   ([`Topology::resized`]), embedded back into the n×n matrix with
//!   inactive slots as isolated identity rows — still symmetric and doubly
//!   stochastic, so every engine's math is unchanged;
//! * a worker **joining** (or re-joining) first receives one full-precision
//!   [`FrameKind::Bootstrap`](crate::transport::FrameKind::Bootstrap) frame
//!   from its designated neighbor and adopts that model, which places it
//!   inside the cohort's θ ball *before* any modulo-quantized frame reaches
//!   it — without this the modulo decode is garbage
//!   (`tests/elastic_equivalence.rs` demonstrates the corruption);
//! * a **crash** is invisible to the rest of the cluster: the worker
//!   restores its last [`Snapshot`](crate::elastic::snapshot::Snapshot) and
//!   replays its [`FrameLog`](crate::elastic::snapshot::FrameLog).
//!
//! Spec syntax (the `churn=` config key): comma-separated events
//! `kind@round:worker`, e.g. `churn=crash@12:2,leave@20:1,join@24:5`.

use std::path::PathBuf;

use anyhow::{bail, ensure, Context, Result};

use crate::topology::{CommMatrix, Topology};

/// What happens to a worker at a round boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChurnKind {
    /// The worker becomes active at `round` (first round it participates
    /// in), after a bootstrap handshake.
    Join,
    /// The worker completes `round - 1` and departs cleanly.
    Leave,
    /// The worker loses all in-memory state at the start of `round` and
    /// recovers from its last checkpoint + frame log. Membership and the
    /// gossip matrix are unchanged.
    Crash,
}

/// One scheduled membership event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChurnEvent {
    pub kind: ChurnKind,
    pub round: u64,
    pub worker: usize,
}

/// The full churn schedule of a run (possibly empty).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MembershipPlan {
    /// Events sorted by (round, worker).
    events: Vec<ChurnEvent>,
}

/// One stretch of rounds with a fixed active cohort, plus everything the
/// workers need at its opening barrier.
#[derive(Clone, Debug)]
pub struct Epoch {
    /// First round of the epoch.
    pub start: u64,
    /// Which of the n slots are active during the epoch.
    pub active: Vec<bool>,
    /// n-sized adjacency (inactive slots have no edges).
    pub adj: Vec<Vec<usize>>,
    /// n×n doubly-stochastic matrix (inactive slots are identity rows).
    pub matrix: CommMatrix,
    /// ρ of `matrix` restricted to the active cohort.
    pub rho: f64,
    /// `(joiner, bootstrapper)` pairs for workers activating at `start`:
    /// the bootstrapper is the joiner's lowest-id active neighbor, and must
    /// ship it one full-precision model frame before round `start` data.
    pub joins: Vec<(usize, usize)>,
}

impl Epoch {
    /// Number of active workers.
    pub fn active_count(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    /// Sum and max of active degrees (the [`RoundLedger`] pricing inputs).
    ///
    /// [`RoundLedger`]: crate::coordinator
    pub fn degrees(&self) -> (usize, usize) {
        let deg_sum = self.adj.iter().map(|a| a.len()).sum();
        let deg_max = self.adj.iter().map(|a| a.len()).max().unwrap_or(0);
        (deg_sum, deg_max)
    }
}

impl MembershipPlan {
    /// Parse the `churn=` spec: `kind@round:worker[,...]` with
    /// `kind ∈ {join, leave, crash}`. An empty spec is the empty plan.
    pub fn parse(spec: &str) -> Result<MembershipPlan> {
        let mut events = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (kind, rest) = part
                .split_once('@')
                .with_context(|| format!("churn event '{part}': expected kind@round:worker"))?;
            let kind = match kind {
                "join" => ChurnKind::Join,
                "leave" => ChurnKind::Leave,
                "crash" => ChurnKind::Crash,
                other => bail!("unknown churn kind '{other}' (join|leave|crash)"),
            };
            let (round, worker) = rest
                .split_once(':')
                .with_context(|| format!("churn event '{part}': expected kind@round:worker"))?;
            events.push(ChurnEvent {
                kind,
                round: round
                    .parse()
                    .with_context(|| format!("churn event '{part}': round"))?,
                worker: worker
                    .parse()
                    .with_context(|| format!("churn event '{part}': worker"))?,
            });
        }
        events.sort_by_key(|e| (e.round, e.worker));
        Ok(MembershipPlan { events })
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[ChurnEvent] {
        &self.events
    }

    /// True if the plan reconfigures membership (joins or leaves) — i.e.
    /// needs matrix swaps; crashes alone do not.
    pub fn reconfigures(&self) -> bool {
        self.events
            .iter()
            .any(|e| matches!(e.kind, ChurnKind::Join | ChurnKind::Leave))
    }

    pub fn has_crashes(&self) -> bool {
        self.events.iter().any(|e| e.kind == ChurnKind::Crash)
    }

    /// Sorted crash rounds scheduled for `worker`.
    pub fn crashes_for(&self, worker: usize) -> Vec<u64> {
        self.events
            .iter()
            .filter(|e| e.kind == ChurnKind::Crash && e.worker == worker)
            .map(|e| e.round)
            .collect()
    }

    /// Founding membership: a slot whose *first* event is a Join starts
    /// inactive (it is provisioned but waits off to the side).
    pub fn initial_active(&self, n: usize) -> Vec<bool> {
        let mut active = vec![true; n];
        for w in 0..n {
            if let Some(first) = self
                .events
                .iter()
                .find(|e| e.worker == w && e.kind != ChurnKind::Crash)
            {
                if first.kind == ChurnKind::Join {
                    active[w] = false;
                }
            }
        }
        active
    }

    /// Validate the plan against cluster shape and schedule, then compute
    /// the epoch sequence. Checks: bounds, orderable per-worker histories
    /// (inactive workers can only Join, active ones only Leave/Crash), a
    /// never-empty cohort, at most one membership event per (round, worker),
    /// and a bootstrappable neighbor for every joiner.
    pub fn epochs(&self, base: &Topology, steps: u64) -> Result<Vec<Epoch>> {
        let n = base.n();
        for e in &self.events {
            ensure!(e.worker < n, "churn worker {} out of range (n = {n})", e.worker);
            ensure!(
                e.round >= 1 && e.round < steps,
                "churn round {} outside 1..{steps} (round 0 membership is the initial \
                 cohort; use a plan without the worker instead)",
                e.round
            );
        }
        for pair in self.events.windows(2) {
            ensure!(
                (pair[0].round, pair[0].worker) != (pair[1].round, pair[1].worker),
                "worker {} has two churn events at round {}",
                pair[0].worker,
                pair[0].round
            );
        }

        let mut active = self.initial_active(n);
        ensure!(
            active.iter().any(|&a| a),
            "the initial cohort is empty — every worker joins later"
        );

        let mut epochs = vec![self.make_epoch(base, 0, &active, &[])?];
        let mut boundaries: Vec<u64> = self
            .events
            .iter()
            .filter(|e| e.kind != ChurnKind::Crash)
            .map(|e| e.round)
            .collect();
        boundaries.dedup();
        for round in boundaries {
            let mut joiners = Vec::new();
            for e in self.events.iter().filter(|e| e.round == round) {
                match e.kind {
                    ChurnKind::Join => {
                        ensure!(
                            !active[e.worker],
                            "worker {} joins at round {round} but is already active",
                            e.worker
                        );
                        active[e.worker] = true;
                        joiners.push(e.worker);
                    }
                    ChurnKind::Leave => {
                        ensure!(
                            active[e.worker],
                            "worker {} leaves at round {round} but is not active",
                            e.worker
                        );
                        active[e.worker] = false;
                    }
                    ChurnKind::Crash => {
                        ensure!(
                            active[e.worker],
                            "worker {} crashes at round {round} but is not active",
                            e.worker
                        );
                    }
                }
            }
            ensure!(
                active.iter().any(|&a| a),
                "membership at round {round} leaves the cohort empty"
            );
            epochs.push(self.make_epoch(base, round, &active, &joiners)?);
        }
        // Crashes of inactive workers (validated per-epoch above only for
        // boundary rounds): check against the epoch each crash lands in.
        for e in self.events.iter().filter(|e| e.kind == ChurnKind::Crash) {
            let ep = epoch_at(&epochs, e.round);
            ensure!(
                ep.active[e.worker],
                "worker {} crashes at round {} but is inactive then",
                e.worker,
                e.round
            );
        }
        Ok(epochs)
    }

    fn make_epoch(
        &self,
        base: &Topology,
        start: u64,
        active: &[bool],
        joiners: &[usize],
    ) -> Result<Epoch> {
        let n = base.n();
        let slots: Vec<usize> =
            (0..n).filter(|&w| active[w]).collect();
        let shape = base.resized(slots.len())?;
        ensure!(
            shape.is_connected(),
            "membership at round {start} disconnects the cohort ({shape:?})"
        );
        // Embed the m-worker shape into the n slots (ascending id order) —
        // inactive slots end up isolated (identity rows in the matrix).
        let small = shape.adjacency();
        let mut adj = vec![Vec::new(); n];
        for (si, nbrs) in small.iter().enumerate() {
            adj[slots[si]] = nbrs.iter().map(|&sj| slots[sj]).collect();
        }
        let matrix = CommMatrix::metropolis(&adj);
        let rho = if slots.len() == n {
            matrix.rho()
        } else {
            // ρ of the active block: the embedded identity rows each add a
            // λ = 1 eigenvalue that is *not* a consensus direction of the
            // cohort, so measure the resized shape directly.
            shape.comm_matrix().rho()
        };
        let mut joins = Vec::new();
        for &j in joiners {
            let boot = adj[j]
                .iter()
                .copied()
                .filter(|&b| !joiners.contains(&b))
                .min()
                .with_context(|| {
                    format!(
                        "joiner {j} at round {start} has no established active neighbor \
                         to bootstrap from"
                    )
                })?;
            joins.push((j, boot));
        }
        Ok(Epoch { start, active: active.to_vec(), adj, matrix, rho, joins })
    }
}

/// Index of the epoch covering `round` (epochs are sorted by `start`, the
/// first starts at 0).
pub fn epoch_index(epochs: &[Epoch], round: u64) -> usize {
    match epochs.binary_search_by_key(&round, |e| e.start) {
        Ok(i) => i,
        Err(i) => i - 1,
    }
}

/// The epoch covering `round`.
pub fn epoch_at(epochs: &[Epoch], round: u64) -> &Epoch {
    &epochs[epoch_index(epochs, round)]
}

/// Elastic knobs of a cluster run ([`ClusterConfig`]'s `elastic` field).
///
/// [`ClusterConfig`]: crate::coordinator::cluster::ClusterConfig
#[derive(Clone, Debug, Default)]
pub struct ElasticConfig {
    pub plan: MembershipPlan,
    /// Write a checkpoint after every `ckpt_every` completed rounds
    /// (0 = never; crashes then recover from genesis by full replay).
    pub ckpt_every: u64,
    /// Durability directory for checkpoints + frame logs. Required whenever
    /// the plan contains crashes.
    pub ckpt_dir: Option<PathBuf>,
    /// TESTING ONLY: joiners consume but ignore their bootstrap frame —
    /// demonstrates the θ-proximity corruption the bootstrap exists to
    /// prevent (`tests/elastic_equivalence.rs`).
    pub skip_bootstrap: bool,
}

impl ElasticConfig {
    /// A plan with checkpoints under `dir` every `every` rounds.
    pub fn with_checkpoints(plan: MembershipPlan, every: u64, dir: PathBuf) -> Self {
        ElasticConfig { plan, ckpt_every: every, ckpt_dir: Some(dir), skip_bootstrap: false }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_kinds_and_sorts() {
        let p = MembershipPlan::parse("leave@20:1, crash@12:2,join@24:5").unwrap();
        let kinds: Vec<(ChurnKind, u64, usize)> =
            p.events().iter().map(|e| (e.kind, e.round, e.worker)).collect();
        assert_eq!(
            kinds,
            vec![
                (ChurnKind::Crash, 12, 2),
                (ChurnKind::Leave, 20, 1),
                (ChurnKind::Join, 24, 5),
            ]
        );
        assert!(p.reconfigures());
        assert!(p.has_crashes());
        assert_eq!(p.crashes_for(2), vec![12]);
        assert!(p.crashes_for(1).is_empty());
    }

    #[test]
    fn rejects_garbage_specs() {
        assert!(MembershipPlan::parse("evaporate@3:1").is_err());
        assert!(MembershipPlan::parse("join@x:1").is_err());
        assert!(MembershipPlan::parse("join@3").is_err());
        assert!(MembershipPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn initial_active_excludes_future_joiners() {
        let p = MembershipPlan::parse("join@5:2,leave@9:2,crash@3:0").unwrap();
        assert_eq!(p.initial_active(4), vec![true, true, false, true]);
    }

    #[test]
    fn epochs_partition_the_run() {
        let topo = Topology::Ring(5);
        let p = MembershipPlan::parse("leave@4:1,join@8:1").unwrap();
        let epochs = p.epochs(&topo, 12).unwrap();
        assert_eq!(epochs.len(), 3);
        assert_eq!(epochs[0].start, 0);
        assert_eq!(epochs[0].active_count(), 5);
        assert_eq!(epochs[1].start, 4);
        assert_eq!(epochs[1].active_count(), 4);
        assert!(epochs[1].adj[1].is_empty(), "departed slot isolated");
        assert_eq!(epochs[2].start, 8);
        assert_eq!(epochs[2].active_count(), 5);
        assert_eq!(epochs[2].joins.len(), 1);
        let (joiner, boot) = epochs[2].joins[0];
        assert_eq!(joiner, 1);
        assert!(epochs[2].adj[1].contains(&boot));
        // lookups
        assert_eq!(epoch_at(&epochs, 0).start, 0);
        assert_eq!(epoch_at(&epochs, 3).start, 0);
        assert_eq!(epoch_at(&epochs, 4).start, 4);
        assert_eq!(epoch_at(&epochs, 11).start, 8);
    }

    #[test]
    fn embedded_matrix_is_doubly_stochastic_with_identity_rows() {
        let topo = Topology::Ring(6);
        let p = MembershipPlan::parse("leave@2:3").unwrap();
        let epochs = p.epochs(&topo, 10).unwrap();
        let m = &epochs[1].matrix;
        assert_eq!(m.n(), 6);
        assert_eq!(m.weight(3, 3), 1.0);
        assert!(m.neighbors[3].is_empty());
        // the active block is the ring(5) metropolis matrix over {0,1,2,4,5}
        assert_eq!(epochs[1].adj[2], vec![1, 4]);
        let (deg_sum, deg_max) = epochs[1].degrees();
        assert_eq!(deg_sum, 10);
        assert_eq!(deg_max, 2);
        assert!(epochs[1].rho < 1.0);
    }

    #[test]
    fn validation_catches_impossible_histories() {
        let topo = Topology::Ring(4);
        // join of an already-active worker
        assert!(MembershipPlan::parse("join@3:1").unwrap().epochs(&topo, 10).is_err());
        // leave of a never-joined worker
        assert!(MembershipPlan::parse("join@3:1,leave@5:1")
            .unwrap()
            .epochs(&topo, 10)
            .is_err());
        // crash of an inactive worker
        assert!(MembershipPlan::parse("leave@2:1,crash@5:1")
            .unwrap()
            .epochs(&topo, 10)
            .is_err());
        // out-of-range round / worker
        assert!(MembershipPlan::parse("leave@20:1").unwrap().epochs(&topo, 10).is_err());
        assert!(MembershipPlan::parse("leave@2:9").unwrap().epochs(&topo, 10).is_err());
        // a valid leave+rejoin of the same worker is fine
        assert!(MembershipPlan::parse("leave@2:1,join@5:1")
            .unwrap()
            .epochs(&topo, 10)
            .is_ok());
        // torus cannot resize
        assert!(MembershipPlan::parse("leave@2:1")
            .unwrap()
            .epochs(&Topology::Torus(2, 2), 10)
            .is_err());
        // crash-only plans never resize, so torus is fine there
        assert!(MembershipPlan::parse("crash@2:1")
            .unwrap()
            .epochs(&Topology::Torus(2, 2), 10)
            .is_ok());
    }

    #[test]
    fn all_joiners_need_an_established_bootstrapper() {
        // ring(2): worker 1 leaves, later rejoins — bootstrapper must be 0.
        let topo = Topology::Ring(2);
        let p = MembershipPlan::parse("leave@2:1,join@4:1").unwrap();
        let epochs = p.epochs(&topo, 8).unwrap();
        assert_eq!(epochs[2].joins, vec![(1, 0)]);
    }
}
