//! Versioned, checksummed **snapshots** — the durability format of the
//! elastic runtime.
//!
//! A [`Snapshot`] is everything one worker needs to come back from the dead
//! bitwise-exactly: its model, its engine's persistent state (compressor
//! replicas, error-feedback accumulators, variance-reduction history — the
//! per-algorithm blob written by [`SyncAlgorithm::snapshot`]), its node-local
//! ledger contribution ([`NodeTrace`]: per-round losses/θ/traffic/wall
//! times, eval snapshots, wire counters), the training cursors (round, lr,
//! g∞), all encoded with the same magic/version/FNV discipline as
//! [`transport::Frame`](crate::transport::Frame):
//!
//! ```text
//!  offset  size  field
//!  ------  ----  -----------------------------------------------------
//!       0     4  magic        b"MQSS"
//!       4     2  version      snapshot-format version (currently 1)
//!       6     2  worker       worker id the snapshot belongs to
//!       8     2  algo         algorithm wire id (cross-algorithm restores
//!                             are refused before any state is touched)
//!      10     8  round        last round this worker fully completed
//!      18     4  lr           learning rate after `round` (f32 bits)
//!      22     8  g_inf        node-local gradient ∞-norm running max
//!      30     …  model        u32 length + f32 little-endian words
//!       …     …  engine       u32 length + per-algorithm state blob
//!       …     …  trace        [`NodeTrace`] section
//!    end-8     8  checksum    FNV-1a over every preceding byte
//! ```
//!
//! Decoding is total: malformed input maps to a typed [`SnapshotError`],
//! fuzzed by `tests/snapshot_roundtrip.rs` exactly like the frame codec.
//!
//! The module also owns the [`FrameLog`] — the receive-side write-ahead log
//! that makes crash recovery *exact*: every frame a worker consumes (or
//! parks) after its last checkpoint is appended to the log, so a recovering
//! worker can replay the rounds between its snapshot and the crash against
//! the very bytes its peers shipped, without asking anyone to retransmit.
//!
//! [`SyncAlgorithm::snapshot`]: crate::algorithms::SyncAlgorithm::snapshot

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::algorithms::CommStats;
use crate::quant::hash::fnv1a_bytes;
use crate::transport::{Frame, FrameError};

/// Leading magic of every snapshot.
pub const MAGIC: [u8; 4] = *b"MQSS";
/// Current snapshot-format version.
pub const VERSION: u16 = 1;
/// Fixed header bytes before the variable sections.
pub const HEADER_LEN: usize = 30;
/// Upper bound on any length prefix inside a snapshot (1 GiB of f32s) —
/// rejects absurd lengths before allocation, like `Frame::MAX_PAYLOAD`.
pub const MAX_SECTION: usize = 1 << 28;

/// Typed decode/restore failures. Mirrors
/// [`FrameError`](crate::transport::FrameError): every variant carries
/// enough context to debug a corrupt checkpoint without a hex dump.
#[derive(Clone, Debug, PartialEq)]
pub enum SnapshotError {
    /// Fewer bytes than a section or the fixed header needs.
    Truncated { expected: usize, got: usize },
    /// Bytes left over after the last section — framing disagreement.
    TrailingBytes { expected: usize, got: usize },
    /// First four bytes are not [`MAGIC`].
    BadMagic([u8; 4]),
    /// Unknown snapshot-format version.
    BadVersion(u16),
    /// A length prefix exceeds [`MAX_SECTION`].
    Oversize(usize),
    /// FNV-1a over the body does not match the checksum field.
    ChecksumMismatch { expected: u64, got: u64 },
    /// The snapshot was written by a different algorithm (wire id).
    AlgoMismatch { expected: u16, got: u16 },
    /// Engine-state blob disagrees with the engine's shape (worker count,
    /// dimension) or carries an invalid tag.
    Malformed(&'static str),
}

impl std::fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SnapshotError::Truncated { expected, got } => {
                write!(f, "truncated snapshot: need {expected} bytes, got {got}")
            }
            SnapshotError::TrailingBytes { expected, got } => {
                write!(f, "snapshot length mismatch: sections end at {expected}, got {got}")
            }
            SnapshotError::BadMagic(m) => write!(f, "bad snapshot magic {m:02x?}"),
            SnapshotError::BadVersion(v) => write!(f, "unsupported snapshot version {v}"),
            SnapshotError::Oversize(n) => {
                write!(f, "section length {n} exceeds MAX_SECTION")
            }
            SnapshotError::ChecksumMismatch { expected, got } => write!(
                f,
                "snapshot checksum mismatch: stored {expected:#018x}, computed {got:#018x}"
            ),
            SnapshotError::AlgoMismatch { expected, got } => write!(
                f,
                "snapshot belongs to algorithm id {got}, restore target is id {expected}"
            ),
            SnapshotError::Malformed(what) => write!(f, "malformed snapshot state: {what}"),
        }
    }
}

impl std::error::Error for SnapshotError {}

// ---------------------------------------------------------------- encoding

/// Append little-endian scalars to a state blob. Free functions (not a
/// writer struct) so engine `snapshot` impls stay one-liners.
pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}
pub fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}
pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}
pub fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}
pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

/// Length-prefixed f32 vector (bit-exact: values travel as raw bits).
pub fn put_f32_slice(out: &mut Vec<u8>, xs: &[f32]) {
    put_u32(out, xs.len() as u32);
    out.reserve(4 * xs.len());
    for &x in xs {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

/// Length-prefixed byte section.
pub fn put_bytes(out: &mut Vec<u8>, bytes: &[u8]) {
    put_u32(out, bytes.len() as u32);
    out.extend_from_slice(bytes);
}

/// Cursor over a state blob with typed truncation errors. Engine `restore`
/// impls take everything through this so no length arithmetic is ever
/// duplicated.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], SnapshotError> {
        if self.buf.len() - self.pos < n {
            return Err(SnapshotError::Truncated {
                expected: self.pos + n,
                got: self.buf.len(),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn take_u8(&mut self) -> Result<u8, SnapshotError> {
        Ok(self.take(1)?[0])
    }
    pub fn take_u16(&mut self) -> Result<u16, SnapshotError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    pub fn take_u32(&mut self) -> Result<u32, SnapshotError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn take_u64(&mut self) -> Result<u64, SnapshotError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn take_f32(&mut self) -> Result<f32, SnapshotError> {
        Ok(f32::from_bits(self.take_u32()?))
    }
    pub fn take_f64(&mut self) -> Result<f64, SnapshotError> {
        Ok(f64::from_bits(self.take_u64()?))
    }

    /// Length-prefixed f32 vector written by [`put_f32_slice`].
    pub fn take_f32_vec(&mut self) -> Result<Vec<f32>, SnapshotError> {
        let n = self.take_u32()? as usize;
        if n > MAX_SECTION / 4 {
            return Err(SnapshotError::Oversize(n));
        }
        let bytes = self.take(4 * n)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
            .collect())
    }

    /// As [`Self::take_f32_vec`] but into an existing buffer whose length
    /// must match (engine state with a fixed shape).
    pub fn take_f32_into(&mut self, out: &mut [f32]) -> Result<(), SnapshotError> {
        let n = self.take_u32()? as usize;
        if n != out.len() {
            return Err(SnapshotError::Malformed("f32 section length != engine shape"));
        }
        let bytes = self.take(4 * n)?;
        for (o, c) in out.iter_mut().zip(bytes.chunks_exact(4)) {
            *o = f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(())
    }

    /// Length-prefixed byte section written by [`put_bytes`].
    pub fn take_bytes(&mut self) -> Result<&'a [u8], SnapshotError> {
        let n = self.take_u32()? as usize;
        if n > MAX_SECTION {
            return Err(SnapshotError::Oversize(n));
        }
        self.take(n)
    }

    /// Assert the blob is fully consumed — trailing garbage is corruption.
    pub fn finish(self) -> Result<(), SnapshotError> {
        if self.pos != self.buf.len() {
            return Err(SnapshotError::TrailingBytes {
                expected: self.pos,
                got: self.buf.len(),
            });
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- trace

/// One worker's accumulated contribution to the run's
/// [`RoundLedger`](crate::coordinator) — per-round losses, θ, traffic
/// stats, wall times, eval snapshots, and wire counters — indexed by
/// absolute round starting at `start_round` (a joiner's trace starts at its
/// join round). Carried inside every [`Snapshot`] so a recovered worker
/// reports exactly what the uninterrupted worker would have.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct NodeTrace {
    /// First round this worker participated in (0 for founding members).
    pub start_round: u64,
    /// Absolute round of each recorded entry, strictly increasing but not
    /// necessarily contiguous (a leave+rejoin worker has a gap).
    pub rounds: Vec<u64>,
    pub losses: Vec<f64>,
    pub thetas: Vec<Option<f64>>,
    pub stats: Vec<CommStats>,
    pub grad_wall: Vec<f64>,
    pub algo_wall: Vec<f64>,
    /// `(round, model)` eval snapshots (rounds where the trainer traces).
    pub evals: Vec<(u64, Vec<f32>)>,
    /// Frames actually shipped through the transport.
    pub frames_sent: u64,
    /// Measured wire bytes (header + payload) shipped.
    pub bytes_sent: u64,
}

impl NodeTrace {
    pub fn starting_at(start_round: u64) -> Self {
        NodeTrace { start_round, ..NodeTrace::default() }
    }

    /// Rounds recorded so far.
    pub fn len(&self) -> usize {
        self.losses.len()
    }

    /// Pre-size the per-round vectors for `additional` more rounds (§Perf:
    /// the cluster node reserves its whole run up front so steady-state
    /// `push_round`s never hit an amortized growth reallocation —
    /// `tests/alloc_discipline.rs` counts on it).
    pub fn reserve(&mut self, additional: usize) {
        self.rounds.reserve(additional);
        self.losses.reserve(additional);
        self.thetas.reserve(additional);
        self.stats.reserve(additional);
        self.grad_wall.reserve(additional);
        self.algo_wall.reserve(additional);
    }

    pub fn is_empty(&self) -> bool {
        self.losses.is_empty()
    }

    /// Index of an absolute round in the per-round vectors.
    fn idx(&self, round: u64) -> Option<usize> {
        self.rounds.binary_search(&round).ok()
    }

    pub fn loss_at(&self, round: u64) -> Option<f64> {
        self.idx(round).map(|i| self.losses[i])
    }

    pub fn theta_at(&self, round: u64) -> Option<Option<f64>> {
        self.idx(round).map(|i| self.thetas[i])
    }

    pub fn stats_at(&self, round: u64) -> Option<CommStats> {
        self.idx(round).map(|i| self.stats[i])
    }

    pub fn grad_wall_at(&self, round: u64) -> Option<f64> {
        self.idx(round).map(|i| self.grad_wall[i])
    }

    pub fn algo_wall_at(&self, round: u64) -> Option<f64> {
        self.idx(round).map(|i| self.algo_wall[i])
    }

    /// Eval snapshot recorded at `round`, if any.
    pub fn eval_at(&self, round: u64) -> Option<&[f32]> {
        self.evals
            .iter()
            .find(|(r, _)| *r == round)
            .map(|(_, x)| x.as_slice())
    }

    /// Record one completed round (must be called in strictly increasing
    /// round order; gaps are fine — a rejoin resumes at a later round).
    #[allow(clippy::too_many_arguments)]
    pub fn push_round(
        &mut self,
        round: u64,
        loss: f64,
        theta: Option<f64>,
        stats: CommStats,
        grad_wall: f64,
        algo_wall: f64,
    ) {
        debug_assert!(match self.rounds.last() {
            Some(&last) => last < round,
            None => true,
        });
        self.rounds.push(round);
        self.losses.push(loss);
        self.thetas.push(theta);
        self.stats.push(stats);
        self.grad_wall.push(grad_wall);
        self.algo_wall.push(algo_wall);
    }

    fn encode_into(&self, out: &mut Vec<u8>) {
        put_u64(out, self.start_round);
        put_u32(out, self.len() as u32);
        for i in 0..self.len() {
            put_u64(out, self.rounds[i]);
            put_f64(out, self.losses[i]);
            match self.thetas[i] {
                None => put_u8(out, 0),
                Some(t) => {
                    put_u8(out, 1);
                    put_f64(out, t);
                }
            }
            let s = &self.stats[i];
            put_u64(out, s.bytes_per_msg as u64);
            put_u64(out, s.messages);
            match s.allreduce_bytes {
                None => put_u8(out, 0),
                Some(b) => {
                    put_u8(out, 1);
                    put_u64(out, b as u64);
                }
            }
            put_u32(out, s.extra_local_passes);
            put_f64(out, self.grad_wall[i]);
            put_f64(out, self.algo_wall[i]);
        }
        put_u32(out, self.evals.len() as u32);
        for (round, x) in &self.evals {
            put_u64(out, *round);
            put_f32_slice(out, x);
        }
        put_u64(out, self.frames_sent);
        put_u64(out, self.bytes_sent);
    }

    fn decode_from(r: &mut Reader<'_>) -> Result<NodeTrace, SnapshotError> {
        let start_round = r.take_u64()?;
        let rounds = r.take_u32()? as usize;
        if rounds > MAX_SECTION {
            return Err(SnapshotError::Oversize(rounds));
        }
        let mut t = NodeTrace::starting_at(start_round);
        let mut prev: Option<u64> = None;
        for _ in 0..rounds {
            let round = r.take_u64()?;
            if prev.is_some() && prev >= Some(round) {
                return Err(SnapshotError::Malformed("trace rounds not increasing"));
            }
            prev = Some(round);
            let loss = r.take_f64()?;
            let theta = match r.take_u8()? {
                0 => None,
                1 => Some(r.take_f64()?),
                _ => return Err(SnapshotError::Malformed("theta tag")),
            };
            let bytes_per_msg = r.take_u64()? as usize;
            let messages = r.take_u64()?;
            let allreduce_bytes = match r.take_u8()? {
                0 => None,
                1 => Some(r.take_u64()? as usize),
                _ => return Err(SnapshotError::Malformed("allreduce tag")),
            };
            let extra_local_passes = r.take_u32()?;
            let grad_wall = r.take_f64()?;
            let algo_wall = r.take_f64()?;
            t.push_round(
                round,
                loss,
                theta,
                CommStats { bytes_per_msg, messages, allreduce_bytes, extra_local_passes },
                grad_wall,
                algo_wall,
            );
        }
        let evals = r.take_u32()? as usize;
        if evals > MAX_SECTION {
            return Err(SnapshotError::Oversize(evals));
        }
        for _ in 0..evals {
            let round = r.take_u64()?;
            let x = r.take_f32_vec()?;
            t.evals.push((round, x));
        }
        t.frames_sent = r.take_u64()?;
        t.bytes_sent = r.take_u64()?;
        Ok(t)
    }
}

// ---------------------------------------------------------------- snapshot

/// One worker's full recoverable state at a round boundary (module docs
/// have the wire diagram).
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    pub worker: u16,
    /// Algorithm wire id ([`algo_wire_id`](crate::transport::algo_wire_id)).
    pub algo: u16,
    /// Last round this worker fully completed (send + recv + trace).
    pub round: u64,
    /// Learning rate in effect *after* `round` (decays already applied).
    pub lr: f32,
    /// Node-local gradient ∞-norm running max.
    pub g_inf: f64,
    /// The model at the end of `round`.
    pub model: Vec<f32>,
    /// Per-algorithm persistent state ([`SyncAlgorithm::snapshot`]).
    ///
    /// [`SyncAlgorithm::snapshot`]: crate::algorithms::SyncAlgorithm::snapshot
    pub engine: Vec<u8>,
    /// The worker's ledger contribution up to and including `round`.
    pub trace: NodeTrace,
}

impl Snapshot {
    /// Serialize into a fresh checksummed buffer.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            HEADER_LEN + 8 + 4 * self.model.len() + self.engine.len() + 64,
        );
        out.extend_from_slice(&MAGIC);
        put_u16(&mut out, VERSION);
        put_u16(&mut out, self.worker);
        put_u16(&mut out, self.algo);
        put_u64(&mut out, self.round);
        put_f32(&mut out, self.lr);
        put_f64(&mut out, self.g_inf);
        put_f32_slice(&mut out, &self.model);
        put_bytes(&mut out, &self.engine);
        self.trace.encode_into(&mut out);
        let h = fnv1a_bytes(&out);
        put_u64(&mut out, h);
        out
    }

    /// Total decode: every malformed input maps to a typed
    /// [`SnapshotError`] — no panics, no partial state.
    pub fn decode(bytes: &[u8]) -> Result<Snapshot, SnapshotError> {
        if bytes.len() < HEADER_LEN + 8 {
            return Err(SnapshotError::Truncated {
                expected: HEADER_LEN + 8,
                got: bytes.len(),
            });
        }
        if bytes[0..4] != MAGIC {
            return Err(SnapshotError::BadMagic([bytes[0], bytes[1], bytes[2], bytes[3]]));
        }
        let body = &bytes[..bytes.len() - 8];
        let stored =
            u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        let computed = fnv1a_bytes(body);
        if stored != computed {
            return Err(SnapshotError::ChecksumMismatch {
                expected: stored,
                got: computed,
            });
        }
        let mut r = Reader::new(&body[4..]);
        let version = r.take_u16()?;
        if version != VERSION {
            return Err(SnapshotError::BadVersion(version));
        }
        let worker = r.take_u16()?;
        let algo = r.take_u16()?;
        let round = r.take_u64()?;
        let lr = r.take_f32()?;
        let g_inf = r.take_f64()?;
        let model = r.take_f32_vec()?;
        let engine = r.take_bytes()?.to_vec();
        let trace = NodeTrace::decode_from(&mut r)?;
        r.finish()?;
        Ok(Snapshot { worker, algo, round, lr, g_inf, model, engine, trace })
    }
}

// ---------------------------------------------------------------- storage

/// Checkpoint file for worker `i` inside `dir`.
pub fn ckpt_path(dir: &Path, worker: usize) -> PathBuf {
    dir.join(format!("ckpt_w{worker}.mqss"))
}

/// Frame-log file for worker `i` inside `dir`.
pub fn log_path(dir: &Path, worker: usize) -> PathBuf {
    dir.join(format!("frames_w{worker}.mqfl"))
}

/// Write a snapshot atomically (tmp file + rename): a crash mid-write can
/// never leave a torn checkpoint, only the previous one.
pub fn write_checkpoint(dir: &Path, snap: &Snapshot) -> std::io::Result<()> {
    fs::create_dir_all(dir)?;
    let path = ckpt_path(dir, snap.worker as usize);
    let tmp = path.with_extension("mqss.tmp");
    {
        let mut f = fs::File::create(&tmp)?;
        f.write_all(&snap.encode())?;
        f.sync_all()?;
    }
    fs::rename(&tmp, &path)
}

/// Load worker `i`'s checkpoint. `Ok(None)` when none was ever written
/// (recovery restarts from genesis); decode failures are real errors — a
/// corrupt checkpoint must fail the run loudly, not silently re-init.
pub fn load_checkpoint(
    dir: &Path,
    worker: usize,
) -> Result<Option<Snapshot>, SnapshotError> {
    let path = ckpt_path(dir, worker);
    let bytes = match fs::read(&path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => {
            return Err(SnapshotError::Malformed(match e.kind() {
                std::io::ErrorKind::PermissionDenied => "checkpoint unreadable",
                _ => "checkpoint io error",
            }))
        }
    };
    Snapshot::decode(&bytes).map(Some)
}

/// Receive-side write-ahead log: length-prefixed encoded frames, truncated
/// at every checkpoint (after re-appending still-pending future frames, so
/// the invariant *log = everything received since the snapshot* holds).
pub struct FrameLog {
    path: PathBuf,
    file: fs::File,
}

impl FrameLog {
    /// Open (creating/truncating) worker `i`'s log under `dir`.
    pub fn create(dir: &Path, worker: usize) -> std::io::Result<FrameLog> {
        fs::create_dir_all(dir)?;
        let path = log_path(dir, worker);
        let file = fs::File::create(&path)?;
        Ok(FrameLog { path, file })
    }

    /// Append one frame (u32 length + the frame's own checksummed wire
    /// bytes — corruption detection comes for free from the frame codec).
    pub fn append(&mut self, frame: &Frame) -> std::io::Result<()> {
        let bytes = frame.encode();
        self.file.write_all(&(bytes.len() as u32).to_le_bytes())?;
        self.file.write_all(&bytes)
    }

    /// Drop everything logged so far (called right after a checkpoint is
    /// durably on disk).
    pub fn truncate(&mut self) -> std::io::Result<()> {
        self.file = fs::File::create(&self.path)?;
        Ok(())
    }

    /// Read a log back into frames. A trailing partial record (torn final
    /// write during the crash) is ignored; a corrupt *complete* record is a
    /// frame-codec error.
    pub fn read_all(dir: &Path, worker: usize) -> Result<Vec<Frame>, FrameError> {
        let bytes = match fs::read(log_path(dir, worker)) {
            Ok(b) => b,
            Err(_) => return Ok(Vec::new()),
        };
        let mut frames = Vec::new();
        let mut pos = 0usize;
        while bytes.len() - pos >= 4 {
            let len =
                u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
            if bytes.len() - pos - 4 < len {
                break; // torn tail
            }
            frames.push(Frame::decode(&bytes[pos + 4..pos + 4 + len])?);
            pos += 4 + len;
        }
        Ok(frames)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::FrameKind;

    fn sample() -> Snapshot {
        let mut trace = NodeTrace::starting_at(0);
        for k in 0..3u64 {
            trace.push_round(
                k,
                0.5 + k as f64,
                if k == 1 { Some(2.0) } else { None },
                CommStats {
                    bytes_per_msg: 24 * (k as usize + 1),
                    messages: 8,
                    allreduce_bytes: if k == 2 { Some(96) } else { None },
                    extra_local_passes: 1,
                },
                1e-3,
                2e-4,
            );
        }
        trace.evals.push((0, vec![1.0, -2.5]));
        trace.frames_sent = 24;
        trace.bytes_sent = 1234;
        Snapshot {
            worker: 3,
            algo: 4,
            round: 2,
            lr: 0.05,
            g_inf: 1.75,
            model: vec![0.25, -1.5, f32::MIN_POSITIVE, 0.0],
            engine: vec![9, 8, 7],
            trace,
        }
    }

    #[test]
    fn roundtrip_preserves_every_field() {
        let s = sample();
        let bytes = s.encode();
        let t = Snapshot::decode(&bytes).unwrap();
        assert_eq!(s, t);
    }

    #[test]
    fn every_truncation_is_typed() {
        let bytes = sample().encode();
        for cut in 0..bytes.len() {
            match Snapshot::decode(&bytes[..cut]) {
                Err(
                    SnapshotError::Truncated { .. }
                    | SnapshotError::ChecksumMismatch { .. }
                    | SnapshotError::Oversize(_),
                ) => {}
                other => panic!("cut={cut}: {other:?}"),
            }
        }
    }

    #[test]
    fn corruption_is_detected() {
        let good = sample().encode();
        for pos in [0usize, 4, 6, 11, 40, good.len() - 1] {
            let mut bad = good.clone();
            bad[pos] ^= 0x10;
            assert!(Snapshot::decode(&bad).is_err(), "pos {pos}");
        }
    }

    #[test]
    fn trace_lookup_by_absolute_round() {
        let mut t = NodeTrace::starting_at(10);
        t.push_round(10, 1.0, None, CommStats::default(), 0.0, 0.0);
        t.push_round(11, 2.0, Some(0.5), CommStats::default(), 0.0, 0.0);
        assert_eq!(t.loss_at(10), Some(1.0));
        assert_eq!(t.loss_at(11), Some(2.0));
        assert_eq!(t.loss_at(9), None);
        assert_eq!(t.loss_at(12), None);
        assert_eq!(t.theta_at(11), Some(Some(0.5)));
    }

    #[test]
    fn checkpoint_store_roundtrip_and_genesis() {
        let dir = std::env::temp_dir()
            .join(format!("moniqua-snap-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        assert_eq!(load_checkpoint(&dir, 0).unwrap(), None);
        let s = sample();
        write_checkpoint(&dir, &s).unwrap();
        assert_eq!(load_checkpoint(&dir, 3).unwrap(), Some(s));
        // another worker's slot is still genesis
        assert_eq!(load_checkpoint(&dir, 1).unwrap(), None);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn frame_log_roundtrip_and_torn_tail() {
        let dir = std::env::temp_dir()
            .join(format!("moniqua-framelog-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let mut log = FrameLog::create(&dir, 2).unwrap();
        let mk = |round: u64, sender: u16| Frame {
            round,
            sender,
            algo: 4,
            bits: 8,
            kind: FrameKind::Data,
            theta: 1.0,
            payload: vec![sender as u8; 5],
        };
        log.append(&mk(0, 1)).unwrap();
        log.append(&mk(1, 0)).unwrap();
        drop(log);
        let frames = FrameLog::read_all(&dir, 2).unwrap();
        assert_eq!(frames.len(), 2);
        assert_eq!((frames[0].round, frames[0].sender), (0, 1));
        // torn tail: append garbage length prefix + partial bytes
        {
            let mut f = fs::OpenOptions::new()
                .append(true)
                .open(log_path(&dir, 2))
                .unwrap();
            f.write_all(&[200, 0, 0, 0, 1, 2, 3]).unwrap();
        }
        let frames = FrameLog::read_all(&dir, 2).unwrap();
        assert_eq!(frames.len(), 2, "torn tail ignored");
        // truncate drops everything
        let mut log = FrameLog::create(&dir, 2).unwrap();
        log.truncate().unwrap();
        drop(log);
        assert!(FrameLog::read_all(&dir, 2).unwrap().is_empty());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_log_is_empty() {
        let dir = std::env::temp_dir().join("moniqua-framelog-missing");
        assert!(FrameLog::read_all(&dir, 9).unwrap().is_empty());
    }
}
