//! Tiny character-level corpus for the end-to-end transformer driver.
//!
//! The AOT transformer LM (vocab 64) trains on byte-folded text. A built-in
//! synthetic corpus (structured, so the LM has something learnable) keeps
//! the example self-contained; `Corpus::from_text` accepts any external
//! file.

use crate::rng::Pcg64;

/// Character-level token stream with a fixed 64-symbol vocabulary.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub tokens: Vec<i32>,
    pub vocab: usize,
}

impl Corpus {
    /// Fold arbitrary text into the 64-symbol vocab: lowercase letters,
    /// digits, common punctuation, everything else -> space.
    pub fn from_text(text: &str, vocab: usize) -> Self {
        assert!(vocab >= 40, "vocab too small for the char map");
        let tokens = text.bytes().map(|b| Self::fold(b, vocab)).collect();
        Corpus { tokens, vocab }
    }

    fn fold(b: u8, vocab: usize) -> i32 {
        let id = match b {
            b'a'..=b'z' => 1 + (b - b'a') as i32,          // 1..=26
            b'A'..=b'Z' => 1 + (b - b'A') as i32,
            b'0'..=b'9' => 27 + (b - b'0') as i32,          // 27..=36
            b'.' => 37,
            b',' => 38,
            b'!' => 39,
            _ => 0,                                         // space / other
        };
        id.min(vocab as i32 - 1)
    }

    /// Built-in synthetic corpus: a Markov-ish word salad with strong local
    /// structure (repeated vocabulary, consistent spelling) so next-token
    /// loss visibly drops below the uniform baseline within a few hundred
    /// steps.
    pub fn synthetic(n_tokens: usize, seed: u64) -> Self {
        const WORDS: [&str; 16] = [
            "decentralized", "gradient", "descent", "moniqua", "modulo",
            "quantized", "communication", "worker", "consensus", "theta",
            "spectral", "gossip", "stochastic", "rounding", "bandwidth",
            "latency",
        ];
        let mut rng = Pcg64::new(seed, 0xC0B5);
        let mut text = String::with_capacity(n_tokens + 16);
        // Biased bigram chain over the word list.
        let mut prev = 0usize;
        while text.len() < n_tokens {
            let next = if rng.next_f32() < 0.6 {
                (prev + 1) % WORDS.len() // predictable transition
            } else {
                rng.below(WORDS.len() as u64) as usize
            };
            text.push_str(WORDS[next]);
            text.push(if rng.next_f32() < 0.1 { '.' } else { ' ' });
            prev = next;
        }
        Self::from_text(&text, 64)
    }

    /// Sample a batch of windows as a row-major [batch, seq] i32 buffer.
    pub fn sample_batch(&self, batch: usize, seq: usize, rng: &mut Pcg64) -> Vec<i32> {
        assert!(self.tokens.len() > seq + 1, "corpus shorter than seq_len");
        let mut out = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let start = rng.below((self.tokens.len() - seq) as u64) as usize;
            out.extend_from_slice(&self.tokens[start..start + seq]);
        }
        out
    }

    /// Disjoint contiguous shards for decentralized training.
    pub fn shard(&self, n_workers: usize) -> Vec<Corpus> {
        let chunk = self.tokens.len() / n_workers;
        assert!(chunk > 2, "corpus too small for {n_workers} shards");
        (0..n_workers)
            .map(|w| Corpus {
                tokens: self.tokens[w * chunk..(w + 1) * chunk].to_vec(),
                vocab: self.vocab,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fold_maps_into_vocab() {
        let c = Corpus::from_text("Hello, World! 42", 64);
        assert!(c.tokens.iter().all(|&t| (0..64).contains(&t)));
        // 'H' and 'h' fold together.
        let h1 = Corpus::from_text("H", 64).tokens[0];
        let h2 = Corpus::from_text("h", 64).tokens[0];
        assert_eq!(h1, h2);
    }

    #[test]
    fn synthetic_is_deterministic_and_sized() {
        let a = Corpus::synthetic(5000, 3);
        let b = Corpus::synthetic(5000, 3);
        assert_eq!(a.tokens, b.tokens);
        assert!(a.tokens.len() >= 5000);
    }

    #[test]
    fn synthetic_has_structure() {
        // Bigram entropy must be well below uniform log2(64)=6 bits.
        let c = Corpus::synthetic(20000, 1);
        let mut uni = [0f64; 64];
        for &t in &c.tokens {
            uni[t as usize] += 1.0;
        }
        let total: f64 = uni.iter().sum();
        let ent: f64 = uni
            .iter()
            .filter(|&&x| x > 0.0)
            .map(|&x| {
                let p = x / total;
                -p * p.log2()
            })
            .sum();
        assert!(ent < 5.0, "unigram entropy {ent}");
    }

    #[test]
    fn batches_and_shards() {
        let c = Corpus::synthetic(10000, 2);
        let mut rng = Pcg64::seeded(0);
        let b = c.sample_batch(4, 32, &mut rng);
        assert_eq!(b.len(), 4 * 32);
        let shards = c.shard(4);
        assert_eq!(shards.len(), 4);
        let total: usize = shards.iter().map(|s| s.tokens.len()).sum();
        assert!(total <= c.tokens.len());
        assert!(shards.iter().all(|s| s.vocab == 64));
    }
}
