//! Synthetic datasets and decentralized partitioning.
//!
//! Substitutes for CIFAR10 (DESIGN.md §Hardware-Adaptation): a Gaussian
//! mixture classification task with the same *mechanism* the paper's
//! experiments exercise — in particular the D² experiment's "one exclusive
//! label per worker" split that maximizes the outer variance ς².

pub mod corpus;
pub mod partition;

use crate::rng::Pcg64;

/// One labeled example (dense features).
#[derive(Clone, Debug)]
pub struct Example {
    pub x: Vec<f32>,
    pub label: usize,
}

/// A classification dataset: k Gaussian blobs in R^dim.
#[derive(Clone, Debug)]
pub struct SynthClassification {
    pub dim: usize,
    pub classes: usize,
    pub train: Vec<Example>,
    pub test: Vec<Example>,
}

/// Generation parameters.
#[derive(Clone, Copy, Debug)]
pub struct SynthSpec {
    pub dim: usize,
    pub classes: usize,
    pub train_per_class: usize,
    pub test_per_class: usize,
    /// Distance of class means from the origin (separability).
    pub mean_scale: f32,
    /// Within-class standard deviation.
    pub noise: f32,
    pub seed: u64,
}

impl Default for SynthSpec {
    fn default() -> Self {
        SynthSpec {
            dim: 32,
            classes: 10,
            train_per_class: 200,
            test_per_class: 40,
            mean_scale: 2.0,
            noise: 1.0,
            seed: 1234,
        }
    }
}

impl SynthClassification {
    pub fn generate(spec: SynthSpec) -> Self {
        let mut rng = Pcg64::new(spec.seed, 0xDA7A);
        // Class means: random unit-ish directions scaled by mean_scale.
        let means: Vec<Vec<f32>> = (0..spec.classes)
            .map(|_| {
                let v: Vec<f32> =
                    (0..spec.dim).map(|_| rng.next_gaussian() as f32).collect();
                let norm = crate::linalg::norm2(&v) as f32;
                v.iter().map(|&x| x / norm * spec.mean_scale).collect()
            })
            .collect();
        let gen_split = |per_class: usize, rng: &mut Pcg64| {
            let mut out = Vec::with_capacity(per_class * spec.classes);
            for (label, mean) in means.iter().enumerate() {
                for _ in 0..per_class {
                    let x: Vec<f32> = mean
                        .iter()
                        .map(|&m| m + rng.next_gaussian() as f32 * spec.noise)
                        .collect();
                    out.push(Example { x, label });
                }
            }
            rng.shuffle(&mut out);
            out
        };
        let train = gen_split(spec.train_per_class, &mut rng);
        let test = gen_split(spec.test_per_class, &mut rng);
        SynthClassification { dim: spec.dim, classes: spec.classes, train, test }
    }

    /// Default dataset used in examples/benches.
    pub fn default_dataset() -> Self {
        Self::generate(SynthSpec::default())
    }
}

impl Default for SynthClassification {
    fn default() -> Self {
        Self::default_dataset()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_sizes() {
        let ds = SynthClassification::generate(SynthSpec {
            classes: 3,
            train_per_class: 10,
            test_per_class: 4,
            ..SynthSpec::default()
        });
        assert_eq!(ds.train.len(), 30);
        assert_eq!(ds.test.len(), 12);
        assert!(ds.train.iter().all(|e| e.x.len() == ds.dim && e.label < 3));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = SynthClassification::generate(SynthSpec::default());
        let b = SynthClassification::generate(SynthSpec::default());
        assert_eq!(a.train[0].x, b.train[0].x);
        assert_eq!(a.train[7].label, b.train[7].label);
    }

    #[test]
    fn classes_are_separable() {
        // A nearest-class-mean classifier should beat chance comfortably.
        let ds = SynthClassification::generate(SynthSpec {
            mean_scale: 3.0,
            noise: 0.8,
            ..SynthSpec::default()
        });
        // Estimate class means from train.
        let mut means = vec![vec![0.0f64; ds.dim]; ds.classes];
        let mut counts = vec![0usize; ds.classes];
        for e in &ds.train {
            for (m, &x) in means[e.label].iter_mut().zip(&e.x) {
                *m += x as f64;
            }
            counts[e.label] += 1;
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c as f64;
            }
        }
        let mut correct = 0;
        for e in &ds.test {
            let pred = (0..ds.classes)
                .min_by(|&a, &b| {
                    let da: f64 = means[a]
                        .iter()
                        .zip(&e.x)
                        .map(|(m, &x)| (m - x as f64).powi(2))
                        .sum();
                    let db: f64 = means[b]
                        .iter()
                        .zip(&e.x)
                        .map(|(m, &x)| (m - x as f64).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if pred == e.label {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.test.len() as f64;
        assert!(acc > 0.5, "nearest-mean accuracy {acc}");
    }
}
