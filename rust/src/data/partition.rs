//! Dataset partitioning across workers.
//!
//! `Iid` shuffles examples uniformly — D-PSGD's assumption (A3) with small
//! outer variance ς². `ByLabel` gives each worker exclusive classes — the D²
//! experiment's setup (Figure 2a) that *maximizes* ς² and breaks D-PSGD.

use super::Example;
use crate::rng::Pcg64;

/// How to split a dataset across n workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partition {
    /// Uniform random split (identical distributions).
    Iid,
    /// Worker i receives only classes ≡ i (mod n): maximal outer variance.
    ByLabel,
}

impl Partition {
    /// Produce per-worker index lists into `examples`.
    pub fn split(
        &self,
        examples: &[Example],
        n_workers: usize,
        seed: u64,
    ) -> Vec<Vec<usize>> {
        assert!(n_workers > 0);
        let mut shards = vec![Vec::new(); n_workers];
        match self {
            Partition::Iid => {
                let mut idx: Vec<usize> = (0..examples.len()).collect();
                Pcg64::new(seed, 0x5011).shuffle(&mut idx);
                for (k, i) in idx.into_iter().enumerate() {
                    shards[k % n_workers].push(i);
                }
            }
            Partition::ByLabel => {
                for (i, e) in examples.iter().enumerate() {
                    shards[e.label % n_workers].push(i);
                }
            }
        }
        shards
    }

    /// Outer-variance proxy: mean squared distance between per-worker label
    /// histograms and the global histogram. 0 for perfectly IID shards.
    pub fn label_skew(examples: &[Example], shards: &[Vec<usize>], classes: usize) -> f64 {
        let n = shards.len();
        let mut global = vec![0.0f64; classes];
        for e in examples {
            global[e.label] += 1.0;
        }
        let total: f64 = global.iter().sum();
        for g in global.iter_mut() {
            *g /= total;
        }
        let mut skew = 0.0;
        for shard in shards {
            if shard.is_empty() {
                continue;
            }
            let mut hist = vec![0.0f64; classes];
            for &i in shard {
                hist[examples[i].label] += 1.0;
            }
            let t: f64 = hist.iter().sum();
            for h in hist.iter_mut() {
                *h /= t;
            }
            skew += hist
                .iter()
                .zip(&global)
                .map(|(h, g)| (h - g).powi(2))
                .sum::<f64>();
        }
        skew / n as f64
    }
}

/// Per-worker mini-batch sampler over a shard (with-replacement sampling,
/// matching the stochastic-gradient model of the analysis).
#[derive(Clone, Debug)]
pub struct ShardSampler {
    shard: Vec<usize>,
    rng: Pcg64,
}

impl ShardSampler {
    pub fn new(shard: Vec<usize>, seed: u64, worker: usize) -> Self {
        assert!(!shard.is_empty(), "worker {worker} got an empty shard");
        ShardSampler { shard, rng: Pcg64::new(seed, 0xBA7C ^ worker as u64) }
    }

    pub fn sample_batch(&mut self, batch: usize) -> Vec<usize> {
        (0..batch)
            .map(|_| self.shard[self.rng.below(self.shard.len() as u64) as usize])
            .collect()
    }

    pub fn shard_len(&self) -> usize {
        self.shard.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SynthClassification, SynthSpec};

    fn dataset() -> SynthClassification {
        SynthClassification::generate(SynthSpec {
            classes: 10,
            train_per_class: 50,
            test_per_class: 5,
            ..SynthSpec::default()
        })
    }

    #[test]
    fn iid_split_covers_everything_evenly() {
        let ds = dataset();
        let shards = Partition::Iid.split(&ds.train, 8, 1);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, ds.train.len());
        let min = shards.iter().map(|s| s.len()).min().unwrap();
        let max = shards.iter().map(|s| s.len()).max().unwrap();
        assert!(max - min <= 1);
    }

    #[test]
    fn by_label_is_exclusive() {
        let ds = dataset();
        let shards = Partition::ByLabel.split(&ds.train, 10, 1);
        for (w, shard) in shards.iter().enumerate() {
            assert!(!shard.is_empty());
            for &i in shard {
                assert_eq!(ds.train[i].label % 10, w);
            }
        }
    }

    #[test]
    fn by_label_has_higher_skew_than_iid() {
        let ds = dataset();
        let iid = Partition::Iid.split(&ds.train, 10, 1);
        let byl = Partition::ByLabel.split(&ds.train, 10, 1);
        let s_iid = Partition::label_skew(&ds.train, &iid, ds.classes);
        let s_byl = Partition::label_skew(&ds.train, &byl, ds.classes);
        assert!(s_byl > 10.0 * s_iid, "skew iid={s_iid} bylabel={s_byl}");
    }

    #[test]
    fn sampler_samples_only_from_shard() {
        let ds = dataset();
        let shards = Partition::ByLabel.split(&ds.train, 10, 1);
        let mut s = ShardSampler::new(shards[3].clone(), 42, 3);
        for i in s.sample_batch(64) {
            assert_eq!(ds.train[i].label % 10, 3);
        }
    }

    #[test]
    fn sampler_deterministic_per_seed() {
        let shard: Vec<usize> = (0..100).collect();
        let mut a = ShardSampler::new(shard.clone(), 7, 0);
        let mut b = ShardSampler::new(shard, 7, 0);
        assert_eq!(a.sample_batch(32), b.sample_batch(32));
    }
}
