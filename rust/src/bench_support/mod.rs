//! Micro/macro benchmark harness (criterion is unavailable offline).
//!
//! [`bench`] runs a closure repeatedly with warmup, reports median /
//! mean ± stddev / min wall time; [`throughput`] converts to bytes/s.
//! The paper benches use it both for hot-path microbenchmarks
//! (bench_quant_throughput) and to time full training sweeps.

use std::time::Instant;

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    /// Bytes/s given per-iteration payload.
    pub fn throughput(&self, bytes_per_iter: usize) -> f64 {
        bytes_per_iter as f64 / self.median_s
    }

    pub fn pretty(&self) -> String {
        format!(
            "{:<40} {:>10.3} µs median  ({:>10.3} ± {:>8.3} µs, min {:>10.3}, n={})",
            self.name,
            self.median_s * 1e6,
            self.mean_s * 1e6,
            self.stddev_s * 1e6,
            self.min_s * 1e6,
            self.iters
        )
    }
}

/// Time `f` with `warmup` + `iters` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / iters as f64;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / iters as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        median_s: samples[iters / 2],
        stddev_s: var.sqrt(),
        min_s: samples[0],
    }
}

/// Convenience wrapper printing the result immediately.
pub fn bench_print<F: FnMut()>(name: &str, warmup: usize, iters: usize, f: F) -> BenchResult {
    let r = bench(name, warmup, iters, f);
    println!("{}", r.pretty());
    r
}

/// GB/s pretty printer.
pub fn print_throughput(r: &BenchResult, bytes_per_iter: usize) {
    println!(
        "{:<40} {:>8.3} GB/s ({} bytes / iter)",
        r.name,
        r.throughput(bytes_per_iter) / 1e9,
        bytes_per_iter
    );
}

/// Median-time ratio `baseline / candidate`: > 1 means the candidate is
/// faster. Used by the perf benches to report fused-vs-unfused and
/// parallel-vs-sequential speedups.
pub fn speedup(baseline: &BenchResult, candidate: &BenchResult) -> f64 {
    baseline.median_s / candidate.median_s
}

/// Pretty-print a speedup line for two results.
pub fn print_speedup(label: &str, baseline: &BenchResult, candidate: &BenchResult) {
    println!(
        "{:<40} {:>8.2}x  ({} -> {})",
        label,
        speedup(baseline, candidate),
        baseline.name,
        candidate.name
    );
}

/// Prevent the optimizer from deleting a computation.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Section header used by the figure/table benches.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop-ish", 2, 20, || {
            black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.iters, 20);
        assert!(r.min_s <= r.median_s);
        assert!(r.median_s > 0.0);
        assert!(r.mean_s > 0.0);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_s: 0.5,
            median_s: 0.5,
            stddev_s: 0.0,
            min_s: 0.5,
        };
        assert_eq!(r.throughput(1_000_000), 2_000_000.0);
    }

    #[test]
    fn speedup_ratio() {
        let mk = |median_s: f64| BenchResult {
            name: "y".into(),
            iters: 1,
            mean_s: median_s,
            median_s,
            stddev_s: 0.0,
            min_s: median_s,
        };
        assert_eq!(speedup(&mk(1.0), &mk(0.25)), 4.0);
    }
}
