//! Micro/macro benchmark harness (criterion is unavailable offline).
//!
//! [`bench`] runs a closure repeatedly with warmup, reports median /
//! mean ± stddev / min wall time; [`throughput`] converts to bytes/s.
//! The paper benches use it both for hot-path microbenchmarks
//! (bench_quant_throughput) and to time full training sweeps.

use std::time::Instant;

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    /// Bytes/s given per-iteration payload.
    pub fn throughput(&self, bytes_per_iter: usize) -> f64 {
        bytes_per_iter as f64 / self.median_s
    }

    pub fn pretty(&self) -> String {
        format!(
            "{:<40} {:>10.3} µs median  ({:>10.3} ± {:>8.3} µs, min {:>10.3}, n={})",
            self.name,
            self.median_s * 1e6,
            self.mean_s * 1e6,
            self.stddev_s * 1e6,
            self.min_s * 1e6,
            self.iters
        )
    }
}

/// True when `MONIQUA_BENCH_QUICK` (or the sweep benches' existing
/// `MONIQUA_FAST`) is set: CI's bench-smoke mode. Every [`bench`] call
/// clamps its warmup/iteration counts so the whole bench suite finishes in
/// seconds — the emitted `BENCH_*.json` files are then smoke/regression
/// artifacts, not publication-grade measurements.
pub fn quick_mode() -> bool {
    std::env::var_os("MONIQUA_BENCH_QUICK").is_some()
        || std::env::var_os("MONIQUA_FAST").is_some()
}

/// Time `f` with `warmup` + `iters` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, mut iters: usize, mut f: F) -> BenchResult {
    let mut warmup = warmup;
    if quick_mode() {
        warmup = warmup.min(1);
        iters = iters.clamp(1, 3);
    }
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / iters as f64;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / iters as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        median_s: samples[iters / 2],
        stddev_s: var.sqrt(),
        min_s: samples[0],
    }
}

/// Convenience wrapper printing the result immediately.
pub fn bench_print<F: FnMut()>(name: &str, warmup: usize, iters: usize, f: F) -> BenchResult {
    let r = bench(name, warmup, iters, f);
    println!("{}", r.pretty());
    r
}

/// GB/s pretty printer.
pub fn print_throughput(r: &BenchResult, bytes_per_iter: usize) {
    println!(
        "{:<40} {:>8.3} GB/s ({} bytes / iter)",
        r.name,
        r.throughput(bytes_per_iter) / 1e9,
        bytes_per_iter
    );
}

/// Median-time ratio `baseline / candidate`: > 1 means the candidate is
/// faster. Used by the perf benches to report fused-vs-unfused and
/// parallel-vs-sequential speedups.
pub fn speedup(baseline: &BenchResult, candidate: &BenchResult) -> f64 {
    baseline.median_s / candidate.median_s
}

/// Best-of-N ratio `baseline.min / candidate.min` — the noise-robust
/// estimator the CI-gated `speedup` metrics use. At quick-mode iteration
/// counts (1–3) a single scheduler stall moves a median past a regression
/// margin; a minimum only moves if *every* iteration stalled.
pub fn speedup_best(baseline: &BenchResult, candidate: &BenchResult) -> f64 {
    baseline.min_s / candidate.min_s
}

/// Pretty-print a speedup line for two results.
pub fn print_speedup(label: &str, baseline: &BenchResult, candidate: &BenchResult) {
    println!(
        "{:<40} {:>8.2}x  ({} -> {})",
        label,
        speedup(baseline, candidate),
        baseline.name,
        candidate.name
    );
}

/// Prevent the optimizer from deleting a computation.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Section header used by the figure/table benches.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Machine-readable bench output: every `benches/bench_*.rs` writes a
/// `BENCH_<name>.json` next to its human-readable stdout so the
/// perf-trajectory tooling can diff runs without scraping text. The format
/// is deliberately flat: `{"bench": "...", "metrics": {"key": number, …},
/// "labels": {"key": "...", …}}`. No serde offline — values are emitted
/// with enough precision to round-trip f64.
pub struct BenchJson {
    name: String,
    metrics: Vec<(String, f64)>,
    labels: Vec<(String, String)>,
}

impl BenchJson {
    pub fn new(name: &str) -> Self {
        BenchJson { name: name.to_string(), metrics: Vec::new(), labels: Vec::new() }
    }

    /// Record a numeric metric (wall-clock seconds, bytes on wire, final
    /// loss, speedups — whatever the bench measures). Non-finite values are
    /// stored as JSON `null` at write time.
    pub fn metric(&mut self, key: &str, value: f64) -> &mut Self {
        self.metrics.push((key.to_string(), value));
        self
    }

    /// Record a string label (scenario names, modes).
    pub fn label(&mut self, key: &str, value: &str) -> &mut Self {
        self.labels.push((key.to_string(), value.to_string()));
        self
    }

    /// The canonical per-scenario triple every training bench emits:
    /// wall-clock (simulated or host seconds), bytes on the wire, final
    /// loss — keyed `<tag>.wall_s` / `<tag>.bytes_on_wire` /
    /// `<tag>.final_loss`.
    pub fn scenario(
        &mut self,
        tag: &str,
        wall_s: f64,
        bytes_on_wire: u64,
        final_loss: f64,
    ) -> &mut Self {
        self.metric(&format!("{tag}.wall_s"), wall_s)
            .metric(&format!("{tag}.bytes_on_wire"), bytes_on_wire as f64)
            .metric(&format!("{tag}.final_loss"), final_loss)
    }

    /// Flatten a telemetry [`Snapshot`](crate::telemetry::Snapshot) into
    /// the bench's metric map under `<tag>.metrics.*`: barrier-wait
    /// p50/p99/mean, reactor poll-iteration and machines-driven counts,
    /// and bytes-by-kind. Zero-count histograms contribute nothing (their
    /// quantiles would be meaningless), so lockstep/DES snapshots only
    /// emit the families they actually populate.
    pub fn telemetry(
        &mut self,
        tag: &str,
        snap: &crate::telemetry::Snapshot,
    ) -> &mut Self {
        use crate::telemetry::{Counter, Hist};
        let barrier = snap.hist(Hist::BarrierWaitNs);
        if barrier.count > 0 {
            self.metric(
                &format!("{tag}.metrics.barrier_wait_p50_ns"),
                barrier.quantile_ns(0.50) as f64,
            )
            .metric(
                &format!("{tag}.metrics.barrier_wait_p99_ns"),
                barrier.quantile_ns(0.99) as f64,
            )
            .metric(&format!("{tag}.metrics.barrier_wait_mean_ns"), barrier.mean_ns());
        }
        let polls = snap.counter(Counter::ReactorPolls);
        if polls > 0 {
            self.metric(&format!("{tag}.metrics.reactor_polls"), polls as f64)
                .metric(
                    &format!("{tag}.metrics.reactor_machines_driven"),
                    snap.counter(Counter::ReactorMachinesDriven) as f64,
                );
        }
        self.metric(
            &format!("{tag}.metrics.bytes_sent_data"),
            snap.counter(Counter::BytesSentData) as f64,
        )
        .metric(
            &format!("{tag}.metrics.bytes_sent_bootstrap"),
            snap.counter(Counter::BytesSentBootstrap) as f64,
        )
        .metric(&format!("{tag}.metrics.frames_sent"), snap.frames_sent() as f64)
    }

    fn render(&self) -> String {
        fn esc(s: &str) -> String {
            s.chars()
                .flat_map(|c| match c {
                    '"' => "\\\"".chars().collect::<Vec<_>>(),
                    '\\' => "\\\\".chars().collect(),
                    '\n' => "\\n".chars().collect(),
                    c if (c as u32) < 0x20 => {
                        format!("\\u{:04x}", c as u32).chars().collect()
                    }
                    c => vec![c],
                })
                .collect()
        }
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"bench\": \"{}\",\n", esc(&self.name)));
        s.push_str("  \"metrics\": {");
        for (i, (k, v)) in self.metrics.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            if v.is_finite() {
                s.push_str(&format!("\n    \"{}\": {v:e}", esc(k)));
            } else {
                s.push_str(&format!("\n    \"{}\": null", esc(k)));
            }
        }
        s.push_str("\n  },\n  \"labels\": {");
        for (i, (k, v)) in self.labels.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("\n    \"{}\": \"{}\"", esc(k), esc(v)));
        }
        s.push_str("\n  }\n}\n");
        s
    }

    /// Write `BENCH_<name>.json` into the current directory (or
    /// `$MONIQUA_BENCH_DIR` when set) and echo the path to stdout.
    pub fn write(&self) -> std::io::Result<std::path::PathBuf> {
        let dir = std::env::var_os("MONIQUA_BENCH_DIR")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|| std::path::PathBuf::from("."));
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.render())?;
        println!("bench json written to {}", path.display());
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop-ish", 2, 20, || {
            black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.iters, 20);
        assert!(r.min_s <= r.median_s);
        assert!(r.median_s > 0.0);
        assert!(r.mean_s > 0.0);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_s: 0.5,
            median_s: 0.5,
            stddev_s: 0.0,
            min_s: 0.5,
        };
        assert_eq!(r.throughput(1_000_000), 2_000_000.0);
    }

    #[test]
    fn bench_json_renders_and_writes() {
        let dir = std::env::temp_dir()
            .join(format!("moniqua-benchjson-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::env::set_var("MONIQUA_BENCH_DIR", &dir);
        let mut j = BenchJson::new("unit_test");
        j.metric("wall_s", 1.25)
            .metric("bytes_on_wire", 1024.0)
            .metric("final_loss", 0.5)
            .metric("nan_is_null", f64::NAN)
            .label("algo\"rithm", "moni\\qua");
        let path = j.write().unwrap();
        std::env::remove_var("MONIQUA_BENCH_DIR");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"bench\": \"unit_test\""));
        assert!(text.contains("\"wall_s\": 1.25e0"));
        assert!(text.contains("\"nan_is_null\": null"));
        assert!(text.contains("algo\\\"rithm"));
        assert!(path.file_name().unwrap().to_str().unwrap() == "BENCH_unit_test.json");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bench_json_telemetry_section() {
        use crate::telemetry::{Counter, Hist, Registry, Telemetry};
        let reg = Registry::new();
        let t = Telemetry::new(&reg, 0);
        t.record(Counter::BytesSentData, 4096);
        t.record(Counter::FramesSentData, 2);
        t.record(Counter::ReactorPolls, 10);
        t.record(Counter::ReactorMachinesDriven, 40);
        t.observe(Hist::BarrierWaitNs, 1000);
        t.observe(Hist::BarrierWaitNs, 3000);
        let snap = reg.snapshot();
        let mut j = BenchJson::new("telemetry_section");
        j.telemetry("run", &snap);
        let text = j.render();
        assert!(text.contains("\"run.metrics.barrier_wait_p50_ns\""));
        assert!(text.contains("\"run.metrics.reactor_polls\": 1e1"));
        assert!(text.contains("\"run.metrics.bytes_sent_data\": 4.096e3"));
        assert!(text.contains("\"run.metrics.frames_sent\": 2e0"));
        // An empty registry emits only the always-present byte counters.
        let empty = Registry::new().snapshot();
        let mut j2 = BenchJson::new("telemetry_empty");
        j2.telemetry("run", &empty);
        let text2 = j2.render();
        assert!(!text2.contains("barrier_wait"));
        assert!(!text2.contains("reactor_polls"));
        assert!(text2.contains("\"run.metrics.bytes_sent_data\": 0e0"));
    }

    #[test]
    fn speedup_ratio() {
        let mk = |median_s: f64| BenchResult {
            name: "y".into(),
            iters: 1,
            mean_s: median_s,
            median_s,
            stddev_s: 0.0,
            min_s: median_s,
        };
        assert_eq!(speedup(&mk(1.0), &mk(0.25)), 4.0);
    }
}
