//! Time-varying topologies: a piecewise-constant schedule of worker graphs.
//!
//! Decentralized systems rarely keep one gossip graph for a whole training
//! run — workers churn, overlays are rebuilt, and the theory (assumption A2
//! holding *per round*) permits any sequence of doubly-stochastic matrices
//! whose graphs stay connected. A [`TopologySchedule`] lists `(time, graph)`
//! stages; the DES runtime (`coordinator::des`) swaps the gossip matrix at
//! each stage boundary — for synchronous algorithms via
//! [`SyncAlgorithm::swap_matrix`](crate::algorithms::SyncAlgorithm::swap_matrix),
//! for AD-PSGD by re-pointing the [`GossipSampler`](super::GossipSampler).

use anyhow::{Context, Result};

use super::Topology;

/// A sorted list of `(activation_time_s, topology)` stages. The first stage
/// must activate at t = 0; all stages must share one worker count.
#[derive(Clone, Debug)]
pub struct TopologySchedule {
    stages: Vec<(f64, Topology)>,
}

impl TopologySchedule {
    pub fn new(stages: Vec<(f64, Topology)>) -> Result<Self> {
        anyhow::ensure!(!stages.is_empty(), "topology schedule must have a stage");
        anyhow::ensure!(stages[0].0 == 0.0, "first stage must activate at t=0");
        let n = stages[0].1.n();
        for win in stages.windows(2) {
            anyhow::ensure!(
                win[0].0 < win[1].0,
                "stage times must strictly increase ({} !< {})",
                win[0].0,
                win[1].0
            );
        }
        for (t, topo) in &stages {
            anyhow::ensure!(topo.n() == n, "stage at t={t} has a different worker count");
            anyhow::ensure!(topo.is_connected(), "stage at t={t} is disconnected");
        }
        Ok(TopologySchedule { stages })
    }

    /// Parse `spec@time,spec@time,...` (e.g. `ring@0,complete@2.5`); specs
    /// as in [`Topology::parse_spec`]. Entries may omit `@time` only for the
    /// first stage (implies 0).
    pub fn parse(text: &str, n: usize, seed: u64) -> Result<Self> {
        let mut stages = Vec::new();
        for (idx, entry) in text.split(',').enumerate() {
            let entry = entry.trim();
            let (spec, time) = match entry.rsplit_once('@') {
                Some((s, t)) => (
                    s,
                    t.parse::<f64>()
                        .with_context(|| format!("stage '{entry}': time"))?,
                ),
                None if idx == 0 => (entry, 0.0),
                None => anyhow::bail!("stage '{entry}': expected spec@time"),
            };
            stages.push((time, Topology::parse_spec(spec, n, seed)?));
        }
        Self::new(stages)
    }

    pub fn n(&self) -> usize {
        self.stages[0].1.n()
    }

    pub fn stages(&self) -> &[(f64, Topology)] {
        &self.stages
    }

    /// Index of the stage active at simulated time `t`.
    pub fn stage_at(&self, t: f64) -> usize {
        match self.stages.iter().rposition(|(at, _)| *at <= t) {
            Some(i) => i,
            None => 0,
        }
    }

    /// The topology active at simulated time `t`.
    pub fn at(&self, t: f64) -> &Topology {
        &self.stages[self.stage_at(t)].1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_lookup() {
        let s = TopologySchedule::new(vec![
            (0.0, Topology::Ring(4)),
            (2.0, Topology::Complete(4)),
            (5.0, Topology::Star(4)),
        ])
        .unwrap();
        assert_eq!(s.stage_at(0.0), 0);
        assert_eq!(s.stage_at(1.999), 0);
        assert_eq!(s.stage_at(2.0), 1);
        assert_eq!(s.stage_at(100.0), 2);
        assert_eq!(*s.at(3.0), Topology::Complete(4));
    }

    #[test]
    fn parse_specs_and_times() {
        let s = TopologySchedule::parse("ring,complete@1.5", 4, 7).unwrap();
        assert_eq!(s.stages().len(), 2);
        assert_eq!(*s.at(0.0), Topology::Ring(4));
        assert_eq!(*s.at(2.0), Topology::Complete(4));
        assert!(TopologySchedule::parse("complete@1.0", 4, 7).is_err(), "no t=0 stage");
        assert!(TopologySchedule::parse("ring,blob@1", 4, 7).is_err());
    }

    #[test]
    fn rejects_mixed_worker_counts_and_unordered_times() {
        assert!(TopologySchedule::new(vec![
            (0.0, Topology::Ring(4)),
            (1.0, Topology::Ring(6)),
        ])
        .is_err());
        assert!(TopologySchedule::new(vec![
            (0.0, Topology::Ring(4)),
            (1.0, Topology::Complete(4)),
            (1.0, Topology::Star(4)),
        ])
        .is_err());
    }
}
