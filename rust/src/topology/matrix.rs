//! Doubly-stochastic communication matrices and their spectral analysis.

use crate::linalg::MatF64;

/// A symmetric doubly-stochastic communication matrix over n workers
/// (paper assumption A2), with cached neighbor structure.
#[derive(Clone, Debug)]
pub struct CommMatrix {
    pub w: MatF64,
    /// Neighbor lists (j such that `W[j][i] > 0`, j ≠ i).
    pub neighbors: Vec<Vec<usize>>,
    /// Sparse per-edge weights parallel to [`Self::neighbors`]:
    /// `neighbor_weights[i][k] = W[neighbors[i][k]][i]` (equal to
    /// `W[i][neighbors[i][k]]` by symmetry). The engines' accumulate loops
    /// zip these with the neighbor lists instead of doing a dense `n`-wide
    /// row lookup per edge — the values are *copies of the same matrix
    /// entries*, so every weighted sum is bitwise what the dense lookup
    /// produced (pinned by `sparse_weights_match_dense` below and the
    /// topology-equivalence case in `tests/engine_equivalence.rs`).
    pub neighbor_weights: Vec<Vec<f64>>,
    /// Cached Σ_i deg(i) — the per-round directed-message count every
    /// engine reports, hoisted out of the round loops.
    deg_sum: usize,
}

impl CommMatrix {
    /// Metropolis–Hastings weights for an undirected graph:
    /// `W_ij = 1 / (1 + max(deg_i, deg_j))` on edges, diagonal absorbs the
    /// rest. Always symmetric + doubly stochastic; standard in the
    /// decentralized-optimization literature.
    pub fn metropolis(adj: &[Vec<usize>]) -> Self {
        let n = adj.len();
        let deg: Vec<usize> = adj.iter().map(|a| a.len()).collect();
        let mut w = MatF64::zeros(n, n);
        for i in 0..n {
            for &j in &adj[i] {
                w[(i, j)] = 1.0 / (1.0 + deg[i].max(deg[j]) as f64);
            }
        }
        for i in 0..n {
            let off: f64 = (0..n).filter(|&j| j != i).map(|j| w.at(i, j)).sum();
            w[(i, i)] = 1.0 - off;
        }
        Self::from_matrix(w)
    }

    /// Uniform averaging over the closed neighborhood (only valid when the
    /// graph is regular — checked). `W_ij = 1/(deg+1)` for j in N(i) ∪ {i}.
    pub fn uniform_regular(adj: &[Vec<usize>]) -> Self {
        let n = adj.len();
        let d = adj[0].len();
        assert!(
            adj.iter().all(|a| a.len() == d),
            "uniform weights need a regular graph"
        );
        let mut w = MatF64::zeros(n, n);
        let p = 1.0 / (d as f64 + 1.0);
        for i in 0..n {
            w[(i, i)] = p;
            for &j in &adj[i] {
                w[(i, j)] = p;
            }
        }
        Self::from_matrix(w)
    }

    /// Wrap an explicit matrix; validates stochasticity and symmetry.
    pub fn from_matrix(w: MatF64) -> Self {
        let n = w.n;
        assert_eq!(w.n, w.m);
        assert!(w.is_symmetric(1e-9), "W must be symmetric");
        for i in 0..n {
            let row: f64 = w.row(i).iter().sum();
            assert!((row - 1.0).abs() < 1e-9, "row {i} sums to {row}");
            assert!(w.row(i).iter().all(|&v| v > -1e-12), "negative entry in row {i}");
        }
        let neighbors: Vec<Vec<usize>> = (0..n)
            .map(|i| {
                (0..n)
                    .filter(|&j| j != i && w.at(i, j) > 1e-15)
                    .collect::<Vec<_>>()
            })
            .collect();
        let neighbor_weights = neighbors
            .iter()
            .enumerate()
            .map(|(i, nbrs)| nbrs.iter().map(|&j| w.at(j, i)).collect::<Vec<_>>())
            .collect();
        let deg_sum = neighbors.iter().map(|v| v.len()).sum();
        CommMatrix { w, neighbors, neighbor_weights, deg_sum }
    }

    /// Cached Σ_i deg(i): directed gossip messages per synchronous round.
    #[inline]
    pub fn deg_sum(&self) -> usize {
        self.deg_sum
    }

    /// Sparse receiver view of row/column `i`: `(j, W[j][i])` pairs in
    /// ascending-neighbor order — the engines' accumulate-loop iterator.
    #[inline]
    pub fn in_edges(&self, i: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        self.neighbors[i]
            .iter()
            .copied()
            .zip(self.neighbor_weights[i].iter().copied())
    }

    pub fn n(&self) -> usize {
        self.w.n
    }

    #[inline]
    pub fn weight(&self, i: usize, j: usize) -> f64 {
        self.w.at(i, j)
    }

    /// Smallest non-zero entry φ (Theorem 1's constant).
    pub fn min_nonzero(&self) -> f64 {
        let mut phi = f64::INFINITY;
        for i in 0..self.n() {
            for j in 0..self.n() {
                let v = self.w.at(i, j);
                if v > 1e-15 {
                    phi = phi.min(v);
                }
            }
        }
        phi
    }

    /// Slack matrix `W̄ = γ W + (1−γ) I` (Theorem 3 — enables 1-bit budgets
    /// by shrinking the per-step averaging and hence the consensus error the
    /// quantizer must survive).
    pub fn slack(&self, gamma: f64) -> CommMatrix {
        assert!((0.0..=1.0).contains(&gamma));
        let n = self.n();
        let mut w = MatF64::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let id = if i == j { 1.0 } else { 0.0 };
                w[(i, j)] = gamma * self.w.at(i, j) + (1.0 - gamma) * id;
            }
        }
        Self::from_matrix(w)
    }

    /// `ρ = max(|λ₂|, |λₙ|)`: the second-largest absolute eigenvalue,
    /// estimated by power iteration on the deflated operator
    /// `x ↦ W x − mean(x)·1` (removes the λ₁ = 1 eigenvector `1`).
    pub fn rho(&self) -> f64 {
        let n = self.n();
        if n == 1 {
            return 0.0;
        }
        // Deterministic, non-degenerate start orthogonal to 1.
        let mut v: Vec<f64> = (0..n)
            .map(|i| ((i * 2654435761usize) % 1000) as f64 / 1000.0 - 0.45)
            .collect();
        deflate(&mut v);
        let mut lambda = 0.0;
        for _ in 0..2000 {
            let mut next = self.w.matvec(&v);
            deflate(&mut next);
            let norm = next.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm < 1e-300 {
                return 0.0;
            }
            for x in next.iter_mut() {
                *x /= norm;
            }
            let wv = self.w.matvec(&next);
            let new_lambda: f64 = next.iter().zip(&wv).map(|(a, b)| a * b).sum();
            if (new_lambda.abs() - lambda).abs() < 1e-12 {
                lambda = new_lambda.abs();
                break;
            }
            lambda = new_lambda.abs();
            v = next;
        }
        lambda.min(1.0)
    }

    /// Spectral gap `1 − ρ`.
    pub fn spectral_gap(&self) -> f64 {
        1.0 - self.rho()
    }

    /// Markov-chain mixing-time upper bound `t_mix ≤ log(4n) / (1−ρ)`
    /// (supplementary §E.1).
    pub fn t_mix_bound(&self) -> f64 {
        let gap = self.spectral_gap().max(1e-12);
        ((4.0 * self.n() as f64).ln() / gap).max(1.0)
    }
}

fn deflate(v: &mut [f64]) {
    let mean = v.iter().sum::<f64>() / v.len() as f64;
    for x in v.iter_mut() {
        *x -= mean;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    fn ring_w(n: usize) -> CommMatrix {
        Topology::Ring(n).comm_matrix()
    }

    #[test]
    fn metropolis_is_doubly_stochastic_symmetric() {
        for topo in [
            Topology::Ring(8),
            Topology::Star(6),
            Topology::Torus(3, 3),
            Topology::Complete(5),
        ] {
            let cm = topo.comm_matrix();
            let n = cm.n();
            for i in 0..n {
                let row: f64 = cm.w.row(i).iter().sum();
                assert!((row - 1.0).abs() < 1e-12);
                for j in 0..n {
                    assert!((cm.w.at(i, j) - cm.w.at(j, i)).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn ring8_rho_matches_closed_form() {
        // Ring with Metropolis weights = 1/3 on edges, 1/3 diagonal:
        // eigenvalues are (1 + 2cos(2πk/8))/3; ρ = (1+2cos(π/4))/3 ≈ 0.8047.
        let rho = ring_w(8).rho();
        let expect = (1.0 + 2.0 * (std::f64::consts::PI / 4.0).cos()) / 3.0;
        assert!((rho - expect).abs() < 1e-6, "rho {rho} vs {expect}");
    }

    #[test]
    fn complete_graph_rho_zero() {
        // Complete-graph Metropolis is exact averaging: W = 11^T/n, ρ = 0.
        let rho = Topology::Complete(6).comm_matrix().rho();
        assert!(rho < 1e-8, "rho {rho}");
    }

    #[test]
    fn rho_less_than_one_iff_connected() {
        for topo in [
            Topology::Ring(12),
            Topology::Chain(9),
            Topology::Star(10),
            Topology::RandomRegular { n: 16, degree: 4, seed: 7 },
        ] {
            let rho = topo.comm_matrix().rho();
            assert!(rho < 1.0 - 1e-6, "{topo:?} rho {rho}");
        }
    }

    #[test]
    fn expander_beats_ring_gap() {
        let ring = Topology::Ring(16).comm_matrix().spectral_gap();
        let exp = Topology::RandomRegular { n: 16, degree: 4, seed: 5 }
            .comm_matrix()
            .spectral_gap();
        assert!(exp > ring, "expander gap {exp} vs ring {ring}");
    }

    #[test]
    fn slack_matrix_shrinks_gap() {
        let w = ring_w(8);
        let s = w.slack(0.25);
        // W̄ eigenvalues: γλ + (1-γ) → ρ̄ = γρ + 1 - γ ≥ ρ.
        let expect = 0.25 * w.rho() + 0.75;
        assert!((s.rho() - expect).abs() < 1e-6);
        // Still doubly stochastic.
        for i in 0..8 {
            assert!((s.w.row(i).iter().sum::<f64>() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn min_nonzero_ring() {
        let phi = ring_w(8).min_nonzero();
        assert!((phi - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn neighbors_match_adjacency() {
        let cm = ring_w(5);
        assert_eq!(cm.neighbors[0], vec![1, 4]);
    }

    #[test]
    fn sparse_weights_match_dense() {
        // The sparse lists must be bitwise copies of the dense entries —
        // every engine's accumulate loop now reads them instead of W.
        for topo in [
            Topology::Ring(8),
            Topology::Star(6),
            Topology::RandomRegular { n: 12, degree: 4, seed: 9 },
        ] {
            let cm = topo.comm_matrix();
            for i in 0..cm.n() {
                assert_eq!(cm.neighbors[i].len(), cm.neighbor_weights[i].len());
                for (j, wji) in cm.in_edges(i) {
                    assert_eq!(wji.to_bits(), cm.weight(j, i).to_bits(), "{topo:?} i={i} j={j}");
                }
            }
            let rescanned: usize = cm.neighbors.iter().map(|v| v.len()).sum();
            assert_eq!(cm.deg_sum(), rescanned, "{topo:?}");
        }
    }

    #[test]
    fn slack_matrix_keeps_sparse_structure_consistent() {
        let s = ring_w(8).slack(0.25);
        for i in 0..8 {
            for (j, wji) in s.in_edges(i) {
                assert_eq!(wji.to_bits(), s.weight(j, i).to_bits(), "i={i} j={j}");
            }
        }
    }

    #[test]
    fn t_mix_bound_reasonable() {
        let t = ring_w(8).t_mix_bound();
        assert!(t > 1.0 && t < 100.0, "t_mix {t}");
    }
}
