//! Worker graph topologies and doubly-stochastic communication matrices.
//!
//! Decentralized SGD is parameterized by a symmetric doubly-stochastic
//! matrix `W` whose support is the worker graph (paper assumption A2). This
//! module builds the graphs the paper's experiments use (ring, and the
//! generalizations a practitioner would want: torus, complete, star, chain,
//! random-regular expanders), derives Metropolis–Hastings weights (always
//! symmetric + doubly stochastic for undirected graphs), estimates the
//! spectral quantity `ρ = max(|λ₂|, |λₙ|)` by power iteration, and produces
//! the *slack* matrix `W̄ = γW + (1−γ)I` that Theorem 3 uses to admit 1-bit
//! quantization. For AD-PSGD it also generates the time-varying pairwise
//! gossip matrices `W_k` and estimates their mixing time `t_mix`.

pub mod gossip;
pub mod matrix;
pub mod schedule;

pub use gossip::{GossipSampler, PairGossip};
pub use matrix::CommMatrix;
pub use schedule::TopologySchedule;

use crate::rng::Pcg64;

/// Static worker graph shapes.
#[derive(Clone, Debug, PartialEq)]
pub enum Topology {
    /// Cycle over n workers — the paper's main experimental topology.
    Ring(usize),
    /// 2-D torus r × c (each worker has 4 neighbors).
    Torus(usize, usize),
    /// Fully connected graph (gossip degenerate to near-AllReduce).
    Complete(usize),
    /// Hub-and-spoke; worker 0 is the hub. Worst-case spectral gap.
    Star(usize),
    /// Path graph (ring with one edge removed).
    Chain(usize),
    /// Random d-regular graph (expander with high probability).
    RandomRegular { n: usize, degree: usize, seed: u64 },
}

impl Topology {
    pub fn ring(n: usize) -> Self {
        Topology::Ring(n)
    }

    /// Parse a topology spec over `n` workers:
    /// `ring|chain|complete|star|torus:RxC|regular:D`. The single source of
    /// truth for the `topology=` config key and the stage specs inside a
    /// [`TopologySchedule`].
    pub fn parse_spec(spec: &str, n: usize, seed: u64) -> anyhow::Result<Topology> {
        Ok(match spec {
            "ring" => Topology::Ring(n),
            "chain" => Topology::Chain(n),
            "complete" => Topology::Complete(n),
            "star" => Topology::Star(n),
            s if s.starts_with("torus:") => {
                let (r, c) = s[6..]
                    .split_once('x')
                    .ok_or_else(|| anyhow::anyhow!("torus:RxC"))?;
                let t = Topology::Torus(r.parse()?, c.parse()?);
                anyhow::ensure!(t.n() == n, "torus dims != workers");
                t
            }
            s if s.starts_with("regular:") => {
                Topology::RandomRegular { n, degree: s[8..].parse()?, seed }
            }
            other => anyhow::bail!("unknown topology '{other}'"),
        })
    }

    /// The same graph *family* re-instantiated over `m` workers — how the
    /// elastic runtime ([`crate::elastic`]) re-wires the gossip graph when
    /// membership changes: the surviving cohort keeps the shape it was
    /// configured with, at its new size. The torus is refused (its shape is
    /// a fixed r×c grid with no canonical resize).
    pub fn resized(&self, m: usize) -> anyhow::Result<Topology> {
        anyhow::ensure!(m >= 1, "cannot resize a topology to zero workers");
        if m == self.n() {
            return Ok(self.clone()); // identity resize (full membership)
        }
        Ok(match *self {
            Topology::Ring(_) => Topology::Ring(m),
            Topology::Chain(_) => Topology::Chain(m),
            Topology::Complete(_) => Topology::Complete(m),
            Topology::Star(_) => Topology::Star(m),
            Topology::RandomRegular { degree, seed, .. } => {
                Topology::RandomRegular { n: m, degree: degree.min(m.saturating_sub(1)), seed }
            }
            Topology::Torus(r, c) => anyhow::bail!(
                "elastic membership needs a resizable topology; torus:{r}x{c} has no \
                 canonical shape at other sizes"
            ),
        })
    }

    /// Number of workers.
    pub fn n(&self) -> usize {
        match *self {
            Topology::Ring(n)
            | Topology::Complete(n)
            | Topology::Star(n)
            | Topology::Chain(n) => n,
            Topology::Torus(r, c) => r * c,
            Topology::RandomRegular { n, .. } => n,
        }
    }

    /// Undirected adjacency lists (no self loops).
    pub fn adjacency(&self) -> Vec<Vec<usize>> {
        let n = self.n();
        let mut adj = vec![Vec::new(); n];
        let add = |adj: &mut Vec<Vec<usize>>, a: usize, b: usize| {
            if a != b && !adj[a].contains(&b) {
                adj[a].push(b);
                adj[b].push(a);
            }
        };
        match *self {
            Topology::Ring(n) => {
                if n == 2 {
                    add(&mut adj, 0, 1);
                } else {
                    for i in 0..n {
                        add(&mut adj, i, (i + 1) % n);
                    }
                }
            }
            Topology::Chain(n) => {
                for i in 0..n.saturating_sub(1) {
                    add(&mut adj, i, i + 1);
                }
            }
            Topology::Complete(n) => {
                for i in 0..n {
                    for j in (i + 1)..n {
                        add(&mut adj, i, j);
                    }
                }
            }
            Topology::Star(n) => {
                for i in 1..n {
                    add(&mut adj, 0, i);
                }
            }
            Topology::Torus(r, c) => {
                let idx = |i: usize, j: usize| i * c + j;
                for i in 0..r {
                    for j in 0..c {
                        add(&mut adj, idx(i, j), idx((i + 1) % r, j));
                        add(&mut adj, idx(i, j), idx(i, (j + 1) % c));
                    }
                }
            }
            Topology::RandomRegular { n, degree, seed } => {
                // Pairing-model construction with retries; falls back to a
                // ring + random chords if pairing fails (still connected).
                let mut rng = Pcg64::new(seed, 0xC0FFEE);
                let ok = try_random_regular(&mut adj, n, degree, &mut rng);
                if !ok {
                    for i in 0..n {
                        add(&mut adj, i, (i + 1) % n);
                    }
                    for i in 0..n {
                        let j = rng.below(n as u64) as usize;
                        add(&mut adj, i, j);
                    }
                }
            }
        }
        for lst in adj.iter_mut() {
            lst.sort_unstable();
        }
        adj
    }

    /// Number of undirected edges m (the Θ(md) memory term in Table 1).
    pub fn edge_count(&self) -> usize {
        self.adjacency().iter().map(|a| a.len()).sum::<usize>() / 2
    }

    /// Metropolis–Hastings communication matrix for this graph.
    pub fn comm_matrix(&self) -> CommMatrix {
        CommMatrix::metropolis(&self.adjacency())
    }

    /// True if the graph is connected (required for consensus).
    pub fn is_connected(&self) -> bool {
        let adj = self.adjacency();
        let n = adj.len();
        if n == 0 {
            return false;
        }
        let mut seen = vec![false; n];
        let mut stack = vec![0usize];
        seen[0] = true;
        while let Some(v) = stack.pop() {
            for &w in &adj[v] {
                if !seen[w] {
                    seen[w] = true;
                    stack.push(w);
                }
            }
        }
        seen.into_iter().all(|s| s)
    }
}

fn try_random_regular(
    adj: &mut [Vec<usize>],
    n: usize,
    degree: usize,
    rng: &mut Pcg64,
) -> bool {
    if n * degree % 2 != 0 || degree >= n {
        return false;
    }
    'attempt: for _ in 0..50 {
        for a in adj.iter_mut() {
            a.clear();
        }
        let mut stubs: Vec<usize> = (0..n).flat_map(|i| std::iter::repeat(i).take(degree)).collect();
        rng.shuffle(&mut stubs);
        for pair in stubs.chunks(2) {
            let (a, b) = (pair[0], pair[1]);
            if a == b || adj[a].contains(&b) {
                continue 'attempt;
            }
            adj[a].push(b);
            adj[b].push(a);
        }
        return true;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_adjacency() {
        let adj = Topology::Ring(5).adjacency();
        assert_eq!(adj[0], vec![1, 4]);
        assert_eq!(adj[2], vec![1, 3]);
        assert_eq!(Topology::Ring(5).edge_count(), 5);
    }

    #[test]
    fn ring_of_two_has_single_edge() {
        let adj = Topology::Ring(2).adjacency();
        assert_eq!(adj[0], vec![1]);
        assert_eq!(Topology::Ring(2).edge_count(), 1);
    }

    #[test]
    fn torus_degree_four() {
        let t = Topology::Torus(3, 4);
        assert_eq!(t.n(), 12);
        for a in t.adjacency() {
            assert_eq!(a.len(), 4);
        }
    }

    #[test]
    fn complete_and_star_counts() {
        assert_eq!(Topology::Complete(6).edge_count(), 15);
        assert_eq!(Topology::Star(6).edge_count(), 5);
        assert_eq!(Topology::Chain(6).edge_count(), 5);
    }

    #[test]
    fn all_topologies_connected() {
        let topos = vec![
            Topology::Ring(8),
            Topology::Torus(3, 3),
            Topology::Complete(5),
            Topology::Star(7),
            Topology::Chain(4),
            Topology::RandomRegular { n: 16, degree: 4, seed: 1 },
        ];
        for t in topos {
            assert!(t.is_connected(), "{t:?}");
        }
    }

    #[test]
    fn random_regular_has_requested_degree() {
        let t = Topology::RandomRegular { n: 20, degree: 4, seed: 3 };
        let adj = t.adjacency();
        // pairing model succeeded (or fallback; both connected) — check most
        // nodes have the right degree when pairing succeeds.
        let deg4 = adj.iter().filter(|a| a.len() == 4).count();
        assert!(deg4 >= 15, "degrees {:?}", adj.iter().map(|a| a.len()).collect::<Vec<_>>());
    }
}
