//! Time-varying gossip matrices for AD-PSGD (paper §5, supplementary §E.2).
//!
//! In AD-PSGD an "iteration" is one gradient update on one worker plus a
//! pairwise averaging with one random neighbor; the induced `W_k` is the
//! identity except for a 2×2 averaging block. Each individual `W_k` has
//! ρ = 1, so convergence is governed by the *mixing time* `t_mix` of the
//! time-inhomogeneous chain — which this module estimates empirically, and
//! which Theorem 5's θ and δ settings consume.

use crate::linalg::MatF64;
use crate::rng::Pcg64;
use crate::topology::Topology;

/// One pairwise gossip event: workers `a` and `b` average (coefficient ½).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PairGossip {
    pub a: usize,
    pub b: usize,
}

impl PairGossip {
    /// The induced n×n doubly-stochastic matrix (identity + 2×2 block).
    pub fn matrix(&self, n: usize) -> MatF64 {
        let mut w = MatF64::eye(n);
        w[(self.a, self.a)] = 0.5;
        w[(self.b, self.b)] = 0.5;
        w[(self.a, self.b)] = 0.5;
        w[(self.b, self.a)] = 0.5;
        w
    }
}

/// Samples the AD-PSGD event sequence: at each event a uniformly random
/// worker wakes and gossips with a uniformly random neighbor.
#[derive(Clone, Debug)]
pub struct GossipSampler {
    adj: Vec<Vec<usize>>,
    rng: Pcg64,
}

impl GossipSampler {
    pub fn new(topo: &Topology, seed: u64) -> Self {
        GossipSampler {
            adj: topo.adjacency(),
            rng: Pcg64::new(seed, 0xAD_5D),
        }
    }

    pub fn n(&self) -> usize {
        self.adj.len()
    }

    /// Raw RNG cursor (for elastic snapshots — the event stream must resume
    /// bit-for-bit after a restore).
    pub fn rng_raw(&self) -> [u64; 4] {
        self.rng.raw()
    }

    /// Restore the RNG cursor saved by [`Self::rng_raw`].
    pub fn set_rng_raw(&mut self, raw: [u64; 4]) {
        self.rng = Pcg64::from_raw(raw);
    }

    /// Swap the underlying graph mid-run (a [`TopologySchedule`] stage
    /// boundary in the DES runtime). The RNG state carries over, so the
    /// event stream stays one deterministic sequence.
    ///
    /// [`TopologySchedule`]: crate::topology::TopologySchedule
    pub fn set_topology(&mut self, topo: &Topology) {
        assert_eq!(topo.n(), self.adj.len(), "topology swap changed worker count");
        self.adj = topo.adjacency();
    }

    /// Next (worker, neighbor) gossip pair.
    pub fn next_pair(&mut self) -> PairGossip {
        let a = self.rng.below(self.adj.len() as u64) as usize;
        self.pair_for(a)
    }

    /// Gossip pair where the waking worker is fixed (used by the wall-clock
    /// async trainer, which wakes the worker whose clock is earliest).
    pub fn pair_for(&mut self, a: usize) -> PairGossip {
        let nbrs = &self.adj[a];
        let b = nbrs[self.rng.below(nbrs.len() as u64) as usize];
        PairGossip { a, b }
    }

    /// Empirical mixing time: smallest t such that for every basis
    /// distribution e_i, ‖(∏_{k<t} W_k) e_i − 1/n‖₁ ≤ ½ along a sampled
    /// event sequence (the condition Theorem 5 assumes). Returns `max_t`
    /// if not mixed by then.
    pub fn estimate_t_mix(&mut self, max_t: usize) -> usize {
        let n = self.n();
        // Columns: current image of each basis vector under the product.
        let mut cols: Vec<Vec<f64>> = (0..n)
            .map(|i| {
                let mut v = vec![0.0; n];
                v[i] = 1.0;
                v
            })
            .collect();
        for t in 1..=max_t {
            let pair = self.next_pair();
            for col in cols.iter_mut() {
                let m = 0.5 * (col[pair.a] + col[pair.b]);
                col[pair.a] = m;
                col[pair.b] = m;
            }
            let worst = cols
                .iter()
                .map(|col| {
                    col.iter().map(|&x| (x - 1.0 / n as f64).abs()).sum::<f64>()
                })
                .fold(0.0f64, f64::max);
            if worst <= 0.5 {
                return t;
            }
        }
        max_t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pair_matrix_doubly_stochastic() {
        let w = PairGossip { a: 1, b: 3 }.matrix(5);
        for i in 0..5 {
            assert!((w.row(i).iter().sum::<f64>() - 1.0).abs() < 1e-12);
        }
        assert!(w.is_symmetric(1e-12));
        assert_eq!(w.at(0, 0), 1.0);
        assert_eq!(w.at(1, 3), 0.5);
    }

    #[test]
    fn sampler_respects_adjacency() {
        let topo = Topology::Ring(6);
        let adj = topo.adjacency();
        let mut s = GossipSampler::new(&topo, 3);
        for _ in 0..200 {
            let p = s.next_pair();
            assert!(adj[p.a].contains(&p.b), "{p:?}");
        }
    }

    #[test]
    fn t_mix_finite_and_scales_with_n() {
        let mut s6 = GossipSampler::new(&Topology::Ring(6), 1);
        let mut s12 = GossipSampler::new(&Topology::Ring(12), 1);
        let t6 = s6.estimate_t_mix(100_000);
        let t12 = s12.estimate_t_mix(100_000);
        assert!(t6 > 0 && t6 < 100_000);
        assert!(t12 > t6, "t12 {t12} t6 {t6}");
    }

    #[test]
    fn complete_graph_mixes_faster_than_ring() {
        let tc = GossipSampler::new(&Topology::Complete(8), 2).estimate_t_mix(100_000);
        let tr = GossipSampler::new(&Topology::Ring(8), 2).estimate_t_mix(100_000);
        assert!(tc <= tr, "complete {tc} ring {tr}");
    }
}
