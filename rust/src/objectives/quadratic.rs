//! The quadratic objective of Theorem 1:
//!
//! ```text
//!     f(x) = ½ ‖x − (δ/2)·1‖²
//! ```
//!
//! Its optimum `x* = (δ/2)·1` sits exactly *between* the representable
//! points of a linear quantizer with step δ — the adversarial construction
//! that makes naive quantization stall at `E‖∇f‖² ≥ φ²δ²/(8(1+φ²))` while
//! Moniqua sails through. Optional gradient noise σ models assumption (A3).

use super::{Eval, Objective};
use crate::rng::worker_rng;

#[derive(Clone, Debug)]
pub struct Quadratic {
    pub dim: usize,
    /// Quantizer step δ of the Theorem 1 construction (optimum at δ/2).
    pub delta: f32,
    /// Gradient noise standard deviation σ.
    pub sigma: f32,
    pub workers: usize,
    pub seed: u64,
    /// Initial point (same for all workers).
    pub x0: f32,
}

impl Quadratic {
    pub fn new(dim: usize, delta: f32, sigma: f32, workers: usize, seed: u64) -> Self {
        Quadratic { dim, delta, sigma, workers, seed, x0: 1.0 }
    }

    #[inline]
    fn opt(&self) -> f32 {
        self.delta / 2.0
    }
}

impl Objective for Quadratic {
    fn dim(&self) -> usize {
        self.dim
    }

    fn init(&self) -> Vec<f32> {
        vec![self.x0; self.dim]
    }

    fn loss_grad(&mut self, worker: usize, step: u64, params: &[f32], grad: &mut [f32]) -> f64 {
        let opt = self.opt();
        let mut loss = 0.0f64;
        if self.sigma > 0.0 {
            let mut rng = worker_rng(self.seed ^ step, worker, 0x60);
            for (g, &p) in grad.iter_mut().zip(params) {
                let d = p - opt;
                loss += 0.5 * (d as f64) * (d as f64);
                *g = d + rng.next_gaussian() as f32 * self.sigma;
            }
        } else {
            for (g, &p) in grad.iter_mut().zip(params) {
                let d = p - opt;
                loss += 0.5 * (d as f64) * (d as f64);
                *g = d;
            }
        }
        loss
    }

    fn eval(&mut self, params: &[f32]) -> Eval {
        let opt = self.opt();
        let loss: f64 = params
            .iter()
            .map(|&p| 0.5 * ((p - opt) as f64).powi(2))
            .sum();
        Eval { loss, accuracy: None }
    }

    fn workers(&self) -> usize {
        self.workers
    }

    fn box_clone(&self) -> Box<dyn Objective> {
        Box::new(self.clone())
    }
}

/// Exact squared gradient norm at `params` (for the Theorem 1 bench).
pub fn grad_norm_sq(q: &Quadratic, params: &[f32]) -> f64 {
    let opt = q.delta / 2.0;
    params.iter().map(|&p| ((p - opt) as f64).powi(2)).sum()
}

/// Theorem 1's stall floor `φ²δ²/(8(1+φ²))`.
pub fn theorem1_floor(phi: f64, delta: f64) -> f64 {
    phi * phi * delta * delta / (8.0 * (1.0 + phi * phi))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradient_points_at_optimum() {
        let mut q = Quadratic::new(4, 1.0, 0.0, 2, 1);
        let params = q.init();
        let mut grad = vec![0.0; 4];
        let loss = q.loss_grad(0, 0, &params, &mut grad);
        // x0 = 1, opt = 0.5: grad = 0.5 each, loss = 4 * 0.125.
        assert!((loss - 0.5).abs() < 1e-9);
        assert!(grad.iter().all(|&g| (g - 0.5).abs() < 1e-6));
    }

    #[test]
    fn gd_converges_without_quantization() {
        let mut q = Quadratic::new(8, 1.0, 0.0, 1, 1);
        let mut x = q.init();
        let mut g = vec![0.0; 8];
        for step in 0..100 {
            q.loss_grad(0, step, &x, &mut g);
            for (xi, gi) in x.iter_mut().zip(&g) {
                *xi -= 0.5 * gi;
            }
        }
        assert!(q.eval(&x).loss < 1e-12);
    }

    #[test]
    fn noise_has_requested_scale() {
        let mut q = Quadratic::new(10_000, 1.0, 0.3, 1, 7);
        let x = q.init();
        let mut g = vec![0.0; 10_000];
        q.loss_grad(0, 0, &x, &mut g);
        // grad = 0.5 + noise; sample variance ≈ 0.09.
        let mean: f64 = g.iter().map(|&v| v as f64).sum::<f64>() / g.len() as f64;
        let var: f64 =
            g.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / g.len() as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
        assert!((var - 0.09).abs() < 0.01, "var {var}");
    }

    #[test]
    fn floor_formula() {
        // φ = 1/3, δ = 1: floor = (1/9)/(8·(10/9)) = 1/80.
        let f = theorem1_floor(1.0 / 3.0, 1.0);
        assert!((f - 1.0 / 80.0).abs() < 1e-12);
    }
}
