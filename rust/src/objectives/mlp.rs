//! One-hidden-layer MLP with manual backprop — the non-convex pure-Rust
//! stand-in for the paper's ResNet workloads (DESIGN.md §Hardware-
//! Adaptation). tanh hidden layer + softmax output; params flat as
//! [W1 (h×d), b1 (h), W2 (c×h), b2 (c)].

use std::sync::Arc;

use super::{Eval, Objective};
use crate::data::partition::{Partition, ShardSampler};
use crate::data::SynthClassification;

#[derive(Clone)]
pub struct Mlp {
    data: Arc<SynthClassification>,
    samplers: Vec<ShardSampler>,
    pub hidden: usize,
    pub batch: usize,
    pub l2: f32,
    n_workers: usize,
    init_seed: u64,
}

impl Mlp {
    pub fn new(
        data: Arc<SynthClassification>,
        n_workers: usize,
        partition: Partition,
        hidden: usize,
        batch: usize,
        seed: u64,
    ) -> Self {
        let shards = partition.split(&data.train, n_workers, seed);
        let samplers = shards
            .into_iter()
            .enumerate()
            .map(|(w, s)| ShardSampler::new(s, seed ^ 0x317, w))
            .collect();
        Mlp { data, samplers, hidden, batch, l2: 1e-4, n_workers, init_seed: seed }
    }

    #[inline]
    fn d(&self) -> usize {
        self.data.dim
    }

    #[inline]
    fn c(&self) -> usize {
        self.data.classes
    }

    fn offsets(&self) -> (usize, usize, usize, usize) {
        let (d, h, c) = (self.d(), self.hidden, self.c());
        let w1 = 0;
        let b1 = w1 + h * d;
        let w2 = b1 + h;
        let b2 = w2 + c * h;
        (w1, b1, w2, b2)
    }

    /// Forward + optional backward for one example. Returns (loss, argmax).
    fn example_pass(
        &self,
        p: &[f32],
        x: &[f32],
        label: usize,
        grad: Option<&mut [f32]>,
    ) -> (f64, usize) {
        let (d, h, c) = (self.d(), self.hidden, self.c());
        let (w1, b1, w2, b2) = self.offsets();
        // hidden pre-activation + tanh
        let mut a = vec![0.0f32; h];
        for j in 0..h {
            let row = &p[w1 + j * d..w1 + (j + 1) * d];
            let mut s = p[b1 + j];
            for (wi, xi) in row.iter().zip(x) {
                s += wi * xi;
            }
            a[j] = s.tanh();
        }
        // output logits
        let mut logits = vec![0.0f64; c];
        for k in 0..c {
            let row = &p[w2 + k * h..w2 + (k + 1) * h];
            let mut s = p[b2 + k] as f64;
            for (wi, ai) in row.iter().zip(&a) {
                s += (*wi as f64) * (*ai as f64);
            }
            logits[k] = s;
        }
        let max = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = logits.iter().map(|&l| (l - max).exp()).collect();
        let z: f64 = exps.iter().sum();
        let loss = -(exps[label] / z).ln();
        let argmax = logits
            .iter()
            .enumerate()
            .max_by(|u, v| u.1.partial_cmp(v.1).unwrap())
            .unwrap()
            .0;
        if let Some(g) = grad {
            // dL/dlogit_k = p_k - 1{k=label}
            let mut dh = vec![0.0f32; h];
            for k in 0..c {
                let err = (exps[k] / z - if k == label { 1.0 } else { 0.0 }) as f32;
                let row = &p[w2 + k * h..w2 + (k + 1) * h];
                let grow = &mut g[w2 + k * h..w2 + (k + 1) * h];
                for j in 0..h {
                    grow[j] += err * a[j];
                    dh[j] += err * row[j];
                }
                g[b2 + k] += err;
            }
            for j in 0..h {
                let da = dh[j] * (1.0 - a[j] * a[j]); // tanh'
                let grow = &mut g[w1 + j * d..w1 + (j + 1) * d];
                for (gi, &xi) in grow.iter_mut().zip(x) {
                    *gi += da * xi;
                }
                g[b1 + j] += da;
            }
        }
        (loss, argmax)
    }
}

impl Objective for Mlp {
    fn dim(&self) -> usize {
        let (d, h, c) = (self.d(), self.hidden, self.c());
        h * d + h + c * h + c
    }

    fn init(&self) -> Vec<f32> {
        // Same init on every worker (assumption A4): seeded Xavier-ish.
        let mut rng = crate::rng::Pcg64::new(self.init_seed, 0x1417);
        let (d, h, _c) = (self.d(), self.hidden, self.c());
        let (w1, b1, w2, b2) = self.offsets();
        let mut p = vec![0.0f32; self.dim()];
        let s1 = (1.0 / d as f32).sqrt();
        for v in p[w1..b1].iter_mut() {
            *v = rng.next_gaussian() as f32 * s1;
        }
        let s2 = (1.0 / h as f32).sqrt();
        for v in p[w2..b2].iter_mut() {
            *v = rng.next_gaussian() as f32 * s2;
        }
        p
    }

    fn loss_grad(&mut self, worker: usize, _step: u64, params: &[f32], grad: &mut [f32]) -> f64 {
        let idx = self.samplers[worker].sample_batch(self.batch);
        grad.fill(0.0);
        let mut loss = 0.0;
        for &i in &idx {
            let ex = &self.data.train[i];
            let (l, _) = self.example_pass(params, &ex.x, ex.label, Some(grad));
            loss += l;
        }
        let inv = 1.0 / idx.len() as f32;
        for (g, &p) in grad.iter_mut().zip(params) {
            *g = *g * inv + self.l2 * p;
        }
        loss / idx.len() as f64
    }

    fn eval(&mut self, params: &[f32]) -> Eval {
        let mut loss = 0.0;
        let mut correct = 0usize;
        for ex in &self.data.test {
            let (l, pred) = self.example_pass(params, &ex.x, ex.label, None);
            loss += l;
            if pred == ex.label {
                correct += 1;
            }
        }
        let n = self.data.test.len() as f64;
        Eval { loss: loss / n, accuracy: Some(correct as f64 / n) }
    }

    fn workers(&self) -> usize {
        self.n_workers
    }

    fn box_clone(&self) -> Box<dyn Objective> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;

    fn small() -> Mlp {
        let data = Arc::new(SynthClassification::generate(SynthSpec {
            dim: 8,
            classes: 4,
            train_per_class: 60,
            test_per_class: 20,
            mean_scale: 2.5,
            ..SynthSpec::default()
        }));
        Mlp::new(data, 2, Partition::Iid, 16, 16, 3)
    }

    #[test]
    fn dim_and_init() {
        let o = small();
        assert_eq!(o.dim(), 16 * 8 + 16 + 4 * 16 + 4);
        let p = o.init();
        assert_eq!(p.len(), o.dim());
        // biases zero
        let (_, b1, _, _) = o.offsets();
        assert_eq!(p[b1], 0.0);
    }

    #[test]
    fn grad_matches_finite_difference() {
        let o = small();
        let mut p = o.init();
        for (i, v) in p.iter_mut().enumerate() {
            *v += ((i % 7) as f32 - 3.0) * 0.01;
        }
        let ex = &o.data.train[0];
        let mut g = vec![0.0f32; o.dim()];
        o.example_pass(&p, &ex.x, ex.label, Some(&mut g));
        let f = |p: &[f32]| o.example_pass(p, &ex.x, ex.label, None).0;
        let eps = 1e-3;
        for &i in &[0usize, 33, 100, o.dim() - 1] {
            let mut pp = p.clone();
            pp[i] += eps;
            let mut pm = p.clone();
            pm[i] -= eps;
            let num = (f(&pp) - f(&pm)) / (2.0 * eps as f64);
            assert!(
                (num - g[i] as f64).abs() < 2e-3 * num.abs().max(1.0),
                "i={i} num={num} ana={}",
                g[i]
            );
        }
    }

    #[test]
    fn sgd_learns_nonconvex() {
        let mut o = small();
        let mut x = o.init();
        let mut g = vec![0.0; o.dim()];
        let l0 = o.eval(&x).loss;
        for step in 0..400 {
            o.loss_grad(0, step, &x, &mut g);
            for (xi, gi) in x.iter_mut().zip(&g) {
                *xi -= 0.2 * gi;
            }
        }
        let e = o.eval(&x);
        assert!(e.loss < l0 * 0.7, "loss {} -> {}", l0, e.loss);
        assert!(e.accuracy.unwrap() > 0.6, "acc {:?}", e.accuracy);
    }

    #[test]
    fn box_clone_preserves_behavior() {
        let mut o = small();
        let mut o2 = o.box_clone();
        let x = o.init();
        let mut g1 = vec![0.0; o.dim()];
        let mut g2 = vec![0.0; o.dim()];
        o.loss_grad(0, 0, &x, &mut g1);
        o2.loss_grad(0, 0, &x, &mut g2);
        assert_eq!(g1, g2);
    }
}
