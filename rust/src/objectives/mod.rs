//! Training objectives (local loss functions f_i of Eq. 3).
//!
//! Every decentralized algorithm in this crate optimizes a [`Objective`]:
//! a per-worker stochastic `loss_grad` plus a global evaluation. Pure-Rust
//! objectives ([`Quadratic`], [`Logistic`], [`Mlp`]) power the sweeps and
//! benches (thousands of steps per second); the PJRT-backed transformer
//! ([`crate::runtime::PjrtObjective`]) powers the end-to-end driver where
//! the gradient is computed by the AOT-compiled JAX/Pallas executable.

pub mod logistic;
pub mod mlp;
pub mod quadratic;

pub use logistic::Logistic;
pub use mlp::Mlp;
pub use quadratic::Quadratic;

/// Evaluation summary on the (global) held-out set.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Eval {
    pub loss: f64,
    pub accuracy: Option<f64>,
}

/// A per-worker stochastic objective. Implementations hold the dataset
/// shards internally; `worker` selects the shard, `step` the mini-batch
/// (deterministic given the experiment seed).
pub trait Objective: Send {
    /// Parameter dimension d.
    fn dim(&self) -> usize;

    /// Initial parameter vector (identical across workers, assumption A4).
    fn init(&self) -> Vec<f32>;

    /// Stochastic loss/gradient of worker `worker` at `step`. Writes the
    /// gradient into `grad` (len = dim) and returns the mini-batch loss.
    fn loss_grad(&mut self, worker: usize, step: u64, params: &[f32], grad: &mut [f32]) -> f64;

    /// Deterministic evaluation of the *global* objective (test set).
    fn eval(&mut self, params: &[f32]) -> Eval;

    /// Number of workers the shards were built for.
    fn workers(&self) -> usize;

    /// Clone into a box (used by the threaded async runtime to give each
    /// worker thread its own sampler state).
    fn box_clone(&self) -> Box<dyn Objective>;
}

impl Clone for Box<dyn Objective> {
    fn clone(&self) -> Self {
        self.box_clone()
    }
}

/// Identifier used by the CLI / config layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObjectiveKind {
    Quadratic,
    Logistic,
    Mlp,
    Transformer,
}

impl std::str::FromStr for ObjectiveKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "quadratic" => Ok(Self::Quadratic),
            "logistic" => Ok(Self::Logistic),
            "mlp" => Ok(Self::Mlp),
            "transformer" => Ok(Self::Transformer),
            other => Err(format!("unknown objective '{other}'")),
        }
    }
}
