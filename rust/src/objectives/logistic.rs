//! Multiclass logistic regression (softmax) on a [`SynthClassification`]
//! dataset — the fast convex-ish workload behind the Figure-1/Table-2
//! sweeps. Convex, so convergence differences between algorithms are purely
//! communication effects.

use std::sync::Arc;

use super::{Eval, Objective};
use crate::data::partition::{Partition, ShardSampler};
use crate::data::SynthClassification;

/// Softmax regression: params laid out as [W (classes × dim), b (classes)].
#[derive(Clone)]
pub struct Logistic {
    data: Arc<SynthClassification>,
    samplers: Vec<ShardSampler>,
    pub batch: usize,
    pub l2: f32,
    n_workers: usize,
}

impl Logistic {
    pub fn new(
        data: Arc<SynthClassification>,
        n_workers: usize,
        partition: Partition,
        batch: usize,
        seed: u64,
    ) -> Self {
        let shards = partition.split(&data.train, n_workers, seed);
        let samplers = shards
            .into_iter()
            .enumerate()
            .map(|(w, shard)| ShardSampler::new(shard, seed, w))
            .collect();
        Logistic { data, samplers, batch, l2: 1e-4, n_workers }
    }

    #[inline]
    fn classes(&self) -> usize {
        self.data.classes
    }

    #[inline]
    fn feat(&self) -> usize {
        self.data.dim
    }

    /// logits[c] = W[c]·x + b[c]; returns (loss, softmax probs) for one
    /// example, accumulating gradient into `grad`.
    fn example_pass(
        &self,
        params: &[f32],
        x: &[f32],
        label: usize,
        grad: Option<&mut [f32]>,
    ) -> (f64, usize) {
        let c = self.classes();
        let d = self.feat();
        let mut logits = vec![0.0f64; c];
        for k in 0..c {
            let w = &params[k * d..(k + 1) * d];
            let b = params[c * d + k];
            logits[k] = w
                .iter()
                .zip(x)
                .map(|(wi, xi)| (*wi as f64) * (*xi as f64))
                .sum::<f64>()
                + b as f64;
        }
        let max = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = logits.iter().map(|&l| (l - max).exp()).collect();
        let z: f64 = exps.iter().sum();
        let loss = -(exps[label] / z).ln();
        let argmax = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if let Some(grad) = grad {
            for k in 0..c {
                let p = exps[k] / z;
                let err = (p - if k == label { 1.0 } else { 0.0 }) as f32;
                let gw = &mut grad[k * d..(k + 1) * d];
                for (g, &xi) in gw.iter_mut().zip(x) {
                    *g += err * xi;
                }
                grad[c * d + k] += err;
            }
        }
        (loss, argmax)
    }
}

impl Objective for Logistic {
    fn dim(&self) -> usize {
        self.classes() * self.feat() + self.classes()
    }

    fn init(&self) -> Vec<f32> {
        vec![0.0; self.dim()]
    }

    fn loss_grad(&mut self, worker: usize, _step: u64, params: &[f32], grad: &mut [f32]) -> f64 {
        let idx = self.samplers[worker].sample_batch(self.batch);
        grad.fill(0.0);
        let mut loss = 0.0;
        for &i in &idx {
            let ex = &self.data.train[i];
            let (l, _) = self.example_pass(params, &ex.x, ex.label, Some(grad));
            loss += l;
        }
        let inv = 1.0 / idx.len() as f32;
        for (g, &p) in grad.iter_mut().zip(params) {
            *g = *g * inv + self.l2 * p;
        }
        loss / idx.len() as f64
    }

    fn eval(&mut self, params: &[f32]) -> Eval {
        let mut loss = 0.0;
        let mut correct = 0usize;
        for ex in &self.data.test {
            let (l, pred) = self.example_pass(params, &ex.x, ex.label, None);
            loss += l;
            if pred == ex.label {
                correct += 1;
            }
        }
        let n = self.data.test.len() as f64;
        Eval { loss: loss / n, accuracy: Some(correct as f64 / n) }
    }

    fn workers(&self) -> usize {
        self.n_workers
    }

    fn box_clone(&self) -> Box<dyn Objective> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;

    fn small() -> Logistic {
        let data = Arc::new(SynthClassification::generate(SynthSpec {
            dim: 8,
            classes: 4,
            train_per_class: 50,
            test_per_class: 20,
            ..SynthSpec::default()
        }));
        Logistic::new(data, 2, Partition::Iid, 16, 7)
    }

    #[test]
    fn dim_layout() {
        let o = small();
        assert_eq!(o.dim(), 4 * 8 + 4);
        assert_eq!(o.init().len(), o.dim());
    }

    #[test]
    fn initial_loss_is_log_classes() {
        let mut o = small();
        let e = o.eval(&o.init());
        assert!((e.loss - (4.0f64).ln()).abs() < 1e-9);
        let acc = e.accuracy.unwrap();
        assert!(acc < 0.6); // chance-ish at init
    }

    #[test]
    fn sgd_learns() {
        let mut o = small();
        let mut x = o.init();
        let mut g = vec![0.0; o.dim()];
        for step in 0..300 {
            o.loss_grad(0, step, &x, &mut g);
            for (xi, gi) in x.iter_mut().zip(&g) {
                *xi -= 0.3 * gi;
            }
        }
        let e = o.eval(&x);
        assert!(e.loss < 1.0, "loss {}", e.loss);
        assert!(e.accuracy.unwrap() > 0.7, "acc {:?}", e.accuracy);
    }

    #[test]
    fn grad_matches_finite_difference() {
        let o = small();
        // Use eval-style deterministic loss: reuse loss_grad on a fixed
        // batch by seeding the same step; instead check on full test pass.
        let mut x = o.init();
        for (i, v) in x.iter_mut().enumerate() {
            *v = ((i % 13) as f32 - 6.0) * 0.05;
        }
        // deterministic "batch" = entire train set via manual accumulation
        let mut grad = vec![0.0f32; o.dim()];
        let mut loss = 0.0f64;
        for ex in o.data.train.iter() {
            let (l, _) = o.example_pass(&x, &ex.x, ex.label, Some(&mut grad));
            loss += l;
        }
        let n = o.data.train.len() as f32;
        for g in grad.iter_mut() {
            *g /= n;
        }
        let _ = loss;
        let f = |params: &[f32], o: &Logistic| -> f64 {
            let mut s = 0.0;
            for ex in o.data.train.iter() {
                let (l, _) = o.example_pass(params, &ex.x, ex.label, None);
                s += l;
            }
            s / o.data.train.len() as f64
        };
        let eps = 1e-3;
        for &i in &[0usize, 5, 17, o.dim() - 1] {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let num = (f(&xp, &o) - f(&xm, &o)) / (2.0 * eps as f64);
            assert!(
                (num - grad[i] as f64).abs() < 1e-3,
                "i={i} num={num} ana={}",
                grad[i]
            );
        }
    }

    #[test]
    fn workers_sample_their_own_shards() {
        let mut o = small();
        let x = o.init();
        let mut g0 = vec![0.0; o.dim()];
        let mut g1 = vec![0.0; o.dim()];
        o.loss_grad(0, 0, &x, &mut g0);
        o.loss_grad(1, 0, &x, &mut g1);
        assert_ne!(g0, g1);
    }
}
