//! Buffer pools for zero-allocation steady-state rounds (§Perf).
//!
//! A synchronous round touches three kinds of transient buffers: the
//! payload a node encodes, the wire bytes a transport ships, and the
//! received frames a node integrates. Before this module each of those was
//! a fresh heap allocation per round per peer; now every one is **checked
//! out** of a pool on first use and **returned** when its round is done, so
//! a steady-state round performs zero heap allocations — enforced by the
//! counting-allocator suite in `tests/alloc_discipline.rs` (two warm-up
//! rounds, then a zero budget for the next N rounds across
//! moniqua/dpsgd/choco on the mem transport).
//!
//! Two types, split by ownership:
//!
//! * [`FramePool`] — a cheaply-clonable, thread-shared pool of `Vec<u8>`
//!   wire buffers. Both transports draw from one pool per cluster: a
//!   sender checks a buffer out, encodes the frame into it, and the
//!   *receiver* (via [`Transport::recycle`](crate::transport::Transport::recycle))
//!   returns it after the engine consumed the payload — so after warm-up
//!   the same few buffers just circulate. A `Mutex<Vec<_>>` is plenty: the
//!   lock is held for one push/pop, far off the critical path next to the
//!   per-frame memcpy.
//! * [`ScratchArena`] — a single-owner checkout pool for round-local byte
//!   scratch, used where a buffer's lifetime is one round but its owner
//!   persists (the cluster node's payload buffer and checkpoint engine
//!   blob; the DES/lockstep trainers need no arena — their former per-eval
//!   allocation was removed by making `linalg::mean_into` generic).
//!
//! ## Pool depth under pipelined rounds
//!
//! The cluster runtime's send-early pipelining (`coordinator::cluster`,
//! §Pipelined rounds) does not deepen the pool's steady-state working set:
//! a peer can still run at most **one** round ahead (its round-k+1 frame
//! needs its round-k mix, which needs our round-k frame), so at most two
//! rounds of frames are ever in flight toward one receiver — the same
//! bound the strict schedule already had from frame parking. The
//! alloc-discipline suite runs with pipelining at its default (on) and
//! still sees zero steady-state allocations. [`FramePool::prewarm`] lets a
//! caller pay the working set up front when even warm-up allocations are
//! unwelcome.
//!
//! ## Why pooling preserves bitwise determinism
//!
//! A checked-out buffer is always `clear()`ed (length 0) before reuse and
//! every producer writes its full contents before any consumer reads it —
//! stale *capacity* is recycled, stale *bytes* are never observable. The
//! value path is byte-for-byte what freshly-allocated buffers produce,
//! which is why the cluster/golden equivalence suites run unchanged on top
//! of the pools.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::telemetry::{Counter, Telemetry};

/// Default upper bound on pooled buffers kept per pool — a backstop so a
/// transient burst (e.g. a crash replay loading a long frame log) cannot
/// pin its high-water mark in memory forever. [`FramePool::prewarm`]
/// raises the bound to the caller's declared working set: a reactor run
/// multiplexing hundreds of workers over one shared pool legitimately
/// keeps more than 256 buffers in steady circulation, and silently capping
/// the prewarm would push the overflow back onto the allocator every
/// round.
const MAX_POOLED: usize = 256;

/// Thread-shared pool of byte buffers (see module docs). Clones share the
/// same pool.
#[derive(Clone)]
pub struct FramePool {
    bufs: Arc<Mutex<Vec<Vec<u8>>>>,
    /// Retention bound: `give` drops buffers beyond it. Starts at
    /// [`MAX_POOLED`]; `prewarm` raises it (never lowers).
    limit: Arc<AtomicUsize>,
    /// Per-clone recording handle (hit/miss counters). Deliberately
    /// per-clone, not shared: each transport attributes checkouts to its
    /// own worker shard.
    telemetry: Telemetry,
}

impl Default for FramePool {
    fn default() -> Self {
        FramePool {
            bufs: Arc::new(Mutex::new(Vec::new())),
            limit: Arc::new(AtomicUsize::new(MAX_POOLED)),
            telemetry: Telemetry::disabled(),
        }
    }
}

impl FramePool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lock the pool, recovering from poisoning: pooled buffers are plain
    /// capacity with no cross-buffer invariant, so a panic elsewhere never
    /// leaves the pool half-updated in a way worth propagating.
    fn locked(&self) -> std::sync::MutexGuard<'_, Vec<Vec<u8>>> {
        match self.bufs.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Attach a telemetry handle to *this clone* of the pool: subsequent
    /// [`Self::take`]s record hit/miss on the handle's worker shard.
    pub fn set_metrics(&mut self, t: Telemetry) {
        self.telemetry = t;
    }

    /// Check a buffer out: recycled (empty, capacity retained) when one is
    /// pooled, freshly allocated otherwise.
    // lint: hot-path
    pub fn take(&self) -> Vec<u8> {
        let got = self.locked().pop();
        let hit = got.is_some();
        self.telemetry
            .record(if hit { Counter::PoolHit } else { Counter::PoolMiss }, 1);
        got.unwrap_or_default()
    }

    /// Return a buffer to the pool. Contents are cleared; capacity is what
    /// makes the next [`Self::take`] allocation-free.
    // lint: hot-path
    pub fn give(&self, mut buf: Vec<u8>) {
        buf.clear();
        let limit = self.limit.load(Ordering::Relaxed);
        let mut g = self.locked();
        if g.len() < limit {
            g.push(buf);
        }
    }

    /// Buffers currently parked in the pool (diagnostics/tests).
    pub fn pooled(&self) -> usize {
        self.locked().len()
    }

    /// Seed the pool with `count` buffers of `capacity` bytes each, and
    /// raise the retention bound to `count` when it exceeds the
    /// [`MAX_POOLED`] default — prewarming *declares* the working set, so
    /// the pool must also be allowed to hold it (a reactor cluster's
    /// steady circulation can legitimately exceed the backstop). Callers
    /// that know their working set (e.g. two rounds of frames in flight
    /// per directed edge under the pipelined scheduler) can move even the
    /// warm-up allocations out of the round loop.
    pub fn prewarm(&self, count: usize, capacity: usize) {
        self.limit.fetch_max(count, Ordering::Relaxed);
        let mut g = self.locked();
        while g.len() < count {
            g.push(Vec::with_capacity(capacity));
        }
    }
}

/// Single-owner checkout pool for round-local scratch buffers.
#[derive(Default)]
pub struct ScratchArena {
    bytes: Vec<Vec<u8>>,
}

impl ScratchArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Check out an empty byte buffer (capacity recycled when available).
    pub fn take_bytes(&mut self) -> Vec<u8> {
        self.bytes.pop().unwrap_or_default()
    }

    /// Return a byte buffer checked out with [`Self::take_bytes`].
    pub fn give_bytes(&mut self, mut buf: Vec<u8>) {
        buf.clear();
        if self.bytes.len() < MAX_POOLED {
            self.bytes.push(buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_pool_recycles_capacity() {
        let pool = FramePool::new();
        let mut b = pool.take();
        b.extend_from_slice(&[1u8; 4096]);
        let cap = b.capacity();
        let ptr = b.as_ptr();
        pool.give(b);
        assert_eq!(pool.pooled(), 1);
        let b2 = pool.take();
        assert_eq!(b2.len(), 0, "recycled buffers come back empty");
        assert!(b2.capacity() >= cap);
        assert_eq!(b2.as_ptr(), ptr, "same allocation circulates");
        assert_eq!(pool.pooled(), 0);
    }

    #[test]
    fn frame_pool_is_shared_across_clones() {
        let pool = FramePool::new();
        let clone = pool.clone();
        clone.give(Vec::with_capacity(128));
        assert_eq!(pool.pooled(), 1);
        assert!(pool.take().capacity() >= 128);
    }

    #[test]
    fn frame_pool_bounds_its_size() {
        let pool = FramePool::new();
        for _ in 0..(MAX_POOLED + 50) {
            pool.give(Vec::with_capacity(8));
        }
        assert_eq!(pool.pooled(), MAX_POOLED);
    }

    #[test]
    fn prewarm_seeds_capacity_up_to_the_cap() {
        let pool = FramePool::new();
        pool.prewarm(8, 1024);
        assert_eq!(pool.pooled(), 8);
        for _ in 0..8 {
            assert!(pool.take().capacity() >= 1024, "prewarmed capacity");
        }
        assert_eq!(pool.pooled(), 0);
        // Idempotent up to `count`.
        pool.prewarm(4, 64);
        pool.prewarm(4, 64);
        assert_eq!(pool.pooled(), 4);
        // A prewarm past the default backstop raises the retention bound
        // to the declared working set instead of silently capping it.
        pool.prewarm(MAX_POOLED + 100, 1);
        assert_eq!(pool.pooled(), MAX_POOLED + 100);
        let b = pool.take();
        pool.give(b);
        assert_eq!(pool.pooled(), MAX_POOLED + 100, "raised bound retains");
    }

    #[test]
    fn frame_pool_counts_hits_and_misses() {
        use crate::telemetry::Registry;
        let reg = Registry::new();
        let mut pool = FramePool::new();
        pool.set_metrics(Telemetry::new(&reg, 0));
        let b = pool.take(); // empty pool: miss
        pool.give(b);
        let _ = pool.take(); // recycled: hit
        let snap = reg.snapshot();
        assert_eq!(snap.counter(Counter::PoolMiss), 1);
        assert_eq!(snap.counter(Counter::PoolHit), 1);
        // A clone without the handle records nothing further.
        let untracked = FramePool::new();
        let _ = untracked.take();
        assert_eq!(reg.snapshot().counter(Counter::PoolMiss), 1);
    }

    #[test]
    fn arena_checkout_roundtrip() {
        let mut a = ScratchArena::new();
        let mut b = a.take_bytes();
        b.resize(100, 7);
        a.give_bytes(b);
        let back = a.take_bytes();
        assert!(back.capacity() >= 100);
        assert!(back.is_empty());
    }

    #[test]
    fn concurrent_checkouts_are_safe() {
        let pool = FramePool::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let p = pool.clone();
                s.spawn(move || {
                    for _ in 0..200 {
                        let mut b = p.take();
                        b.push(1);
                        p.give(b);
                    }
                });
            }
        });
        assert!(pool.pooled() <= 4);
    }
}
