//! Parametric network model — the substitute for the paper's tc-shaped
//! GCP links (DESIGN.md §Hardware-Adaptation).
//!
//! Figure 1 sweeps bandwidth/latency regimes; what matters for wall-clock
//! convergence is the per-iteration communication time each algorithm pays.
//! This module prices messages exactly the way the paper's testbed did:
//!
//! * a per-message latency `lat` (propagation + handshake),
//! * a serialization time `bytes * 8 / bandwidth`,
//! * gossip exchanges happen in parallel across disjoint links, so a
//!   synchronous round costs the *max* over workers of their per-round
//!   send time (all workers talk concurrently, each link at full rate),
//! * AllReduce is priced as the standard ring-allreduce:
//!   `2 (n−1) messages of size d/n` plus latency per hop.
//!
//! Local computation is priced separately by the coordinator (gradient time
//! + algorithm-specific *extra local pass* cost, which is how the paper
//! explains DCD/ECD/Choco/DeepSqueeze lagging Moniqua on fast networks).

pub mod link;

pub use link::LinkMatrix;

/// Link parameters. Defaults correspond to Figure 1(a)'s "fast" network.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetworkConfig {
    /// Link bandwidth in bits per second.
    pub bandwidth_bps: f64,
    /// One-way message latency in seconds.
    pub latency_s: f64,
}

impl NetworkConfig {
    pub fn new(bandwidth_bps: f64, latency_s: f64) -> Self {
        assert!(bandwidth_bps > 0.0 && latency_s >= 0.0);
        NetworkConfig { bandwidth_bps, latency_s }
    }

    /// Figure 1(a): 10 Gbps, 0.05 ms.
    pub fn fig1a() -> Self {
        Self::new(10e9, 0.05e-3)
    }

    /// Figure 1(b): 1 Gbps, 0.05 ms.
    pub fn fig1b() -> Self {
        Self::new(1e9, 0.05e-3)
    }

    /// Figure 1(c): 1 Gbps, 5 ms.
    pub fn fig1c() -> Self {
        Self::new(1e9, 5e-3)
    }

    /// Figure 1(d): 100 Mbps, 20 ms ("extremely poor network").
    pub fn fig1d() -> Self {
        Self::new(100e6, 20e-3)
    }

    /// Figure 2(b)'s AD-PSGD network: 20 Mbps, 0.15 ms.
    pub fn fig2b() -> Self {
        Self::new(20e6, 0.15e-3)
    }

    /// Time to push one message of `bytes` over one link.
    #[inline]
    pub fn message_time(&self, bytes: usize) -> f64 {
        self.latency_s + (bytes as f64 * 8.0) / self.bandwidth_bps
    }

    /// Synchronous gossip round: every worker exchanges `bytes_per_neighbor`
    /// with each of its neighbors concurrently; links are full-duplex and
    /// disjoint sends are parallel, so the round costs the slowest worker's
    /// serialization plus one latency.
    pub fn gossip_round_time(&self, degree_max: usize, bytes_per_neighbor: usize) -> f64 {
        if degree_max == 0 {
            return 0.0;
        }
        self.latency_s + degree_max as f64 * (bytes_per_neighbor as f64 * 8.0) / self.bandwidth_bps
    }

    /// Ring-allreduce on `n` workers over a payload of `total_bytes`:
    /// `2(n−1)` phases, each moving `total_bytes/n` and paying latency.
    pub fn allreduce_time(&self, n: usize, total_bytes: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let phases = 2 * (n - 1);
        let chunk = total_bytes as f64 / n as f64;
        phases as f64 * (self.latency_s + chunk * 8.0 / self.bandwidth_bps)
    }
}

/// A network model bound to a worker count, tracking cumulative traffic.
#[derive(Clone, Debug)]
pub struct NetworkModel {
    pub cfg: NetworkConfig,
    /// Total payload bytes ever charged (all links).
    pub total_bytes: u64,
    /// Total messages charged.
    pub total_messages: u64,
}

impl NetworkModel {
    pub fn new(cfg: NetworkConfig) -> Self {
        NetworkModel { cfg, total_bytes: 0, total_messages: 0 }
    }

    /// Charge a synchronous gossip round over a topology with max degree
    /// `deg_max` where each worker sends `bytes` to each neighbor; returns
    /// elapsed simulated time for the round.
    pub fn charge_gossip_round(
        &mut self,
        n_workers: usize,
        deg_sum: usize,
        deg_max: usize,
        bytes_per_msg: usize,
    ) -> f64 {
        let msgs = deg_sum as u64; // directed messages = sum of degrees
        self.total_messages += msgs;
        self.total_bytes += msgs * bytes_per_msg as u64;
        let _ = n_workers;
        self.cfg.gossip_round_time(deg_max, bytes_per_msg)
    }

    /// Charge one point-to-point message (AD-PSGD event).
    pub fn charge_message(&mut self, bytes: usize) -> f64 {
        self.total_messages += 1;
        self.total_bytes += bytes as u64;
        self.cfg.message_time(bytes)
    }

    /// Charge a full allreduce.
    pub fn charge_allreduce(&mut self, n: usize, total_bytes: usize) -> f64 {
        if n > 1 {
            // Each of n workers sends 2(n−1) chunks of total/n bytes:
            // aggregate bytes on the wire = 2 (n−1) · total_bytes.
            self.total_messages += (2 * (n - 1) * n) as u64;
            self.total_bytes += 2 * (n as u64 - 1) * total_bytes as u64;
        }
        self.cfg.allreduce_time(n, total_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn message_time_components() {
        let net = NetworkConfig::new(8e6, 1e-3); // 1 MB/s
        // 1000 bytes = 8000 bits -> 1 ms serialization + 1 ms latency.
        assert!((net.message_time(1000) - 2e-3).abs() < 1e-12);
    }

    #[test]
    fn fig1_configs_ordered_by_quality() {
        let a = NetworkConfig::fig1a().message_time(125_000);
        let b = NetworkConfig::fig1b().message_time(125_000);
        let c = NetworkConfig::fig1c().message_time(125_000);
        let d = NetworkConfig::fig1d().message_time(125_000);
        assert!(a < b && b < c && c < d, "{a} {b} {c} {d}");
    }

    #[test]
    fn gossip_parallelism() {
        let net = NetworkConfig::new(1e9, 0.0);
        // Degree 2 costs twice the serialization of degree 1, regardless of n.
        let t1 = net.gossip_round_time(1, 1_000_000);
        let t2 = net.gossip_round_time(2, 1_000_000);
        assert!((t2 - 2.0 * t1).abs() < 1e-12);
    }

    #[test]
    fn allreduce_scales_with_n_latency() {
        let net = NetworkConfig::new(1e9, 10e-3); // latency-dominated
        let t4 = net.allreduce_time(4, 1000);
        let t8 = net.allreduce_time(8, 1000);
        // 2(n-1) latency hops: 6 vs 14 (small bandwidth term allowed).
        assert!((t8 / t4 - 14.0 / 6.0).abs() < 1e-3);
    }

    #[test]
    fn allreduce_bandwidth_term_nearly_constant_in_n() {
        let net = NetworkConfig::new(1e6, 0.0);
        let t4 = net.allreduce_time(4, 1_000_000);
        let t16 = net.allreduce_time(16, 1_000_000);
        // 2(n-1)/n -> 2; ratio t16/t4 = (30/16)/(6/4) = 1.25
        assert!((t16 / t4 - 1.25).abs() < 1e-9);
    }

    #[test]
    fn model_accumulates_traffic() {
        let mut m = NetworkModel::new(NetworkConfig::fig1b());
        m.charge_message(100);
        m.charge_gossip_round(8, 16, 2, 50);
        assert_eq!(m.total_messages, 17);
        assert_eq!(m.total_bytes, 100 + 16 * 50);
    }

    #[test]
    fn quantization_shrinks_round_time_proportionally() {
        // 8-bit vs 32-bit payload on a bandwidth-dominated link: 4x faster.
        let net = NetworkConfig::new(1e8, 0.0);
        let d = 100_000;
        let full = net.gossip_round_time(2, d * 4);
        let q8 = net.gossip_round_time(2, d);
        assert!((full / q8 - 4.0).abs() < 1e-9);
    }
}
