//! Per-edge link parameters — the heterogeneous-network generalization of
//! the single [`NetworkConfig`](super::NetworkConfig) every link shared
//! before the DES runtime existed.
//!
//! Real decentralized deployments (the regime Figures 1/2b abstract) do not
//! run over one uniform link: rack-local pairs see 10 Gbps while
//! cross-region pairs see 100 Mbps and 20 ms. A [`LinkMatrix`] assigns every
//! directed worker pair its own bandwidth/latency; the DES runtime
//! (`coordinator::des`) prices each message on the link it actually
//! traverses. Links are stored symmetrically (`link(i,j) == link(j,i)`)
//! because the gossip exchanges the paper studies are full-duplex pairwise
//! connections.

use anyhow::{Context, Result};

use super::NetworkConfig;
use crate::rng::Pcg64;

/// An n×n matrix of link parameters. Construction guarantees symmetry;
/// the diagonal is never consulted (workers do not message themselves).
#[derive(Clone, Debug)]
pub struct LinkMatrix {
    n: usize,
    links: Vec<NetworkConfig>,
    uniform: bool,
}

impl LinkMatrix {
    /// Every pair shares `cfg` — the degenerate case equivalent to the
    /// pre-DES `NetworkConfig` pricing.
    pub fn uniform(n: usize, cfg: NetworkConfig) -> Self {
        assert!(n > 0);
        LinkMatrix { n, links: vec![cfg; n * n], uniform: true }
    }

    /// Heterogeneous links: each undirected pair's bandwidth and latency are
    /// the base values multiplied by independent log-normal factors
    /// `exp(sigma·g)` (bandwidth divided, latency multiplied, so `sigma`
    /// uniformly *degrades* in distribution tails — the shape measured for
    /// shared cloud networks). Deterministic in `(n, base, sigma, seed)`.
    pub fn lognormal(n: usize, base: NetworkConfig, sigma: f64, seed: u64) -> Self {
        assert!(n > 0 && sigma >= 0.0);
        let mut m = Self::uniform(n, base);
        if sigma == 0.0 {
            return m;
        }
        m.uniform = false;
        for i in 0..n {
            for j in (i + 1)..n {
                // Per-pair stream: independent of iteration order.
                let mut rng = Pcg64::new(
                    seed ^ 0x11_4B_ED_5E,
                    ((i as u64) << 32) | j as u64,
                );
                let bw = base.bandwidth_bps / (sigma * rng.next_gaussian()).exp();
                let lat = base.latency_s * (sigma * rng.next_gaussian()).exp();
                let cfg = NetworkConfig::new(bw, lat);
                m.links[i * n + j] = cfg;
                m.links[j * n + i] = cfg;
            }
        }
        m
    }

    /// Parse an explicit link table: one `i j bandwidth_mbps latency_ms`
    /// line per undirected pair (`#` comments and blank lines ignored).
    /// Pairs not listed keep `base`.
    pub fn from_table(text: &str, n: usize, base: NetworkConfig) -> Result<Self> {
        let mut m = Self::uniform(n, base);
        m.uniform = false;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            let lno = lineno + 1;
            anyhow::ensure!(
                fields.len() == 4,
                "link table line {lno}: expected `i j bandwidth_mbps latency_ms`"
            );
            let i: usize = fields[0].parse().with_context(|| format!("link table line {lno}"))?;
            let j: usize = fields[1].parse().with_context(|| format!("link table line {lno}"))?;
            let bw_mbps: f64 =
                fields[2].parse().with_context(|| format!("link table line {lno}"))?;
            let lat_ms: f64 =
                fields[3].parse().with_context(|| format!("link table line {lno}"))?;
            anyhow::ensure!(i < n && j < n && i != j, "link table line {lno}: bad pair {i},{j}");
            let cfg = NetworkConfig::new(bw_mbps * 1e6, lat_ms * 1e-3);
            m.links[i * n + j] = cfg;
            m.links[j * n + i] = cfg;
        }
        Ok(m)
    }

    /// Parse a CLI/config spec: `uniform`, `lognormal:SIGMA`, or
    /// `file:PATH` (a [`Self::from_table`] file).
    pub fn from_spec(spec: &str, n: usize, base: NetworkConfig, seed: u64) -> Result<Self> {
        if spec == "uniform" {
            return Ok(Self::uniform(n, base));
        }
        if let Some(sigma) = spec.strip_prefix("lognormal:") {
            let sigma: f64 = sigma.parse().context("link_matrix=lognormal:SIGMA")?;
            return Ok(Self::lognormal(n, base, sigma, seed));
        }
        if let Some(path) = spec.strip_prefix("file:") {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("read link table {path}"))?;
            return Self::from_table(&text, n, base);
        }
        anyhow::bail!("unknown link_matrix spec '{spec}' (uniform|lognormal:S|file:PATH)")
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// True when every link is identical (the DES round time then reduces
    /// to the closed-form `NetworkConfig::gossip_round_time`).
    pub fn is_uniform(&self) -> bool {
        self.uniform
    }

    /// Link parameters of the (i, j) pair.
    #[inline]
    pub fn link(&self, i: usize, j: usize) -> &NetworkConfig {
        debug_assert!(i < self.n && j < self.n);
        &self.links[i * self.n + j]
    }

    /// One-way time of a `bytes` message on the (i, j) link.
    #[inline]
    pub fn message_time(&self, i: usize, j: usize, bytes: usize) -> f64 {
        self.link(i, j).message_time(bytes)
    }

    /// Serialization-only time (no latency) — the uplink occupancy of one
    /// message, which consecutive sends from the same worker pay serially.
    #[inline]
    pub fn serialization_time(&self, i: usize, j: usize, bytes: usize) -> f64 {
        bytes as f64 * 8.0 / self.link(i, j).bandwidth_bps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_matches_base_everywhere() {
        let m = LinkMatrix::uniform(4, NetworkConfig::fig1b());
        assert!(m.is_uniform());
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(*m.link(i, j), NetworkConfig::fig1b());
            }
        }
    }

    #[test]
    fn lognormal_is_symmetric_and_deterministic() {
        let a = LinkMatrix::lognormal(6, NetworkConfig::fig1b(), 0.5, 9);
        let b = LinkMatrix::lognormal(6, NetworkConfig::fig1b(), 0.5, 9);
        assert!(!a.is_uniform());
        for i in 0..6 {
            for j in 0..6 {
                assert_eq!(a.link(i, j), b.link(i, j));
                assert_eq!(a.link(i, j), a.link(j, i));
            }
        }
        let c = LinkMatrix::lognormal(6, NetworkConfig::fig1b(), 0.5, 10);
        assert_ne!(a.link(0, 1), c.link(0, 1), "seed must matter");
    }

    #[test]
    fn lognormal_zero_sigma_is_uniform() {
        let m = LinkMatrix::lognormal(4, NetworkConfig::fig1a(), 0.0, 1);
        assert!(m.is_uniform());
    }

    #[test]
    fn table_overrides_named_pairs_only() {
        let base = NetworkConfig::new(1e9, 1e-3);
        let m = LinkMatrix::from_table("# slow edge\n0 1 10 5\n", 3, base).unwrap();
        assert_eq!(m.link(0, 1).bandwidth_bps, 10e6);
        assert_eq!(m.link(1, 0).latency_s, 5e-3);
        assert_eq!(*m.link(1, 2), base);
        assert!(LinkMatrix::from_table("0 0 10 5\n", 3, base).is_err());
        assert!(LinkMatrix::from_table("0 9 10 5\n", 3, base).is_err());
    }

    #[test]
    fn spec_parsing() {
        let base = NetworkConfig::fig1b();
        assert!(LinkMatrix::from_spec("uniform", 4, base, 1).unwrap().is_uniform());
        assert!(!LinkMatrix::from_spec("lognormal:0.3", 4, base, 1)
            .unwrap()
            .is_uniform());
        assert!(LinkMatrix::from_spec("nope", 4, base, 1).is_err());
    }

    #[test]
    fn message_time_uses_the_edge_link() {
        let mut m = LinkMatrix::uniform(2, NetworkConfig::new(8e6, 0.0));
        m.uniform = false;
        m.links[1] = NetworkConfig::new(8e6, 1e-3); // 0->1 gains latency
        m.links[2] = m.links[1];
        assert!((m.message_time(0, 1, 1000) - 2e-3).abs() < 1e-12);
        assert!((m.serialization_time(0, 1, 1000) - 1e-3).abs() < 1e-12);
    }
}
