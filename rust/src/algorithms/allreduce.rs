//! Centralized baseline: exact AllReduce averaging of gradients every step
//! (what TensorFlow/MPI-style synchronous data parallelism does). All
//! workers hold identical parameters; the network model prices a full-
//! precision ring-allreduce per step — the latency/bandwidth hog of
//! Figure 1(c)/(d).

use super::engine::RoundPool;
use super::{common, CommScope, CommStats, Inbox, SendPhase, StepCtx, SyncAlgorithm};

pub struct AllReduce {
    d: usize,
    pool: RoundPool,
    mean_grad: Vec<f32>,
    /// Node-mode decode buffer for one peer's gradient payload.
    decode: Vec<f32>,
}

impl AllReduce {
    pub fn new(d: usize) -> Self {
        AllReduce {
            d,
            pool: RoundPool::for_dim(d),
            mean_grad: vec![0.0; d],
            decode: vec![0.0; d],
        }
    }
}

impl SyncAlgorithm for AllReduce {
    fn name(&self) -> &'static str {
        "allreduce"
    }

    fn set_threads(&mut self, threads: usize) {
        self.pool = RoundPool::new(threads);
    }

    fn swap_matrix(&mut self, _w: &crate::topology::CommMatrix) -> bool {
        true // AllReduce ignores the gossip graph entirely.
    }

    fn step(
        &mut self,
        xs: &mut [Vec<f32>],
        grads: &[Vec<f32>],
        lr: f32,
        _round: u64,
        _ctx: &StepCtx,
    ) -> CommStats {
        let n = xs.len();
        // The reduction stays sequential: its summation order is part of the
        // determinism contract (worker order, every pool width).
        self.mean_grad.fill(0.0);
        for g in grads {
            crate::linalg::axpy(&mut self.mean_grad, 1.0 / n as f32, g);
        }
        {
            let mean_grad = &self.mean_grad;
            self.pool.for_each_mut(xs, |_i, x| {
                crate::linalg::axpy(x, -lr, mean_grad);
            });
        }
        CommStats {
            bytes_per_msg: 0,
            messages: 0,
            allreduce_bytes: Some(self.d * 4),
            extra_local_passes: 0,
        }
    }

    /// The seal is appended/stripped by the round machine; the collective's
    /// byte model stays `allreduce_bytes` (the network prices a ring
    /// all-reduce, not the all-broadcast frames the cluster realizes it
    /// with), so there is nothing to re-price here — just accept the gate.
    fn set_verify_wire(&mut self, _on: bool) -> bool {
        true
    }

    fn comm_scope(&self) -> CommScope {
        // The collective needs every worker's gradient; the cluster runtime
        // realizes the allreduce as an all-broadcast (the network *model*
        // still prices it as a ring-allreduce, exactly like the lockstep
        // trainer).
        CommScope::All
    }

    fn node_send(
        &mut self,
        _i: usize,
        _x: &[f32],
        grad: &[f32],
        _lr: f32,
        _round: u64,
        _ctx: &StepCtx,
        payload: &mut Vec<u8>,
    ) {
        common::put_f32s(payload, grad);
    }

    /// The payload *is* the gradient: nothing exists to send before
    /// `loss_grad` finishes.
    fn send_phase(&self) -> SendPhase {
        SendPhase::PostGradient
    }

    fn node_recv(
        &mut self,
        i: usize,
        x: &mut [f32],
        grad: &[f32],
        lr: f32,
        _round: u64,
        _ctx: &StepCtx,
        inbox: &Inbox,
    ) -> CommStats {
        // Same sequential worker-order reduction as the lockstep step —
        // summation order is part of the determinism contract. The cohort is
        // {i} ∪ inbox senders, merged in ascending id order (identical to
        // the old 0..n loop for a contiguous cohort, and correct when an
        // elastic membership leaves holes in the id space).
        let n = inbox.len() + 1;
        let AllReduce { mean_grad, decode, .. } = self;
        mean_grad.fill(0.0);
        let scale = 1.0 / n as f32;
        let mut own_added = false;
        for (j, payload) in inbox.iter() {
            if !own_added && i < j {
                crate::linalg::axpy(mean_grad, scale, grad);
                own_added = true;
            }
            common::read_f32s_into(payload, decode);
            crate::linalg::axpy(mean_grad, scale, decode);
        }
        if !own_added {
            crate::linalg::axpy(mean_grad, scale, grad);
        }
        crate::linalg::axpy(x, -lr, mean_grad);
        CommStats {
            bytes_per_msg: 0,
            messages: 0,
            allreduce_bytes: Some(self.d * 4),
            extra_local_passes: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workers_stay_identical_and_descend() {
        let mut alg = AllReduce::new(4);
        let mut xs: Vec<Vec<f32>> = (0..3).map(|_| vec![1.0; 4]).collect();
        let grads: Vec<Vec<f32>> = (0..3)
            .map(|i| vec![i as f32; 4]) // mean gradient = 1.0
            .collect();
        let ctx = StepCtx { seed: 0, rho: 0.0, g_inf: 1.0 };
        let stats = alg.step(&mut xs, &grads, 0.5, 0, &ctx);
        for x in &xs {
            assert_eq!(x, &vec![0.5; 4]);
        }
        assert_eq!(stats.allreduce_bytes, Some(16));
    }
}
