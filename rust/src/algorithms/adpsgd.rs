//! AD-PSGD (Lian et al. 2017) and **Moniqua-AD-PSGD (Algorithm 3)** —
//! asynchronous decentralized SGD.
//!
//! An *iteration* is one event: a random worker `a` wakes, gossip-averages
//! with one random neighbor `b` (the time-varying `W_k` is the identity
//! plus a 2×2 ½-averaging block), and applies a gradient computed on a
//! *stale* snapshot of its own model (delay τ_k ≤ T):
//!
//! ```text
//!     X_{k+1} = X_k W_k + (X̂_k − X_k)(W_k − I) − α G̃_{k−τ_k}
//! ```
//!
//! The Moniqua variant exchanges modulo-quantized models on the gossip edge
//! with θ = 16·t_mix·α·G∞ and δ = 1/(64·t_mix + 2) (Theorem 5).

use super::common::{self, CommStats};
use crate::quant::{MoniquaCodec, QuantConfig};
use crate::topology::{GossipSampler, PairGossip, Topology};

/// Precision of the gossip exchange.
#[derive(Clone, Debug)]
pub enum AsyncVariant {
    FullPrecision,
    Moniqua { theta: f32, quant: QuantConfig },
}

/// Event-driven AD-PSGD engine. Gradients are supplied by the caller (the
/// coordinator owns the objective); this struct owns the gossip dynamics,
/// staleness bookkeeping, and quantized exchange.
pub struct AdPsgd {
    pub variant: AsyncVariant,
    sampler: GossipSampler,
    d: usize,
    /// Per-worker stale snapshot the in-flight gradient was computed on.
    snapshots: Vec<Option<(Vec<f32>, u64)>>,
    /// Observed staleness (events between snapshot and application).
    pub max_observed_delay: u64,
    codes: Vec<u32>,
    buf_a: Vec<f32>,
    buf_b: Vec<f32>,
    self_a: Vec<f32>,
    self_b: Vec<f32>,
    grad_buf: Vec<f32>,
    noise: Vec<f32>,
    seed: u64,
}

impl AdPsgd {
    pub fn new(topo: &Topology, d: usize, variant: AsyncVariant, seed: u64) -> Self {
        AdPsgd {
            variant,
            sampler: GossipSampler::new(topo, seed),
            d,
            snapshots: vec![None; topo.n()],
            max_observed_delay: 0,
            codes: vec![0; d],
            buf_a: vec![0.0; d],
            buf_b: vec![0.0; d],
            self_a: vec![0.0; d],
            self_b: vec![0.0; d],
            grad_buf: vec![0.0; d],
            noise: Vec::new(),
            seed,
        }
    }

    /// Estimate t_mix of this topology's gossip chain (Theorem 5 inputs).
    pub fn estimate_t_mix(topo: &Topology, seed: u64, max_t: usize) -> usize {
        GossipSampler::new(topo, seed).estimate_t_mix(max_t)
    }

    /// One asynchronous event. `grad_of(worker, params, out)` computes the
    /// stochastic gradient of `worker` at `params`. Returns the gossip pair
    /// and the traffic of this event.
    pub fn step_event(
        &mut self,
        xs: &mut [Vec<f32>],
        grad_of: &mut dyn FnMut(usize, &[f32], &mut [f32]),
        lr: f32,
        event: u64,
    ) -> (PairGossip, CommStats) {
        let pair = self.sampler.next_pair();
        self.step_pair(pair, xs, grad_of, lr, event)
    }

    /// As [`Self::step_event`] but with the waking worker chosen by the
    /// caller (the wall-clock trainer wakes the earliest-clock worker).
    pub fn step_for_worker(
        &mut self,
        a: usize,
        xs: &mut [Vec<f32>],
        grad_of: &mut dyn FnMut(usize, &[f32], &mut [f32]),
        lr: f32,
        event: u64,
    ) -> (PairGossip, CommStats) {
        let pair = self.sampler.pair_for(a);
        self.step_pair(pair, xs, grad_of, lr, event)
    }

    fn step_pair(
        &mut self,
        pair: PairGossip,
        xs: &mut [Vec<f32>],
        grad_of: &mut dyn FnMut(usize, &[f32], &mut [f32]),
        lr: f32,
        event: u64,
    ) -> (PairGossip, CommStats) {
        let (a, b) = (pair.a, pair.b);

        // --- gossip averaging over the (a, b) edge -----------------------
        let stats = match &self.variant {
            AsyncVariant::FullPrecision => {
                for k in 0..self.d {
                    let m = 0.5 * (xs[a][k] + xs[b][k]);
                    self.buf_a[k] = m;
                }
                xs[a].copy_from_slice(&self.buf_a);
                xs[b].copy_from_slice(&self.buf_a);
                CommStats {
                    bytes_per_msg: self.d * 4,
                    messages: 2,
                    allreduce_bytes: None,
                    extra_local_passes: 0,
                }
            }
            AsyncVariant::Moniqua { theta, quant } => {
                let codec = MoniquaCodec::from_theta(*theta, quant);
                common::rounding_noise(quant, self.seed, event, 0, self.d, &mut self.noise);
                // a -> b
                codec.encode_into(&xs[a], &self.noise, &mut self.codes);
                let bytes = common::wire_bytes(quant, &self.codes);
                codec.recover_into(&self.codes, &xs[b], &mut self.buf_a); // x̂_a at b
                // b -> a
                codec.encode_into(&xs[b], &self.noise, &mut self.codes);
                codec.recover_into(&self.codes, &xs[a], &mut self.buf_b); // x̂_b at a
                // local biased terms cancel the self-quantization noise
                // (persistent scratch: no per-event allocation on this path)
                codec.local_biased_into(&xs[a], &self.noise, &mut self.self_a);
                codec.local_biased_into(&xs[b], &self.noise, &mut self.self_b);
                for k in 0..self.d {
                    let da = 0.5 * (self.buf_b[k] - self.self_a[k]);
                    let db = 0.5 * (self.buf_a[k] - self.self_b[k]);
                    xs[a][k] += da;
                    xs[b][k] += db;
                }
                CommStats {
                    bytes_per_msg: bytes,
                    messages: 2,
                    allreduce_bytes: None,
                    extra_local_passes: 0,
                }
            }
        };

        // --- stale gradient update on the waking worker a ----------------
        match self.snapshots[a].take() {
            Some((snap, when)) => {
                self.max_observed_delay = self.max_observed_delay.max(event - when);
                self.grad_buf.fill(0.0);
                grad_of(a, &snap, &mut self.grad_buf);
                for k in 0..self.d {
                    xs[a][k] -= lr * self.grad_buf[k];
                }
            }
            None => {
                // First activation: no in-flight gradient yet.
            }
        }
        // Start computing the next gradient on the current model.
        self.snapshots[a] = Some((xs[a].clone(), event));

        (pair, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::theta::{delta_adpsgd, theta_adpsgd};

    fn quad_grad(c: f32) -> impl FnMut(usize, &[f32], &mut [f32]) {
        move |_w, p, g| {
            for (gi, &pi) in g.iter_mut().zip(p) {
                *gi = pi - c;
            }
        }
    }

    fn run(variant: AsyncVariant, events: u64, lr: f32) -> Vec<Vec<f32>> {
        let topo = Topology::Ring(6);
        let d = 8;
        let mut alg = AdPsgd::new(&topo, d, variant, 17);
        let mut xs: Vec<Vec<f32>> = (0..6).map(|_| vec![1.0; d]).collect();
        let mut grad = quad_grad(0.3);
        for e in 0..events {
            alg.step_event(&mut xs, &mut grad, lr, e);
        }
        xs
    }

    #[test]
    fn full_precision_converges() {
        let xs = run(AsyncVariant::FullPrecision, 3000, 0.1);
        for x in &xs {
            for &v in x {
                assert!((v - 0.3).abs() < 0.05, "v {v}");
            }
        }
    }

    #[test]
    fn moniqua_variant_converges_with_theorem5_settings() {
        let topo = Topology::Ring(6);
        let t_mix = AdPsgd::estimate_t_mix(&topo, 1, 100_000) as f64;
        let lr = 0.1;
        // Theorem 5: θ = 16 t_mix α G∞ (G∞ ≈ 1 here), δ = 1/(64 t_mix + 2).
        let delta = delta_adpsgd(t_mix);
        let bits = ((1.0 / delta).log2().ceil() as u32).clamp(2, 16);
        let theta = theta_adpsgd(lr as f64, 1.0, t_mix) as f32;
        let quant = QuantConfig::stochastic(bits);
        let xs = run(AsyncVariant::Moniqua { theta, quant }, 3000, lr);
        for x in &xs {
            for &v in x {
                assert!((v - 0.3).abs() < 0.1, "v {v}");
            }
        }
    }

    #[test]
    fn staleness_is_observed_and_bounded() {
        let topo = Topology::Ring(4);
        let mut alg = AdPsgd::new(&topo, 4, AsyncVariant::FullPrecision, 3);
        let mut xs: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0; 4]).collect();
        let mut grad = quad_grad(0.0);
        for e in 0..2000 {
            alg.step_event(&mut xs, &mut grad, 0.01, e);
        }
        assert!(alg.max_observed_delay > 0);
        assert!(alg.max_observed_delay < 200, "delay {}", alg.max_observed_delay);
    }

    #[test]
    fn moniqua_traffic_is_quantized() {
        let topo = Topology::Ring(4);
        let quant = QuantConfig::stochastic(8);
        let mut alg = AdPsgd::new(
            &topo,
            1000,
            AsyncVariant::Moniqua { theta: 2.0, quant },
            5,
        );
        let mut xs: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0; 1000]).collect();
        let mut grad = quad_grad(0.0);
        let (_, stats) = alg.step_event(&mut xs, &mut grad, 0.1, 0);
        assert_eq!(stats.bytes_per_msg, 1000);
        assert_eq!(stats.messages, 2);
    }

    #[test]
    fn gossip_preserves_mean_full_precision() {
        let topo = Topology::Ring(4);
        let mut alg = AdPsgd::new(&topo, 2, AsyncVariant::FullPrecision, 7);
        let mut xs: Vec<Vec<f32>> =
            (0..4).map(|i| vec![i as f32; 2]).collect();
        let mut grad = |_w: usize, _p: &[f32], g: &mut [f32]| g.fill(0.0);
        for e in 0..500 {
            alg.step_event(&mut xs, &mut grad, 0.0, e);
        }
        let mean: f32 = xs.iter().map(|x| x[0]).sum::<f32>() / 4.0;
        assert!((mean - 1.5).abs() < 1e-4, "mean {mean}");
        // consensus
        assert!(crate::linalg::linf_dist(&xs[0], &xs[3]) < 1e-3);
    }
}
