//! AD-PSGD (Lian et al. 2017) and **Moniqua-AD-PSGD (Algorithm 3)** —
//! asynchronous decentralized SGD.
//!
//! An *iteration* is one event: a random worker `a` wakes, gossip-averages
//! with one random neighbor `b` (the time-varying `W_k` is the identity
//! plus a 2×2 ½-averaging block), and applies a gradient computed on a
//! *stale* snapshot of its own model (delay τ_k ≤ T):
//!
//! ```text
//!     X_{k+1} = X_k W_k + (X̂_k − X_k)(W_k − I) − α G̃_{k−τ_k}
//! ```
//!
//! The Moniqua variant exchanges modulo-quantized models on the gossip edge
//! with θ = 16·t_mix·α·G∞ and δ = 1/(64·t_mix + 2) (Theorem 5).

// BTreeMap, not HashMap: the stale cache is serialized into snapshot blobs
// that equivalence suites compare bitwise, so iteration order is part of
// the value path (`unordered` lint).
use std::collections::BTreeMap;

use super::common::{self, CommStats};
use crate::quant::{MoniquaCodec, QuantConfig};
use crate::topology::{GossipSampler, PairGossip, Topology};

/// Precision of the gossip exchange.
#[derive(Clone, Debug)]
pub enum AsyncVariant {
    FullPrecision,
    Moniqua { theta: f32, quant: QuantConfig },
}

/// Event-driven AD-PSGD engine. Gradients are supplied by the caller (the
/// coordinator owns the objective); this struct owns the gossip dynamics,
/// staleness bookkeeping, and quantized exchange.
pub struct AdPsgd {
    pub variant: AsyncVariant,
    sampler: GossipSampler,
    d: usize,
    /// Per-worker stale snapshot the in-flight gradient was computed on.
    snapshots: Vec<Option<(Vec<f32>, u64)>>,
    /// Observed staleness (events between snapshot and application).
    pub max_observed_delay: u64,
    codes: Vec<u32>,
    buf_a: Vec<f32>,
    buf_b: Vec<f32>,
    self_a: Vec<f32>,
    self_b: Vec<f32>,
    grad_buf: Vec<f32>,
    noise: Vec<f32>,
    seed: u64,
    /// Stale-neighbor cache for fault-tolerant gossip (`None` until
    /// [`Self::enable_fault_tolerance`]): `stale[r][s]` is the last model of
    /// sender `s` that receiver `r` successfully obtained (for Moniqua, the
    /// *recovered* full-precision x̂ — so a drop-recovery never re-enters the
    /// modulo decode, which is what keeps the decode in-range even while
    /// faults temporarily widen the consensus distance past θ).
    stale: Option<Vec<BTreeMap<usize, Vec<f32>>>>,
    /// Directed deliveries that fell back to the stale cache.
    pub stale_fallbacks: u64,
    /// Directed deliveries dropped with no cached fallback (receiver side
    /// of the exchange skipped entirely).
    pub lost_exchanges: u64,
}

impl AdPsgd {
    pub fn new(topo: &Topology, d: usize, variant: AsyncVariant, seed: u64) -> Self {
        AdPsgd {
            variant,
            sampler: GossipSampler::new(topo, seed),
            d,
            snapshots: vec![None; topo.n()],
            max_observed_delay: 0,
            codes: vec![0; d],
            buf_a: vec![0.0; d],
            buf_b: vec![0.0; d],
            self_a: vec![0.0; d],
            self_b: vec![0.0; d],
            grad_buf: vec![0.0; d],
            noise: Vec::new(),
            seed,
            stale: None,
            stale_fallbacks: 0,
            lost_exchanges: 0,
        }
    }

    /// Turn on the stale-neighbor cache so dropped gossip messages degrade
    /// to averaging with the last successfully received copy instead of
    /// skipping the exchange. Off by default: the cache costs one d-vector
    /// per live (receiver, sender) pair and one copy per delivery.
    pub fn enable_fault_tolerance(&mut self) {
        if self.stale.is_none() {
            self.stale = Some(vec![BTreeMap::new(); self.snapshots.len()]);
        }
    }

    /// Swap the gossip graph mid-run (a `TopologySchedule` stage boundary);
    /// sampler RNG state and all per-worker state carry over.
    pub fn set_topology(&mut self, topo: &Topology) {
        assert_eq!(topo.n(), self.snapshots.len(), "topology swap changed worker count");
        self.sampler.set_topology(topo);
    }

    /// Sample the gossip pair for waking worker `a` without stepping — the
    /// DES runtime needs the peer to price the exchange's links before it
    /// commits the event.
    pub fn sample_pair(&mut self, a: usize) -> PairGossip {
        self.sampler.pair_for(a)
    }

    /// Estimate t_mix of this topology's gossip chain (Theorem 5 inputs).
    pub fn estimate_t_mix(topo: &Topology, seed: u64, max_t: usize) -> usize {
        GossipSampler::new(topo, seed).estimate_t_mix(max_t)
    }

    /// One asynchronous event. `grad_of(worker, params, out)` computes the
    /// stochastic gradient of `worker` at `params`. Returns the gossip pair
    /// and the traffic of this event.
    pub fn step_event(
        &mut self,
        xs: &mut [Vec<f32>],
        grad_of: &mut dyn FnMut(usize, &[f32], &mut [f32]),
        lr: f32,
        event: u64,
    ) -> (PairGossip, CommStats) {
        let pair = self.sampler.next_pair();
        self.step_pair_with_faults(pair, xs, grad_of, lr, event, true, true)
    }

    /// As [`Self::step_event`] but with the waking worker chosen by the
    /// caller (the wall-clock trainer wakes the earliest-clock worker).
    pub fn step_for_worker(
        &mut self,
        a: usize,
        xs: &mut [Vec<f32>],
        grad_of: &mut dyn FnMut(usize, &[f32], &mut [f32]),
        lr: f32,
        event: u64,
    ) -> (PairGossip, CommStats) {
        let pair = self.sampler.pair_for(a);
        self.step_pair_with_faults(pair, xs, grad_of, lr, event, true, true)
    }

    /// One asynchronous event over a caller-chosen pair with per-direction
    /// delivery flags (the DES runtime samples drops and prices links before
    /// committing the event). `deliver_ab` is the a→b message reaching `b`;
    /// `deliver_ba` is b→a reaching `a`. Both senders transmit regardless —
    /// a drop loses the payload in flight, it does not refund the wire.
    ///
    /// A receiver whose incoming message dropped falls back to the stale
    /// cache (see [`Self::enable_fault_tolerance`]); with no cached copy its
    /// half of the averaging is skipped. With both flags true this is
    /// bitwise-identical to the fault-free exchange.
    pub fn step_pair_with_faults(
        &mut self,
        pair: PairGossip,
        xs: &mut [Vec<f32>],
        grad_of: &mut dyn FnMut(usize, &[f32], &mut [f32]),
        lr: f32,
        event: u64,
        deliver_ab: bool,
        deliver_ba: bool,
    ) -> (PairGossip, CommStats) {
        let (a, b) = (pair.a, pair.b);
        let d = self.d;
        // Clone the (small) variant descriptor: the fallback paths below
        // need `&mut self` while the exchange dispatches on it.
        let variant = self.variant.clone();

        // --- gossip averaging over the (a, b) edge -----------------------
        let stats = match &variant {
            AsyncVariant::FullPrecision => {
                // Pre-exchange snapshots: both sides read the models as they
                // were when the messages left.
                self.buf_a.copy_from_slice(&xs[a]);
                self.buf_b.copy_from_slice(&xs[b]);
                if let Some(cache) = &mut self.stale {
                    if deliver_ab {
                        cache_store(cache, b, a, &self.buf_a);
                    }
                    if deliver_ba {
                        cache_store(cache, a, b, &self.buf_b);
                    }
                }
                if deliver_ba {
                    for k in 0..d {
                        xs[a][k] = 0.5 * (self.buf_a[k] + self.buf_b[k]);
                    }
                } else {
                    self.recover_from_stale(xs, a, b);
                }
                if deliver_ab {
                    for k in 0..d {
                        xs[b][k] = 0.5 * (self.buf_b[k] + self.buf_a[k]);
                    }
                } else {
                    self.recover_from_stale(xs, b, a);
                }
                CommStats {
                    bytes_per_msg: d * 4,
                    messages: 2,
                    allreduce_bytes: None,
                    extra_local_passes: 0,
                }
            }
            AsyncVariant::Moniqua { theta, quant } => {
                let codec = MoniquaCodec::from_theta(*theta, quant);
                common::rounding_noise(quant, self.seed, event, 0, d, &mut self.noise);
                // Both senders encode and transmit regardless of delivery;
                // each delivered direction is decoded against the
                // *receiver's* model (Lemma 1's reference point), before
                // either side updates.
                codec.encode_into(&xs[a], &self.noise, &mut self.codes); // a -> b
                let bytes = common::wire_bytes(quant, &self.codes);
                if deliver_ab {
                    codec.recover_into(&self.codes, &xs[b], &mut self.buf_a); // x̂_a at b
                    codec.local_biased_into(&xs[b], &self.noise, &mut self.self_b);
                }
                codec.encode_into(&xs[b], &self.noise, &mut self.codes); // b -> a
                if deliver_ba {
                    codec.recover_into(&self.codes, &xs[a], &mut self.buf_b); // x̂_b at a
                    codec.local_biased_into(&xs[a], &self.noise, &mut self.self_a);
                }
                if let Some(cache) = &mut self.stale {
                    // Cache the *recovered* full-precision copies: a later
                    // drop-recovery averages with plain f32 values and never
                    // asks the modulo decode to span a fault-widened gap.
                    if deliver_ab {
                        cache_store(cache, b, a, &self.buf_a);
                    }
                    if deliver_ba {
                        cache_store(cache, a, b, &self.buf_b);
                    }
                }
                // local biased terms cancel the self-quantization noise
                // (persistent scratch: no per-event allocation on this path)
                if deliver_ba {
                    for k in 0..d {
                        xs[a][k] += 0.5 * (self.buf_b[k] - self.self_a[k]);
                    }
                } else {
                    self.recover_from_stale(xs, a, b);
                }
                if deliver_ab {
                    for k in 0..d {
                        xs[b][k] += 0.5 * (self.buf_a[k] - self.self_b[k]);
                    }
                } else {
                    self.recover_from_stale(xs, b, a);
                }
                CommStats {
                    bytes_per_msg: bytes,
                    messages: 2,
                    allreduce_bytes: None,
                    extra_local_passes: 0,
                }
            }
        };

        // --- stale gradient update on the waking worker a ----------------
        match self.snapshots[a].take() {
            Some((snap, when)) => {
                self.max_observed_delay = self.max_observed_delay.max(event - when);
                self.grad_buf.fill(0.0);
                grad_of(a, &snap, &mut self.grad_buf);
                for k in 0..self.d {
                    xs[a][k] -= lr * self.grad_buf[k];
                }
            }
            None => {
                // First activation: no in-flight gradient yet.
            }
        }
        // Start computing the next gradient on the current model.
        self.snapshots[a] = Some((xs[a].clone(), event));

        (pair, stats)
    }

    /// Serialize the engine's persistent state: the gossip sampler's RNG
    /// cursor, the in-flight stale-gradient snapshots, the stale-neighbor
    /// cache, and the fault counters — everything a crashed async worker
    /// needs to resume its event stream bit-for-bit. Companion of
    /// [`SyncAlgorithm::snapshot`](crate::algorithms::SyncAlgorithm::snapshot)
    /// for the event-driven engine (which is not a `SyncAlgorithm`).
    pub fn snapshot(&self, out: &mut Vec<u8>) {
        use crate::elastic::snapshot as ss;
        for w in self.sampler.rng_raw() {
            ss::put_u64(out, w);
        }
        ss::put_u64(out, self.max_observed_delay);
        ss::put_u64(out, self.stale_fallbacks);
        ss::put_u64(out, self.lost_exchanges);
        ss::put_u32(out, self.snapshots.len() as u32);
        for snap in &self.snapshots {
            match snap {
                None => ss::put_u8(out, 0),
                Some((x, when)) => {
                    ss::put_u8(out, 1);
                    ss::put_f32_slice(out, x);
                    ss::put_u64(out, *when);
                }
            }
        }
        match &self.stale {
            None => ss::put_u8(out, 0),
            Some(cache) => {
                ss::put_u8(out, 1);
                for per_recv in cache {
                    // BTreeMap iteration is already sorted by sender, so
                    // the blob is insertion-order independent (snapshot
                    // bytes are compared bitwise by the roundtrip property
                    // test and `stale_cache_snapshot_is_order_independent`).
                    ss::put_u32(out, per_recv.len() as u32);
                    for (s, x) in per_recv {
                        ss::put_u64(out, *s as u64);
                        ss::put_f32_slice(out, x);
                    }
                }
            }
        }
    }

    /// Restore state written by [`Self::snapshot`] onto a freshly
    /// constructed engine of the same topology/dimension/variant.
    pub fn restore(&mut self, bytes: &[u8]) -> Result<(), crate::elastic::SnapshotError> {
        use crate::elastic::{snapshot as ss, SnapshotError};
        let mut r = ss::Reader::new(bytes);
        let raw = [r.take_u64()?, r.take_u64()?, r.take_u64()?, r.take_u64()?];
        let max_observed_delay = r.take_u64()?;
        let stale_fallbacks = r.take_u64()?;
        let lost_exchanges = r.take_u64()?;
        let n = r.take_u32()? as usize;
        if n != self.snapshots.len() {
            return Err(SnapshotError::Malformed("adpsgd worker count"));
        }
        let mut snapshots = Vec::with_capacity(n);
        for _ in 0..n {
            snapshots.push(match r.take_u8()? {
                0 => None,
                1 => {
                    let x = r.take_f32_vec()?;
                    if x.len() != self.d {
                        return Err(SnapshotError::Malformed("adpsgd snapshot dim"));
                    }
                    let when = r.take_u64()?;
                    Some((x, when))
                }
                _ => return Err(SnapshotError::Malformed("adpsgd snapshot tag")),
            });
        }
        let stale = match r.take_u8()? {
            0 => None,
            1 => {
                let mut cache = Vec::with_capacity(n);
                for _ in 0..n {
                    let entries = r.take_u32()? as usize;
                    let mut per_recv = BTreeMap::new();
                    for _ in 0..entries {
                        let s = r.take_u64()? as usize;
                        if s >= n {
                            return Err(SnapshotError::Malformed("adpsgd stale sender"));
                        }
                        let x = r.take_f32_vec()?;
                        if x.len() != self.d {
                            return Err(SnapshotError::Malformed("adpsgd stale dim"));
                        }
                        per_recv.insert(s, x);
                    }
                    cache.push(per_recv);
                }
                Some(cache)
            }
            _ => return Err(SnapshotError::Malformed("adpsgd stale tag")),
        };
        r.finish()?;
        self.sampler.set_rng_raw(raw);
        self.max_observed_delay = max_observed_delay;
        self.stale_fallbacks = stale_fallbacks;
        self.lost_exchanges = lost_exchanges;
        self.snapshots = snapshots;
        self.stale = stale;
        Ok(())
    }

    /// Receiver `r` lost the incoming message from sender `s`: average with
    /// the cached stale copy when one exists (plain f32, never through the
    /// modulo decode), otherwise skip `r`'s half of the exchange.
    fn recover_from_stale(&mut self, xs: &mut [Vec<f32>], r: usize, s: usize) {
        let d = self.d;
        let hit = if let Some(old) = self.stale.as_ref().and_then(|c| c[r].get(&s)) {
            for k in 0..d {
                xs[r][k] = 0.5 * (xs[r][k] + old[k]);
            }
            true
        } else {
            false
        };
        if hit {
            self.stale_fallbacks += 1;
        } else {
            self.lost_exchanges += 1;
        }
    }
}

/// Overwrite receiver `recv`'s cached copy of sender `send`'s model.
fn cache_store(
    cache: &mut [BTreeMap<usize, Vec<f32>>],
    recv: usize,
    send: usize,
    val: &[f32],
) {
    let slot = cache[recv].entry(send).or_default();
    slot.resize(val.len(), 0.0);
    slot.copy_from_slice(val);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::theta::{delta_adpsgd, theta_adpsgd};

    fn quad_grad(c: f32) -> impl FnMut(usize, &[f32], &mut [f32]) {
        move |_w, p, g| {
            for (gi, &pi) in g.iter_mut().zip(p) {
                *gi = pi - c;
            }
        }
    }

    fn run(variant: AsyncVariant, events: u64, lr: f32) -> Vec<Vec<f32>> {
        let topo = Topology::Ring(6);
        let d = 8;
        let mut alg = AdPsgd::new(&topo, d, variant, 17);
        let mut xs: Vec<Vec<f32>> = (0..6).map(|_| vec![1.0; d]).collect();
        let mut grad = quad_grad(0.3);
        for e in 0..events {
            alg.step_event(&mut xs, &mut grad, lr, e);
        }
        xs
    }

    #[test]
    fn stale_cache_snapshot_is_order_independent() {
        // Pins the `unordered` lint's reason to exist: the stale cache is
        // serialized into snapshot blobs that replicas compare bitwise, so
        // the bytes must not depend on cache insertion order.
        let topo = Topology::Ring(4);
        let d = 4;
        let mk = || {
            let mut a = AdPsgd::new(&topo, d, AsyncVariant::FullPrecision, 7);
            a.enable_fault_tolerance();
            a
        };
        let mut a = mk();
        let mut b = mk();
        let vals: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32 + 0.5; d]).collect();
        for s in 0..4usize {
            cache_store(a.stale.as_mut().unwrap(), 0, s, &vals[s]);
        }
        for s in (0..4usize).rev() {
            cache_store(b.stale.as_mut().unwrap(), 0, s, &vals[s]);
        }
        let (mut blob_a, mut blob_b) = (Vec::new(), Vec::new());
        a.snapshot(&mut blob_a);
        b.snapshot(&mut blob_b);
        assert_eq!(blob_a, blob_b, "snapshot bytes depend on insertion order");
    }

    #[test]
    fn full_precision_converges() {
        let xs = run(AsyncVariant::FullPrecision, 3000, 0.1);
        for x in &xs {
            for &v in x {
                assert!((v - 0.3).abs() < 0.05, "v {v}");
            }
        }
    }

    #[test]
    fn moniqua_variant_converges_with_theorem5_settings() {
        let topo = Topology::Ring(6);
        let t_mix = AdPsgd::estimate_t_mix(&topo, 1, 100_000) as f64;
        let lr = 0.1;
        // Theorem 5: θ = 16 t_mix α G∞ (G∞ ≈ 1 here), δ = 1/(64 t_mix + 2).
        let delta = delta_adpsgd(t_mix);
        let bits = ((1.0 / delta).log2().ceil() as u32).clamp(2, 16);
        let theta = theta_adpsgd(lr as f64, 1.0, t_mix) as f32;
        let quant = QuantConfig::stochastic(bits);
        let xs = run(AsyncVariant::Moniqua { theta, quant }, 3000, lr);
        for x in &xs {
            for &v in x {
                assert!((v - 0.3).abs() < 0.1, "v {v}");
            }
        }
    }

    #[test]
    fn staleness_is_observed_and_bounded() {
        let topo = Topology::Ring(4);
        let mut alg = AdPsgd::new(&topo, 4, AsyncVariant::FullPrecision, 3);
        let mut xs: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0; 4]).collect();
        let mut grad = quad_grad(0.0);
        for e in 0..2000 {
            alg.step_event(&mut xs, &mut grad, 0.01, e);
        }
        assert!(alg.max_observed_delay > 0);
        assert!(alg.max_observed_delay < 200, "delay {}", alg.max_observed_delay);
    }

    #[test]
    fn moniqua_traffic_is_quantized() {
        let topo = Topology::Ring(4);
        let quant = QuantConfig::stochastic(8);
        let mut alg = AdPsgd::new(
            &topo,
            1000,
            AsyncVariant::Moniqua { theta: 2.0, quant },
            5,
        );
        let mut xs: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0; 1000]).collect();
        let mut grad = quad_grad(0.0);
        let (_, stats) = alg.step_event(&mut xs, &mut grad, 0.1, 0);
        assert_eq!(stats.bytes_per_msg, 1000);
        assert_eq!(stats.messages, 2);
    }

    #[test]
    fn dropped_message_falls_back_to_stale_cache() {
        let topo = Topology::Ring(4);
        let d = 6;
        let mut alg = AdPsgd::new(&topo, d, AsyncVariant::FullPrecision, 11);
        alg.enable_fault_tolerance();
        let mut xs: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32; d]).collect();
        let mut grad = |_w: usize, _p: &[f32], g: &mut [f32]| g.fill(0.0);
        let pair = PairGossip { a: 0, b: 1 };
        // Delivered exchange caches each side's pre-exchange model.
        alg.step_pair_with_faults(pair, &mut xs, &mut grad, 0.0, 0, true, true);
        assert_eq!(xs[0][0], 0.5);
        assert_eq!(xs[1][0], 0.5);
        // Worker 0 drifts; its next message to 1 is dropped: 1 averages
        // with the stale cached copy (0.0), 0 still gets 1's fresh model.
        xs[0] = vec![10.0; d];
        alg.step_pair_with_faults(pair, &mut xs, &mut grad, 0.0, 1, false, true);
        assert_eq!(alg.stale_fallbacks, 1);
        assert_eq!(xs[0][0], 0.5 * (10.0 + 0.5));
        assert_eq!(xs[1][0], 0.5 * (0.5 + 0.0));
        // A drop on a never-exchanged edge is a lost exchange: the receiver
        // keeps its model.
        let fresh = PairGossip { a: 2, b: 3 };
        let before = xs[3][0];
        alg.step_pair_with_faults(fresh, &mut xs, &mut grad, 0.0, 2, false, true);
        assert_eq!(alg.lost_exchanges, 1);
        assert_eq!(xs[3][0], before);
    }

    #[test]
    fn moniqua_converges_under_random_drops_with_fallback() {
        let topo = Topology::Ring(6);
        let d = 8;
        let quant = QuantConfig::stochastic(8);
        let mut alg =
            AdPsgd::new(&topo, d, AsyncVariant::Moniqua { theta: 2.0, quant }, 17);
        alg.enable_fault_tolerance();
        let mut xs: Vec<Vec<f32>> = (0..6).map(|_| vec![1.0; d]).collect();
        let mut grad = quad_grad(0.3);
        let mut drops = crate::rng::Pcg64::seeded(5);
        for e in 0..4000u64 {
            let a = drops.below(6) as usize;
            let pair = alg.sample_pair(a);
            let dab = drops.next_f64() >= 0.2;
            let dba = drops.next_f64() >= 0.2;
            alg.step_pair_with_faults(pair, &mut xs, &mut grad, 0.1, e, dab, dba);
        }
        assert!(alg.stale_fallbacks > 0, "drops must have fired");
        for x in &xs {
            for &v in x {
                assert!((v - 0.3).abs() < 0.15, "v {v}");
            }
        }
    }

    #[test]
    fn gossip_preserves_mean_full_precision() {
        let topo = Topology::Ring(4);
        let mut alg = AdPsgd::new(&topo, 2, AsyncVariant::FullPrecision, 7);
        let mut xs: Vec<Vec<f32>> =
            (0..4).map(|i| vec![i as f32; 2]).collect();
        let mut grad = |_w: usize, _p: &[f32], g: &mut [f32]| g.fill(0.0);
        for e in 0..500 {
            alg.step_event(&mut xs, &mut grad, 0.0, e);
        }
        let mean: f32 = xs.iter().map(|x| x[0]).sum::<f32>() / 4.0;
        assert!((mean - 1.5).abs() < 1e-4, "mean {mean}");
        // consensus
        assert!(crate::linalg::linf_dist(&xs[0], &xs[3]) < 1e-3);
    }
}
