//! Shared plumbing for the algorithm engines.

use crate::quant::{packing, LinearQuantizer, QuantConfig};
use crate::rng::{shared_round_rng, worker_rng, Pcg64};

/// Per-round context handed to [`super::SyncAlgorithm::step`].
#[derive(Clone, Copy, Debug)]
pub struct StepCtx {
    /// Experiment seed (drives shared-randomness streams).
    pub seed: u64,
    /// Spectral quantity ρ of the communication matrix (θ formulas).
    pub rho: f64,
    /// Tracked gradient ∞-norm (θ formulas; updated by the trainer).
    pub g_inf: f64,
}

/// Wire-traffic report for one synchronous round.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommStats {
    /// Bytes of one directed message (post-packing, post-compression,
    /// including the 8-byte digest when verification is on).
    pub bytes_per_msg: usize,
    /// Directed messages sent this round across the cluster.
    pub messages: u64,
    /// True when the round used an AllReduce instead of gossip (priced
    /// differently by the network model).
    pub allreduce_bytes: Option<usize>,
    /// Extra *local* full-vector passes beyond D-PSGD's (replica updates,
    /// error accumulators): the source of the constant lag the paper
    /// observes for DCD/ECD/Choco/DeepSqueeze on fast networks.
    pub extra_local_passes: u32,
}

/// Draw the stochastic-rounding noise vector for a round, honoring the
/// shared-randomness setting: shared → one stream per round identical on
/// every worker; private → per-(worker, round) stream.
pub fn rounding_noise(
    cfg: &QuantConfig,
    seed: u64,
    round: u64,
    worker: usize,
    d: usize,
    buf: &mut Vec<f32>,
) {
    buf.resize(d, 0.0);
    if cfg.rounding == crate::quant::Rounding::Nearest {
        return; // unused
    }
    let mut rng: Pcg64 = if cfg.shared_randomness {
        shared_round_rng(seed, round)
    } else {
        worker_rng(seed ^ round, worker, 0x0153)
    };
    rng.fill_uniform_f32(buf);
}

/// Encode-phase noise for worker `i` inside a parallel phase: in
/// shared-randomness mode, returns the round-shared buffer the caller drew
/// once before the phase (see [`rounding_noise`] with worker 0 — the
/// stream ignores the worker index there); in private mode, fills this
/// worker's scratch from its own `(seed, round, worker)` stream. Keeping
/// this in one place means the Moniqua and D² engines can never diverge on
/// the noise-stream convention.
pub fn phase_noise<'a>(
    cfg: &QuantConfig,
    seed: u64,
    round: u64,
    worker: usize,
    d: usize,
    shared: &'a [f32],
    buf: &'a mut Vec<f32>,
) -> &'a [f32] {
    if cfg.shared_randomness {
        shared
    } else {
        rounding_noise(cfg, seed, round, worker, d, buf);
        buf
    }
}

/// Wire size of a packed+compressed+digested message carrying `d` codes.
///
/// Without recompression the payload length is a pure function of `(d,
/// bits)`, so it is computed arithmetically via
/// [`QuantConfig::payload_bytes`] — the compressor (and the re-pack that
/// used to feed it) only runs when `compression != None`.
pub fn wire_bytes(cfg: &QuantConfig, codes: &[u32]) -> usize {
    let payload = match cfg.compression {
        crate::quant::Compression::None => cfg.payload_bytes(codes.len()),
        comp => comp.wire_len(&packing::pack(codes, cfg.bits)),
    };
    payload + if cfg.verify_hash { 8 } else { 0 }
}

/// As [`wire_bytes`] but for a message that already exists in packed wire
/// form (the fused `encode_packed_into` path): never re-packs, and only
/// invokes the compressor when one is configured.
pub fn wire_bytes_packed(cfg: &QuantConfig, d: usize, packed: &[u8]) -> usize {
    debug_assert_eq!(packed.len(), cfg.payload_bytes(d));
    let payload = match cfg.compression {
        crate::quant::Compression::None => cfg.payload_bytes(d),
        comp => comp.wire_len(packed),
    };
    payload + if cfg.verify_hash { 8 } else { 0 }
}

/// How an engine folds neighbor contributions into its local model (the
/// `mix=` config key). [`MixPolicy::Mean`] is the paper's weighted gossip
/// average and the bitwise-pinned default; the robust options bound a
/// Byzantine outlier's influence on each coordinate:
///
/// * [`MixPolicy::Clipped`]`(τ)` clamps every neighbor *deviation* term
///   (the neighbor's value relative to the local model) to `[-τ, τ]`
///   before applying the gossip weight;
/// * [`MixPolicy::Median`] replaces the weighted sum of deviations with
///   the coordinate-wise median of neighbor deviations, scaled by the
///   total off-diagonal weight.
///
/// Both are deterministic: the per-coordinate operations are pure
/// functions of the (ascending-sender-ordered) neighbor values, so the
/// lockstep and cluster runtimes stay bitwise identical under any policy.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum MixPolicy {
    #[default]
    Mean,
    /// Clamp each coordinate's deviation to `±τ` (τ > 0).
    Clipped(f32),
    /// Coordinate-wise median of neighbor deviations.
    Median,
}

impl MixPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            MixPolicy::Mean => "mean",
            MixPolicy::Clipped(_) => "clipped",
            MixPolicy::Median => "median",
        }
    }
}

/// Which peers a node-level round exchanges payloads with (the
/// [`super::SyncAlgorithm::node_send`] /
/// [`super::SyncAlgorithm::node_recv`] split).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommScope {
    /// Gossip: payloads flow along topology edges only.
    Neighbors,
    /// Collective: every worker's payload reaches every other worker
    /// (AllReduce's gradient exchange).
    All,
}

/// One round's inbound payloads at a node, keyed by sender id. Engine
/// iteration order never depends on arrival order — the message-passing
/// analogue of the round engine's "accumulate in neighbor order"
/// determinism rule.
///
/// Two representations, same contract:
///
/// * [`Inbox::new`] — owned `(sender, payload)` pairs, sorted here;
/// * [`Inbox::from_frames`] — a borrowed slice of received
///   [`Frame`](crate::transport::Frame)s the caller sorted by sender
///   (§Perf: the cluster node's persistent frame buffer, so building an
///   inbox allocates nothing — pinned by `tests/alloc_discipline.rs`).
pub struct Inbox<'a> {
    msgs: InboxRepr<'a>,
}

enum InboxRepr<'a> {
    Pairs(Vec<(usize, &'a [u8])>),
    Frames(&'a [crate::transport::Frame]),
    /// Frames plus a sorted list of senders whose payload is *substituted*
    /// by the receiver's own current-round payload — the defense layer's
    /// detection-window fallback: a rejected sender contributes the local
    /// model, which cancels its deviation term exactly (gossip weights
    /// stay row-stochastic, no engine change needed).
    FramesSub {
        frames: &'a [crate::transport::Frame],
        own: &'a [u8],
        subst: &'a [usize],
    },
}

impl<'a> Inbox<'a> {
    pub fn new(mut msgs: Vec<(usize, &'a [u8])>) -> Self {
        msgs.sort_by_key(|&(from, _)| from);
        debug_assert!(
            msgs.windows(2).all(|w| w[0].0 != w[1].0),
            "duplicate sender in inbox"
        );
        Inbox { msgs: InboxRepr::Pairs(msgs) }
    }

    /// Borrow a round's frames directly — no per-round allocation. The
    /// caller must have sorted them by ascending sender (the determinism
    /// order); duplicate senders are rejected in debug builds.
    pub fn from_frames(frames: &'a [crate::transport::Frame]) -> Self {
        debug_assert!(
            frames.windows(2).all(|w| w[0].sender < w[1].sender),
            "frames must be sorted by sender, without duplicates"
        );
        Inbox { msgs: InboxRepr::Frames(frames) }
    }

    /// As [`Inbox::from_frames`], but senders listed in `subst` (sorted
    /// ascending) answer [`Inbox::payload`] with `own` — the receiver's
    /// own current-round payload — instead of a held frame. Used by the
    /// defense layer while a striking peer awaits conviction: the
    /// self-substituted contribution is the neutral element of every
    /// engine's accumulate loop, so no engine needs a rejection branch.
    pub fn from_frames_with_self(
        frames: &'a [crate::transport::Frame],
        own: &'a [u8],
        subst: &'a [usize],
    ) -> Self {
        debug_assert!(
            frames.windows(2).all(|w| w[0].sender < w[1].sender),
            "frames must be sorted by sender, without duplicates"
        );
        debug_assert!(
            subst.windows(2).all(|w| w[0] < w[1]),
            "substituted senders must be sorted, without duplicates"
        );
        debug_assert!(
            frames.iter().all(|f| subst.binary_search(&(f.sender as usize)).is_err()),
            "a sender cannot be both held and substituted"
        );
        Inbox { msgs: InboxRepr::FramesSub { frames, own, subst } }
    }

    /// Payload from sender `from`; panics if that peer's frame is missing
    /// (the cluster round barrier guarantees completeness before recv).
    pub fn payload(&self, from: usize) -> &'a [u8] {
        let found = match &self.msgs {
            InboxRepr::Pairs(msgs) => msgs
                .iter()
                .find(|&&(j, _)| j == from)
                .map(|&(_, p)| p),
            InboxRepr::Frames(frames) => {
                let frames: &'a [crate::transport::Frame] = *frames;
                frames
                    .iter()
                    .find(|f| f.sender as usize == from)
                    .map(|f| f.payload.as_slice())
            }
            InboxRepr::FramesSub { frames, own, subst } => {
                if subst.binary_search(&from).is_ok() {
                    Some(*own)
                } else {
                    let frames: &'a [crate::transport::Frame] = *frames;
                    frames
                        .iter()
                        .find(|f| f.sender as usize == from)
                        .map(|f| f.payload.as_slice())
                }
            }
        };
        found.unwrap_or_else(|| panic!("inbox missing payload from worker {from}"))
    }

    pub fn len(&self) -> usize {
        match &self.msgs {
            InboxRepr::Pairs(msgs) => msgs.len(),
            InboxRepr::Frames(frames) => frames.len(),
            InboxRepr::FramesSub { frames, subst, .. } => frames.len() + subst.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `(sender, payload)` pairs in ascending sender order.
    pub fn iter(&self) -> InboxIter<'a, '_> {
        InboxIter { inbox: self, fi: 0, si: 0 }
    }
}

/// Ascending-sender iterator over an [`Inbox`] (merges held frames with
/// substituted senders in the [`InboxRepr::FramesSub`] case). A named
/// type (not `impl Iterator`) so the three representations share one
/// zero-allocation walker.
pub struct InboxIter<'a, 'b> {
    inbox: &'b Inbox<'a>,
    fi: usize,
    si: usize,
}

impl<'a> Iterator for InboxIter<'a, '_> {
    type Item = (usize, &'a [u8]);

    fn next(&mut self) -> Option<(usize, &'a [u8])> {
        match &self.inbox.msgs {
            InboxRepr::Pairs(msgs) => {
                let &(j, p) = msgs.get(self.fi)?;
                self.fi += 1;
                Some((j, p))
            }
            InboxRepr::Frames(frames) => {
                let f = frames.get(self.fi)?;
                self.fi += 1;
                Some((f.sender as usize, f.payload.as_slice()))
            }
            InboxRepr::FramesSub { frames, own, subst } => {
                let frame = frames.get(self.fi);
                let sub = subst.get(self.si).copied();
                match (frame, sub) {
                    (None, None) => None,
                    (Some(f), None) => {
                        self.fi += 1;
                        Some((f.sender as usize, f.payload.as_slice()))
                    }
                    (None, Some(s)) => {
                        self.si += 1;
                        Some((s, *own))
                    }
                    (Some(f), Some(s)) => {
                        if (f.sender as usize) < s {
                            self.fi += 1;
                            Some((f.sender as usize, f.payload.as_slice()))
                        } else {
                            self.si += 1;
                            Some((s, *own))
                        }
                    }
                }
            }
        }
    }
}

/// Append `xs` as little-endian f32 words — the full-precision payload
/// encoding (lossless: `f32 → bits → f32` is the identity, so decoded
/// models are bitwise the models the lockstep engines read directly).
pub fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(4 * xs.len());
    for &x in xs {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

/// Decode a [`put_f32s`] payload into `out` (lengths must agree).
pub fn read_f32s_into(bytes: &[u8], out: &mut [f32]) {
    assert_eq!(bytes.len(), 4 * out.len(), "f32 payload length mismatch");
    for (o, c) in out.iter_mut().zip(bytes.chunks_exact(4)) {
        *o = f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
}

/// Receiver half of the baseline engines' wire format: strip the optional
/// 4-byte dynamic-scale header (QSGD-style self-describing range), unpack
/// the `bits`-packed codes, and decode them to grid values — bitwise the
/// `values` the sender's `quantize_into`/`quantize_dynamic_into` produced.
/// One definition so the dcd/ecd/naive/choco/deepsqueeze recv halves can
/// never disagree on this layout.
pub fn decode_baseline_payload(
    quant: &RangeQuantizer,
    dynamic: bool,
    bits: u32,
    payload: &[u8],
    codes: &mut [u32],
    vals: &mut [f32],
) {
    let (range, codes_bytes) = if dynamic {
        let b = u32::from_le_bytes(payload[..4].try_into().expect("4-byte scale header"));
        (f32::from_bits(b), &payload[4..])
    } else {
        (quant.range, payload)
    };
    packing::unpack_into(codes_bytes, bits, codes);
    RangeQuantizer { inner: quant.inner, range }.dequantize_into(codes, vals);
}

/// A bounded-range quantizer used by the *baseline* algorithms (DCD/ECD/
/// Choco/DeepSqueeze and the naive scheme): values are scaled by `1/range`,
/// clipped into `[-1/2, 1/2)`, and quantized by the shared linear quantizer.
/// Matches how the paper runs all baselines with "the same quantizer"
/// (stochastic rounding at a fixed bit width); `range` plays the role of
/// the representable span. Clipping is what makes aggressive budgets break
/// the difference-compression baselines, exactly as in Table 2.
#[derive(Clone, Copy, Debug)]
pub struct RangeQuantizer {
    pub inner: LinearQuantizer,
    pub range: f32,
}

impl RangeQuantizer {
    pub fn new(cfg: &QuantConfig, range: f32) -> Self {
        assert!(range > 0.0);
        RangeQuantizer {
            inner: LinearQuantizer::new(cfg.levels(), cfg.rounding),
            range,
        }
    }

    /// Absolute-value error bound: δ·range.
    pub fn max_error(&self) -> f32 {
        (self.inner.delta() as f32) * self.range
    }

    /// Dynamic per-message scaling (QSGD-style, what practical systems —
    /// and the DCD/ECD baselines' reference implementations — do): the
    /// range is `2·max|v|` for this message and travels as a 4-byte f32
    /// header. Unbiased with *relative* error ≤ 2δ·max|v|; returns the
    /// scale used. Self-describing, so no range tuning and no clipping.
    pub fn quantize_dynamic_into(
        &self,
        x: &[f32],
        noise: &[f32],
        codes: &mut [u32],
        values: &mut [f32],
    ) -> f32 {
        let maxabs = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let range = (2.0 * maxabs).max(1e-12);
        let q = RangeQuantizer { inner: self.inner, range };
        q.quantize_into(x, noise, codes, values);
        range
    }

    /// Receiver-side decode: grid values for `codes` — exactly the
    /// `values` that [`Self::quantize_into`] wrote on the sender (the value
    /// is a pure function of the code, the level count, and the range, so
    /// recomputing it from the wire codes is bitwise the sender's result).
    pub fn dequantize_into(&self, codes: &[u32], out: &mut [f32]) {
        debug_assert_eq!(codes.len(), out.len());
        let l = self.inner.levels as f32;
        for (o, &c) in out.iter_mut().zip(codes) {
            *o = ((c as f32 + 0.5) / l - 0.5) * self.range;
        }
    }

    /// Quantize `x` into codes (scaled+clipped), writing grid values
    /// (de-quantized, re-scaled) into `values`.
    pub fn quantize_into(
        &self,
        x: &[f32],
        noise: &[f32],
        codes: &mut [u32],
        values: &mut [f32],
    ) {
        let inv_r = 1.0 / self.range;
        let l = self.inner.levels as f32;
        let max_code = (self.inner.levels - 1) as i64;
        let stochastic = matches!(self.inner.rounding, crate::quant::Rounding::Stochastic);
        for i in 0..x.len() {
            let w = (x[i] * inv_r).clamp(-0.5, 0.4999999);
            let t = if stochastic {
                (w + 0.5) * l - 0.5 + noise[i]
            } else {
                (w + 0.5) * l
            };
            let c = (t.floor() as i64).clamp(0, max_code) as u32;
            codes[i] = c;
            values[i] = ((c as f32 + 0.5) / l - 0.5) * self.range;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Compression, QuantConfig};
    use crate::testing::{forall, gaussian_vec};

    #[test]
    fn shared_noise_identical_across_workers() {
        let cfg = QuantConfig::stochastic(8);
        let mut a = Vec::new();
        let mut b = Vec::new();
        rounding_noise(&cfg, 7, 3, 0, 64, &mut a);
        rounding_noise(&cfg, 7, 3, 5, 64, &mut b);
        assert_eq!(a, b);
        let cfg2 = cfg.with_shared_randomness(false);
        rounding_noise(&cfg2, 7, 3, 5, 64, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn wire_bytes_accounts_hash_and_compression() {
        let codes = vec![7u32; 1000];
        let plain = wire_bytes(&QuantConfig::stochastic(8), &codes);
        assert_eq!(plain, 1000);
        let hashed = wire_bytes(&QuantConfig::stochastic(8).with_verify_hash(true), &codes);
        assert_eq!(hashed, 1008);
        // RLE is always compiled in; a constant stream collapses to runs.
        let zipped = wire_bytes(
            &QuantConfig::stochastic(8).with_compression(Compression::Rle),
            &codes,
        );
        assert!(zipped < plain, "constant stream compresses: {zipped}");
    }

    #[test]
    fn wire_bytes_is_arithmetic_without_compression() {
        // No compressor configured → length must equal the closed form for
        // every bit width (the packed buffer is never rebuilt).
        for bits in [1u32, 3, 8, 13] {
            let cfg = QuantConfig::nearest(bits);
            let codes = vec![0u32; 777];
            assert_eq!(wire_bytes(&cfg, &codes), cfg.payload_bytes(777));
        }
    }

    #[test]
    fn wire_bytes_packed_matches_codes_path() {
        let codes: Vec<u32> = (0..500u32).map(|i| i % 16).collect();
        for comp in Compression::enabled() {
            let cfg = QuantConfig::nearest(4)
                .with_compression(comp)
                .with_verify_hash(true);
            let packed = packing::pack(&codes, 4);
            assert_eq!(
                wire_bytes_packed(&cfg, codes.len(), &packed),
                wire_bytes(&cfg, &codes),
                "{comp:?}"
            );
        }
    }

    #[test]
    fn range_quantizer_error_within_range() {
        forall(100, |rng| {
            let cfg = QuantConfig::stochastic(2 + rng.below(7) as u32);
            let range = 0.5 + rng.next_f32() * 8.0;
            let q = RangeQuantizer::new(&cfg, range);
            let n = 1 + rng.below(200) as usize;
            // values inside the representable span
            let x: Vec<f32> = (0..n)
                .map(|_| (rng.next_f32() - 0.5) * 0.999 * range)
                .collect();
            let noise: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let mut codes = vec![0u32; n];
            let mut vals = vec![0.0f32; n];
            q.quantize_into(&x, &noise, &mut codes, &mut vals);
            for i in 0..n {
                assert!(
                    (vals[i] - x[i]).abs() <= q.max_error() + 1e-5,
                    "err {} bound {}",
                    (vals[i] - x[i]).abs(),
                    q.max_error()
                );
            }
        });
    }

    #[test]
    fn range_quantizer_clips_outliers() {
        let cfg = QuantConfig::nearest(4);
        let q = RangeQuantizer::new(&cfg, 1.0);
        let x = [100.0f32, -100.0];
        let mut codes = [0u32; 2];
        let mut vals = [0.0f32; 2];
        q.quantize_into(&x, &[0.0, 0.0], &mut codes, &mut vals);
        // clipped to the span edges: large *irreducible* error — the DCD/ECD
        // failure mode at low bit budgets.
        assert!(vals[0] < 1.0 && vals[1] > -1.0);
        assert!((vals[0] - 100.0).abs() > 90.0);
    }

    #[test]
    fn dequantize_matches_sender_values_bitwise() {
        forall(100, |rng| {
            let cfg = QuantConfig::stochastic(1 + rng.below(16) as u32);
            let range = 0.5 + rng.next_f32() * 8.0;
            let q = RangeQuantizer::new(&cfg, range);
            let n = rng.below(200) as usize;
            let x = gaussian_vec(rng, n, 2.0);
            let noise: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let mut codes = vec![0u32; n];
            let mut vals = vec![0.0f32; n];
            q.quantize_into(&x, &noise, &mut codes, &mut vals);
            let mut decoded = vec![0.0f32; n];
            q.dequantize_into(&codes, &mut decoded);
            assert_eq!(
                decoded.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            );
        });
    }

    #[test]
    fn f32_payload_roundtrip_is_bitwise() {
        forall(50, |rng| {
            let n = rng.below(300) as usize;
            let x = gaussian_vec(rng, n, 10.0);
            let mut bytes = Vec::new();
            put_f32s(&mut bytes, &x);
            assert_eq!(bytes.len(), 4 * n);
            let mut back = vec![0.0f32; n];
            read_f32s_into(&bytes, &mut back);
            assert_eq!(
                back.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            );
        });
    }

    #[test]
    fn inbox_sorts_and_looks_up() {
        let p2 = [2u8];
        let p0 = [0u8];
        let inbox = Inbox::new(vec![(2, &p2[..]), (0, &p0[..])]);
        assert_eq!(inbox.len(), 2);
        assert_eq!(inbox.payload(0), &p0[..]);
        assert_eq!(inbox.payload(2), &p2[..]);
        let order: Vec<usize> = inbox.iter().map(|(j, _)| j).collect();
        assert_eq!(order, vec![0, 2]);
    }

    #[test]
    #[should_panic]
    fn inbox_panics_on_missing_sender() {
        let inbox = Inbox::new(vec![]);
        inbox.payload(3);
    }

    #[test]
    fn inbox_from_frames_matches_owned_repr() {
        use crate::transport::{Frame, FrameKind};
        let mk = |sender: u16, payload: Vec<u8>| Frame {
            round: 1,
            sender,
            algo: 4,
            bits: 8,
            kind: FrameKind::Data,
            theta: 0.0,
            payload,
        };
        let frames = vec![mk(0, vec![10]), mk(2, vec![20, 21])];
        let borrowed = Inbox::from_frames(&frames);
        let owned = Inbox::new(
            frames.iter().map(|f| (f.sender as usize, f.payload.as_slice())).collect(),
        );
        assert_eq!(borrowed.len(), owned.len());
        for from in [0usize, 2] {
            assert_eq!(borrowed.payload(from), owned.payload(from));
        }
        let a: Vec<(usize, &[u8])> = borrowed.iter().collect();
        let b: Vec<(usize, &[u8])> = owned.iter().collect();
        assert_eq!(a, b);
        assert!(!borrowed.is_empty());
    }

    #[test]
    fn inbox_with_self_substitution_merges_in_sender_order() {
        use crate::transport::{Frame, FrameKind};
        let mk = |sender: u16, payload: Vec<u8>| Frame {
            round: 1,
            sender,
            algo: 4,
            bits: 8,
            kind: FrameKind::Data,
            theta: 0.0,
            payload,
        };
        let frames = vec![mk(0, vec![10]), mk(3, vec![30])];
        let own = [42u8];
        let subst = [1usize, 2];
        let inbox = Inbox::from_frames_with_self(&frames, &own, &subst);
        assert_eq!(inbox.len(), 4);
        // Substituted senders answer with the receiver's own payload…
        assert_eq!(inbox.payload(1), &own[..]);
        assert_eq!(inbox.payload(2), &own[..]);
        // …held senders with their frame.
        assert_eq!(inbox.payload(0), &[10][..]);
        assert_eq!(inbox.payload(3), &[30][..]);
        let order: Vec<(usize, &[u8])> = inbox.iter().collect();
        assert_eq!(
            order,
            vec![
                (0usize, &[10u8][..]),
                (1, &own[..]),
                (2, &own[..]),
                (3, &[30u8][..]),
            ]
        );
    }

    #[test]
    fn mix_policy_default_and_names() {
        assert_eq!(MixPolicy::default(), MixPolicy::Mean);
        assert_eq!(MixPolicy::Mean.name(), "mean");
        assert_eq!(MixPolicy::Clipped(0.5).name(), "clipped");
        assert_eq!(MixPolicy::Median.name(), "median");
    }

    #[test]
    fn noise_buffer_resized() {
        let cfg = QuantConfig::nearest(8);
        let mut buf = vec![1.0; 3];
        rounding_noise(&cfg, 1, 1, 0, 10, &mut buf);
        assert_eq!(buf.len(), 10);
    }

    #[test]
    fn range_quantizer_roundtrip_statistics() {
        let cfg = QuantConfig::stochastic(8);
        let q = RangeQuantizer::new(&cfg, 4.0);
        let mut rng = crate::rng::Pcg64::seeded(2);
        let x = gaussian_vec(&mut rng, 10_000, 0.5);
        let noise: Vec<f32> = (0..x.len()).map(|_| rng.next_f32()).collect();
        let mut codes = vec![0u32; x.len()];
        let mut vals = vec![0.0f32; x.len()];
        q.quantize_into(&x, &noise, &mut codes, &mut vals);
        let bias: f64 = x
            .iter()
            .zip(&vals)
            .map(|(a, b)| (*b - *a) as f64)
            .sum::<f64>()
            / x.len() as f64;
        assert!(bias.abs() < 1e-3, "stochastic rounding unbiased: {bias}");
    }
}
