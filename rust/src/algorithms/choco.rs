//! ChocoSGD (Koloskova et al. 2019): gossip with compressed model
//! differences and a consensus step size γ. Supports *arbitrary* (biased,
//! 1-bit) compressors by shrinking γ — at the cost of per-neighbor
//! estimate vectors (Θ(md) memory across the graph):
//!
//! ```text
//!     x_{k+½,i} = x_{k,i} − α g̃_i
//!     q_i = Q( x_{k+½,i} − x̂_i );   broadcast q_i
//!     x̂_i ← x̂_i + q_i                       (on every holder of x̂_i)
//!     x_{k+1,i} = x_{k+½,i} + γ Σ_j W_ji (x̂_j − x̂_i)
//! ```

use super::engine::RoundPool;
use super::{common, CommStats, Inbox, RangeQuantizer, SendPhase, StepCtx, SyncAlgorithm};
use crate::quant::{packing, QuantConfig};
use crate::topology::CommMatrix;

/// Per-worker round scratch (each field was previously either a shared
/// single buffer or a parallel `Vec<Vec<..>>`; bundling makes the compress
/// phase a single disjoint-write parallel loop).
struct Ws {
    half: Vec<f32>,
    diff: Vec<f32>,
    noise: Vec<f32>,
    codes: Vec<u32>,
    qdiff: Vec<f32>,
}

pub struct Choco {
    w: CommMatrix,
    d: usize,
    cfg: QuantConfig,
    quant: RangeQuantizer,
    pub gamma: f64,
    pool: RoundPool,
    xhat: Vec<Vec<f32>>,
    ws: Vec<Ws>,
    /// Node-mode decode buffers for one neighbor's quantized difference.
    node_codes: Vec<u32>,
    node_vals: Vec<f32>,
}

impl Choco {
    pub fn new(w: CommMatrix, d: usize, cfg: QuantConfig, range: f32, gamma: f64) -> Self {
        let n = w.n();
        Choco {
            w,
            d,
            cfg,
            quant: RangeQuantizer::new(&cfg, range),
            gamma,
            pool: RoundPool::for_dim(d),
            // ChocoSGD initializes estimates at 0 (not at x_0).
            xhat: vec![vec![0.0; d]; n],
            ws: (0..n)
                .map(|_| Ws {
                    half: vec![0.0; d],
                    diff: vec![0.0; d],
                    noise: Vec::new(),
                    codes: vec![0; d],
                    qdiff: vec![0.0; d],
                })
                .collect(),
            node_codes: vec![0; d],
            node_vals: vec![0.0; d],
        }
    }
}

impl SyncAlgorithm for Choco {
    fn name(&self) -> &'static str {
        "choco"
    }

    fn set_threads(&mut self, threads: usize) {
        self.pool = RoundPool::new(threads);
    }

    // Persistent state: the gossip estimates x̂ (initialized at 0, so no
    // lazy-init flag to carry).
    fn snapshot(&self, out: &mut Vec<u8>) {
        use crate::elastic::snapshot as ss;
        ss::put_u32(out, self.xhat.len() as u32);
        for row in &self.xhat {
            ss::put_f32_slice(out, row);
        }
    }

    fn restore(&mut self, bytes: &[u8]) -> Result<(), crate::elastic::SnapshotError> {
        use crate::elastic::{snapshot as ss, SnapshotError};
        let mut r = ss::Reader::new(bytes);
        if r.take_u32()? as usize != self.xhat.len() {
            return Err(SnapshotError::Malformed("choco estimate count"));
        }
        for row in self.xhat.iter_mut() {
            r.take_f32_into(row)?;
        }
        r.finish()
    }

    fn step(
        &mut self,
        xs: &mut [Vec<f32>],
        grads: &[Vec<f32>],
        lr: f32,
        round: u64,
        ctx: &StepCtx,
    ) -> CommStats {
        let cfg = self.cfg;
        let d = self.d;
        let quant = self.quant;
        let seed = ctx.seed;
        // half-step + compress difference to own estimate
        {
            let xs_r: &[Vec<f32>] = xs;
            let xhat = &self.xhat;
            self.pool.for_each_mut(&mut self.ws, |i, ws| {
                for k in 0..d {
                    ws.half[k] = xs_r[i][k] - lr * grads[i][k];
                }
                common::rounding_noise(&cfg, seed, round, i, d, &mut ws.noise);
                for k in 0..d {
                    ws.diff[k] = ws.half[k] - xhat[i][k];
                }
                quant.quantize_into(&ws.diff, &ws.noise, &mut ws.codes, &mut ws.qdiff);
            });
        }
        let bytes = common::wire_bytes(&cfg, &self.ws[0].codes);
        // estimate updates (applied by all holders)
        {
            let ws = &self.ws;
            self.pool.for_each_mut(&mut self.xhat, |i, xh| {
                for k in 0..d {
                    xh[k] += ws[i].qdiff[k];
                }
            });
        }
        // consensus step with γ
        {
            let gamma = self.gamma as f32;
            let w = &self.w;
            let ws = &self.ws;
            let xhat = &self.xhat;
            self.pool.for_each_mut(xs, |i, x| {
                x.copy_from_slice(&ws[i].half);
                for (j, wji) in w.in_edges(i) {
                    let wji = wji as f32;
                    for k in 0..d {
                        x[k] += gamma * wji * (xhat[j][k] - xhat[i][k]);
                    }
                }
            });
        }
        let deg_sum = self.w.deg_sum();
        CommStats {
            bytes_per_msg: bytes,
            messages: deg_sum as u64,
            allreduce_bytes: None,
            extra_local_passes: 1, // estimate maintenance
        }
    }

    fn node_send(
        &mut self,
        i: usize,
        x: &[f32],
        grad: &[f32],
        lr: f32,
        round: u64,
        ctx: &StepCtx,
        payload: &mut Vec<u8>,
    ) {
        let cfg = self.cfg;
        let quant = self.quant;
        let d = self.d;
        let Choco { xhat, ws, .. } = self;
        let ws = &mut ws[i];
        for k in 0..d {
            ws.half[k] = x[k] - lr * grad[k];
        }
        common::rounding_noise(&cfg, ctx.seed, round, i, d, &mut ws.noise);
        for k in 0..d {
            ws.diff[k] = ws.half[k] - xhat[i][k];
        }
        quant.quantize_into(&ws.diff, &ws.noise, &mut ws.codes, &mut ws.qdiff);
        payload.resize(packing::packed_len(d, cfg.bits), 0);
        packing::pack_into(&ws.codes, cfg.bits, payload);
    }

    /// The quantized difference is taken from the half-step
    /// `x − α g` — the gradient is baked into the payload.
    fn send_phase(&self) -> SendPhase {
        SendPhase::PostGradient
    }

    fn node_recv(
        &mut self,
        i: usize,
        x: &mut [f32],
        _grad: &[f32],
        _lr: f32,
        _round: u64,
        _ctx: &StepCtx,
        inbox: &Inbox,
    ) -> CommStats {
        let cfg = self.cfg;
        let quant = self.quant;
        let d = self.d;
        let gamma = self.gamma as f32;
        let Choco { w, ws, xhat, node_codes, node_vals, .. } = self;
        for k in 0..d {
            xhat[i][k] += ws[i].qdiff[k];
        }
        for &j in &w.neighbors[i] {
            common::decode_baseline_payload(
                &quant,
                false,
                cfg.bits,
                inbox.payload(j),
                node_codes,
                node_vals,
            );
            for k in 0..d {
                xhat[j][k] += node_vals[k];
            }
        }
        x.copy_from_slice(&ws[i].half);
        for (j, wji) in w.in_edges(i) {
            let wji = wji as f32;
            for k in 0..d {
                x[k] += gamma * wji * (xhat[j][k] - xhat[i][k]);
            }
        }
        let deg_sum = w.deg_sum();
        CommStats {
            bytes_per_msg: common::wire_bytes(&cfg, &ws[i].codes),
            messages: deg_sum as u64,
            allreduce_bytes: None,
            extra_local_passes: 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    fn ctx(rho: f64) -> StepCtx {
        StepCtx { seed: 21, rho, g_inf: 1.0 }
    }

    fn quad_run(alg: &mut dyn SyncAlgorithm, steps: u64, lr: f32, rho: f64) -> f64 {
        let n = 4;
        let d = 8;
        let c = 0.3f32;
        // asymmetric starts: consensus dynamics actually exercised
        let mut xs: Vec<Vec<f32>> = (0..n).map(|i| vec![1.0 + 0.2 * i as f32; d]).collect();
        for k in 0..steps {
            let grads: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| x.iter().map(|&v| v - c).collect())
                .collect();
            alg.step(&mut xs, &grads, lr, k, &ctx(rho));
        }
        xs.iter()
            .map(|x| x.iter().map(|&v| ((v - c) as f64).powi(2)).sum::<f64>())
            .sum::<f64>()
            / n as f64
    }

    #[test]
    fn converges_at_8_bits() {
        let w = Topology::Ring(4).comm_matrix();
        let rho = w.rho();
        let mut alg = Choco::new(w, 8, QuantConfig::stochastic(8), 4.0, 0.8);
        let loss = quad_run(&mut alg, 500, 0.1, rho);
        assert!(loss < 1e-2, "loss {loss}");
    }

    #[test]
    fn one_bit_converges_with_small_gamma() {
        // The ChocoSGD claim: arbitrary compressors via γ — and the Table 2
        // observation that it survives 1-bit budgets.
        let w = Topology::Ring(4).comm_matrix();
        let rho = w.rho();
        let mut alg = Choco::new(w, 8, QuantConfig::nearest(1), 4.0, 0.05);
        let loss = quad_run(&mut alg, 2000, 0.05, rho);
        assert!(loss < 0.05, "1-bit Choco loss {loss}");
    }

    #[test]
    fn one_bit_diverges_with_large_gamma() {
        // γ matters: aggressive consensus with a 1-bit compressor blows up
        // (this is why γ must be tuned, unlike Moniqua's parameter-free use
        // of the same budget via the slack matrix).
        let w = Topology::Ring(4).comm_matrix();
        let rho = w.rho();
        let mut alg = Choco::new(w, 8, QuantConfig::nearest(1), 4.0, 1.0);
        let loss = quad_run(&mut alg, 500, 0.05, rho);
        assert!(loss > 0.05, "expected instability, got {loss}");
    }
}
