//! Decentralized training algorithms: Moniqua (the paper's contribution)
//! and every baseline its evaluation compares against.
//!
//! | variant | paper | quantized? | extra memory |
//! |---|---|---|---|
//! | [`Algorithm::AllReduce`]   | centralized SGD        | no  | 0 |
//! | [`Algorithm::DPsgd`]       | Lian et al. 2017       | no  | 0 |
//! | [`Algorithm::NaiveQuant`]  | §3 counterexample      | yes | 0 (diverges) |
//! | [`Algorithm::Moniqua`]     | **Algorithm 1**        | yes | 0 |
//! | [`Algorithm::D2`]          | Tang et al. 2018 (D²)  | no  | 0 |
//! | [`Algorithm::MoniquaD2`]   | **Algorithm 2**        | yes | 0 |
//! | [`Algorithm::Dcd`]         | Tang et al. 2018       | yes | Θ(md) |
//! | [`Algorithm::Ecd`]         | Tang et al. 2018       | yes | Θ(md) |
//! | [`Algorithm::Choco`]       | Koloskova et al. 2019  | yes | Θ(md) |
//! | [`Algorithm::DeepSqueeze`] | Tang et al. 2019       | yes | Θ(nd) |
//!
//! AD-PSGD / Moniqua-AD-PSGD (**Algorithm 3**) are event-driven and live in
//! [`adpsgd`], driven by [`crate::coordinator::AsyncTrainer`].
//!
//! All synchronous variants implement [`SyncAlgorithm`]: the trainer
//! computes the per-worker stochastic gradients, then hands the full state
//! to `step`, which performs communication + update and reports the wire
//! traffic it generated (the network simulator prices it afterwards).

pub mod adpsgd;
pub mod allreduce;
pub mod choco;
pub mod common;
pub mod d2;
pub mod dcd;
pub mod deepsqueeze;
pub mod dpsgd;
pub mod ecd;
pub mod engine;
pub mod moniqua;
pub mod naive;

pub use adpsgd::{AdPsgd, AsyncVariant};
pub use common::{CommScope, CommStats, Inbox, MixPolicy, RangeQuantizer, StepCtx};
pub use engine::RoundPool;

use crate::quant::QuantConfig;
use crate::topology::CommMatrix;

/// When, relative to the round's local gradient computation, an engine's
/// [`SyncAlgorithm::node_send`] half may run.
///
/// The pipelined cluster scheduler
/// ([`coordinator::cluster`](crate::coordinator::cluster)) broadcasts a
/// `PreGradient` engine's frame at round entry, so the wire drains *while*
/// the gradient is computed and a comm-bound round costs
/// `max(compute, comm) + mix` instead of `compute + comm`. This is bitwise
/// safe exactly when the send half's payload bytes are a pure function of
/// `(x, lr, round, seed)`: the model is unchanged until the recv half, and
/// the only `StepCtx` field that differs before vs. after the gradient is
/// `g_inf`, which feeds nothing but the Theorem-2 θ policy the cluster
/// runtime refuses at construction. The DES runtime uses the same flag to
/// model overlapped round timing (`coordinator::des`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SendPhase {
    /// `node_send` never reads the gradient: the frame can be encoded and
    /// broadcast before `loss_grad` runs. The scheduler passes an **empty
    /// gradient slice** in this mode — any accidental read is a loud index
    /// panic, not a silent value divergence.
    PreGradient,
    /// `node_send` consumes the round's gradient (payload = f(x, g)): the
    /// frame can only leave after the gradient finishes. Safe default.
    PostGradient,
}

/// θ policy for Moniqua variants (paper §6 "Choosing θ empirically").
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ThetaPolicy {
    /// Fixed hyperparameter (the paper's experiments use θ = 2.0).
    Constant(f32),
    /// Theorem-2 formula with a G∞ estimate tracked over `warmup` steps and
    /// a multiplicative safety factor.
    Theorem2 { warmup: u64, safety: f64 },
}

impl ThetaPolicy {
    /// θ for the current round. `g_inf` is the tracked gradient ∞-norm.
    pub fn theta(&self, alpha: f64, g_inf: f64, n: usize, rho: f64) -> f64 {
        match *self {
            ThetaPolicy::Constant(t) => t as f64,
            ThetaPolicy::Theorem2 { safety, .. } => {
                crate::quant::theta::theta_theorem2(alpha, g_inf.max(1e-8) * safety, n, rho)
            }
        }
    }

    pub fn warmup(&self) -> u64 {
        match *self {
            ThetaPolicy::Constant(_) => 0,
            ThetaPolicy::Theorem2 { warmup, .. } => warmup,
        }
    }
}

/// Top-level algorithm selector (config / CLI level).
#[derive(Clone, Debug, PartialEq)]
pub enum Algorithm {
    AllReduce,
    DPsgd,
    NaiveQuant { quant: QuantConfig, range: f32 },
    Moniqua { theta: ThetaPolicy, quant: QuantConfig },
    /// Moniqua with the Theorem-3 slack matrix `W̄ = γW + (1−γ)I` (1-bit mode).
    MoniquaSlack { theta: ThetaPolicy, quant: QuantConfig, gamma: f64 },
    D2,
    MoniquaD2 { theta: ThetaPolicy, quant: QuantConfig },
    Dcd { quant: QuantConfig, range: f32 },
    Ecd { quant: QuantConfig, range: f32 },
    Choco { quant: QuantConfig, range: f32, gamma: f64 },
    DeepSqueeze { quant: QuantConfig, range: f32, gamma: f64 },
}

impl Algorithm {
    /// Short name used in reports/CSV.
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::AllReduce => "allreduce",
            Algorithm::DPsgd => "dpsgd",
            Algorithm::NaiveQuant { .. } => "naive",
            Algorithm::Moniqua { .. } => "moniqua",
            Algorithm::MoniquaSlack { .. } => "moniqua-slack",
            Algorithm::D2 => "d2",
            Algorithm::MoniquaD2 { .. } => "moniqua-d2",
            Algorithm::Dcd { .. } => "dcd",
            Algorithm::Ecd { .. } => "ecd",
            Algorithm::Choco { .. } => "choco",
            Algorithm::DeepSqueeze { .. } => "deepsqueeze",
        }
    }

    /// Extra memory (floats, whole cluster) versus D-PSGD — Table 1/2's
    /// "extra memory" column.
    pub fn extra_memory_floats(&self, n: usize, m: usize, d: usize) -> usize {
        let key = match self {
            Algorithm::Dcd { .. } => "dcd",
            Algorithm::Ecd { .. } => "ecd",
            Algorithm::Choco { .. } => "choco",
            Algorithm::DeepSqueeze { .. } => "deepsqueeze",
            _ => "moniqua",
        };
        crate::quant::extra_memory_floats(key, n, m, d)
    }

    /// Instantiate the synchronous engine. Panics for AD-PSGD variants
    /// (use [`crate::coordinator::AsyncTrainer`]).
    pub fn make_sync(&self, w: &CommMatrix, d: usize) -> Box<dyn SyncAlgorithm> {
        match self.clone() {
            Algorithm::AllReduce => Box::new(allreduce::AllReduce::new(d)),
            Algorithm::DPsgd => Box::new(dpsgd::DPsgd::new(w.clone(), d)),
            Algorithm::NaiveQuant { quant, range } => {
                Box::new(naive::NaiveQuant::new(w.clone(), d, quant, range))
            }
            Algorithm::Moniqua { theta, quant } => {
                Box::new(moniqua::MoniquaSync::new(w.clone(), d, theta, quant))
            }
            Algorithm::MoniquaSlack { theta, quant, gamma } => Box::new(
                moniqua::MoniquaSync::named(w.slack(gamma), d, theta, quant, "moniqua-slack"),
            ),
            Algorithm::D2 => Box::new(d2::D2::new(w.clone(), d, None)),
            Algorithm::MoniquaD2 { theta, quant } => {
                Box::new(d2::D2::new(w.clone(), d, Some((theta, quant))))
            }
            Algorithm::Dcd { quant, range } => {
                Box::new(dcd::Dcd::new(w.clone(), d, quant, range))
            }
            Algorithm::Ecd { quant, range } => {
                Box::new(ecd::Ecd::new(w.clone(), d, quant, range))
            }
            Algorithm::Choco { quant, range, gamma } => {
                Box::new(choco::Choco::new(w.clone(), d, quant, range, gamma))
            }
            Algorithm::DeepSqueeze { quant, range, gamma } => Box::new(
                deepsqueeze::DeepSqueeze::new(w.clone(), d, quant, range, gamma),
            ),
        }
    }
}

/// One synchronous communication+update engine.
///
/// Engines expose the same round through two surfaces:
///
/// * [`Self::step`] — the lockstep form: the trainer hands over the whole
///   cluster state and the engine fans the phases across the
///   [`RoundPool`].
/// * [`Self::node_send`] / [`Self::node_recv`] — the **message-passing
///   decomposition** the cluster runtime
///   ([`coordinator::cluster`](crate::coordinator::cluster)) drives: the
///   send half computes everything worker `i` can from its *own* model and
///   gradient and serializes the payload `i` broadcasts; the recv half
///   integrates the peers' payloads (delivered as an [`Inbox`]) and
///   finishes the round.
///
/// ### Node-mode contract
///
/// A node-mode engine instance is constructed exactly like a lockstep one
/// (full cluster shape — worker-indexed state such as DCD/ECD replicas is
/// allocated for all `n`), but each instance is *pinned to one worker
/// index*: all `node_send`/`node_recv` calls on it use the same `i`, and
/// the only worker-`j` state it may touch is replica state that worker `i`
/// reconstructs purely from `j`'s wire payloads. Under that rule, `n`
/// pinned instances wired payload-for-payload produce **bitwise** the
/// models one lockstep instance produces (pinned by
/// `tests/cluster_equivalence.rs`), because every float op runs in the
/// same order on the same bits — the payload encodings are either
/// lossless (raw f32 words) or the exact wire codes the lockstep engines
/// already exchange.
///
/// The recv half must accumulate neighbors in ascending-sender order (what
/// [`Inbox::iter`] yields and the lockstep phases' "neighbor order" rule
/// requires) and must return the same [`CommStats`] the lockstep `step`
/// reports.
pub trait SyncAlgorithm: Send {
    fn name(&self) -> &'static str;

    /// Perform one synchronous round *after* gradients were computed:
    /// averaging/communication plus the `x ← x − α g` step, mutating `xs`
    /// in place. Returns the traffic generated this round.
    fn step(
        &mut self,
        xs: &mut [Vec<f32>],
        grads: &[Vec<f32>],
        lr: f32,
        round: u64,
        ctx: &StepCtx,
    ) -> CommStats;

    /// Node-mode send half: update worker `i`'s pre-communication state
    /// from its own model/gradient and append `i`'s round payload to
    /// `payload` (cleared by the caller). See the trait docs for the
    /// pinned-instance contract.
    fn node_send(
        &mut self,
        i: usize,
        x: &[f32],
        grad: &[f32],
        lr: f32,
        round: u64,
        ctx: &StepCtx,
        payload: &mut Vec<u8>,
    );

    /// Node-mode recv half: integrate the round's inbound payloads and
    /// finish worker `i`'s round, mutating `x` in place. Returns the same
    /// cluster-wide traffic stats the lockstep [`Self::step`] reports.
    fn node_recv(
        &mut self,
        i: usize,
        x: &mut [f32],
        grad: &[f32],
        lr: f32,
        round: u64,
        ctx: &StepCtx,
        inbox: &Inbox,
    ) -> CommStats;

    /// Which peers the node-mode round exchanges payloads with.
    fn comm_scope(&self) -> CommScope {
        CommScope::Neighbors
    }

    /// Whether this engine's send half depends on the round's gradient
    /// (see [`SendPhase`]). Engines whose payload is a pure function of
    /// `(x, lr, round, seed)` override this to [`SendPhase::PreGradient`]
    /// to opt into the pipelined scheduler's early broadcast; the default
    /// is the conservative [`SendPhase::PostGradient`].
    fn send_phase(&self) -> SendPhase {
        SendPhase::PostGradient
    }

    /// The θ bound the algorithm used this round (Moniqua variants), for
    /// diagnostics/verification traces.
    fn last_theta(&self) -> Option<f64> {
        None
    }

    /// Resize this engine's [`RoundPool`] (1 = sequential reference run).
    /// The determinism contract (`rust/DESIGN.md` §Engine) guarantees
    /// bitwise-identical results for every width; the equivalence tests
    /// pin it. Default: no-op for engines with no parallel phases.
    fn set_threads(&mut self, threads: usize) {
        let _ = threads;
    }

    /// Replace the communication matrix mid-run — a
    /// [`TopologySchedule`](crate::topology::TopologySchedule) stage
    /// boundary in the DES runtime (`coordinator::des`), or an elastic
    /// reconfiguration barrier in the cluster runtime
    /// ([`crate::elastic`]). The new matrix must cover the same worker
    /// count. Returns `false` when this engine cannot re-target (per-edge
    /// state, or a derived matrix like the Theorem-3 slack form whose
    /// transform the engine cannot re-apply); the runtimes surface a
    /// scheduled swap on such an engine as a configuration error instead of
    /// silently training on a stale graph.
    fn swap_matrix(&mut self, w: &CommMatrix) -> bool {
        let _ = w;
        false
    }

    /// Enable (or disable) the round-bound wire seal for this engine's
    /// node-mode payloads. The seal itself is appended/stripped by the
    /// round machine — an engine only needs to *account* for the 8-byte
    /// tail in its reported [`CommStats::bytes_per_msg`], in both the
    /// lockstep `step` and the node halves, so measured wire bytes keep
    /// matching the ledger's prediction. Returns `false` when the engine
    /// cannot account for a seal (the quantized engines, whose wire format
    /// either carries the §6 digest already or refuses verification);
    /// turning it *off* always succeeds.
    fn set_verify_wire(&mut self, on: bool) -> bool {
        !on
    }

    /// Select the neighbor-mix policy (the `mix=` config key). Returns
    /// `false` when this engine does not implement the requested policy —
    /// the runtimes surface that as a configuration error. Every engine
    /// accepts [`MixPolicy::Mean`] (it is the existing accumulate path).
    fn set_mix(&mut self, mix: MixPolicy) -> bool {
        mix == MixPolicy::Mean
    }

    /// Drain the senders whose payloads failed this engine's *semantic*
    /// verification during the last `node_recv` (the Moniqua family's §6
    /// digest check) into `out`, one entry per failed sender, clearing the
    /// engine's internal record. The round machine turns these into
    /// strikes. Default: engines with no engine-side verification never
    /// report any.
    fn drain_strikes(&mut self, out: &mut Vec<u16>) {
        let _ = out;
    }

    /// Serialize every bit of *persistent* state this engine carries across
    /// rounds (compressor replicas, error-feedback accumulators,
    /// variance-reduction history, diagnostic counters) into `out` — the
    /// engine section of an elastic [`Snapshot`](crate::elastic::Snapshot).
    /// Round-scratch buffers are excluded by definition: a round boundary
    /// is the only snapshot point. Default: no persistent state (the
    /// zero-extra-memory engines — exactly Table 1's memory column).
    ///
    /// Contract (pinned by `tests/snapshot_roundtrip.rs`): for a fresh
    /// engine `b` of the same construction,
    /// `b.restore(&a.snapshot())` makes every subsequent round of `b`
    /// bitwise-identical to `a`'s, and `b.snapshot() == a.snapshot()`.
    fn snapshot(&self, out: &mut Vec<u8>) {
        let _ = out;
    }

    /// Restore state written by [`Self::snapshot`] on an engine of the same
    /// construction (same algorithm, cluster shape, and dimension). Total:
    /// malformed blobs return a typed error and must not leave the engine
    /// partially mutated in ways a caller could observe after discarding it.
    fn restore(&mut self, bytes: &[u8]) -> Result<(), crate::elastic::SnapshotError> {
        if bytes.is_empty() {
            Ok(())
        } else {
            Err(crate::elastic::SnapshotError::Malformed(
                "engine has no persistent state but the snapshot carries some",
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    #[test]
    fn names_are_stable() {
        assert_eq!(Algorithm::DPsgd.name(), "dpsgd");
        assert_eq!(
            Algorithm::Moniqua {
                theta: ThetaPolicy::Constant(2.0),
                quant: QuantConfig::stochastic(8)
            }
            .name(),
            "moniqua"
        );
    }

    #[test]
    fn theta_policy_constant_and_formula() {
        let c = ThetaPolicy::Constant(2.0);
        assert_eq!(c.theta(0.1, 5.0, 8, 0.8), 2.0);
        let f = ThetaPolicy::Theorem2 { warmup: 10, safety: 2.0 };
        let got = f.theta(0.1, 5.0, 8, 0.8);
        let want = crate::quant::theta::theta_theorem2(0.1, 10.0, 8, 0.8);
        assert!((got - want).abs() < 1e-12);
        assert_eq!(f.warmup(), 10);
    }

    #[test]
    fn extra_memory_ranking_matches_table1() {
        let (n, d) = (8, 1000);
        let m = Topology::Ring(n).edge_count();
        let mk = |a: Algorithm| a.extra_memory_floats(n, m, d);
        let q = QuantConfig::stochastic(8);
        assert_eq!(
            mk(Algorithm::Moniqua {
                theta: ThetaPolicy::Constant(1.0),
                quant: q
            }),
            0
        );
        let dcd = mk(Algorithm::Dcd { quant: q, range: 1.0 });
        let ds = mk(Algorithm::DeepSqueeze { quant: q, range: 1.0, gamma: 0.5 });
        let choco = mk(Algorithm::Choco { quant: q, range: 1.0, gamma: 0.5 });
        assert!(dcd > 0 && ds > 0);
        assert!(ds < choco, "DeepSqueeze {ds} < ChocoSGD {choco} (Table 2)");
    }

    #[test]
    fn all_sync_variants_instantiate() {
        let w = Topology::Ring(4).comm_matrix();
        let q = QuantConfig::stochastic(4);
        let t = ThetaPolicy::Constant(2.0);
        let algos = vec![
            Algorithm::AllReduce,
            Algorithm::DPsgd,
            Algorithm::NaiveQuant { quant: q, range: 4.0 },
            Algorithm::Moniqua { theta: t, quant: q },
            Algorithm::MoniquaSlack { theta: t, quant: q, gamma: 0.1 },
            Algorithm::D2,
            Algorithm::MoniquaD2 { theta: t, quant: q },
            Algorithm::Dcd { quant: q, range: 4.0 },
            Algorithm::Ecd { quant: q, range: 4.0 },
            Algorithm::Choco { quant: q, range: 4.0, gamma: 0.3 },
            Algorithm::DeepSqueeze { quant: q, range: 4.0, gamma: 0.3 },
        ];
        for a in algos {
            let engine = a.make_sync(&w, 10);
            assert_eq!(engine.name(), a.name());
        }
    }
}
