//! DeepSqueeze (Tang et al. 2019): error-compensated compression for
//! decentralized SGD with a consensus factor γ. Each worker keeps one error
//! accumulator (Θ(nd) memory across the cluster — cheaper than the Θ(md)
//! replica schemes, Table 1):
//!
//! ```text
//!     v_i = x_{k,i} − α g̃_i
//!     u_i = v_i + e_i            (compensate)
//!     c_i = Q(u_i);   e_i ← u_i − c_i
//!     x_{k+1,i} = v_i + γ Σ_j W_ji (c_j − c_i)
//! ```
//!
//! Error feedback makes even biased compressors usable, but at 1 bit the
//! compensation noise is large — Table 2 shows it converging with slightly
//! lower accuracy than Moniqua/Choco.

use super::engine::RoundPool;
use super::{common, CommStats, Inbox, RangeQuantizer, SendPhase, StepCtx, SyncAlgorithm};
use crate::quant::{packing, QuantConfig};
use crate::topology::CommMatrix;

/// Per-worker state + scratch: `err` is the algorithm's persistent error
/// accumulator (the Θ(nd) memory of Table 1); the rest is round scratch.
struct Ws {
    err: Vec<f32>,
    v: Vec<f32>,
    c: Vec<f32>,
    u: Vec<f32>,
    codes: Vec<u32>,
    noise: Vec<f32>,
}

pub struct DeepSqueeze {
    w: CommMatrix,
    d: usize,
    cfg: QuantConfig,
    quant: RangeQuantizer,
    pub gamma: f64,
    pool: RoundPool,
    ws: Vec<Ws>,
    /// Node-mode decode buffers for one neighbor's compressed vector.
    node_codes: Vec<u32>,
    node_vals: Vec<f32>,
}

impl DeepSqueeze {
    pub fn new(w: CommMatrix, d: usize, cfg: QuantConfig, range: f32, gamma: f64) -> Self {
        let n = w.n();
        DeepSqueeze {
            w,
            d,
            cfg,
            quant: RangeQuantizer::new(&cfg, range),
            gamma,
            pool: RoundPool::for_dim(d),
            ws: (0..n)
                .map(|_| Ws {
                    err: vec![0.0; d],
                    v: vec![0.0; d],
                    c: vec![0.0; d],
                    u: vec![0.0; d],
                    codes: vec![0; d],
                    noise: Vec::new(),
                })
                .collect(),
            node_codes: vec![0; d],
            node_vals: vec![0.0; d],
        }
    }

    /// Worker `i`'s error accumulator (diagnostics/tests).
    pub fn error_accumulator(&self, i: usize) -> &[f32] {
        &self.ws[i].err
    }
}

impl SyncAlgorithm for DeepSqueeze {
    fn name(&self) -> &'static str {
        "deepsqueeze"
    }

    fn set_threads(&mut self, threads: usize) {
        self.pool = RoundPool::new(threads);
    }

    // Persistent state: the error-feedback accumulators (Table 1's Θ(nd)
    // memory); everything else in Ws is round scratch.
    fn snapshot(&self, out: &mut Vec<u8>) {
        use crate::elastic::snapshot as ss;
        ss::put_u32(out, self.ws.len() as u32);
        for ws in &self.ws {
            ss::put_f32_slice(out, &ws.err);
        }
    }

    fn restore(&mut self, bytes: &[u8]) -> Result<(), crate::elastic::SnapshotError> {
        use crate::elastic::{snapshot as ss, SnapshotError};
        let mut r = ss::Reader::new(bytes);
        if r.take_u32()? as usize != self.ws.len() {
            return Err(SnapshotError::Malformed("deepsqueeze accumulator count"));
        }
        for ws in self.ws.iter_mut() {
            r.take_f32_into(&mut ws.err)?;
        }
        r.finish()
    }

    fn step(
        &mut self,
        xs: &mut [Vec<f32>],
        grads: &[Vec<f32>],
        lr: f32,
        round: u64,
        ctx: &StepCtx,
    ) -> CommStats {
        let cfg = self.cfg;
        let d = self.d;
        let quant = self.quant;
        let seed = ctx.seed;
        {
            let xs_r: &[Vec<f32>] = xs;
            self.pool.for_each_mut(&mut self.ws, |i, ws| {
                for k in 0..d {
                    ws.v[k] = xs_r[i][k] - lr * grads[i][k];
                    ws.u[k] = ws.v[k] + ws.err[k];
                }
                common::rounding_noise(&cfg, seed, round, i, d, &mut ws.noise);
                quant.quantize_into(&ws.u, &ws.noise, &mut ws.codes, &mut ws.c);
                for k in 0..d {
                    ws.err[k] = ws.u[k] - ws.c[k];
                }
            });
        }
        let bytes = common::wire_bytes(&cfg, &self.ws[0].codes);
        {
            let gamma = self.gamma as f32;
            let w = &self.w;
            let ws = &self.ws;
            self.pool.for_each_mut(xs, |i, x| {
                x.copy_from_slice(&ws[i].v);
                for (j, wji) in w.in_edges(i) {
                    let wji = wji as f32;
                    for k in 0..d {
                        x[k] += gamma * wji * (ws[j].c[k] - ws[i].c[k]);
                    }
                }
            });
        }
        let deg_sum = self.w.deg_sum();
        CommStats {
            bytes_per_msg: bytes,
            messages: deg_sum as u64,
            allreduce_bytes: None,
            extra_local_passes: 1, // error-tracking pass
        }
    }

    fn node_send(
        &mut self,
        i: usize,
        x: &[f32],
        grad: &[f32],
        lr: f32,
        round: u64,
        ctx: &StepCtx,
        payload: &mut Vec<u8>,
    ) {
        let cfg = self.cfg;
        let quant = self.quant;
        let d = self.d;
        let ws = &mut self.ws[i];
        for k in 0..d {
            ws.v[k] = x[k] - lr * grad[k];
            ws.u[k] = ws.v[k] + ws.err[k];
        }
        common::rounding_noise(&cfg, ctx.seed, round, i, d, &mut ws.noise);
        quant.quantize_into(&ws.u, &ws.noise, &mut ws.codes, &mut ws.c);
        for k in 0..d {
            ws.err[k] = ws.u[k] - ws.c[k];
        }
        payload.resize(packing::packed_len(d, cfg.bits), 0);
        packing::pack_into(&ws.codes, cfg.bits, payload);
    }

    /// Error feedback compresses `v = x − α g` plus the carried error:
    /// both the payload and the updated `err` state need the gradient.
    fn send_phase(&self) -> SendPhase {
        SendPhase::PostGradient
    }

    fn node_recv(
        &mut self,
        i: usize,
        x: &mut [f32],
        _grad: &[f32],
        _lr: f32,
        _round: u64,
        _ctx: &StepCtx,
        inbox: &Inbox,
    ) -> CommStats {
        let cfg = self.cfg;
        let quant = self.quant;
        let d = self.d;
        let gamma = self.gamma as f32;
        let DeepSqueeze { w, ws, node_codes, node_vals, .. } = self;
        x.copy_from_slice(&ws[i].v);
        for (j, wji) in w.in_edges(i) {
            common::decode_baseline_payload(
                &quant,
                false,
                cfg.bits,
                inbox.payload(j),
                node_codes,
                node_vals,
            );
            let wji = wji as f32;
            for k in 0..d {
                x[k] += gamma * wji * (node_vals[k] - ws[i].c[k]);
            }
        }
        let deg_sum = w.deg_sum();
        CommStats {
            bytes_per_msg: common::wire_bytes(&cfg, &ws[i].codes),
            messages: deg_sum as u64,
            allreduce_bytes: None,
            extra_local_passes: 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    fn ctx(rho: f64) -> StepCtx {
        StepCtx { seed: 31, rho, g_inf: 1.0 }
    }

    fn quad_run(alg: &mut dyn SyncAlgorithm, steps: u64, lr: f32, rho: f64) -> f64 {
        let n = 4;
        let d = 8;
        let c = 0.3f32;
        let mut xs: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0; d]).collect();
        for k in 0..steps {
            let grads: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| x.iter().map(|&v| v - c).collect())
                .collect();
            alg.step(&mut xs, &grads, lr, k, &ctx(rho));
        }
        xs.iter()
            .map(|x| x.iter().map(|&v| ((v - c) as f64).powi(2)).sum::<f64>())
            .sum::<f64>()
            / n as f64
    }

    #[test]
    fn converges_at_8_bits() {
        let w = Topology::Ring(4).comm_matrix();
        let rho = w.rho();
        let mut alg = DeepSqueeze::new(w, 8, QuantConfig::stochastic(8), 4.0, 0.5);
        let loss = quad_run(&mut alg, 500, 0.1, rho);
        assert!(loss < 1e-2, "loss {loss}");
    }

    #[test]
    fn error_feedback_keeps_low_bits_alive() {
        let w = Topology::Ring(4).comm_matrix();
        let rho = w.rho();
        let mut alg = DeepSqueeze::new(w, 8, QuantConfig::stochastic(2), 4.0, 0.1);
        let loss = quad_run(&mut alg, 2000, 0.05, rho);
        assert!(loss < 0.1, "2-bit DeepSqueeze loss {loss}");
    }

    #[test]
    fn error_accumulator_stays_bounded() {
        let w = Topology::Ring(4).comm_matrix();
        let rho = w.rho();
        let mut alg = DeepSqueeze::new(w.clone(), 8, QuantConfig::stochastic(4), 4.0, 0.3);
        let mut xs: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0; 8]).collect();
        for k in 0..300 {
            let grads: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| x.iter().map(|&v| v - 0.3).collect())
                .collect();
            alg.step(&mut xs, &grads, 0.1, k, &ctx(rho));
        }
        let worst = (0..4)
            .map(|i| crate::linalg::norm_inf(alg.error_accumulator(i)))
            .fold(0.0f32, f32::max);
        // error feedback bounded by quantizer resolution scale
        assert!(worst <= 2.0 * alg.quant.max_error() + 1e-4, "err {worst}");
    }
}
