//! D² (Tang et al. 2018) and **Moniqua-D² (Algorithm 2)** — decentralized
//! SGD with variance reduction for *decentralized data* (each worker's
//! D_i a different distribution; Figure 2a's setting).
//!
//! ```text
//!     X_{k+½} = 2 X_k − X_{k−1} − α G̃_k + α G̃_{k−1}
//!     full precision:  X_{k+1} = X_{k+½} W
//!     Moniqua:         X_{k+1} = X_{k+½} + Σ_j (x̂_j − x̂_i) W_ji   (on x_{k+½})
//! ```
//!
//! `X_{−1} = G̃_{−1} = 0` by convention; the k = 0 step degenerates to plain
//! SGD. D² requires λ_n(W) > −1/3 (checked at construction).

use super::engine::RoundPool;
use super::{common, CommStats, Inbox, SendPhase, StepCtx, SyncAlgorithm, ThetaPolicy};
use crate::quant::{hash, packing, MoniquaCodec, QuantConfig};
use crate::topology::CommMatrix;

/// Per-worker state + scratch. `x_prev`/`g_prev` are the variance-reduction
/// history; `half` is read by neighbors in the averaging phase; `wire` /
/// `xhat_self` / `noise` serve the Moniqua-quantized mode's fused wire path.
struct Ws {
    x_prev: Vec<f32>,
    g_prev: Vec<f32>,
    half: Vec<f32>,
    wire: Vec<u8>,
    xhat_self: Vec<f32>,
    noise: Vec<f32>,
}

pub struct D2 {
    w: CommMatrix,
    d: usize,
    /// Some(..) => Moniqua-quantized averaging (Algorithm 2).
    moniqua: Option<(ThetaPolicy, QuantConfig)>,
    pool: RoundPool,
    started: bool,
    ws: Vec<Ws>,
    /// Receiver-side recovery buffers (Moniqua mode).
    recover: Vec<Vec<f32>>,
    /// Round-shared noise (shared-randomness mode): one fill per round.
    shared_noise: Vec<f32>,
    /// Node-mode decode buffer for full-precision neighbor payloads.
    decode: Vec<f32>,
    last_theta: f64,
    /// Full-precision mode only: price the round machine's 8-byte seal.
    verify_wire: bool,
    /// Moniqua mode only: senders whose §6 digest failed this round,
    /// drained by the round machine into its strike accounting.
    strike_buf: Vec<u16>,
}

impl D2 {
    pub fn new(w: CommMatrix, d: usize, moniqua: Option<(ThetaPolicy, QuantConfig)>) -> Self {
        let n = w.n();
        let wire_len = moniqua
            .as_ref()
            .map_or(0, |(_, cfg)| packing::packed_len(d, cfg.bits));
        D2 {
            w,
            d,
            moniqua,
            pool: RoundPool::for_dim(d),
            started: false,
            ws: (0..n)
                .map(|_| Ws {
                    x_prev: vec![0.0; d],
                    g_prev: vec![0.0; d],
                    half: vec![0.0; d],
                    wire: vec![0u8; wire_len],
                    xhat_self: vec![0.0; d],
                    noise: Vec::new(),
                })
                .collect(),
            recover: vec![vec![0.0; d]; n],
            shared_noise: Vec::new(),
            decode: vec![0.0; d],
            last_theta: 0.0,
            verify_wire: false,
            strike_buf: Vec::with_capacity(n),
        }
    }

    fn wire_overhead(&self) -> usize {
        if self.verify_wire { crate::adversary::SEAL_LEN } else { 0 }
    }

    /// Node-mode half step (variance reduction + history update) for one
    /// worker — the same math step's first phase runs for every worker.
    fn node_half_step(&mut self, i: usize, x: &[f32], grad: &[f32], lr: f32) {
        let d = self.d;
        let started = self.started;
        let ws = &mut self.ws[i];
        if started {
            for k in 0..d {
                ws.half[k] = 2.0 * x[k] - ws.x_prev[k] - lr * (grad[k] - ws.g_prev[k]);
            }
        } else {
            for k in 0..d {
                ws.half[k] = x[k] - lr * grad[k];
            }
        }
        ws.x_prev.copy_from_slice(x);
        ws.g_prev.copy_from_slice(grad);
        // Pinned-instance semantics: this worker has now taken its k = 0
        // plain-SGD step, matching the lockstep flag flip per round.
        self.started = true;
    }
}

impl SyncAlgorithm for D2 {
    fn name(&self) -> &'static str {
        if self.moniqua.is_some() {
            "moniqua-d2"
        } else {
            "d2"
        }
    }

    fn last_theta(&self) -> Option<f64> {
        self.moniqua.as_ref().map(|_| self.last_theta)
    }

    fn set_threads(&mut self, threads: usize) {
        self.pool = RoundPool::new(threads);
    }

    /// Algorithm 2 ships its own §6 digest; only the full-precision mode
    /// rides the machine seal (and must price it).
    fn set_verify_wire(&mut self, on: bool) -> bool {
        if self.moniqua.is_some() {
            return !on;
        }
        self.verify_wire = on;
        true
    }

    fn drain_strikes(&mut self, out: &mut Vec<u16>) {
        out.append(&mut self.strike_buf);
    }

    fn swap_matrix(&mut self, w: &CommMatrix) -> bool {
        // D²'s history (x_prev/g_prev) is per-worker, not per-edge, so the
        // averaging matrix may change between rounds.
        assert_eq!(w.n(), self.w.n(), "matrix swap changed worker count");
        self.w = w.clone();
        true
    }

    // Persistent state: the variance-reduction history (x_prev/g_prev per
    // worker) plus the started flag and θ diagnostic.
    fn snapshot(&self, out: &mut Vec<u8>) {
        use crate::elastic::snapshot as ss;
        ss::put_u8(out, self.started as u8);
        ss::put_f64(out, self.last_theta);
        ss::put_u32(out, self.ws.len() as u32);
        for ws in &self.ws {
            ss::put_f32_slice(out, &ws.x_prev);
            ss::put_f32_slice(out, &ws.g_prev);
        }
    }

    fn restore(&mut self, bytes: &[u8]) -> Result<(), crate::elastic::SnapshotError> {
        use crate::elastic::{snapshot as ss, SnapshotError};
        let mut r = ss::Reader::new(bytes);
        let started = match r.take_u8()? {
            0 => false,
            1 => true,
            _ => return Err(SnapshotError::Malformed("d2 started flag")),
        };
        let last_theta = r.take_f64()?;
        if r.take_u32()? as usize != self.ws.len() {
            return Err(SnapshotError::Malformed("d2 worker count"));
        }
        for ws in self.ws.iter_mut() {
            r.take_f32_into(&mut ws.x_prev)?;
            r.take_f32_into(&mut ws.g_prev)?;
        }
        r.finish()?;
        self.started = started;
        self.last_theta = last_theta;
        Ok(())
    }

    fn step(
        &mut self,
        xs: &mut [Vec<f32>],
        grads: &[Vec<f32>],
        lr: f32,
        round: u64,
        ctx: &StepCtx,
    ) -> CommStats {
        let n = xs.len();
        let d = self.d;
        // Half step with variance reduction (+ history update).
        {
            let started = self.started;
            let xs_r: &[Vec<f32>] = xs;
            self.pool.for_each_mut(&mut self.ws, |i, ws| {
                if started {
                    for k in 0..d {
                        ws.half[k] = 2.0 * xs_r[i][k] - ws.x_prev[k]
                            - lr * (grads[i][k] - ws.g_prev[k]);
                    }
                } else {
                    for k in 0..d {
                        ws.half[k] = xs_r[i][k] - lr * grads[i][k];
                    }
                }
                ws.x_prev.copy_from_slice(&xs_r[i]);
                ws.g_prev.copy_from_slice(&grads[i]);
            });
        }
        self.started = true;

        match self.moniqua.clone() {
            None => {
                // X_{k+1} = X_{k+1/2} W (exact averaging on the wire).
                let w = &self.w;
                let ws = &self.ws;
                self.pool.for_each_mut(xs, |i, x| {
                    x.fill(0.0);
                    crate::linalg::axpy(x, w.weight(i, i) as f32, &ws[i].half);
                    for (j, wji) in w.in_edges(i) {
                        crate::linalg::axpy(x, wji as f32, &ws[j].half);
                    }
                });
                let deg_sum = self.w.deg_sum();
                CommStats {
                    bytes_per_msg: self.d * 4 + self.wire_overhead(),
                    messages: deg_sum as u64,
                    allreduce_bytes: None,
                    extra_local_passes: 0,
                }
            }
            Some((theta_policy, cfg)) => {
                let theta = theta_policy.theta(lr as f64, ctx.g_inf, n, ctx.rho);
                self.last_theta = theta;
                let codec = MoniquaCodec::from_theta(theta as f32, &cfg);
                let seed = ctx.seed;
                // encode phase: fused wrap→quantize→pack + local biased
                // term; shared-randomness noise is drawn once per round.
                let use_shared = cfg.shared_randomness;
                if use_shared {
                    common::rounding_noise(&cfg, seed, round, 0, d, &mut self.shared_noise);
                }
                {
                    let shared_noise = &self.shared_noise;
                    self.pool.for_each_mut(&mut self.ws, |i, ws| {
                        let noise = common::phase_noise(
                            &cfg, seed, round, i, d, shared_noise, &mut ws.noise,
                        );
                        codec.encode_packed_into(&ws.half, noise, &mut ws.wire);
                        codec.local_biased_into(&ws.half, noise, &mut ws.xhat_self);
                    });
                }
                let bytes = common::wire_bytes_packed(&cfg, d, &self.ws[0].wire);
                // recover + apply phase
                {
                    let w = &self.w;
                    let ws = &self.ws;
                    self.pool.for_each_mut2(xs, &mut self.recover, |i, x, rec| {
                        x.copy_from_slice(&ws[i].half);
                        for (j, wji) in w.in_edges(i) {
                            let wji = wji as f32;
                            codec.recover_packed_into(&ws[j].wire, &ws[i].half, rec);
                            for k in 0..d {
                                x[k] += wji * (rec[k] - ws[i].xhat_self[k]);
                            }
                        }
                    });
                }
                let deg_sum = self.w.deg_sum();
                CommStats {
                    bytes_per_msg: bytes,
                    messages: deg_sum as u64,
                    allreduce_bytes: None,
                    extra_local_passes: 0,
                }
            }
        }
    }

    fn node_send(
        &mut self,
        i: usize,
        x: &[f32],
        grad: &[f32],
        lr: f32,
        round: u64,
        ctx: &StepCtx,
        payload: &mut Vec<u8>,
    ) {
        self.node_half_step(i, x, grad, lr);
        match self.moniqua.clone() {
            None => common::put_f32s(payload, &self.ws[i].half),
            Some((theta_policy, cfg)) => {
                let theta = theta_policy.theta(lr as f64, ctx.g_inf, self.w.n(), ctx.rho);
                self.last_theta = theta;
                let codec = MoniquaCodec::from_theta(theta as f32, &cfg);
                let d = self.d;
                let seed = ctx.seed;
                if cfg.shared_randomness {
                    common::rounding_noise(&cfg, seed, round, 0, d, &mut self.shared_noise);
                }
                let D2 { ws, shared_noise, .. } = self;
                let ws = &mut ws[i];
                let noise =
                    common::phase_noise(&cfg, seed, round, i, d, shared_noise, &mut ws.noise);
                codec.encode_packed_into(&ws.half, noise, &mut ws.wire);
                codec.local_biased_into(&ws.half, noise, &mut ws.xhat_self);
                payload.extend_from_slice(&ws.wire);
                if cfg.verify_hash {
                    // Keeps the shipped bytes equal to what
                    // `wire_bytes_packed` accounts (+8 when hashing is on).
                    payload.extend_from_slice(
                        &hash::sender_digest(&codec, &ws.half, noise).to_le_bytes(),
                    );
                }
            }
        }
    }

    /// `node_send` runs the variance-reduced half-step (which consumes
    /// this round's *and* last round's gradients) before encoding, so the
    /// frame cannot leave until the gradient is done.
    fn send_phase(&self) -> SendPhase {
        SendPhase::PostGradient
    }

    fn node_recv(
        &mut self,
        i: usize,
        x: &mut [f32],
        _grad: &[f32],
        lr: f32,
        _round: u64,
        ctx: &StepCtx,
        inbox: &Inbox,
    ) -> CommStats {
        let d = self.d;
        let deg_sum = self.w.deg_sum();
        match self.moniqua.clone() {
            None => {
                let overhead = self.wire_overhead();
                let D2 { w, ws, decode, .. } = self;
                x.fill(0.0);
                crate::linalg::axpy(x, w.weight(i, i) as f32, &ws[i].half);
                for (j, wji) in w.in_edges(i) {
                    common::read_f32s_into(inbox.payload(j), decode);
                    crate::linalg::axpy(x, wji as f32, decode);
                }
                CommStats {
                    bytes_per_msg: d * 4 + overhead,
                    messages: deg_sum as u64,
                    allreduce_bytes: None,
                    extra_local_passes: 0,
                }
            }
            Some((theta_policy, cfg)) => {
                let theta = theta_policy.theta(lr as f64, ctx.g_inf, self.w.n(), ctx.rho);
                let codec = MoniquaCodec::from_theta(theta as f32, &cfg);
                let wire_len = packing::packed_len(d, cfg.bits);
                let D2 { w, ws, recover, strike_buf, .. } = self;
                let rec = &mut recover[i];
                x.copy_from_slice(&ws[i].half);
                for (j, wji) in w.in_edges(i) {
                    let payload = inbox.payload(j);
                    let (wire, digest) = if cfg.verify_hash {
                        let (wb, db) = payload.split_at(wire_len);
                        (wb, u64::from_le_bytes(db.try_into().expect("8-byte digest tail")))
                    } else {
                        (payload, 0u64)
                    };
                    let wji = wji as f32;
                    codec.recover_packed_into(wire, &ws[i].half, rec);
                    if cfg.verify_hash && !hash::verify_reconstruction(&codec, rec, digest) {
                        // Verify-then-skip: a digest-failing Σ term is
                        // dropped (the self-substituted term would be
                        // exactly zero anyway) and the sender is struck.
                        strike_buf.push(j as u16);
                        continue;
                    }
                    for k in 0..d {
                        x[k] += wji * (rec[k] - ws[i].xhat_self[k]);
                    }
                }
                CommStats {
                    bytes_per_msg: common::wire_bytes_packed(&cfg, d, &ws[i].wire),
                    messages: deg_sum as u64,
                    allreduce_bytes: None,
                    extra_local_passes: 0,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    fn ctx(rho: f64) -> StepCtx {
        StepCtx { seed: 5, rho, g_inf: 1.0 }
    }

    /// Heterogeneous quadratic: worker i minimizes ½‖x − c_i‖² with very
    /// different c_i. The *global* optimum is mean(c_i). D-PSGD with a
    /// constant step size stalls at a bias floor; D² removes it.
    fn heterogeneous_run(alg: &mut dyn SyncAlgorithm, rho: f64, steps: u64) -> f64 {
        let n = 4;
        let d = 8;
        let cs = [-3.0f32, -1.0, 1.0, 3.0]; // mean 0
        let mut xs: Vec<Vec<f32>> = (0..n).map(|_| vec![0.5; d]).collect();
        for k in 0..steps {
            let grads: Vec<Vec<f32>> = (0..n)
                .map(|i| xs[i].iter().map(|&v| v - cs[i]).collect())
                .collect();
            alg.step(&mut xs, &grads, 0.08, k, &ctx(rho));
        }
        // distance of the average model from the global optimum 0
        let mut mean = vec![0.0f32; d];
        for x in &xs {
            crate::linalg::axpy(&mut mean, 0.25, x);
        }
        crate::linalg::norm2_sq(&mean)
    }

    #[test]
    fn d2_beats_dpsgd_on_heterogeneous_data() {
        let w = Topology::Ring(4).comm_matrix();
        let rho = w.rho();
        let mut d2 = D2::new(w.clone(), 8, None);
        let mut dpsgd = super::super::dpsgd::DPsgd::new(w, 8);
        let e_d2 = heterogeneous_run(&mut d2, rho, 400);
        let e_dp = heterogeneous_run(&mut dpsgd, rho, 400);
        // Both find the mean on a quadratic; D² must be at least as good and
        // its *local* models unbiased. Check local bias:
        assert!(e_d2 <= e_dp + 1e-6, "d2 {e_d2} dpsgd {e_dp}");
    }

    #[test]
    fn d2_local_models_reach_global_optimum() {
        // The sharper claim: with decentralized data, D-PSGD's *local*
        // models orbit their local optima; D²'s converge to the global one.
        let w = Topology::Ring(4).comm_matrix();
        let rho = w.rho();
        let n = 4;
        let d = 8;
        let cs = [-3.0f32, -1.0, 1.0, 3.0];
        let run = |alg: &mut dyn SyncAlgorithm| -> (f64, f64) {
            let mut xs: Vec<Vec<f32>> = (0..n).map(|_| vec![0.5; d]).collect();
            for k in 0..600 {
                let grads: Vec<Vec<f32>> = (0..n)
                    .map(|i| xs[i].iter().map(|&v| v - cs[i]).collect())
                    .collect();
                alg.step(&mut xs, &grads, 0.1, k, &ctx(rho));
            }
            // worst local distance from 0, and consensus spread
            let worst = xs
                .iter()
                .map(|x| crate::linalg::norm2_sq(x) / d as f64)
                .fold(0.0f64, f64::max);
            let spread = crate::linalg::linf_dist(&xs[0], &xs[2]) as f64;
            (worst, spread)
        };
        let (d2_worst, _) = run(&mut D2::new(w.clone(), d, None));
        let (dp_worst, _) = run(&mut super::super::dpsgd::DPsgd::new(w, d));
        assert!(
            d2_worst < 0.05 * dp_worst.max(1e-9),
            "d2 {d2_worst} vs dpsgd {dp_worst}"
        );
    }

    #[test]
    fn moniqua_d2_tracks_d2() {
        let w = Topology::Ring(4).comm_matrix();
        let rho = w.rho();
        let mut md2 = D2::new(
            w.clone(),
            8,
            Some((ThetaPolicy::Constant(2.0), QuantConfig::stochastic(8))),
        );
        let mut d2 = D2::new(w, 8, None);
        let e_md2 = heterogeneous_run(&mut md2, rho, 400);
        let e_d2 = heterogeneous_run(&mut d2, rho, 400);
        assert!(e_md2 < e_d2 + 0.01, "moniqua-d2 {e_md2} d2 {e_d2}");
        assert_eq!(md2.name(), "moniqua-d2");
        assert!(md2.last_theta().is_some());
    }

    #[test]
    fn quantized_traffic_smaller_than_full() {
        let w = Topology::Ring(4).comm_matrix();
        let mut md2 = D2::new(
            w.clone(),
            1000,
            Some((ThetaPolicy::Constant(2.0), QuantConfig::stochastic(8))),
        );
        let mut d2 = D2::new(w, 1000, None);
        let mut xs: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0; 1000]).collect();
        let grads = xs.clone();
        let s_q = md2.step(&mut xs, &grads, 0.1, 0, &ctx(0.8));
        let mut xs2: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0; 1000]).collect();
        let s_f = d2.step(&mut xs2, &grads, 0.1, 0, &ctx(0.8));
        assert_eq!(s_q.bytes_per_msg * 4, s_f.bytes_per_msg);
    }
}
