//! DCD-PSGD (Tang et al. 2018, "Communication compression for decentralized
//! training", Alg. 1): difference-compression with per-neighbor replicas.
//!
//! Every worker keeps a replica x̂_j of each neighbor (Θ(md) memory across
//! the graph) kept in sync by broadcasting quantized *differences*:
//!
//! ```text
//!     z_i   = Σ_j W_ji x̂_j − α g̃_i          (average replicas + grad)
//!     q_i   = Q( z_i − x̂_i )                 (quantize the self-difference)
//!     x̂_i ← x̂_i + q_i                        (applied by i and all neighbors)
//!     x_i   = z_i
//! ```
//!
//! Unbiased quantizers only; the error of Q must contract faster than the
//! consensus dynamics amplify it — which fails at aggressive budgets
//! (1–2 bits) exactly as Table 2 reports ("diverge").

use super::engine::RoundPool;
use super::{common, CommStats, Inbox, RangeQuantizer, SendPhase, StepCtx, SyncAlgorithm};
use crate::quant::{packing, QuantConfig};
use crate::topology::CommMatrix;

/// Per-worker quantization scratch for the compress phase.
struct Ws {
    diff: Vec<f32>,
    noise: Vec<f32>,
    codes: Vec<u32>,
    qdiff: Vec<f32>,
}

pub struct Dcd {
    w: CommMatrix,
    d: usize,
    cfg: QuantConfig,
    quant: RangeQuantizer,
    /// true → per-message (QSGD-style) rescaling with a 4-byte header;
    /// false → the paper's fixed-grid quantizer (range clipping).
    dynamic: bool,
    pool: RoundPool,
    /// Replicas x̂_i — one logical copy per (edge, endpoint) in a real
    /// deployment (Θ(md) memory, see `extra_memory_floats`), stored once
    /// here since the simulator shares address space.
    xhat: Vec<Vec<f32>>,
    z: Vec<Vec<f32>>,
    ws: Vec<Ws>,
    initialized: bool,
    /// Node-mode decode buffers for one neighbor's quantized difference.
    node_codes: Vec<u32>,
    node_vals: Vec<f32>,
}

impl Dcd {
    /// `range == 0` selects dynamic per-message scaling (the charitable
    /// baseline); `range > 0` the fixed grid the paper's Table 2 uses.
    pub fn new(w: CommMatrix, d: usize, cfg: QuantConfig, range: f32) -> Self {
        let n = w.n();
        let dynamic = range == 0.0;
        Dcd {
            w,
            d,
            cfg,
            quant: RangeQuantizer::new(&cfg, if dynamic { 1.0 } else { range }),
            dynamic,
            pool: RoundPool::for_dim(d),
            xhat: vec![vec![0.0; d]; n],
            z: vec![vec![0.0; d]; n],
            ws: (0..n)
                .map(|_| Ws {
                    diff: vec![0.0; d],
                    noise: Vec::new(),
                    codes: vec![0; d],
                    qdiff: vec![0.0; d],
                })
                .collect(),
            initialized: false,
            node_codes: vec![0; d],
            node_vals: vec![0.0; d],
        }
    }
}

impl SyncAlgorithm for Dcd {
    fn name(&self) -> &'static str {
        "dcd"
    }

    fn set_threads(&mut self, threads: usize) {
        self.pool = RoundPool::new(threads);
    }

    // Persistent state: the per-neighbor replicas x̂ (Table 1's Θ(md)
    // memory) plus the lazy-init flag. `z` is round scratch (recomputed by
    // the next send half).
    fn snapshot(&self, out: &mut Vec<u8>) {
        use crate::elastic::snapshot as ss;
        ss::put_u8(out, self.initialized as u8);
        ss::put_u32(out, self.xhat.len() as u32);
        for row in &self.xhat {
            ss::put_f32_slice(out, row);
        }
    }

    fn restore(&mut self, bytes: &[u8]) -> Result<(), crate::elastic::SnapshotError> {
        use crate::elastic::{snapshot as ss, SnapshotError};
        let mut r = ss::Reader::new(bytes);
        let initialized = match r.take_u8()? {
            0 => false,
            1 => true,
            _ => return Err(SnapshotError::Malformed("dcd initialized flag")),
        };
        if r.take_u32()? as usize != self.xhat.len() {
            return Err(SnapshotError::Malformed("dcd replica count"));
        }
        for row in self.xhat.iter_mut() {
            r.take_f32_into(row)?;
        }
        r.finish()?;
        self.initialized = initialized;
        Ok(())
    }

    fn step(
        &mut self,
        xs: &mut [Vec<f32>],
        grads: &[Vec<f32>],
        lr: f32,
        round: u64,
        ctx: &StepCtx,
    ) -> CommStats {
        let n = xs.len();
        let cfg = self.cfg;
        let d = self.d;
        let quant = self.quant;
        let dynamic = self.dynamic;
        let seed = ctx.seed;
        if !self.initialized {
            // Replicas start at the (identical) initialization — exact.
            for i in 0..n {
                self.xhat[i].copy_from_slice(&xs[i]);
            }
            self.initialized = true;
        }
        // z_i = Σ_j W_ji x̂_j − α g_i
        {
            let w = &self.w;
            let xhat = &self.xhat;
            self.pool.for_each_mut(&mut self.z, |i, z| {
                z.fill(0.0);
                crate::linalg::axpy(z, w.weight(i, i) as f32, &xhat[i]);
                for (j, wji) in w.in_edges(i) {
                    crate::linalg::axpy(z, wji as f32, &xhat[j]);
                }
                crate::linalg::axpy(z, -lr, &grads[i]);
            });
        }
        // quantize differences
        {
            let z = &self.z;
            let xhat = &self.xhat;
            self.pool.for_each_mut(&mut self.ws, |i, ws| {
                common::rounding_noise(&cfg, seed, round, i, d, &mut ws.noise);
                for k in 0..d {
                    ws.diff[k] = z[i][k] - xhat[i][k];
                }
                if dynamic {
                    quant.quantize_dynamic_into(
                        &ws.diff, &ws.noise, &mut ws.codes, &mut ws.qdiff,
                    );
                } else {
                    quant.quantize_into(&ws.diff, &ws.noise, &mut ws.codes, &mut ws.qdiff);
                }
            });
        }
        let bytes = common::wire_bytes(&cfg, &self.ws[0].codes)
            + if dynamic { 4 } else { 0 };
        // update replicas + adopt z
        {
            let ws = &self.ws;
            self.pool.for_each_mut(&mut self.xhat, |i, xh| {
                for k in 0..d {
                    xh[k] += ws[i].qdiff[k];
                }
            });
        }
        {
            let z = &self.z;
            self.pool.for_each_mut(xs, |i, x| x.copy_from_slice(&z[i]));
        }
        let deg_sum = self.w.deg_sum();
        CommStats {
            bytes_per_msg: bytes,
            messages: deg_sum as u64,
            allreduce_bytes: None,
            // replica maintenance: one extra full-vector pass per round
            extra_local_passes: 1,
        }
    }

    fn node_send(
        &mut self,
        i: usize,
        x: &[f32],
        grad: &[f32],
        lr: f32,
        round: u64,
        ctx: &StepCtx,
        payload: &mut Vec<u8>,
    ) {
        let cfg = self.cfg;
        let quant = self.quant;
        let dynamic = self.dynamic;
        let d = self.d;
        if !self.initialized {
            // Replicas start at the identical initialization (assumption
            // A4) — worker i's own model is every worker's model at k = 0.
            for xh in self.xhat.iter_mut() {
                xh.copy_from_slice(x);
            }
            self.initialized = true;
        }
        // z_i = Σ_j W_ji x̂_j − α g_i over replicas i actually holds.
        {
            let Dcd { w, xhat, z, .. } = self;
            let z = &mut z[i];
            z.fill(0.0);
            crate::linalg::axpy(z, w.weight(i, i) as f32, &xhat[i]);
            for (j, wji) in w.in_edges(i) {
                crate::linalg::axpy(z, wji as f32, &xhat[j]);
            }
            crate::linalg::axpy(z, -lr, grad);
        }
        let scale = {
            let Dcd { z, xhat, ws, .. } = self;
            let ws = &mut ws[i];
            common::rounding_noise(&cfg, ctx.seed, round, i, d, &mut ws.noise);
            for k in 0..d {
                ws.diff[k] = z[i][k] - xhat[i][k];
            }
            if dynamic {
                quant.quantize_dynamic_into(&ws.diff, &ws.noise, &mut ws.codes, &mut ws.qdiff)
            } else {
                quant.quantize_into(&ws.diff, &ws.noise, &mut ws.codes, &mut ws.qdiff);
                quant.range
            }
        };
        if dynamic {
            // QSGD-style self-describing scale: the 4-byte header
            // `wire_bytes` has always charged for dynamic mode.
            payload.extend_from_slice(&scale.to_bits().to_le_bytes());
        }
        let base = payload.len();
        payload.resize(base + packing::packed_len(d, cfg.bits), 0);
        packing::pack_into(&self.ws[i].codes, cfg.bits, &mut payload[base..]);
    }

    /// The wire difference is taken against `z = Σ_j W_ji x̂_j − α g_i`,
    /// which consumes the round's gradient — send must follow compute.
    fn send_phase(&self) -> SendPhase {
        SendPhase::PostGradient
    }

    fn node_recv(
        &mut self,
        i: usize,
        x: &mut [f32],
        _grad: &[f32],
        _lr: f32,
        _round: u64,
        _ctx: &StepCtx,
        inbox: &Inbox,
    ) -> CommStats {
        let cfg = self.cfg;
        let quant = self.quant;
        let dynamic = self.dynamic;
        let d = self.d;
        let Dcd { w, ws, xhat, z, node_codes, node_vals, .. } = self;
        // Own replica absorbs the difference i just broadcast…
        for k in 0..d {
            xhat[i][k] += ws[i].qdiff[k];
        }
        // …and each neighbor replica absorbs the decoded wire difference
        // (bitwise the sender's qdiff — the value is a pure function of the
        // code and the scale).
        for &j in &w.neighbors[i] {
            common::decode_baseline_payload(
                &quant,
                dynamic,
                cfg.bits,
                inbox.payload(j),
                node_codes,
                node_vals,
            );
            for k in 0..d {
                xhat[j][k] += node_vals[k];
            }
        }
        x.copy_from_slice(&z[i]);
        let deg_sum = w.deg_sum();
        CommStats {
            bytes_per_msg: common::wire_bytes(&cfg, &ws[i].codes) + if dynamic { 4 } else { 0 },
            messages: deg_sum as u64,
            allreduce_bytes: None,
            extra_local_passes: 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    fn ctx(rho: f64) -> StepCtx {
        StepCtx { seed: 9, rho, g_inf: 1.0 }
    }

    fn quad_run(alg: &mut dyn SyncAlgorithm, steps: u64, lr: f32, rho: f64) -> f64 {
        let n = 4;
        let d = 8;
        let c = 0.3f32;
        let mut xs: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0; d]).collect();
        for k in 0..steps {
            let grads: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| x.iter().map(|&v| v - c).collect())
                .collect();
            alg.step(&mut xs, &grads, lr, k, &ctx(rho));
        }
        xs.iter()
            .map(|x| x.iter().map(|&v| ((v - c) as f64).powi(2)).sum::<f64>())
            .sum::<f64>()
            / n as f64
    }

    #[test]
    fn converges_at_8_bits() {
        let w = Topology::Ring(4).comm_matrix();
        let rho = w.rho();
        let mut alg = Dcd::new(w, 8, QuantConfig::stochastic(8), 0.0);
        let loss = quad_run(&mut alg, 400, 0.1, rho);
        assert!(loss < 1e-2, "loss {loss}");
    }

    /// Noisy heterogeneous run: per-worker optima + gradient noise keep the
    /// quantized differences non-vanishing — the regime where 1-bit
    /// difference compression actually fails (a noiseless symmetric
    /// quadratic lets the diffs contract to zero and hides it).
    fn noisy_run(alg: &mut dyn SyncAlgorithm, steps: u64, rho: f64) -> f64 {
        let n = 4;
        let d = 8;
        let cs = [0.0f32, 0.2, 0.4, 0.6]; // mean 0.3
        let mut rng = crate::rng::Pcg64::seeded(5);
        let mut xs: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0; d]).collect();
        for k in 0..steps {
            let grads: Vec<Vec<f32>> = (0..n)
                .map(|i| {
                    xs[i]
                        .iter()
                        .map(|&v| v - cs[i] + 0.05 * rng.next_gaussian() as f32)
                        .collect()
                })
                .collect();
            alg.step(&mut xs, &grads, 0.1, k, &ctx(rho));
        }
        xs.iter()
            .map(|x| x.iter().map(|&v| ((v - 0.3) as f64).powi(2)).sum::<f64>())
            .sum::<f64>()
            / n as f64
    }

    #[test]
    fn one_bit_much_worse_than_8_bit_under_noise() {
        // Table 2's "diverge" row: 1-bit difference compression cannot
        // track noisy non-vanishing diffs (relative error = max|diff|).
        let w = Topology::Ring(4).comm_matrix();
        let rho = w.rho();
        // paper-faithful fixed-grid mode (what Table 2 ran)
        let mut a8 = Dcd::new(w.clone(), 8, QuantConfig::stochastic(8), 4.0);
        let mut a1 = Dcd::new(w, 8, QuantConfig::stochastic(1), 4.0);
        let l8 = noisy_run(&mut a8, 400, rho);
        let l1 = noisy_run(&mut a1, 400, rho);
        assert!(
            l1 > 10.0 * l8 || l1.is_nan(),
            "1-bit DCD should degrade: {l1} vs 8-bit {l8}"
        );
    }

    #[test]
    fn reports_extra_local_pass() {
        let w = Topology::Ring(4).comm_matrix();
        let mut alg = Dcd::new(w, 16, QuantConfig::stochastic(8), 4.0);
        let mut xs: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0; 16]).collect();
        let grads = xs.clone();
        let s = alg.step(&mut xs, &grads, 0.1, 0, &ctx(0.8));
        assert_eq!(s.extra_local_passes, 1);
        assert_eq!(s.bytes_per_msg, 16); // 8 bits, fixed grid: no header
    }
}
