//! The parallel round engine: a persistent worker pool that fans the three
//! per-round phases of every [`super::SyncAlgorithm`] out across cores.
//!
//! A synchronous decentralized round is embarrassingly parallel across the
//! `n` simulated workers in each of its phases (see `rust/DESIGN.md`
//! §Engine):
//!
//! 1. **encode** — every worker wraps/quantizes/packs its own model
//!    (Algorithm 1 lines 3–4): reads `xs`, writes worker-local send scratch;
//! 2. **recover + accumulate** — every receiver reconstructs each
//!    neighbor's model and accumulates weighted differences (lines 5–6):
//!    reads the send scratch of phase 1, writes receiver-local scratch;
//! 3. **apply** — every worker applies its accumulated update and the
//!    gradient step (line 7): writes only `xs[i]`.
//!
//! Each phase writes to disjoint per-worker state, so the pool simply
//! partitions the worker index range into contiguous chunks — one per OS
//! thread — with no locks and no atomics on the hot path.
//!
//! ## Determinism contract
//!
//! Results are **bitwise identical** for every pool size, including 1:
//!
//! * all randomness is drawn from per-`(seed, round, worker)` PCG64 streams
//!   ([`crate::rng`]) — no thread observes another thread's RNG;
//! * every write target is owned by exactly one worker index;
//! * each receiver accumulates its neighbors *sequentially in neighbor
//!   order*, so floating-point summation order never depends on the
//!   schedule.
//!
//! The `tests/engine_equivalence.rs` suite pins this contract for every
//! algorithm in the crate.
//!
//! ## Phase split at the node level
//!
//! The same three phases reappear in the message-passing runtime as the
//! per-node halves `node_send` (phase 1 for one worker) and `node_recv`
//! (phases 2–3 against the inbox). Whether phase 1 runs before or after
//! the round's gradient is the engine's [`super::SendPhase`]: engines
//! whose encode reads only `x` declare `PreGradient`, which lets the
//! cluster scheduler broadcast the frame while the gradient computes
//! (`coordinator::cluster`, §Pipelined rounds) without changing a single
//! payload byte. Engines whose encode consumes the gradient (`x − αg`
//! half-steps, error feedback, the raw-gradient baselines) declare
//! `PostGradient` and keep the strict order.
//!
//! ## Threading model
//!
//! The [`RoundPool`] object is persistent (constructed once per algorithm
//! engine); the OS threads themselves are spawned per phase through
//! [`std::thread::scope`], which is the only std-safe way to lend the
//! borrowed round state (`xs`, `grads`, scratch) to worker threads without
//! `unsafe` lifetime erasure. Scoped spawn costs O(10 µs) per thread —
//! negligible against the O(n·d) floating-point work of a phase at the
//! model sizes the benches run (see `bench_quant_throughput`). Pools of
//! size 1, and phases with a single item, run inline with zero spawns.

/// Below this per-worker dimension a phase's floating-point work is in the
/// same ballpark as scoped-spawn overhead (~10 µs/thread), so engines built
/// by [`RoundPool::for_dim`] stay sequential — matching the pre-engine
/// behavior for the tiny models unit tests and sweeps use. Explicit widths
/// (`set_threads`, `TrainConfig::threads`, `MONIQUA_THREADS`) always win.
const MIN_PARALLEL_DIM: usize = 1 << 16;

/// A persistent, fixed-width worker pool for data-parallel round phases.
#[derive(Clone, Debug)]
pub struct RoundPool {
    threads: usize,
}

impl RoundPool {
    /// Pool with an explicit width (clamped to ≥ 1). Width 1 is the
    /// sequential reference engine.
    pub fn new(threads: usize) -> Self {
        RoundPool { threads: threads.max(1) }
    }

    /// Pool sized to the available cores, overridable with the
    /// `MONIQUA_THREADS` environment variable (0 or unset → all cores).
    pub fn auto() -> Self {
        let env = std::env::var("MONIQUA_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&t| t > 0);
        let threads = env.unwrap_or_else(|| {
            std::thread::available_parallelism().map_or(1, |p| p.get())
        });
        Self::new(threads)
    }

    /// Default pool for an engine over `d`-dimensional models: sequential
    /// below [`MIN_PARALLEL_DIM`] (spawns would cost more than they buy),
    /// [`Self::auto`] at bench/production scales. A `MONIQUA_THREADS`
    /// override applies regardless of `d`.
    pub fn for_dim(d: usize) -> Self {
        let forced = std::env::var("MONIQUA_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&t| t > 0);
        match forced {
            Some(t) => Self::new(t),
            None if d < MIN_PARALLEL_DIM => Self::new(1),
            None => Self::auto(),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(i, &mut items[i])` for every item, partitioned across the
    /// pool. Mutable access is disjoint by construction (`chunks_mut`).
    pub fn for_each_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let n = items.len();
        let t = self.threads.min(n);
        if t <= 1 {
            for (i, item) in items.iter_mut().enumerate() {
                f(i, item);
            }
            return;
        }
        let chunk = n.div_ceil(t);
        let f = &f;
        std::thread::scope(|s| {
            for (ci, ca) in items.chunks_mut(chunk).enumerate() {
                let base = ci * chunk;
                s.spawn(move || {
                    for (k, item) in ca.iter_mut().enumerate() {
                        f(base + k, item);
                    }
                });
            }
        });
    }

    /// Run `f(i, &mut a[i], &mut b[i])` over two equal-length slices —
    /// for phases that mutate two per-worker arrays at once (e.g. `xs[i]`
    /// plus a receiver-local recovery buffer).
    pub fn for_each_mut2<A, B, F>(&self, a: &mut [A], b: &mut [B], f: F)
    where
        A: Send,
        B: Send,
        F: Fn(usize, &mut A, &mut B) + Sync,
    {
        assert_eq!(a.len(), b.len(), "for_each_mut2 slices must zip exactly");
        let n = a.len();
        let t = self.threads.min(n);
        if t <= 1 {
            for (i, (x, y)) in a.iter_mut().zip(b.iter_mut()).enumerate() {
                f(i, x, y);
            }
            return;
        }
        let chunk = n.div_ceil(t);
        let f = &f;
        std::thread::scope(|s| {
            for (ci, (ca, cb)) in a.chunks_mut(chunk).zip(b.chunks_mut(chunk)).enumerate() {
                let base = ci * chunk;
                s.spawn(move || {
                    for (k, (x, y)) in ca.iter_mut().zip(cb.iter_mut()).enumerate() {
                        f(base + k, x, y);
                    }
                });
            }
        });
    }
}

impl Default for RoundPool {
    fn default() -> Self {
        Self::auto()
    }
}

/// Chunk size (in codes) of the pooled fused-codec paths: 32 Ki codes is
/// 128 KiB of f32 input — sized to stream through a per-core L2 — and a
/// multiple of 64, so every chunk boundary lands on an 8-byte word boundary
/// of the packed stream at *every* bit width (`64·bits ≡ 0 (mod 8)`).
/// Word-aligned boundaries are what let each chunk run the word kernels
/// independently with byte-identical output to the single-pass kernel.
pub const CODEC_CHUNK_CODES: usize = 1 << 15;

impl RoundPool {
    /// Fused encode ([`crate::quant::MoniquaCodec::encode_packed_into`]) blocked into
    /// cache-sized, word-aligned chunks and fanned across the pool.
    ///
    /// Bitwise-identical to the single-pass kernel at every pool width
    /// (each element's code is a pure function of its index, and chunk
    /// writes are disjoint byte ranges), pinned by
    /// `tests/quant_properties.rs`. Width-1 pools and small inputs take the
    /// single-pass kernel directly — no chunk bookkeeping, no allocation
    /// (the cluster runtime's per-node engines run exactly this path).
    // lint: hot-path
    pub fn encode_packed(
        &self,
        codec: &crate::quant::MoniquaCodec,
        x: &[f32],
        noise: &[f32],
        out: &mut [u8],
    ) {
        let n = x.len();
        if self.threads <= 1 || n < 2 * CODEC_CHUNK_CODES {
            codec.encode_packed_into(x, noise, out);
            return;
        }
        let byte_per = CODEC_CHUNK_CODES * codec.bits() as usize / 8;
        let mut chunks: Vec<(&[f32], &[f32], &mut [u8])> =
            Vec::with_capacity(n.div_ceil(CODEC_CHUNK_CODES));
        let mut xr = x;
        // Nearest-rounding callers pass an ignored (possibly d-length)
        // noise buffer; slice it alongside x only when it actually zips.
        let mut nr = if noise.len() == n { noise } else { &[][..] };
        let mut or: &mut [u8] = out;
        while xr.len() > CODEC_CHUNK_CODES {
            let (xa, xb) = xr.split_at(CODEC_CHUNK_CODES);
            let (na, nb) = if nr.is_empty() {
                (nr, nr)
            } else {
                nr.split_at(CODEC_CHUNK_CODES)
            };
            let (oa, ob) = std::mem::replace(&mut or, &mut []).split_at_mut(byte_per);
            chunks.push((xa, na, oa));
            xr = xb;
            nr = nb;
            or = ob;
        }
        chunks.push((xr, nr, or));
        self.for_each_mut(&mut chunks, |_, c| codec.encode_packed_into(c.0, c.1, c.2));
    }

    /// Fused recover ([`crate::quant::MoniquaCodec::recover_packed_into`]) blocked into
    /// the same word-aligned chunks as [`Self::encode_packed`] and fanned
    /// across the pool. Same bitwise-identity contract.
    // lint: hot-path
    pub fn recover_packed(
        &self,
        codec: &crate::quant::MoniquaCodec,
        bytes: &[u8],
        y: &[f32],
        out: &mut [f32],
    ) {
        let n = out.len();
        if self.threads <= 1 || n < 2 * CODEC_CHUNK_CODES {
            codec.recover_packed_into(bytes, y, out);
            return;
        }
        let byte_per = CODEC_CHUNK_CODES * codec.bits() as usize / 8;
        let mut chunks: Vec<(&[u8], &[f32], &mut [f32])> =
            Vec::with_capacity(n.div_ceil(CODEC_CHUNK_CODES));
        let mut br = bytes;
        let mut yr = y;
        let mut or: &mut [f32] = out;
        while or.len() > CODEC_CHUNK_CODES {
            let (ba, bb) = br.split_at(byte_per);
            let (ya, yb) = yr.split_at(CODEC_CHUNK_CODES);
            let (oa, ob) = std::mem::replace(&mut or, &mut []).split_at_mut(CODEC_CHUNK_CODES);
            chunks.push((ba, ya, oa));
            br = bb;
            yr = yb;
            or = ob;
        }
        chunks.push((br, yr, or));
        self.for_each_mut(&mut chunks, |_, c| codec.recover_packed_into(c.0, c.1, c.2));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn for_each_mut_visits_every_index_once() {
        for threads in [1usize, 2, 3, 8, 64] {
            let pool = RoundPool::new(threads);
            let mut hits: Vec<AtomicUsize> = (0..37).map(|_| AtomicUsize::new(0)).collect();
            pool.for_each_mut(&mut hits, |i, h| {
                assert_eq!(h.load(Ordering::Relaxed), 0, "i={i}");
                h.fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "t={threads}");
        }
    }

    #[test]
    fn for_dim_is_sequential_for_tiny_models() {
        if std::env::var("MONIQUA_THREADS").is_ok() {
            return; // explicit override wins by design
        }
        assert_eq!(RoundPool::for_dim(64).threads(), 1);
        assert!(RoundPool::for_dim(1 << 20).threads() >= 1);
    }

    #[test]
    fn for_each_mut_results_independent_of_width() {
        let compute = |threads: usize| -> Vec<u64> {
            let pool = RoundPool::new(threads);
            let mut items: Vec<u64> = vec![0; 101];
            pool.for_each_mut(&mut items, |i, v| {
                // index-dependent work: any schedule dependence would show
                *v = crate::rng::Pcg64::new(7, i as u64).next_u64();
            });
            items
        };
        let seq = compute(1);
        for threads in [2usize, 4, 16] {
            assert_eq!(compute(threads), seq, "t={threads}");
        }
    }

    #[test]
    fn for_each_mut2_zips_disjointly() {
        let pool = RoundPool::new(4);
        let mut a = vec![0usize; 50];
        let mut b = vec![0usize; 50];
        pool.for_each_mut2(&mut a, &mut b, |i, x, y| {
            *x = i;
            *y = 2 * i;
        });
        for i in 0..50 {
            assert_eq!(a[i], i);
            assert_eq!(b[i], 2 * i);
        }
    }

    #[test]
    #[should_panic]
    fn for_each_mut2_rejects_length_mismatch() {
        let pool = RoundPool::new(2);
        let mut a = vec![0u8; 3];
        let mut b = vec![0u8; 4];
        pool.for_each_mut2(&mut a, &mut b, |_, _, _| {});
    }

    #[test]
    fn empty_and_single_item_run_inline() {
        let pool = RoundPool::new(8);
        let mut items: Vec<u32> = vec![];
        pool.for_each_mut(&mut items, |_, _| unreachable!());
        let mut one = vec![5u32];
        pool.for_each_mut(&mut one, |i, v| *v += i as u32 + 1);
        assert_eq!(one[0], 6);
    }

    #[test]
    fn auto_pool_has_at_least_one_thread() {
        assert!(RoundPool::auto().threads() >= 1);
        assert_eq!(RoundPool::new(0).threads(), 1);
    }
}
