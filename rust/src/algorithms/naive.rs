//! Naive quantized D-PSGD — the §3 counterexample (Theorem 1):
//!
//! ```text
//!     x_{k+1,i} = W_ii x_{k,i} + Σ_{j≠i} W_ji Q_δ(x_{k,j}) − α_k g̃_{k,i}
//! ```
//!
//! With an *unbiased* linear quantizer whose representable points are `δ·Z`,
//! the iterates provably cannot enter the region
//! `E‖∇f‖² < φ²δ²/(8(1+φ²))` on the Theorem-1 quadratic. This engine exists
//! to regenerate that result (bench_theorem1_naive).

use super::engine::RoundPool;
use super::{common, CommStats, Inbox, RangeQuantizer, SendPhase, StepCtx, SyncAlgorithm};
use crate::quant::{packing, QuantConfig};
use crate::topology::CommMatrix;

/// Per-worker encode scratch (noise + codes were previously shared single
/// buffers; per-worker copies make the encode phase data-parallel).
struct Enc {
    noise: Vec<f32>,
    codes: Vec<u32>,
    qval: Vec<f32>,
}

pub struct NaiveQuant {
    w: CommMatrix,
    d: usize,
    cfg: QuantConfig,
    quant: RangeQuantizer,
    pool: RoundPool,
    enc: Vec<Enc>,
    scratch: Vec<Vec<f32>>,
    /// Node-mode decode buffers for one neighbor's packed codes.
    node_codes: Vec<u32>,
    node_vals: Vec<f32>,
}

impl NaiveQuant {
    pub fn new(w: CommMatrix, d: usize, cfg: QuantConfig, range: f32) -> Self {
        let n = w.n();
        NaiveQuant {
            w,
            d,
            cfg,
            quant: RangeQuantizer::new(&cfg, range),
            pool: RoundPool::for_dim(d),
            enc: (0..n)
                .map(|_| Enc {
                    noise: Vec::new(),
                    codes: vec![0; d],
                    qval: vec![0.0; d],
                })
                .collect(),
            scratch: vec![vec![0.0; d]; n],
            node_codes: vec![0; d],
            node_vals: vec![0.0; d],
        }
    }

    /// Effective absolute quantization step δ·range of the underlying grid.
    pub fn absolute_delta(&self) -> f32 {
        self.quant.max_error()
    }
}

impl SyncAlgorithm for NaiveQuant {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn set_threads(&mut self, threads: usize) {
        self.pool = RoundPool::new(threads);
    }

    fn step(
        &mut self,
        xs: &mut [Vec<f32>],
        grads: &[Vec<f32>],
        lr: f32,
        round: u64,
        ctx: &StepCtx,
    ) -> CommStats {
        let cfg = self.cfg;
        let d = self.d;
        let quant = self.quant;
        let seed = ctx.seed;
        // Every worker quantizes its own model directly (no modulo, no
        // replica): exactly Eq. (4).
        {
            let xs_r: &[Vec<f32>] = xs;
            self.pool.for_each_mut(&mut self.enc, |i, e| {
                common::rounding_noise(&cfg, seed, round, i, d, &mut e.noise);
                quant.quantize_into(&xs_r[i], &e.noise, &mut e.codes, &mut e.qval);
            });
        }
        let bytes = common::wire_bytes(&cfg, &self.enc[0].codes);
        {
            let w = &self.w;
            let enc = &self.enc;
            let xs_r: &[Vec<f32>] = xs;
            self.pool.for_each_mut(&mut self.scratch, |i, out| {
                out.fill(0.0);
                crate::linalg::axpy(out, w.weight(i, i) as f32, &xs_r[i]);
                for (j, wji) in w.in_edges(i) {
                    crate::linalg::axpy(out, wji as f32, &enc[j].qval);
                }
                crate::linalg::axpy(out, -lr, &grads[i]);
            });
        }
        {
            let scratch = &self.scratch;
            self.pool.for_each_mut(xs, |i, x| x.copy_from_slice(&scratch[i]));
        }
        let deg_sum = self.w.deg_sum();
        CommStats {
            bytes_per_msg: bytes,
            messages: deg_sum as u64,
            allreduce_bytes: None,
            extra_local_passes: 0,
        }
    }

    fn node_send(
        &mut self,
        i: usize,
        x: &[f32],
        _grad: &[f32],
        _lr: f32,
        round: u64,
        ctx: &StepCtx,
        payload: &mut Vec<u8>,
    ) {
        let cfg = self.cfg;
        let quant = self.quant;
        let d = self.d;
        let e = &mut self.enc[i];
        common::rounding_noise(&cfg, ctx.seed, round, i, d, &mut e.noise);
        quant.quantize_into(x, &e.noise, &mut e.codes, &mut e.qval);
        payload.resize(packing::packed_len(d, cfg.bits), 0);
        packing::pack_into(&e.codes, cfg.bits, payload);
    }

    /// Quantizes the model `x` with `(seed, round, i)`-keyed noise — no
    /// gradient read in the send half (the update is applied on recv), so
    /// the frame can leave before the gradient is computed.
    fn send_phase(&self) -> SendPhase {
        SendPhase::PreGradient
    }

    fn node_recv(
        &mut self,
        i: usize,
        x: &mut [f32],
        grad: &[f32],
        lr: f32,
        _round: u64,
        _ctx: &StepCtx,
        inbox: &Inbox,
    ) -> CommStats {
        let cfg = self.cfg;
        let quant = self.quant;
        let NaiveQuant { w, enc, scratch, node_codes, node_vals, .. } = self;
        let out = &mut scratch[i];
        out.fill(0.0);
        crate::linalg::axpy(out, w.weight(i, i) as f32, x);
        for (j, wji) in w.in_edges(i) {
            common::decode_baseline_payload(
                &quant,
                false,
                cfg.bits,
                inbox.payload(j),
                node_codes,
                node_vals,
            );
            crate::linalg::axpy(out, wji as f32, node_vals);
        }
        crate::linalg::axpy(out, -lr, grad);
        x.copy_from_slice(out);
        let deg_sum = w.deg_sum();
        CommStats {
            bytes_per_msg: common::wire_bytes(&cfg, &enc[i].codes),
            messages: deg_sum as u64,
            allreduce_bytes: None,
            extra_local_passes: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objectives::quadratic::theorem1_floor;
    use crate::topology::Topology;

    /// Theorem 1 reproduced at unit scale: on f(x)=½‖x−δ1/2‖², naive
    /// quantization stalls above the floor while plain D-PSGD converges.
    #[test]
    fn stalls_on_theorem1_quadratic() {
        let topo = Topology::Ring(4);
        let w = topo.comm_matrix();
        let phi = w.min_nonzero();
        let d = 16usize;
        // Use an unbiased (stochastic) quantizer with absolute step 1.0:
        // bits=2, range=2.0 -> step = range/levels = 0.5... choose so that
        // absolute delta = range * (1/levels) = 1.0.
        let cfg = QuantConfig::stochastic(2).with_shared_randomness(false);
        let range = 4.0f32; // step = 4/4 = 1.0
        let delta_abs = 1.0f64;
        let mut alg = NaiveQuant::new(w.clone(), d, cfg, range);
        assert!((alg.absolute_delta() as f64 - delta_abs).abs() < 1e-6);

        // Theorem 1 places the optimum exactly *between* two representable
        // points. Our grid sits at half-integers {±0.5, ±1.5}, so the
        // adversarial optimum is 0.0 (distance δ/2 from both neighbors) —
        // the same construction as the paper's δ·Z grid with optimum δ/2.
        let opt = 0.0f32;
        let mut xs: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0; d]).collect();
        let ctx = StepCtx { seed: 3, rho: w.rho(), g_inf: 1.0 };
        let mut floor_hits = 0usize;
        for k in 0..400 {
            // gradient of the quadratic: x - opt
            let grads: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| x.iter().map(|&v| v - opt).collect())
                .collect();
            alg.step(&mut xs, &grads, 0.05, k, &ctx);
            if k >= 200 {
                // E||grad f(x_i)||^2 per coordinate ~ mean over coords
                let gsq: f64 = xs[0]
                    .iter()
                    .map(|&v| ((v - opt) as f64).powi(2))
                    .sum::<f64>()
                    / d as f64;
                if gsq * d as f64 >= theorem1_floor(phi, delta_abs) {
                    floor_hits += 1;
                }
            }
        }
        // The iterates must stay at/above the floor essentially always.
        assert!(floor_hits > 190, "hits {floor_hits}");
    }

    #[test]
    fn traffic_is_quantized_size() {
        let w = Topology::Ring(4).comm_matrix();
        let cfg = QuantConfig::stochastic(8);
        let mut alg = NaiveQuant::new(w, 1000, cfg, 2.0);
        let mut xs: Vec<Vec<f32>> = (0..4).map(|_| vec![0.1; 1000]).collect();
        let grads = xs.clone();
        let ctx = StepCtx { seed: 0, rho: 0.8, g_inf: 1.0 };
        let stats = alg.step(&mut xs, &grads, 0.1, 0, &ctx);
        assert_eq!(stats.bytes_per_msg, 1000); // 8 bits/param
        assert_eq!(stats.messages, 8);
    }
}
