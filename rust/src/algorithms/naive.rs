//! Naive quantized D-PSGD — the §3 counterexample (Theorem 1):
//!
//! ```text
//!     x_{k+1,i} = W_ii x_{k,i} + Σ_{j≠i} W_ji Q_δ(x_{k,j}) − α_k g̃_{k,i}
//! ```
//!
//! With an *unbiased* linear quantizer whose representable points are `δ·Z`,
//! the iterates provably cannot enter the region
//! `E‖∇f‖² < φ²δ²/(8(1+φ²))` on the Theorem-1 quadratic. This engine exists
//! to regenerate that result (bench_theorem1_naive).

use super::{common, CommStats, RangeQuantizer, StepCtx, SyncAlgorithm};
use crate::quant::QuantConfig;
use crate::topology::CommMatrix;

pub struct NaiveQuant {
    w: CommMatrix,
    d: usize,
    cfg: QuantConfig,
    quant: RangeQuantizer,
    scratch: Vec<Vec<f32>>,
    qvals: Vec<Vec<f32>>,
    noise: Vec<f32>,
    codes: Vec<u32>,
}

impl NaiveQuant {
    pub fn new(w: CommMatrix, d: usize, cfg: QuantConfig, range: f32) -> Self {
        let n = w.n();
        NaiveQuant {
            w,
            d,
            cfg,
            quant: RangeQuantizer::new(&cfg, range),
            scratch: vec![vec![0.0; d]; n],
            qvals: vec![vec![0.0; d]; n],
            noise: Vec::new(),
            codes: vec![0; d],
        }
    }

    /// Effective absolute quantization step δ·range of the underlying grid.
    pub fn absolute_delta(&self) -> f32 {
        self.quant.max_error()
    }
}

impl SyncAlgorithm for NaiveQuant {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn step(
        &mut self,
        xs: &mut [Vec<f32>],
        grads: &[Vec<f32>],
        lr: f32,
        round: u64,
        ctx: &StepCtx,
    ) -> CommStats {
        let n = xs.len();
        // Every worker quantizes its own model directly (no modulo, no
        // replica): exactly Eq. (4).
        let mut bytes = 0usize;
        for i in 0..n {
            common::rounding_noise(&self.cfg, ctx.seed, round, i, self.d, &mut self.noise);
            self.quant
                .quantize_into(&xs[i], &self.noise, &mut self.codes, &mut self.qvals[i]);
            bytes = common::wire_bytes(&self.cfg, &self.codes);
        }
        for i in 0..n {
            let out = &mut self.scratch[i];
            out.fill(0.0);
            crate::linalg::axpy(out, self.w.weight(i, i) as f32, &xs[i]);
            for &j in &self.w.neighbors[i] {
                crate::linalg::axpy(out, self.w.weight(j, i) as f32, &self.qvals[j]);
            }
            crate::linalg::axpy(out, -lr, &grads[i]);
        }
        for i in 0..n {
            xs[i].copy_from_slice(&self.scratch[i]);
        }
        let deg_sum: usize = self.w.neighbors.iter().map(|v| v.len()).sum();
        CommStats {
            bytes_per_msg: bytes,
            messages: deg_sum as u64,
            allreduce_bytes: None,
            extra_local_passes: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objectives::quadratic::theorem1_floor;
    use crate::topology::Topology;

    /// Theorem 1 reproduced at unit scale: on f(x)=½‖x−δ1/2‖², naive
    /// quantization stalls above the floor while plain D-PSGD converges.
    #[test]
    fn stalls_on_theorem1_quadratic() {
        let topo = Topology::Ring(4);
        let w = topo.comm_matrix();
        let phi = w.min_nonzero();
        let d = 16usize;
        // Use an unbiased (stochastic) quantizer with absolute step 1.0:
        // bits=2, range=2.0 -> step = range/levels = 0.5... choose so that
        // absolute delta = range * (1/levels) = 1.0.
        let cfg = QuantConfig::stochastic(2).with_shared_randomness(false);
        let range = 4.0f32; // step = 4/4 = 1.0
        let delta_abs = 1.0f64;
        let mut alg = NaiveQuant::new(w.clone(), d, cfg, range);
        assert!((alg.absolute_delta() as f64 - delta_abs).abs() < 1e-6);

        // Theorem 1 places the optimum exactly *between* two representable
        // points. Our grid sits at half-integers {±0.5, ±1.5}, so the
        // adversarial optimum is 0.0 (distance δ/2 from both neighbors) —
        // the same construction as the paper's δ·Z grid with optimum δ/2.
        let opt = 0.0f32;
        let mut xs: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0; d]).collect();
        let ctx = StepCtx { seed: 3, rho: w.rho(), g_inf: 1.0 };
        let mut floor_hits = 0usize;
        for k in 0..400 {
            // gradient of the quadratic: x - opt
            let grads: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| x.iter().map(|&v| v - opt).collect())
                .collect();
            alg.step(&mut xs, &grads, 0.05, k, &ctx);
            if k >= 200 {
                // E||grad f(x_i)||^2 per coordinate ~ mean over coords
                let gsq: f64 = xs[0]
                    .iter()
                    .map(|&v| ((v - opt) as f64).powi(2))
                    .sum::<f64>()
                    / d as f64;
                if gsq * d as f64 >= theorem1_floor(phi, delta_abs) {
                    floor_hits += 1;
                }
            }
        }
        // The iterates must stay at/above the floor essentially always.
        assert!(floor_hits > 190, "hits {floor_hits}");
    }

    #[test]
    fn traffic_is_quantized_size() {
        let w = Topology::Ring(4).comm_matrix();
        let cfg = QuantConfig::stochastic(8);
        let mut alg = NaiveQuant::new(w, 1000, cfg, 2.0);
        let mut xs: Vec<Vec<f32>> = (0..4).map(|_| vec![0.1; 1000]).collect();
        let grads = xs.clone();
        let ctx = StepCtx { seed: 0, rho: 0.8, g_inf: 1.0 };
        let stats = alg.step(&mut xs, &grads, 0.1, 0, &ctx);
        assert_eq!(stats.bytes_per_msg, 1000); // 8 bits/param
        assert_eq!(stats.messages, 8);
    }
}
