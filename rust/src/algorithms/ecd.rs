//! ECD-PSGD (Tang et al. 2018, Alg. 2): extrapolation-compressed
//! difference. Like DCD the workers keep per-neighbor estimates (Θ(md)
//! memory), but the estimate is updated through a time-weighted
//! extrapolation that tolerates larger (still unbiased) quantization noise:
//!
//! ```text
//!     x_{k+1,i} = Σ_j W_ji x̂_{k,j} − α g̃_i
//!     z_{k+1,i} = (1 − (k+2)/2)·x_{k,i} + ((k+2)/2)·x_{k+1,i}
//!     send  Q(z_{k+1,i})
//!     x̂_{k+1,i} = (1 − 2/(k+2))·x̂_{k,i} + (2/(k+2))·Q(z_{k+1,i})
//! ```
//!
//! The growing extrapolation weight makes z's magnitude grow with k, so a
//! *clipped* fixed-range quantizer (any real bit budget) eventually
//! saturates — ECD degrades/diverges at low bits (Table 2: diverges at
//! 1 bit, ≈36% accuracy at 2 bits).

use super::engine::RoundPool;
use super::{common, CommStats, Inbox, RangeQuantizer, SendPhase, StepCtx, SyncAlgorithm};
use crate::quant::{packing, QuantConfig};
use crate::topology::CommMatrix;

/// Per-worker extrapolate+quantize scratch.
struct Ws {
    z: Vec<f32>,
    noise: Vec<f32>,
    codes: Vec<u32>,
    qz: Vec<f32>,
}

pub struct Ecd {
    w: CommMatrix,
    d: usize,
    cfg: QuantConfig,
    quant: RangeQuantizer,
    /// true → per-message rescaling (+4-byte header); false → fixed grid.
    dynamic: bool,
    pool: RoundPool,
    xhat: Vec<Vec<f32>>,
    x_new: Vec<Vec<f32>>,
    ws: Vec<Ws>,
    initialized: bool,
    /// Node-mode decode buffers for one neighbor's quantized estimate.
    node_codes: Vec<u32>,
    node_vals: Vec<f32>,
}

impl Ecd {
    /// `range == 0` → dynamic per-message scaling; `range > 0` → fixed grid.
    pub fn new(w: CommMatrix, d: usize, cfg: QuantConfig, range: f32) -> Self {
        let n = w.n();
        let dynamic = range == 0.0;
        Ecd {
            w,
            d,
            cfg,
            quant: RangeQuantizer::new(&cfg, if dynamic { 1.0 } else { range }),
            dynamic,
            pool: RoundPool::for_dim(d),
            xhat: vec![vec![0.0; d]; n],
            x_new: vec![vec![0.0; d]; n],
            ws: (0..n)
                .map(|_| Ws {
                    z: vec![0.0; d],
                    noise: Vec::new(),
                    codes: vec![0; d],
                    qz: vec![0.0; d],
                })
                .collect(),
            initialized: false,
            node_codes: vec![0; d],
            node_vals: vec![0.0; d],
        }
    }
}

impl SyncAlgorithm for Ecd {
    fn name(&self) -> &'static str {
        "ecd"
    }

    fn set_threads(&mut self, threads: usize) {
        self.pool = RoundPool::new(threads);
    }

    // Persistent state: the extrapolated estimates x̂ plus the lazy-init
    // flag (`x_new`/`z` are within-round scratch; the round-indexed
    // ext/eta weights come from the round number, not stored state).
    fn snapshot(&self, out: &mut Vec<u8>) {
        use crate::elastic::snapshot as ss;
        ss::put_u8(out, self.initialized as u8);
        ss::put_u32(out, self.xhat.len() as u32);
        for row in &self.xhat {
            ss::put_f32_slice(out, row);
        }
    }

    fn restore(&mut self, bytes: &[u8]) -> Result<(), crate::elastic::SnapshotError> {
        use crate::elastic::{snapshot as ss, SnapshotError};
        let mut r = ss::Reader::new(bytes);
        let initialized = match r.take_u8()? {
            0 => false,
            1 => true,
            _ => return Err(SnapshotError::Malformed("ecd initialized flag")),
        };
        if r.take_u32()? as usize != self.xhat.len() {
            return Err(SnapshotError::Malformed("ecd estimate count"));
        }
        for row in self.xhat.iter_mut() {
            r.take_f32_into(row)?;
        }
        r.finish()?;
        self.initialized = initialized;
        Ok(())
    }

    fn step(
        &mut self,
        xs: &mut [Vec<f32>],
        grads: &[Vec<f32>],
        lr: f32,
        round: u64,
        ctx: &StepCtx,
    ) -> CommStats {
        let n = xs.len();
        let cfg = self.cfg;
        let d = self.d;
        let quant = self.quant;
        let dynamic = self.dynamic;
        let seed = ctx.seed;
        if !self.initialized {
            for i in 0..n {
                self.xhat[i].copy_from_slice(&xs[i]);
            }
            self.initialized = true;
        }
        let k = round as f32;
        let ext = (k + 2.0) / 2.0; // extrapolation weight
        let eta = 2.0 / (k + 2.0); // estimate update weight
        // averaging with estimates + gradient
        {
            let w = &self.w;
            let xhat = &self.xhat;
            self.pool.for_each_mut(&mut self.x_new, |i, xn| {
                xn.fill(0.0);
                crate::linalg::axpy(xn, w.weight(i, i) as f32, &xhat[i]);
                for (j, wji) in w.in_edges(i) {
                    crate::linalg::axpy(xn, wji as f32, &xhat[j]);
                }
                crate::linalg::axpy(xn, -lr, &grads[i]);
            });
        }
        // extrapolate and quantize.
        // The extrapolated z grows like (k+2)/2·‖x‖ by construction, so the
        // fixed grid saturates after ~2·range/‖x‖ rounds — exactly how ECD
        // dies at fixed budgets (Table 2). Dynamic mode models the
        // charitable per-message-rescaled implementation instead.
        {
            let xs_r: &[Vec<f32>] = xs;
            let x_new = &self.x_new;
            self.pool.for_each_mut(&mut self.ws, |i, ws| {
                common::rounding_noise(&cfg, seed, round, i, d, &mut ws.noise);
                for kk in 0..d {
                    ws.z[kk] = (1.0 - ext) * xs_r[i][kk] + ext * x_new[i][kk];
                }
                if dynamic {
                    quant.quantize_dynamic_into(&ws.z, &ws.noise, &mut ws.codes, &mut ws.qz);
                } else {
                    quant.quantize_into(&ws.z, &ws.noise, &mut ws.codes, &mut ws.qz);
                }
            });
        }
        let bytes = common::wire_bytes(&cfg, &self.ws[0].codes)
            + if dynamic { 4 } else { 0 };
        // estimate update + adopt x_new
        {
            let ws = &self.ws;
            self.pool.for_each_mut(&mut self.xhat, |i, xh| {
                for kk in 0..d {
                    xh[kk] = (1.0 - eta) * xh[kk] + eta * ws[i].qz[kk];
                }
            });
        }
        {
            let x_new = &self.x_new;
            self.pool.for_each_mut(xs, |i, x| x.copy_from_slice(&x_new[i]));
        }
        let deg_sum = self.w.deg_sum();
        CommStats {
            bytes_per_msg: bytes,
            messages: deg_sum as u64,
            allreduce_bytes: None,
            // extrapolation + estimate update: two extra full-vector passes
            extra_local_passes: 2,
        }
    }

    fn node_send(
        &mut self,
        i: usize,
        x: &[f32],
        grad: &[f32],
        lr: f32,
        round: u64,
        ctx: &StepCtx,
        payload: &mut Vec<u8>,
    ) {
        let cfg = self.cfg;
        let quant = self.quant;
        let dynamic = self.dynamic;
        let d = self.d;
        if !self.initialized {
            for xh in self.xhat.iter_mut() {
                xh.copy_from_slice(x); // identical init (A4)
            }
            self.initialized = true;
        }
        let ext = (round as f32 + 2.0) / 2.0;
        {
            let Ecd { w, xhat, x_new, .. } = self;
            let xn = &mut x_new[i];
            xn.fill(0.0);
            crate::linalg::axpy(xn, w.weight(i, i) as f32, &xhat[i]);
            for (j, wji) in w.in_edges(i) {
                crate::linalg::axpy(xn, wji as f32, &xhat[j]);
            }
            crate::linalg::axpy(xn, -lr, grad);
        }
        let scale = {
            let Ecd { x_new, ws, .. } = self;
            let ws = &mut ws[i];
            common::rounding_noise(&cfg, ctx.seed, round, i, d, &mut ws.noise);
            for kk in 0..d {
                ws.z[kk] = (1.0 - ext) * x[kk] + ext * x_new[i][kk];
            }
            if dynamic {
                quant.quantize_dynamic_into(&ws.z, &ws.noise, &mut ws.codes, &mut ws.qz)
            } else {
                quant.quantize_into(&ws.z, &ws.noise, &mut ws.codes, &mut ws.qz);
                quant.range
            }
        };
        if dynamic {
            payload.extend_from_slice(&scale.to_bits().to_le_bytes());
        }
        let base = payload.len();
        payload.resize(base + packing::packed_len(d, cfg.bits), 0);
        packing::pack_into(&self.ws[i].codes, cfg.bits, &mut payload[base..]);
    }

    /// The extrapolated send state folds in `−α g` before quantizing, so
    /// the gradient gates the send half.
    fn send_phase(&self) -> SendPhase {
        SendPhase::PostGradient
    }

    fn node_recv(
        &mut self,
        i: usize,
        x: &mut [f32],
        _grad: &[f32],
        _lr: f32,
        round: u64,
        _ctx: &StepCtx,
        inbox: &Inbox,
    ) -> CommStats {
        let cfg = self.cfg;
        let quant = self.quant;
        let dynamic = self.dynamic;
        let d = self.d;
        let eta = 2.0 / (round as f32 + 2.0);
        let Ecd { w, ws, xhat, x_new, node_codes, node_vals, .. } = self;
        for k in 0..d {
            xhat[i][k] = (1.0 - eta) * xhat[i][k] + eta * ws[i].qz[k];
        }
        for &j in &w.neighbors[i] {
            common::decode_baseline_payload(
                &quant,
                dynamic,
                cfg.bits,
                inbox.payload(j),
                node_codes,
                node_vals,
            );
            for k in 0..d {
                xhat[j][k] = (1.0 - eta) * xhat[j][k] + eta * node_vals[k];
            }
        }
        x.copy_from_slice(&x_new[i]);
        let deg_sum = w.deg_sum();
        CommStats {
            bytes_per_msg: common::wire_bytes(&cfg, &ws[i].codes) + if dynamic { 4 } else { 0 },
            messages: deg_sum as u64,
            allreduce_bytes: None,
            extra_local_passes: 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    fn ctx(rho: f64) -> StepCtx {
        StepCtx { seed: 13, rho, g_inf: 1.0 }
    }

    fn quad_run(alg: &mut dyn SyncAlgorithm, steps: u64, lr: f32, rho: f64) -> f64 {
        let n = 4;
        let d = 8;
        let c = 0.3f32;
        let mut xs: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0; d]).collect();
        for k in 0..steps {
            let grads: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| x.iter().map(|&v| v - c).collect())
                .collect();
            alg.step(&mut xs, &grads, lr, k, &ctx(rho));
        }
        xs.iter()
            .map(|x| x.iter().map(|&v| ((v - c) as f64).powi(2)).sum::<f64>())
            .sum::<f64>()
            / n as f64
    }

    #[test]
    fn converges_at_8_bits() {
        let w = Topology::Ring(4).comm_matrix();
        let rho = w.rho();
        // range must cover the growing extrapolation for the horizon used
        let mut alg = Ecd::new(w, 8, QuantConfig::stochastic(8), 0.0);
        let loss = quad_run(&mut alg, 300, 0.1, rho);
        assert!(loss < 5e-2, "loss {loss}");
    }

    #[test]
    fn fails_at_low_bits() {
        let w = Topology::Ring(4).comm_matrix();
        let rho = w.rho();
        let mut alg = Ecd::new(w, 8, QuantConfig::stochastic(2), 16.0);
        let loss = quad_run(&mut alg, 300, 0.1, rho);
        assert!(loss > 0.05 || loss.is_nan(), "2-bit ECD should fail: {loss}");
    }

    #[test]
    fn two_extra_local_passes() {
        let w = Topology::Ring(4).comm_matrix();
        let mut alg = Ecd::new(w, 16, QuantConfig::stochastic(8), 8.0);
        let mut xs: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0; 16]).collect();
        let grads = xs.clone();
        let s = alg.step(&mut xs, &grads, 0.1, 0, &ctx(0.8));
        assert_eq!(s.extra_local_passes, 2);
    }
}
