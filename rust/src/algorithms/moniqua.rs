//! **Moniqua — Algorithm 1** of the paper, synchronous form.
//!
//! Per round k on every worker i (all ops elementwise over d params):
//!
//! ```text
//!  3:  q_i  = Q_δ( (x_i / B_θ) mod 1 )                      [send codes]
//!  4:  x̂_i = q_i·B_θ − (x_i mod B_θ) + x_i                  [local biased]
//!  5:  x̂_j = (q_j·B_θ − x_i) mod B_θ + x_i                  [recover]
//!  6:  x_i ← x_i + Σ_{j∈N_i} (x̂_j − x̂_i) W_ji              [average]
//!  7:  x_i ← x_i − α_k g̃_i                                  [gradient]
//! ```
//!
//! The only state is the model itself: **zero additional memory**, the
//! paper's headline systems property.
//!
//! ## Engine notes (see `rust/DESIGN.md` §Engine)
//!
//! The three phases fan out across the [`RoundPool`]: encode/local-biased
//! writes only sender-local scratch, recover/accumulate writes only
//! receiver-local scratch, apply writes only `xs[i]` — so every pool width
//! produces bitwise-identical results. The wire path is fused: line 3 goes
//! straight to packed bytes (`encode_packed_into`) and line 5 reads them
//! back (`recover_packed_into`); no `Vec<u32>` code vector exists per
//! round. When §6 verification is on, each sender's digest is computed
//! **once** in the encode phase (with that sender's own noise stream) and
//! reused at every receiving edge.

use super::engine::RoundPool;
use super::{
    common, CommStats, Inbox, MixPolicy, SendPhase, StepCtx, SyncAlgorithm, ThetaPolicy,
};
use crate::quant::{hash, packing, MoniquaCodec, QuantConfig};
use crate::topology::CommMatrix;

/// Sender-side per-worker scratch: written in the encode phase, read-only
/// in the recover phase.
struct SendScratch {
    noise: Vec<f32>,
    /// Packed wire bytes of this worker's round message (the fused line-3
    /// output — exactly what a real deployment puts on the network).
    wire: Vec<u8>,
    xhat_self: Vec<f32>,
    /// §6 digest of this sender's un-modded codes (valid iff verify_hash).
    digest: u64,
}

/// Receiver-side per-worker scratch: written in the recover phase.
struct RecvScratch {
    acc: Vec<f32>,
    recover: Vec<f32>,
    failures: u64,
    /// Median-mix only: one deviation row per in-neighbor (empty under
    /// mean/clipped — sized by [`MoniquaSync::set_mix`]).
    dev: Vec<Vec<f32>>,
    /// Median-mix only: per-coordinate sort buffer (capacity = degree).
    sortbuf: Vec<f32>,
}

pub struct MoniquaSync {
    w: CommMatrix,
    d: usize,
    theta: ThetaPolicy,
    cfg: QuantConfig,
    name: &'static str,
    /// False when `w` is a *derived* matrix (the Theorem-3 slack form):
    /// the engine cannot re-apply the transform to a raw swap-in, so
    /// topology swaps are refused.
    raw_matrix: bool,
    last_theta: f64,
    pool: RoundPool,
    send: Vec<SendScratch>,
    recv: Vec<RecvScratch>,
    /// Round-shared noise vector (shared-randomness mode): drawn once per
    /// round, read by every worker — avoids n redundant identical fills.
    shared_noise: Vec<f32>,
    /// Count of θ-verification failures observed (when cfg.verify_hash).
    pub verify_failures: u64,
    /// Neighbor-mix policy (mean is the paper's gossip average).
    mix: MixPolicy,
    /// Senders that failed the §6 digest in the last `node_recv`, drained
    /// by the round machine into strike accounting.
    strike_buf: Vec<u16>,
}

impl MoniquaSync {
    pub fn new(w: CommMatrix, d: usize, theta: ThetaPolicy, cfg: QuantConfig) -> Self {
        let mut s = Self::named(w, d, theta, cfg, "moniqua");
        s.raw_matrix = true; // `w` is the graph's own Metropolis matrix
        s
    }

    /// As `new` but with an explicit report name (the Theorem-3 slack-matrix
    /// variant reports as "moniqua-slack"). Engines built this way carry a
    /// *transformed* matrix and refuse [`SyncAlgorithm::swap_matrix`].
    pub fn named(
        w: CommMatrix,
        d: usize,
        theta: ThetaPolicy,
        cfg: QuantConfig,
        name: &'static str,
    ) -> Self {
        let n = w.n();
        let wire_len = packing::packed_len(d, cfg.bits);
        MoniquaSync {
            w,
            d,
            theta,
            cfg,
            name,
            raw_matrix: false,
            last_theta: 0.0,
            pool: RoundPool::for_dim(d),
            send: (0..n)
                .map(|_| SendScratch {
                    noise: Vec::new(),
                    wire: vec![0u8; wire_len],
                    xhat_self: vec![0.0; d],
                    digest: 0,
                })
                .collect(),
            recv: (0..n)
                .map(|_| RecvScratch {
                    acc: vec![0.0; d],
                    recover: vec![0.0; d],
                    failures: 0,
                    dev: Vec::new(),
                    sortbuf: Vec::new(),
                })
                .collect(),
            shared_noise: Vec::new(),
            verify_failures: 0,
            mix: MixPolicy::Mean,
            strike_buf: Vec::with_capacity(n),
        }
    }

    /// The codec for a given round (θ can be round-dependent).
    fn codec(&self, lr: f32, ctx: &StepCtx) -> MoniquaCodec {
        let theta = self.theta.theta(lr as f64, ctx.g_inf, self.w.n(), ctx.rho);
        MoniquaCodec::from_theta(theta as f32, &self.cfg)
    }

    /// (Re)size the median-mix scratch: one deviation row per in-neighbor
    /// of each receiver. Cold — called from `set_mix`/`swap_matrix` only.
    // lint: cold
    fn size_median_scratch(&mut self) {
        for i in 0..self.w.n() {
            let deg = self.w.in_edges(i).count();
            let rs = &mut self.recv[i];
            rs.dev = (0..deg).map(|_| vec![0.0; self.d]).collect();
            rs.sortbuf = Vec::with_capacity(deg.max(1));
        }
    }
}

/// Fold one neighbor's recovered model into the accumulator under the
/// active mix policy. `ok == false` (a §6 digest failure) contributes the
/// neutral element — the same thing the cluster defense layer's
/// self-substitution produces for machine-level rejects — so the lockstep
/// and node paths agree bitwise. The mean arm with `ok == true` is the
/// paper's weighted gossip sum, byte-for-byte the pre-defense loop.
// lint: hot-path
#[inline]
fn mix_neighbor(
    mix: MixPolicy,
    rs: &mut RecvScratch,
    xh: &[f32],
    wji: f32,
    ok: bool,
    d: usize,
    wsum: &mut f32,
    t: &mut usize,
) {
    match mix {
        MixPolicy::Mean => {
            if ok {
                for k in 0..d {
                    rs.acc[k] += wji * (rs.recover[k] - xh[k]);
                }
            }
        }
        MixPolicy::Clipped(tau) => {
            if ok {
                for k in 0..d {
                    rs.acc[k] += wji * (rs.recover[k] - xh[k]).clamp(-tau, tau);
                }
            }
        }
        MixPolicy::Median => {
            let row = &mut rs.dev[*t];
            if ok {
                for k in 0..d {
                    row[k] = rs.recover[k] - xh[k];
                }
            } else {
                row.fill(0.0);
            }
            *wsum += wji;
            *t += 1;
        }
    }
}

/// Median-mix epilogue: the coordinate-wise median of the neighbor
/// deviation rows, scaled by the total off-diagonal weight. `total_cmp`
/// ordering makes the sort (and therefore the result) a pure function of
/// the input bits, so every runtime computes the same median bitwise; an
/// even neighbor count takes the exact mean of the two middles.
// lint: hot-path
fn median_finalize(rs: &mut RecvScratch, wsum: f32, t: usize, d: usize) {
    for k in 0..d {
        rs.sortbuf.clear();
        for row in &rs.dev[..t] {
            rs.sortbuf.push(row[k]);
        }
        rs.sortbuf.sort_unstable_by(|a, b| a.total_cmp(b));
        let m = rs.sortbuf.len();
        let med = if m == 0 {
            0.0
        } else if m % 2 == 1 {
            rs.sortbuf[m / 2]
        } else {
            0.5 * (rs.sortbuf[m / 2 - 1] + rs.sortbuf[m / 2])
        };
        rs.acc[k] = wsum * med;
    }
}

impl SyncAlgorithm for MoniquaSync {
    fn name(&self) -> &'static str {
        self.name
    }

    fn last_theta(&self) -> Option<f64> {
        Some(self.last_theta)
    }

    fn set_threads(&mut self, threads: usize) {
        self.pool = RoundPool::new(threads);
    }

    fn swap_matrix(&mut self, w: &CommMatrix) -> bool {
        // A derived matrix (slack W̄ = γW + (1−γ)I) can't absorb a raw
        // swap-in: the engine doesn't know the transform to re-apply.
        if !self.raw_matrix {
            return false;
        }
        assert_eq!(w.n(), self.w.n(), "matrix swap changed worker count");
        self.w = w.clone();
        if matches!(self.mix, MixPolicy::Median) {
            self.size_median_scratch(); // degrees may have changed
        }
        true
    }

    fn set_mix(&mut self, mix: MixPolicy) -> bool {
        if let MixPolicy::Clipped(tau) = mix {
            if !(tau > 0.0) {
                return false;
            }
        }
        self.mix = mix;
        if matches!(mix, MixPolicy::Median) {
            self.size_median_scratch();
        }
        true
    }

    fn drain_strikes(&mut self, out: &mut Vec<u16>) {
        out.append(&mut self.strike_buf);
    }

    // Moniqua's headline property — zero extra memory — means the only
    // cross-round state is diagnostics: last θ and the §6 failure counter.
    fn snapshot(&self, out: &mut Vec<u8>) {
        crate::elastic::snapshot::put_f64(out, self.last_theta);
        crate::elastic::snapshot::put_u64(out, self.verify_failures);
    }

    fn restore(&mut self, bytes: &[u8]) -> Result<(), crate::elastic::SnapshotError> {
        let mut r = crate::elastic::snapshot::Reader::new(bytes);
        self.last_theta = r.take_f64()?;
        self.verify_failures = r.take_u64()?;
        r.finish()
    }

    fn step(
        &mut self,
        xs: &mut [Vec<f32>],
        grads: &[Vec<f32>],
        lr: f32,
        round: u64,
        ctx: &StepCtx,
    ) -> CommStats {
        let n = xs.len();
        debug_assert_eq!(n, self.send.len());
        let codec = self.codec(lr, ctx);
        self.last_theta = codec.b_theta as f64 * (1.0 - 2.0 * codec.quant.delta()) / 2.0;
        let cfg = self.cfg;
        let d = self.d;
        let seed = ctx.seed;

        // --- phase 1: encode (line 3, fused to packed bytes) + local
        // biased term (line 4) + the once-per-sender §6 digest. With shared
        // randomness the per-round noise is drawn once here and read by
        // every worker (the streams coincide by construction — drawing it
        // per worker would be n identical fills); private-noise mode draws
        // each worker's own (seed, round, worker) stream inside the phase.
        let use_shared = cfg.shared_randomness;
        if use_shared {
            common::rounding_noise(&cfg, seed, round, 0, d, &mut self.shared_noise);
        }
        {
            let xs_r: &[Vec<f32>] = xs;
            let shared_noise = &self.shared_noise;
            self.pool.for_each_mut(&mut self.send, |i, ws| {
                let noise =
                    common::phase_noise(&cfg, seed, round, i, d, shared_noise, &mut ws.noise);
                codec.encode_packed_into(&xs_r[i], noise, &mut ws.wire);
                codec.local_biased_into(&xs_r[i], noise, &mut ws.xhat_self);
                if cfg.verify_hash {
                    ws.digest = hash::sender_digest(&codec, &xs_r[i], noise);
                }
            });
        }
        let bytes_per_msg = common::wire_bytes_packed(&cfg, d, &self.send[0].wire);

        // --- phase 2 (lines 5-6): each receiver recovers its neighbors
        // straight from their wire bytes and accumulates the weighted
        // differences, in neighbor order (deterministic summation). A §6
        // digest failure *excludes* that neighbor's term (the defense
        // layer's verify-then-skip): a θ-escaped decode is garbage, so
        // integrating it would hand one Byzantine frame a whole round.
        {
            let send = &self.send;
            let w = &self.w;
            let mix = self.mix;
            let xs_r: &[Vec<f32>] = xs;
            self.pool.for_each_mut(&mut self.recv, |i, rs| {
                rs.failures = 0;
                rs.acc.fill(0.0);
                let mut wsum = 0.0f32;
                let mut t = 0usize;
                for (j, wji) in w.in_edges(i) {
                    let wji = wji as f32;
                    codec.recover_packed_into(&send[j].wire, &xs_r[i], &mut rs.recover);
                    let ok = !cfg.verify_hash
                        || hash::verify_reconstruction(&codec, &rs.recover, send[j].digest);
                    if !ok {
                        rs.failures += 1;
                    }
                    mix_neighbor(mix, rs, &send[i].xhat_self, wji, ok, d, &mut wsum, &mut t);
                }
                if let MixPolicy::Median = mix {
                    median_finalize(rs, wsum, t, d);
                }
            });
        }
        if cfg.verify_hash {
            self.verify_failures += self.recv.iter().map(|r| r.failures).sum::<u64>();
        }

        // --- phase 3: apply averaging + line 7 gradient step.
        {
            let recv = &self.recv;
            self.pool.for_each_mut(xs, |i, x| {
                let acc = &recv[i].acc;
                let g = &grads[i];
                for k in 0..d {
                    x[k] += acc[k] - lr * g[k];
                }
            });
        }

        let deg_sum = self.w.deg_sum();
        CommStats {
            bytes_per_msg,
            messages: deg_sum as u64,
            allreduce_bytes: None,
            extra_local_passes: 0,
        }
    }

    // lint: hot-path
    fn node_send(
        &mut self,
        i: usize,
        x: &[f32],
        _grad: &[f32],
        lr: f32,
        round: u64,
        ctx: &StepCtx,
        payload: &mut Vec<u8>,
    ) {
        // Same per-worker work as step's encode phase, pinned to worker i.
        let codec = self.codec(lr, ctx);
        self.last_theta = codec.b_theta as f64 * (1.0 - 2.0 * codec.quant.delta()) / 2.0;
        let cfg = self.cfg;
        let d = self.d;
        let seed = ctx.seed;
        if cfg.shared_randomness {
            common::rounding_noise(&cfg, seed, round, 0, d, &mut self.shared_noise);
        }
        let MoniquaSync { send, shared_noise, pool, .. } = self;
        let ws = &mut send[i];
        let noise = common::phase_noise(&cfg, seed, round, i, d, shared_noise, &mut ws.noise);
        // Chunked across this node's pool when one is configured; width-1
        // pools (the cluster default) take the plain fused kernel inline.
        pool.encode_packed(&codec, x, noise, &mut ws.wire);
        codec.local_biased_into(x, noise, &mut ws.xhat_self);
        payload.extend_from_slice(&ws.wire);
        if cfg.verify_hash {
            // The §6 digest travels appended to the payload — exactly the
            // +8 bytes `wire_bytes_packed` has always accounted for.
            ws.digest = hash::sender_digest(&codec, x, noise);
            payload.extend_from_slice(&ws.digest.to_le_bytes());
        }
    }

    // lint: hot-path
    /// The modulo-encoded payload is a pure function of `(x, lr, round,
    /// seed)`: the gradient only enters in the recv half's
    /// `x ← mix − α g` update, and `ctx.g_inf` only feeds the Theorem-2 θ
    /// policy, which the cluster runtime refuses at construction (the
    /// Constant policy — the only one that reaches this path — ignores
    /// it). The frame can therefore stream under the gradient compute.
    fn send_phase(&self) -> SendPhase {
        SendPhase::PreGradient
    }

    fn node_recv(
        &mut self,
        i: usize,
        x: &mut [f32],
        grad: &[f32],
        lr: f32,
        _round: u64,
        ctx: &StepCtx,
        inbox: &Inbox,
    ) -> CommStats {
        let codec = self.codec(lr, ctx);
        let cfg = self.cfg;
        let d = self.d;
        let mix = self.mix;
        let wire_len = packing::packed_len(d, cfg.bits);
        let MoniquaSync { w, send, recv, verify_failures, pool, strike_buf, .. } = self;
        let rs = &mut recv[i];
        rs.failures = 0;
        rs.acc.fill(0.0);
        let mut wsum = 0.0f32;
        let mut t = 0usize;
        for (j, wji) in w.in_edges(i) {
            let payload = inbox.payload(j);
            let (wire, digest) = if cfg.verify_hash {
                let (wb, db) = payload.split_at(wire_len);
                (wb, u64::from_le_bytes(db.try_into().expect("8-byte digest tail")))
            } else {
                (payload, 0u64)
            };
            let wji = wji as f32;
            pool.recover_packed(&codec, wire, x, &mut rs.recover);
            let ok =
                !cfg.verify_hash || hash::verify_reconstruction(&codec, &rs.recover, digest);
            if !ok {
                // Verify-then-skip (the term is excluded by mix_neighbor),
                // and feed the sender to the machine's strike accounting.
                rs.failures += 1;
                strike_buf.push(j as u16);
            }
            mix_neighbor(mix, rs, &send[i].xhat_self, wji, ok, d, &mut wsum, &mut t);
        }
        if let MixPolicy::Median = mix {
            median_finalize(rs, wsum, t, d);
        }
        *verify_failures += rs.failures;
        for k in 0..d {
            x[k] += rs.acc[k] - lr * grad[k];
        }
        let deg_sum = w.deg_sum();
        CommStats {
            bytes_per_msg: common::wire_bytes_packed(&cfg, d, &send[i].wire),
            messages: deg_sum as u64,
            allreduce_bytes: None,
            extra_local_passes: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    fn ctx(rho: f64) -> StepCtx {
        StepCtx { seed: 11, rho, g_inf: 1.0 }
    }

    fn run_consensus(bits: u32, theta: f32, rounds: u64) -> Vec<Vec<f32>> {
        let w = Topology::Ring(6).comm_matrix();
        let rho = w.rho();
        let d = 32;
        let mut alg = MoniquaSync::new(
            w,
            d,
            ThetaPolicy::Constant(theta),
            QuantConfig::stochastic(bits),
        );
        // initial spread well inside θ
        let mut xs: Vec<Vec<f32>> = (0..6)
            .map(|i| vec![0.1 * i as f32; d])
            .collect();
        let grads: Vec<Vec<f32>> = (0..6).map(|_| vec![0.0; d]).collect();
        for k in 0..rounds {
            alg.step(&mut xs, &grads, 0.0, k, &ctx(rho));
        }
        xs
    }

    #[test]
    fn drives_consensus_within_quant_error() {
        let xs = run_consensus(8, 2.0, 150);
        let spread = xs
            .iter()
            .map(|x| x[0])
            .fold((f32::MAX, f32::MIN), |(lo, hi), v| (lo.min(v), hi.max(v)));
        // consensus to within a few quantization errors (δ·B ≈ 0.016)
        assert!(spread.1 - spread.0 < 0.1, "spread {spread:?}");
    }

    #[test]
    fn mean_drift_is_bounded_by_quant_noise() {
        // Unlike D-PSGD the average can drift by quantization noise, but it
        // must stay small (the local biased term cancels most of it).
        let xs = run_consensus(8, 2.0, 150);
        let mean: f32 = xs.iter().map(|x| x[0]).sum::<f32>() / 6.0;
        assert!((mean - 0.25).abs() < 0.1, "mean {mean}"); // init mean 0.25
    }

    #[test]
    fn optimizes_quadratic_like_full_precision() {
        // End-to-end sanity at engine level: minimize ½‖x−c‖² decentralized.
        let w = Topology::Ring(4).comm_matrix();
        let rho = w.rho();
        let d = 16;
        let c = 0.3f32;
        let mut alg = MoniquaSync::new(
            w,
            d,
            ThetaPolicy::Constant(1.0),
            QuantConfig::stochastic(8),
        );
        let mut xs: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0; d]).collect();
        for k in 0..300 {
            let grads: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| x.iter().map(|&v| v - c).collect())
                .collect();
            alg.step(&mut xs, &grads, 0.1, k, &ctx(rho));
        }
        for x in &xs {
            for &v in x.iter() {
                assert!((v - c).abs() < 0.02, "v {v}");
            }
        }
    }

    #[test]
    fn two_bit_budget_still_converges() {
        let w = Topology::Ring(4).comm_matrix();
        let rho = w.rho();
        let d = 8;
        let mut alg = MoniquaSync::new(
            w,
            d,
            ThetaPolicy::Constant(1.0),
            QuantConfig::stochastic(2),
        );
        let mut xs: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0; d]).collect();
        for k in 0..500 {
            let grads: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| x.iter().map(|&v| v - 0.3).collect())
                .collect();
            alg.step(&mut xs, &grads, 0.05, k, &ctx(rho));
        }
        let loss: f64 = xs[0].iter().map(|&v| ((v - 0.3) as f64).powi(2)).sum();
        assert!(loss < 0.05, "loss {loss}");
    }

    #[test]
    fn wire_traffic_is_bits_per_param() {
        let w = Topology::Ring(4).comm_matrix();
        let mut alg = MoniquaSync::new(
            w,
            1000,
            ThetaPolicy::Constant(2.0),
            QuantConfig::stochastic(4),
        );
        let mut xs: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0; 1000]).collect();
        let grads = xs.clone();
        let stats = alg.step(&mut xs, &grads, 0.1, 0, &ctx(0.8));
        assert_eq!(stats.bytes_per_msg, 500); // 4 bits * 1000 / 8
        assert!(alg.last_theta().is_some());
    }

    #[test]
    fn verification_clean_when_theta_holds() {
        let w = Topology::Ring(4).comm_matrix();
        let rho = w.rho();
        let mut alg = MoniquaSync::new(
            w,
            16,
            ThetaPolicy::Constant(2.0),
            QuantConfig::stochastic(8).with_verify_hash(true),
        );
        let mut xs: Vec<Vec<f32>> = (0..4).map(|i| vec![0.01 * i as f32; 16]).collect();
        let grads: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0; 16]).collect();
        for k in 0..20 {
            alg.step(&mut xs, &grads, 0.0, k, &ctx(rho));
        }
        assert_eq!(alg.verify_failures, 0);
    }

    #[test]
    fn verification_fires_when_theta_violated() {
        let w = Topology::Ring(4).comm_matrix();
        let rho = w.rho();
        let mut alg = MoniquaSync::new(
            w,
            16,
            ThetaPolicy::Constant(0.05), // far too small for the spread
            QuantConfig::nearest(8).with_verify_hash(true),
        );
        let mut xs: Vec<Vec<f32>> = (0..4).map(|i| vec![1.0 * i as f32; 16]).collect();
        let grads: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0; 16]).collect();
        alg.step(&mut xs, &grads, 0.0, 0, &ctx(rho));
        assert!(alg.verify_failures > 0);
    }
}
