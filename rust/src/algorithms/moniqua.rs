//! **Moniqua — Algorithm 1** of the paper, synchronous form.
//!
//! Per round k on every worker i (all ops elementwise over d params):
//!
//! ```text
//!  3:  q_i  = Q_δ( (x_i / B_θ) mod 1 )                      [send codes]
//!  4:  x̂_i = q_i·B_θ − (x_i mod B_θ) + x_i                  [local biased]
//!  5:  x̂_j = (q_j·B_θ − x_i) mod B_θ + x_i                  [recover]
//!  6:  x_i ← x_i + Σ_{j∈N_i} (x̂_j − x̂_i) W_ji              [average]
//!  7:  x_i ← x_i − α_k g̃_i                                  [gradient]
//! ```
//!
//! The only state is the model itself: **zero additional memory**, the
//! paper's headline systems property.

use super::{common, CommStats, StepCtx, SyncAlgorithm, ThetaPolicy};
use crate::quant::{MoniquaCodec, QuantConfig};
use crate::topology::CommMatrix;

pub struct MoniquaSync {
    w: CommMatrix,
    d: usize,
    theta: ThetaPolicy,
    cfg: QuantConfig,
    name: &'static str,
    last_theta: f64,
    /// Scratch: per-worker code vectors + reconstruction buffers. These are
    /// engine-local workspaces (reused every round), not algorithm state.
    codes: Vec<Vec<u32>>,
    xhat_self: Vec<Vec<f32>>,
    delta_acc: Vec<Vec<f32>>,
    recover_buf: Vec<f32>,
    noise: Vec<f32>,
    /// Count of θ-verification failures observed (when cfg.verify_hash).
    pub verify_failures: u64,
}

impl MoniquaSync {
    pub fn new(w: CommMatrix, d: usize, theta: ThetaPolicy, cfg: QuantConfig) -> Self {
        Self::named(w, d, theta, cfg, "moniqua")
    }

    /// As `new` but with an explicit report name (the Theorem-3 slack-matrix
    /// variant reports as "moniqua-slack").
    pub fn named(
        w: CommMatrix,
        d: usize,
        theta: ThetaPolicy,
        cfg: QuantConfig,
        name: &'static str,
    ) -> Self {
        let n = w.n();
        MoniquaSync {
            w,
            d,
            theta,
            cfg,
            name,
            last_theta: 0.0,
            codes: vec![vec![0; d]; n],
            xhat_self: vec![vec![0.0; d]; n],
            delta_acc: vec![vec![0.0; d]; n],
            recover_buf: vec![0.0; d],
            noise: Vec::new(),
            verify_failures: 0,
        }
    }

    /// The codec for a given round (θ can be round-dependent).
    fn codec(&self, lr: f32, ctx: &StepCtx) -> MoniquaCodec {
        let theta = self.theta.theta(lr as f64, ctx.g_inf, self.w.n(), ctx.rho);
        MoniquaCodec::from_theta(theta as f32, &self.cfg)
    }
}

impl SyncAlgorithm for MoniquaSync {
    fn name(&self) -> &'static str {
        self.name
    }

    fn last_theta(&self) -> Option<f64> {
        Some(self.last_theta)
    }

    fn step(
        &mut self,
        xs: &mut [Vec<f32>],
        grads: &[Vec<f32>],
        lr: f32,
        round: u64,
        ctx: &StepCtx,
    ) -> CommStats {
        let n = xs.len();
        let codec = self.codec(lr, ctx);
        self.last_theta = codec.b_theta as f64 * (1.0 - 2.0 * codec.quant.delta()) / 2.0;

        // Shared-randomness: one noise vector per round, identical on all
        // workers (drawn once here; in a real deployment each worker
        // regenerates it from the shared seed).
        common::rounding_noise(&self.cfg, ctx.seed, round, 0, self.d, &mut self.noise);

        let mut bytes_per_msg = 0usize;
        for i in 0..n {
            if !self.cfg.shared_randomness {
                common::rounding_noise(&self.cfg, ctx.seed, round, i, self.d, &mut self.noise);
            }
            // line 3: encode
            codec.encode_into(&xs[i], &self.noise, &mut self.codes[i]);
            // line 4: local biased term
            codec.local_biased_into(&xs[i], &self.noise, &mut self.xhat_self[i]);
            if i == 0 {
                bytes_per_msg = common::wire_bytes(&self.cfg, &self.codes[i]);
            }
        }

        // lines 5-6: recover neighbors, accumulate weighted differences.
        let mut verify_failures = 0u64;
        for i in 0..n {
            let acc = &mut self.delta_acc[i];
            acc.fill(0.0);
            for &j in &self.w.neighbors[i] {
                let wji = self.w.weight(j, i) as f32;
                codec.recover_into(&self.codes[j], &xs[i], &mut self.recover_buf);
                if self.cfg.verify_hash {
                    // §6 verification: sender j's digest vs our reconstruction.
                    let noise = &self.noise;
                    let digest = crate::quant::hash::fnv1a_abs_codes(
                        &crate::quant::hash::sender_abs_codes(&codec, &xs[j], noise),
                    );
                    if !crate::quant::hash::verify_reconstruction(
                        &codec,
                        &self.recover_buf,
                        digest,
                    ) {
                        verify_failures += 1;
                    }
                }
                for k in 0..self.d {
                    acc[k] += wji * (self.recover_buf[k] - self.xhat_self[i][k]);
                }
            }
        }
        self.verify_failures += verify_failures;

        // apply averaging + line 7 gradient step
        for i in 0..n {
            let x = &mut xs[i];
            let acc = &self.delta_acc[i];
            let g = &grads[i];
            for k in 0..self.d {
                x[k] += acc[k] - lr * g[k];
            }
        }

        let deg_sum: usize = self.w.neighbors.iter().map(|v| v.len()).sum();
        CommStats {
            bytes_per_msg,
            messages: deg_sum as u64,
            allreduce_bytes: None,
            extra_local_passes: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    fn ctx(rho: f64) -> StepCtx {
        StepCtx { seed: 11, rho, g_inf: 1.0 }
    }

    fn run_consensus(bits: u32, theta: f32, rounds: u64) -> Vec<Vec<f32>> {
        let w = Topology::Ring(6).comm_matrix();
        let rho = w.rho();
        let d = 32;
        let mut alg = MoniquaSync::new(
            w,
            d,
            ThetaPolicy::Constant(theta),
            QuantConfig::stochastic(bits),
        );
        // initial spread well inside θ
        let mut xs: Vec<Vec<f32>> = (0..6)
            .map(|i| vec![0.1 * i as f32; d])
            .collect();
        let grads: Vec<Vec<f32>> = (0..6).map(|_| vec![0.0; d]).collect();
        for k in 0..rounds {
            alg.step(&mut xs, &grads, 0.0, k, &ctx(rho));
        }
        xs
    }

    #[test]
    fn drives_consensus_within_quant_error() {
        let xs = run_consensus(8, 2.0, 150);
        let spread = xs
            .iter()
            .map(|x| x[0])
            .fold((f32::MAX, f32::MIN), |(lo, hi), v| (lo.min(v), hi.max(v)));
        // consensus to within a few quantization errors (δ·B ≈ 0.016)
        assert!(spread.1 - spread.0 < 0.1, "spread {spread:?}");
    }

    #[test]
    fn mean_drift_is_bounded_by_quant_noise() {
        // Unlike D-PSGD the average can drift by quantization noise, but it
        // must stay small (the local biased term cancels most of it).
        let xs = run_consensus(8, 2.0, 150);
        let mean: f32 = xs.iter().map(|x| x[0]).sum::<f32>() / 6.0;
        assert!((mean - 0.25).abs() < 0.1, "mean {mean}"); // init mean 0.25
    }

    #[test]
    fn optimizes_quadratic_like_full_precision() {
        // End-to-end sanity at engine level: minimize ½‖x−c‖² decentralized.
        let w = Topology::Ring(4).comm_matrix();
        let rho = w.rho();
        let d = 16;
        let c = 0.3f32;
        let mut alg = MoniquaSync::new(
            w,
            d,
            ThetaPolicy::Constant(1.0),
            QuantConfig::stochastic(8),
        );
        let mut xs: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0; d]).collect();
        for k in 0..300 {
            let grads: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| x.iter().map(|&v| v - c).collect())
                .collect();
            alg.step(&mut xs, &grads, 0.1, k, &ctx(rho));
        }
        for x in &xs {
            for &v in x.iter() {
                assert!((v - c).abs() < 0.02, "v {v}");
            }
        }
    }

    #[test]
    fn two_bit_budget_still_converges() {
        let w = Topology::Ring(4).comm_matrix();
        let rho = w.rho();
        let d = 8;
        let mut alg = MoniquaSync::new(
            w,
            d,
            ThetaPolicy::Constant(1.0),
            QuantConfig::stochastic(2),
        );
        let mut xs: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0; d]).collect();
        for k in 0..500 {
            let grads: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| x.iter().map(|&v| v - 0.3).collect())
                .collect();
            alg.step(&mut xs, &grads, 0.05, k, &ctx(rho));
        }
        let loss: f64 = xs[0].iter().map(|&v| ((v - 0.3) as f64).powi(2)).sum();
        assert!(loss < 0.05, "loss {loss}");
    }

    #[test]
    fn wire_traffic_is_bits_per_param() {
        let w = Topology::Ring(4).comm_matrix();
        let mut alg = MoniquaSync::new(
            w,
            1000,
            ThetaPolicy::Constant(2.0),
            QuantConfig::stochastic(4),
        );
        let mut xs: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0; 1000]).collect();
        let grads = xs.clone();
        let stats = alg.step(&mut xs, &grads, 0.1, 0, &ctx(0.8));
        assert_eq!(stats.bytes_per_msg, 500); // 4 bits * 1000 / 8
        assert!(alg.last_theta().is_some());
    }

    #[test]
    fn verification_clean_when_theta_holds() {
        let w = Topology::Ring(4).comm_matrix();
        let rho = w.rho();
        let mut alg = MoniquaSync::new(
            w,
            16,
            ThetaPolicy::Constant(2.0),
            QuantConfig::stochastic(8).with_verify_hash(true),
        );
        let mut xs: Vec<Vec<f32>> = (0..4).map(|i| vec![0.01 * i as f32; 16]).collect();
        let grads: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0; 16]).collect();
        for k in 0..20 {
            alg.step(&mut xs, &grads, 0.0, k, &ctx(rho));
        }
        assert_eq!(alg.verify_failures, 0);
    }

    #[test]
    fn verification_fires_when_theta_violated() {
        let w = Topology::Ring(4).comm_matrix();
        let rho = w.rho();
        let mut alg = MoniquaSync::new(
            w,
            16,
            ThetaPolicy::Constant(0.05), // far too small for the spread
            QuantConfig::nearest(8).with_verify_hash(true),
        );
        let mut xs: Vec<Vec<f32>> = (0..4).map(|i| vec![1.0 * i as f32; 16]).collect();
        let grads: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0; 16]).collect();
        alg.step(&mut xs, &grads, 0.0, 0, &ctx(rho));
        assert!(alg.verify_failures > 0);
    }
}
