//! D-PSGD (Lian et al. 2017) with full-precision communication — the
//! baseline every quantized variant is measured against:
//!
//! ```text
//!     x_{k+1,i} = Σ_j W_ji x_{k,j} − α_k g̃_{k,i}
//! ```

use super::engine::RoundPool;
use super::{common, CommStats, Inbox, SendPhase, StepCtx, SyncAlgorithm};
use crate::topology::CommMatrix;

pub struct DPsgd {
    w: CommMatrix,
    d: usize,
    pool: RoundPool,
    scratch: Vec<Vec<f32>>,
    /// Node-mode decode buffer for one neighbor's f32 payload.
    decode: Vec<f32>,
}

impl DPsgd {
    pub fn new(w: CommMatrix, d: usize) -> Self {
        let n = w.n();
        DPsgd {
            w,
            d,
            pool: RoundPool::for_dim(d),
            scratch: vec![vec![0.0; d]; n],
            decode: vec![0.0; d],
        }
    }
}

impl SyncAlgorithm for DPsgd {
    fn name(&self) -> &'static str {
        "dpsgd"
    }

    fn set_threads(&mut self, threads: usize) {
        self.pool = RoundPool::new(threads);
    }

    fn swap_matrix(&mut self, w: &CommMatrix) -> bool {
        assert_eq!(w.n(), self.w.n(), "matrix swap changed worker count");
        self.w = w.clone();
        true
    }

    fn step(
        &mut self,
        xs: &mut [Vec<f32>],
        grads: &[Vec<f32>],
        lr: f32,
        _round: u64,
        _ctx: &StepCtx,
    ) -> CommStats {
        // x_{k+1,i} = Σ_j W_ji x_j − α g_i  (exact neighbor models on the wire)
        {
            let w = &self.w;
            let xs_r: &[Vec<f32>] = xs;
            self.pool.for_each_mut(&mut self.scratch, |i, out| {
                out.fill(0.0);
                crate::linalg::axpy(out, w.weight(i, i) as f32, &xs_r[i]);
                for (j, wji) in w.in_edges(i) {
                    crate::linalg::axpy(out, wji as f32, &xs_r[j]);
                }
                crate::linalg::axpy(out, -lr, &grads[i]);
            });
        }
        {
            let scratch = &self.scratch;
            self.pool.for_each_mut(xs, |i, x| x.copy_from_slice(&scratch[i]));
        }
        let deg_sum = self.w.deg_sum();
        CommStats {
            bytes_per_msg: self.d * 4, // full f32 model
            messages: deg_sum as u64,
            allreduce_bytes: None,
            extra_local_passes: 0,
        }
    }

    fn node_send(
        &mut self,
        _i: usize,
        x: &[f32],
        _grad: &[f32],
        _lr: f32,
        _round: u64,
        _ctx: &StepCtx,
        payload: &mut Vec<u8>,
    ) {
        // Exact neighbor models on the wire: the payload is the raw model.
        common::put_f32s(payload, x);
    }

    /// The payload is the raw model — `node_send` never touches the
    /// gradient (the `x − α g` update happens in the recv half), so the
    /// frame can stream on the wire while `loss_grad` runs.
    fn send_phase(&self) -> SendPhase {
        SendPhase::PreGradient
    }

    fn node_recv(
        &mut self,
        i: usize,
        x: &mut [f32],
        grad: &[f32],
        lr: f32,
        _round: u64,
        _ctx: &StepCtx,
        inbox: &Inbox,
    ) -> CommStats {
        let DPsgd { w, scratch, decode, .. } = self;
        let out = &mut scratch[i];
        out.fill(0.0);
        crate::linalg::axpy(out, w.weight(i, i) as f32, x);
        for (j, wji) in w.in_edges(i) {
            common::read_f32s_into(inbox.payload(j), decode);
            crate::linalg::axpy(out, wji as f32, decode);
        }
        crate::linalg::axpy(out, -lr, grad);
        x.copy_from_slice(out);
        let deg_sum = w.deg_sum();
        CommStats {
            bytes_per_msg: self.d * 4,
            messages: deg_sum as u64,
            allreduce_bytes: None,
            extra_local_passes: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    #[test]
    fn preserves_average_modulo_gradient() {
        // W doubly stochastic: the mean of xs after averaging equals the
        // mean before, minus lr * mean gradient.
        let w = Topology::Ring(5).comm_matrix();
        let d = 8;
        let mut alg = DPsgd::new(w, d);
        let mut xs: Vec<Vec<f32>> =
            (0..5).map(|i| vec![i as f32; d]).collect();
        let grads: Vec<Vec<f32>> = (0..5).map(|_| vec![0.5; d]).collect();
        let mean_before: f32 = xs.iter().map(|x| x[0]).sum::<f32>() / 5.0;
        let ctx = StepCtx { seed: 0, rho: 0.8, g_inf: 1.0 };
        let stats = alg.step(&mut xs, &grads, 0.1, 0, &ctx);
        let mean_after: f32 = xs.iter().map(|x| x[0]).sum::<f32>() / 5.0;
        assert!((mean_after - (mean_before - 0.05)).abs() < 1e-5);
        assert_eq!(stats.bytes_per_msg, d * 4);
        assert_eq!(stats.messages, 10);
    }

    #[test]
    fn reaches_consensus_without_gradients() {
        let w = Topology::Ring(6).comm_matrix();
        let d = 4;
        let mut alg = DPsgd::new(w, d);
        let mut xs: Vec<Vec<f32>> = (0..6).map(|i| vec![i as f32; d]).collect();
        let grads: Vec<Vec<f32>> = (0..6).map(|_| vec![0.0; d]).collect();
        let ctx = StepCtx { seed: 0, rho: 0.8, g_inf: 0.0 };
        for k in 0..200 {
            alg.step(&mut xs, &grads, 0.0, k, &ctx);
        }
        let spread = xs
            .iter()
            .map(|x| x[0])
            .fold((f32::MAX, f32::MIN), |(lo, hi), v| (lo.min(v), hi.max(v)));
        assert!(spread.1 - spread.0 < 1e-4, "spread {spread:?}");
        // consensus value = initial mean = 2.5
        assert!((xs[0][0] - 2.5).abs() < 1e-4);
    }
}
