//! D-PSGD (Lian et al. 2017) with full-precision communication — the
//! baseline every quantized variant is measured against:
//!
//! ```text
//!     x_{k+1,i} = Σ_j W_ji x_{k,j} − α_k g̃_{k,i}
//! ```

use super::engine::RoundPool;
use super::{common, CommStats, Inbox, MixPolicy, SendPhase, StepCtx, SyncAlgorithm};
use crate::topology::CommMatrix;

pub struct DPsgd {
    w: CommMatrix,
    d: usize,
    pool: RoundPool,
    scratch: Vec<Vec<f32>>,
    /// Node-mode decode buffer for one neighbor's f32 payload.
    decode: Vec<f32>,
    /// When set, the round machine appends an 8-byte seal to every frame;
    /// the engine's only obligation is to price it into `bytes_per_msg`.
    verify_wire: bool,
    mix: MixPolicy,
    /// Median-mix only: staged neighbor deviations (max in-degree rows).
    dev: Vec<Vec<f32>>,
    /// Median-mix only: per-coordinate sort buffer.
    sortbuf: Vec<f32>,
}

impl DPsgd {
    pub fn new(w: CommMatrix, d: usize) -> Self {
        let n = w.n();
        DPsgd {
            w,
            d,
            pool: RoundPool::for_dim(d),
            scratch: vec![vec![0.0; d]; n],
            decode: vec![0.0; d],
            verify_wire: false,
            mix: MixPolicy::Mean,
            dev: Vec::new(),
            sortbuf: Vec::new(),
        }
    }

    // lint: cold
    fn size_median_scratch(&mut self) {
        let n = self.w.n();
        let deg = (0..n).map(|i| self.w.in_edges(i).count()).max().unwrap_or(0);
        self.dev = (0..deg).map(|_| vec![0.0; self.d]).collect();
        self.sortbuf = Vec::with_capacity(deg.max(1));
    }

    fn wire_overhead(&self) -> usize {
        if self.verify_wire { crate::adversary::SEAL_LEN } else { 0 }
    }
}

/// Coordinate-wise median of the first `t` staged deviation rows, scaled
/// by the total off-diagonal weight `wsum`, written into `out` as
/// `out[k] = base[k] + wsum·median_k − lr·grad[k]`. Deterministic: the
/// rows are sorted with `total_cmp` (a pure function of the f32 bits) and
/// the even-count midpoint uses an exact ×0.5.
// lint: hot-path
fn median_apply(
    dev: &[Vec<f32>],
    sortbuf: &mut Vec<f32>,
    t: usize,
    wsum: f32,
    base: &[f32],
    grad: &[f32],
    lr: f32,
    out: &mut [f32],
) {
    for k in 0..base.len() {
        sortbuf.clear();
        for row in &dev[..t] {
            sortbuf.push(row[k]);
        }
        sortbuf.sort_unstable_by(|a, b| a.total_cmp(b));
        let m = sortbuf.len();
        let med = if m == 0 {
            0.0
        } else if m % 2 == 1 {
            sortbuf[m / 2]
        } else {
            0.5 * (sortbuf[m / 2 - 1] + sortbuf[m / 2])
        };
        out[k] = base[k] + wsum * med - lr * grad[k];
    }
}

impl SyncAlgorithm for DPsgd {
    fn name(&self) -> &'static str {
        "dpsgd"
    }

    fn set_threads(&mut self, threads: usize) {
        self.pool = RoundPool::new(threads);
    }

    fn swap_matrix(&mut self, w: &CommMatrix) -> bool {
        assert_eq!(w.n(), self.w.n(), "matrix swap changed worker count");
        self.w = w.clone();
        if matches!(self.mix, MixPolicy::Median) {
            self.size_median_scratch();
        }
        true
    }

    fn step(
        &mut self,
        xs: &mut [Vec<f32>],
        grads: &[Vec<f32>],
        lr: f32,
        _round: u64,
        _ctx: &StepCtx,
    ) -> CommStats {
        // x_{k+1,i} = Σ_j W_ji x_j − α g_i  (exact neighbor models on the wire)
        let d = self.d;
        match self.mix {
            MixPolicy::Mean => {
                let w = &self.w;
                let xs_r: &[Vec<f32>] = xs;
                self.pool.for_each_mut(&mut self.scratch, |i, out| {
                    out.fill(0.0);
                    crate::linalg::axpy(out, w.weight(i, i) as f32, &xs_r[i]);
                    for (j, wji) in w.in_edges(i) {
                        crate::linalg::axpy(out, wji as f32, &xs_r[j]);
                    }
                    crate::linalg::axpy(out, -lr, &grads[i]);
                });
            }
            MixPolicy::Clipped(tau) => {
                // Deviation form x_i + Σ_j W_ji clamp(x_j − x_i, ±τ) − α g_i:
                // algebraically the mean update when no coordinate clips, but
                // a bounded-influence step whenever a neighbor strays.
                let w = &self.w;
                let xs_r: &[Vec<f32>] = xs;
                self.pool.for_each_mut(&mut self.scratch, |i, out| {
                    let xi = &xs_r[i];
                    out.copy_from_slice(xi);
                    for (j, wji) in w.in_edges(i) {
                        let wji = wji as f32;
                        let xj = &xs_r[j];
                        for k in 0..d {
                            out[k] += wji * (xj[k] - xi[k]).clamp(-tau, tau);
                        }
                    }
                    crate::linalg::axpy(out, -lr, &grads[i]);
                });
            }
            MixPolicy::Median => {
                // Sequential: the robust path trades the pool fan-out for a
                // shared sort buffer; determinism is the same either way.
                let n = self.w.n();
                for i in 0..n {
                    let mut wsum = 0.0f32;
                    let mut t = 0usize;
                    for (j, wji) in self.w.in_edges(i) {
                        for k in 0..d {
                            self.dev[t][k] = xs[j][k] - xs[i][k];
                        }
                        wsum += wji as f32;
                        t += 1;
                    }
                    median_apply(
                        &self.dev,
                        &mut self.sortbuf,
                        t,
                        wsum,
                        &xs[i],
                        &grads[i],
                        lr,
                        &mut self.scratch[i],
                    );
                }
            }
        }
        {
            let scratch = &self.scratch;
            self.pool.for_each_mut(xs, |i, x| x.copy_from_slice(&scratch[i]));
        }
        let deg_sum = self.w.deg_sum();
        CommStats {
            bytes_per_msg: self.d * 4 + self.wire_overhead(),
            messages: deg_sum as u64,
            allreduce_bytes: None,
            extra_local_passes: 0,
        }
    }

    fn set_verify_wire(&mut self, _on: bool) -> bool {
        self.verify_wire = _on;
        true
    }

    fn set_mix(&mut self, mix: MixPolicy) -> bool {
        if let MixPolicy::Clipped(tau) = mix {
            if !(tau > 0.0) {
                return false;
            }
        }
        self.mix = mix;
        if matches!(mix, MixPolicy::Median) {
            self.size_median_scratch();
        }
        true
    }

    fn node_send(
        &mut self,
        _i: usize,
        x: &[f32],
        _grad: &[f32],
        _lr: f32,
        _round: u64,
        _ctx: &StepCtx,
        payload: &mut Vec<u8>,
    ) {
        // Exact neighbor models on the wire: the payload is the raw model.
        common::put_f32s(payload, x);
    }

    /// The payload is the raw model — `node_send` never touches the
    /// gradient (the `x − α g` update happens in the recv half), so the
    /// frame can stream on the wire while `loss_grad` runs.
    fn send_phase(&self) -> SendPhase {
        SendPhase::PreGradient
    }

    fn node_recv(
        &mut self,
        i: usize,
        x: &mut [f32],
        grad: &[f32],
        lr: f32,
        _round: u64,
        _ctx: &StepCtx,
        inbox: &Inbox,
    ) -> CommStats {
        let mix = self.mix;
        let d = self.d;
        let DPsgd { w, scratch, decode, dev, sortbuf, .. } = self;
        let out = &mut scratch[i];
        match mix {
            MixPolicy::Mean => {
                out.fill(0.0);
                crate::linalg::axpy(out, w.weight(i, i) as f32, x);
                for (j, wji) in w.in_edges(i) {
                    common::read_f32s_into(inbox.payload(j), decode);
                    crate::linalg::axpy(out, wji as f32, decode);
                }
                crate::linalg::axpy(out, -lr, grad);
            }
            MixPolicy::Clipped(tau) => {
                out.copy_from_slice(x);
                for (j, wji) in w.in_edges(i) {
                    common::read_f32s_into(inbox.payload(j), decode);
                    let wji = wji as f32;
                    for k in 0..d {
                        out[k] += wji * (decode[k] - x[k]).clamp(-tau, tau);
                    }
                }
                crate::linalg::axpy(out, -lr, grad);
            }
            MixPolicy::Median => {
                let mut wsum = 0.0f32;
                let mut t = 0usize;
                for (j, wji) in w.in_edges(i) {
                    common::read_f32s_into(inbox.payload(j), decode);
                    for k in 0..d {
                        dev[t][k] = decode[k] - x[k];
                    }
                    wsum += wji as f32;
                    t += 1;
                }
                median_apply(dev, sortbuf, t, wsum, x, grad, lr, out);
            }
        }
        x.copy_from_slice(out);
        let deg_sum = w.deg_sum();
        CommStats {
            bytes_per_msg: self.d * 4 + self.wire_overhead(),
            messages: deg_sum as u64,
            allreduce_bytes: None,
            extra_local_passes: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    #[test]
    fn preserves_average_modulo_gradient() {
        // W doubly stochastic: the mean of xs after averaging equals the
        // mean before, minus lr * mean gradient.
        let w = Topology::Ring(5).comm_matrix();
        let d = 8;
        let mut alg = DPsgd::new(w, d);
        let mut xs: Vec<Vec<f32>> =
            (0..5).map(|i| vec![i as f32; d]).collect();
        let grads: Vec<Vec<f32>> = (0..5).map(|_| vec![0.5; d]).collect();
        let mean_before: f32 = xs.iter().map(|x| x[0]).sum::<f32>() / 5.0;
        let ctx = StepCtx { seed: 0, rho: 0.8, g_inf: 1.0 };
        let stats = alg.step(&mut xs, &grads, 0.1, 0, &ctx);
        let mean_after: f32 = xs.iter().map(|x| x[0]).sum::<f32>() / 5.0;
        assert!((mean_after - (mean_before - 0.05)).abs() < 1e-5);
        assert_eq!(stats.bytes_per_msg, d * 4);
        assert_eq!(stats.messages, 10);
    }

    #[test]
    fn reaches_consensus_without_gradients() {
        let w = Topology::Ring(6).comm_matrix();
        let d = 4;
        let mut alg = DPsgd::new(w, d);
        let mut xs: Vec<Vec<f32>> = (0..6).map(|i| vec![i as f32; d]).collect();
        let grads: Vec<Vec<f32>> = (0..6).map(|_| vec![0.0; d]).collect();
        let ctx = StepCtx { seed: 0, rho: 0.8, g_inf: 0.0 };
        for k in 0..200 {
            alg.step(&mut xs, &grads, 0.0, k, &ctx);
        }
        let spread = xs
            .iter()
            .map(|x| x[0])
            .fold((f32::MAX, f32::MIN), |(lo, hi), v| (lo.min(v), hi.max(v)));
        assert!(spread.1 - spread.0 < 1e-4, "spread {spread:?}");
        // consensus value = initial mean = 2.5
        assert!((xs[0][0] - 2.5).abs() < 1e-4);
    }

    #[test]
    fn robust_mixes_track_mean_on_benign_runs() {
        let w = Topology::Ring(5).comm_matrix();
        let d = 4;
        let ctx = StepCtx { seed: 0, rho: 0.8, g_inf: 1.0 };
        let init: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32; d]).collect();
        let grads: Vec<Vec<f32>> = (0..5).map(|_| vec![0.25; d]).collect();
        let mut mean = DPsgd::new(w.clone(), d);
        let mut xs_mean = init.clone();
        mean.step(&mut xs_mean, &grads, 0.1, 0, &ctx);

        // A clip bound nothing reaches reproduces the mean update
        // (deviation form is algebraically identical, not bitwise).
        let mut clip = DPsgd::new(w.clone(), d);
        assert!(clip.set_mix(MixPolicy::Clipped(100.0)));
        assert!(!clip.set_mix(MixPolicy::Clipped(0.0)), "τ=0 must be refused");
        let mut xs_clip = init.clone();
        clip.step(&mut xs_clip, &grads, 0.1, 0, &ctx);
        for (a, b) in xs_mean.iter().zip(&xs_clip) {
            for k in 0..d {
                assert!((a[k] - b[k]).abs() < 1e-5);
            }
        }

        // On a degree-2 ring the coordinate-wise median of two deviations is
        // their midpoint, so the median mix IS the metropolis mean there.
        let mut med = DPsgd::new(w, d);
        assert!(med.set_mix(MixPolicy::Median));
        let mut xs_med = init;
        med.step(&mut xs_med, &grads, 0.1, 0, &ctx);
        for (a, b) in xs_mean.iter().zip(&xs_med) {
            for k in 0..d {
                assert!((a[k] - b[k]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn verify_wire_prices_the_seal_into_bytes() {
        let w = Topology::Ring(5).comm_matrix();
        let mut alg = DPsgd::new(w, 8);
        assert!(alg.set_verify_wire(true));
        let mut xs: Vec<Vec<f32>> = (0..5).map(|_| vec![0.0; 8]).collect();
        let grads = xs.clone();
        let ctx = StepCtx { seed: 0, rho: 0.8, g_inf: 0.0 };
        let stats = alg.step(&mut xs, &grads, 0.1, 0, &ctx);
        assert_eq!(stats.bytes_per_msg, 8 * 4 + crate::adversary::SEAL_LEN);
    }
}
