//! PJRT runtime bridge: load the AOT artifacts Python emitted and execute
//! them from the Rust hot path. Python never runs at training time.
//!
//! The bridge depends on the `xla` crate (PJRT CPU client), which is not
//! available in offline/default builds, so the implementation lives behind
//! the `pjrt` cargo feature:
//!
//! * `--features pjrt` → [`pjrt`]: the real HLO-text → compile → execute
//!   pipeline (see that module for the jax/xla_extension interop notes).
//! * default → [`stub`]: the same public API surface (`Runtime`,
//!   `LoadedModel`, `PjrtObjective`) whose entry point `Runtime::new`
//!   returns a descriptive error, so CLI paths and examples compile and
//!   fail gracefully at *runtime* only when the transformer objective is
//!   actually requested.
//!
//! [`ModelMeta`] (artifact metadata parsing) is dependency-free and shared
//! by both.

use anyhow::{Context, Result};

/// Metadata emitted next to each model artifact (`model_<name>.meta`).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelMeta {
    pub params: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub seq_len: usize,
    pub batch: usize,
}

impl ModelMeta {
    pub fn parse(text: &str) -> Result<Self> {
        // BTreeMap for deterministic behavior under the `unordered` lint;
        // lookup-only here, but the rule is uniform across the crate.
        let mut kv = std::collections::BTreeMap::new();
        for line in text.lines() {
            if let Some((k, v)) = line.split_once('=') {
                kv.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
        let get = |k: &str| -> Result<usize> {
            kv.get(k)
                .with_context(|| format!("meta missing key {k}"))?
                .parse::<usize>()
                .with_context(|| format!("meta key {k} not an integer"))
        };
        Ok(ModelMeta {
            params: get("params")?,
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            seq_len: get("seq_len")?,
            batch: get("batch")?,
        })
    }
}

#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{LoadedModel, PjrtObjective, Runtime};

#[cfg(not(feature = "pjrt"))]
pub mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{LoadedModel, PjrtObjective, Runtime};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parse_roundtrip() {
        let m = ModelMeta::parse(
            "params=100\nvocab=64\nd_model=32\nn_heads=2\nn_layers=1\nd_ff=128\nseq_len=16\nbatch=4\n",
        )
        .unwrap();
        assert_eq!(m.params, 100);
        assert_eq!(m.batch, 4);
        assert!(ModelMeta::parse("vocab=64").is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_missing_feature() {
        let err = Runtime::new("artifacts").err().expect("stub must error");
        assert!(format!("{err:#}").contains("pjrt"), "{err:#}");
    }
}
