//! Dependency-free stand-in for the PJRT bridge, compiled when the `pjrt`
//! feature is off (the default in offline environments).
//!
//! It mirrors the public API of [`super::pjrt`] exactly, so every caller —
//! the CLI's `objective=transformer` path, `examples/train_transformer`,
//! the cross-language tests — type-checks identically against either
//! implementation. The only reachable entry point, [`Runtime::new`],
//! returns an error explaining how to enable the real bridge; the other
//! methods are therefore unreachable in practice and defend themselves
//! with panics carrying the same message.

use std::path::Path;

use anyhow::Result;

use super::ModelMeta;
use crate::data::corpus::Corpus;
use crate::objectives::{Eval, Objective};

const MSG: &str =
    "PJRT runtime not compiled in: rebuild with `--features pjrt` (requires the `xla` crate \
     and the AOT artifacts from `make artifacts`)";

/// A compiled loss+grad executable plus its metadata and initialization.
/// In the stub build this value cannot be produced by [`Runtime`]; the
/// fields exist so diagnostic code paths compile unchanged.
pub struct LoadedModel {
    pub meta: ModelMeta,
    pub init: Vec<f32>,
}

impl LoadedModel {
    pub fn loss_and_grad(&self, _params: &[f32], _tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
        anyhow::bail!(MSG)
    }
}

/// PJRT CPU runtime holding the client and loaded executables (stub).
pub struct Runtime {
    _private: (),
}

impl Runtime {
    /// Always fails in stub builds — this is the single gate that keeps the
    /// rest of the stub unreachable.
    pub fn new<P: AsRef<Path>>(_artifacts_dir: P) -> Result<Self> {
        anyhow::bail!(MSG)
    }

    pub fn platform(&self) -> String {
        unreachable!("{MSG}")
    }

    pub fn load_model(&self, _name: &str) -> Result<LoadedModel> {
        anyhow::bail!(MSG)
    }
}

/// [`Objective`] backed by the AOT transformer executable (stub).
pub struct PjrtObjective {
    model: std::sync::Arc<LoadedModel>,
    n_workers: usize,
}

impl PjrtObjective {
    pub fn new(model: LoadedModel, _corpus: &Corpus, n_workers: usize, _seed: u64) -> Self {
        PjrtObjective { model: std::sync::Arc::new(model), n_workers }
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.model.meta
    }
}

impl Objective for PjrtObjective {
    fn dim(&self) -> usize {
        self.model.meta.params
    }

    fn init(&self) -> Vec<f32> {
        self.model.init.clone()
    }

    fn loss_grad(
        &mut self,
        _worker: usize,
        _step: u64,
        _params: &[f32],
        _grad: &mut [f32],
    ) -> f64 {
        unreachable!("{MSG}")
    }

    fn eval(&mut self, _params: &[f32]) -> Eval {
        unreachable!("{MSG}")
    }

    fn workers(&self) -> usize {
        self.n_workers
    }

    fn box_clone(&self) -> Box<dyn Objective> {
        Box::new(PjrtObjective {
            model: std::sync::Arc::clone(&self.model),
            n_workers: self.n_workers,
        })
    }
}
