//! The real PJRT bridge (requires the `pjrt` cargo feature / `xla` crate).
//!
//! Pattern (see /opt/xla-example): HLO **text** → `HloModuleProto::
//! from_text_file` → `XlaComputation::from_proto` → `PjRtClient::compile` →
//! `execute`. Text is the interchange format because jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects in serialized
//! protos; the text parser reassigns ids.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::ModelMeta;
use crate::data::corpus::Corpus;
use crate::objectives::{Eval, Objective};
use crate::rng::Pcg64;

/// A compiled loss+grad executable plus its metadata and initialization.
pub struct LoadedModel {
    pub meta: ModelMeta,
    pub init: Vec<f32>,
    exe: xla::PjRtLoadedExecutable,
}

// SAFETY: the `xla` crate wraps raw PJRT pointers without Send/Sync, but the
// PJRT C API specifies that `PJRT_LoadedExecutable_Execute` and buffer
// transfers are thread-safe, and the CPU plugin honors that. We only move
// the executable between threads wholesale (never share the non-atomic Rc
// of the *client* across concurrent clones: the client handle inside the
// executable is cloned at load time, before any thread spawns, and is only
// dropped when the last worker finishes). The threaded runtime exercises
// this under `cargo test` with real concurrency.
unsafe impl Send for LoadedModel {}
unsafe impl Sync for LoadedModel {}

/// PJRT CPU runtime holding the client and loaded executables.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("PjRtClient::cpu")?;
        Ok(Runtime { client, artifacts_dir: artifacts_dir.as_ref().to_path_buf() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an arbitrary HLO-text file.
    pub fn compile_hlo<P: AsRef<Path>>(&self, path: P) -> Result<xla::PjRtLoadedExecutable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).context("PJRT compile")
    }

    /// Load a named model artifact: HLO + meta + init vector.
    pub fn load_model(&self, name: &str) -> Result<LoadedModel> {
        let dir = &self.artifacts_dir;
        let meta_text = std::fs::read_to_string(dir.join(format!("model_{name}.meta")))
            .with_context(|| format!("read model_{name}.meta (run `make artifacts`)"))?;
        let meta = ModelMeta::parse(&meta_text)?;
        let init_bytes = std::fs::read(dir.join(format!("model_{name}.init.bin")))?;
        anyhow::ensure!(
            init_bytes.len() == 4 * meta.params,
            "init.bin size {} != 4*{}",
            init_bytes.len(),
            meta.params
        );
        let init: Vec<f32> = init_bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let exe = self.compile_hlo(dir.join(format!("model_{name}.hlo.txt")))?;
        Ok(LoadedModel { meta, init, exe })
    }
}

impl LoadedModel {
    /// Run loss+grad: params f32[P], tokens i32[B*S] (row-major [B, S]).
    /// Returns (loss, grad).
    pub fn loss_and_grad(&self, params: &[f32], tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
        anyhow::ensure!(params.len() == self.meta.params, "param length mismatch");
        anyhow::ensure!(
            tokens.len() == self.meta.batch * self.meta.seq_len,
            "token length mismatch"
        );
        let p = xla::Literal::vec1(params);
        let t = xla::Literal::vec1(tokens)
            .reshape(&[self.meta.batch as i64, self.meta.seq_len as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[p, t])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: (loss f32[], grad f32[P]).
        let (loss_lit, grad_lit) = result.to_tuple2()?;
        let loss = loss_lit.to_vec::<f32>()?[0];
        let grad = grad_lit.to_vec::<f32>()?;
        anyhow::ensure!(grad.len() == self.meta.params, "grad length mismatch");
        Ok((loss, grad))
    }
}

/// [`Objective`] backed by the AOT transformer executable: the end-to-end
/// driver's objective. Each worker samples windows from its own corpus
/// shard; the gradient is computed by the compiled JAX/Pallas module.
pub struct PjrtObjective {
    model: std::sync::Arc<LoadedModel>,
    shards: Vec<Corpus>,
    eval_corpus: Corpus,
    rngs: Vec<Pcg64>,
    eval_batches: usize,
}

impl PjrtObjective {
    pub fn new(model: LoadedModel, corpus: &Corpus, n_workers: usize, seed: u64) -> Self {
        let shards = corpus.shard(n_workers);
        let rngs = (0..n_workers)
            .map(|w| Pcg64::new(seed, 0xDA7A ^ w as u64))
            .collect();
        PjrtObjective {
            model: std::sync::Arc::new(model),
            shards,
            eval_corpus: corpus.clone(),
            rngs,
            eval_batches: 4,
        }
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.model.meta
    }
}

impl Objective for PjrtObjective {
    fn dim(&self) -> usize {
        self.model.meta.params
    }

    fn init(&self) -> Vec<f32> {
        self.model.init.clone()
    }

    fn loss_grad(&mut self, worker: usize, _step: u64, params: &[f32], grad: &mut [f32]) -> f64 {
        let m = &self.model.meta;
        let tokens = self.shards[worker].sample_batch(m.batch, m.seq_len, &mut self.rngs[worker]);
        let (loss, g) = self
            .model
            .loss_and_grad(params, &tokens)
            .expect("pjrt execution failed");
        grad.copy_from_slice(&g);
        loss as f64
    }

    fn eval(&mut self, params: &[f32]) -> Eval {
        let m = &self.model.meta;
        let mut rng = Pcg64::new(0xE7A1, 0);
        let mut loss = 0.0;
        for _ in 0..self.eval_batches {
            let tokens = self.eval_corpus.sample_batch(m.batch, m.seq_len, &mut rng);
            let (l, _) = self
                .model
                .loss_and_grad(params, &tokens)
                .expect("pjrt eval failed");
            loss += l as f64;
        }
        Eval { loss: loss / self.eval_batches as f64, accuracy: None }
    }

    fn workers(&self) -> usize {
        self.shards.len()
    }

    fn box_clone(&self) -> Box<dyn Objective> {
        // The PJRT executable is shared behind an Arc; clones share it but
        // get independent sampler state.
        Box::new(PjrtObjective {
            model: std::sync::Arc::clone(&self.model),
            shards: self.shards.clone(),
            eval_corpus: self.eval_corpus.clone(),
            rngs: self.rngs.clone(),
            eval_batches: self.eval_batches,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // tests run from the workspace root
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("model_tiny.hlo.txt").exists()
    }

    #[test]
    fn load_and_execute_tiny_model() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let rt = Runtime::new(artifacts_dir()).unwrap();
        let model = rt.load_model("tiny").unwrap();
        let m = model.meta.clone();
        let tokens: Vec<i32> = (0..m.batch * m.seq_len).map(|i| (i % m.vocab) as i32).collect();
        let (loss, grad) = model.loss_and_grad(&model.init, &tokens).unwrap();
        // random init: loss ≈ ln(vocab)
        assert!(
            (loss - (m.vocab as f32).ln()).abs() < 1.5,
            "loss {loss} vs ln(vocab) {}",
            (m.vocab as f32).ln()
        );
        assert_eq!(grad.len(), m.params);
        assert!(grad.iter().any(|&g| g != 0.0));
        assert!(grad.iter().all(|g| g.is_finite()));
    }

    #[test]
    fn gradient_descends_through_pjrt() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new(artifacts_dir()).unwrap();
        let model = rt.load_model("tiny").unwrap();
        let m = model.meta.clone();
        let tokens: Vec<i32> = (0..m.batch * m.seq_len).map(|i| ((i * 7) % m.vocab) as i32).collect();
        let mut params = model.init.clone();
        let (l0, mut g) = model.loss_and_grad(&params, &tokens).unwrap();
        for _ in 0..10 {
            for (p, gi) in params.iter_mut().zip(&g) {
                *p -= 0.5 * gi;
            }
            let (_, g2) = model.loss_and_grad(&params, &tokens).unwrap();
            g = g2;
        }
        let (l1, _) = model.loss_and_grad(&params, &tokens).unwrap();
        assert!(l1 < l0 - 0.1, "{l0} -> {l1}");
    }

    #[test]
    fn pjrt_objective_interface() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new(artifacts_dir()).unwrap();
        let model = rt.load_model("tiny").unwrap();
        let corpus = Corpus::synthetic(20_000, 3);
        let mut obj = PjrtObjective::new(model, &corpus, 2, 11);
        assert_eq!(obj.workers(), 2);
        let mut grad = vec![0.0; obj.dim()];
        let init = obj.init();
        let l = obj.loss_grad(0, 0, &init, &mut grad);
        assert!(l > 0.0 && l.is_finite());
        let e = obj.eval(&init);
        assert!(e.loss.is_finite());
    }
}
