//! Deterministic pseudo-random number generation.
//!
//! The crate needs (a) fast, reproducible streams for synthetic data and
//! stochastic rounding, and (b) the paper's §6 *shared randomness* trick:
//! all workers must draw the **same** uniform noise `u` for stochastic
//! rounding in the same round, which provably reduces the pairwise
//! quantization error (supplementary §C). We implement PCG64 (O'Neill,
//! PCG-XSL-RR 128/64) so streams are splittable by `(seed, stream)` pairs:
//! the shared stream is keyed by the round number only, per-worker streams
//! by `(worker, round)`.

/// PCG-XSL-RR 128/64 generator. Small, fast, statistically solid, and fully
/// deterministic across platforms — no external crates required.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different stream ids
    /// yield statistically independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 1) | 1) ^ 0x5851_f42d_4c95_7f2d;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        // A few warmup rounds to diffuse low-entropy seeds.
        for _ in 0..4 {
            rng.next_u64();
        }
        rng
    }

    /// Single-argument convenience constructor (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// The generator's raw `(state, inc)` words, little-end first — the RNG
    /// cursor an elastic [`Snapshot`](crate::elastic::Snapshot) persists so
    /// a restored stream continues bit-for-bit where it left off.
    pub fn raw(&self) -> [u64; 4] {
        [
            self.state as u64,
            (self.state >> 64) as u64,
            self.inc as u64,
            (self.inc >> 64) as u64,
        ]
    }

    /// Rebuild a generator from [`Self::raw`] output (no warmup — the words
    /// are the post-warmup cursor).
    pub fn from_raw(raw: [u64; 4]) -> Self {
        Pcg64 {
            state: (raw[0] as u128) | ((raw[1] as u128) << 64),
            inc: (raw[2] as u128) | ((raw[3] as u128) << 64),
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // 24 mantissa bits of a u32.
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) via Lemire's rejection-free-ish reduction.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller (cached second value is discarded to
    /// keep the generator state a pure function of draw count).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fill `buf` with uniform f32 in [0,1).
    pub fn fill_uniform_f32(&mut self, buf: &mut [f32]) {
        for v in buf.iter_mut() {
            *v = self.next_f32();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

/// Derives the *shared randomness* stream for a communication round: every
/// worker constructs the identical generator, so their stochastic-rounding
/// noise vectors match elementwise (paper §6, supplementary §C).
pub fn shared_round_rng(experiment_seed: u64, round: u64) -> Pcg64 {
    Pcg64::new(experiment_seed ^ 0x9e37_79b9_7f4a_7c15, round)
}

/// Per-worker private stream (gradient sampling, data order, ...).
pub fn worker_rng(experiment_seed: u64, worker: usize, purpose: u64) -> Pcg64 {
    Pcg64::new(
        experiment_seed.wrapping_add(0x517c_c1b7_2722_0a95),
        ((worker as u64) << 8) | purpose,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Pcg64::seeded(1);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg64::seeded(2);
        let n = 100_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.next_gaussian();
            s1 += g;
            s2 += g * g;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg64::seeded(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shared_round_rng_identical_for_all_workers() {
        // The whole point: two "workers" derive the same stream per round.
        let mut w0 = shared_round_rng(99, 5);
        let mut w1 = shared_round_rng(99, 5);
        for _ in 0..32 {
            assert_eq!(w0.next_u32(), w1.next_u32());
        }
        // ...but different rounds differ.
        let mut r6 = shared_round_rng(99, 6);
        assert_ne!(shared_round_rng(99, 5).next_u64(), r6.next_u64());
    }

    #[test]
    fn raw_roundtrip_resumes_stream() {
        let mut a = Pcg64::new(9, 3);
        a.next_u64();
        let mut b = Pcg64::from_raw(a.raw());
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(4);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
