//! Minimal dense linear algebra used across the crate.
//!
//! Everything operates on `&[f32]` / `&mut [f32]` slices so the hot paths
//! (quantize → average → step) stay allocation-free. A tiny `MatF64` type
//! backs the communication-matrix math in [`crate::topology`], where f64
//! precision matters for spectral-gap estimates.

/// `y += a * x` (fused on the training hot path).
#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * xi;
    }
}

/// `y = x` (memcpy wrapper for symmetry).
#[inline]
pub fn copy(y: &mut [f32], x: &[f32]) {
    y.copy_from_slice(x);
}

/// `y *= a`.
#[inline]
pub fn scale(y: &mut [f32], a: f32) {
    for yi in y.iter_mut() {
        *yi *= a;
    }
}

/// Dot product in f64 accumulation.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
}

/// Squared L2 norm (f64 accumulation).
#[inline]
pub fn norm2_sq(a: &[f32]) -> f64 {
    a.iter().map(|x| (*x as f64) * (*x as f64)).sum()
}

/// L2 norm.
#[inline]
pub fn norm2(a: &[f32]) -> f64 {
    norm2_sq(a).sqrt()
}

/// L∞ norm.
#[inline]
pub fn norm_inf(a: &[f32]) -> f32 {
    a.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
}

/// L∞ distance between two vectors — the consensus metric of the paper
/// (`θ` must upper-bound this for Moniqua's recovery to be exact).
#[inline]
pub fn linf_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .fold(0.0f32, |m, (x, y)| m.max((x - y).abs()))
}

/// Mean of several equal-length vectors into `out`. Generic over the row
/// type so callers holding `Vec<Vec<f32>>` state (the trainers' eval path,
/// every `eval_every` rounds) pass their rows directly instead of
/// materializing a `Vec<&[f32]>` per call (§Perf) — one accumulation loop,
/// one summation order, for every caller.
pub fn mean_into<V: AsRef<[f32]>>(out: &mut [f32], vs: &[V]) {
    assert!(!vs.is_empty());
    out.fill(0.0);
    for v in vs {
        axpy(out, 1.0, v.as_ref());
    }
    scale(out, 1.0 / vs.len() as f32);
}

/// Small dense f64 matrix (row-major) for communication-matrix math.
#[derive(Clone, Debug, PartialEq)]
pub struct MatF64 {
    pub n: usize,
    pub m: usize,
    pub data: Vec<f64>,
}

impl MatF64 {
    pub fn zeros(n: usize, m: usize) -> Self {
        MatF64 { n, m, data: vec![0.0; n * m] }
    }

    pub fn eye(n: usize) -> Self {
        let mut a = Self::zeros(n, n);
        for i in 0..n {
            a[(i, i)] = 1.0;
        }
        a
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.m + j]
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.m..(i + 1) * self.m]
    }

    /// `self * v` for a column vector.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.m);
        let mut out = vec![0.0; self.n];
        for i in 0..self.n {
            let row = self.row(i);
            out[i] = row.iter().zip(v).map(|(a, b)| a * b).sum();
        }
        out
    }

    /// `self * other`.
    pub fn matmul(&self, other: &MatF64) -> MatF64 {
        assert_eq!(self.m, other.n);
        let mut out = MatF64::zeros(self.n, other.m);
        for i in 0..self.n {
            for k in 0..self.m {
                let a = self.at(i, k);
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.m {
                    out[(i, j)] += a * other.at(k, j);
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> MatF64 {
        let mut t = MatF64::zeros(self.m, self.n);
        for i in 0..self.n {
            for j in 0..self.m {
                t[(j, i)] = self.at(i, j);
            }
        }
        t
    }

    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.n != self.m {
            return false;
        }
        for i in 0..self.n {
            for j in (i + 1)..self.m {
                if (self.at(i, j) - self.at(j, i)).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

impl std::ops::Index<(usize, usize)> for MatF64 {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.m + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for MatF64 {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.m + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_and_norms() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(&mut y, 2.0, &[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![3.0, 4.0, 5.0]);
        assert!((norm2_sq(&y) - 50.0).abs() < 1e-9);
        assert_eq!(norm_inf(&[-7.0, 2.0]), 7.0);
        assert_eq!(linf_dist(&[1.0, 5.0], &[2.0, 2.0]), 3.0);
    }

    #[test]
    fn mean_into_averages() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 6.0];
        let mut out = [0.0f32; 2];
        mean_into(&mut out, &[&a, &b]);
        assert_eq!(out, [2.0, 4.0]);
    }

    #[test]
    fn matvec_matmul_roundtrip() {
        let mut a = MatF64::zeros(2, 2);
        a[(0, 0)] = 1.0;
        a[(0, 1)] = 2.0;
        a[(1, 0)] = 3.0;
        a[(1, 1)] = 4.0;
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        let i = MatF64::eye(2);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(a.transpose().at(0, 1), 3.0);
        assert!(!a.is_symmetric(1e-12));
    }

    #[test]
    fn dot_f64_accumulation() {
        let a = vec![1e-4f32; 10_000];
        let b = vec![1e-4f32; 10_000];
        let d = dot(&a, &b);
        assert!((d - 1e-4).abs() < 1e-9);
    }
}
