//! Experiment configuration: `key=value` files + CLI override parsing.
//!
//! clap is unavailable offline, so this is a small self-contained layer:
//! a config is an ordered `key=value` map loadable from a file (one pair
//! per line, `#` comments) and overridable by `--key value` / `key=value`
//! CLI arguments. Typed getters centralize parse errors.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::adversary::{ByzMode, ByzantineConfig};
use crate::algorithms::{Algorithm, MixPolicy, ThetaPolicy};
use crate::coordinator::cluster::{ClusterConfig, DriverKind, TransportKind};
use crate::coordinator::des::FaultConfig;
use crate::elastic::{ElasticConfig, MembershipPlan};
use crate::data::partition::Partition;
use crate::network::{LinkMatrix, NetworkConfig};
use crate::quant::{Compression, QuantConfig, Rounding};
use crate::telemetry::MetricsMode;
use crate::topology::{Topology, TopologySchedule};

/// Ordered string map with typed access.
#[derive(Clone, Debug, Default)]
pub struct Config {
    map: BTreeMap<String, String>,
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse `key=value` lines (`#` comments, blank lines ignored).
    pub fn from_str_cfg(text: &str) -> Result<Self> {
        let mut cfg = Config::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key=value", lineno + 1))?;
            cfg.set(k.trim(), v.trim());
        }
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {path}"))?;
        Self::from_str_cfg(&text)
    }

    /// Apply CLI args: `--key value`, `--flag` (→ "true"), or `key=value`.
    pub fn apply_args(&mut self, args: &[String]) -> Result<()> {
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    self.set(k, v);
                } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    self.set(key, &args[i + 1]);
                    i += 1;
                } else {
                    self.set(key, "true");
                }
            } else if let Some((k, v)) = a.split_once('=') {
                self.set(k, v);
            } else {
                anyhow::bail!("unrecognized argument '{a}'");
            }
            i += 1;
        }
        Ok(())
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.map.insert(key.to_string(), value.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("{key}={v} not u64")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.u64_or(key, default as u64)? as usize)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("{key}={v} not f64")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => anyhow::bail!("{key}={v} not a bool"),
        }
    }

    // ---- domain-typed getters -------------------------------------------

    /// `topology=ring|chain|complete|star|torus:RxC|regular:D` over `workers`.
    pub fn topology(&self) -> Result<Topology> {
        let n = self.usize_or("workers", 8)?;
        Topology::parse_spec(self.str_or("topology", "ring"), n, self.u64_or("seed", 42)?)
    }

    /// Quantizer from `bits`, `rounding`, `shared_randomness`, `compression`.
    pub fn quant(&self) -> Result<QuantConfig> {
        let bits = self.u64_or("bits", 8)? as u32;
        let rounding = match self.str_or("rounding", "stochastic") {
            "stochastic" => Rounding::Stochastic,
            "nearest" => Rounding::Nearest,
            other => anyhow::bail!("unknown rounding '{other}'"),
        };
        let compression = match self.str_or("compression", "none") {
            "none" => Compression::None,
            "deflate" => {
                if cfg!(feature = "deflate") {
                    Compression::Deflate
                } else {
                    anyhow::bail!(
                        "compression=deflate needs a build with `--features deflate`"
                    )
                }
            }
            "bzip2" => {
                if cfg!(feature = "bzip2") {
                    Compression::Bzip2
                } else {
                    anyhow::bail!("compression=bzip2 needs a build with `--features bzip2`")
                }
            }
            "rle" => Compression::Rle,
            other => anyhow::bail!("unknown compression '{other}'"),
        };
        let mut q = QuantConfig::stochastic(bits);
        q.rounding = rounding;
        q.shared_randomness = self.bool_or("shared_randomness", true)?;
        q.compression = compression;
        q.verify_hash = self.bool_or("verify_hash", false)?;
        Ok(q)
    }

    /// θ policy from `theta` (number) or `theta=auto` (Theorem-2 formula).
    pub fn theta_policy(&self) -> Result<ThetaPolicy> {
        match self.str_or("theta", "2.0") {
            "auto" => Ok(ThetaPolicy::Theorem2 {
                warmup: self.u64_or("theta_warmup", 20)?,
                safety: self.f64_or("theta_safety", 2.0)?,
            }),
            v => Ok(ThetaPolicy::Constant(
                v.parse::<f32>().context("theta must be a number or 'auto'")?,
            )),
        }
    }

    /// Algorithm from `algorithm=` plus quantizer/θ keys.
    pub fn algorithm(&self) -> Result<Algorithm> {
        let quant = self.quant()?;
        let range = self.f64_or("range", 4.0)? as f32;
        let gamma = self.f64_or("gamma", 0.2)?;
        Ok(match self.str_or("algorithm", "moniqua") {
            "allreduce" => Algorithm::AllReduce,
            "dpsgd" => Algorithm::DPsgd,
            "naive" => Algorithm::NaiveQuant { quant, range },
            "moniqua" => Algorithm::Moniqua { theta: self.theta_policy()?, quant },
            "moniqua-slack" => Algorithm::MoniquaSlack {
                theta: self.theta_policy()?,
                quant,
                gamma,
            },
            "d2" => Algorithm::D2,
            "moniqua-d2" => Algorithm::MoniquaD2 { theta: self.theta_policy()?, quant },
            "dcd" => Algorithm::Dcd { quant, range },
            "ecd" => Algorithm::Ecd { quant, range },
            "choco" => Algorithm::Choco { quant, range, gamma },
            "deepsqueeze" => Algorithm::DeepSqueeze { quant, range, gamma },
            other => anyhow::bail!("unknown algorithm '{other}'"),
        })
    }

    /// Network from `bandwidth_mbps` + `latency_ms` or a `network=fig1a..d`
    /// preset; `network=none` disables pricing.
    pub fn network(&self) -> Result<Option<NetworkConfig>> {
        match self.get("network") {
            Some("none") => Ok(None),
            Some("fig1a") => Ok(Some(NetworkConfig::fig1a())),
            Some("fig1b") => Ok(Some(NetworkConfig::fig1b())),
            Some("fig1c") => Ok(Some(NetworkConfig::fig1c())),
            Some("fig1d") => Ok(Some(NetworkConfig::fig1d())),
            Some("fig2b") => Ok(Some(NetworkConfig::fig2b())),
            Some(other) => anyhow::bail!("unknown network preset '{other}'"),
            None => {
                let bw = self.f64_or("bandwidth_mbps", 1000.0)?;
                let lat = self.f64_or("latency_ms", 0.05)?;
                Ok(Some(NetworkConfig::new(bw * 1e6, lat * 1e-3)))
            }
        }
    }

    /// Byzantine fault plane from `byz_workers=i,j,…` (comma list of
    /// adversarial worker ids), `byz_mode=flip|replay|equivocate|wrap`
    /// (default flip), and `quarantine_strikes=K` (gate rejections an
    /// honest node tolerates before excising the offender; ≥ 1, default
    /// 3). `None` when `byz_workers` is absent; `byz_mode` or
    /// `quarantine_strikes` without it is a loud error, mirroring the
    /// `drop_prob` range checks.
    pub fn byz(&self) -> Result<Option<ByzantineConfig>> {
        let Some(spec) = self.get("byz_workers") else {
            anyhow::ensure!(
                self.get("byz_mode").is_none() && self.get("quarantine_strikes").is_none(),
                "byz_mode/quarantine_strikes need byz_workers to name the adversaries"
            );
            return Ok(None);
        };
        let b = ByzantineConfig {
            workers: ByzantineConfig::parse_workers(spec)?,
            mode: ByzMode::parse(self.str_or("byz_mode", "flip"))?,
            strike_limit: self.u64_or("quarantine_strikes", 3)? as u32,
        };
        b.validate(self.usize_or("workers", 8)?)?;
        Ok(Some(b))
    }

    /// Gossip mix policy from `mix=mean|clipped|median` plus `mix_clip=τ`
    /// (clip radius, clipped mode only, must be positive).
    pub fn mix(&self) -> Result<MixPolicy> {
        Ok(match self.str_or("mix", "mean") {
            "mean" => MixPolicy::Mean,
            "clipped" => {
                let tau = self.f64_or("mix_clip", 1.0)? as f32;
                anyhow::ensure!(
                    tau > 0.0 && tau.is_finite(),
                    "mix_clip must be a positive clip radius, got {tau}"
                );
                MixPolicy::Clipped(tau)
            }
            "median" => MixPolicy::Median,
            other => anyhow::bail!("unknown mix '{other}' (mean|clipped|median)"),
        })
    }

    /// DES fault model from `drop_prob`, `delay_prob`, `delay_ms`,
    /// `straggler` (all default 0 — the fault-free regime), plus the
    /// Byzantine keys of [`Self::byz`].
    pub fn faults(&self) -> Result<FaultConfig> {
        let f = FaultConfig {
            drop_prob: self.f64_or("drop_prob", 0.0)?,
            delay_prob: self.f64_or("delay_prob", 0.0)?,
            delay_s: self.f64_or("delay_ms", 0.0)? * 1e-3,
            straggler: self.f64_or("straggler", 0.0)?,
            byz: self.byz()?,
        };
        f.validate_for(self.usize_or("workers", 8)?)?;
        Ok(f)
    }

    /// Per-edge link matrix from `link_matrix=uniform|lognormal:S|file:PATH`
    /// over the base `network` (which must not be `none` for the DES).
    pub fn link_matrix(&self, n: usize) -> Result<LinkMatrix> {
        let base = self
            .network()?
            .ok_or_else(|| anyhow::anyhow!("the DES runtime needs a network (network!=none)"))?;
        self.link_matrix_with_base(n, base)
    }

    /// As [`Self::link_matrix`] but over a caller-supplied base link — the
    /// async command substitutes its historical Figure-2b default instead
    /// of erroring when `network` is unset.
    pub fn link_matrix_with_base(&self, n: usize, base: NetworkConfig) -> Result<LinkMatrix> {
        LinkMatrix::from_spec(
            self.str_or("link_matrix", "uniform"),
            n,
            base,
            self.u64_or("seed", 42)?,
        )
    }

    /// Optional time-varying topology from `topo_schedule=spec@t,spec@t,…`.
    pub fn topo_schedule(&self) -> Result<Option<TopologySchedule>> {
        match self.get("topo_schedule") {
            None => Ok(None),
            Some(text) => Ok(Some(TopologySchedule::parse(
                text,
                self.usize_or("workers", 8)?,
                self.u64_or("seed", 42)?,
            )?)),
        }
    }

    /// Cluster-runtime config from `transport=mem|tcp`, `port_base`
    /// (0 = OS ephemeral ports, collision-safe), `recv_timeout_ms`,
    /// `pipeline=true|false` (send-early round pipelining; on by default,
    /// bitwise value-equivalent to the strict schedule), and
    /// `reactor_threads=N` (readiness-loop driver threads; only consulted
    /// when `runtime=reactor`, 0 = one per core), plus the elastic keys
    /// (see [`Self::elastic`]) and the Byzantine keys (see [`Self::byz`]).
    pub fn cluster(&self) -> Result<ClusterConfig> {
        let transport = match self.str_or("transport", "mem") {
            "mem" => TransportKind::Mem,
            "tcp" => {
                let base = self.u64_or("port_base", 0)?;
                if base > u16::MAX as u64 {
                    anyhow::bail!("port_base={base} exceeds the u16 port range");
                }
                TransportKind::Tcp { port_base: base as u16 }
            }
            other => anyhow::bail!("unknown transport '{other}' (mem|tcp)"),
        };
        let driver = if self.str_or("runtime", "sync") == "reactor" {
            let threads = self.u64_or("reactor_threads", 0)? as usize;
            DriverKind::Reactor { threads }
        } else {
            DriverKind::Threaded
        };
        Ok(ClusterConfig {
            transport,
            recv_timeout: std::time::Duration::from_millis(
                self.u64_or("recv_timeout_ms", 30_000)?,
            ),
            elastic: self.elastic()?,
            pipeline: self.bool_or("pipeline", true)?,
            driver,
            byz: self.byz()?,
        })
    }

    /// Elastic membership + checkpointing from `churn=kind@round:worker,…`
    /// (`kind ∈ {join, leave, crash}`), `ckpt_every=K` (rounds between
    /// checkpoints; 0 = never), `ckpt_dir=PATH` (durability directory,
    /// required for crash plans), and the testing-only `skip_bootstrap`.
    /// `None` when no elastic key is present — the static cluster.
    pub fn elastic(&self) -> Result<Option<ElasticConfig>> {
        let churn = self.get("churn");
        let ckpt_every = self.u64_or("ckpt_every", 0)?;
        let ckpt_dir = self.get("ckpt_dir").map(std::path::PathBuf::from);
        let skip_bootstrap = self.bool_or("skip_bootstrap", false)?;
        if churn.is_none() && ckpt_every == 0 && ckpt_dir.is_none() {
            return Ok(None);
        }
        let plan = match churn {
            Some(spec) => MembershipPlan::parse(spec)?,
            None => MembershipPlan::default(),
        };
        if ckpt_every > 0 || plan.has_crashes() {
            anyhow::ensure!(
                ckpt_dir.is_some(),
                "ckpt_every/crash plans need a ckpt_dir=PATH to write into"
            );
        }
        Ok(Some(ElasticConfig { plan, ckpt_every, ckpt_dir, skip_bootstrap }))
    }

    /// Metrics export from `metrics=off|json|prom` (default off) and
    /// `metrics_path=PATH` (default `moniqua_metrics.json` /
    /// `moniqua_metrics.prom` by mode). Returns `(mode, path)`; the path is
    /// meaningless (but still defaulted) when the mode is `off`. The
    /// telemetry plane *records* unconditionally — this key gates only
    /// whether a snapshot is exported at the end of the run, which is why
    /// `metrics=json` runs are bitwise-identical to `metrics=off` runs.
    pub fn metrics(&self) -> Result<(MetricsMode, String)> {
        let mode = MetricsMode::parse_mode(self.str_or("metrics", "off"))
            .map_err(|e| anyhow::anyhow!(e))?;
        let path = self
            .str_or("metrics_path", mode.default_path())
            .to_string();
        Ok((mode, path))
    }

    pub fn partition(&self) -> Result<Partition> {
        match self.str_or("partition", "iid") {
            "iid" => Ok(Partition::Iid),
            "by_label" | "bylabel" => Ok(Partition::ByLabel),
            other => anyhow::bail!("unknown partition '{other}'"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_file_and_overrides() {
        let mut cfg = Config::from_str_cfg(
            "# experiment\nworkers = 8\nalgorithm=moniqua\nbits=4\n\ntheta=1.5\n",
        )
        .unwrap();
        assert_eq!(cfg.usize_or("workers", 0).unwrap(), 8);
        cfg.apply_args(&["--bits".into(), "2".into(), "lr=0.05".into()])
            .unwrap();
        assert_eq!(cfg.u64_or("bits", 0).unwrap(), 2);
        assert_eq!(cfg.f64_or("lr", 0.0).unwrap(), 0.05);
    }

    #[test]
    fn flag_without_value_is_true() {
        let mut cfg = Config::new();
        cfg.apply_args(&["--verify_hash".into()]).unwrap();
        assert!(cfg.bool_or("verify_hash", false).unwrap());
    }

    #[test]
    fn typed_getters_reject_garbage() {
        let cfg = Config::from_str_cfg("workers=eight").unwrap();
        assert!(cfg.usize_or("workers", 1).is_err());
        let cfg = Config::from_str_cfg("algorithm=nope").unwrap();
        assert!(cfg.algorithm().is_err());
    }

    #[test]
    fn builds_all_algorithms() {
        for name in [
            "allreduce", "dpsgd", "naive", "moniqua", "moniqua-slack", "d2",
            "moniqua-d2", "dcd", "ecd", "choco", "deepsqueeze",
        ] {
            let cfg = Config::from_str_cfg(&format!("algorithm={name}")).unwrap();
            let a = cfg.algorithm().unwrap();
            assert_eq!(a.name(), name, "{name}");
        }
    }

    #[test]
    fn topology_specs() {
        let cfg = Config::from_str_cfg("workers=12\ntopology=torus:3x4").unwrap();
        assert_eq!(cfg.topology().unwrap().n(), 12);
        let cfg = Config::from_str_cfg("workers=8\ntopology=regular:4").unwrap();
        assert!(matches!(cfg.topology().unwrap(), Topology::RandomRegular { .. }));
        let cfg = Config::from_str_cfg("topology=blob").unwrap();
        assert!(cfg.topology().is_err());
    }

    #[test]
    fn network_presets_and_custom() {
        let cfg = Config::from_str_cfg("network=fig1d").unwrap();
        assert_eq!(cfg.network().unwrap().unwrap(), NetworkConfig::fig1d());
        let cfg = Config::from_str_cfg("bandwidth_mbps=50\nlatency_ms=2").unwrap();
        let net = cfg.network().unwrap().unwrap();
        assert_eq!(net.bandwidth_bps, 50e6);
        assert_eq!(net.latency_s, 2e-3);
        let cfg = Config::from_str_cfg("network=none").unwrap();
        assert!(cfg.network().unwrap().is_none());
    }

    #[test]
    fn des_keys_parse_and_validate() {
        let cfg = Config::from_str_cfg(
            "workers=4\ndrop_prob=0.1\ndelay_prob=0.2\ndelay_ms=5\nstraggler=0.4\n",
        )
        .unwrap();
        let f = cfg.faults().unwrap();
        assert_eq!(f.drop_prob, 0.1);
        assert!((f.delay_s - 5e-3).abs() < 1e-12);
        assert!(Config::from_str_cfg("drop_prob=1.0").unwrap().faults().is_err());

        let cfg = Config::from_str_cfg("workers=4\nnetwork=fig1b\n").unwrap();
        assert!(cfg.link_matrix(4).unwrap().is_uniform());
        let cfg =
            Config::from_str_cfg("workers=4\nnetwork=fig1b\nlink_matrix=lognormal:0.5\n")
                .unwrap();
        assert!(!cfg.link_matrix(4).unwrap().is_uniform());
        let cfg = Config::from_str_cfg("workers=4\nnetwork=none\n").unwrap();
        assert!(cfg.link_matrix(4).is_err(), "DES needs a priced network");

        let cfg =
            Config::from_str_cfg("workers=4\ntopo_schedule=ring,complete@2.0\n").unwrap();
        let sched = cfg.topo_schedule().unwrap().unwrap();
        assert_eq!(sched.stages().len(), 2);
        assert!(Config::from_str_cfg("topo_schedule=bogus@0")
            .unwrap()
            .topo_schedule()
            .is_err());
    }

    #[test]
    fn cluster_keys_parse_and_validate() {
        let cfg = Config::from_str_cfg("").unwrap();
        let c = cfg.cluster().unwrap();
        assert_eq!(c.transport, TransportKind::Mem);
        assert_eq!(c.recv_timeout.as_millis(), 30_000);
        assert!(c.elastic.is_none());
        assert!(c.pipeline, "send-early pipelining is on by default");
        assert_eq!(c.driver, DriverKind::Threaded);

        let cfg = Config::from_str_cfg(
            "transport=tcp\nport_base=9000\nrecv_timeout_ms=500\npipeline=false",
        )
        .unwrap();
        let c = cfg.cluster().unwrap();
        assert_eq!(c.transport, TransportKind::Tcp { port_base: 9000 });
        assert_eq!(c.recv_timeout.as_millis(), 500);
        assert!(!c.pipeline);

        let cfg =
            Config::from_str_cfg("runtime=reactor\nreactor_threads=3").unwrap();
        assert_eq!(cfg.cluster().unwrap().driver, DriverKind::Reactor { threads: 3 });
        let cfg = Config::from_str_cfg("runtime=reactor").unwrap();
        assert_eq!(cfg.cluster().unwrap().driver, DriverKind::Reactor { threads: 0 });

        assert!(Config::from_str_cfg("transport=carrier-pigeon")
            .unwrap()
            .cluster()
            .is_err());
        assert!(Config::from_str_cfg("transport=tcp\nport_base=70000")
            .unwrap()
            .cluster()
            .is_err());
    }

    #[test]
    fn elastic_keys_parse_and_validate() {
        // churn + checkpoints
        let cfg = Config::from_str_cfg(
            "churn=crash@12:2,leave@20:1\nckpt_every=5\nckpt_dir=/tmp/ck\n",
        )
        .unwrap();
        let e = cfg.elastic().unwrap().unwrap();
        assert_eq!(e.plan.events().len(), 2);
        assert_eq!(e.ckpt_every, 5);
        assert_eq!(e.ckpt_dir.as_deref(), Some(std::path::Path::new("/tmp/ck")));
        assert!(!e.skip_bootstrap);
        // crash plans insist on a durability directory
        assert!(Config::from_str_cfg("churn=crash@3:0")
            .unwrap()
            .elastic()
            .is_err());
        assert!(Config::from_str_cfg("ckpt_every=5").unwrap().elastic().is_err());
        // churn without crashes needs no ckpt_dir
        let e = Config::from_str_cfg("churn=leave@3:0")
            .unwrap()
            .elastic()
            .unwrap()
            .unwrap();
        assert!(e.ckpt_dir.is_none());
        // garbage spec
        assert!(Config::from_str_cfg("churn=dance@3:0").unwrap().elastic().is_err());
        // no keys → None
        assert!(Config::from_str_cfg("workers=4").unwrap().elastic().unwrap().is_none());
    }

    #[test]
    fn metrics_keys_parse_and_validate() {
        // Default: export off, path defaulted but unused.
        let (mode, _) = Config::from_str_cfg("").unwrap().metrics().unwrap();
        assert_eq!(mode, MetricsMode::Off);
        // Mode picks the default filename…
        let (mode, path) =
            Config::from_str_cfg("metrics=prom").unwrap().metrics().unwrap();
        assert_eq!(mode, MetricsMode::Prom);
        assert_eq!(path, "moniqua_metrics.prom");
        let (_, path) =
            Config::from_str_cfg("metrics=json").unwrap().metrics().unwrap();
        assert_eq!(path, "moniqua_metrics.json");
        // …and metrics_path overrides it.
        let (_, path) =
            Config::from_str_cfg("metrics=json\nmetrics_path=/tmp/m.json")
                .unwrap()
                .metrics()
                .unwrap();
        assert_eq!(path, "/tmp/m.json");
        assert!(Config::from_str_cfg("metrics=csv").unwrap().metrics().is_err());
    }

    #[test]
    fn byzantine_keys_parse_and_validate() {
        let cfg = Config::from_str_cfg(
            "workers=4\nbyz_workers=0,2\nbyz_mode=equivocate\nquarantine_strikes=5\n",
        )
        .unwrap();
        let b = cfg.byz().unwrap().unwrap();
        assert_eq!(b.workers, 0b101);
        assert_eq!(b.mode, ByzMode::Equivocate);
        assert_eq!(b.strike_limit, 5);
        // Defaults: flip mode, 3 strikes; flows into faults() and cluster().
        let cfg = Config::from_str_cfg("workers=4\nbyz_workers=1\n").unwrap();
        let b = cfg.byz().unwrap().unwrap();
        assert_eq!(b.mode, ByzMode::Flip);
        assert_eq!(b.strike_limit, 3);
        assert_eq!(cfg.faults().unwrap().byz, Some(b));
        assert_eq!(cfg.cluster().unwrap().byz, Some(b));
        // No byz_workers → None, and the satellite keys alone are loud errors.
        assert!(Config::from_str_cfg("workers=4").unwrap().byz().unwrap().is_none());
        assert!(Config::from_str_cfg("byz_mode=flip").unwrap().byz().is_err());
        assert!(Config::from_str_cfg("quarantine_strikes=2").unwrap().byz().is_err());
        // Out-of-range values: worker id >= n, zero strike budget, all byz.
        assert!(Config::from_str_cfg("workers=4\nbyz_workers=7").unwrap().byz().is_err());
        assert!(Config::from_str_cfg("workers=4\nbyz_workers=1\nquarantine_strikes=0")
            .unwrap()
            .byz()
            .is_err());
        assert!(Config::from_str_cfg("workers=2\nbyz_workers=0,1").unwrap().byz().is_err());
        assert!(Config::from_str_cfg("workers=4\nbyz_workers=1\nbyz_mode=gaslight")
            .unwrap()
            .byz()
            .is_err());
    }

    #[test]
    fn mix_keys_parse_and_validate() {
        let cfg = Config::from_str_cfg("").unwrap();
        assert_eq!(cfg.mix().unwrap(), MixPolicy::Mean);
        let cfg = Config::from_str_cfg("mix=median").unwrap();
        assert_eq!(cfg.mix().unwrap(), MixPolicy::Median);
        let cfg = Config::from_str_cfg("mix=clipped\nmix_clip=0.25").unwrap();
        assert_eq!(cfg.mix().unwrap(), MixPolicy::Clipped(0.25));
        assert!(Config::from_str_cfg("mix=clipped\nmix_clip=0")
            .unwrap()
            .mix()
            .is_err());
        assert!(Config::from_str_cfg("mix=trimmed").unwrap().mix().is_err());
    }

    #[test]
    fn theta_auto() {
        let cfg = Config::from_str_cfg("theta=auto").unwrap();
        assert!(matches!(
            cfg.theta_policy().unwrap(),
            ThetaPolicy::Theorem2 { .. }
        ));
    }
}
