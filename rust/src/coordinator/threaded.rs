//! Real-concurrency gossip runtime: each worker is a `std::thread`
//! exchanging Moniqua-coded messages over `mpsc` channels.
//!
//! The event-driven [`super::AsyncTrainer`] models wall-clock; this runtime
//! proves the protocol is actually *asynchronous-safe* — no global barrier,
//! workers make progress at their own pace, messages carry only the packed
//! codes (plus a tiny header), and recovery uses whatever local model the
//! receiver has at arrival time (the staleness AD-PSGD's analysis admits).
//!
//! tokio is unavailable offline; std threads + channels express the same
//! structure.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier};
use std::thread;

use crate::objectives::Objective;
use crate::quant::{packing, MoniquaCodec, QuantConfig};
use crate::rng::Pcg64;
use crate::topology::Topology;

/// A gossip message: packed Moniqua codes of the sender's model.
struct GossipMsg {
    #[allow(dead_code)] // diagnostic field (printed when debugging protocol issues)
    from: usize,
    round: u64,
    payload: Vec<u8>,
}

/// Result per worker thread.
#[derive(Clone, Debug)]
pub struct WorkerResult {
    pub worker: usize,
    pub steps: u64,
    pub final_params: Vec<f32>,
    pub bytes_sent: u64,
    pub msgs_received: u64,
}

/// Configuration for the threaded run.
#[derive(Clone)]
pub struct ThreadedConfig {
    pub topo: Topology,
    pub steps: u64,
    pub lr: f32,
    pub theta: f32,
    pub quant: QuantConfig,
    pub seed: u64,
}

/// Run decentralized asynchronous Moniqua training with one OS thread per
/// worker. Returns per-worker results (params should be near consensus).
pub fn run_threaded(cfg: ThreadedConfig, objective: &dyn Objective) -> Vec<WorkerResult> {
    let n = cfg.topo.n();
    let d = objective.dim();
    let adj = cfg.topo.adjacency();
    let init = objective.init();

    // channel mesh: txs[i] sends to worker i's inbox
    let mut txs: Vec<Sender<GossipMsg>> = Vec::with_capacity(n);
    let mut rxs: Vec<Option<Receiver<GossipMsg>>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        txs.push(tx);
        rxs.push(Some(rx));
    }
    let start = Arc::new(Barrier::new(n));

    let mut handles = Vec::with_capacity(n);
    for w in 0..n {
        let rx = rxs[w].take().unwrap();
        let peers: Vec<(usize, Sender<GossipMsg>)> = adj[w]
            .iter()
            .map(|&j| (j, txs[j].clone()))
            .collect();
        let mut objective = objective.box_clone();
        let init = init.clone();
        let cfg = cfg.clone();
        let start = Arc::clone(&start);
        handles.push(thread::spawn(move || {
            let codec = MoniquaCodec::from_theta(cfg.theta, &cfg.quant);
            let wire_len = packing::packed_len(d, cfg.quant.bits);
            let mut x = init;
            let mut grad = vec![0.0f32; d];
            let mut noise = vec![0.0f32; d];
            let mut recover = vec![0.0f32; d];
            let mut xhat_self = vec![0.0f32; d];
            let mut rng = Pcg64::new(cfg.seed, w as u64 ^ 0x7EAD);
            let mut bytes_sent = 0u64;
            let mut msgs_received = 0u64;
            start.wait();
            for step in 0..cfg.steps {
                // local gradient step
                objective.loss_grad(w, step, &x, &mut grad);
                for k in 0..d {
                    x[k] -= cfg.lr * grad[k];
                }
                // encode and push to one random neighbor (async gossip).
                // NOTE: shared randomness needs a common round index; async
                // workers don't share one, so each message carries its own
                // noise seed = (sender, step) and receivers only *decode*
                // (decoding needs no noise).
                let mut nrng = Pcg64::new(cfg.seed ^ step, w as u64);
                nrng.fill_uniform_f32(&mut noise);
                // Fused wrap→quantize→pack straight into the message buffer:
                // the owned Vec is the allocation the channel send needs
                // anyway; no intermediate Vec<u32> code vector exists.
                let mut payload = vec![0u8; wire_len];
                codec.encode_packed_into(&x, &noise, &mut payload);
                bytes_sent += payload.len() as u64;
                let (_, tx) = &peers[rng.below(peers.len() as u64) as usize];
                // peer may have exited already: ignore send failures.
                let _ = tx.send(GossipMsg { from: w, round: step, payload });

                // drain inbox; average with whatever arrived (AD-PSGD's
                // single-edge 1/2 averaging per message)
                while let Ok(msg) = rx.try_recv() {
                    msgs_received += 1;
                    codec.recover_packed_into(&msg.payload, &x, &mut recover);
                    // self-biased term w.r.t. our own model
                    let mut srng = Pcg64::new(cfg.seed ^ msg.round, w as u64);
                    srng.fill_uniform_f32(&mut noise);
                    codec.local_biased_into(&x, &noise, &mut xhat_self);
                    for k in 0..d {
                        x[k] += 0.5 * (recover[k] - xhat_self[k]);
                    }
                }
            }
            WorkerResult {
                worker: w,
                steps: cfg.steps,
                final_params: x,
                bytes_sent,
                msgs_received,
            }
        }));
    }
    drop(txs);
    let mut results: Vec<WorkerResult> =
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect();
    results.sort_by_key(|r| r.worker);
    results
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objectives::Quadratic;

    #[test]
    fn threads_converge_to_consensus_optimum() {
        let cfg = ThreadedConfig {
            topo: Topology::Ring(4),
            steps: 400,
            lr: 0.1,
            theta: 2.0,
            quant: QuantConfig::stochastic(8),
            seed: 9,
        };
        let obj = Quadratic::new(16, 1.0, 0.0, 4, 1); // optimum at 0.5
        let results = run_threaded(cfg, &obj);
        assert_eq!(results.len(), 4);
        for r in &results {
            assert!(r.bytes_sent > 0);
            for &v in &r.final_params {
                assert!((v - 0.5).abs() < 0.1, "worker {} v {v}", r.worker);
            }
        }
        // At least some gossip actually happened.
        let total_msgs: u64 = results.iter().map(|r| r.msgs_received).sum();
        assert!(total_msgs > 100, "msgs {total_msgs}");
    }

    #[test]
    fn no_deadlock_on_star_topology() {
        let cfg = ThreadedConfig {
            topo: Topology::Star(5),
            steps: 50,
            lr: 0.05,
            theta: 2.0,
            quant: QuantConfig::stochastic(4),
            seed: 2,
        };
        let obj = Quadratic::new(8, 1.0, 0.0, 5, 1);
        let results = run_threaded(cfg, &obj);
        assert_eq!(results.len(), 5);
    }
}
