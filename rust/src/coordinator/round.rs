//! The per-worker round protocol, factored out of the drivers: one
//! [`RoundStateMachine`] is *exactly* the body of the old thread-per-worker
//! `run_node` loop, re-expressed as an explicit state machine so the same
//! code drives both runtimes:
//!
//! * the **threaded** driver ([`cluster`](super::cluster)) wraps one
//!   machine per OS thread and parks in blocking `recv` whenever the
//!   machine reports it is waiting;
//! * the **reactor** driver ([`reactor`](super::reactor)) multiplexes many
//!   machines onto a few driver threads, feeding each machine the frames
//!   its nonblocking transport has ready and advancing it until it reports
//!   [`MachineStatus::Waiting`] again.
//!
//! The machine owns every piece of per-worker state the old loop kept on
//! its stack — model, gradient buffer, parked frames, bootstrap queue,
//! frame log, crash cursor — and exposes three entry points:
//! [`drive`](RoundStateMachine::drive) (run until blocked or done),
//! [`accept_frame`](RoundStateMachine::accept_frame) (hand it one inbound
//! frame), and the failure constructors
//! ([`timeout_failure`](RoundStateMachine::timeout_failure),
//! [`recv_failure`](RoundStateMachine::recv_failure)) that produce the
//! *same* typed [`WorkerFailure`] strings the threaded runtime always
//! produced (pinned by `tests/barrier_deadline.rs`).
//!
//! Bitwise safety: the machine performs the identical sequence of engine
//! calls (`node_send` → `loss_grad` → `node_recv`), in the identical
//! order, with identical [`StepCtx`] values, as the old inline loop — the
//! refactor moves control flow, not arithmetic. `tests/reactor_equivalence.rs`
//! pins reactor ≡ threaded ≡ lockstep for the algorithm matrix.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::TrainConfig;
use crate::adversary::{self, ByzMode};
use crate::algorithms::{CommScope, Inbox, MixPolicy, SendPhase, StepCtx, SyncAlgorithm};
use crate::elastic::membership::{epoch_at, epoch_index, Epoch};
use crate::elastic::snapshot::{
    load_checkpoint, write_checkpoint, FrameLog, NodeTrace, Snapshot,
};
use crate::objectives::Objective;
use crate::telemetry::{Clock, Counter, Hist, Telemetry};
use crate::transport::{Frame, FrameKind, Transport, TransportError, WakeHandle};

/// How often a worker blocked in a barrier/bootstrap wait wakes to poll
/// the cluster's [`AbortLatch`]: the bound on how long a sibling outlives
/// the originating failure. (The reactor does better — the latch wakes its
/// shards directly — but the threaded driver's blocking `recv` keeps this
/// tick as its documented fallback.)
pub(crate) const ABORT_POLL_TICK: Duration = Duration::from_millis(50);

/// Typed round failure a worker hands back instead of panicking: a barrier
/// deadline expiry, a transport error, or an abort triggered by a sibling.
/// [`ClusterTrainer::run`](super::cluster::ClusterTrainer::run) joins
/// these and names the originating worker.
#[derive(Clone, Debug)]
pub struct WorkerFailure {
    pub worker: usize,
    pub round: u64,
    pub reason: String,
}

impl WorkerFailure {
    pub(crate) fn new(worker: usize, round: u64, reason: String) -> Self {
        WorkerFailure { worker, round, reason }
    }
}

impl std::fmt::Display for WorkerFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "worker {} round {}: {}", self.worker, self.round, self.reason)
    }
}

/// Shared round-failure latch: the first worker to fail records itself
/// here; every sibling's recv loop polls [`Self::tripped`] once per
/// [`ABORT_POLL_TICK`] (and every reactor shard is woken directly through
/// its registered [`WakeHandle`]) and aborts instead of burning its own
/// full `recv_timeout` on frames that will never arrive.
#[derive(Default)]
pub(crate) struct AbortLatch {
    tripped: AtomicBool,
    origin: Mutex<Option<WorkerFailure>>,
    /// Reactor-shard wake tokens: tripping the latch wakes every parked
    /// shard immediately, so the abort propagates within one poll
    /// iteration instead of one park tick.
    wakers: Mutex<Vec<Arc<WakeHandle>>>,
}

impl AbortLatch {
    pub(crate) fn tripped(&self) -> bool {
        self.tripped.load(Ordering::Acquire)
    }

    /// Register a shard's wake token so [`Self::trip`] can interrupt its
    /// park instead of waiting for the next poll tick.
    pub(crate) fn register_waker(&self, w: &Arc<WakeHandle>) {
        self.wakers.lock().unwrap().push(Arc::clone(w));
    }

    /// Record `failure` as the origin if the latch is still clear; either
    /// way the latch is tripped and `failure` is handed back so callers
    /// can `return Err(latch.trip(f))`.
    pub(crate) fn trip(&self, failure: WorkerFailure) -> WorkerFailure {
        {
            let mut origin = self.origin.lock().unwrap();
            if origin.is_none() {
                *origin = Some(failure.clone());
            }
        }
        self.tripped.store(true, Ordering::Release);
        for w in self.wakers.lock().unwrap().iter() {
            w.wake();
        }
        failure
    }

    pub(crate) fn origin(&self) -> Option<WorkerFailure> {
        self.origin.lock().unwrap().clone()
    }

    /// The reason string a sibling reports when it aborts out of a wait
    /// because someone else tripped the latch; `how` names the wait
    /// granularity ("recv tick" for the threaded driver, "poll iteration"
    /// for the reactor).
    fn sibling_reason(&self, how: &str) -> String {
        match self.origin() {
            Some(o) => format!(
                "aborted within one {how}: sibling worker {} failed round {}",
                o.worker, o.round
            ),
            None => format!("aborted within one {how} by the cluster latch"),
        }
    }

    /// A sibling's failure for aborting out of a blocking wait after
    /// someone else tripped the latch.
    pub(crate) fn sibling_abort(&self, worker: usize, round: u64) -> WorkerFailure {
        WorkerFailure::new(worker, round, self.sibling_reason("recv tick"))
    }

    /// Reactor-flavored sibling abort: same origin attribution, but the
    /// wait unit is the shard's poll iteration.
    pub(crate) fn sibling_abort_via(
        &self,
        worker: usize,
        round: u64,
        how: &str,
    ) -> WorkerFailure {
        WorkerFailure::new(worker, round, self.sibling_reason(how))
    }
}

/// One deadline-bounded, abort-aware transport wait.
pub(crate) enum BarrierRecv {
    Frame(Frame),
    /// The caller's deadline passed without a frame.
    TimedOut,
    /// A sibling tripped the [`AbortLatch`]; stop waiting.
    Aborted,
    Failed(TransportError),
}

/// Wait for one frame until `deadline`, polling `abort` once per
/// [`ABORT_POLL_TICK`]. The deadline is the *caller's* (computed once per
/// barrier), so consecutive calls consume one shared budget — an arriving
/// frame never resets the clock.
pub(crate) fn recv_until(
    transport: &mut dyn Transport,
    deadline: Instant,
    abort: &AbortLatch,
) -> BarrierRecv {
    // lint: allow(wall_clock) — deadline arithmetic gates *when* a frame is
    // handed to the caller, never which frame or its bytes.
    loop {
        if abort.tripped() {
            return BarrierRecv::Aborted;
        }
        let now = Instant::now();
        if now >= deadline {
            return BarrierRecv::TimedOut;
        }
        let wait = ABORT_POLL_TICK.min(deadline - now);
        match transport.recv(wait) {
            Ok(f) => return BarrierRecv::Frame(f),
            Err(TransportError::Timeout) => continue,
            Err(e) => return BarrierRecv::Failed(e),
        }
    }
}

/// Close out one barrier/bootstrap wait: observe its duration into the
/// matching histogram and clear the stamp. Shared by both drivers (the
/// threaded `run_node` loop and the reactor's `drive_shard`), so the wait
/// taxonomy cannot drift between them. No-ops when `wait_start` is empty
/// or telemetry is disabled.
pub(crate) fn observe_wait_end(
    telemetry: &Telemetry,
    clock: &Clock,
    wait_start: &mut Option<(WaitKey, u64)>,
) {
    if let Some((key, t0)) = wait_start.take() {
        let dt = clock.now_ns().saturating_sub(t0);
        match key {
            WaitKey::Barrier { .. } => telemetry.observe(Hist::BarrierWaitNs, dt),
            WaitKey::Bootstrap { .. } => telemetry.observe(Hist::BootstrapWaitNs, dt),
        }
    }
}

/// Everything one worker brings home.
pub(crate) struct NodeResult {
    pub(crate) worker: usize,
    pub(crate) final_x: Vec<f32>,
    pub(crate) trace: NodeTrace,
}

/// Everything a node needs beyond its engine/transport/objective.
pub(crate) struct NodeSpec<'a> {
    pub(crate) cfg: TrainConfig,
    pub(crate) recv_timeout: Duration,
    pub(crate) algo_id: u16,
    pub(crate) wire_bits: u16,
    pub(crate) scope: CommScope,
    pub(crate) epochs: &'a [Epoch],
    /// Sorted rounds at which this worker crashes.
    pub(crate) crashes: Vec<u64>,
    /// Checkpoint cadence (0 = never; crashes recover from genesis).
    pub(crate) ckpt_every: u64,
    pub(crate) ckpt_dir: Option<PathBuf>,
    pub(crate) skip_bootstrap: bool,
    /// Send-early pipelining: PreGradient engines ship their round frame
    /// before the gradient step (see `ClusterConfig::pipeline`).
    pub(crate) pipeline: bool,
    /// Recording handle on this worker's shard (disabled when the run has
    /// no registry). Telemetry is observation-only: nothing recorded here
    /// ever feeds back into model bytes (DESIGN.md §Telemetry).
    pub(crate) telemetry: Telemetry,
    /// Time source for duration histograms: monotonic under the cluster
    /// drivers, [`Clock::Disabled`] when telemetry is off.
    pub(crate) clock: Clock,
    /// The base topology — what [`adversary::excised_matrix`] re-derives
    /// the gossip row over when a peer is quarantined.
    pub(crate) topo: crate::topology::Topology,
    /// `Some(mode)` makes THIS worker Byzantine: its send half emits the
    /// mode's corrupted/extra traffic instead of (or on top of) the honest
    /// broadcast. Fault injection only — the defense below never reads it.
    pub(crate) byz: Option<ByzMode>,
    /// Strikes a sender may accumulate before this observer excises it.
    /// 0 disables quarantine (strikes are still counted).
    pub(crate) strike_limit: u32,
    /// Append/verify the machine-level round-bound seal on Data payloads.
    /// On for raw-f32 engines under `verify_hash`/`verify_wire`; off for
    /// the Moniqua family, whose §6 digest already covers the wire.
    pub(crate) seal: bool,
}

/// This worker's peer set during an epoch.
pub(crate) fn peers_of(ep: &Epoch, i: usize, scope: CommScope) -> Vec<usize> {
    match scope {
        CommScope::Neighbors => ep.adj[i].clone(),
        CommScope::All => (0..ep.active.len())
            .filter(|&j| j != i && ep.active[j])
            .collect(),
    }
}

/// First round ≥ `from` in which worker `i` is active, if any.
pub(crate) fn next_active_round(
    epochs: &[Epoch],
    i: usize,
    from: u64,
    steps: u64,
) -> Option<u64> {
    let mut round = from;
    while round < steps {
        let ep = epoch_at(epochs, round);
        if ep.active[i] {
            return Some(round);
        }
        // jump to the next epoch boundary
        round = epochs
            .iter()
            .map(|e| e.start)
            .find(|&s| s > round)?;
    }
    None
}

/// Learning rate in effect entering `round` (all scheduled decays at
/// earlier rounds applied).
pub(crate) fn lr_at(cfg: &TrainConfig, round: u64) -> f32 {
    let mut lr = cfg.lr;
    for k in 0..round {
        if cfg.decay_at.contains(&k) {
            lr *= cfg.decay_factor;
        }
    }
    lr
}

/// Remove and return the parked frame for `(round, sender)`, if present.
/// Linear scan + `swap_remove`: the parked set holds at most one frame per
/// peer in steady state, and replay consumption order is keyed, not
/// positional.
fn take_parked(parked: &mut Vec<Frame>, round: u64, sender: usize) -> Option<Frame> {
    parked
        .iter()
        .position(|f| f.round == round && f.sender as usize == sender)
        .map(|at| parked.swap_remove(at))
}

/// Shared sanity gate for every Data frame before it can reach an engine:
/// same algorithm and same bit budget, both enforced loudly — a
/// cross-wired frame must die, never be averaged. Applied on the live recv
/// path and on crash-replay frames from the log.
///
/// Deliberately NOT enforced here: peer-set membership under `Neighbors`
/// scope. A neighbor that convicts a shared peer rewires its gossip row
/// first and starts bridging immediately, so its frames can arrive while
/// this observer's own peer set still predates the rewire. Those frames
/// are *parked* (never delivered to the barrier) until the observer's own
/// conviction admits the sender — see [`RoundStateMachine::accept_frame`].
/// Under `All` scope the peer set never grows, so a non-peer sender there
/// is corruption and still dies loudly.
fn validate_data_frame(i: usize, f: &Frame, spec: &NodeSpec<'_>) {
    let from = f.sender as usize;
    assert_eq!(f.algo, spec.algo_id, "worker {i}: cross-algorithm frame from {from}");
    assert_eq!(f.bits, spec.wire_bits, "worker {i}: bit-budget mismatch from {from}");
    if spec.scope == CommScope::All {
        let f_ep = epoch_at(spec.epochs, f.round);
        assert!(
            f_ep.active[from] && from != i,
            "worker {i}: round-{} frame from non-peer {from}",
            f.round
        );
    }
}

/// What the machine is blocked on when [`RoundStateMachine::drive`]
/// returns `Waiting`: the driver should feed it frames (via
/// [`accept_frame`](RoundStateMachine::accept_frame)) until the key
/// changes or the deadline the driver keeps for this key expires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum WaitKey {
    /// Waiting for round-`round` data frames from peers.
    Barrier { round: u64 },
    /// Waiting for this worker's (re)join bootstrap frame.
    Bootstrap { round: u64 },
}

/// Result of one [`RoundStateMachine::drive`] call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum MachineStatus {
    /// Blocked until more frames arrive; the key identifies the wait so
    /// drivers can keep one deadline per barrier (never per frame).
    Waiting(WaitKey),
    /// Every round is complete; call
    /// [`into_result`](RoundStateMachine::into_result).
    Done,
}

/// Where the machine resumes on the next `drive` call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    /// Top of the round loop: membership, crash, rewire checks.
    RoundEntry,
    /// Epoch-opening bootstrap handshake in progress.
    AwaitBootstrap,
    /// Frame sent, gradient done; collecting the round barrier.
    AwaitBarrier,
    /// All rounds complete (or this worker never activates).
    Finished,
}

/// Round state carried across the barrier wait: everything `finish_round`
/// needs that was computed before the barrier. (`StepCtx` is reconstructed
/// at mix time from `seed`/`rho`/`g_inf`, all of which are unchanged
/// between the gradient and the mix.)
struct PendingRound {
    loss: f64,
    grad_wall: f64,
    frame: Frame,
    send_compute: f64,
}

/// One worker's whole life as a resumable state machine: send (pipelined)
/// → gradient → frame barrier → recv, for every round it is a member of,
/// with crash/restore and join/leave handling when an elastic plan is
/// active. Expected runtime failures (broadcast errors) come back as typed
/// [`WorkerFailure`]s — the *driver* owns deadlines and the abort latch —
/// while protocol violations (corrupt frames, foreign checkpoints) stay
/// panics: a corrupt cluster must die loudly.
pub(crate) struct RoundStateMachine<'a> {
    i: usize,
    d: usize,
    seed: u64,
    engine: Box<dyn SyncAlgorithm>,
    objective: Box<dyn Objective>,
    spec: NodeSpec<'a>,
    phase: Phase,
    /// Next unprocessed entry of the current epoch's join list.
    join_ix: usize,
    x: Vec<f32>,
    grad: Vec<f32>,
    /// Round-local buffers come out of a per-node arena (§Perf): after the
    /// warm-up rounds every checkout is recycled capacity, so a
    /// steady-state round allocates nothing (tests/alloc_discipline.rs).
    arena: crate::mem::ScratchArena,
    payload: Vec<u8>,
    /// Data frames from workers running ahead of us. A peer can run at
    /// most one round ahead (it needs our round-k frame to pass its own
    /// round-k barrier), so this stays tiny in steady state; crash replay
    /// preloads the whole frame log into it. Also holds early *bridge*
    /// frames from senders not (yet) in our peer set — a neighbor that
    /// convicted a shared peer rewires and bridges before we do; frames
    /// for rounds our own rewire never admitted are recycled at each
    /// round boundary.
    parked: Vec<Frame>,
    /// Bootstrap frames waiting for their join round, keyed by round.
    boot_pending: BTreeMap<u64, Frame>,
    /// This round's barrier frames, reused across rounds (payload buffers
    /// are recycled into the transport's pool after the recv half).
    got: Vec<Frame>,
    /// Peer list of the current epoch (recomputed only at epoch
    /// boundaries, not per round), shrunk further by quarantine rewires.
    peers: Vec<usize>,
    /// Who this worker broadcasts to. Starts equal to `peers`; a
    /// quarantine rewire *adds* bridge peers but never removes the
    /// convicted one — excision is one-way (we stop averaging a convicted
    /// peer but keep serving it frames), so a conviction can never wedge
    /// the convicted node's barrier.
    send_peers: Vec<usize>,
    /// Per-sender strike count across every reject class (seal, replay,
    /// equivocation, engine §6 digest). Reaching `spec.strike_limit`
    /// convicts. Not checkpointed: a crash resets the local ledger, and
    /// the offender simply re-earns its strikes.
    strikes: Vec<u32>,
    /// `(round, sender)` pairs whose frame the seal gate rejected: a
    /// reject *satisfies* that round's barrier slot (the mix substitutes
    /// the local model), so one bad frame costs one strike, not a timeout.
    /// A ledger, not a per-sender scalar, because several rounds' rejects
    /// can be outstanding at once (a fast adversary's round-(r+1) frame
    /// arrives before our round-r barrier closes) and because crash replay
    /// must re-satisfy the slots of rejected frames that were deliberately
    /// never WAL-logged. Pruned once no live barrier or replay can revisit
    /// an entry; deliberately survives [`crash_restore`](Self::crash_restore).
    reject_log: Vec<(u64, u16)>,
    quarantined: Vec<bool>,
    /// Senders substituted in this round's inbox (rejected, frame absent).
    subst: Vec<usize>,
    /// Drain buffer for the engine's §6 digest strikes.
    strike_scratch: Vec<u16>,
    /// Replay mode: the previous round's own frame, kept for re-broadcast.
    byz_prev: Option<Frame>,
    /// Wrap mode: exact model bytes to restore after the perturbed encode.
    byz_save: Vec<f32>,
    trace: NodeTrace,
    lr: f32,
    g_inf: f64,
    /// Next unconsumed entry of `spec.crashes`.
    crash_ix: usize,
    framelog: Option<FrameLog>,
    /// Rounds < live_from are replays after a crash: sends are suppressed
    /// (their frames already crossed the wire) and the barrier is
    /// satisfied purely from the logged frames.
    live_from: u64,
    cur_epoch: usize,
    round: u64,
    start_round: u64,
    pending: Option<PendingRound>,
}

impl<'a> RoundStateMachine<'a> {
    pub(crate) fn new(
        i: usize,
        engine: Box<dyn SyncAlgorithm>,
        objective: Box<dyn Objective>,
        spec: NodeSpec<'a>,
    ) -> Self {
        let d = objective.dim();
        let steps = spec.cfg.steps;
        let seed = spec.cfg.seed;
        let x = objective.init();
        let (phase, start_round, trace) =
            match next_active_round(spec.epochs, i, 0, steps) {
                // Provisioned slot that never activates: idle for the run.
                None => (Phase::Finished, steps, NodeTrace::starting_at(steps)),
                Some(s) => {
                    let mut t = NodeTrace::starting_at(s);
                    t.reserve((steps - s) as usize);
                    (Phase::RoundEntry, s, t)
                }
            };
        // The receive-side WAL only exists to serve this worker's own
        // crash replays; workers with no scheduled crash skip the
        // per-frame disk write entirely.
        let framelog = if spec.crashes.is_empty() || phase == Phase::Finished {
            None
        } else {
            spec.ckpt_dir
                .as_ref()
                .map(|dir| FrameLog::create(dir, i).expect("create frame log"))
        };
        let lr = lr_at(&spec.cfg, start_round);
        let mut arena = crate::mem::ScratchArena::new();
        let payload = arena.take_bytes();
        let n = spec.cfg.workers;
        let mut machine = RoundStateMachine {
            i,
            d,
            seed,
            engine,
            objective,
            spec,
            phase,
            join_ix: 0,
            x,
            grad: vec![0.0f32; d],
            arena,
            payload,
            parked: Vec::new(),
            boot_pending: BTreeMap::new(),
            got: Vec::new(),
            peers: Vec::new(),
            send_peers: Vec::new(),
            strikes: vec![0; n],
            reject_log: Vec::new(),
            quarantined: vec![false; n],
            subst: Vec::with_capacity(n),
            strike_scratch: Vec::with_capacity(n),
            byz_prev: None,
            byz_save: Vec::new(),
            trace,
            lr,
            g_inf: 0.0,
            crash_ix: 0,
            framelog,
            live_from: start_round,
            cur_epoch: usize::MAX,
            round: start_round,
            start_round,
            pending: None,
        };
        machine.apply_engine_config();
        machine
    }

    /// Engine knobs that are configuration, not state: applied at
    /// construction and re-applied after the crash-replay engine rebuild
    /// (they are not part of the snapshot). Support is validated by the
    /// driver before any machine exists, so a refusal here is a bug.
    fn apply_engine_config(&mut self) {
        if self.spec.seal {
            assert!(
                self.engine.set_verify_wire(true),
                "engine '{}' refused verify_wire (validated at construction)",
                self.engine.name()
            );
        }
        if self.spec.cfg.mix != MixPolicy::Mean {
            assert!(
                self.engine.set_mix(self.spec.cfg.mix),
                "engine '{}' refused mix={} (validated at construction)",
                self.engine.name(),
                self.spec.cfg.mix.name()
            );
        }
    }

    pub(crate) fn worker(&self) -> usize {
        self.i
    }

    pub(crate) fn round(&self) -> u64 {
        self.round
    }

    /// This worker's telemetry handle (shard = worker index) — drivers
    /// borrow it to observe barrier/bootstrap waits on the machine's shard.
    pub(crate) fn telemetry(&self) -> &Telemetry {
        &self.spec.telemetry
    }

    /// The clock the machine's spec carries (monotonic under the cluster
    /// drivers, virtual under DES, disabled in unit tests).
    pub(crate) fn clock(&self) -> &Clock {
        &self.spec.clock
    }

    /// The epoch covering the machine's current round. `spec.epochs` is a
    /// borrowed slice, so the returned reference is independent of `self`.
    fn cur_ep(&self) -> &'a Epoch {
        let epochs = self.spec.epochs;
        &epochs[epoch_index(epochs, self.round)]
    }

    fn failure(&self, reason: String) -> WorkerFailure {
        WorkerFailure::new(self.i, self.round, reason)
    }

    /// The round barrier holds when every peer slot is satisfied — by a
    /// held frame or by the gate's rejection of that sender's frame for
    /// this round. The honest fast path is the same length check as ever.
    // lint: hot-path
    fn barrier_complete(&self) -> bool {
        if self.got.len() == self.peers.len() {
            return true;
        }
        self.peers.iter().all(|&p| {
            self.was_rejected(self.round, p)
                || self.got.iter().any(|f| f.sender as usize == p)
        })
    }

    /// Whether the seal gate rejected `p`'s frame for `round` (the reject
    /// satisfied that round's barrier slot). Empty ledger — every honest
    /// run — makes this free.
    // lint: hot-path
    fn was_rejected(&self, round: u64, p: usize) -> bool {
        self.reject_log.iter().any(|&(r, s)| r == round && s as usize == p)
    }

    /// Whether `from` is in this machine's *current* recv set: the epoch
    /// peers, minus quarantine excisions, plus bridge peers a rewire
    /// added. Frames from anyone else are parked, never delivered to the
    /// barrier — see [`accept_frame`](Self::accept_frame).
    // lint: hot-path
    fn is_recv_peer(&self, from: usize) -> bool {
        self.peers.contains(&from)
    }

    /// The `(round, sender)` pairs the current barrier is still waiting
    /// on. A peer whose round frame was *rejected* by the defense gate is
    /// not missing: the gate satisfied the barrier for it and the mix
    /// substitutes the local model.
    fn missing_pairs(&self) -> Vec<(u64, usize)> {
        self.peers
            .iter()
            .filter(|&&p| !self.was_rejected(self.round, p))
            .filter(|&&p| !self.got.iter().any(|f| f.sender as usize == p))
            .map(|&p| (self.round, p))
            .collect()
    }

    // lint: hot-path
    fn note_strike(&mut self, from: usize) {
        if from < self.strikes.len() {
            self.strikes[from] += 1;
        }
    }

    /// Excise every convicted peer from this observer's gossip row:
    /// re-derive the communication matrix over the survivors (reusing the
    /// elastic-membership machinery), swap it into the engine, and adopt
    /// the new adjacency row as the recv set. The send set only *grows*
    /// (bridge peers) — convicted peers are still served frames so a
    /// conviction never wedges anyone's barrier, at the cost of wasted
    /// egress.
    // lint: cold
    fn apply_quarantine(&mut self) -> Result<(), WorkerFailure> {
        if self.spec.epochs.len() > 1 {
            return Err(self.failure(
                "quarantine cannot re-derive the gossip row under an \
                 elastic membership plan"
                    .into(),
            ));
        }
        match self.spec.scope {
            CommScope::All => {
                self.peers.retain(|&p| !self.quarantined[p]);
                if self.peers.is_empty() {
                    return Err(self.failure(
                        "quarantine leaves fewer than 2 workers; quorum lost".into(),
                    ));
                }
            }
            CommScope::Neighbors => {
                let (matrix, adj) =
                    adversary::excised_matrix(&self.spec.topo, &self.quarantined)
                        .map_err(|e| {
                            self.failure(format!("quarantine rewire failed: {e:#}"))
                        })?;
                if !self.engine.swap_matrix(&matrix) {
                    return Err(self.failure(format!(
                        "engine '{}' cannot swap matrices; quarantine requires a \
                         swap-capable engine",
                        self.engine.name()
                    )));
                }
                let new_peers = &adj[self.i];
                for &p in new_peers {
                    if !self.send_peers.contains(&p) {
                        self.send_peers.push(p);
                    }
                }
                self.send_peers.sort_unstable();
                self.peers.clear();
                self.peers.extend_from_slice(new_peers);
            }
        }
        Ok(())
    }

    /// Advance until the machine either completes every round or blocks
    /// on inbound frames. Drivers loop: `drive` → on `Waiting`, deliver
    /// frames through [`accept_frame`] (enforcing their own deadline per
    /// [`WaitKey`]) → `drive` again.
    pub(crate) fn drive(
        &mut self,
        transport: &mut dyn Transport,
    ) -> Result<MachineStatus, WorkerFailure> {
        loop {
            match self.phase {
                Phase::RoundEntry => {
                    let steps = self.spec.cfg.steps;
                    if self.round >= steps {
                        self.phase = Phase::Finished;
                        continue;
                    }
                    let epochs = self.spec.epochs;
                    let ep_idx = epoch_index(epochs, self.round);
                    let ep = &epochs[ep_idx];
                    if !ep.active[self.i] {
                        // We left the cohort; either rejoin at a later
                        // epoch or retire.
                        match next_active_round(epochs, self.i, self.round, steps) {
                            Some(r) => {
                                for k in self.round..r {
                                    if self.spec.cfg.decay_at.contains(&k) {
                                        self.lr *= self.spec.cfg.decay_factor;
                                    }
                                }
                                self.round = r;
                                continue;
                            }
                            None => {
                                self.phase = Phase::Finished;
                                continue;
                            }
                        }
                    }

                    // Scheduled crash: lose everything, restore, replay.
                    if self.round >= self.live_from
                        && self.spec.crashes.get(self.crash_ix) == Some(&self.round)
                    {
                        self.crash_ix += 1;
                        self.crash_restore();
                        continue;
                    }

                    // Reconfiguration barrier: wire the engine for this
                    // epoch.
                    if ep_idx != self.cur_epoch {
                        if epochs.len() > 1 {
                            assert!(
                                self.engine.swap_matrix(&ep.matrix),
                                "engine '{}' refused a matrix swap (validated at construction)",
                                self.engine.name()
                            );
                        }
                        // Peer set is a pure function of the epoch:
                        // compute it once here instead of cloning the
                        // adjacency row every round.
                        self.peers = peers_of(ep, self.i, self.spec.scope);
                        self.send_peers.clear();
                        self.send_peers.extend_from_slice(&self.peers);
                        self.cur_epoch = ep_idx;
                        // A rewire resets the gossip row to the epoch's;
                        // standing convictions must be re-excised (the
                        // post-crash re-entry lands here too).
                        if self.quarantined.iter().any(|&q| q) {
                            self.apply_quarantine()?;
                        }
                    }
                    self.join_ix = 0;
                    self.phase = Phase::AwaitBootstrap;
                }
                Phase::AwaitBootstrap => {
                    if self.advance_joins(transport)? {
                        return Ok(MachineStatus::Waiting(WaitKey::Bootstrap {
                            round: self.round,
                        }));
                    }
                    self.begin_round_work(transport)?;
                    self.phase = Phase::AwaitBarrier;
                }
                Phase::AwaitBarrier => {
                    if !self.barrier_complete() {
                        return Ok(MachineStatus::Waiting(WaitKey::Barrier {
                            round: self.round,
                        }));
                    }
                    self.finish_round(transport)?;
                    self.round += 1;
                    self.phase = Phase::RoundEntry;
                }
                Phase::Finished => return Ok(MachineStatus::Done),
            }
        }
    }

    /// The epoch-opening bootstrap handshake (duty sends and join
    /// adoption). Returns `true` when the machine must wait for its own
    /// bootstrap frame before the round can start; the handshake resumes
    /// at the same join entry once the frame lands in `boot_pending`
    /// (joiner ≠ bootstrapper per plan validation, so no duty send can
    /// re-run).
    fn advance_joins(
        &mut self,
        transport: &mut dyn Transport,
    ) -> Result<bool, WorkerFailure> {
        let ep = self.cur_ep();
        if self.round != ep.start {
            return Ok(false);
        }
        while self.join_ix < ep.joins.len() {
            let (joiner, boot) = ep.joins[self.join_ix];
            if boot == self.i {
                // Our duty: ship the joiner one full-precision model so
                // its decode reference is inside the cohort's θ ball.
                // (During replay the pre-crash incarnation already sent
                // it; count it once, transmit nothing.)
                let mut model_bytes = Vec::with_capacity(4 * self.d);
                crate::algorithms::common::put_f32s(&mut model_bytes, &self.x);
                let bf = Frame {
                    round: self.round,
                    sender: self.i as u16,
                    algo: self.spec.algo_id,
                    bits: 32,
                    kind: FrameKind::Bootstrap,
                    theta: 0.0,
                    payload: model_bytes,
                };
                if self.round >= self.live_from {
                    transport.send(joiner, &bf).map_err(|e| {
                        self.failure(format!("bootstrap send failed: {e}"))
                    })?;
                }
                self.trace.frames_sent += 1;
                self.trace.bytes_sent += bf.encoded_len() as u64;
            }
            if joiner == self.i {
                // The frame may already be parked (it overtook us while
                // we were in an earlier barrier, or came from the crash
                // replay log); otherwise block for it through the driver.
                let bf = if let Some(f) = self.boot_pending.remove(&self.round) {
                    f
                } else if self.round < self.live_from {
                    panic!(
                        "worker {}: replay log is missing the round-{} \
                         bootstrap frame from worker {}",
                        self.i, self.round, boot
                    )
                } else {
                    return Ok(true);
                };
                assert_eq!(
                    bf.sender as usize, boot,
                    "worker {}: bootstrap from unexpected sender",
                    self.i
                );
                assert_eq!(
                    bf.bits, 32,
                    "worker {}: bootstrap must be full precision",
                    self.i
                );
                assert_eq!(bf.payload.len(), 4 * self.d, "bootstrap payload size");
                if self.spec.skip_bootstrap {
                    // TESTING ONLY: consume the frame but keep the stale
                    // model — the θ-proximity violation the negative test
                    // demonstrates.
                } else {
                    crate::algorithms::common::read_f32s_into(&bf.payload, &mut self.x);
                }
            }
            self.join_ix += 1;
        }
        Ok(false)
    }

    /// Everything between the handshake and the barrier: decay, the
    /// (possibly pipelined) send half, the local gradient, and barrier
    /// setup from already-parked frames.
    fn begin_round_work(
        &mut self,
        transport: &mut dyn Transport,
    ) -> Result<(), WorkerFailure> {
        // lint: allow(wall_clock) — the gradient timer feeds per-node perf
        // accounting only; model bytes are unaffected.
        if self.spec.cfg.decay_at.contains(&self.round) {
            self.lr *= self.spec.cfg.decay_factor;
        }

        // Pipelined send half (PreGradient engines): engines whose payload
        // does not read this round's gradient ship their frame *before*
        // the gradient step, so the frame crosses the wire while
        // `loss_grad` runs. The empty gradient slice is a tripwire — a
        // PreGradient engine that reads it dies loudly instead of silently
        // consuming stale data. `ctx.g_inf` is the pre-round running max
        // there, which is safe because the only g_inf consumer is the
        // Theorem-2 θ policy this runtime refuses at construction.
        let pre_send =
            self.spec.pipeline && self.engine.send_phase() == SendPhase::PreGradient;
        let mut sent: Option<(Frame, f64)> = None;
        if pre_send {
            sent = Some(self.send_half(transport, true)?);
        }

        // Local gradient. Node-local running max — Trainer's global
        // version only feeds the Theorem-2 θ policy, which this runtime
        // refuses.
        let t0 = Instant::now();
        let loss = self
            .objective
            .loss_grad(self.i, self.round, &self.x, &mut self.grad);
        self.g_inf = self.g_inf.max(crate::linalg::norm_inf(&self.grad) as f64);
        let grad_wall = t0.elapsed().as_secs_f64();
        // Reuses the perf-accounting timer above — telemetry adds no new
        // clock reads on this path.
        self.spec
            .telemetry
            .observe(Hist::GradComputeNs, (grad_wall * 1e9) as u64);

        // Send half (PostGradient engines, or pipelining off).
        let (frame, send_compute) = match sent.take() {
            Some(s) => s,
            None => self.send_half(transport, false)?,
        };
        self.pending = Some(PendingRound { loss, grad_wall, frame, send_compute });

        // Round barrier from the frames themselves: seed it with frames
        // that already overtook us.
        self.got.clear();
        for k in 0..self.peers.len() {
            let p = self.peers[k];
            if let Some(f) = take_parked(&mut self.parked, self.round, p) {
                self.got.push(f);
            }
        }
        if self.round < self.live_from && self.got.len() < self.peers.len() {
            // Rejected frames are deliberately absent from the log; the
            // reject ledger re-satisfies their slots, so only genuinely
            // missing pairs are fatal.
            let missing = self.missing_pairs();
            if !missing.is_empty() {
                panic!(
                    "worker {}: replay log is missing frames {missing:?} for round {} \
                     (log truncated outside a checkpoint?)",
                    self.i, self.round
                );
            }
        }
        Ok(())
    }

    /// The "send half" of a round: encode this worker's frame and
    /// broadcast it to every peer. Shared between the pipelined
    /// pre-gradient path (where the engine sees the empty tripwire slice)
    /// and the post-gradient path. Returns the frame (its payload buffer
    /// is reclaimed after the mix) and the encode wall time.
    fn send_half(
        &mut self,
        transport: &mut dyn Transport,
        pre: bool,
    ) -> Result<(Frame, f64), WorkerFailure> {
        // lint: allow(wall_clock) — the encode timer feeds per-node perf
        // accounting only; frame contents are unaffected.
        let t1 = Instant::now();
        let mut payload = std::mem::take(&mut self.payload);
        payload.clear();
        let ctx = StepCtx { seed: self.seed, rho: self.cur_ep().rho, g_inf: self.g_inf };
        let grad: &[f32] = if pre { &[] } else { &self.grad };
        let byz_live = self.round >= self.live_from && self.spec.byz.is_some();
        // Wrap attack: encode from a model kicked far outside the θ ball,
        // then restore the exact bytes. The frame is wire-valid; only the
        // §6 semantic digest can tell the decode went wrong.
        let wrap = byz_live && self.spec.byz == Some(ByzMode::Wrap);
        if wrap {
            self.byz_save.clear();
            self.byz_save.extend_from_slice(&self.x);
            for v in self.x.iter_mut() {
                *v += adversary::WRAP_KICK;
            }
        }
        self.engine
            .node_send(self.i, &self.x, grad, self.lr, self.round, &ctx, &mut payload);
        if wrap {
            self.x.copy_from_slice(&self.byz_save);
        }
        if self.spec.seal {
            adversary::seal_payload(self.round, &mut payload);
        }
        // Flip attack: corrupt one body byte *after* sealing — the frame
        // checksum is recomputed over the corrupt bytes (so the transport
        // accepts it) but the seal/digest no longer matches.
        if byz_live && self.spec.byz == Some(ByzMode::Flip) {
            if let Some(b) = payload.first_mut() {
                *b ^= 0xFF;
            }
        }
        let frame = Frame {
            round: self.round,
            sender: self.i as u16,
            algo: self.spec.algo_id,
            bits: self.spec.wire_bits,
            kind: FrameKind::Data,
            theta: self.engine.last_theta().unwrap_or(0.0) as f32,
            payload,
        };
        let send_compute = t1.elapsed().as_secs_f64();
        self.spec
            .telemetry
            .observe(Hist::EncodeNs, (send_compute * 1e9) as u64);
        self.spec.telemetry.record(Counter::CodesPacked, self.d as u64);
        if self.round >= self.live_from {
            // One broadcast call: the frame is serialized + checksummed
            // once and the wire bytes are reused for every peer.
            transport.broadcast(&self.send_peers, &frame).map_err(|e| {
                self.failure(format!("broadcast failed: {e}"))
            })?;
        }
        // Replayed rounds count their original (pre-crash) send exactly
        // once: the counters that recorded it died with the old
        // incarnation.
        self.trace.frames_sent += self.send_peers.len() as u64;
        self.trace.bytes_sent +=
            self.send_peers.len() as u64 * frame.encoded_len() as u64;
        if byz_live {
            self.byz_followup(transport, &frame)?;
        }
        Ok((frame, send_compute))
    }

    /// The replay/equivocate modes' *extra* traffic, sent after the honest
    /// broadcast. Fault injection only: nothing here runs unless this
    /// worker was designated Byzantine.
    // lint: cold
    fn byz_followup(
        &mut self,
        transport: &mut dyn Transport,
        frame: &Frame,
    ) -> Result<(), WorkerFailure> {
        match self.spec.byz {
            None | Some(ByzMode::Flip) | Some(ByzMode::Wrap) => {}
            Some(ByzMode::Replay) => {
                if let Some(stale) = self.byz_prev.take() {
                    // The stale copy still carries its original round stamp
                    // and a seal valid *for that round* — only the round
                    // gate can strike it.
                    transport.broadcast(&self.send_peers, &stale).map_err(|e| {
                        self.failure(format!("broadcast failed: {e}"))
                    })?;
                    self.trace.frames_sent += self.send_peers.len() as u64;
                    self.trace.bytes_sent +=
                        self.send_peers.len() as u64 * stale.encoded_len() as u64;
                }
                self.byz_prev = Some(Frame {
                    round: frame.round,
                    sender: frame.sender,
                    algo: frame.algo,
                    bits: frame.bits,
                    kind: FrameKind::Data,
                    theta: frame.theta,
                    payload: frame.payload.clone(),
                });
            }
            Some(ByzMode::Equivocate) => {
                let body_len = if self.spec.seal {
                    frame.payload.len() - adversary::SEAL_LEN
                } else {
                    frame.payload.len()
                };
                if body_len == 0 {
                    return Ok(());
                }
                let mut eq = Frame {
                    round: frame.round,
                    sender: frame.sender,
                    algo: frame.algo,
                    bits: frame.bits,
                    kind: FrameKind::Data,
                    theta: frame.theta,
                    payload: Vec::new(),
                };
                for k in 0..self.send_peers.len() {
                    let p = self.send_peers[k];
                    // Per-peer divergent second copy, re-sealed valid: the
                    // seal gate passes it; only the duplicate screen can
                    // see the two copies disagree.
                    eq.payload.clear();
                    eq.payload.extend_from_slice(&frame.payload[..body_len]);
                    eq.payload[p % body_len] ^= 0x55;
                    if self.spec.seal {
                        adversary::seal_payload(self.round, &mut eq.payload);
                    }
                    transport.send(p, &eq).map_err(|e| {
                        self.failure(format!("send failed: {e}"))
                    })?;
                    self.trace.frames_sent += 1;
                    self.trace.bytes_sent += eq.encoded_len() as u64;
                }
            }
        }
        Ok(())
    }

    /// The recv half + checkpoint: runs once the barrier holds a round
    /// frame (or a gate rejection) from every peer. Fails typed on a
    /// quarantine conviction that loses quorum or cannot rewire.
    fn finish_round(&mut self, transport: &mut dyn Transport) -> Result<(), WorkerFailure> {
        // lint: allow(wall_clock) — the mix timer feeds per-node perf
        // accounting only; model bytes are unaffected.
        let PendingRound { loss, grad_wall, frame, send_compute } = self
            .pending
            .take()
            .expect("finish_round without a pending round");
        let t2 = Instant::now();
        // Ascending-sender order is the engines' determinism contract;
        // sort_unstable is in-place, and the borrowed inbox makes this the
        // allocation-free path (Inbox::from_frames).
        self.got.sort_unstable_by_key(|f| f.sender);
        // Senders the gate rejected this round contribute the local model
        // instead — the neutral element of every accumulate loop.
        self.subst.clear();
        for k in 0..self.peers.len() {
            let p = self.peers[k];
            if self.was_rejected(self.round, p)
                && !self.got.iter().any(|g| g.sender as usize == p)
            {
                self.subst.push(p);
            }
        }
        let ctx = StepCtx { seed: self.seed, rho: self.cur_ep().rho, g_inf: self.g_inf };
        let c0 = self.spec.clock.now_ns();
        let stats = {
            let inbox = if self.subst.is_empty() {
                Inbox::from_frames(&self.got)
            } else {
                let own = if self.spec.seal {
                    adversary::sealed_body(&frame.payload)
                } else {
                    frame.payload.as_slice()
                };
                Inbox::from_frames_with_self(&self.got, own, &self.subst)
            };
            self.engine.node_recv(
                self.i, &mut self.x, &self.grad, self.lr, self.round, &ctx, &inbox,
            )
        };
        self.spec
            .telemetry
            .observe(Hist::DecodeNs, self.spec.clock.now_ns().saturating_sub(c0));
        self.spec.telemetry.record(Counter::RoundsTotal, 1);
        // The Moniqua family's §6 digest failures surface as engine
        // strikes: drain them into the same ledger the seal gate feeds.
        self.strike_scratch.clear();
        self.engine.drain_strikes(&mut self.strike_scratch);
        if !self.strike_scratch.is_empty() {
            self.spec
                .telemetry
                .record(Counter::DigestRejects, self.strike_scratch.len() as u64);
            for k in 0..self.strike_scratch.len() {
                let p = self.strike_scratch[k] as usize;
                self.note_strike(p);
            }
        }
        // Consumed payload buffers go back to the transport's wire pool.
        for f in self.got.drain(..) {
            transport.recycle(f.payload);
        }
        self.trace.push_round(
            self.round,
            loss,
            self.engine.last_theta(),
            stats,
            grad_wall,
            send_compute + t2.elapsed().as_secs_f64(),
        );
        if self.round % self.spec.cfg.eval_every == 0
            || self.round + 1 == self.spec.cfg.steps
        {
            self.trace.evals.push((self.round, self.x.clone()));
        }
        self.payload = frame.payload; // reuse the allocation next round

        // Quarantine conviction: any sender over the strike budget is
        // excised from the gossip row before the next round's send half.
        if self.spec.strike_limit > 0 {
            let mut convicted = false;
            for p in 0..self.strikes.len() {
                if !self.quarantined[p] && self.strikes[p] >= self.spec.strike_limit {
                    self.quarantined[p] = true;
                    self.spec.telemetry.record(Counter::QuarantinedPeers, 1);
                    convicted = true;
                }
            }
            if convicted {
                self.apply_quarantine()?;
            }
        }

        // A parked frame for a round whose barrier just closed can never
        // be consumed (take_parked only queries the current round going
        // forward): recycle it instead of holding it for the run. The
        // normal case is a *bridge* frame from a neighbor that convicted a
        // shared peer earlier than we did — its first bridged rounds may
        // predate our own rewire.
        let mut k = 0;
        while k < self.parked.len() {
            if self.parked[k].round <= self.round {
                let f = self.parked.swap_remove(k);
                transport.recycle(f.payload);
            } else {
                k += 1;
            }
        }
        // Reject-ledger entries for closed barriers are only needed again
        // by crash replay; without a frame log no replay exists and they
        // can go now (with one, they go at the checkpoint cut below).
        if self.framelog.is_none() {
            self.reject_log.retain(|&(r, _)| r > self.round);
        }

        // Checkpoint at the round boundary.
        if self.round >= self.live_from
            && self.spec.ckpt_every > 0
            && (self.round + 1) % self.spec.ckpt_every == 0
        {
            if let Some(dir) = self.spec.ckpt_dir.as_ref() {
                let ck0 = self.spec.clock.now_ns();
                let mut engine_blob = self.arena.take_bytes();
                self.engine.snapshot(&mut engine_blob);
                let snap = Snapshot {
                    worker: self.i as u16,
                    algo: self.spec.algo_id,
                    round: self.round,
                    lr: self.lr,
                    g_inf: self.g_inf,
                    model: self.x.clone(),
                    engine: engine_blob,
                    trace: self.trace.clone(),
                };
                write_checkpoint(dir, &snap).expect("write checkpoint");
                self.arena.give_bytes(snap.engine);
                if let Some(log) = self.framelog.as_mut() {
                    // The log's new epoch is "everything since this
                    // snapshot": truncate, then re-log frames that were
                    // received but not yet consumed (data frames parked
                    // for future rounds and any early-delivered
                    // bootstrap). Replay consumes them by (round, sender)
                    // lookup, so their order in the log does not matter.
                    log.truncate().expect("truncate frame log");
                    for f in &self.parked {
                        log.append(f).expect("re-log pending frame");
                    }
                    for f in self.boot_pending.values() {
                        log.append(f).expect("re-log pending bootstrap");
                    }
                    // Replay can never reach behind this snapshot, so
                    // reject-ledger entries for rounds it covers are done.
                    let cut = self.round;
                    self.reject_log.retain(|&(r, _)| r > cut);
                }
                self.spec.telemetry.observe(
                    Hist::CkptWriteNs,
                    self.spec.clock.now_ns().saturating_sub(ck0),
                );
            }
        }
        Ok(())
    }

    /// Scheduled crash: lose everything, restore the last [`Snapshot`],
    /// replay the rounds in between against the [`FrameLog`].
    fn crash_restore(&mut self) {
        let dir = self
            .spec
            .ckpt_dir
            .as_ref()
            .expect("crash plans are validated to carry a ckpt_dir");
        let snap = load_checkpoint(dir, self.i)
            .unwrap_or_else(|e| panic!("worker {}: corrupt checkpoint: {e}", self.i));
        self.parked.clear();
        self.boot_pending.clear();
        for f in FrameLog::read_all(dir, self.i)
            .unwrap_or_else(|e| panic!("worker {}: corrupt frame log: {e}", self.i))
        {
            self.spec.telemetry.record(Counter::WalReplays, 1);
            match f.kind {
                FrameKind::Data => {
                    // Replayed frames were gated (and seal-stripped)
                    // before they reached the WAL; only the sanity checks
                    // re-run here.
                    validate_data_frame(self.i, &f, &self.spec);
                    self.parked.push(f);
                }
                FrameKind::Bootstrap => {
                    self.boot_pending.insert(f.round, f);
                }
            }
        }
        self.engine = self
            .spec
            .cfg
            .algorithm
            .make_sync(&self.spec.epochs[0].matrix, self.d);
        self.engine.set_threads(1);
        self.apply_engine_config();
        match snap {
            Some(s) => {
                assert_eq!(
                    s.algo, self.spec.algo_id,
                    "worker {}: checkpoint belongs to another algorithm",
                    self.i
                );
                assert_eq!(
                    s.worker as usize, self.i,
                    "worker {}: foreign checkpoint",
                    self.i
                );
                assert_eq!(
                    s.model.len(),
                    self.d,
                    "worker {}: checkpoint dimension",
                    self.i
                );
                self.engine
                    .restore(&s.engine)
                    .unwrap_or_else(|e| panic!("worker {}: engine restore: {e}", self.i));
                self.x = s.model;
                self.lr = s.lr;
                self.g_inf = s.g_inf;
                self.live_from = self.round;
                self.round = s.round + 1;
                self.trace = s.trace;
            }
            None => {
                // Genesis recovery: no checkpoint yet — replay the whole
                // history from the (never-truncated) frame log.
                self.x = self.objective.init();
                self.lr = lr_at(&self.spec.cfg, self.start_round);
                self.g_inf = 0.0;
                self.live_from = self.round;
                self.round = self.start_round;
                self.trace = NodeTrace::starting_at(self.start_round);
            }
        }
        self.cur_epoch = usize::MAX; // force re-wiring on re-entry
    }

    /// Hand the machine one inbound frame. Data frames pass the defense
    /// gate *before* the WAL (only admitted, seal-stripped frames are
    /// logged — crash replay must not re-average rejected traffic); where
    /// an admitted frame lands depends on what the machine is waiting for,
    /// the same routing the old inline recv loops performed.
    pub(crate) fn accept_frame(&mut self, f: Frame) {
        if self.phase == Phase::Finished {
            // Late traffic after this worker retired: the run is over for
            // it, so the frame is simply dropped.
            drop(f);
            return;
        }
        let f = match f.kind {
            FrameKind::Bootstrap => f,
            FrameKind::Data => match self.gate_data_frame(f) {
                Some(f) => f,
                None => return,
            },
        };
        if let Some(log) = self.framelog.as_mut() {
            log.append(&f).expect("frame log append");
            self.spec.telemetry.record(Counter::WalAppends, 1);
        }
        match self.phase {
            Phase::AwaitBarrier => {
                if f.kind == FrameKind::Bootstrap {
                    // A bootstrapper past an upcoming reconfiguration
                    // barrier delivered our (re)join bootstrap early: park
                    // it for the join round.
                    self.boot_pending.insert(f.round, f);
                    return;
                }
                // Only current peers may reach the barrier inbox — an
                // early *bridge* frame (a neighbor convicted a shared peer
                // and rewired before we did) parks until our own rewire
                // admits its sender; everything parked is picked up by
                // `take_parked`, which is keyed on the peer set of the
                // round that consumes it.
                if f.round == self.round && self.is_recv_peer(f.sender as usize) {
                    self.got.push(f);
                } else {
                    self.parked.push(f);
                }
            }
            Phase::AwaitBootstrap | Phase::RoundEntry => match f.kind {
                FrameKind::Bootstrap => {
                    self.boot_pending.insert(f.round, f);
                }
                FrameKind::Data => {
                    self.parked.push(f);
                }
            },
            Phase::Finished => unreachable!("handled above"),
        }
    }

    /// The defense gate every live inbound Data frame passes before it can
    /// reach the WAL or an engine: quarantine screen, round-bound seal
    /// verification (stripped on success), staleness, and duplicate
    /// screening. `None` means rejected — the typed telemetry counter
    /// records why and the sender is struck; the payload is dropped.
    // lint: hot-path
    fn gate_data_frame(&mut self, mut f: Frame) -> Option<Frame> {
        let from = f.sender as usize;
        // Convicted-sender traffic is dropped wholesale.
        if from < self.quarantined.len() && self.quarantined[from] {
            self.spec.telemetry.record(Counter::ReplayRejects, 1);
            return None;
        }
        validate_data_frame(self.i, &f, &self.spec);
        if self.spec.seal {
            if !adversary::seal_ok(f.round, &f.payload) {
                // Checksum-valid but seal-wrong: corruption past the
                // transport layer. Ledger the (round, sender) pair so the
                // frame's barrier slot is satisfied (the mix substitutes
                // the local model) — one bad frame costs a strike, not a
                // barrier timeout — and so crash replay can re-satisfy the
                // slot (rejected frames are never WAL-logged).
                self.spec.telemetry.record(Counter::DigestRejects, 1);
                self.note_strike(from);
                if !self.was_rejected(f.round, from) {
                    self.reject_log.push((f.round, f.sender));
                }
                return None;
            }
            let keep = f.payload.len() - adversary::SEAL_LEN;
            f.payload.truncate(keep);
        }
        if f.round < self.round {
            // Stale (round, sender) re-broadcast: that barrier already
            // closed — classic replay. (Its seal, if any, verified above:
            // the seal binds the *frame's* round, so only this gate can
            // catch the re-send.)
            self.spec.telemetry.record(Counter::ReplayRejects, 1);
            self.note_strike(from);
            return None;
        }
        // Duplicate screen: at most one Data frame per (round, sender) may
        // be held. A byte-identical second copy is a replay; a divergent
        // one is equivocation. The collection searched mirrors where
        // `accept_frame` would route this frame.
        let held = if f.round == self.round
            && self.phase == Phase::AwaitBarrier
            && self.is_recv_peer(from)
        {
            self.got.iter().find(|g| g.sender == f.sender)
        } else {
            self.parked
                .iter()
                .find(|g| g.round == f.round && g.sender == f.sender)
        };
        if let Some(held) = held {
            if held.payload == f.payload {
                self.spec.telemetry.record(Counter::ReplayRejects, 1);
            } else {
                self.spec.telemetry.record(Counter::EquivocationRejects, 1);
            }
            self.note_strike(from);
            return None;
        }
        Some(f)
    }

    /// The typed failure for a driver whose deadline for the current
    /// [`WaitKey`] expired — same strings the threaded runtime always
    /// produced (pinned by `tests/barrier_deadline.rs`).
    pub(crate) fn timeout_failure(&self) -> WorkerFailure {
        match self.phase {
            Phase::AwaitBootstrap => self.failure(format!(
                "timed out waiting for the round-{} bootstrap \
                 frame: exceeded the configured recv_timeout of {:?}",
                self.round, self.spec.recv_timeout,
            )),
            _ => {
                let missing = self.missing_pairs();
                self.failure(format!(
                    "barrier timed out: exceeded the configured \
                     recv_timeout of {:?} with {} of {} peer frames \
                     held; still waiting on (round, sender) pairs \
                     {missing:?}",
                    self.spec.recv_timeout,
                    self.got.len(),
                    self.peers.len(),
                ))
            }
        }
    }

    /// The typed failure for a transport error surfaced while waiting.
    pub(crate) fn recv_failure(&self, e: &TransportError) -> WorkerFailure {
        match self.phase {
            Phase::AwaitBootstrap => self.failure(format!("bootstrap recv failed: {e}")),
            _ => self.failure(format!("barrier recv failed: {e}")),
        }
    }

    pub(crate) fn into_result(self) -> NodeResult {
        NodeResult { worker: self.i, final_x: self.x, trace: self.trace }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::Algorithm;
    use crate::elastic::MembershipPlan;
    use crate::topology::Topology;
    use crate::transport::{algo_wire_id, MemTransport};

    #[test]
    fn sibling_abort_names_the_origin_and_wait_unit() {
        let latch = AbortLatch::default();
        latch.trip(WorkerFailure::new(3, 7, "boom".into()));
        let s = latch.sibling_abort(1, 7);
        assert_eq!(
            s.reason,
            "aborted within one recv tick: sibling worker 3 failed round 7"
        );
        let r = latch.sibling_abort_via(1, 7, "poll iteration");
        assert_eq!(
            r.reason,
            "aborted within one poll iteration: sibling worker 3 failed round 7"
        );
    }

    #[test]
    fn trip_wakes_registered_wakers() {
        let latch = AbortLatch::default();
        let w = WakeHandle::new();
        latch.register_waker(&w);
        latch.trip(WorkerFailure::new(0, 0, "x".into()));
        // A tripped latch must have fired the token: park returns at once.
        let t0 = Instant::now();
        w.park_timeout(Duration::from_secs(5));
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    /// Two machines, one thread, a real Mem transport: the state machine
    /// alone (no driver) completes a run, and both workers agree on the
    /// round count. This is the smallest proof that `drive`/`accept_frame`
    /// carry the whole protocol.
    #[test]
    fn two_machines_interleave_to_completion_on_one_thread() {
        let cfg = TrainConfig {
            workers: 2,
            steps: 4,
            eval_every: 2,
            algorithm: Algorithm::DPsgd,
            ..TrainConfig::default()
        };
        let topo = Topology::Ring(2);
        let epochs = MembershipPlan::default().epochs(&topo, cfg.steps).unwrap();
        let objective =
            || Box::new(crate::objectives::Quadratic::new(6, 1.0, 0.1, 2, 3));
        let d = objective().dim();
        let mut transports = MemTransport::cluster(2);
        let mut machines: Vec<RoundStateMachine<'_>> = (0..2)
            .map(|i| {
                let mut engine = cfg.algorithm.make_sync(&epochs[0].matrix, d);
                engine.set_threads(1);
                let spec = NodeSpec {
                    cfg: cfg.clone(),
                    recv_timeout: Duration::from_secs(5),
                    algo_id: algo_wire_id(cfg.algorithm.name()),
                    wire_bits: 32,
                    scope: engine.comm_scope(),
                    epochs: &epochs,
                    crashes: Vec::new(),
                    ckpt_every: 0,
                    ckpt_dir: None,
                    skip_bootstrap: false,
                    pipeline: true,
                    telemetry: Telemetry::disabled(),
                    clock: Clock::disabled(),
                    topo: topo.clone(),
                    byz: None,
                    strike_limit: 3,
                    seal: false,
                };
                RoundStateMachine::new(i, engine, objective(), spec)
            })
            .collect();

        let mut done = [false, false];
        let mut spins = 0usize;
        while !done.iter().all(|&b| b) {
            spins += 1;
            assert!(spins < 10_000, "machines wedged");
            for i in 0..2 {
                if done[i] {
                    continue;
                }
                let t: &mut dyn Transport = &mut transports[i];
                match machines[i].drive(t).unwrap() {
                    MachineStatus::Done => done[i] = true,
                    MachineStatus::Waiting(_) => {
                        if let Ok(f) = t.recv(Duration::from_millis(1)) {
                            machines[i].accept_frame(f);
                        }
                    }
                }
            }
        }
        for (i, m) in machines.into_iter().enumerate() {
            let r = m.into_result();
            assert_eq!(r.worker, i);
            assert_eq!(r.final_x.len(), d);
            assert!(r.trace.loss_at(3).is_some());
        }
    }

    /// Three machines, one of them flipping payload bytes under a live
    /// seal: the two honest workers strike it each round, convict at the
    /// strike limit, excise it from their gossip row, and still complete
    /// every round — as does the (now-ignored) adversary, because honest
    /// nodes keep serving it frames.
    #[test]
    fn flip_adversary_is_quarantined_and_the_cohort_completes() {
        let cfg = TrainConfig {
            workers: 3,
            steps: 8,
            eval_every: 4,
            algorithm: Algorithm::DPsgd,
            ..TrainConfig::default()
        };
        let topo = Topology::Ring(3);
        let epochs = MembershipPlan::default().epochs(&topo, cfg.steps).unwrap();
        let objective =
            || Box::new(crate::objectives::Quadratic::new(6, 1.0, 0.1, 3, 3));
        let d = objective().dim();
        let mut transports = MemTransport::cluster(3);
        let byz_worker = 2usize;
        let mut machines: Vec<RoundStateMachine<'_>> = (0..3)
            .map(|i| {
                let mut engine = cfg.algorithm.make_sync(&epochs[0].matrix, d);
                engine.set_threads(1);
                let spec = NodeSpec {
                    cfg: cfg.clone(),
                    recv_timeout: Duration::from_secs(5),
                    algo_id: algo_wire_id(cfg.algorithm.name()),
                    wire_bits: 32,
                    scope: engine.comm_scope(),
                    epochs: &epochs,
                    crashes: Vec::new(),
                    ckpt_every: 0,
                    ckpt_dir: None,
                    skip_bootstrap: false,
                    pipeline: true,
                    telemetry: Telemetry::disabled(),
                    clock: Clock::disabled(),
                    topo: topo.clone(),
                    byz: (i == byz_worker).then_some(ByzMode::Flip),
                    strike_limit: 2,
                    seal: true,
                };
                RoundStateMachine::new(i, engine, objective(), spec)
            })
            .collect();

        let mut done = [false, false, false];
        let mut spins = 0usize;
        while !done.iter().all(|&b| b) {
            spins += 1;
            assert!(spins < 100_000, "machines wedged");
            for i in 0..3 {
                if done[i] {
                    continue;
                }
                let t: &mut dyn Transport = &mut transports[i];
                match machines[i].drive(t).unwrap() {
                    MachineStatus::Done => done[i] = true,
                    MachineStatus::Waiting(_) => {
                        if let Ok(f) = t.recv(Duration::from_millis(1)) {
                            machines[i].accept_frame(f);
                        }
                    }
                }
            }
        }
        for i in [0usize, 1] {
            assert!(
                machines[i].quarantined[byz_worker],
                "worker {i} never convicted the adversary"
            );
            assert_eq!(machines[i].strikes[byz_worker], 2, "exactly strike_limit strikes");
            // Post-excision gossip row: the ring(3) minus the adversary is
            // a 2-ring; each honest worker's recv set is the other one.
            assert_eq!(machines[i].peers, vec![1 - i]);
            // ... but the adversary stays in the send set (one-way excision).
            assert!(machines[i].send_peers.contains(&byz_worker));
        }
        assert!(
            !machines[byz_worker].quarantined.iter().any(|&q| q),
            "honest traffic must not strike"
        );
        for m in machines.into_iter() {
            let r = m.into_result();
            assert!(r.trace.loss_at(7).is_some(), "all workers complete all rounds");
        }
    }

    /// Construct a seal-armed dpsgd cohort on `Ring(n)` with worker
    /// `byz_worker` flipping payload bytes, for the race-orchestration
    /// tests below.
    fn flip_cohort<'a>(
        cfg: &TrainConfig,
        topo: &Topology,
        epochs: &'a [Epoch],
        byz_worker: usize,
        strike_limit: u32,
    ) -> Vec<RoundStateMachine<'a>> {
        let n = cfg.workers;
        let objective =
            || Box::new(crate::objectives::Quadratic::new(6, 1.0, 0.1, n, 3));
        let d = objective().dim();
        (0..n)
            .map(|i| {
                let mut engine = cfg.algorithm.make_sync(&epochs[0].matrix, d);
                engine.set_threads(1);
                let spec = NodeSpec {
                    cfg: cfg.clone(),
                    recv_timeout: Duration::from_secs(5),
                    algo_id: algo_wire_id(cfg.algorithm.name()),
                    wire_bits: 32,
                    scope: engine.comm_scope(),
                    epochs,
                    crashes: Vec::new(),
                    ckpt_every: 0,
                    ckpt_dir: None,
                    skip_bootstrap: false,
                    pipeline: true,
                    telemetry: Telemetry::disabled(),
                    clock: Clock::disabled(),
                    topo: topo.clone(),
                    byz: (i == byz_worker).then_some(ByzMode::Flip),
                    strike_limit,
                    seal: true,
                };
                RoundStateMachine::new(i, engine, objective(), spec)
            })
            .collect()
    }

    /// Drive one machine until it completes or blocks with an empty inbox.
    fn pump(m: &mut RoundStateMachine<'_>, t: &mut MemTransport) -> bool {
        loop {
            match m.drive(t).unwrap() {
                MachineStatus::Done => return true,
                MachineStatus::Waiting(_) => match t.recv(Duration::from_millis(1)) {
                    Ok(f) => m.accept_frame(f),
                    Err(_) => return false,
                },
            }
        }
    }

    /// The quarantine rewire race: on Ring(4), both neighbors of the
    /// adversary convict it, but worker 1 finishes its conviction round
    /// first, rewires, and its pipelined round-2 entry broadcasts to the
    /// new bridge peer 3 — whose own peer set still predates the rewire,
    /// so the sender is in neither worker 3's epoch adjacency nor its
    /// current peers. The frame must park (never panic, never enter the
    /// barrier inbox) and be consumed once worker 3's own conviction
    /// admits the bridge.
    #[test]
    fn early_bridge_frame_parks_until_the_receivers_own_rewire() {
        let cfg = TrainConfig {
            workers: 4,
            steps: 6,
            eval_every: 3,
            algorithm: Algorithm::DPsgd,
            ..TrainConfig::default()
        };
        let topo = Topology::Ring(4);
        let epochs = MembershipPlan::default().epochs(&topo, cfg.steps).unwrap();
        let mut transports = MemTransport::cluster(4);
        let byz_worker = 2usize;
        let mut machines = flip_cohort(&cfg, &topo, &epochs, byz_worker, 2);

        // Worker 3 ships its round-0 frame (its neighbors 0 and 2 need it
        // to advance) and then goes silent: we withhold its inbox so its
        // peer set stays pre-rewire while the rest of the ring runs ahead.
        {
            let t: &mut dyn Transport = &mut transports[3];
            assert_eq!(
                machines[3].drive(t).unwrap(),
                MachineStatus::Waiting(WaitKey::Barrier { round: 0 })
            );
        }
        // Workers 0/1/2 run until quiescent. Worker 1 sees the flipped
        // frames at rounds 0 and 1, convicts at the 2-strike budget at the
        // end of round 1, rewires, and its round-2 entry broadcasts to the
        // bridge peer 3.
        for _ in 0..16 {
            for i in [0usize, 1, 2] {
                assert!(!pump(&mut machines[i], &mut transports[i]));
            }
        }
        assert!(
            machines[1].quarantined[byz_worker],
            "worker 1 must have convicted the adversary while worker 3 idles"
        );
        let mut rewired = machines[1].peers.clone();
        rewired.sort_unstable();
        assert_eq!(rewired, vec![0, 3], "worker 1's row must bridge to 3");
        assert!(!machines[3].quarantined[byz_worker]);

        // Deliver worker 1's bridge frame FIRST — ahead of the round-0/1
        // traffic that would let worker 3 convict and rewire. This is the
        // ordering TCP can produce across per-sender connections, and the
        // exact state the old assert died on.
        let mut inbox = Vec::new();
        while let Ok(f) = transports[3].recv(Duration::from_millis(1)) {
            inbox.push(f);
        }
        let at = inbox
            .iter()
            .position(|f| f.sender == 1 && f.round == 2)
            .expect("worker 1's bridge frame must be queued for worker 3");
        let bridge = inbox.remove(at);
        machines[3].accept_frame(bridge);
        assert!(
            machines[3].got.is_empty(),
            "a non-peer frame must never enter the barrier inbox"
        );
        assert_eq!(machines[3].parked.len(), 1, "bridge frame must park");

        // Now worker 3 catches up round by round: one strike at round 0,
        // the second at round 1, conviction and rewire at the end of
        // round 1 — at which point the parked bridge frame satisfies its
        // round-2 barrier slot for the new peer.
        let (r0_frames, later): (Vec<Frame>, Vec<Frame>) =
            inbox.into_iter().partition(|f| f.round == 0);
        for f in r0_frames {
            machines[3].accept_frame(f);
        }
        assert!(!pump(&mut machines[3], &mut transports[3]));
        assert_eq!(machines[3].round(), 1, "one strike must not convict");
        for f in later {
            machines[3].accept_frame(f);
        }
        let mut done = [false; 4];
        let mut spins = 0usize;
        while !done.iter().all(|&b| b) {
            spins += 1;
            assert!(spins < 100_000, "machines wedged");
            for i in 0..4 {
                if !done[i] {
                    done[i] = pump(&mut machines[i], &mut transports[i]);
                }
            }
        }
        for i in [1usize, 3] {
            assert!(
                machines[i].quarantined[byz_worker],
                "worker {i} never convicted the adversary"
            );
        }
        for m in machines.into_iter() {
            let r = m.into_result();
            assert!(r.trace.loss_at(5).is_some(), "all workers complete all rounds");
        }
    }

    /// Two outstanding rejects from one sender must both hold their
    /// barrier slots: the adversary's round-0 AND round-1 flipped frames
    /// reach worker 0 before worker 0 has processed anything, and the
    /// later reject must not evict the earlier round's record (the old
    /// per-sender scalar did exactly that, wedging the round-0 barrier
    /// into a timeout).
    #[test]
    fn stacked_rejects_from_one_sender_keep_every_barrier_slot() {
        let cfg = TrainConfig {
            workers: 3,
            steps: 4,
            eval_every: 2,
            algorithm: Algorithm::DPsgd,
            ..TrainConfig::default()
        };
        let topo = Topology::Ring(3);
        let epochs = MembershipPlan::default().epochs(&topo, cfg.steps).unwrap();
        let mut transports = MemTransport::cluster(3);
        let byz_worker = 2usize;
        let mut machines = flip_cohort(&cfg, &topo, &epochs, byz_worker, 3);

        // Everyone ships round 0; then the adversary alone is fed its
        // inbox so it advances to round 1 and ships a second flipped
        // frame while workers 0 and 1 have processed nothing.
        for i in 0..3 {
            let t: &mut dyn Transport = &mut transports[i];
            assert_eq!(
                machines[i].drive(t).unwrap(),
                MachineStatus::Waiting(WaitKey::Barrier { round: 0 })
            );
        }
        assert!(!pump(&mut machines[byz_worker], &mut transports[byz_worker]));
        assert_eq!(machines[byz_worker].round(), 1);

        // Worker 0's inbox now holds 1's round-0 frame plus the
        // adversary's round-0 and round-1 frames. Deliver both bad frames
        // before the honest one.
        let mut inbox = Vec::new();
        while let Ok(f) = transports[0].recv(Duration::from_millis(1)) {
            inbox.push(f);
        }
        inbox.sort_by_key(|f| (f.sender as usize != byz_worker, f.round));
        for f in inbox {
            machines[0].accept_frame(f);
        }
        assert!(
            machines[0].was_rejected(0, byz_worker)
                && machines[0].was_rejected(1, byz_worker),
            "both rejected rounds must stay ledgered"
        );
        // The regression: with the old scalar, round 0's slot was lost and
        // worker 0 stayed wedged in round 0 forever.
        assert!(!pump(&mut machines[0], &mut transports[0]));
        assert_eq!(machines[0].round(), 1, "round-0 barrier must close off the ledger");

        let mut done = [false; 3];
        let mut spins = 0usize;
        while !done.iter().all(|&b| b) {
            spins += 1;
            assert!(spins < 100_000, "machines wedged");
            for i in 0..3 {
                if !done[i] {
                    done[i] = pump(&mut machines[i], &mut transports[i]);
                }
            }
        }
        for i in [0usize, 1] {
            assert!(
                machines[i].quarantined[byz_worker],
                "worker {i} never convicted the adversary"
            );
        }
        for m in machines.into_iter() {
            let r = m.into_result();
            assert!(r.trace.loss_at(3).is_some(), "all workers complete all rounds");
        }
    }
}
