//! Experiment traces and report serialization (CSV / pretty tables).

use std::io::Write;

/// One logged evaluation point.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRow {
    pub step: u64,
    /// Simulated wall-clock seconds (local compute + modeled network).
    pub sim_time_s: f64,
    pub train_loss: f64,
    pub eval_loss: f64,
    pub eval_acc: Option<f64>,
    /// Max ℓ∞ distance of any local model from the mean — the quantity θ
    /// must dominate.
    pub consensus_linf: f64,
    pub bytes_total: u64,
    pub theta: Option<f64>,
}

/// Full result of one training run.
#[derive(Clone, Debug)]
pub struct Report {
    pub algorithm: String,
    pub workers: usize,
    pub dim: usize,
    pub trace: Vec<TraceRow>,
    pub total_bytes: u64,
    pub total_messages: u64,
    /// Measured wire bytes split `(data, bootstrap)`, from the telemetry
    /// plane. Only runtimes with a real transport fill this; lockstep/DES
    /// leave it `None` and the table prints "-".
    pub wire_bytes_by_kind: Option<(u64, u64)>,
    pub extra_memory_floats: usize,
    pub final_params: Vec<f32>,
}

impl Report {
    pub fn new(algorithm: &str, workers: usize, dim: usize) -> Self {
        Report {
            algorithm: algorithm.to_string(),
            workers,
            dim,
            trace: Vec::new(),
            total_bytes: 0,
            total_messages: 0,
            wire_bytes_by_kind: None,
            extra_memory_floats: 0,
            final_params: Vec::new(),
        }
    }

    pub fn first_loss(&self) -> f64 {
        self.trace.first().map(|r| r.eval_loss).unwrap_or(f64::NAN)
    }

    pub fn final_loss(&self) -> f64 {
        self.trace.last().map(|r| r.eval_loss).unwrap_or(f64::NAN)
    }

    pub fn final_accuracy(&self) -> Option<f64> {
        self.trace.last().and_then(|r| r.eval_acc)
    }

    pub fn final_sim_time(&self) -> f64 {
        self.trace.last().map(|r| r.sim_time_s).unwrap_or(0.0)
    }

    /// Earliest simulated time at which eval loss drops below `target`
    /// (None if never) — the Figure-1 "time to loss" readout.
    pub fn time_to_loss(&self, target: f64) -> Option<f64> {
        self.trace
            .iter()
            .find(|r| r.eval_loss <= target)
            .map(|r| r.sim_time_s)
    }

    /// CSV serialization (header + rows).
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "algorithm,step,sim_time_s,train_loss,eval_loss,eval_acc,consensus_linf,bytes_total,theta\n",
        );
        for r in &self.trace {
            s.push_str(&format!(
                "{},{},{:.6e},{:.6e},{:.6e},{},{:.6e},{},{}\n",
                self.algorithm,
                r.step,
                r.sim_time_s,
                r.train_loss,
                r.eval_loss,
                r.eval_acc.map_or(String::new(), |a| format!("{a:.4}")),
                r.consensus_linf,
                r.bytes_total,
                r.theta.map_or(String::new(), |t| format!("{t:.4e}")),
            ));
        }
        s
    }

    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }
}

/// Pretty-print a set of reports as an aligned comparison table (the form
/// the benches print for each paper table/figure). `messages` is the
/// modeled message count (previously computed but silently dropped from
/// the table); `wire_MB(data/boot)` is the *measured* byte split from the
/// telemetry plane, "-" for runtimes without a transport.
pub fn comparison_table(reports: &[&Report]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<16} {:>12} {:>10} {:>12} {:>14} {:>10} {:>20} {:>12}\n",
        "algorithm",
        "final_loss",
        "acc",
        "sim_time_s",
        "MB_on_wire",
        "messages",
        "wire_MB(data/boot)",
        "extra_mem_MB"
    ));
    for r in reports {
        s.push_str(&format!(
            "{:<16} {:>12.4} {:>10} {:>12.3} {:>14.2} {:>10} {:>20} {:>12.3}\n",
            r.algorithm,
            r.final_loss(),
            r.final_accuracy()
                .map_or("-".to_string(), |a| format!("{:.1}%", 100.0 * a)),
            r.final_sim_time(),
            r.total_bytes as f64 / 1e6,
            r.total_messages,
            r.wire_bytes_by_kind.map_or("-".to_string(), |(data, boot)| {
                format!("{:.2}/{:.2}", data as f64 / 1e6, boot as f64 / 1e6)
            }),
            r.extra_memory_floats as f64 * 4.0 / 1e6,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_with(losses: &[f64]) -> Report {
        let mut r = Report::new("test", 4, 10);
        for (i, &l) in losses.iter().enumerate() {
            r.trace.push(TraceRow {
                step: i as u64,
                sim_time_s: i as f64 * 0.5,
                train_loss: l,
                eval_loss: l,
                eval_acc: Some(0.9),
                consensus_linf: 0.01,
                bytes_total: 100 * i as u64,
                theta: Some(2.0),
            });
        }
        r
    }

    #[test]
    fn loss_accessors() {
        let r = report_with(&[2.0, 1.0, 0.5]);
        assert_eq!(r.first_loss(), 2.0);
        assert_eq!(r.final_loss(), 0.5);
        assert_eq!(r.final_accuracy(), Some(0.9));
        assert_eq!(r.time_to_loss(1.0), Some(0.5));
        assert_eq!(r.time_to_loss(0.1), None);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let r = report_with(&[1.0, 0.5]);
        let csv = r.to_csv();
        assert!(csv.starts_with("algorithm,step"));
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.contains("test,1,"));
    }

    #[test]
    fn table_formats_all_reports() {
        let a = report_with(&[1.0]);
        let mut b = report_with(&[0.7]);
        b.total_messages = 1234;
        b.wire_bytes_by_kind = Some((2_000_000, 500_000));
        let t = comparison_table(&[&a, &b]);
        assert_eq!(t.lines().count(), 3);
        assert!(t.contains("final_loss"));
        // The message column is no longer dropped, and the measured byte
        // split renders data/bootstrap (or "-" without a transport).
        assert!(t.contains("messages"));
        assert!(t.contains("1234"));
        assert!(t.contains("2.00/0.50"));
        let row_a = t.lines().nth(1).unwrap();
        assert!(row_a.contains(" - "));
    }

    #[test]
    fn csv_empty_optionals_round_trip() {
        // Missing eval_acc/theta serialize as *empty* fields (not "NaN",
        // not "-"), so downstream parsers can distinguish absent from
        // zero; the python plotting helpers rely on this exact shape.
        let mut r = Report::new("bare", 2, 4);
        r.trace.push(TraceRow {
            step: 0,
            sim_time_s: 0.5,
            train_loss: 1.0,
            eval_loss: 1.0,
            eval_acc: None,
            consensus_linf: 0.01,
            bytes_total: 64,
            theta: None,
        });
        let csv = r.to_csv();
        let row = csv.lines().nth(1).unwrap();
        let fields: Vec<&str> = row.split(',').collect();
        assert_eq!(fields.len(), 9);
        assert_eq!(fields[5], ""); // eval_acc
        assert_eq!(fields[8], ""); // theta
    }

    #[test]
    fn empty_report_is_nan_not_panic() {
        let r = Report::new("x", 1, 1);
        assert!(r.final_loss().is_nan());
        assert_eq!(r.final_sim_time(), 0.0);
    }
}
