//! The cluster runtime: one OS thread per worker, communicating
//! **exclusively** through a [`Transport`] — the first runtime in the repo
//! where neighbor models exist only as wire bytes.
//!
//! ## Structure
//!
//! Every worker thread owns its model, its gradient buffer, its RNG
//! streams (implicit in the per-`(seed, round, worker)` keying), and one
//! transport endpoint. A synchronous round is:
//!
//! 1. local gradient (`Objective::loss_grad` on this worker's shard);
//! 2. [`SyncAlgorithm::node_send`] — serialize this worker's payload —
//!    then one [`Frame`] per peer through the transport;
//! 3. a **round barrier built from the frames themselves**: the worker
//!    blocks in `recv` until it holds a round-`k` frame from every peer
//!    (frames from workers running ahead are parked in a pending map);
//! 4. [`SyncAlgorithm::node_recv`] — integrate the inbox, finish the
//!    round.
//!
//! ## Bitwise equivalence
//!
//! The run is bitwise-identical to the lockstep [`Trainer`](super::Trainer)
//! — same per-round losses, same final models, same wire-byte accounting —
//! for every [`SyncAlgorithm`], because (a) per-sender FIFO plus round
//! tagging means each worker integrates exactly the payloads the lockstep
//! engine would hand it, (b) payload encodings are lossless or are the
//! exact wire codes the lockstep engines already exchange, and (c) each
//! engine's recv half accumulates in ascending-sender order — the same
//! order the lockstep phases use. `tests/cluster_equivalence.rs` pins this
//! for all algorithms; `rust/DESIGN.md` §Wire-format spells out the
//! argument.
//!
//! Two configurations are refused because they need *global* statistics no
//! message-passing worker can know locally: the Theorem-2 θ policy (its
//! G∞ estimate is a cluster-wide max) and compressed-stream accounting
//! (the lockstep model charges worker 0's compressed length for every
//! message). Both fail fast in [`ClusterTrainer::new`].

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::metrics::{Report, TraceRow};
use super::TrainConfig;
use crate::algorithms::{Algorithm, CommScope, CommStats, Inbox, StepCtx, ThetaPolicy};
use crate::objectives::Objective;
use crate::topology::Topology;
use crate::transport::{algo_wire_id, Frame, MemTransport, TcpTransport, Transport};

/// Which transport implementation carries the cluster's frames.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process mpsc channels (deterministic, no sockets).
    Mem,
    /// Localhost TCP; `port_base = 0` uses OS-assigned ephemeral ports
    /// (collision-safe), otherwise worker `i` listens on `port_base + i`.
    Tcp { port_base: u16 },
}

/// Cluster-runtime knobs on top of [`TrainConfig`].
#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    pub transport: TransportKind,
    /// Per-`recv` timeout of the round barrier: a worker that waits this
    /// long without a frame declares the cluster wedged and panics (which
    /// fails the run loudly instead of hanging CI).
    pub recv_timeout: Duration,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            transport: TransportKind::Mem,
            recv_timeout: Duration::from_secs(30),
        }
    }
}

/// Everything one worker thread brings home.
struct NodeResult {
    worker: usize,
    final_x: Vec<f32>,
    losses: Vec<f64>,
    thetas: Vec<Option<f64>>,
    stats: Vec<CommStats>,
    snapshots: Vec<(u64, Vec<f32>)>,
    grad_wall: Vec<f64>,
    algo_wall: Vec<f64>,
    frames_sent: u64,
    bytes_sent: u64,
}

/// Message-passing decentralized trainer (see module docs).
pub struct ClusterTrainer {
    cfg: TrainConfig,
    cluster: ClusterConfig,
    topo: Topology,
    objective: Box<dyn Objective>,
    rho: f64,
    deg_max: usize,
    deg_sum: usize,
    /// Frames actually shipped through the transport in the last `run`.
    pub frames_sent: u64,
    /// Measured wire bytes (header + payload) of the last `run` — compare
    /// against `Report::total_bytes`, the model's payload-only prediction.
    pub wire_bytes_sent: u64,
}

impl ClusterTrainer {
    pub fn new(
        cfg: TrainConfig,
        topo: Topology,
        objective: Box<dyn Objective>,
        cluster: ClusterConfig,
    ) -> Result<Self> {
        if topo.n() != cfg.workers {
            bail!("topology covers {} workers, config says {}", topo.n(), cfg.workers);
        }
        if objective.workers() < cfg.workers {
            bail!("objective sharded for fewer workers than the cluster");
        }
        if let Some(theta) = theta_policy(&cfg.algorithm) {
            if matches!(theta, ThetaPolicy::Theorem2 { .. }) {
                bail!(
                    "runtime=cluster needs a constant θ: the Theorem-2 policy tracks a \
                     cluster-wide G∞ estimate no message-passing worker can know locally"
                );
            }
        }
        if let Some(q) = quant_config(&cfg.algorithm) {
            if q.compression != crate::quant::Compression::None {
                bail!(
                    "runtime=cluster ships raw packed payloads; compressed-stream \
                     accounting is lockstep-only (set compression=none)"
                );
            }
            // Only the Moniqua family actually ships the §6 digest its
            // byte accounting charges (+8/message); on the baselines the
            // lockstep model counts bytes that would never cross the wire,
            // which would break measured = predicted + header·frames.
            let ships_digest = matches!(
                cfg.algorithm,
                Algorithm::Moniqua { .. }
                    | Algorithm::MoniquaSlack { .. }
                    | Algorithm::MoniquaD2 { .. }
            );
            if q.verify_hash && !ships_digest {
                bail!(
                    "runtime=cluster supports verify_hash only for the Moniqua family \
                     (algorithm '{}' has no digest on its wire format)",
                    cfg.algorithm.name()
                );
            }
        }
        let w = topo.comm_matrix();
        let rho = w.rho();
        let adj = topo.adjacency();
        let deg_max = adj.iter().map(|a| a.len()).max().unwrap_or(0);
        let deg_sum = adj.iter().map(|a| a.len()).sum();
        Ok(ClusterTrainer {
            cfg,
            cluster,
            topo,
            objective,
            rho,
            deg_max,
            deg_sum,
            frames_sent: 0,
            wire_bytes_sent: 0,
        })
    }

    /// ρ of the communication matrix in use.
    pub fn rho(&self) -> f64 {
        self.rho
    }

    /// Run the experiment: spawn the cluster, train, reassemble the
    /// [`Report`] from the per-node traces.
    pub fn run(&mut self) -> Result<Report> {
        let n = self.cfg.workers;
        let d = self.objective.dim();
        let w = self.topo.comm_matrix();
        let adj = self.topo.adjacency();

        let mut engines: Vec<_> =
            (0..n).map(|_| self.cfg.algorithm.make_sync(&w, d)).collect();
        for e in engines.iter_mut() {
            // One engine per OS thread: keep each round pool sequential so
            // an n-node cluster doesn't oversubscribe n× the cores. The
            // engine determinism contract makes this a pure perf knob.
            e.set_threads(1);
        }
        let scope = engines[0].comm_scope();
        let algo_id = algo_wire_id(self.cfg.algorithm.name());
        let wire_bits = quant_config(&self.cfg.algorithm).map_or(32, |q| q.bits as u16);

        let transports: Vec<Box<dyn Transport>> = match self.cluster.transport {
            TransportKind::Mem => MemTransport::cluster(n)
                .into_iter()
                .map(|t| Box::new(t) as Box<dyn Transport>)
                .collect(),
            TransportKind::Tcp { port_base } => TcpTransport::cluster(n, port_base)
                .context("bind cluster TCP listeners")?
                .into_iter()
                .map(|t| Box::new(t) as Box<dyn Transport>)
                .collect(),
        };

        let recv_timeout = self.cluster.recv_timeout;
        let mut results: Vec<NodeResult> = {
            let cfg = &self.cfg;
            let objective = &self.objective;
            let adj = &adj;
            std::thread::scope(|s| {
                let mut handles = Vec::with_capacity(n);
                for (i, (engine, transport)) in
                    engines.into_iter().zip(transports).enumerate()
                {
                    let peers: Vec<usize> = match scope {
                        CommScope::Neighbors => adj[i].clone(),
                        CommScope::All => (0..n).filter(|&j| j != i).collect(),
                    };
                    let node_cfg = cfg.clone();
                    let node_obj = objective.box_clone();
                    let rho = self.rho;
                    handles.push(s.spawn(move || {
                        run_node(
                            i,
                            node_cfg,
                            engine,
                            transport,
                            node_obj,
                            peers,
                            rho,
                            recv_timeout,
                            algo_id,
                            wire_bits,
                        )
                    }));
                }
                handles
                    .into_iter()
                    .map(|h| h.join().expect("cluster worker panicked"))
                    .collect()
            })
        };
        results.sort_by_key(|r| r.worker);
        self.frames_sent = results.iter().map(|r| r.frames_sent).sum();
        self.wire_bytes_sent = results.iter().map(|r| r.bytes_sent).sum();

        Ok(self.assemble_report(n, d, results))
    }

    /// Reassemble the lockstep trainer's [`Report`] from per-node traces.
    /// The pricing calls, byte formulas, and mean/consensus evaluation are
    /// the *same code* `Trainer::run` uses ([`RoundLedger`](super::RoundLedger),
    /// [`eval_mean`](super::eval_mean)), and the summation orders match
    /// (losses in ascending worker order), so every determinism-relevant
    /// field is bitwise what the lockstep run produces. Only `sim_time_s`
    /// differs in *semantics*: a concurrent round is paced by its slowest
    /// worker (max over nodes) rather than the lockstep's
    /// sequential-measured average.
    fn assemble_report(&mut self, n: usize, d: usize, results: Vec<NodeResult>) -> Report {
        let mut report = Report::new(self.cfg.algorithm.name(), n, d);
        report.extra_memory_floats = self
            .cfg
            .algorithm
            .extra_memory_floats(n, self.topo.edge_count(), d);
        let mut ledger =
            super::RoundLedger::new(self.cfg.network, n, self.deg_sum, self.deg_max);
        let mut mean = vec![0.0f32; d];
        let mut eval_idx = 0usize;
        for step in 0..self.cfg.steps {
            let r = step as usize;
            let stats = results[0].stats[r];
            let train_loss =
                results.iter().map(|nr| nr.losses[r]).sum::<f64>() / n as f64;
            let grad_wall =
                results.iter().map(|nr| nr.grad_wall[r]).fold(0.0f64, f64::max);
            let grad_time = self.cfg.grad_time_s.unwrap_or(grad_wall);
            let algo_wall =
                results.iter().map(|nr| nr.algo_wall[r]).fold(0.0f64, f64::max);
            ledger.charge(&stats, grad_time, algo_wall);

            if step % self.cfg.eval_every == 0 || step + 1 == self.cfg.steps {
                let xs: Vec<&[f32]> = results
                    .iter()
                    .map(|nr| {
                        let (snap_step, x) = &nr.snapshots[eval_idx];
                        debug_assert_eq!(*snap_step, step);
                        x.as_slice()
                    })
                    .collect();
                let (eval, consensus) =
                    super::eval_mean(self.objective.as_mut(), &xs, &mut mean);
                report.trace.push(TraceRow {
                    step,
                    sim_time_s: ledger.sim_time,
                    train_loss,
                    eval_loss: eval.loss,
                    eval_acc: eval.accuracy,
                    consensus_linf: consensus,
                    bytes_total: ledger.total_bytes,
                    theta: results[0].thetas[r],
                });
                eval_idx += 1;
            }
        }
        ledger.finish(&mut report);
        report.final_params = {
            let xs: Vec<&[f32]> =
                results.iter().map(|nr| nr.final_x.as_slice()).collect();
            crate::linalg::mean_into(&mut mean, &xs);
            mean.clone()
        };
        report
    }
}

/// θ policy carried by the algorithm selector, if any.
fn theta_policy(a: &Algorithm) -> Option<ThetaPolicy> {
    match a {
        Algorithm::Moniqua { theta, .. }
        | Algorithm::MoniquaSlack { theta, .. }
        | Algorithm::MoniquaD2 { theta, .. } => Some(*theta),
        _ => None,
    }
}

/// Quantizer config carried by the algorithm selector, if any.
fn quant_config(a: &Algorithm) -> Option<crate::quant::QuantConfig> {
    match a {
        Algorithm::NaiveQuant { quant, .. }
        | Algorithm::Moniqua { quant, .. }
        | Algorithm::MoniquaSlack { quant, .. }
        | Algorithm::MoniquaD2 { quant, .. }
        | Algorithm::Dcd { quant, .. }
        | Algorithm::Ecd { quant, .. }
        | Algorithm::Choco { quant, .. }
        | Algorithm::DeepSqueeze { quant, .. } => Some(*quant),
        Algorithm::AllReduce | Algorithm::DPsgd | Algorithm::D2 => None,
    }
}

/// One worker's whole life: gradient → send → frame barrier → recv, for
/// every round. Panics (failing the run) on transport errors or protocol
/// violations — a wedged or corrupt cluster must die loudly.
#[allow(clippy::too_many_arguments)]
fn run_node(
    i: usize,
    cfg: TrainConfig,
    mut engine: Box<dyn crate::algorithms::SyncAlgorithm>,
    mut transport: Box<dyn Transport>,
    mut objective: Box<dyn Objective>,
    peers: Vec<usize>,
    rho: f64,
    recv_timeout: Duration,
    algo_id: u16,
    wire_bits: u16,
) -> NodeResult {
    let d = objective.dim();
    let mut x = objective.init();
    let mut grad = vec![0.0f32; d];
    let mut payload: Vec<u8> = Vec::new();
    // Frames from workers running ahead of us, keyed (round, sender).
    let mut pending: BTreeMap<(u64, usize), Frame> = BTreeMap::new();
    let mut result = NodeResult {
        worker: i,
        final_x: Vec::new(),
        losses: Vec::with_capacity(cfg.steps as usize),
        thetas: Vec::with_capacity(cfg.steps as usize),
        stats: Vec::with_capacity(cfg.steps as usize),
        snapshots: Vec::new(),
        grad_wall: Vec::with_capacity(cfg.steps as usize),
        algo_wall: Vec::with_capacity(cfg.steps as usize),
        frames_sent: 0,
        bytes_sent: 0,
    };
    let mut lr = cfg.lr;
    let mut g_inf = 0.0f64;
    for round in 0..cfg.steps {
        if cfg.decay_at.contains(&round) {
            lr *= cfg.decay_factor;
        }
        // --- local gradient --------------------------------------------
        let t0 = Instant::now();
        let loss = objective.loss_grad(i, round, &x, &mut grad);
        // Node-local running max — Trainer's global version only feeds the
        // Theorem-2 θ policy, which this runtime refuses.
        g_inf = g_inf.max(crate::linalg::norm_inf(&grad) as f64);
        result.grad_wall.push(t0.elapsed().as_secs_f64());
        let ctx = StepCtx { seed: cfg.seed, rho, g_inf };

        // --- send half --------------------------------------------------
        let t1 = Instant::now();
        payload.clear();
        engine.node_send(i, &x, &grad, lr, round, &ctx, &mut payload);
        let frame = Frame {
            round,
            sender: i as u16,
            algo: algo_id,
            bits: wire_bits,
            theta: engine.last_theta().unwrap_or(0.0) as f32,
            payload: std::mem::take(&mut payload),
        };
        let send_compute = t1.elapsed().as_secs_f64();
        // One broadcast call: the frame is serialized + checksummed once
        // and the wire bytes are reused for every peer.
        transport
            .broadcast(&peers, &frame)
            .unwrap_or_else(|e| panic!("worker {i} round {round}: broadcast failed: {e}"));
        result.frames_sent += peers.len() as u64;
        result.bytes_sent += peers.len() as u64 * frame.encoded_len() as u64;

        // --- round barrier from the frames themselves ------------------
        let mut got: Vec<Frame> = Vec::with_capacity(peers.len());
        for &p in &peers {
            if let Some(f) = pending.remove(&(round, p)) {
                got.push(f);
            }
        }
        while got.len() < peers.len() {
            let f = transport.recv(recv_timeout).unwrap_or_else(|e| {
                panic!("worker {i} round {round}: barrier recv failed: {e}")
            });
            let from = f.sender as usize;
            assert_eq!(f.algo, algo_id, "worker {i}: cross-algorithm frame from {from}");
            assert_eq!(f.bits, wire_bits, "worker {i}: bit-budget mismatch from {from}");
            assert!(
                peers.contains(&from),
                "worker {i}: frame from non-peer {from}"
            );
            assert!(
                f.round >= round,
                "worker {i}: stale round-{} frame from {from} at round {round}",
                f.round
            );
            if f.round == round {
                got.push(f);
            } else {
                pending.insert((f.round, from), f);
            }
        }

        // --- recv half --------------------------------------------------
        let t2 = Instant::now();
        let inbox = Inbox::new(
            got.iter().map(|f| (f.sender as usize, f.payload.as_slice())).collect(),
        );
        let stats = engine.node_recv(i, &mut x, &grad, lr, round, &ctx, &inbox);
        result.algo_wall.push(send_compute + t2.elapsed().as_secs_f64());
        result.losses.push(loss);
        result.thetas.push(engine.last_theta());
        result.stats.push(stats);
        if round % cfg.eval_every == 0 || round + 1 == cfg.steps {
            result.snapshots.push((round, x.clone()));
        }
        payload = frame.payload; // reuse the allocation next round
    }
    result.final_x = x;
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::ThetaPolicy;
    use crate::quant::{Compression, QuantConfig};

    fn base_cfg(algorithm: Algorithm) -> TrainConfig {
        TrainConfig { workers: 4, steps: 6, eval_every: 2, algorithm, ..TrainConfig::default() }
    }

    fn objective() -> Box<dyn Objective> {
        Box::new(crate::objectives::Quadratic::new(8, 1.0, 0.1, 4, 3))
    }

    #[test]
    fn refuses_theorem2_theta() {
        let cfg = base_cfg(Algorithm::Moniqua {
            theta: ThetaPolicy::Theorem2 { warmup: 5, safety: 2.0 },
            quant: QuantConfig::stochastic(8),
        });
        let err = ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            ClusterConfig::default(),
        );
        assert!(err.is_err());
    }

    #[test]
    fn refuses_verify_hash_outside_moniqua_family() {
        // Baselines charge +8 B/message for a digest they never ship.
        let cfg = base_cfg(Algorithm::Dcd {
            quant: QuantConfig::stochastic(8).with_verify_hash(true),
            range: 4.0,
        });
        assert!(ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            ClusterConfig::default(),
        )
        .is_err());
        // …while Moniqua (which does ship it) is accepted.
        let cfg = base_cfg(Algorithm::Moniqua {
            theta: ThetaPolicy::Constant(2.0),
            quant: QuantConfig::stochastic(8).with_verify_hash(true),
        });
        assert!(ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            ClusterConfig::default(),
        )
        .is_ok());
    }

    #[test]
    fn refuses_compressed_streams() {
        let cfg = base_cfg(Algorithm::Moniqua {
            theta: ThetaPolicy::Constant(2.0),
            quant: QuantConfig::stochastic(8).with_compression(Compression::Rle),
        });
        assert!(ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            ClusterConfig::default(),
        )
        .is_err());
    }

    #[test]
    fn mem_cluster_trains_and_reports() {
        let cfg = base_cfg(Algorithm::DPsgd);
        let mut t = ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            ClusterConfig::default(),
        )
        .unwrap();
        let report = t.run().unwrap();
        assert_eq!(report.trace.len(), 4); // steps 0,2,4,5
        assert!(t.frames_sent > 0);
        assert!(t.wire_bytes_sent as usize > report.total_bytes as usize);
        assert_eq!(report.final_params.len(), 8);
    }
}
