//! The cluster runtime: workers communicating **exclusively** through a
//! [`Transport`] — the first runtime in the repo where neighbor models
//! exist only as wire bytes.
//!
//! ## Structure
//!
//! Every worker owns its model, its gradient buffer, its RNG streams
//! (implicit in the per-`(seed, round, worker)` keying), and one transport
//! endpoint. A synchronous round is:
//!
//! 1. local gradient (`Objective::loss_grad` on this worker's shard);
//! 2. [`SyncAlgorithm::node_send`] — serialize this worker's payload —
//!    then one [`Frame`](crate::transport::Frame) per peer through the
//!    transport;
//! 3. a **round barrier built from the frames themselves**: the worker
//!    waits until it holds a round-`k` frame from every peer (frames from
//!    workers running ahead are parked in a pending map);
//! 4. [`SyncAlgorithm::node_recv`] — integrate the inbox, finish the
//!    round.
//!
//! The whole per-worker protocol lives in
//! [`RoundStateMachine`](super::round::RoundStateMachine) (`round.rs`),
//! which is runtime-agnostic. Two drivers advance it:
//!
//! * [`DriverKind::Threaded`] — one OS thread per worker, blocking in
//!   `recv` between state-machine steps (this module's [`run_node`]);
//! * [`DriverKind::Reactor`] — a readiness loop
//!   ([`super::reactor`]) multiplexing hundreds-to-thousands of workers
//!   onto a small pool of driver threads over nonblocking transports.
//!
//! Both produce bitwise-identical runs; `tests/reactor_equivalence.rs`
//! pins reactor ≡ threaded ≡ lockstep.
//!
//! ## Pipelined rounds
//!
//! With [`ClusterConfig::pipeline`] (the default), step 2 moves to *round
//! entry* for engines whose send half never reads the gradient
//! ([`SendPhase::PreGradient`](crate::algorithms::SendPhase)): the frame
//! is encoded from `x` alone and broadcast before `loss_grad` runs, so the
//! wire drains **under** the compute and a comm-bound round costs
//! `max(compute, comm) + mix` instead of `compute + comm`. The payload
//! bytes are identical either way — `x`, `lr`, `round`, and the RNG seed
//! are all fixed before the gradient, and the one `StepCtx` field that is
//! not (`g_inf`) feeds only the Theorem-2 θ policy this runtime refuses —
//! so the bitwise contract below is untouched
//! (`tests/cluster_equivalence.rs` pins the pipelined and strict schedules
//! against the lockstep trainer). Gradient-consuming engines keep the
//! strict order under the same scheduler. `rust/DESIGN.md` §Pipelining has
//! the full state machine and the WAL/checkpoint interaction.
//!
//! ## Failure propagation
//!
//! A worker that cannot complete a round — its barrier deadline expires,
//! or the transport fails under it — does not panic: it records a typed
//! [`WorkerFailure`] on the cluster's shared abort latch and returns it.
//! Sibling workers poll the latch once per recv tick (threaded driver) or
//! are woken directly through the latch's wake tokens (reactor), so they
//! abort within one tick/poll-iteration instead of each burning its own
//! full `recv_timeout` and dying with a misleading "missing frames"
//! message. [`ClusterTrainer::run`] surfaces the *originating* worker (the
//! first to trip the latch) in its error, and keeps every per-worker
//! failure in [`ClusterTrainer::failures`]. Protocol violations (corrupt
//! frames, cross-algorithm traffic, replay holes) still panic — those are
//! bugs, not cluster wedges.
//!
//! ## Bitwise equivalence
//!
//! The run is bitwise-identical to the lockstep [`Trainer`](super::Trainer)
//! — same per-round losses, same final models, same wire-byte accounting —
//! for every [`SyncAlgorithm`], because (a) per-sender FIFO plus round
//! tagging means each worker integrates exactly the payloads the lockstep
//! engine would hand it, (b) payload encodings are lossless or are the
//! exact wire codes the lockstep engines already exchange, and (c) each
//! engine's recv half accumulates in ascending-sender order — the same
//! order the lockstep phases use. `tests/cluster_equivalence.rs` pins this
//! for all algorithms; `rust/DESIGN.md` §Wire-format spells out the
//! argument.
//!
//! ## Elasticity
//!
//! With an [`ElasticConfig`] the run becomes a sequence of **epochs of
//! stable membership** separated by reconfiguration barriers
//! ([`MembershipPlan`](crate::elastic::MembershipPlan),
//! `rust/DESIGN.md` §Elasticity):
//!
//! * **crash@r:w** — worker `w` loses all in-memory state at the start of
//!   round `r`, restores its last snapshot from `ckpt_dir`, replays the
//!   rounds in between against its frame log (no retransmissions, no
//!   peer involvement), and produces a **bitwise-identical** run — pinned
//!   by `tests/elastic_equivalence.rs` against the uninterrupted lockstep
//!   trainer for every algorithm over both transports.
//! * **join@r:w / leave@r:w** — the gossip matrix is re-wired through
//!   [`SyncAlgorithm::swap_matrix`] over the active cohort. A joiner first
//!   receives one full-precision bootstrap frame from its designated
//!   neighbor and adopts that model: the modulo decode of Lemma 1 is only
//!   exact within the θ proximity ball, which an arbitrary model does not
//!   satisfy (the negative test shows the decode corrupting when the
//!   bootstrap is skipped).
//!
//! Two configurations are refused because they need *global* statistics no
//! message-passing worker can know locally: the Theorem-2 θ policy (its
//! G∞ estimate is a cluster-wide max) and compressed-stream accounting
//! (the lockstep model charges worker 0's compressed length for every
//! message). Both fail fast in [`ClusterTrainer::new`].

use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::metrics::{Report, TraceRow};
use super::round::{
    observe_wait_end, peers_of, recv_until, AbortLatch, BarrierRecv, MachineStatus,
    NodeResult, NodeSpec, RoundStateMachine, WaitKey,
};
use crate::telemetry::{Clock, Counter, Registry, Telemetry};
use super::TrainConfig;
use crate::adversary::ByzantineConfig;
use crate::algorithms::{Algorithm, CommScope, MixPolicy, SyncAlgorithm, ThetaPolicy};
use crate::elastic::membership::{epoch_at, ElasticConfig, Epoch};
use crate::objectives::Objective;
use crate::topology::Topology;
use crate::transport::{
    algo_wire_id, saturating_deadline, MemTransport, NbTcpTransport, TcpTransport,
    Transport,
};

pub use super::round::WorkerFailure;

/// Which transport implementation carries the cluster's frames.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process mpsc channels (deterministic, no sockets).
    Mem,
    /// Localhost TCP; `port_base = 0` uses OS-assigned ephemeral ports
    /// (collision-safe), otherwise worker `i` listens on `port_base + i`.
    /// The threaded driver uses the reader-thread [`TcpTransport`]; the
    /// reactor uses the thread-free nonblocking [`NbTcpTransport`].
    Tcp { port_base: u16 },
}

/// Which driver advances the per-worker round machines.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriverKind {
    /// One OS thread per worker, blocking in `recv` between state-machine
    /// steps — the runtime this module always had.
    Threaded,
    /// A readiness loop ([`super::reactor`]) multiplexing every worker's
    /// round machine onto `threads` driver threads over nonblocking
    /// transports — hundreds-to-thousands of workers per core. `threads =
    /// 0` means one per available core (capped at the worker count).
    Reactor { threads: usize },
}

/// Cluster-runtime knobs on top of [`TrainConfig`].
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub transport: TransportKind,
    /// Total time budget of one round barrier (and of one bootstrap
    /// wait). The deadline is computed **once** at barrier entry and every
    /// `recv` gets only the remaining slice, so a trickle of stragglers
    /// can never stretch one "30s" barrier to `peers × 30s`. A worker
    /// whose deadline expires fails the run with a typed error naming the
    /// configured timeout and the exact `(round, sender)` pairs it is
    /// still missing. Arbitrarily large values (`Duration::MAX` = "never")
    /// are safe: deadlines saturate instead of overflowing.
    pub recv_timeout: Duration,
    /// Elastic membership + checkpoint/recovery plan (None = the fixed
    /// cohort the runtime always had).
    pub elastic: Option<ElasticConfig>,
    /// Pipelined round scheduling (module docs §Pipelined rounds):
    /// gradient-independent frames are broadcast at round entry so they
    /// stream on the wire while the local gradient is computed. Bitwise
    /// value-equivalent to the strict schedule; `false` forces the strict
    /// gradient → send → barrier → mix sequence for every engine.
    pub pipeline: bool,
    /// Which driver advances the round machines (module docs §Structure).
    pub driver: DriverKind,
    /// Byzantine fault injection: which workers turn adversarial, how they
    /// misbehave, and how many strikes an honest node tolerates before
    /// excising the offender from its gossip row (`rust/DESIGN.md`
    /// §Adversarial-robustness). `None` means no adversaries — the defense
    /// gate still runs on every frame, it just never fires on honest
    /// traffic.
    pub byz: Option<ByzantineConfig>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            transport: TransportKind::Mem,
            recv_timeout: Duration::from_secs(30),
            elastic: None,
            pipeline: true,
            driver: DriverKind::Threaded,
            byz: None,
        }
    }
}

/// Message-passing decentralized trainer (see module docs).
pub struct ClusterTrainer {
    cfg: TrainConfig,
    cluster: ClusterConfig,
    objective: Box<dyn Objective>,
    /// The physical topology — kept so quarantine can re-derive the gossip
    /// row over survivors ([`crate::adversary::excised_matrix`]).
    topo: Topology,
    /// Membership epochs (exactly one for a non-elastic run).
    epochs: Vec<Epoch>,
    rho: f64,
    /// Whether data frames carry the machine-level round-bound seal
    /// (decided once in `new`: `verify_wire`, or `verify_hash` on an engine
    /// that does not ship its own §6 digest).
    seal: bool,
    /// Frames actually shipped through the transport in the last `run`
    /// (bootstrap frames included; replayed rounds count their original
    /// send exactly once).
    pub frames_sent: u64,
    /// Measured wire bytes (header + payload) of the last `run` — compare
    /// against `Report::total_bytes`, the model's payload-only prediction.
    pub wire_bytes_sent: u64,
    /// Every typed per-worker failure of the last `run` (empty on
    /// success): the origin plus its sibling aborts, in join order. The
    /// `run` error names only the origin; tests and callers that need the
    /// full picture read this.
    pub failures: Vec<WorkerFailure>,
    /// Per-run telemetry registry (sharded counters + log2 histograms).
    /// Every transport endpoint and round machine records into it on its
    /// own worker shard; recording is always on (a few relaxed-atomic adds
    /// per event) and only the *export* is gated by the `metrics=` config —
    /// so a metrics-enabled run is bitwise the metrics-off run by
    /// construction.
    metrics: Registry,
}

impl ClusterTrainer {
    pub fn new(
        cfg: TrainConfig,
        topo: Topology,
        objective: Box<dyn Objective>,
        cluster: ClusterConfig,
    ) -> Result<Self> {
        if topo.n() != cfg.workers {
            bail!("topology covers {} workers, config says {}", topo.n(), cfg.workers);
        }
        if objective.workers() < cfg.workers {
            bail!("objective sharded for fewer workers than the cluster");
        }
        if let Some(theta) = theta_policy(&cfg.algorithm) {
            if matches!(theta, ThetaPolicy::Theorem2 { .. }) {
                bail!(
                    "runtime=cluster needs a constant θ: the Theorem-2 policy tracks a \
                     cluster-wide G∞ estimate no message-passing worker can know locally"
                );
            }
        }
        if let Some(q) = quant_config(&cfg.algorithm) {
            if q.compression != crate::quant::Compression::None {
                bail!(
                    "runtime=cluster ships raw packed payloads; compressed-stream \
                     accounting is lockstep-only (set compression=none)"
                );
            }
        }
        // Membership epochs: one full-cohort epoch without a plan; a
        // validated sequence of reconfigurations with one. The epoch-0
        // matrix of a full cohort is bitwise the topology's own Metropolis
        // matrix, so the non-elastic path is unchanged.
        let plan = cluster
            .elastic
            .as_ref()
            .map(|e| e.plan.clone())
            .unwrap_or_default();
        let epochs = plan
            .epochs(&topo, cfg.steps)
            .context("invalid elastic membership plan")?;
        if let Some(elastic) = &cluster.elastic {
            if elastic.plan.has_crashes() && elastic.ckpt_dir.is_none() {
                bail!("churn plan contains crashes but no ckpt_dir is configured");
            }
            if elastic.plan.reconfigures() {
                // Probe: reconfiguration re-wires the gossip matrix through
                // swap_matrix, which per-edge-state engines (and derived
                // matrices like the Theorem-3 slack form) refuse.
                let mut probe = cfg.algorithm.make_sync(&epochs[0].matrix, objective.dim());
                if !probe.swap_matrix(&epochs[0].matrix) {
                    bail!(
                        "algorithm '{}' cannot re-target its gossip matrix, so it does \
                         not support elastic joins/leaves (crash-only plans are fine)",
                        cfg.algorithm.name()
                    );
                }
            }
        }
        // Wire-integrity gate. Only the Moniqua family ships the §6
        // semantic digest its byte accounting charges (+8/message); every
        // other engine can opt into a machine-level round-bound seal over
        // the raw wire bytes instead — same +8 B tail, appended after
        // `node_send` and verified+stripped by the gate before the engine
        // sees the payload. An engine must price that tail into its byte
        // model (`set_verify_wire`) or measured = predicted + header·frames
        // breaks, so engines that cannot are refused up front.
        let ships_digest = matches!(
            cfg.algorithm,
            Algorithm::Moniqua { .. }
                | Algorithm::MoniquaSlack { .. }
                | Algorithm::MoniquaD2 { .. }
        );
        let verify_hash = quant_config(&cfg.algorithm).is_some_and(|q| q.verify_hash);
        let seal = cfg.verify_wire || (verify_hash && !ships_digest);
        if let Some(b) = cluster.byz {
            b.validate(cfg.workers)
                .context("invalid byzantine fault configuration")?;
        }
        if seal || cfg.mix != MixPolicy::Mean || cluster.byz.is_some() {
            // Probe one engine so unsupported combinations fail with one
            // typed error here instead of a mid-run panic in every worker.
            let mut probe = cfg.algorithm.make_sync(&epochs[0].matrix, objective.dim());
            if seal && !probe.set_verify_wire(true) {
                bail!(
                    "algorithm '{}' cannot price the +8 B machine seal into its byte \
                     model, so the wire-integrity gate is refused (the Moniqua family \
                     ships its own §6 digest — request it with verify_hash instead)",
                    cfg.algorithm.name()
                );
            }
            if !probe.set_mix(cfg.mix) {
                bail!(
                    "algorithm '{}' does not support mix={}: robust mixing needs a \
                     deviation-form gossip accumulate (and clip radii must be positive)",
                    cfg.algorithm.name(),
                    cfg.mix.name()
                );
            }
            if cluster.byz.is_some()
                && matches!(probe.comm_scope(), CommScope::Neighbors)
                && !probe.swap_matrix(&epochs[0].matrix)
            {
                bail!(
                    "algorithm '{}' cannot re-target its gossip matrix, so quarantine \
                     cannot excise convicted peers from the averaging row",
                    cfg.algorithm.name()
                );
            }
        }
        let rho = epochs[0].rho;
        Ok(ClusterTrainer {
            cfg,
            cluster,
            objective,
            topo,
            epochs,
            rho,
            seal,
            frames_sent: 0,
            wire_bytes_sent: 0,
            failures: Vec::new(),
            metrics: Registry::new(),
        })
    }

    /// ρ of the founding epoch's communication matrix.
    pub fn rho(&self) -> f64 {
        self.rho
    }

    /// The run's telemetry registry — snapshot it *after* `run` returns
    /// (snapshotting allocates; the hot path never does).
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Run the experiment: spawn the cluster, train, reassemble the
    /// [`Report`] from the per-node traces.
    pub fn run(&mut self) -> Result<Report> {
        let n = self.cfg.workers;
        let d = self.objective.dim();
        self.failures.clear();
        // Fresh registry per run: like `frames_sent`, the counters describe
        // the *last* run, not the trainer's lifetime.
        self.metrics = Registry::new();

        let mut engines: Vec<_> = (0..n)
            .map(|_| self.cfg.algorithm.make_sync(&self.epochs[0].matrix, d))
            .collect();
        for e in engines.iter_mut() {
            // One engine per driver thread: keep each round pool sequential
            // so the cluster doesn't oversubscribe n× the cores. The
            // engine determinism contract makes this a pure perf knob.
            e.set_threads(1);
        }
        let scope = engines[0].comm_scope();
        let algo_id = algo_wire_id(self.cfg.algorithm.name());
        let wire_bits = quant_config(&self.cfg.algorithm).map_or(32, |q| q.bits as u16);

        // Topology-aware pool prewarm: the steady-state working set is two
        // rounds of frames in flight per *directed edge of the densest
        // epoch* (pipelining keeps round k and k+1 alive at once), plus one
        // scratch buffer per worker — on sparse graphs this is O(n·deg),
        // not the O(n²) a dense-cohort bound would seed. `4·d` bytes covers
        // every payload encoding (quantized codes are strictly smaller)
        // plus header slack, so warm-up rounds draw only recycled capacity.
        let working_set = {
            let densest: usize = self
                .epochs
                .iter()
                .map(|ep| {
                    (0..n)
                        .filter(|&i| ep.active[i])
                        .map(|i| peers_of(ep, i, scope).len())
                        .sum()
                })
                .max()
                .unwrap_or(0);
            2 * densest + n
        };

        let use_reactor = matches!(self.cluster.driver, DriverKind::Reactor { .. });
        let mut transports: Vec<Box<dyn Transport>> = match self.cluster.transport {
            TransportKind::Mem => MemTransport::cluster_prewarmed(n, working_set, 4 * d + 64)
                .into_iter()
                .map(|t| Box::new(t) as Box<dyn Transport>)
                .collect(),
            TransportKind::Tcp { port_base } if use_reactor => {
                // The reactor drives transports by polling; the nonblocking
                // endpoint carries the run with zero reader threads.
                NbTcpTransport::cluster(n, port_base)
                    .context("bind cluster TCP listeners")?
                    .into_iter()
                    .map(|t| Box::new(t) as Box<dyn Transport>)
                    .collect()
            }
            TransportKind::Tcp { port_base } => TcpTransport::cluster(n, port_base)
                .context("bind cluster TCP listeners")?
                .into_iter()
                .map(|t| Box::new(t) as Box<dyn Transport>)
                .collect(),
        };
        // Each endpoint attributes its frames/bytes/pool traffic to its own
        // worker's shard; drivers record wait/latency histograms on the
        // same shard through the spec below.
        for (i, t) in transports.iter_mut().enumerate() {
            t.set_metrics(Telemetry::new(&self.metrics, i));
        }

        let (ckpt_every, ckpt_dir, skip_bootstrap) = match &self.cluster.elastic {
            Some(e) => (e.ckpt_every, e.ckpt_dir.clone(), e.skip_bootstrap),
            None => (0, None, false),
        };
        let recv_timeout = self.cluster.recv_timeout;
        let pipeline = self.cluster.pipeline;
        let abort = AbortLatch::default();
        let mut results: Vec<NodeResult> = Vec::with_capacity(n);
        let mut failures: Vec<WorkerFailure> = Vec::new();
        {
            let cfg = &self.cfg;
            let objective = &self.objective;
            let epochs: &[Epoch] = &self.epochs;
            let elastic_plan = self.cluster.elastic.as_ref().map(|e| &e.plan);
            let abort = &abort;
            let registry = self.metrics.clone();
            let topo = &self.topo;
            let byz = self.cluster.byz;
            let strike_limit = byz.map_or(3, |b| b.strike_limit);
            let seal = self.seal;
            let make_spec = |i: usize| NodeSpec {
                cfg: cfg.clone(),
                recv_timeout,
                algo_id,
                wire_bits,
                scope,
                epochs,
                crashes: elastic_plan
                    .map(|p| p.crashes_for(i))
                    .unwrap_or_default(),
                ckpt_every,
                ckpt_dir: ckpt_dir.clone(),
                skip_bootstrap,
                pipeline,
                telemetry: Telemetry::new(&registry, i),
                clock: Clock::monotonic(),
                topo: topo.clone(),
                byz: byz.and_then(|b| b.is_byz(i).then_some(b.mode)),
                strike_limit,
                seal,
            };
            match self.cluster.driver {
                DriverKind::Threaded => std::thread::scope(|s| {
                    let mut handles = Vec::with_capacity(n);
                    for (i, (engine, transport)) in
                        engines.into_iter().zip(transports).enumerate()
                    {
                        let spec = make_spec(i);
                        let node_obj = objective.box_clone();
                        handles.push(s.spawn(move || {
                            run_node(i, engine, transport, node_obj, spec, abort)
                        }));
                    }
                    for h in handles {
                        match h.join() {
                            Ok(Ok(r)) => results.push(r),
                            Ok(Err(f)) => failures.push(f),
                            // Protocol-violation panics stay panics:
                            // re-raise after the scope has joined every
                            // thread.
                            Err(p) => std::panic::resume_unwind(p),
                        }
                    }
                }),
                DriverKind::Reactor { threads } => {
                    let workers: Vec<_> = engines
                        .into_iter()
                        .zip(transports)
                        .enumerate()
                        .map(|(i, (engine, transport))| {
                            super::reactor::ReactorWorker::new(
                                RoundStateMachine::new(
                                    i,
                                    engine,
                                    objective.box_clone(),
                                    make_spec(i),
                                ),
                                transport,
                            )
                        })
                        .collect();
                    let threads = if threads == 0 {
                        std::thread::available_parallelism()
                            .map(|p| p.get())
                            .unwrap_or(1)
                    } else {
                        threads
                    };
                    let threads = threads.clamp(1, n.max(1));
                    let (rs, fs) = super::reactor::drive(
                        workers,
                        threads,
                        recv_timeout,
                        abort,
                        registry.clone(),
                    );
                    results = rs;
                    failures = fs;
                }
            }
        };
        if !failures.is_empty() {
            // The originating worker is the first to have tripped the
            // latch; every other failure is (usually) a sibling abort.
            let origin = abort.origin().unwrap_or_else(|| failures[0].clone());
            let siblings: Vec<String> = failures
                .iter()
                .filter(|f| f.worker != origin.worker)
                .map(|f| f.to_string())
                .collect();
            self.failures = failures;
            if siblings.is_empty() {
                bail!("cluster run failed at {origin}");
            }
            bail!("cluster run failed at {origin}; siblings: [{}]", siblings.join("; "));
        }
        results.sort_by_key(|r| r.worker);
        self.frames_sent = results.iter().map(|r| r.trace.frames_sent).sum();
        self.wire_bytes_sent = results.iter().map(|r| r.trace.bytes_sent).sum();

        Ok(self.assemble_report(n, d, results))
    }

    /// Reassemble the lockstep trainer's [`Report`] from per-node traces.
    /// The pricing calls, byte formulas, and mean/consensus evaluation are
    /// the *same code* `Trainer::run` uses ([`RoundLedger`](super::RoundLedger),
    /// [`eval_mean`](super::eval_mean)), and the summation orders match
    /// (ascending worker order over the round's *active* cohort — the whole
    /// cluster when membership is static), so every determinism-relevant
    /// field is bitwise what the lockstep run produces. Only `sim_time_s`
    /// differs in *semantics*: a concurrent round is paced by its slowest
    /// worker (max over nodes) rather than the lockstep's
    /// sequential-measured average.
    fn assemble_report(&mut self, n: usize, d: usize, results: Vec<NodeResult>) -> Report {
        let mut report = Report::new(self.cfg.algorithm.name(), n, d);
        report.extra_memory_floats = self.cfg.algorithm.extra_memory_floats(
            n,
            self.epochs[0].adj.iter().map(|a| a.len()).sum::<usize>() / 2,
            d,
        );
        let (deg_sum0, deg_max0) = self.epochs[0].degrees();
        let mut ledger = super::RoundLedger::new(
            self.cfg.network,
            self.epochs[0].active_count(),
            deg_sum0,
            deg_max0,
        );
        let mut mean = vec![0.0f32; d];
        let mut cur_epoch_start = self.epochs[0].start;
        for step in 0..self.cfg.steps {
            let ep = epoch_at(&self.epochs, step);
            if ep.start != cur_epoch_start {
                cur_epoch_start = ep.start;
                let (deg_sum, deg_max) = ep.degrees();
                ledger.reconfigure(ep.active_count(), deg_sum, deg_max);
            }
            let active: Vec<&NodeResult> = results
                .iter()
                .filter(|nr| ep.active[nr.worker])
                .collect();
            let stats = active[0].trace.stats_at(step).unwrap_or_else(|| {
                panic!("worker {} has no stats for round {step}", active[0].worker)
            });
            let train_loss = active
                .iter()
                .map(|nr| {
                    nr.trace.loss_at(step).unwrap_or_else(|| {
                        panic!("worker {} has no loss for round {step}", nr.worker)
                    })
                })
                .sum::<f64>()
                / active.len() as f64;
            let grad_wall = active
                .iter()
                .map(|nr| nr.trace.grad_wall_at(step).unwrap_or(0.0))
                .fold(0.0f64, f64::max);
            let grad_time = self.cfg.grad_time_s.unwrap_or(grad_wall);
            let algo_wall = active
                .iter()
                .map(|nr| nr.trace.algo_wall_at(step).unwrap_or(0.0))
                .fold(0.0f64, f64::max);
            ledger.charge(&stats, grad_time, algo_wall);

            if step % self.cfg.eval_every == 0 || step + 1 == self.cfg.steps {
                let xs: Vec<&[f32]> = active
                    .iter()
                    .map(|nr| {
                        nr.trace.eval_at(step).unwrap_or_else(|| {
                            panic!(
                                "worker {} has no eval snapshot for round {step}",
                                nr.worker
                            )
                        })
                    })
                    .collect();
                let (eval, consensus) =
                    super::eval_mean(self.objective.as_mut(), &xs, &mut mean);
                report.trace.push(TraceRow {
                    step,
                    sim_time_s: ledger.sim_time,
                    train_loss,
                    eval_loss: eval.loss,
                    eval_acc: eval.accuracy,
                    consensus_linf: consensus,
                    bytes_total: ledger.total_bytes,
                    theta: active[0].trace.theta_at(step).flatten(),
                });
            }
        }
        ledger.finish(&mut report);
        // Measured wire bytes split by frame kind, from the telemetry
        // plane (the table prints data vs bootstrap next to the model's
        // payload-only prediction). Lockstep runs leave this None.
        let snap = self.metrics.snapshot();
        report.wire_bytes_by_kind = Some((
            snap.counter(Counter::BytesSentData),
            snap.counter(Counter::BytesSentBootstrap),
        ));
        report.final_params = {
            let last_ep = epoch_at(&self.epochs, self.cfg.steps.saturating_sub(1));
            let xs: Vec<&[f32]> = results
                .iter()
                .filter(|nr| last_ep.active[nr.worker])
                .map(|nr| nr.final_x.as_slice())
                .collect();
            crate::linalg::mean_into(&mut mean, &xs);
            mean.clone()
        };
        report
    }
}

/// θ policy carried by the algorithm selector, if any.
fn theta_policy(a: &Algorithm) -> Option<ThetaPolicy> {
    match a {
        Algorithm::Moniqua { theta, .. }
        | Algorithm::MoniquaSlack { theta, .. }
        | Algorithm::MoniquaD2 { theta, .. } => Some(*theta),
        _ => None,
    }
}

/// Quantizer config carried by the algorithm selector, if any.
fn quant_config(a: &Algorithm) -> Option<crate::quant::QuantConfig> {
    match a {
        Algorithm::NaiveQuant { quant, .. }
        | Algorithm::Moniqua { quant, .. }
        | Algorithm::MoniquaSlack { quant, .. }
        | Algorithm::MoniquaD2 { quant, .. }
        | Algorithm::Dcd { quant, .. }
        | Algorithm::Ecd { quant, .. }
        | Algorithm::Choco { quant, .. }
        | Algorithm::DeepSqueeze { quant, .. } => Some(*quant),
        Algorithm::AllReduce | Algorithm::DPsgd | Algorithm::D2 => None,
    }
}

/// The threaded driver: one OS thread runs one worker's
/// [`RoundStateMachine`] to completion, blocking in abort-aware `recv`
/// whenever the machine reports it is waiting on frames. Expected runtime
/// failures (barrier deadline, transport errors, sibling aborts) come back
/// as typed [`WorkerFailure`]s so the coordinator can name the originating
/// worker; protocol violations (corrupt frames, foreign checkpoints) stay
/// panics — a corrupt cluster must die loudly.
fn run_node(
    i: usize,
    engine: Box<dyn SyncAlgorithm>,
    mut transport: Box<dyn Transport>,
    objective: Box<dyn Objective>,
    spec: NodeSpec<'_>,
    abort: &AbortLatch,
) -> Result<NodeResult, WorkerFailure> {
    // lint: allow(wall_clock) — the wait deadline gates *when* a worker
    // gives up on a barrier, never the bytes of any frame.
    let recv_timeout = spec.recv_timeout;
    let telemetry = spec.telemetry.clone();
    let clock = spec.clock.clone();
    let mut sm = RoundStateMachine::new(i, engine, objective, spec);
    // One deadline per barrier/bootstrap wait, keyed by what the machine
    // is blocked on: an arriving frame never resets the clock, so a
    // trickle of stragglers cannot stretch one "recv_timeout" barrier to
    // peers × recv_timeout.
    let mut wait: Option<(WaitKey, Instant)> = None;
    // Telemetry stamp of the current wait (same key discipline as the
    // deadline): observed into the barrier/bootstrap histogram when the
    // machine moves past it.
    let mut wait_start: Option<(WaitKey, u64)> = None;
    loop {
        match sm.drive(transport.as_mut()) {
            Ok(MachineStatus::Done) => {
                observe_wait_end(&telemetry, &clock, &mut wait_start);
                return Ok(sm.into_result());
            }
            Ok(MachineStatus::Waiting(key)) => {
                let deadline = match wait {
                    Some((k, dl)) if k == key => dl,
                    _ => saturating_deadline(Instant::now(), recv_timeout),
                };
                wait = Some((key, deadline));
                match wait_start {
                    Some((k, _)) if k == key => {}
                    _ => {
                        observe_wait_end(&telemetry, &clock, &mut wait_start);
                        wait_start = Some((key, clock.now_ns()));
                    }
                }
                match recv_until(transport.as_mut(), deadline, abort) {
                    BarrierRecv::Frame(f) => sm.accept_frame(f),
                    BarrierRecv::TimedOut => {
                        return Err(abort.trip(sm.timeout_failure()));
                    }
                    BarrierRecv::Aborted => {
                        return Err(abort.sibling_abort(sm.worker(), sm.round()));
                    }
                    BarrierRecv::Failed(e) => {
                        return Err(abort.trip(sm.recv_failure(&e)));
                    }
                }
            }
            Err(f) => return Err(abort.trip(f)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adversary::ByzMode;
    use crate::algorithms::ThetaPolicy;
    use crate::elastic::MembershipPlan;
    use crate::quant::{Compression, QuantConfig};
    use std::path::PathBuf;

    fn base_cfg(algorithm: Algorithm) -> TrainConfig {
        TrainConfig { workers: 4, steps: 6, eval_every: 2, algorithm, ..TrainConfig::default() }
    }

    fn objective() -> Box<dyn Objective> {
        Box::new(crate::objectives::Quadratic::new(8, 1.0, 0.1, 4, 3))
    }

    fn elastic(spec: &str, ckpt_dir: Option<&str>) -> ClusterConfig {
        ClusterConfig {
            elastic: Some(ElasticConfig {
                plan: MembershipPlan::parse(spec).unwrap(),
                ckpt_every: 2,
                ckpt_dir: ckpt_dir.map(PathBuf::from),
                skip_bootstrap: false,
            }),
            ..ClusterConfig::default()
        }
    }

    #[test]
    fn refuses_theorem2_theta() {
        let cfg = base_cfg(Algorithm::Moniqua {
            theta: ThetaPolicy::Theorem2 { warmup: 5, safety: 2.0 },
            quant: QuantConfig::stochastic(8),
        });
        let err = ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            ClusterConfig::default(),
        );
        assert!(err.is_err());
    }

    #[test]
    fn integrity_gate_covers_every_engine_or_refuses_loudly() {
        // Quantized baselines cannot price the +8 B seal tail: refused,
        // exactly like the pre-seal refusal of verify_hash outside the
        // Moniqua family.
        let cfg = base_cfg(Algorithm::Dcd {
            quant: QuantConfig::stochastic(8).with_verify_hash(true),
            range: 4.0,
        });
        assert!(ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            ClusterConfig::default(),
        )
        .is_err());
        // Moniqua ships its own §6 digest inside the payload: accepted,
        // no machine seal.
        let cfg = base_cfg(Algorithm::Moniqua {
            theta: ThetaPolicy::Constant(2.0),
            quant: QuantConfig::stochastic(8).with_verify_hash(true),
        });
        assert!(ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            ClusterConfig::default(),
        )
        .is_ok());
        // …but refuses the machine seal on top (it would double-charge the
        // wire and double-gate every frame).
        let cfg = TrainConfig {
            verify_wire: true,
            ..base_cfg(Algorithm::Moniqua {
                theta: ThetaPolicy::Constant(2.0),
                quant: QuantConfig::stochastic(8),
            })
        };
        assert!(ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            ClusterConfig::default(),
        )
        .is_err());
        // Raw-f32 engines price the seal through verify_wire and the
        // measured-vs-predicted byte equation still closes with the +8 B
        // tail on every data frame.
        let cfg = TrainConfig { verify_wire: true, ..base_cfg(Algorithm::DPsgd) };
        let mut t = ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            ClusterConfig::default(),
        )
        .unwrap();
        let report = t.run().unwrap();
        assert_eq!(
            t.wire_bytes_sent,
            report.total_bytes + t.frames_sent * crate::transport::HEADER_LEN as u64
        );
    }

    #[test]
    fn flip_adversary_is_excised_and_the_run_completes() {
        // Worker 2 flips a payload byte after sealing: both ring neighbors
        // reject its frames at the gate, convict it after two strikes, and
        // re-derive their gossip rows over the survivors. The run finishes
        // with finite models and the counters narrate the story.
        let cfg = TrainConfig { verify_wire: true, ..base_cfg(Algorithm::DPsgd) };
        let mut t = ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            ClusterConfig {
                byz: Some(ByzantineConfig {
                    workers: 0b100,
                    mode: ByzMode::Flip,
                    strike_limit: 2,
                }),
                ..ClusterConfig::default()
            },
        )
        .unwrap();
        let report = t.run().unwrap();
        assert!(t.failures.is_empty());
        assert!(report.final_params.iter().all(|v| v.is_finite()));
        let snap = t.metrics().snapshot();
        // Two honest neighbors each struck worker 2 twice before convicting.
        assert!(snap.counter(Counter::DigestRejects) >= 4);
        assert_eq!(snap.counter(Counter::QuarantinedPeers), 2);
    }

    #[test]
    fn refuses_compressed_streams() {
        let cfg = base_cfg(Algorithm::Moniqua {
            theta: ThetaPolicy::Constant(2.0),
            quant: QuantConfig::stochastic(8).with_compression(Compression::Rle),
        });
        assert!(ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            ClusterConfig::default(),
        )
        .is_err());
    }

    #[test]
    fn refuses_crash_plan_without_ckpt_dir() {
        let cfg = base_cfg(Algorithm::DPsgd);
        assert!(ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            elastic("crash@3:1", None),
        )
        .is_err());
    }

    #[test]
    fn refuses_churn_on_swap_refusing_engines() {
        // moniqua-slack carries a derived (slack) matrix: joins/leaves are
        // refused, crash-only plans are accepted.
        let slack = || {
            base_cfg(Algorithm::MoniquaSlack {
                theta: ThetaPolicy::Constant(2.0),
                quant: QuantConfig::stochastic(8),
                gamma: 0.3,
            })
        };
        assert!(ClusterTrainer::new(
            slack(),
            Topology::Ring(4),
            objective(),
            elastic("leave@3:1", Some("/tmp/moniqua-never-used")),
        )
        .is_err());
        assert!(ClusterTrainer::new(
            slack(),
            Topology::Ring(4),
            objective(),
            elastic("crash@3:1", Some("/tmp/moniqua-never-used")),
        )
        .is_ok());
        // DCD keeps per-neighbor replicas: same refusal.
        assert!(ClusterTrainer::new(
            base_cfg(Algorithm::Dcd { quant: QuantConfig::stochastic(8), range: 4.0 }),
            Topology::Ring(4),
            objective(),
            elastic("leave@3:1", Some("/tmp/moniqua-never-used")),
        )
        .is_err());
    }

    #[test]
    fn mem_cluster_trains_and_reports() {
        let cfg = base_cfg(Algorithm::DPsgd);
        let mut t = ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            ClusterConfig::default(),
        )
        .unwrap();
        let report = t.run().unwrap();
        assert_eq!(report.trace.len(), 4); // steps 0,2,4,5
        assert!(t.frames_sent > 0);
        assert!(t.wire_bytes_sent as usize > report.total_bytes as usize);
        assert_eq!(report.final_params.len(), 8);
        // The telemetry plane and the per-node traces count the same wire:
        // frames and bytes must agree exactly, and nothing may be lost in
        // flight (conservation).
        let snap = t.metrics().snapshot();
        assert_eq!(snap.frames_sent(), t.frames_sent);
        assert_eq!(
            snap.counter(Counter::BytesSentData)
                + snap.counter(Counter::BytesSentBootstrap),
            t.wire_bytes_sent
        );
        assert_eq!(
            snap.frames_sent(),
            snap.frames_received() + snap.counter(Counter::FramesRejected)
        );
        assert_eq!(report.wire_bytes_by_kind, Some((t.wire_bytes_sent, 0)));
    }

    #[test]
    fn reactor_driver_trains_and_reports() {
        let cfg = base_cfg(Algorithm::DPsgd);
        let mut t = ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            ClusterConfig {
                driver: DriverKind::Reactor { threads: 2 },
                ..ClusterConfig::default()
            },
        )
        .unwrap();
        let report = t.run().unwrap();
        assert_eq!(report.trace.len(), 4);
        assert!(t.frames_sent > 0);
        assert!(t.failures.is_empty());
        assert_eq!(report.final_params.len(), 8);
    }

    #[test]
    fn membership_run_with_leave_and_rejoin() {
        let dir = std::env::temp_dir()
            .join(format!("moniqua-cluster-churn-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = TrainConfig {
            workers: 4,
            steps: 10,
            eval_every: 3,
            algorithm: Algorithm::DPsgd,
            ..TrainConfig::default()
        };
        let mut t = ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            ClusterConfig {
                elastic: Some(ElasticConfig {
                    plan: MembershipPlan::parse("leave@3:2,join@7:2").unwrap(),
                    ckpt_every: 0,
                    ckpt_dir: Some(dir.clone()),
                    skip_bootstrap: false,
                }),
                ..ClusterConfig::default()
            },
        )
        .unwrap();
        let report = t.run().unwrap();
        assert_eq!(report.trace.len(), 4); // steps 0, 3, 6, 9 (9 is also last)
        assert!(report.final_params.iter().all(|v| v.is_finite()));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
